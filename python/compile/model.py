"""Layer 2: the quantized Transformer encoder in JAX, calling the Pallas
kernels (Layer 1). Build-time only — lowered once to HLO text by aot.py and
executed from rust via PJRT; Python is never on the request path.

The model follows the paper's evaluation networks (footnotes 4-6):

  MobileBERT         S=128, E=128, P=64, H=4,  N=24, d_ff=512  (4 stacked FFNs)
  DINOv2-Small       S=241, E=384, P=64, H=6,  N=12, d_ff=1536 (padded to S=256)
  Whisper-Tiny enc.  S=512, E=384, P=64, H=6,  N=4,  d_ff=1536

All arithmetic is 8-bit integer (int32 containers) with ITA's exact
semantics: GEMMs/attention use the Pallas kernels; LayerNorm and residual
adds use the integer "cluster core" ops from kernels.quant (these run on
the Snitch cores in the paper — ITA does not support them).

Weight layout per encoder layer (synthetic int8 weights; the paper's
metrics are activity/latency/energy, never task accuracy):
  wq, wk, wv : (H, E, P)    bq, bk, bv : (H, P)
  wo         : (H, P, E)    bo         : (E,)
  w1         : (F, E, d_ff) b1         : (F, d_ff)     F = ffn_stack
  w2         : (F, d_ff, E) b2         : (F, E)
  ln1_g/b    : (E,)         ln2_g/b    : (F, E)
"""

import dataclasses
import math

import numpy as np
import jax.numpy as jnp

from .kernels import ita_attention, ita_gemm
from .kernels.quant import clip_i8, ilayernorm, requant


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Geometry of one evaluation network (paper footnotes 4-6)."""

    name: str
    seq: int  # padded sequence length at ITA boundaries
    seq_logical: int  # the paper's sequence length (GOp accounting)
    emb: int
    proj: int
    heads: int
    layers: int
    dff: int
    ffn_stack: int = 1  # MobileBERT stacks 4 FFNs per block
    act: str = "gelu"
    gop_per_inference: float = 0.0  # paper-reported GOp (footnotes)


MOBILEBERT = ModelConfig(
    "mobilebert", 128, 128, 128, 64, 4, 24, 512, ffn_stack=4, act="relu",
    gop_per_inference=4.74,
)
DINOV2S = ModelConfig(
    "dinov2s", 256, 241, 384, 64, 6, 12, 1536, gop_per_inference=11.7
)
WHISPER_TINY_ENC = ModelConfig(
    "whisper_tiny_enc", 512, 512, 384, 64, 6, 4, 1536, gop_per_inference=9.74
)

CONFIGS = {c.name: c for c in (MOBILEBERT, DINOV2S, WHISPER_TINY_ENC)}


def rq_for(k_dim, target_std=30.0):
    """Requantization (mult, shift) for a GEMM with reduction dim k_dim.

    Chosen so int8 activations with std ~74 (uniform) map back to std
    ~target_std after the GEMM — keeps every layer in live int8 range.
    Deterministic; mirrored by rust models::rq_for.
    """
    acc_std = math.sqrt(k_dim) * 74.0 * 74.0
    ratio = target_std / acc_std
    shift = 14
    mult = max(1, round(ratio * (1 << shift)))
    return mult, shift


def rq_params(cfg: ModelConfig):
    """All requant params of one encoder layer, keyed as ref.mha expects."""
    qm, qs = rq_for(cfg.emb)
    qkm, qks = rq_for(cfg.proj, target_std=40.0)  # logits: slightly hotter
    avm, avs = rq_for(128, target_std=30.0)  # A rows sum to ~128 (scale 1/128)
    om, os_ = rq_for(cfg.proj * cfg.heads)
    f1m, f1s = rq_for(cfg.emb)
    f2m, f2s = rq_for(cfg.dff)
    lnm, lns = 16, 12  # layernorm output gain
    return {
        "q_mult": qm, "q_shift": qs,
        "k_mult": qm, "k_shift": qs,
        "v_mult": qm, "v_shift": qs,
        "qk_mult": qkm, "qk_shift": qks,
        "av_mult": avm, "av_shift": avs,
        "o_mult": om, "o_shift": os_,
        "ffn1_mult": f1m, "ffn1_shift": f1s,
        "ffn2_mult": f2m, "ffn2_shift": f2s,
        "ln_mult": lnm, "ln_shift": lns,
    }


GELU_S = 0.1  # activation scale fed to i-GeLU (fixed, see quant.igelu)


def mha(x, wq, wk, wv, wo, bq, bk, bv, bo, rq, cfg: ModelConfig):
    """Multi-head attention, head-by-head as ITA executes it (Pallas L1).

    Partial per-head output projections are accumulated in int32 (the
    cluster's head-accumulation layer) and requantized once.
    """
    s, e = x.shape
    acc = jnp.zeros((s, e), dtype=jnp.int32)
    for h in range(cfg.heads):
        q = ita_gemm.gemm_rq(x, wq[h], bq[h], rq["q_mult"], rq["q_shift"])
        k = ita_gemm.gemm_rq(x, wk[h], bk[h], rq["k_mult"], rq["k_shift"])
        v = ita_gemm.gemm_rq(x, wv[h], bv[h], rq["v_mult"], rq["v_shift"])
        o = ita_attention.attention_head(
            q, k, v, rq["qk_mult"], rq["qk_shift"], rq["av_mult"], rq["av_shift"]
        )
        acc = acc + jnp.matmul(
            o, wo[h].astype(jnp.int32), preferred_element_type=jnp.int32
        )
    acc = acc + bo.astype(jnp.int32)
    return requant(acc, rq["o_mult"], rq["o_shift"])


def encoder_layer(
    x, wq, wk, wv, wo, bq, bk, bv, bo, w1, b1, w2, b2,
    ln1_g, ln1_b, ln2_g, ln2_b, cfg: ModelConfig,
):
    """One pre-LN encoder block in ITA integer semantics.

    x: (S, E) int8-range int32. Residual adds saturate to int8 (the
    cluster's requant-add). Returns (S, E) int8-range.
    """
    rq = rq_params(cfg)

    h = ilayernorm(x, ln1_g, ln1_b, rq["ln_mult"], rq["ln_shift"])
    attn = mha(h, wq, wk, wv, wo, bq, bk, bv, bo, rq, cfg)
    x = clip_i8(x + attn)

    for f in range(cfg.ffn_stack):
        h = ilayernorm(x, ln2_g[f], ln2_b[f], rq["ln_mult"], rq["ln_shift"])
        u = ita_gemm.gemm_rq(
            h, w1[f], b1[f], rq["ffn1_mult"], rq["ffn1_shift"],
            act=cfg.act, gelu_s=GELU_S,
        )
        d = ita_gemm.gemm_rq(u, w2[f], b2[f], rq["ffn2_mult"], rq["ffn2_shift"])
        x = clip_i8(x + d)
    return x


def layer_weight_shapes(cfg: ModelConfig):
    """Argument order + shapes of encoder_layer weights (AOT manifest)."""
    e, p, h, f, dff = cfg.emb, cfg.proj, cfg.heads, cfg.ffn_stack, cfg.dff
    return [
        ("wq", (h, e, p)), ("wk", (h, e, p)), ("wv", (h, e, p)),
        ("wo", (h, p, e)),
        ("bq", (h, p)), ("bk", (h, p)), ("bv", (h, p)), ("bo", (e,)),
        ("w1", (f, e, dff)), ("b1", (f, dff)),
        ("w2", (f, dff, e)), ("b2", (f, e)),
        ("ln1_g", (e,)), ("ln1_b", (e,)),
        ("ln2_g", (f, e)), ("ln2_b", (f, e)),
    ]


# --- deterministic synthetic weights (mirrored by rust models::synth) -------

_SPLITMIX_GAMMA = 0x9E3779B97F4A7C15
_MASK64 = (1 << 64) - 1


def splitmix64(x):
    """splitmix64 finalizer — pure function of the index (vectorizable)."""
    x = np.asarray(x, dtype=np.uint64)
    x = (x + np.uint64(_SPLITMIX_GAMMA)) & np.uint64(_MASK64)
    x ^= x >> np.uint64(30)
    x = (x * np.uint64(0xBF58476D1CE4E5B9)) & np.uint64(_MASK64)
    x ^= x >> np.uint64(27)
    x = (x * np.uint64(0x94D049BB133111EB)) & np.uint64(_MASK64)
    x ^= x >> np.uint64(31)
    return x


def fnv1a(s):
    """FNV-1a 64-bit hash of a string — tensor-name keying."""
    h = 0xCBF29CE484222325
    for ch in s.encode():
        h = ((h ^ ch) * 0x100000001B3) & _MASK64
    return h


def synth_tensor(name, shape, kind, seed=0):
    """Deterministic synthetic tensor: value_i = f(seed, name, i).

    kind: 'w' int8 weights, 'b' small biases, 'g' gamma [32,96), 'beta'
    [-16,16). Bit-identical to rust models::synth_tensor.
    """
    n = int(np.prod(shape))
    key = (fnv1a(name) ^ (np.uint64(seed) * np.uint64(_SPLITMIX_GAMMA))) & np.uint64(
        _MASK64
    )
    with np.errstate(over="ignore"):
        r = splitmix64(np.arange(n, dtype=np.uint64) + key)
    if kind == "w":
        vals = (r & np.uint64(0xFF)).astype(np.int64) - 128
    elif kind == "b":
        vals = (r & np.uint64(0xFFF)).astype(np.int64) - 2048
    elif kind == "g":
        vals = (r & np.uint64(0x3F)).astype(np.int64) + 32
    elif kind == "beta":
        vals = (r & np.uint64(0x1F)).astype(np.int64) - 16
    else:
        raise ValueError(kind)
    return vals.astype(np.int32).reshape(shape)


def _kind_of(name):
    if name.endswith("_g"):
        return "g"
    if name.endswith("_b") and name.startswith("ln"):
        return "beta"
    return "w" if name.startswith("w") else "b"


def synth_layer_weights(cfg: ModelConfig, layer_idx=0, seed=0):
    """All weights of one encoder layer, keyed by (seed, layer, name)."""
    out = []
    for name, shape in layer_weight_shapes(cfg):
        key = f"{cfg.name}/L{layer_idx}/{name}"
        out.append((name, synth_tensor(key, shape, _kind_of(name), seed=seed)))
    return out


def synth_input(cfg: ModelConfig, seed=1):
    """Deterministic synthetic int8 input activation (S, E)."""
    t = synth_tensor(f"{cfg.name}/input", (cfg.seq, cfg.emb), "w", seed=seed)
    return t


def forward(cfg: ModelConfig, x, all_weights):
    """Full-network forward: N encoder layers (build-time reference)."""
    for li in range(cfg.layers):
        w = dict(all_weights[li])
        x = encoder_layer(
            x, w["wq"], w["wk"], w["wv"], w["wo"], w["bq"], w["bk"], w["bv"],
            w["bo"], w["w1"], w["b1"], w["w2"], w["b2"],
            w["ln1_g"], w["ln1_b"], w["ln2_g"], w["ln2_b"], cfg,
        )
    return x
