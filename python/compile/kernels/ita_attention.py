"""Pallas kernels for ITA's attention hot path (Layer 1).

Two kernels mirror ITA's two-phase dataflow (Fig. 2 of the paper):

  qk_itamax   — Q x K^T tiles + the streaming DA stage: as each quantized
                QK tile is produced, the running row max and renormalized
                denominator are updated in carry buffers. This is the
                hardware's "Softmax without additional latency": the DA
                stage rides on the QK producer.
  av_en       — DI + EN + A x V: the denominator is inverted once, the
                stored QK logits are normalized on the fly (never
                materializing A in memory ahead of time) and multiplied
                with V tiles into a partial-sum accumulator, requantized
                at the last tile.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): ITA's 16x64
dot-product array becomes (64, 64) MXU-shaped tiles; the streamers'
HBM<->VMEM schedule is expressed with BlockSpec index maps; the DA chunk
order (16 elements) is preserved inside each tile so the result is
bit-exact against the `ref.py` / `quant.py` streaming spec.

All kernels run with interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); on a real TPU the same code lowers to Mosaic.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import quant
from .quant import (
    ITA_DA_CHUNK,
    ITA_INV_BITS,
    ITA_EN_SHIFT,
    ITA_A_MAX,
    ITAMAX_M0,
    exp2_num,
    renorm_den,
    requant,
)

DEFAULT_TILE = 64  # ITA processes 64-wide tiles (M = 64 vector length)


def _qk_itamax_kernel(
    q_ref, k_ref, lut_ref, qk_ref, m_ref, den_ref, *, mult, shift, t_kv
):
    """Grid step i: produce quantized QK tile i and fold it into (m, den)."""
    i = pl.program_id(0)
    lut = lut_ref[...]

    acc = jnp.dot(q_ref[...], k_ref[...].T, preferred_element_type=jnp.int32)
    qk = requant(acc, mult, shift)
    qk_ref[...] = qk

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref[...], -ITAMAX_M0)
        den_ref[...] = jnp.zeros_like(den_ref[...])

    # DA stage: scan the tile in the hardware's 16-element chunk order.
    m = m_ref[...]  # (S, 1)
    den = den_ref[...]  # (S, 1)
    for c in range(t_kv // ITA_DA_CHUNK):
        chunk = qk[:, c * ITA_DA_CHUNK : (c + 1) * ITA_DA_CHUNK]
        lm = jnp.max(chunk, axis=-1, keepdims=True)
        m_new = jnp.maximum(m, lm)
        delta = m_new - m
        den = renorm_den(den, delta, lut=lut)
        den = den + jnp.sum(exp2_num(m_new - chunk, lut=lut), axis=-1, keepdims=True)
        m = m_new
    m_ref[...] = m
    den_ref[...] = den


def qk_itamax(q, k, mult, shift, t_kv=DEFAULT_TILE):
    """Phase 1: QK = requant(Q @ K^T) with streaming ITAMax statistics.

    q: (S, P), k: (S_kv, P) int8-range int32. Returns (qk, m, den):
    qk (S, S_kv) int8-range, m/den (S, 1) int32 running max/denominator.
    """
    s, p = q.shape
    s_kv = k.shape[0]
    assert s_kv % t_kv == 0 and t_kv % ITA_DA_CHUNK == 0
    n_kv = s_kv // t_kv
    kernel = functools.partial(_qk_itamax_kernel, mult=mult, shift=shift, t_kv=t_kv)
    return pl.pallas_call(
        kernel,
        grid=(n_kv,),
        in_specs=[
            pl.BlockSpec((s, p), lambda i: (0, 0)),  # Q resident across tiles
            pl.BlockSpec((t_kv, p), lambda i: (i, 0)),  # K streamed tile by tile
            pl.BlockSpec((32,), lambda i: (0,)),  # EXP2 LUT
        ],
        out_specs=[
            pl.BlockSpec((s, t_kv), lambda i: (0, i)),
            pl.BlockSpec((s, 1), lambda i: (0, 0)),  # carry: running max
            pl.BlockSpec((s, 1), lambda i: (0, 0)),  # carry: denominator
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, s_kv), jnp.int32),
            jax.ShapeDtypeStruct((s, 1), jnp.int32),
            jax.ShapeDtypeStruct((s, 1), jnp.int32),
        ],
        interpret=True,
    )(
        q.astype(jnp.int32),
        k.astype(jnp.int32),
        jnp.asarray(quant.EXP2_LUT, dtype=jnp.int32),
    )


def _av_en_kernel(
    qk_ref, m_ref, den_ref, v_ref, lut_ref, acc_ref, o_ref, *, mult, shift, n_kv
):
    """Grid step i: EN-normalize QK tile i on the fly and accumulate A @ V."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref[...])

    inv = (1 << ITA_INV_BITS) // den_ref[...]  # DI stage (cheap, rematerialized)
    num = exp2_num(m_ref[...] - qk_ref[...], lut=lut_ref[...])
    a = jnp.minimum((num * inv) >> ITA_EN_SHIFT, ITA_A_MAX)
    acc_ref[...] += jnp.dot(a, v_ref[...], preferred_element_type=jnp.int32)

    @pl.when(i == n_kv - 1)
    def _final():
        o_ref[...] = requant(acc_ref[...], mult, shift)


def av_en(qk, m, den, v, mult, shift, t_kv=DEFAULT_TILE):
    """Phase 2: O = requant(EN(QK) @ V) with on-the-fly normalization.

    qk: (S, S_kv) quantized logits from phase 1, m/den: (S, 1) statistics,
    v: (S_kv, P). Returns (S, P) int8-range output.
    """
    s, s_kv = qk.shape
    p = v.shape[1]
    assert s_kv % t_kv == 0
    n_kv = s_kv // t_kv
    kernel = functools.partial(_av_en_kernel, mult=mult, shift=shift, n_kv=n_kv)
    _, o = pl.pallas_call(
        kernel,
        grid=(n_kv,),
        in_specs=[
            pl.BlockSpec((s, t_kv), lambda i: (0, i)),
            pl.BlockSpec((s, 1), lambda i: (0, 0)),
            pl.BlockSpec((s, 1), lambda i: (0, 0)),
            pl.BlockSpec((t_kv, p), lambda i: (i, 0)),
            pl.BlockSpec((32,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((s, p), lambda i: (0, 0)),  # partial-sum buffer
            pl.BlockSpec((s, p), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, p), jnp.int32),
            jax.ShapeDtypeStruct((s, p), jnp.int32),
        ],
        interpret=True,
    )(qk, m, den, v.astype(jnp.int32), jnp.asarray(quant.EXP2_LUT, dtype=jnp.int32))
    return o


def attention_head(q, k, v, qk_mult, qk_shift, av_mult, av_shift, t_kv=DEFAULT_TILE):
    """Single-head quantized attention, both phases. Matches ref.attention_head."""
    qk, m, den = qk_itamax(q, k, qk_mult, qk_shift, t_kv=t_kv)
    return av_en(qk, m, den, v, av_mult, av_shift, t_kv=t_kv)
