"""Standalone Pallas ITAMax kernel (Layer 1).

Row-wise streaming integer softmax as its own kernel — used when the
deployment flow needs softmax *outside* a fused attention (e.g. a final
classification head), and as the minimal demonstrator of the DA/DI/EN
pipeline. Grid over row blocks; within the kernel the DA stage scans the
hardware's 16-element chunk order, so results are bit-exact with
`quant.itamax` and the rust `ita::softmax` model.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import quant
from .quant import (
    ITA_DA_CHUNK,
    ITA_INV_BITS,
    ITA_EN_SHIFT,
    ITA_A_MAX,
    ITAMAX_M0,
    exp2_num,
    renorm_den,
)


def _itamax_kernel(x_ref, lut_ref, a_ref, *, cols):
    x = x_ref[...]
    lut = lut_ref[...]
    m = jnp.full((x.shape[0], 1), -ITAMAX_M0, dtype=jnp.int32)
    den = jnp.zeros((x.shape[0], 1), dtype=jnp.int32)
    # DA: 16-element chunks, streaming renormalization
    for c in range(cols // ITA_DA_CHUNK):
        chunk = x[:, c * ITA_DA_CHUNK : (c + 1) * ITA_DA_CHUNK]
        lm = jnp.max(chunk, axis=-1, keepdims=True)
        m_new = jnp.maximum(m, lm)
        den = renorm_den(den, m_new - m, lut=lut)
        den = den + jnp.sum(exp2_num(m_new - chunk, lut=lut), axis=-1, keepdims=True)
        m = m_new
    # DI + EN
    inv = (1 << ITA_INV_BITS) // den
    num = exp2_num(m - x, lut=lut)
    a_ref[...] = jnp.minimum((num * inv) >> ITA_EN_SHIFT, ITA_A_MAX)


def itamax(x, block_rows=64):
    """Row-wise ITAMax over a (R, C) int8-range matrix; C % 16 == 0."""
    rows, cols = x.shape
    assert cols % ITA_DA_CHUNK == 0, f"cols={cols}"
    br = min(block_rows, rows)
    assert rows % br == 0, (rows, br)
    kernel = functools.partial(_itamax_kernel, cols=cols)
    return pl.pallas_call(
        kernel,
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, cols), lambda i: (i, 0)),
            pl.BlockSpec((32,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.int32),
        interpret=True,
    )(x.astype(jnp.int32), jnp.asarray(quant.EXP2_LUT, dtype=jnp.int32))
