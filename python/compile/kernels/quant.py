"""Shared integer-arithmetic primitives of the ITA datapath.

This module is the *specification* of ITA's integer semantics. Three
implementations must agree bit-exactly:

  1. these jnp functions (used by the L2 model and the pure-jnp oracle),
  2. the Pallas kernels in this package (streaming formulation),
  3. the rust functional model in ``rust/src/ita/`` (checked end-to-end by
     running the AOT artifacts through PJRT from rust and comparing).

All tensors at ITA boundaries are int8 carried in int32 containers (the
HLO interface uses i32 for portability across the PJRT literal API; values
are kept in int8 range by construction).

ITAMax numeric spec
-------------------
ITA computes a base-2 softmax (the log2(e) factor is absorbed into the
requantization scale of the Q×K^T output, as in Softermax):

  softmax2(x)_i = 2^((x_i - max(x)) / 2^F) / sum_j 2^((x_j - max(x)) / 2^F)

with F = ITA_F = 5 fractional bits. For an int difference d = max - x_i >= 0:

  shift = min(d >> F, 31)          # integer part of the exponent
  frac  = d & (2^F - 1)            # fractional part
  num_i = EXP2_LUT[frac] >> shift  # in [0, 256], EXP2_LUT[f] = round(256 * 2^(-f/32))

The denominator is the exact integer sum of the numerators; the
Denominator-Inversion stage computes inv = floor(2^24 / den) and the
Element-Normalization stage emits

  A_i = min((num_i * inv) >> 17, 127)   # A in [0, 127], scale 1/2^7

so a row of A sums to ~128 (quantized probabilities).

Streaming renormalization: when the running max grows by delta, the
accumulated denominator is rescaled by 2^(-delta / 2^F):

  acc <- (acc * EXP2_LUT[delta & 31]) >> (8 + (delta >> 5))

which is one multiply and one shift — the cheap renormalization the paper's
DA stage performs in hardware.
"""

import numpy as np
import jax.numpy as jnp
from jax import lax

# --- ITAMax constants -------------------------------------------------------

ITA_F = 5  # fractional bits of the base-2 exponent
EXP2_LUT_LIST = [int(round(256 * 2 ** (-i / 32))) for i in range(32)]
EXP2_LUT = np.asarray(EXP2_LUT_LIST, dtype=np.int32)
ITA_INV_BITS = 24  # Denominator-Inversion precision
ITA_EN_SHIFT = 17  # Element-Normalization output shift -> A scale = 1/128
ITA_A_MAX = 127

# ITA geometry (Section IV-B of the paper)
ITA_N_UNITS = 16  # dot-product units
ITA_M = 64  # vector length per dot-product unit
ITA_ACC_BITS = 26  # accumulator width

# i-GeLU polynomial constants (I-BERT, Kim et al. 2021)
IGELU_A = -0.2888
IGELU_B = -1.769


def clip_i8(x):
    """Clip an int32 tensor into int8 value range."""
    return jnp.clip(x, -128, 127)


def requant(acc, mult, shift, zero=0):
    """ITA/Deeploy requantization: (acc * mult + round) >> shift, clipped.

    ``acc`` int32, ``mult``/``shift``/``zero`` python ints. Rounding adds
    half an LSB before the arithmetic right shift, matching the PULP RQS
    hardware and the rust model (`ita::quant::requant`).
    """
    acc = acc.astype(jnp.int32) * jnp.int32(mult)
    rnd = jnp.int32(1 << (shift - 1)) if shift > 0 else jnp.int32(0)
    shifted = (acc + rnd) >> shift
    return clip_i8(shifted + jnp.int32(zero))


def lut_lookup(lut, idx):
    """LUT lookup as a one-hot contraction (gather-free).

    The AOT interchange path (jax 0.8 MLIR -> HLO text -> xla_extension
    0.5.1) mis-executes HLO gather (it returns the *indices*), so every LUT
    access is expressed as compare+multiply+reduce instead. Bit-exact with
    a real gather, lowers to vectorizable ops everywhere, and on a real TPU
    the one-hot form is MXU-friendly.

    lut: (32,) int32, idx: any-shape int32 in [0, 32).
    """
    iota = lax.broadcasted_iota(jnp.int32, idx.shape + (32,), len(idx.shape))
    onehot = (idx[..., None] == iota).astype(jnp.int32)
    return jnp.sum(onehot * lut, axis=-1)


def exp2_num(diff, lut=None):
    """Numerator of the base-2 softmax for non-negative diff = max - x.

    ``lut`` lets Pallas kernels pass the EXP2 table through a Ref (captured
    constants are not allowed inside pallas_call bodies).
    """
    diff = diff.astype(jnp.int32)
    shift = jnp.minimum(diff >> ITA_F, 31)
    frac = diff & ((1 << ITA_F) - 1)
    if lut is None:
        lut = jnp.asarray(EXP2_LUT, dtype=jnp.int32)
    return lut_lookup(lut, frac) >> shift


def renorm_den(acc, delta, lut=None):
    """Streaming DA renormalization of the partial denominator.

    acc * 2^(-delta/32) using one LUT multiply and a shift. The shift is
    clamped to 31 (values there are zero anyway for int8-range rows) so the
    behaviour is defined and identical in jnp / Pallas / rust.
    """
    if lut is None:
        lut = jnp.asarray(EXP2_LUT, dtype=jnp.int32)
    shift = jnp.minimum(8 + (delta >> ITA_F), 31)
    return (acc * lut_lookup(lut, delta & 31)) >> shift


# DA stage processes this many elements per step (the N=16 dot-product
# units emit 16 row elements per cycle). The streaming denominator is NOT
# bit-identical to a batch max/sum — the spec is this exact chunk order,
# and all three implementations follow it.
ITA_DA_CHUNK = 16
ITAMAX_M0 = 1 << 20  # initial running max = -ITAMAX_M0


def itamax_stats(x):
    """DA stage over the last axis: streaming (max, den) per row.

    x: (..., S) int8-range values, S % ITA_DA_CHUNK == 0. Scans chunks of
    16 elements carrying the running max and the renormalized denominator,
    exactly as the hardware's DA stage does. Returns (m, den) with
    keepdims, int32.
    """
    x = x.astype(jnp.int32)
    s = x.shape[-1]
    assert s % ITA_DA_CHUNK == 0, f"S={s} not a multiple of {ITA_DA_CHUNK}"
    lead = x.shape[:-1]
    xr = x.reshape(-1, s // ITA_DA_CHUNK, ITA_DA_CHUNK)
    xs = jnp.swapaxes(xr, 0, 1)  # (chunks, rows, 16)

    def step(carry, chunk):
        m, den = carry
        lm = jnp.max(chunk, axis=-1)
        m_new = jnp.maximum(m, lm)
        delta = m_new - m
        den = renorm_den(den, delta)
        den = den + jnp.sum(exp2_num(m_new[:, None] - chunk), axis=-1)
        return (m_new, den), None

    rows = xs.shape[1]
    m0 = jnp.full((rows,), -ITAMAX_M0, dtype=jnp.int32)
    d0 = jnp.zeros((rows,), dtype=jnp.int32)
    (m, den), _ = lax.scan(step, (m0, d0), xs)
    return m.reshape(*lead, 1), den.reshape(*lead, 1)


def itamax_inv(den):
    """DI stage: inv = floor(2^24 / den)."""
    return (1 << ITA_INV_BITS) // den


def itamax_en(x, m, inv):
    """EN stage: normalize on the fly, emitting A in [0, 127]."""
    num = exp2_num(m - x.astype(jnp.int32))
    a = (num * inv) >> ITA_EN_SHIFT
    return jnp.minimum(a, ITA_A_MAX)


def itamax(x):
    """Full ITAMax over the last axis: DA -> DI -> EN."""
    m, den = itamax_stats(x)
    return itamax_en(x, m, itamax_inv(den))


# --- i-GeLU (I-BERT) --------------------------------------------------------


def igelu_consts(s_in):
    """Precompute the integer constants of i-GeLU for input scale ``s_in``.

    Returns (b_int, c_int, sig_mult, sig_shift) used identically by the jnp
    reference, the Pallas kernel, and rust ``ita::gelu``. ``sig_mult/shift``
    fold the output scale s_out = s_in * a * s_erf^2 / 2 into a requant to
    int8 at scale s_in (so GeLU is a drop-in on the int8 tensor).
    """
    s_erf = s_in / np.sqrt(2.0)
    b_int = int(np.floor(IGELU_B / s_erf))
    c_int = int(np.floor(1.0 / (IGELU_A * s_erf * s_erf)))
    s_out = s_in * (IGELU_A * s_erf * s_erf) / 2.0
    # requant factor from s_out to s_in: s_out / s_in = a*s_erf^2/2
    ratio = s_out / s_in
    sig_shift = 20
    sig_mult = int(round(ratio * (1 << sig_shift)))
    # int32-overflow guard: |q| <= 128, |q_erf + q_one| <= 2|c_int|
    assert 128 * 2 * abs(c_int) * abs(sig_mult) < 2**31, (
        f"igelu constants overflow i32 for s_in={s_in}"
    )
    return b_int, c_int, sig_mult, sig_shift


def igelu(q, s_in):
    """Integer GeLU on int8-range values ``q`` (int32 container).

    i-GeLU from I-BERT: erf approximated by a clipped parabola, everything
    in integer arithmetic. Output is int8 range at the same scale as the
    input (requantized internally).
    """
    b_int, c_int, sig_mult, sig_shift = igelu_consts(s_in)
    q = q.astype(jnp.int32)
    sgn = jnp.sign(q)
    q_abs = jnp.abs(q)
    q_clip = jnp.minimum(q_abs, jnp.int32(-b_int))
    t = q_clip + jnp.int32(b_int)  # <= 0
    q_erf = sgn * (t * t + jnp.int32(c_int))
    q_one = jnp.int32(c_int)  # erf(+inf) in the same scale: 1/(a*s_erf^2)
    acc = q * (q_erf + q_one)
    # requant: acc * s_out -> int8 at scale s_in. All int32: for s_in >=
    # 0.05, |acc * sig_mult| < 2^31 (checked in igelu_consts) — the rust
    # model uses the same i32 arithmetic.
    out = (acc * jnp.int32(sig_mult)) >> sig_shift
    return clip_i8(out)


def irelu(q):
    """Integer ReLU."""
    return jnp.maximum(q.astype(jnp.int32), 0)


# --- integer sqrt + LayerNorm (I-BERT style, runs on cluster cores) ---------

ISQRT_ITERS = 16


def isqrt(n):
    """Integer Newton sqrt, fixed 16 iterations — bit-exact vs rust.

    n: int32 >= 0. Returns floor-ish sqrt (exact floor after convergence
    for n < 2^31; the fixed iteration count keeps jnp/rust in lockstep).
    """
    n = n.astype(jnp.int32)

    def body(_, x):
        x_safe = jnp.maximum(x, 1)
        return (x_safe + n // x_safe) >> 1

    x0 = jnp.full_like(n, 1 << 15)
    x = lax.fori_loop(0, ISQRT_ITERS, body, x0)
    # one floor-correction step: Newton can overshoot by 1
    x = jnp.where(x * x > n, x - 1, x)
    return jnp.maximum(x, 1)


def ilayernorm(x, gamma, beta, mult, shift):
    """Integer LayerNorm over the last axis.

    x int8-range (int32 container), gamma/beta int8-range per-channel.
    y = requant(((x - mu) << 7) / sigma * gamma) + beta, clipped to int8.
    This is the auxiliary operator executed on the cluster cores in the
    paper (ITA does not support LayerNorm).
    """
    x = x.astype(jnp.int32)
    e = x.shape[-1]
    mu = jnp.sum(x, axis=-1, keepdims=True) // e
    d = x - mu
    var = jnp.sum(d * d, axis=-1, keepdims=True) // e
    sigma = isqrt(var)
    n = (d * 128) // sigma
    acc = n * gamma.astype(jnp.int32)
    y = requant(acc, mult, shift)
    return clip_i8(y + beta.astype(jnp.int32))
