"""Pallas kernel for ITA's GEMM mode (Layer 1).

ITA doubles as a plain int8 GEMM accelerator with a fused activation unit
(Identity / ReLU / i-GeLU) — this kernel is that mode. Tiled 3D grid with
the reduction dimension innermost; the partial-sum buffer (the paper's
extension to ITA) lives in an accumulator output that is requantized and
activated on the last reduction step.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quant import igelu, irelu, requant

DEFAULT_TILE = 64


def _gemm_kernel(x_ref, w_ref, b_ref, acc_ref, o_ref, *, mult, shift, act, gelu_s, n_k):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref[...])

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.int32)

    @pl.when(k == n_k - 1)
    def _final():
        y = requant(acc_ref[...] + b_ref[...], mult, shift)
        if act == "gelu":
            y = igelu(y, gelu_s)
        elif act == "relu":
            y = irelu(y)
        o_ref[...] = y


def gemm_rq(x, w, bias, mult, shift, act="identity", gelu_s=0.1, tile=DEFAULT_TILE):
    """int8 GEMM + bias + requant + activation. Matches ref.gemm_rq.

    x: (M, K), w: (K, N), bias: (N,) int32. M, K, N multiples of ``tile``
    (the deployment flow pads to ITA's geometry before offloading).
    """
    m, kdim = x.shape
    n = w.shape[1]
    assert m % tile == 0 and kdim % tile == 0 and n % tile == 0, (m, kdim, n)
    n_k = kdim // tile
    kernel = functools.partial(
        _gemm_kernel, mult=mult, shift=shift, act=act, gelu_s=gelu_s, n_k=n_k
    )
    _, o = pl.pallas_call(
        kernel,
        grid=(m // tile, n // tile, n_k),
        in_specs=[
            pl.BlockSpec((tile, tile), lambda i, j, k: (i, k)),
            pl.BlockSpec((tile, tile), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, tile), lambda i, j, k: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((tile, tile), lambda i, j, k: (i, j)),  # partial sums
            pl.BlockSpec((tile, tile), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), jnp.int32),
            jax.ShapeDtypeStruct((m, n), jnp.int32),
        ],
        interpret=True,
    )(x.astype(jnp.int32), w.astype(jnp.int32), bias.astype(jnp.int32).reshape(1, n))
    return o
