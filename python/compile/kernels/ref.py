"""Pure-jnp oracle for the ITA datapath.

Non-streaming, whole-tensor formulations of everything the Pallas kernels
compute in streaming/tiled form. This is the correctness anchor:

  pallas kernel  ==  ref (bit-exact)       [test_kernels.py]
  ref            ~=  float softmax/gelu    [test_approx.py, loose tolerance]
  rust ita model ==  ref                   [via PJRT artifacts, rust tests]
"""

import jax.numpy as jnp

from . import quant
from .quant import clip_i8, igelu, irelu, itamax, requant


def gemm_rq(x, w, bias, mult, shift, act="identity", gelu_s=0.1):
    """int8 GEMM with 26-bit-style accumulation, bias add, requant, act.

    x: (M, K) int8-range, w: (K, N) int8-range, bias: (N,) int32
    (24-bit in hardware). Returns (M, N) int8-range int32.

    The accumulator in ITA is D=26 bits; for the supported dims
    (K <= 512: 512 * 127 * 127 < 2^24) int32 never overflows it.
    """
    acc = jnp.matmul(
        x.astype(jnp.int32), w.astype(jnp.int32), preferred_element_type=jnp.int32
    )
    acc = acc + bias.astype(jnp.int32)
    y = requant(acc, mult, shift)
    if act == "gelu":
        y = igelu(y, gelu_s)
    elif act == "relu":
        y = irelu(y)
    elif act != "identity":
        raise ValueError(f"unknown activation {act}")
    return y


def attention_head(q, k, v, qk_mult, qk_shift, av_mult, av_shift):
    """Single-head quantized attention, the ITA hot path.

    q, k, v: (S, P) int8-range. Computes
      QK = requant(Q @ K^T)        # int8 logits
      A  = ITAMax(QK)              # streaming softmax in hardware
      O  = requant(A @ V)          # int8 output
    Returns (O, QK, A) so tests can check each stage.
    """
    qk_acc = jnp.matmul(
        q.astype(jnp.int32), k.astype(jnp.int32).T, preferred_element_type=jnp.int32
    )
    qk = requant(qk_acc, qk_mult, qk_shift)
    a = itamax(qk)
    av_acc = jnp.matmul(a, v.astype(jnp.int32), preferred_element_type=jnp.int32)
    o = requant(av_acc, av_mult, av_shift)
    return o, qk, a


def mha(x, wq, wk, wv, wo, bq, bk, bv, bo, rq):
    """Multi-head attention, head-by-head as ITA executes it.

    x: (S, E); wq/wk/wv: (H, E, P); wo: (H, P, E); biases per head except
    bo: (E,) added once. rq: dict of requant params. The partial output
    projections are accumulated in int32 by the cluster cores (the paper's
    head-accumulation layer) and requantized once at the end.
    """
    h = wq.shape[0]
    s, e = x.shape
    acc = jnp.zeros((s, e), dtype=jnp.int32)
    for i in range(h):
        q = gemm_rq(x, wq[i], bq[i], rq["q_mult"], rq["q_shift"])
        k = gemm_rq(x, wk[i], bk[i], rq["k_mult"], rq["k_shift"])
        v = gemm_rq(x, wv[i], bv[i], rq["v_mult"], rq["v_shift"])
        o, _, _ = attention_head(
            q, k, v, rq["qk_mult"], rq["qk_shift"], rq["av_mult"], rq["av_shift"]
        )
        # partial output projection for this head, left in int32
        acc = acc + jnp.matmul(
            o, wo[i].astype(jnp.int32), preferred_element_type=jnp.int32
        )
    acc = acc + bo.astype(jnp.int32)
    return requant(acc, rq["o_mult"], rq["o_shift"])


# --- float references for approximation-quality tests -----------------------


def float_softmax_base2(x):
    """Float base-2 softmax — what ITAMax approximates (scale 1/128)."""
    xf = x.astype(jnp.float32) / (1 << quant.ITA_F)
    m = jnp.max(xf, axis=-1, keepdims=True)
    e = jnp.exp2(xf - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def float_gelu(x):
    """Exact float GeLU for i-GeLU quality checks."""
    from jax.scipy.stats import norm

    xf = x.astype(jnp.float32)
    return xf * norm.cdf(xf)


def float_layernorm(x):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return (xf - mu) / jnp.sqrt(var + 1e-5)
