"""AOT compilation: lower the L2 model + L1 kernels to HLO text artifacts.

Run once at build time (`make artifacts`); the rust runtime loads the
resulting ``artifacts/*.hlo.txt`` via PJRT and never touches Python again.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects (proto.id() <= INT_MAX); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts
---------
  gemm.hlo.txt            int8 GEMM + requant (128x128x128), identity act
  gemm_relu.hlo.txt       same geometry, fused ReLU
  gemm_gelu.hlo.txt       same geometry, fused i-GeLU
  attn_head.hlo.txt       single-head attention S=128, P=64 (QK+ITAMax+AV)
  encoder_<model>.hlo.txt one full encoder layer per evaluation network
  manifest.json           shapes, argument order, requant constants — the
                          contract the rust runtime + tests program against
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import ita_attention, ita_gemm

GEMM_DIM = 128
ATTN_S, ATTN_P = 128, 64


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path).

    CRITICAL: print with print_large_constants=True. The default printer
    elides payloads of large dense constants as ``constant({...})`` and the
    xla_extension 0.5.1 text parser silently substitutes garbage for them
    (observed: an s32[32] LUT turned into iota) instead of erroring.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # jax 0.8 metadata carries source_end_line/... attributes the 0.5.1
    # text parser rejects — strip it.
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def i32(shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def build_gemm(act):
    mult, shift = M.rq_for(GEMM_DIM)

    def fn(x, w, b):
        return (ita_gemm.gemm_rq(x, w, b, mult, shift, act=act, gelu_s=M.GELU_S),)

    lowered = jax.jit(fn).lower(
        i32((GEMM_DIM, GEMM_DIM)), i32((GEMM_DIM, GEMM_DIM)), i32((GEMM_DIM,))
    )
    entry = {
        "inputs": [
            {"name": "x", "shape": [GEMM_DIM, GEMM_DIM]},
            {"name": "w", "shape": [GEMM_DIM, GEMM_DIM]},
            {"name": "bias", "shape": [GEMM_DIM]},
        ],
        "outputs": [{"name": "y", "shape": [GEMM_DIM, GEMM_DIM]}],
        "rq": {"mult": mult, "shift": shift},
        "act": act,
        "gelu_s": M.GELU_S,
    }
    return lowered, entry


def build_attn_head():
    qkm, qks = M.rq_for(ATTN_P, target_std=40.0)
    avm, avs = M.rq_for(128, target_std=30.0)

    def fn(q, k, v):
        return (
            ita_attention.attention_head(q, k, v, qkm, qks, avm, avs),
        )

    spec = i32((ATTN_S, ATTN_P))
    lowered = jax.jit(fn).lower(spec, spec, spec)
    entry = {
        "inputs": [
            {"name": "q", "shape": [ATTN_S, ATTN_P]},
            {"name": "k", "shape": [ATTN_S, ATTN_P]},
            {"name": "v", "shape": [ATTN_S, ATTN_P]},
        ],
        "outputs": [{"name": "o", "shape": [ATTN_S, ATTN_P]}],
        "rq": {
            "qk_mult": qkm, "qk_shift": qks,
            "av_mult": avm, "av_shift": avs,
        },
    }
    return lowered, entry


def build_encoder(cfg: M.ModelConfig):
    shapes = M.layer_weight_shapes(cfg)

    def fn(x, *weights):
        return (M.encoder_layer(x, *weights, cfg),)

    specs = [i32((cfg.seq, cfg.emb))] + [i32(s) for _, s in shapes]
    lowered = jax.jit(fn).lower(*specs)
    entry = {
        "inputs": (
            [{"name": "x", "shape": [cfg.seq, cfg.emb]}]
            + [{"name": n, "shape": list(s)} for n, s in shapes]
        ),
        "outputs": [{"name": "x_out", "shape": [cfg.seq, cfg.emb]}],
        "rq": M.rq_params(cfg),
        "config": {
            "name": cfg.name, "seq": cfg.seq, "seq_logical": cfg.seq_logical,
            "emb": cfg.emb, "proj": cfg.proj, "heads": cfg.heads,
            "layers": cfg.layers, "dff": cfg.dff, "ffn_stack": cfg.ffn_stack,
            "act": cfg.act, "gop_per_inference": cfg.gop_per_inference,
        },
    }
    return lowered, entry


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--skip-encoders", action="store_true",
        help="only the micro kernels (fast dev loop)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": "hlo-text", "artifacts": {}}

    jobs = [
        ("gemm", lambda: build_gemm("identity")),
        ("gemm_relu", lambda: build_gemm("relu")),
        ("gemm_gelu", lambda: build_gemm("gelu")),
        ("attn_head", build_attn_head),
    ]
    if not args.skip_encoders:
        for cfg in M.CONFIGS.values():
            jobs.append(
                (f"encoder_{cfg.name}", lambda cfg=cfg: build_encoder(cfg))
            )

    for name, builder in jobs:
        lowered, entry = builder()
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        entry["file"] = fname
        manifest["artifacts"][name] = entry
        print(f"  wrote {fname} ({len(text) / 1024:.0f} KiB)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"  wrote manifest.json ({len(manifest['artifacts'])} artifacts)")


if __name__ == "__main__":
    main()
