"""Pallas kernels vs the pure-jnp oracle — the core L1 correctness signal.

Every test asserts *bit-exact* equality: the kernels implement the same
integer spec as ref.py, only in streaming/tiled form.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ita_attention as att
from compile.kernels import ita_gemm
from compile.kernels import quant, ref


def rand_i8(rng, shape):
    return rng.integers(-128, 128, shape).astype(np.int32)


# --- attention ---------------------------------------------------------------


@pytest.mark.parametrize("s", [64, 128, 256])
@pytest.mark.parametrize("p", [64, 128])
def test_attention_head_matches_ref(s, p):
    rng = np.random.default_rng(s * 1000 + p)
    q, k, v = (rand_i8(rng, (s, p)) for _ in range(3))
    o_ref, qk_ref, _ = ref.attention_head(q, k, v, 15, 14, 8, 14)
    qk, m, den = att.qk_itamax(q, k, 15, 14)
    np.testing.assert_array_equal(np.asarray(qk), np.asarray(qk_ref))
    o = att.av_en(qk, m, den, v, 8, 14)
    np.testing.assert_array_equal(np.asarray(o), np.asarray(o_ref))


def test_attention_stats_match_oracle_streaming_order():
    """The kernel's cross-tile carry must equal the oracle's chunk scan."""
    rng = np.random.default_rng(7)
    q, k = rand_i8(rng, (128, 64)), rand_i8(rng, (128, 64))
    qk, m, den = att.qk_itamax(q, k, 15, 14)
    m_ref, den_ref = quant.itamax_stats(np.asarray(qk))
    np.testing.assert_array_equal(np.asarray(m), np.asarray(m_ref))
    np.testing.assert_array_equal(np.asarray(den), np.asarray(den_ref))


def test_attention_rectangular_kv():
    """S_q != S_kv (cross-attention shape)."""
    rng = np.random.default_rng(11)
    q = rand_i8(rng, (64, 64))
    k, v = rand_i8(rng, (192, 64)), rand_i8(rng, (192, 64))
    qk_acc = q.astype(np.int64) @ k.T.astype(np.int64)
    qk_ref = np.asarray(quant.requant(jnp.asarray(qk_acc.astype(np.int32)), 15, 14))
    a_ref = np.asarray(quant.itamax(jnp.asarray(qk_ref)))
    o_ref = np.asarray(
        quant.requant(jnp.asarray(a_ref @ v), 8, 14)
    )
    qk, m, den = att.qk_itamax(q, k, 15, 14)
    o = att.av_en(qk, m, den, v, 8, 14)
    np.testing.assert_array_equal(np.asarray(o), o_ref)


@settings(max_examples=20, deadline=None)
@given(
    s=st.sampled_from([64, 128, 192]),
    p=st.sampled_from([64, 128]),
    t_kv=st.sampled_from([32, 64]),
    seed=st.integers(0, 2**31 - 1),
    qk_shift=st.integers(10, 16),
)
def test_attention_property(s, p, t_kv, seed, qk_shift):
    """Hypothesis sweep: shapes, tile sizes, requant params, seeds."""
    rng = np.random.default_rng(seed)
    q, k, v = (rand_i8(rng, (s, p)) for _ in range(3))
    o_ref, _, _ = ref.attention_head(q, k, v, 15, qk_shift, 8, 14)
    o = att.attention_head(q, k, v, 15, qk_shift, 8, 14, t_kv=t_kv)
    np.testing.assert_array_equal(np.asarray(o), np.asarray(o_ref))


def test_attention_constant_rows():
    """Degenerate input: all logits equal -> uniform probabilities."""
    s = 64
    q = np.zeros((s, 64), np.int32)
    k = np.zeros((s, 64), np.int32)
    v = np.full((s, 64), 100, np.int32)
    o_ref, _, a = ref.attention_head(q, k, v, 15, 14, 8, 14)
    o = att.attention_head(q, k, v, 15, 14, 8, 14)
    np.testing.assert_array_equal(np.asarray(o), np.asarray(o_ref))
    a = np.asarray(a)
    assert (a == a[0, 0]).all(), "uniform logits must give uniform A"


def test_attention_onehot_rows():
    """One dominant logit -> A concentrates at ~127 on that element."""
    s, p = 64, 64
    rng = np.random.default_rng(3)
    q = rand_i8(rng, (s, p))
    k = rand_i8(rng, (s, p))
    qk, m, den = att.qk_itamax(q, k, 15, 2)  # tiny shift -> saturated logits
    a = np.asarray(quant.itamax(np.asarray(qk)))
    assert a.max() <= 127 and a.min() >= 0


# --- GEMM --------------------------------------------------------------------


@pytest.mark.parametrize("act", ["identity", "relu", "gelu"])
@pytest.mark.parametrize("dims", [(64, 64, 64), (128, 192, 64), (64, 512, 128)])
def test_gemm_matches_ref(act, dims):
    m, k, n = dims
    rng = np.random.default_rng(m + k + n)
    x, w = rand_i8(rng, (m, k)), rand_i8(rng, (k, n))
    b = rng.integers(-(2**11), 2**11, (n,)).astype(np.int32)
    g_ref = ref.gemm_rq(x, w, b, 7, 13, act=act)
    g = ita_gemm.gemm_rq(x, w, b, 7, 13, act=act)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(g_ref))


@settings(max_examples=20, deadline=None)
@given(
    mt=st.integers(1, 3),
    kt=st.integers(1, 4),
    nt=st.integers(1, 3),
    seed=st.integers(0, 2**31 - 1),
    mult=st.integers(1, 64),
    shift=st.integers(8, 16),
)
def test_gemm_property(mt, kt, nt, seed, mult, shift):
    """Hypothesis sweep over tile-multiples and requant params."""
    m, k, n = 64 * mt, 64 * kt, 64 * nt
    rng = np.random.default_rng(seed)
    x, w = rand_i8(rng, (m, k)), rand_i8(rng, (k, n))
    b = rng.integers(-(2**11), 2**11, (n,)).astype(np.int32)
    g_ref = ref.gemm_rq(x, w, b, mult, shift)
    g = ita_gemm.gemm_rq(x, w, b, mult, shift)
    np.testing.assert_array_equal(np.asarray(g), np.asarray(g_ref))


def test_gemm_bias_zero_and_saturation():
    m = k = n = 64
    x = np.full((m, k), 127, np.int32)
    w = np.full((k, n), 127, np.int32)
    b = np.zeros(n, np.int32)
    g = np.asarray(ita_gemm.gemm_rq(x, w, b, 1 << 8, 8))
    assert (g == 127).all(), "saturating accumulation must clip at +127"
    g2 = np.asarray(ita_gemm.gemm_rq(x, -w, b, 1 << 8, 8))
    assert (g2 == -128).all(), "negative saturation must clip at -128"
