"""AOT pipeline tests: HLO text artifacts round-trip and manifest contract.

The interchange constraints (print_large_constants=True, no metadata, no
gather ops) exist because of version skew between jax 0.8 and the rust
xla_extension 0.5.1 — see aot.to_hlo_text. These tests keep the artifacts
within that envelope.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M
from compile.kernels import ita_gemm


def test_hlo_text_has_no_elided_constants():
    def fn(x):
        lut = jnp.asarray(list(range(100, 164)), dtype=jnp.int32)
        from compile.kernels.quant import lut_lookup
        return (lut_lookup(lut[:32], x & 31),)

    low = jax.jit(fn).lower(jax.ShapeDtypeStruct((64,), jnp.int32))
    text = aot.to_hlo_text(low)
    assert "constant({...})" not in text, "elided constant payload"
    assert "source_end_line" not in text, "metadata the 0.5.1 parser rejects"


def test_hlo_text_has_no_gather():
    """HLO gather is mis-executed by xla_extension 0.5.1 — must not appear."""
    mult, shift = M.rq_for(64)

    def fn(q, k, v):
        from compile.kernels import ita_attention as att
        return (att.attention_head(q, k, v, mult, shift, 8, 14),)

    spec = jax.ShapeDtypeStruct((64, 64), jnp.int32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec, spec))
    for line in text.splitlines():
        assert not line.strip().startswith("%gather"), line


def test_gemm_artifact_builder():
    lowered, entry = aot.build_gemm("gelu")
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert entry["act"] == "gelu"
    assert entry["rq"]["mult"] >= 1


def test_manifest_written(tmp_path):
    import subprocess, sys
    out = tmp_path / "arts"
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out), "--skip-encoders"],
        capture_output=True, text=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, r.stderr
    man = json.loads((out / "manifest.json").read_text())
    assert man["format"] == "hlo-text"
    for name in ["gemm", "gemm_relu", "gemm_gelu", "attn_head"]:
        assert name in man["artifacts"]
        assert (out / man["artifacts"][name]["file"]).exists()


def test_encoder_entry_matches_weight_shapes():
    cfg = M.CONFIGS["mobilebert"]
    _, entry = None, None
    shapes = M.layer_weight_shapes(cfg)
    names = [n for n, _ in shapes]
    # order contract with rust runtime: x first, then weights in this order
    assert names[:4] == ["wq", "wk", "wv", "wo"]
    assert names[-4:] == ["ln1_g", "ln1_b", "ln2_g", "ln2_b"]


@pytest.mark.parametrize("name", list(M.CONFIGS))
def test_artifact_files_within_interchange_envelope(name):
    """The on-disk encoder artifacts must contain no elided constants, no
    metadata, and no gather ops — the three known 0.5.1 parser traps."""
    art_dir = os.path.join(
        os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))),
        "artifacts",
    )
    path = os.path.join(art_dir, f"encoder_{name}.hlo.txt")
    if not os.path.exists(path):
        pytest.skip("artifacts not built")
    text = open(path).read()
    assert "constant({...})" not in text, "elided constant payload"
    assert "source_end_line" not in text, "unparseable metadata"
    for line in text.splitlines():
        stripped = line.strip()
        assert not stripped.startswith("%gather"), stripped[:80]
        assert not stripped.startswith("gather"), stripped[:80]


@pytest.mark.parametrize("name", list(M.CONFIGS))
def test_existing_artifacts_fresh(name):
    """If artifacts/ exists, its manifest must match current configs."""
    art_dir = os.path.join(
        os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))),
        "artifacts",
    )
    man_path = os.path.join(art_dir, "manifest.json")
    if not os.path.exists(man_path):
        pytest.skip("artifacts not built")
    man = json.loads(open(man_path).read())
    key = f"encoder_{name}"
    assert key in man["artifacts"], "run `make artifacts`"
    cfgm = man["artifacts"][key]["config"]
    cfg = M.CONFIGS[name]
    assert cfgm["seq"] == cfg.seq and cfgm["emb"] == cfg.emb
    assert cfgm["layers"] == cfg.layers and cfgm["heads"] == cfg.heads
