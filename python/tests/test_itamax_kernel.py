"""Standalone ITAMax Pallas kernel vs the oracle — bit-exact, plus
block-size invariance (the kernel result must not depend on how rows are
split across the grid)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import itamax as km
from compile.kernels import quant


@pytest.mark.parametrize("rows,cols", [(16, 16), (64, 64), (128, 256), (64, 512)])
def test_matches_oracle(rows, cols):
    rng = np.random.default_rng(rows * 7 + cols)
    x = rng.integers(-128, 128, (rows, cols)).astype(np.int32)
    got = np.asarray(km.itamax(jnp.asarray(x)))
    want = np.asarray(quant.itamax(jnp.asarray(x)))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=15, deadline=None)
@given(
    rows=st.sampled_from([32, 64, 128]),
    cols=st.sampled_from([16, 48, 64, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_matches_oracle(rows, cols, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, (rows, cols)).astype(np.int32)
    got = np.asarray(km.itamax(jnp.asarray(x)))
    want = np.asarray(quant.itamax(jnp.asarray(x)))
    np.testing.assert_array_equal(got, want)


def test_block_size_invariance():
    rng = np.random.default_rng(3)
    x = rng.integers(-128, 128, (128, 64)).astype(np.int32)
    a32 = np.asarray(km.itamax(jnp.asarray(x), block_rows=32))
    a64 = np.asarray(km.itamax(jnp.asarray(x), block_rows=64))
    a128 = np.asarray(km.itamax(jnp.asarray(x), block_rows=128))
    np.testing.assert_array_equal(a32, a64)
    np.testing.assert_array_equal(a64, a128)


def test_saturated_inputs():
    x = np.full((16, 32), 127, np.int32)
    a = np.asarray(km.itamax(jnp.asarray(x)))
    # uniform max logits -> uniform probabilities 128/32 = 4
    assert (a == 4).all()
    x = np.full((16, 32), -128, np.int32)
    a = np.asarray(km.itamax(jnp.asarray(x)))
    assert (a == 4).all(), "softmax is shift-invariant even at the rail"
