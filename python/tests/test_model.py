"""L2 model tests: encoder layer shapes, determinism, synthetic weights,
and consistency between the Pallas-kernel model and the pure-jnp oracle.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.kernels import ref


def small_cfg(**kw):
    """A scaled-down config for fast tests."""
    base = dict(
        name="tiny", seq=64, seq_logical=64, emb=64, proj=64, heads=2,
        layers=2, dff=128, ffn_stack=1, act="gelu", gop_per_inference=0.1,
    )
    base.update(kw)
    return M.ModelConfig(**base)


def weights_dict(cfg, layer=0, seed=0):
    return dict(M.synth_layer_weights(cfg, layer_idx=layer, seed=seed))


def run_layer(cfg, x, w):
    return M.encoder_layer(
        jnp.asarray(x), w["wq"], w["wk"], w["wv"], w["wo"], w["bq"], w["bk"],
        w["bv"], w["bo"], w["w1"], w["b1"], w["w2"], w["b2"],
        w["ln1_g"], w["ln1_b"], w["ln2_g"], w["ln2_b"], cfg,
    )


def test_encoder_layer_shape_and_range():
    cfg = small_cfg()
    w = weights_dict(cfg)
    x = M.synth_input(cfg)
    y = np.asarray(run_layer(cfg, x, w))
    assert y.shape == (cfg.seq, cfg.emb)
    assert y.min() >= -128 and y.max() <= 127
    assert y.std() > 5.0, "activations must stay alive through the layer"


def test_encoder_layer_deterministic():
    cfg = small_cfg()
    w = weights_dict(cfg)
    x = M.synth_input(cfg)
    y1 = np.asarray(run_layer(cfg, x, w))
    y2 = np.asarray(run_layer(cfg, x, w))
    np.testing.assert_array_equal(y1, y2)


def test_mha_matches_ref_oracle():
    """model.mha (Pallas kernels) == ref.mha (pure jnp), bit-exact."""
    cfg = small_cfg()
    w = weights_dict(cfg)
    rq = M.rq_params(cfg)
    x = M.synth_input(cfg)
    got = np.asarray(
        M.mha(jnp.asarray(x), w["wq"], w["wk"], w["wv"], w["wo"], w["bq"],
              w["bk"], w["bv"], w["bo"], rq, cfg)
    )
    want = np.asarray(
        ref.mha(jnp.asarray(x), w["wq"], w["wk"], w["wv"], w["wo"], w["bq"],
                w["bk"], w["bv"], w["bo"], rq)
    )
    np.testing.assert_array_equal(got, want)


def test_ffn_stack_count():
    """MobileBERT's 4 stacked FFNs must actually change the output."""
    cfg1 = small_cfg(ffn_stack=1)
    cfg4 = small_cfg(ffn_stack=4)
    x = M.synth_input(cfg1)
    w1, w4 = weights_dict(cfg1), weights_dict(cfg4)
    y1 = np.asarray(run_layer(cfg1, x, w1))
    y4 = np.asarray(run_layer(cfg4, x, w4))
    assert not np.array_equal(y1, y4)


def test_synth_weights_deterministic_and_keyed():
    cfg = small_cfg()
    a = weights_dict(cfg, layer=0)
    b = weights_dict(cfg, layer=0)
    c = weights_dict(cfg, layer=1)
    np.testing.assert_array_equal(a["wq"], b["wq"])
    assert not np.array_equal(a["wq"], c["wq"]), "layers must differ"
    assert a["wq"].min() >= -128 and a["wq"].max() <= 127
    assert a["ln1_g"].min() >= 32 and a["ln1_g"].max() < 96


def test_splitmix_golden():
    """Golden values pin the splitmix64 stream shared with rust."""
    vals = M.splitmix64(np.arange(4, dtype=np.uint64))
    assert vals.tolist() == [
        16294208416658607535,
        10451216379200822465,
        10905525725756348110,
        2092789425003139053,
    ]
    assert M.fnv1a("mobilebert/L0/wq") == M.fnv1a("mobilebert/L0/wq")
    assert M.fnv1a("a") != M.fnv1a("b")


@pytest.mark.parametrize("name", list(M.CONFIGS))
def test_paper_configs(name):
    cfg = M.CONFIGS[name]
    assert cfg.seq % 64 == 0, "ITA tiling requires padded sequence"
    assert cfg.proj == 64  # P = 64 across all three networks
    assert cfg.gop_per_inference > 0


def test_forward_two_layers_composes():
    """Full-network forward: layers chain without drift or saturation."""
    cfg = small_cfg(layers=2)
    weights = [M.synth_layer_weights(cfg, layer_idx=l) for l in range(2)]
    x = M.synth_input(cfg)
    y = np.asarray(M.forward(cfg, jnp.asarray(x), weights))
    assert y.shape == (cfg.seq, cfg.emb)
    assert y.min() >= -128 and y.max() <= 127
    # layer 2 must actually transform layer 1's output
    y1 = np.asarray(run_layer(cfg, x, dict(weights[0])))
    assert not np.array_equal(y, y1)
    # saturation must not collapse the distribution
    sat = np.mean((y == 127) | (y == -128))
    assert sat < 0.2, f"saturation fraction {sat}"


def test_paper_gop_footnotes_consistent():
    """Recompute GOp from geometry; must be within ~20% of the footnotes
    (the footnotes include auxiliary ops we don't count here)."""
    for cfg in M.CONFIGS.values():
        s, e, p, h, dff, f = (
            cfg.seq_logical, cfg.emb, cfg.proj, cfg.heads, cfg.dff, cfg.ffn_stack,
        )
        qkv = 3 * 2 * s * e * p * h
        attn = 2 * 2 * s * s * p * h
        out = 2 * s * p * h * e
        ffn = f * 2 * 2 * s * e * dff
        total = (qkv + attn + out + ffn) * cfg.layers / 1e9
        assert abs(total - cfg.gop_per_inference) / cfg.gop_per_inference < 0.25, (
            cfg.name, total, cfg.gop_per_inference,
        )
