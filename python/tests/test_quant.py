"""Integer-primitive spec tests: ITAMax / i-GeLU / i-LayerNorm / requant.

These pin down the *specification* that the rust functional model
(rust/src/ita/) re-implements — plus approximation-quality checks against
float references (loose tolerances: these are 8-bit approximations).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quant, ref


# --- EXP2 LUT / exp2_num -----------------------------------------------------


def test_exp2_lut_values():
    """The table is round(256 * 2^(-i/32)) — golden, shared with rust."""
    expected = [int(round(256 * 2 ** (-i / 32))) for i in range(32)]
    assert quant.EXP2_LUT_LIST == expected
    assert quant.EXP2_LUT_LIST[0] == 256
    assert quant.EXP2_LUT_LIST[31] == 131


def test_exp2_num_monotone_decreasing():
    d = jnp.arange(0, 1024, dtype=jnp.int32)
    n = np.asarray(quant.exp2_num(d))
    assert (np.diff(n) <= 0).all()
    assert n[0] == 256
    assert n[-1] == 0


def test_exp2_num_matches_float():
    d = np.arange(0, 512, dtype=np.int32)
    n = np.asarray(quant.exp2_num(jnp.asarray(d))).astype(np.float64)
    f = 256.0 * 2.0 ** (-d / 32.0)
    # LUT quantization + truncation: error bounded by ~1 output LSB + shift
    assert np.max(np.abs(n - f)) <= 2.0


# --- ITAMax ------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(
    rows=st.integers(1, 8),
    cols=st.sampled_from([16, 32, 64, 128, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_itamax_rows_sum_to_one(rows, cols, seed):
    """Quantized probabilities: rows sum to at most 128 (scale 1/2^7).

    EN truncation can only lose mass, never create it. For peaked rows the
    sum stays near 128; near-uniform long rows lose most of it to the 8-bit
    granularity (1/128 cannot represent 1/512) — an inherent property of
    ITA's 8-bit attention, pinned by test_itamax_uniform_long_row.
    """
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, (rows, cols)).astype(np.int32)
    a = np.asarray(quant.itamax(jnp.asarray(x)))
    assert a.min() >= 0 and a.max() <= 127
    sums = a.sum(axis=-1)
    assert (sums <= 128).all()
    if cols <= 64:
        assert (sums >= 96).all(), sums


def test_itamax_uniform_long_row():
    """Uniform 512-wide rows underflow 8-bit probabilities to zero."""
    x = np.zeros((1, 512), np.int32)
    a = np.asarray(quant.itamax(jnp.asarray(x)))
    assert (a == 0).all()  # 1/512 < 1/128 LSB — documented precision floor


def test_itamax_peaked_short_row():
    """Max-contrast logit on a short row concentrates the mass.

    With F=5 fractional bits an int8 logit spans +-4 octaves, so the
    max/min probability ratio is 2^(255/32) ~ 250x: on a 16-wide row the
    peak gets a = floor(256 * inv(256 + 15) >> 17) = 120 of 128.
    Attention *sharpness* is controlled by the QK requant scale upstream,
    exactly as ITA's calibrated dequantization eps does.
    """
    x = np.full((1, 16), -128, np.int32)
    x[0, 3] = 127
    a = np.asarray(quant.itamax(jnp.asarray(x)))
    assert a[0, 3] == 120
    assert a[0, 0] == 0


@settings(max_examples=30, deadline=None)
@given(
    cols=st.sampled_from([64, 128, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_itamax_approximates_float_softmax(cols, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, (16, cols)).astype(np.int32)
    a = np.asarray(quant.itamax(jnp.asarray(x))) / 128.0
    f = np.asarray(ref.float_softmax_base2(jnp.asarray(x)))
    assert np.max(np.abs(a - f)) < 0.02


def test_itamax_invariant_to_shift():
    """Softmax(x + c) == Softmax(x): adding a row constant is a no-op."""
    rng = np.random.default_rng(5)
    x = rng.integers(-100, 20, (4, 64)).astype(np.int32)
    a1 = np.asarray(quant.itamax(jnp.asarray(x)))
    a2 = np.asarray(quant.itamax(jnp.asarray(x + 27)))
    np.testing.assert_array_equal(a1, a2)


def test_itamax_streaming_chunk_order_matters():
    """Pin the DA chunk width: results are defined by 16-element chunks."""
    rng = np.random.default_rng(9)
    x = rng.integers(-128, 128, (4, 128)).astype(np.int32)
    m, den = quant.itamax_stats(jnp.asarray(x))
    # manual scan, numpy, same spec
    for r in range(4):
        mm, dd = -quant.ITAMAX_M0, 0
        for c in range(128 // 16):
            ch = x[r, c * 16 : (c + 1) * 16]
            lm = ch.max()
            m_new = max(mm, lm)
            delta = m_new - mm
            shift = min(8 + (delta >> 5), 31)
            dd = (dd * quant.EXP2_LUT_LIST[delta & 31]) >> shift
            d2 = m_new - ch
            nums = [
                quant.EXP2_LUT_LIST[d & 31] >> min(d >> 5, 31) for d in d2
            ]
            dd += sum(nums)
            mm = m_new
        assert int(np.asarray(m)[r, 0]) == mm
        assert int(np.asarray(den)[r, 0]) == dd


def test_itamax_renorm_shift_clamp():
    """First-chunk delta is huge; the shift clamp keeps behaviour defined."""
    x = np.full((1, 16), -128, np.int32)
    m, den = quant.itamax_stats(jnp.asarray(x))
    assert int(np.asarray(m)[0, 0]) == -128
    assert int(np.asarray(den)[0, 0]) == 16 * 256  # all-equal row


# --- requant -----------------------------------------------------------------


@settings(max_examples=50, deadline=None)
@given(
    acc=st.integers(-(2**25), 2**25),
    mult=st.integers(1, 255),
    shift=st.integers(1, 20),
)
def test_requant_matches_scalar_spec(acc, mult, shift):
    got = int(np.asarray(quant.requant(jnp.asarray([acc], dtype=jnp.int32), mult, shift))[0])
    prod = acc * mult
    if abs(prod) >= 2**31:
        return  # out of contract
    want = (prod + (1 << (shift - 1))) >> shift
    want = max(-128, min(127, want))
    assert got == want


def test_requant_rounding_half_up():
    # (1 * 1 + 1) >> 1 = 1 : rounds 0.5 up
    assert int(np.asarray(quant.requant(jnp.asarray([1]), 1, 1))[0]) == 1
    assert int(np.asarray(quant.requant(jnp.asarray([-1]), 1, 1))[0]) == 0


# --- i-GeLU ------------------------------------------------------------------


def test_igelu_matches_float_gelu():
    x = np.arange(-128, 128, dtype=np.int32).reshape(1, -1)
    s = 0.1
    g = np.asarray(quant.igelu(jnp.asarray(x), s)).astype(np.float64)
    f = np.asarray(ref.float_gelu(jnp.asarray(x * s))) / s
    assert np.max(np.abs(g - f)) <= 2.0  # <= 2 LSB over the whole int8 range


def test_igelu_fixed_points():
    x = jnp.asarray([[0, 127, -128]], dtype=jnp.int32)
    g = np.asarray(quant.igelu(x, 0.1))
    assert g[0, 0] == 0
    assert abs(int(g[0, 1]) - 127) <= 1  # gelu(12.7) ~ 12.7
    assert abs(int(g[0, 2])) <= 1  # gelu(-12.8) ~ 0


@settings(max_examples=20, deadline=None)
@given(s=st.sampled_from([0.05, 0.1, 0.2, 0.5]), seed=st.integers(0, 2**31 - 1))
def test_igelu_property(s, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(-128, 128, (64,)).astype(np.int32)
    g = np.asarray(quant.igelu(jnp.asarray(x), s)).astype(np.float64)
    f = np.asarray(ref.float_gelu(jnp.asarray(x * s))) / s
    tol = max(2.0, 0.05 / s)  # coarser scales -> coarser approximation
    assert np.max(np.abs(g - f)) <= tol


# --- isqrt / i-LayerNorm -----------------------------------------------------


@settings(max_examples=100, deadline=None)
@given(n=st.integers(0, 2**30))
def test_isqrt_is_floor_sqrt(n):
    got = int(np.asarray(quant.isqrt(jnp.asarray([n], dtype=jnp.int32)))[0])
    want = max(1, int(np.floor(np.sqrt(n))))
    assert got == want


def test_ilayernorm_zero_mean_unit_var():
    rng = np.random.default_rng(2)
    x = rng.integers(-128, 128, (8, 128)).astype(np.int32)
    g = np.full(128, 64, np.int32)
    b = np.zeros(128, np.int32)
    y = np.asarray(quant.ilayernorm(jnp.asarray(x), jnp.asarray(g), jnp.asarray(b), 16, 12))
    yf = np.asarray(ref.float_layernorm(jnp.asarray(x)))
    # output scale: (d*128/sigma)*64*16 >> 12 = 32*(d/sigma)
    corr = np.corrcoef(y.ravel(), yf.ravel())[0, 1]
    assert corr > 0.999, corr
    assert abs(y.mean()) < 1.0


def test_ilayernorm_beta_offset():
    x = np.zeros((2, 64), np.int32)
    g = np.full(64, 64, np.int32)
    b = np.full(64, 7, np.int32)
    y = np.asarray(quant.ilayernorm(jnp.asarray(x), jnp.asarray(g), jnp.asarray(b), 16, 12))
    assert (y == 7).all()
