"""Oracle self-consistency tests: ref.py's composed operators must equal
their stage-by-stage composition, and degenerate cases behave physically.
(The oracle anchors everything else, so it gets its own scrutiny.)"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels import quant, ref


def rand_i8(rng, shape):
    return rng.integers(-128, 128, shape).astype(np.int32)


def test_attention_head_equals_stage_composition():
    rng = np.random.default_rng(0)
    q, k, v = (rand_i8(rng, (64, 64)) for _ in range(3))
    o, qk, a = ref.attention_head(q, k, v, 15, 14, 8, 14)
    # stage 1: requantized QK
    qk_manual = np.asarray(
        quant.requant(jnp.asarray(q.astype(np.int64) @ k.T.astype(np.int64), dtype=jnp.int32), 15, 14)
    )
    np.testing.assert_array_equal(np.asarray(qk), qk_manual)
    # stage 2: ITAMax
    np.testing.assert_array_equal(
        np.asarray(a), np.asarray(quant.itamax(jnp.asarray(qk_manual)))
    )
    # stage 3: requantized AV
    o_manual = np.asarray(
        quant.requant(jnp.asarray(np.asarray(a) @ v), 8, 14)
    )
    np.testing.assert_array_equal(np.asarray(o), o_manual)


def test_mha_equals_per_head_composition():
    cfg = M.ModelConfig(
        name="t", seq=64, seq_logical=64, emb=64, proj=64, heads=2, layers=1,
        dff=128, ffn_stack=1, act="gelu", gop_per_inference=0.1,
    )
    rq = M.rq_params(cfg)
    rng = np.random.default_rng(1)
    x = rand_i8(rng, (64, 64))
    wq, wk, wv = (rand_i8(rng, (2, 64, 64)) for _ in range(3))
    wo = rand_i8(rng, (2, 64, 64))
    bq, bk, bv = (rng.integers(-2048, 2048, (2, 64)).astype(np.int32) for _ in range(3))
    bo = rng.integers(-2048, 2048, (64,)).astype(np.int32)

    got = np.asarray(ref.mha(jnp.asarray(x), wq, wk, wv, wo, bq, bk, bv, bo, rq))

    acc = np.zeros((64, 64), np.int64)
    for h in range(2):
        q = np.asarray(ref.gemm_rq(x, wq[h], bq[h], rq["q_mult"], rq["q_shift"]))
        k = np.asarray(ref.gemm_rq(x, wk[h], bk[h], rq["k_mult"], rq["k_shift"]))
        v = np.asarray(ref.gemm_rq(x, wv[h], bv[h], rq["v_mult"], rq["v_shift"]))
        o, _, _ = ref.attention_head(
            q, k, v, rq["qk_mult"], rq["qk_shift"], rq["av_mult"], rq["av_shift"]
        )
        acc += np.asarray(o).astype(np.int64) @ wo[h].astype(np.int64)
    want = np.asarray(
        quant.requant(jnp.asarray((acc + bo).astype(np.int32)), rq["o_mult"], rq["o_shift"])
    )
    np.testing.assert_array_equal(got, want)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_gemm_identity_weight_is_requant(seed):
    """x @ I * 2^s scaled back = clip(x) — GEMM reduces to requant."""
    rng = np.random.default_rng(seed)
    x = rand_i8(rng, (64, 64))
    w = (np.eye(64) * 64).astype(np.int32)  # I * 2^6
    b = np.zeros(64, np.int32)
    g = np.asarray(ref.gemm_rq(x, w, b, 1 << 8, 14))  # undo the 2^6
    np.testing.assert_array_equal(g, x)


def test_single_chunk_streaming_equals_batch():
    """With S_kv = 16 (one DA chunk) the streaming denominator reduces to
    the plain batch formula — verifiable directly in numpy."""
    rng = np.random.default_rng(5)
    qk = rand_i8(rng, (8, 16))
    m, den = quant.itamax_stats(jnp.asarray(qk))
    m_np = qk.max(axis=1, keepdims=True)
    diff = m_np - qk
    num = np.array(quant.EXP2_LUT)[diff & 31] >> np.minimum(diff >> 5, 31)
    np.testing.assert_array_equal(np.asarray(m), m_np)
    np.testing.assert_array_equal(np.asarray(den).ravel(), num.sum(axis=1))
