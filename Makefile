# attn-tinyml build entry points.
#
#   make build       release build (std-only default features)
#   make test        tier-1 verify: cargo build --release && cargo test -q
#   make bench       compile + run every bench target
#   make serve-smoke multi-request serving smoke run (the CI guard that
#                    keeps the serve subcommand from bitrotting)
#   make artifacts   AOT-lower the JAX/Pallas models to HLO-text artifacts
#                    (needs the python environment; the rust side works
#                    without this — the reference backend is the default)
#   make check       type-check all feature combinations
#   make lint        clippy, warnings as errors (same as CI)
#   make fmt         rustfmt check

CARGO ?= cargo
PYTHON ?= python3
ARTIFACTS_DIR ?= artifacts

.PHONY: build test bench serve-smoke artifacts check lint fmt clean

build:
	$(CARGO) build --release

test: build
	$(CARGO) test -q

bench:
	$(CARGO) bench --no-run
	$(CARGO) bench

serve-smoke: build
	$(CARGO) run --release -- serve --requests 32 --clusters 2

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../$(ARTIFACTS_DIR)

check:
	$(CARGO) check --all-targets
	$(CARGO) check --all-targets --no-default-features
	$(CARGO) check --all-targets --features pjrt

lint:
	$(CARGO) clippy --all-targets -- -D warnings

fmt:
	$(CARGO) fmt --check

clean:
	$(CARGO) clean
	rm -rf $(ARTIFACTS_DIR)
