# attn-tinyml build entry points.
#
#   make build       release build (std-only default features)
#   make test        tier-1 verify: cargo build --release && cargo test -q
#   make bench       compile + run every bench target
#   make serve-smoke multi-request serving smoke run (the CI guard that
#                    keeps the serve subcommand from bitrotting)
#   make perf-smoke  serve hot-path perf bench in assert mode on reduced
#                    request counts (CI guard: optimized loop must stay
#                    >= 3x ahead of the retained naive reference and the
#                    reports must stay bit-identical)
#   make perf-bench  the full perf bench (100k comparison at >= 10x,
#                    1M-request sweep); regenerates BENCH_perf.json
#   make control-smoke  control-plane bench in assert mode on reduced
#                    request counts (CI guard: static-nominal stays a
#                    bit-identical no-op, slo-dvfs holds the p99 SLO and
#                    strictly lowers J/request on the diurnal leg)
#   make control-bench  the full control-plane bench (15k requests per
#                    leg); regenerates BENCH_control.json
#   make trace-smoke    trace-replay smoke run (CI guard): generate a
#                    10k-row 9:1-skew trace with `trace gen`, serve it
#                    under the wfq scheduler, then run the fairness
#                    bench in assert mode (Wfq/Drf hold Jain >= 0.95 at
#                    the overload horizon, Fifo collapses below 0.75)
#   make trace-bench    the full fairness bench (20k-row horizon legs +
#                    million-row streaming leg); regenerates
#                    BENCH_trace.json
#   make fleet-smoke    topology/locality smoke run (CI guard): a small
#                    pod-topology serve with --locality through the CLI,
#                    then the fleet-scaling bench in assert mode (links
#                    carry real traffic, locality never thrashes more
#                    weight DMA than blind placement, bit-identical
#                    same-seed rerun)
#   make fleet-bench    the full fleet-scaling bench (1 -> 10k shards,
#                    blind vs locality legs); regenerates BENCH_fleet.json
#   make fault-smoke    fault-injection smoke run (CI guard): serve the
#                    committed plans/fault_smoke.json (shard crash +
#                    recover, link degrade + outage, transient failures)
#                    under threshold admission with a deadline and retry
#                    budget through the CLI, then the fault-tolerance
#                    bench in assert mode (availability >= 0.99 through a
#                    1-of-8 crash, threshold bounds the overload p99,
#                    offered == served + shed + expired, bit-identical
#                    same-seed rerun)
#   make fault-bench    the full fault-tolerance bench (800-request crash
#                    leg + 400-at-once overload); regenerates
#                    BENCH_fault.json
#   make obs-smoke      observability smoke run (CI guard): a faulted
#                    serve with --events-out + --profile through the
#                    CLI, exporting both the Chrome trace_event JSON
#                    and the JSONL event stream, then parse-validating
#                    both documents (round-trip through json.tool /
#                    json.loads — malformed exporter output fails CI)
#   make explore-smoke  design-space exploration smoke run: tiny grid,
#                    2 operating points — the CLI errors out on an
#                    empty frontier, so a green run asserts one exists
#   make explore-bench  the full exploration bench (default-space grid +
#                    halving determinism); regenerates BENCH_explore.json
#   make artifacts   AOT-lower the JAX/Pallas models to HLO-text artifacts
#                    (needs the python environment; the rust side works
#                    without this — the reference backend is the default)
#   make check       type-check all feature combinations
#   make lint        clippy, warnings as errors (same as CI)
#   make fmt         rustfmt check

CARGO ?= cargo
PYTHON ?= python3
ARTIFACTS_DIR ?= artifacts

.PHONY: build test bench serve-smoke perf-smoke perf-bench control-smoke control-bench trace-smoke trace-bench fleet-smoke fleet-bench fault-smoke fault-bench obs-smoke explore-smoke explore-bench artifacts check lint fmt clean

build:
	$(CARGO) build --release

test: build
	$(CARGO) test -q

bench:
	$(CARGO) bench --no-run
	$(CARGO) bench

serve-smoke: build
	$(CARGO) run --release -- serve --requests 32 --clusters 2

perf-smoke:
	PERF_SERVE_SMOKE=1 $(CARGO) bench --bench perf_serve

perf-bench:
	$(CARGO) bench --bench perf_serve

control-smoke:
	CONTROL_PLANE_SMOKE=1 $(CARGO) bench --bench control_plane

control-bench:
	$(CARGO) bench --bench control_plane

trace-smoke: build
	$(CARGO) run --release -- trace gen --rows 10000 --skew --out target/trace-smoke.csv
	$(CARGO) run --release -- serve --trace target/trace-smoke.csv --clusters 2 --scheduler wfq
	TRACE_FAIRNESS_SMOKE=1 $(CARGO) bench --bench trace_fairness

trace-bench:
	$(CARGO) bench --bench trace_fairness

fleet-smoke: build
	$(CARGO) run --release -- serve --requests 48 --clusters 8 --topology pod:2x2x2 --locality --scheduler batch
	FLEET_SCALING_SMOKE=1 $(CARGO) bench --bench fleet_scaling

fleet-bench:
	$(CARGO) bench --bench fleet_scaling

fault-smoke: build
	$(CARGO) run --release -- serve --requests 48 --clusters 8 --topology pod:2x2x2 --faults plans/fault_smoke.json --admission threshold:16 --deadline-ms 50 --max-retries 2
	FAULT_TOLERANCE_SMOKE=1 $(CARGO) bench --bench fault_tolerance

fault-bench:
	$(CARGO) bench --bench fault_tolerance

obs-smoke: build
	$(CARGO) run --release -- serve --requests 48 --clusters 8 --topology pod:2x2x2 --faults plans/fault_smoke.json --admission threshold:16 --deadline-ms 50 --max-retries 2 --profile --sample 2 --events-out target/obs-smoke.json
	$(CARGO) run --release -- serve --requests 48 --clusters 8 --topology pod:2x2x2 --faults plans/fault_smoke.json --admission threshold:16 --deadline-ms 50 --max-retries 2 --events-out target/obs-smoke.jsonl
	$(PYTHON) -m json.tool target/obs-smoke.json > /dev/null
	$(PYTHON) -c "import json; [json.loads(l) for l in open('target/obs-smoke.jsonl') if l.strip()]"

explore-smoke: build
	$(CARGO) run --release -- explore --space tiny --strategy grid --budget 8 --seed 7

explore-bench:
	$(CARGO) bench --bench explore_pareto

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../$(ARTIFACTS_DIR)

check:
	$(CARGO) check --all-targets
	$(CARGO) check --all-targets --no-default-features
	$(CARGO) check --all-targets --features pjrt

lint:
	$(CARGO) clippy --all-targets -- -D warnings

fmt:
	$(CARGO) fmt --check

clean:
	$(CARGO) clean
	rm -rf $(ARTIFACTS_DIR)
