//! Invariants of the fault-injection + graceful-degradation layer
//! (`serve/fault.rs` + `fault/`), proven end-to-end through the public
//! serve surface:
//!
//! 1. **Conservation by exact count** — on a drained run every offered
//!    request terminates exactly once: `offered == served + shed +
//!    expired` (with `expired = expired_deadline + retry_exhausted`),
//!    even under a compound of crash + admission + deadline +
//!    transient failures.
//! 2. **The inert config is a provable identity** — an empty
//!    `FaultPlan` under `AdmitAll` changes no report field (floats by
//!    bit pattern). The full randomized matrix lives in
//!    `tests/serve_equivalence.rs`; this file keeps one directed leg.
//! 3. **Root-store cold-fetch liveness** — crashing the only potential
//!    weight holder at cycle 0 cannot deadlock the fleet: weights
//!    re-stage from the root store and the survivors drain everything.
//! 4. **Deadline 0 sheds everything** — every admitted request expires
//!    before dispatch; nothing is served, the ledger still balances.
//! 5. **Determinism under active faults** — same seed + same plan is
//!    bit-identical, including the fault summary.

use attn_tinyml::deeploy::Target;
use attn_tinyml::fault::FaultPlan;
use attn_tinyml::models::MOBILEBERT;
use attn_tinyml::net::Topology;
use attn_tinyml::serve::{
    AdmissionPolicy, FaultConfig, Fifo, Fleet, RequestClass, ServeReport, Workload,
};
use attn_tinyml::sim::ClusterConfig;

fn fleet(n: usize) -> Fleet {
    Fleet::new(ClusterConfig::default(), Target::MultiCoreIta, n)
}

fn classes() -> Vec<RequestClass> {
    vec![RequestClass::new(&MOBILEBERT, 1)]
}

/// The compound stress config: simultaneous overload against a
/// bounded queue, a mid-batch crash with late recovery, a per-attempt
/// deadline, and a 20% transient failure rate with one retry.
fn stress_config() -> FaultConfig {
    FaultConfig {
        plan: FaultPlan::empty()
            .crash(1, 1)
            .recover(20_000_000, 1)
            .transient(200_000)
            .seeded(9),
        admission: AdmissionPolicy::Threshold { max_depth: 16 },
        deadline_cycles: Some(2_000_000),
        max_retries: 1,
        retry_backoff_cycles: 10_000,
    }
}

fn run_stress() -> ServeReport {
    let w = Workload::trace(classes(), vec![(0, 0); 60]);
    fleet(2).serve_faulted(&w, &mut Fifo, stress_config()).unwrap()
}

#[test]
fn conservation_holds_by_exact_count_under_compound_faults() {
    let r = run_stress();
    let f = r.fault.as_ref().expect("faulted run carries a summary");
    // every offered request terminates exactly once
    assert_eq!(
        r.offered as u64,
        r.served as u64 + f.shed + f.expired,
        "ledger must balance: offered {} != served {} + shed {} + expired {}",
        r.offered,
        r.served,
        f.shed,
        f.expired
    );
    assert_eq!(f.expired, f.expired_deadline + f.retry_exhausted);
    assert_eq!(f.shed_by_tenant.iter().sum::<u64>(), f.shed);
    // the FIFO fleet drains whatever it admitted
    assert_eq!(r.final_queue_depth, 0);
    // the stress shape actually exercised every degradation path
    assert_eq!(f.shed, 44, "60 at-once arrivals vs a 16-deep bound");
    assert_eq!(f.crashes, 1);
    assert!(f.killed_in_flight >= 1, "the crash caught a batch mid-flight");
    assert!(
        f.transient_failures > 0,
        "a 20% transient rate over dozens of commits must fire"
    );
    assert!(f.availability > 0.0 && f.availability < 1.0);
}

#[test]
fn inert_config_is_a_report_identity() {
    let w = Workload::poisson(classes(), 800.0, 24, 0xFA17);
    let plain = fleet(2).serve(&w, &mut Fifo).unwrap();
    let faulted =
        fleet(2).serve_faulted(&w, &mut Fifo, FaultConfig::default()).unwrap();
    assert_eq!(plain.makespan_cycles, faulted.makespan_cycles);
    assert_eq!(plain.served, faulted.served);
    assert_eq!(plain.batches, faulted.batches);
    assert_eq!(plain.p50_cycles, faulted.p50_cycles);
    assert_eq!(plain.p99_cycles, faulted.p99_cycles);
    assert_eq!(plain.energy_j.to_bits(), faulted.energy_j.to_bits());
    assert_eq!(
        plain.mean_queue_depth.to_bits(),
        faulted.mean_queue_depth.to_bits()
    );
    assert!(plain.fault.is_none());
    let f = faulted.fault.as_ref().unwrap();
    assert_eq!(f.crashes + f.shed + f.expired + f.retried, 0);
    assert_eq!(f.availability.to_bits(), 1.0f64.to_bits());
}

#[test]
fn crashing_the_only_holder_at_cycle_zero_still_drains() {
    // shard 0 is down before it ever stages weights: the survivor must
    // cold-fetch from the root store instead of waiting on a holder
    // that will never answer — liveness, not just correctness
    let w = Workload::trace(classes(), vec![(0, 0); 10]);
    let cfg = FaultConfig::with_plan(FaultPlan::empty().crash(0, 0));
    let r = fleet(2)
        .with_topology(Topology::parse("pod:1x1x2").unwrap())
        .serve_faulted(&w, &mut Fifo, cfg)
        .unwrap();
    assert_eq!(r.served, 10, "the surviving shard drains everything");
    assert_eq!(r.final_queue_depth, 0);
    let f = r.fault.as_ref().unwrap();
    assert_eq!((f.crashes, f.recoveries), (1, 0));
    assert_eq!(f.killed_in_flight, 0, "nothing was in flight at cycle 0");
    assert_eq!(f.availability.to_bits(), 1.0f64.to_bits());
    // the weights really came over the interconnect from the root
    let net = r.net.as_ref().expect("topology run carries a net block");
    assert!(net.restages >= 1, "cold fetch must be priced as a restage");
    // the dead shard did no work
    assert_eq!(r.cluster_utilization[0].to_bits(), 0.0f64.to_bits());
    assert!(r.cluster_utilization[1] > 0.0);
}

#[test]
fn deadline_zero_expires_every_request_and_still_balances() {
    let w = Workload::trace(classes(), (0..20).map(|i| (i * 1000, 0)).collect());
    let cfg = FaultConfig {
        deadline_cycles: Some(0),
        ..FaultConfig::default()
    };
    let r = fleet(2).serve_faulted(&w, &mut Fifo, cfg).unwrap();
    let f = r.fault.as_ref().unwrap();
    assert_eq!(r.served, 0, "a zero deadline expires ahead of dispatch");
    assert_eq!(f.expired, 20);
    assert_eq!(f.expired_deadline, 20);
    assert_eq!(f.shed, 0, "admission admitted everything");
    assert_eq!(r.offered as u64, r.served as u64 + f.shed + f.expired);
    assert_eq!(f.availability.to_bits(), 0.0f64.to_bits());
    assert_eq!(r.batches, 0);
    assert_eq!(r.final_queue_depth, 0);
}

#[test]
fn same_seed_and_plan_replay_bit_identically_with_faults_active() {
    let a = run_stress();
    let b = run_stress();
    assert_eq!(a.served, b.served);
    assert_eq!(a.makespan_cycles, b.makespan_cycles);
    assert_eq!(a.batches, b.batches);
    assert_eq!(a.class_switches, b.class_switches);
    assert_eq!(a.p50_cycles, b.p50_cycles);
    assert_eq!(a.p90_cycles, b.p90_cycles);
    assert_eq!(a.p99_cycles, b.p99_cycles);
    assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
    assert_eq!(a.gopj.to_bits(), b.gopj.to_bits());
    assert_eq!(a.mean_queue_depth.to_bits(), b.mean_queue_depth.to_bits());
    assert_eq!(a.final_queue_depth, b.final_queue_depth);
    // the whole degraded ledger, field for field
    assert_eq!(a.fault, b.fault);
}
