//! ServeReport invariants across schedulers, fleet sizes and arrival
//! processes: percentile ordering, served-request conservation, and the
//! degenerate one-request/one-cluster identity with `Compiled::stats()`.

use attn_tinyml::deeploy::Target;
use attn_tinyml::energy;
use attn_tinyml::models::{DINOV2S, MOBILEBERT, WHISPER_TINY_ENC};
use attn_tinyml::pipeline::Pipeline;
use attn_tinyml::serve::{
    scheduler_by_name, DynamicBatch, Fifo, RequestClass, RoundRobin, ServeReport, Workload,
};
use attn_tinyml::sim::ClusterConfig;
use attn_tinyml::util::propcheck::{check, Config};

fn classes() -> Vec<RequestClass> {
    vec![RequestClass::new(&MOBILEBERT, 1), RequestClass::new(&DINOV2S, 1)]
}

fn assert_invariants(r: &ServeReport, offered: usize, clusters: usize) {
    assert_eq!(r.offered, offered);
    assert_eq!(r.served, offered, "request conservation ({})", r.scheduler);
    assert!(r.p50_cycles <= r.p90_cycles, "p50 {} > p90 {}", r.p50_cycles, r.p90_cycles);
    assert!(r.p90_cycles <= r.p99_cycles, "p90 {} > p99 {}", r.p90_cycles, r.p99_cycles);
    assert!(
        r.p99_cycles <= r.makespan_cycles,
        "p99 {} > makespan {}",
        r.p99_cycles,
        r.makespan_cycles
    );
    assert_eq!(r.cluster_utilization.len(), clusters);
    for &u in &r.cluster_utilization {
        assert!((0.0..=1.0).contains(&u), "utilization {u} out of range");
    }
    assert!(r.mean_queue_depth >= 0.0);
    assert!(r.mean_queue_depth <= r.max_queue_depth as f64);
    assert!(r.energy_j > 0.0 && r.req_per_s > 0.0 && r.gops > 0.0);
    assert!(r.seconds > 0.0);
}

#[test]
fn single_request_single_cluster_reproduces_compiled_stats() {
    let compiled = Pipeline::new(ClusterConfig::default())
        .model(&MOBILEBERT)
        .target(Target::MultiCoreIta)
        .layers(1)
        .compile()
        .unwrap();
    let stats = compiled.stats();
    let w = Workload::single(&MOBILEBERT, 1);
    let r = Pipeline::new(ClusterConfig::default()).fleet(1).serve(&w).unwrap();
    // cycle-for-cycle: serve() degenerates to one pass of the compiled
    // command stream — no switch, no queueing, no batching
    assert_eq!(r.makespan_cycles, stats.cycles);
    assert_eq!(r.p50_cycles, stats.cycles);
    assert_eq!(r.p90_cycles, stats.cycles);
    assert_eq!(r.p99_cycles, stats.cycles);
    assert_eq!(r.served, 1);
    assert_eq!(r.batches, 1);
    assert_eq!(r.class_switches, 0);
    assert!((r.cluster_utilization[0] - 1.0).abs() < 1e-12);
    // and the energy identity: active energy + idle floor over one
    // cluster == the single-inference energy model evaluation
    let e = energy::evaluate(stats, ClusterConfig::default().freq_hz);
    let rel = (r.energy_j - e.total_j).abs() / e.total_j;
    assert!(rel < 1e-9, "serve energy {} vs simulate {}", r.energy_j, e.total_j);
}

#[test]
fn invariants_hold_across_random_open_loop_workloads() {
    // property: any (requests, clusters, scheduler, rate, seed) combo
    // conserves requests and keeps the percentile ordering
    let gen = |rng: &mut attn_tinyml::util::prng::XorShift64| {
        (
            1 + rng.next_below(24) as usize,       // requests
            1 + rng.next_below(4) as usize,        // clusters
            rng.next_below(3) as usize,            // scheduler
            50.0 * (1 + rng.next_below(20)) as f64, // rate req/s
            rng.next_u64(),                        // workload seed
        )
    };
    let shrink = |&(req, cl, s, rate, seed): &(usize, usize, usize, f64, u64)| {
        let mut c = Vec::new();
        if req > 1 {
            c.push((req / 2, cl, s, rate, seed));
        }
        if cl > 1 {
            c.push((req, cl / 2, s, rate, seed));
        }
        c
    };
    check(
        Config { cases: 30, seed: 0x5EED_CAFE },
        gen,
        shrink,
        |&(requests, clusters, sched_idx, rate, seed)| {
            let name = ["fifo", "rr", "batch"][sched_idx];
            let mut sched = scheduler_by_name(name).unwrap();
            let w = Workload::poisson(classes(), rate, requests, seed);
            let r = Pipeline::new(ClusterConfig::default())
                .fleet(clusters)
                .serve_with(&w, sched.as_mut())
                .map_err(|e| format!("serve failed: {e}"))?;
            if r.served != requests {
                return Err(format!(
                    "{name}: served {} of {requests} on {clusters} clusters",
                    r.served
                ));
            }
            if r.p50_cycles > r.p90_cycles || r.p90_cycles > r.p99_cycles {
                return Err(format!(
                    "{name}: percentiles out of order: {} {} {}",
                    r.p50_cycles, r.p90_cycles, r.p99_cycles
                ));
            }
            if r.p99_cycles > r.makespan_cycles {
                return Err(format!(
                    "{name}: p99 {} beyond makespan {}",
                    r.p99_cycles, r.makespan_cycles
                ));
            }
            if r.cluster_utilization.iter().any(|u| !(0.0..=1.0).contains(u)) {
                return Err(format!("{name}: utilization out of [0,1]"));
            }
            Ok(())
        },
    );
}

#[test]
fn bursty_workload_invariants_all_schedulers() {
    let w = Workload::bursty(classes(), 300.0, 4.0, 0.02, 48, 0xB00);
    for name in ["fifo", "rr", "batch"] {
        let mut sched = scheduler_by_name(name).unwrap();
        let r = Pipeline::new(ClusterConfig::default())
            .fleet(2)
            .serve_with(&w, sched.as_mut())
            .unwrap();
        assert_invariants(&r, 48, 2);
    }
}

#[test]
fn closed_loop_conserves_requests_and_orders_percentiles() {
    let w = Workload::closed_loop(classes(), 3, 10_000, 12, 0xC10);
    let r = Pipeline::new(ClusterConfig::default())
        .fleet(2)
        .serve_with(&w, &mut RoundRobin)
        .unwrap();
    assert_invariants(&r, 12, 2);
    // closed loop never queues more than the client count
    assert!(r.max_queue_depth <= 3, "depth {} > clients", r.max_queue_depth);
}

#[test]
fn trace_replay_with_all_three_networks() {
    let classes = vec![
        RequestClass::new(&MOBILEBERT, 1),
        RequestClass::new(&DINOV2S, 1),
        RequestClass::new(&WHISPER_TINY_ENC, 1),
    ];
    let w = Workload::trace(
        classes,
        vec![(0, 0), (0, 1), (0, 2), (1_000_000, 0), (1_000_000, 1), (1_000_000, 2)],
    );
    let r = Pipeline::new(ClusterConfig::default())
        .fleet(3)
        .serve_with(&w, &mut DynamicBatch::default())
        .unwrap();
    assert_invariants(&r, 6, 3);
}

#[test]
fn batching_never_loses_to_fifo_on_one_cluster() {
    // on a single cluster the dynamic batcher is fifo + coalescing:
    // coalescing only removes class switches and converts cold passes
    // to steady-state increments, so throughput can only improve
    let w = Workload::bursty(classes(), 400.0, 4.0, 0.02, 40, 0xAB);
    let fifo = Pipeline::new(ClusterConfig::default()).fleet(1).serve(&w).unwrap();
    let batch = Pipeline::new(ClusterConfig::default())
        .fleet(1)
        .serve_with(&w, &mut DynamicBatch::default())
        .unwrap();
    assert_eq!(fifo.served, batch.served);
    assert!(
        batch.makespan_cycles <= fifo.makespan_cycles,
        "batch {} > fifo {}",
        batch.makespan_cycles,
        fifo.makespan_cycles
    );
}

#[test]
fn serve_is_deterministic() {
    let w = Workload::poisson(classes(), 250.0, 20, 0xD0D0);
    let a = Pipeline::new(ClusterConfig::default())
        .fleet(2)
        .serve_with(&w, &mut Fifo)
        .unwrap();
    let b = Pipeline::new(ClusterConfig::default())
        .fleet(2)
        .serve_with(&w, &mut Fifo)
        .unwrap();
    assert_eq!(a.makespan_cycles, b.makespan_cycles);
    assert_eq!(a.p99_cycles, b.p99_cycles);
    assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
}
