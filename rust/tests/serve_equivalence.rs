//! The refactors changed no observable result: the steppable engine
//! (`ServeEngine` behind `Fleet::serve`, bucketed QueueView + streamed
//! arrivals + wake heap + bounded LatencyStore) and the retained
//! pre-optimization loop (`serve::naive`) produce **bit-identical**
//! `ServeReport`s on randomized small workloads, across all three
//! built-in schedulers, fleet sizes 1–4, and every arrival process
//! (poisson, bursty, trace, closed-loop, diurnal, and multi-tenant
//! trace replay through `trace::generate` — per-tenant summaries and
//! the Jain index included in the bit-for-bit check). The same matrix
//! also propchecks that attaching the `StaticNominal` controller is a
//! provable no-op: every core report field stays bit-identical, only
//! the `control` summary block appears — and that attaching the
//! degenerate `Flat` topology (`Fleet::with_topology`) is likewise a
//! no-op: the router prices nothing, every core field (per-tenant
//! summaries and Jain included) stays bit-identical, and only an empty
//! `net` block (no levels, zero re-staging fetch cycles) appears.
//! Likewise for the fault layer: an empty `FaultPlan` under `AdmitAll`
//! (`FaultConfig::default()` through `Fleet::serve_faulted`) must be
//! provably inert — every core field bit-identical, only an all-zero
//! `FaultSummary` with availability 1.0 attached.

use attn_tinyml::deeploy::Target;
use attn_tinyml::energy::operating_point::NOMINAL_INDEX;
use attn_tinyml::models::{DINOV2S, MOBILEBERT};
use attn_tinyml::net::Topology;
use attn_tinyml::serve::naive::{serve_naive, NaivePolicy};
use attn_tinyml::serve::{
    scheduler_by_name, FaultConfig, Fleet, RequestClass, ServeReport, StaticNominal,
    Workload, DEFAULT_CONTROL_CADENCE_CYCLES,
};
use attn_tinyml::sim::ClusterConfig;
use attn_tinyml::util::prng::XorShift64;
use attn_tinyml::util::propcheck::{check, Config};

fn classes() -> Vec<RequestClass> {
    vec![RequestClass::new(&MOBILEBERT, 1), RequestClass::new(&DINOV2S, 1)]
}

/// Field-for-field equality, floats compared by bit pattern.
fn reports_identical(a: &ServeReport, b: &ServeReport) -> Result<(), String> {
    let mut errs = Vec::new();
    let mut chk = |field: &str, same: bool| {
        if !same {
            errs.push(field.to_string());
        }
    };
    chk("scheduler", a.scheduler == b.scheduler);
    chk("clusters", a.clusters == b.clusters);
    chk("offered", a.offered == b.offered);
    chk("served", a.served == b.served);
    chk("makespan_cycles", a.makespan_cycles == b.makespan_cycles);
    chk("seconds", a.seconds.to_bits() == b.seconds.to_bits());
    chk("req_per_s", a.req_per_s.to_bits() == b.req_per_s.to_bits());
    chk("gops", a.gops.to_bits() == b.gops.to_bits());
    chk("energy_j", a.energy_j.to_bits() == b.energy_j.to_bits());
    chk("mj_per_req", a.mj_per_req.to_bits() == b.mj_per_req.to_bits());
    chk("gopj", a.gopj.to_bits() == b.gopj.to_bits());
    chk("p50_cycles", a.p50_cycles == b.p50_cycles);
    chk("p90_cycles", a.p90_cycles == b.p90_cycles);
    chk("p99_cycles", a.p99_cycles == b.p99_cycles);
    chk(
        "mean_latency_cycles",
        a.mean_latency_cycles.to_bits() == b.mean_latency_cycles.to_bits(),
    );
    chk(
        "mean_queue_depth",
        a.mean_queue_depth.to_bits() == b.mean_queue_depth.to_bits(),
    );
    chk("max_queue_depth", a.max_queue_depth == b.max_queue_depth);
    chk(
        "cluster_utilization",
        a.cluster_utilization.len() == b.cluster_utilization.len()
            && a
                .cluster_utilization
                .iter()
                .zip(&b.cluster_utilization)
                .all(|(x, y)| x.to_bits() == y.to_bits()),
    );
    chk("class_switches", a.class_switches == b.class_switches);
    chk("batches", a.batches == b.batches);
    chk("fairness_jain", a.fairness_jain.to_bits() == b.fairness_jain.to_bits());
    chk(
        "tenants",
        a.tenants.len() == b.tenants.len()
            && a.tenants.iter().zip(&b.tenants).all(|(x, y)| {
                x.tenant == y.tenant
                    && x.served == y.served
                    && x.req_per_s.to_bits() == y.req_per_s.to_bits()
                    && x.p50_cycles == y.p50_cycles
                    && x.p99_cycles == y.p99_cycles
                    && x.mean_latency_cycles.to_bits()
                        == y.mean_latency_cycles.to_bits()
                    && x.dominant_share.to_bits() == y.dominant_share.to_bits()
            }),
    );
    chk("freq_hz", a.freq_hz.to_bits() == b.freq_hz.to_bits());
    chk("final_queue_depth", a.final_queue_depth == b.final_queue_depth);
    if errs.is_empty() {
        Ok(())
    } else {
        Err(format!("fields differ: {}", errs.join(", ")))
    }
}

fn workload_for(kind: usize, rate: f64, requests: usize, seed: u64) -> Workload {
    match kind {
        0 => Workload::poisson(classes(), rate, requests, seed),
        1 => Workload::bursty(classes(), rate, 6.0, 0.02, requests, seed),
        2 => {
            // deterministic trace derived from the seed: clustered and
            // tied arrival cycles exercise the admission-order paths
            let mut rng = XorShift64::new(seed);
            let entries: Vec<(u64, usize)> = (0..requests)
                .map(|_| {
                    (rng.next_below(2_000_000) / 4 * 4, rng.next_below(2) as usize)
                })
                .collect();
            Workload::trace(classes(), entries)
        }
        3 => Workload::closed_loop(
            classes(),
            1 + (seed % 5) as usize,
            (seed % 100_000).max(1),
            requests,
            seed,
        ),
        4 => Workload::diurnal(classes(), rate, 0.8, 0.1, requests, seed),
        _ => {
            // multi-tenant trace replay through trace::generate — the
            // 9:1 tenant skew and tied cycles must flow through both
            // loops (and the per-tenant summaries) identically
            let cls = classes();
            let class_seq: Vec<usize> = cls.iter().map(|c| c.bucket()).collect();
            let spec = attn_tinyml::trace::skewed_two_tenant(
                requests,
                rate * 10.0,
                &class_seq,
                seed,
            );
            let entries = attn_tinyml::trace::generate(spec).expect("valid spec");
            Workload::trace_entries(cls, entries)
        }
    }
}

/// `StaticNominal` at the default cadence must be a provable no-op:
/// every core field of the report stays bit-identical to the
/// uncontrolled run; only the `control` summary block appears.
fn static_nominal_is_noop(
    fleet: &Fleet,
    w: &Workload,
    name: &str,
    opt: &ServeReport,
) -> Result<(), String> {
    let mut sched = scheduler_by_name(name).unwrap();
    let mut ctl = StaticNominal;
    let controlled = fleet
        .serve_controlled(w, sched.as_mut(), &mut ctl, DEFAULT_CONTROL_CADENCE_CYCLES, NOMINAL_INDEX)
        .map_err(|e| format!("controlled serve failed: {e}"))?;
    reports_identical(&controlled, opt).map_err(|e| format!("static-nominal deviated: {e}"))?;
    if opt.control.is_some() {
        return Err("uncontrolled run carries a control summary".into());
    }
    let summary = controlled
        .control
        .as_ref()
        .ok_or("controlled run lost its control summary")?;
    if summary.controller != "static-nominal" {
        return Err(format!("wrong controller name: {}", summary.controller));
    }
    if summary.dvfs_transitions != 0 || summary.parks != 0 || summary.wakes != 0 {
        return Err("static-nominal actuated something".into());
    }
    if summary.energy_saved_j.to_bits() != 0.0f64.to_bits() {
        return Err(format!("phantom energy delta: {}", summary.energy_saved_j));
    }
    Ok(())
}

/// A `Flat` topology must be a provable no-op: the fleet carries a
/// router, but every path is free, so every core report field stays
/// bit-identical and only the empty `net` block appears.
fn flat_topology_is_identity(
    clusters: usize,
    w: &Workload,
    name: &str,
    opt: &ServeReport,
) -> Result<(), String> {
    let fleet = Fleet::new(ClusterConfig::default(), Target::MultiCoreIta, clusters)
        .with_topology(Topology::Flat);
    let mut sched = scheduler_by_name(name).unwrap();
    let flat = fleet
        .serve(w, sched.as_mut())
        .map_err(|e| format!("flat-topology serve failed: {e}"))?;
    reports_identical(&flat, opt).map_err(|e| format!("flat topology deviated: {e}"))?;
    if opt.net.is_some() {
        return Err("topology-free run carries a net block".into());
    }
    let net = flat.net.as_ref().ok_or("flat run lost its net block")?;
    if net.topology != "flat" {
        return Err(format!("wrong topology label: {}", net.topology));
    }
    if !net.levels.is_empty() {
        return Err(format!("flat topology grew {} link levels", net.levels.len()));
    }
    if net.restage_fetch_cycles != 0 {
        return Err(format!(
            "flat topology charged {} fetch cycles",
            net.restage_fetch_cycles
        ));
    }
    if net.dispatches != opt.batches {
        return Err(format!(
            "router priced {} dispatches, engine ran {} batches",
            net.dispatches, opt.batches
        ));
    }
    Ok(())
}

/// `FaultConfig::default()` (empty plan, admit-all, no deadline) must
/// be a provable no-op: the fault layer is attached but defers
/// nothing, so every core report field stays bit-identical and only
/// the all-zero `FaultSummary` appears.
fn empty_fault_plan_is_identity(
    fleet: &Fleet,
    w: &Workload,
    name: &str,
    opt: &ServeReport,
) -> Result<(), String> {
    let mut sched = scheduler_by_name(name).unwrap();
    let faulted = fleet
        .serve_faulted(w, sched.as_mut(), FaultConfig::default())
        .map_err(|e| format!("faulted serve failed: {e}"))?;
    reports_identical(&faulted, opt)
        .map_err(|e| format!("empty fault plan deviated: {e}"))?;
    if opt.fault.is_some() {
        return Err("fault-free run carries a fault summary".into());
    }
    let f = faulted.fault.as_ref().ok_or("faulted run lost its fault summary")?;
    if f.admission != "admit-all" {
        return Err(format!("wrong admission label: {}", f.admission));
    }
    let zeros = [
        ("crashes", f.crashes),
        ("recoveries", f.recoveries),
        ("link_events", f.link_events),
        ("killed_in_flight", f.killed_in_flight),
        ("transient_failures", f.transient_failures),
        ("shed", f.shed),
        ("expired", f.expired),
        ("expired_deadline", f.expired_deadline),
        ("retry_exhausted", f.retry_exhausted),
        ("retried", f.retried),
        ("failed_over", f.failed_over),
    ];
    for (field, v) in zeros {
        if v != 0 {
            return Err(format!("inert config counted {field} = {v}"));
        }
    }
    if f.availability.to_bits() != 1.0f64.to_bits() {
        return Err(format!("availability {} != 1.0", f.availability));
    }
    if f.deadline_cycles.is_some() {
        return Err("inert config reports a deadline".into());
    }
    if faulted.final_queue_depth != 0 {
        return Err(format!(
            "drained run left {} queued",
            faulted.final_queue_depth
        ));
    }
    Ok(())
}

#[test]
fn optimized_and_naive_loops_are_bit_identical() {
    let gen = |rng: &mut XorShift64| {
        (
            1 + rng.next_below(24) as usize,        // requests
            1 + rng.next_below(4) as usize,         // clusters 1..=4
            rng.next_below(3) as usize,             // scheduler
            rng.next_below(6) as usize,             // arrival kind
            50.0 * (1 + rng.next_below(20)) as f64, // rate req/s
            rng.next_u64(),                         // workload seed
        )
    };
    let shrink = |&(req, cl, s, k, rate, seed): &(
        usize,
        usize,
        usize,
        usize,
        f64,
        u64,
    )| {
        let mut c = Vec::new();
        if req > 1 {
            c.push((req / 2, cl, s, k, rate, seed));
        }
        if cl > 1 {
            c.push((req, cl / 2, s, k, rate, seed));
        }
        if k > 0 {
            c.push((req, cl, s, 0, rate, seed));
        }
        c
    };
    check(
        Config { cases: 40, seed: 0xE0_1DE7 },
        gen,
        shrink,
        |&(requests, clusters, sched_idx, kind, rate, seed)| {
            let name = ["fifo", "rr", "batch"][sched_idx];
            let w = workload_for(kind, rate, requests, seed);
            let fleet = Fleet::new(ClusterConfig::default(), Target::MultiCoreIta, clusters);
            let policy = NaivePolicy::by_name(name).unwrap();
            let naive = serve_naive(&fleet, &w, &policy)
                .map_err(|e| format!("naive serve failed: {e}"))?;
            let mut sched = scheduler_by_name(name).unwrap();
            let opt = fleet
                .serve(&w, sched.as_mut())
                .map_err(|e| format!("optimized serve failed: {e}"))?;
            reports_identical(&opt, &naive)
                .map_err(|e| format!("{name}/{kind} x{requests} on {clusters}: {e}"))?;
            static_nominal_is_noop(&fleet, &w, name, &opt)
                .map_err(|e| format!("{name}/{kind} x{requests} on {clusters}: {e}"))?;
            flat_topology_is_identity(clusters, &w, name, &opt)
                .map_err(|e| format!("{name}/{kind} x{requests} on {clusters}: {e}"))?;
            empty_fault_plan_is_identity(&fleet, &w, name, &opt)
                .map_err(|e| format!("{name}/{kind} x{requests} on {clusters}: {e}"))
        },
    );
}

#[test]
fn equivalence_holds_under_sustained_backlog() {
    // one directed heavy case per scheduler: a single-cluster overload
    // where the naive loop's queue actually backs up (the regime the
    // perf bench measures), still bit-identical
    let w = Workload::bursty(classes(), 5_000.0, 8.0, 0.02, 96, 0xBAC1406);
    let fleet = Fleet::new(ClusterConfig::default(), Target::MultiCoreIta, 2);
    for name in ["fifo", "rr", "batch"] {
        let naive = serve_naive(&fleet, &w, &NaivePolicy::by_name(name).unwrap()).unwrap();
        let mut sched = scheduler_by_name(name).unwrap();
        let opt = fleet.serve(&w, sched.as_mut()).unwrap();
        reports_identical(&opt, &naive).unwrap_or_else(|e| panic!("{name}: {e}"));
        static_nominal_is_noop(&fleet, &w, name, &opt)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        flat_topology_is_identity(2, &w, name, &opt)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        empty_fault_plan_is_identity(&fleet, &w, name, &opt)
            .unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(opt.max_queue_depth >= 8, "{name}: workload failed to backlog");
    }
}
