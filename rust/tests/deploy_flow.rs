//! Integration tests of the full deployment flow: graph -> passes ->
//! tiling -> lifetime -> allocation -> schedule -> codegen -> simulate,
//! across all three evaluation networks and both targets.

use attn_tinyml::coordinator::ModelReport;
use attn_tinyml::deeploy::{
    self, allocator, lifetime, passes, schedule, tiler, Target,
};
use attn_tinyml::models::{self, ModelConfig, ALL_MODELS, MOBILEBERT};
use attn_tinyml::pipeline::Pipeline;
use attn_tinyml::sim::{ClusterConfig, Cmd, Engine};
use attn_tinyml::util::propcheck::{check, Config};
use attn_tinyml::util::prng::XorShift64;

/// The builder API with the paper's default geometry.
fn run_layers(cfg: &ModelConfig, target: Target, layers: usize) -> ModelReport {
    Pipeline::new(ClusterConfig::default())
        .model(cfg)
        .target(target)
        .layers(layers)
        .compile()
        .unwrap()
        .simulate()
}

#[test]
fn deploy_all_models_both_targets() {
    for cfg in ALL_MODELS {
        for target in [Target::MultiCore, Target::MultiCoreIta] {
            let dep = deeploy::deploy_layers(cfg, target, 1).unwrap();
            assert!(!dep.steps.is_empty(), "{}", cfg.name);
            assert!(dep.total_ops > 0);
            assert!(
                dep.l1_peak_bytes <= tiler::L1_BUDGET,
                "{}: L1 {}",
                cfg.name,
                dep.l1_peak_bytes
            );
        }
    }
}

#[test]
fn allocator_invariant_on_real_graphs() {
    // the property test in allocator.rs uses synthetic intervals; this
    // runs the verifier on every real network graph
    for cfg in ALL_MODELS {
        for fuse in [false, true] {
            let mut g = models::build_graph_layers(cfg, 2);
            if fuse {
                passes::fuse_mha(&mut g);
            }
            passes::map_operators(&mut g, fuse);
            let order = schedule::topo_schedule(&g);
            let ivs = lifetime::analyze(&g, &order);
            let alloc = allocator::allocate(&ivs);
            allocator::verify(&ivs, &alloc)
                .unwrap_or_else(|(a, b)| panic!("{}: {a} overlaps {b}", cfg.name));
        }
    }
}

#[test]
fn fusion_preserves_mac_work() {
    // fusing MHA must not change the MAC content of the network
    // (softmax accounting differs: 5 ops/elem ride on the fused op)
    let mut g1 = models::build_graph_layers(&MOBILEBERT, 1);
    let before_macs: u64 = g1
        .nodes
        .iter()
        .filter(|n| {
            matches!(
                n.op,
                deeploy::ir::Op::MatMul | deeploy::ir::Op::Gemm { .. }
            )
        })
        .map(|n| g1.node_ops(n))
        .sum();
    passes::fuse_mha(&mut g1);
    let after: u64 = g1.nodes.iter().map(|n| g1.node_ops(n)).sum();
    // fused total >= unfused MACs (adds softmax ops, removes none)
    assert!(after >= before_macs);
}

#[test]
fn simulation_deterministic() {
    // .uncached() forces two genuinely independent deploy+simulate runs
    // (the cache would otherwise share one memoized simulation)
    let run = || {
        Pipeline::new(ClusterConfig::default())
            .model(&MOBILEBERT)
            .target(Target::MultiCoreIta)
            .layers(1)
            .uncached()
            .compile()
            .unwrap()
            .simulate()
    };
    let a = run();
    let b = run();
    assert_eq!(a.cycles, b.cycles);
    assert_eq!(a.mj_per_inf, b.mj_per_inf);
}

#[test]
fn acceleration_strictly_ordered() {
    // multicore < unfused ITA < fused ITA, for every network
    let cluster = ClusterConfig::default();
    let engine = Engine::new(cluster);
    for cfg in ALL_MODELS {
        let mut cycles = Vec::new();
        for (fuse, ita) in [(false, false), (false, true), (true, true)] {
            let mut g = models::build_graph_layers(cfg, 1);
            if fuse {
                passes::fuse_mha(&mut g);
            }
            passes::map_operators(&mut g, ita);
            let order = schedule::topo_schedule(&g);
            let plans = tiler::plan_graph(&g, tiler::L1_BUDGET).unwrap();
            let steps = deeploy::codegen::generate(&g, &order, &plans).unwrap();
            cycles.push(engine.run(&steps).cycles);
        }
        assert!(cycles[0] > cycles[1], "{}: {:?}", cfg.name, cycles);
        assert!(cycles[1] > cycles[2], "{}: {:?}", cfg.name, cycles);
    }
}

#[test]
fn layer_scaling_is_linear() {
    // identical encoder blocks: N layers ~ N x 1 layer (within the
    // one-off input staging)
    let one = run_layers(&MOBILEBERT, Target::MultiCoreIta, 1);
    let four = run_layers(&MOBILEBERT, Target::MultiCoreIta, 4);
    let ratio = four.seconds / one.seconds; // both extrapolate to 24 layers
    assert!((ratio - 1.0).abs() < 0.02, "ratio {ratio}");
}

#[test]
fn property_deployment_never_breaks_invariants() {
    // random layer counts and models: steps reference only earlier
    // steps, ITA commands only appear for the ITA target
    check(
        Config { cases: 12, seed: 0xDEB10 },
        |rng: &mut XorShift64| {
            (
                rng.next_below(3) as usize,
                1 + rng.next_below(2) as usize,
                rng.next_below(2) == 0,
            )
        },
        |&(m, l, t)| {
            let mut v = Vec::new();
            if l > 1 {
                v.push((m, l - 1, t));
            }
            v
        },
        |&(model_idx, layers, use_ita)| {
            let cfg = ALL_MODELS[model_idx];
            let target = if use_ita { Target::MultiCoreIta } else { Target::MultiCore };
            let dep = deeploy::deploy_layers(cfg, target, layers)
                .map_err(|e| format!("deploy failed: {e}"))?;
            for (i, s) in dep.steps.iter().enumerate() {
                for &d in &s.deps {
                    if d >= i {
                        return Err(format!("step {i} deps on {d}"));
                    }
                }
                if !use_ita
                    && matches!(s.cmd, Cmd::ItaGemm { .. } | Cmd::ItaAttention { .. })
                {
                    return Err("ITA command on multicore target".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn bank_sweep_monotone() {
    // more banks -> less contention -> never slower (the tunable
    // interconnect claim, quantified by benches/ablation_interconnect)
    let dep = deeploy::deploy_layers(&MOBILEBERT, Target::MultiCoreIta, 1).unwrap();
    let mut prev = u64::MAX;
    for banks in [8, 16, 32, 64] {
        let mut cfg = ClusterConfig::default();
        cfg.tcdm_banks = banks;
        cfg.tcdm_bank_bytes = 128 * 1024 / banks;
        let cycles = Engine::new(cfg).run(&dep.steps).cycles;
        assert!(cycles <= prev, "banks {banks}: {cycles} > {prev}");
        prev = cycles;
    }
}

#[test]
fn port_sweep_saturates_at_sixteen() {
    use attn_tinyml::sim::timing::TimingModel;
    let dep = deeploy::deploy_layers(&MOBILEBERT, Target::MultiCoreIta, 1).unwrap();
    let base = ClusterConfig::default();
    let run_ports = |ports: usize| {
        let tm = TimingModel::with_ports(&base.ita, base.tcdm_banks, ports);
        Engine::with_timing(base.clone(), tm).run(&dep.steps).cycles
    };
    let c8 = run_ports(8);
    let c16 = run_ports(16);
    let c32 = run_ports(32);
    assert!(c8 > c16, "under-provisioned ports must starve the datapath");
    assert_eq!(c16, c32, "beyond 128 B/cy the datapath is the limit");
}

#[test]
fn single_context_regfile_exposes_config() {
    let dep = deeploy::deploy_layers(&MOBILEBERT, Target::MultiCoreIta, 1).unwrap();
    let dual = Engine::new(ClusterConfig::default()).run(&dep.steps).cycles;
    let mut e = Engine::new(ClusterConfig::default());
    e.expose_config = true;
    let single = e.run(&dep.steps).cycles;
    assert!(single > dual);
    // bounded by (#ITA tasks - 1) x CONFIG_CYCLES
    let n_ita = dep
        .steps
        .iter()
        .filter(|s| matches!(s.cmd, Cmd::ItaGemm { .. } | Cmd::ItaAttention { .. }))
        .count() as u64;
    assert!(single - dual <= n_ita * attn_tinyml::sim::timing::CONFIG_CYCLES);
}

#[test]
fn whisper_stem_accounted_once() {
    use attn_tinyml::models::WHISPER_TINY_ENC;
    // extrapolating from 1 layer (+ stem added analytically) must agree
    // with the full-network simulation within a few percent
    let one = run_layers(&WHISPER_TINY_ENC, Target::MultiCoreIta, 1);
    let full = run_layers(
        &WHISPER_TINY_ENC,
        Target::MultiCoreIta,
        WHISPER_TINY_ENC.layers,
    );
    let err = (one.seconds - full.seconds).abs() / full.seconds;
    assert!(err < 0.05, "extrapolation error {err}");
}

#[test]
fn e2e_report_fields_consistent() {
    let r = run_layers(&MOBILEBERT, Target::MultiCoreIta, 1);
    assert!((r.gops - MOBILEBERT.gop_per_inference / r.seconds).abs() < 1e-9);
    assert!((r.mj_per_inf - r.energy_j * 1e3).abs() < 1e-12);
    assert!((r.inf_per_s * r.seconds - 1.0).abs() < 1e-9);
    assert!(r.ita_utilization > 0.5 && r.ita_utilization < 1.0);
}
