//! Fairness contracts for multi-tenant trace serving.
//!
//! A drained run serves every offered request, so the end-of-run Jain
//! index always reflects the *offered* mix, not the scheduler. The
//! scheduler's fairness shows up **during sustained contention**: these
//! tests freeze a run mid-overload with `ServeEngine::run_until` and
//! read the per-tenant delivered throughput at that horizon. On the
//! bundled 9:1-skew two-tenant overload trace (`trace::skewed_two_tenant`
//! at ~8x fleet capacity) the fair policies must hold Jain >= 0.95 while
//! Fifo — which serves in arrival order and therefore mirrors the 9:1
//! skew — collapses below 0.75. `benches/trace_fairness` records the
//! same scenario in `BENCH_trace.json`.

use attn_tinyml::deeploy::Target;
use attn_tinyml::energy::operating_point::NOMINAL_FREQ_HZ;
use attn_tinyml::models::MOBILEBERT;
use attn_tinyml::serve::{
    Drf, Fifo, Fleet, RequestClass, Scheduler, ServeEngine, ServeReport, Wfq, Workload,
};
use attn_tinyml::sim::ClusterConfig;
use attn_tinyml::trace::{
    generate, skewed_two_tenant, symmetric, write_csv, write_jsonl, TraceEntry,
};

fn classes() -> Vec<RequestClass> {
    vec![RequestClass::new(&MOBILEBERT, 1)]
}

fn class_seq() -> Vec<usize> {
    classes().iter().map(|c| c.bucket()).collect()
}

fn fleet(n: usize) -> Fleet {
    Fleet::new(ClusterConfig::default(), Target::MultiCoreIta, n)
}

/// The bundled overload scenario: 9:1 tenant skew at ~12 000 req/s
/// against ~1 560 inf/s of two-cluster capacity. Even the minority
/// tenant (~1 200 req/s) exceeds its fair half-share (~780 inf/s), so
/// both tenants stay backlogged through the measurement horizon — the
/// regime where the scheduler, not the arrival mix, decides who runs.
fn skewed_overload(seed: u64) -> Workload {
    let entries = generate(skewed_two_tenant(4_000, 12_000.0, &class_seq(), seed)).unwrap();
    Workload::trace_entries(classes(), entries)
}

/// Freeze the run at `horizon` cycles and report what was delivered.
fn report_at(
    fleet: &Fleet,
    w: &Workload,
    sched: &mut dyn Scheduler,
    horizon: u64,
) -> ServeReport {
    let mut engine = ServeEngine::new(fleet, w, sched).expect("engine builds");
    engine.run_until(horizon);
    engine.finish()
}

/// 0.2 simulated seconds: late enough for ~300 completions, early
/// enough that the 4 000-row trace is still arriving and backlogged.
fn horizon() -> u64 {
    (0.2 * NOMINAL_FREQ_HZ) as u64
}

#[test]
fn symmetric_tenants_score_a_perfect_jain_index() {
    // strictly alternating tenants, run to completion: delivered counts
    // are exactly equal and the Jain index is exactly 1.0 (n*x^2 and
    // (sum x)^2 are the same integer-valued float)
    let cls = classes();
    let bucket = cls[0].bucket();
    let entries: Vec<TraceEntry> = (0..200)
        .map(|i| TraceEntry { cycle: i as u64 * 10_000, tenant: i % 2, class: 0, seq_len: bucket })
        .collect();
    let w = Workload::trace_entries(cls, entries);
    let r = fleet(2).serve(&w, &mut Wfq::default()).unwrap();
    assert_eq!(r.served, 200);
    assert_eq!(r.tenants.len(), 2);
    assert_eq!(r.tenants[0].served, 100);
    assert_eq!(r.tenants[1].served, 100);
    assert_eq!(r.fairness_jain.to_bits(), 1.0f64.to_bits(), "jain {}", r.fairness_jain);
    assert_eq!(
        r.tenants[0].dominant_share.to_bits(),
        r.tenants[1].dominant_share.to_bits()
    );

    // the seeded symmetric generator draws tenants uniformly, so the
    // delivered split is near-even and the index near-perfect
    let w = Workload::trace_entries(
        classes(),
        generate(symmetric(2_000, 2, 1_000.0, &class_seq(), 11)).unwrap(),
    );
    let r = fleet(2).serve(&w, &mut Wfq::default()).unwrap();
    assert_eq!(r.served, 2_000);
    assert!(r.fairness_jain > 0.99, "jain {}", r.fairness_jain);
}

#[test]
fn fair_schedulers_hold_jain_under_skewed_overload_where_fifo_collapses() {
    let w = skewed_overload(0xFA1);
    let f = fleet(2);
    let h = horizon();

    let wfq = report_at(&f, &w, &mut Wfq::default(), h);
    let drf = report_at(&f, &w, &mut Drf::default(), h);
    let fifo = report_at(&f, &w, &mut Fifo, h);

    // enough completions at the horizon for the index to be meaningful
    for r in [&wfq, &drf, &fifo] {
        assert!(r.served > 100, "{}: only {} served by the horizon", r.scheduler, r.served);
        assert!(r.served < r.offered, "{}: overload drained early", r.scheduler);
        assert_eq!(r.tenants.len(), 2);
    }

    // the acceptance bounds: fair policies >= 0.95, fifo < 0.75
    assert!(wfq.fairness_jain >= 0.95, "wfq jain {}", wfq.fairness_jain);
    assert!(drf.fairness_jain >= 0.95, "drf jain {}", drf.fairness_jain);
    assert!(fifo.fairness_jain < 0.75, "fifo jain {}", fifo.fairness_jain);

    // fifo mirrors the 9:1 arrival skew; the fair policies split the
    // fleet near-evenly while both tenants stay backlogged
    assert!(
        fifo.tenants[0].served > 4 * fifo.tenants[1].served,
        "fifo split {}:{}",
        fifo.tenants[0].served,
        fifo.tenants[1].served
    );
    let (a, b) = (wfq.tenants[0].served, wfq.tenants[1].served);
    assert!(a.abs_diff(b) * 5 < a + b, "wfq split {a}:{b} drifted past 20%");
}

#[test]
fn minority_p99_stays_within_twice_the_fair_share_baseline() {
    // fair-share baseline: the minority tenant's rows alone on half the
    // fleet (1 of 2 clusters) — the service it would get from a hard
    // partition. Under WFQ/DRF on the shared fleet its p99 at the same
    // horizon must stay within 2x of that.
    let w = skewed_overload(0xFA1);
    let minority: Vec<TraceEntry> =
        generate(skewed_two_tenant(4_000, 12_000.0, &class_seq(), 0xFA1))
            .unwrap()
            .into_iter()
            .filter(|e| e.tenant == 1)
            .collect();
    assert!(minority.len() > 200, "seed produced only {} minority rows", minority.len());
    let alone = Workload::trace_entries(classes(), minority);
    let h = horizon();

    let baseline = report_at(&fleet(1), &alone, &mut Fifo, h);
    let base_p99 = baseline.tenants[1].p99_cycles;
    assert!(base_p99 > 0, "baseline served nothing by the horizon");

    let f = fleet(2);
    let wfq = report_at(&f, &w, &mut Wfq::default(), h);
    let drf = report_at(&f, &w, &mut Drf::default(), h);
    let fifo = report_at(&f, &w, &mut Fifo, h);
    assert!(
        wfq.tenants[1].p99_cycles <= 2 * base_p99,
        "wfq minority p99 {} vs fair-share baseline {base_p99}",
        wfq.tenants[1].p99_cycles
    );
    assert!(
        drf.tenants[1].p99_cycles <= 2 * base_p99,
        "drf minority p99 {} vs fair-share baseline {base_p99}",
        drf.tenants[1].p99_cycles
    );
    // fifo makes the minority wait behind the whole shared backlog
    assert!(
        fifo.tenants[1].p99_cycles > wfq.tenants[1].p99_cycles,
        "fifo minority p99 {} should exceed wfq's {}",
        fifo.tenants[1].p99_cycles,
        wfq.tenants[1].p99_cycles
    );
}

/// Field-for-field report equality, floats by bit pattern.
fn assert_reports_identical(a: &ServeReport, b: &ServeReport, what: &str) {
    assert_eq!(a.scheduler, b.scheduler, "{what}: scheduler");
    assert_eq!(a.offered, b.offered, "{what}: offered");
    assert_eq!(a.served, b.served, "{what}: served");
    assert_eq!(a.makespan_cycles, b.makespan_cycles, "{what}: makespan");
    assert_eq!(a.req_per_s.to_bits(), b.req_per_s.to_bits(), "{what}: req_per_s");
    assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits(), "{what}: energy");
    assert_eq!(a.p50_cycles, b.p50_cycles, "{what}: p50");
    assert_eq!(a.p99_cycles, b.p99_cycles, "{what}: p99");
    assert_eq!(a.batches, b.batches, "{what}: batches");
    assert_eq!(a.max_queue_depth, b.max_queue_depth, "{what}: max depth");
    assert_eq!(
        a.fairness_jain.to_bits(),
        b.fairness_jain.to_bits(),
        "{what}: fairness_jain"
    );
    assert_eq!(a.tenants.len(), b.tenants.len(), "{what}: tenant count");
    for (x, y) in a.tenants.iter().zip(&b.tenants) {
        assert_eq!(x.tenant, y.tenant, "{what}: tenant id");
        assert_eq!(x.served, y.served, "{what}: tenant {} served", x.tenant);
        assert_eq!(
            x.req_per_s.to_bits(),
            y.req_per_s.to_bits(),
            "{what}: tenant {} req/s",
            x.tenant
        );
        assert_eq!(x.p50_cycles, y.p50_cycles, "{what}: tenant {} p50", x.tenant);
        assert_eq!(x.p99_cycles, y.p99_cycles, "{what}: tenant {} p99", x.tenant);
        assert_eq!(
            x.mean_latency_cycles.to_bits(),
            y.mean_latency_cycles.to_bits(),
            "{what}: tenant {} mean",
            x.tenant
        );
        assert_eq!(
            x.dominant_share.to_bits(),
            y.dominant_share.to_bits(),
            "{what}: tenant {} dominant share",
            x.tenant
        );
    }
}

#[test]
fn file_replay_reproduces_the_in_memory_report_bit_for_bit() {
    // gen -> write -> stream back must be a lossless round trip: the
    // served report from the file path is bit-identical to replaying
    // the same rows from memory, for both on-disk formats
    let entries = generate(skewed_two_tenant(600, 6_000.0, &class_seq(), 42)).unwrap();
    let mem = Workload::trace_entries(classes(), entries.clone());
    let f = fleet(2);
    let want = f.serve(&mem, &mut Wfq::default()).unwrap();
    assert_eq!(want.served, 600);

    let csv_path = std::env::temp_dir().join("attn_tinyml_fairness_roundtrip.csv");
    let mut buf = Vec::new();
    write_csv(&mut buf, entries.iter().copied()).unwrap();
    std::fs::write(&csv_path, &buf).unwrap();
    let from_csv = Workload::trace_file(classes(), &csv_path).unwrap();
    let got = f.serve(&from_csv, &mut Wfq::default()).unwrap();
    assert_reports_identical(&got, &want, "csv");
    std::fs::remove_file(&csv_path).ok();

    let jsonl_path = std::env::temp_dir().join("attn_tinyml_fairness_roundtrip.jsonl");
    let mut buf = Vec::new();
    write_jsonl(&mut buf, entries.iter().copied()).unwrap();
    std::fs::write(&jsonl_path, &buf).unwrap();
    let from_jsonl = Workload::trace_file(classes(), &jsonl_path).unwrap();
    let got = f.serve(&from_jsonl, &mut Wfq::default()).unwrap();
    assert_reports_identical(&got, &want, "jsonl");
    std::fs::remove_file(&jsonl_path).ok();
}

#[test]
fn streamed_trace_serves_under_capacity_with_a_bounded_queue() {
    // a 20k-row file streams through the O(1) reader into a fleet with
    // headroom (~1000 req/s against ~1560 inf/s): every row is served,
    // the queue never builds a backlog proportional to the trace, and
    // the near-even tenant mix scores a near-perfect index
    let entries = generate(symmetric(20_000, 2, 1_000.0, &class_seq(), 7)).unwrap();
    let path = std::env::temp_dir().join("attn_tinyml_fairness_stream.csv");
    let mut buf = Vec::new();
    write_csv(&mut buf, entries.iter().copied()).unwrap();
    std::fs::write(&path, &buf).unwrap();

    let w = Workload::trace_file(classes(), &path).unwrap();
    assert_eq!(w.requests, 20_000);
    let r = fleet(2).serve(&w, &mut Wfq::default()).unwrap();
    assert_eq!(r.served, 20_000);
    assert!(r.max_queue_depth < 64, "queue built a backlog: {}", r.max_queue_depth);
    assert!(r.fairness_jain > 0.999, "jain {}", r.fairness_jain);
    std::fs::remove_file(&path).ok();
}
