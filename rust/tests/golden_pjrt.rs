//! Cross-layer golden tests: the golden runtime's active backend must
//! agree BIT-EXACTLY with the rust ITA functional model on the full
//! artifact contract. This closes the loop over all three layers:
//!
//!   Pallas kernel == jnp oracle          (pytest, python side)
//!   jnp model -> HLO text -> PJRT        (aot.py + pjrt backend)
//!   backend output == rust ita::engine   (these tests)
//!
//! Under the default std-only build the runtime serves the reference
//! backend, so these tests always run (no artifacts needed) and pin the
//! argument-marshalling/manifest contract. With `--features pjrt` and
//! `make artifacts`, the same assertions verify the PJRT path. The
//! tests skip with a notice only if no backend can be constructed at
//! all (e.g. ATTN_TINYML_BACKEND forces an unavailable backend).

use attn_tinyml::coordinator::forward;
use attn_tinyml::ita::engine::{attention_head, gemm_rq, Mat};
use attn_tinyml::ita::gelu::Act;
use attn_tinyml::models;
use attn_tinyml::runtime::{Runtime, TensorIn};
use attn_tinyml::util::prng::XorShift64;

fn runtime() -> Option<Runtime> {
    match Runtime::new(&Runtime::default_dir()) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: no runtime backend available ({e})");
            None
        }
    }
}

#[test]
fn runtime_available_without_artifacts() {
    // the default build must never skip the golden comparison: the
    // reference backend serves the full artifact set from a clean
    // checkout with no network and no `make artifacts`
    let rt = runtime().expect("default build must always have a backend");
    for name in ["gemm", "gemm_relu", "gemm_gelu", "attn_head"] {
        assert!(rt.manifest.artifacts.contains_key(name), "{name}");
        rt.compile(name).unwrap();
    }
    for cfg in models::ALL_MODELS {
        assert!(rt.manifest.artifacts.contains_key(&format!("encoder_{}", cfg.name)));
    }
}

#[test]
fn gemm_artifacts_bit_exact() {
    let Some(rt) = runtime() else { return };
    for (name, act) in
        [("gemm", Act::Identity), ("gemm_relu", Act::Relu), ("gemm_gelu", Act::Gelu)]
    {
        let entry = &rt.manifest.artifacts[name];
        let (mult, shift) = (entry.rq["mult"] as i32, entry.rq["shift"] as u32);
        for seed in [1u64, 2, 3] {
            let mut rng = XorShift64::new(seed);
            let x = rng.tensor_i8(128 * 128);
            let w = rng.tensor_i8(128 * 128);
            let b: Vec<i32> = (0..128).map(|_| rng.next_range(-2048, 2048)).collect();
            let got = rt
                .execute(
                    name,
                    &[
                        TensorIn { data: &x, shape: vec![128, 128] },
                        TensorIn { data: &w, shape: vec![128, 128] },
                        TensorIn { data: &b, shape: vec![128] },
                    ],
                )
                .unwrap();
            let want = gemm_rq(
                &Mat::new(128, 128, x),
                &Mat::new(128, 128, w),
                &b,
                mult,
                shift,
                act,
                0.1,
            );
            assert_eq!(got[0], want.data, "{name} seed {seed}");
        }
    }
}

#[test]
fn attention_artifact_bit_exact() {
    let Some(rt) = runtime() else { return };
    let entry = &rt.manifest.artifacts["attn_head"];
    let (qkm, qks) = (entry.rq["qk_mult"] as i32, entry.rq["qk_shift"] as u32);
    let (avm, avs) = (entry.rq["av_mult"] as i32, entry.rq["av_shift"] as u32);
    for seed in [5u64, 6, 7] {
        let mut rng = XorShift64::new(seed);
        let q = rng.tensor_i8(128 * 64);
        let k = rng.tensor_i8(128 * 64);
        let v = rng.tensor_i8(128 * 64);
        let got = rt
            .execute(
                "attn_head",
                &[
                    TensorIn { data: &q, shape: vec![128, 64] },
                    TensorIn { data: &k, shape: vec![128, 64] },
                    TensorIn { data: &v, shape: vec![128, 64] },
                ],
            )
            .unwrap();
        let (o, _, _) = attention_head(
            &Mat::new(128, 64, q),
            &Mat::new(128, 64, k),
            &Mat::new(128, 64, v),
            qkm,
            qks,
            avm,
            avs,
        );
        assert_eq!(got[0], o.data, "seed {seed}");
    }
}

#[test]
fn encoder_layers_bit_exact_all_models() {
    let Some(rt) = runtime() else { return };
    for cfg in models::ALL_MODELS {
        let name = format!("encoder_{}", cfg.name);
        let w = forward::synth_layer_weights(cfg, 0);
        let x = models::synth_input(cfg);
        let shapes = forward::weight_shapes(cfg);
        let datas: Vec<&Vec<i32>> = vec![
            &w.wq, &w.wk, &w.wv, &w.wo, &w.bq, &w.bk, &w.bv, &w.bo, &w.w1, &w.b1,
            &w.w2, &w.b2, &w.ln1_g, &w.ln1_b, &w.ln2_g, &w.ln2_b,
        ];
        let mut inputs: Vec<TensorIn> =
            vec![TensorIn { data: &x, shape: vec![cfg.seq, cfg.emb] }];
        for (d, (_, s)) in datas.iter().zip(&shapes) {
            inputs.push(TensorIn { data: d, shape: s.clone() });
        }
        let got = rt.execute(&name, &inputs).unwrap();
        let want =
            forward::encoder_layer(cfg, &Mat::new(cfg.seq, cfg.emb, x.clone()), &w);
        assert_eq!(got[0], want.data, "{name}");
    }
}

#[test]
fn two_layer_chain_composes() {
    // chaining the artifact output back as input must equal the rust
    // two-layer forward — proves composition without accumulation drift
    let Some(rt) = runtime() else { return };
    let cfg = &models::MOBILEBERT;
    let name = format!("encoder_{}", cfg.name);
    let shapes = forward::weight_shapes(cfg);
    let mut x = models::synth_input(cfg);
    let mut x_rust = Mat::new(cfg.seq, cfg.emb, x.clone());
    for l in 0..2 {
        let w = forward::synth_layer_weights(cfg, l);
        let datas: Vec<&Vec<i32>> = vec![
            &w.wq, &w.wk, &w.wv, &w.wo, &w.bq, &w.bk, &w.bv, &w.bo, &w.w1, &w.b1,
            &w.w2, &w.b2, &w.ln1_g, &w.ln1_b, &w.ln2_g, &w.ln2_b,
        ];
        let mut inputs: Vec<TensorIn> =
            vec![TensorIn { data: &x, shape: vec![cfg.seq, cfg.emb] }];
        for (d, (_, s)) in datas.iter().zip(&shapes) {
            inputs.push(TensorIn { data: d, shape: s.clone() });
        }
        x = rt.execute(&name, &inputs).unwrap().remove(0);
        x_rust = forward::encoder_layer(cfg, &x_rust, &w);
        assert_eq!(x, x_rust.data, "layer {l}");
    }
}
