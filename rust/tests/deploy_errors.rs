//! Error-path tests of the deployment flow: user-supplied graphs must
//! surface typed [`DeployError`]s — never panic — for every failure
//! mode: structural invalidity, cycles, ITA geometry violations,
//! over-budget tiling, and unlowerable operators. A property test
//! corrupts valid graphs in random ways and checks the flow always
//! returns a `Result`.

use attn_tinyml::deeploy::ir::{Activation, DType, Graph, Node, Op, TensorKind};
use attn_tinyml::deeploy::{self, DeployError, Target};
use attn_tinyml::models::{self, MOBILEBERT};
use attn_tinyml::pipeline::Pipeline;
use attn_tinyml::sim::ClusterConfig;
use attn_tinyml::util::propcheck::{check, Config};
use attn_tinyml::util::prng::XorShift64;

/// A minimal valid single-GEMM graph (ITA-compatible dims).
fn gemm_graph() -> Graph {
    let mut g = Graph::new("tiny");
    g.add_tensor("x", &[64, 64], DType::I8, TensorKind::Input);
    g.add_tensor("w", &[64, 64], DType::I8, TensorKind::Weight);
    g.add_tensor("b", &[64], DType::I32, TensorKind::Weight);
    g.add_tensor("y", &[64, 64], DType::I8, TensorKind::Output);
    g.add_node(Node::new(
        "gemm0",
        Op::Gemm { act: Activation::Identity },
        &["x", "w", "b"],
        &["y"],
    ));
    g
}

#[test]
fn valid_graph_deploys_on_both_targets() {
    for target in [Target::MultiCore, Target::MultiCoreIta] {
        deeploy::deploy_graph(gemm_graph(), target).unwrap();
    }
}

#[test]
fn undeclared_tensor_is_invalid_graph() {
    let mut g = gemm_graph();
    g.nodes[0].inputs[1] = "nope".into();
    match deeploy::deploy_graph(g, Target::MultiCoreIta) {
        Err(DeployError::InvalidGraph { reason, .. }) => {
            assert!(reason.contains("nope"), "{reason}")
        }
        other => panic!("expected InvalidGraph, got {:?}", other.err()),
    }
}

#[test]
fn consumed_but_never_produced_is_invalid_graph() {
    let mut g = gemm_graph();
    g.add_tensor("ghost", &[64, 64], DType::I8, TensorKind::Activation);
    g.add_tensor("z", &[64, 64], DType::I8, TensorKind::Activation);
    g.add_node(Node::new("add0", Op::Add, &["ghost", "y"], &["z"]));
    assert!(matches!(
        deeploy::deploy_graph(g, Target::MultiCore),
        Err(DeployError::InvalidGraph { .. })
    ));
}

#[test]
fn cyclic_graph_is_typed_through_the_public_api() {
    let mut g = Graph::new("loop");
    g.add_tensor("x", &[64, 64], DType::I8, TensorKind::Input);
    g.add_tensor("a", &[64, 64], DType::I8, TensorKind::Activation);
    g.add_tensor("b", &[64, 64], DType::I8, TensorKind::Output);
    g.add_node(Node::new("n0", Op::Add, &["x", "b"], &["a"]));
    g.add_node(Node::new("n1", Op::Add, &["a", "x"], &["b"]));
    match deeploy::deploy_graph(g, Target::MultiCore) {
        Err(DeployError::CyclicGraph { graph, .. }) => assert_eq!(graph, "loop"),
        other => panic!("expected CyclicGraph, got {:?}", other.err()),
    }
    // ... and through the pipeline
    let mut g = Graph::new("loop2");
    g.add_tensor("x", &[64, 64], DType::I8, TensorKind::Input);
    g.add_tensor("a", &[64, 64], DType::I8, TensorKind::Activation);
    g.add_tensor("b", &[64, 64], DType::I8, TensorKind::Output);
    g.add_node(Node::new("n0", Op::Add, &["x", "b"], &["a"]));
    g.add_node(Node::new("n1", Op::Add, &["a", "x"], &["b"]));
    assert!(matches!(
        Pipeline::new(ClusterConfig::default()).graph(g).compile(),
        Err(DeployError::CyclicGraph { .. })
    ));
}

#[test]
fn unpadded_dims_are_an_ita_constraint_error() {
    let mut g = Graph::new("unpadded");
    g.add_tensor("x", &[100, 64], DType::I8, TensorKind::Input);
    g.add_tensor("w", &[64, 64], DType::I8, TensorKind::Weight);
    g.add_tensor("b", &[64], DType::I32, TensorKind::Weight);
    g.add_tensor("y", &[100, 64], DType::I8, TensorKind::Output);
    g.add_node(Node::new(
        "g0",
        Op::Gemm { act: Activation::Identity },
        &["x", "w", "b"],
        &["y"],
    ));
    match Pipeline::new(ClusterConfig::default())
        .graph(g)
        .target(Target::MultiCoreIta)
        .compile()
    {
        Err(DeployError::ItaConstraint { dim, .. }) => assert_eq!(dim, 100),
        other => panic!("expected ItaConstraint, got {:?}", other.err()),
    }
}

#[test]
fn unpadded_graph_still_deploys_on_multicore() {
    // the constraint is ITA-specific; the software target accepts it
    let mut g = Graph::new("unpadded");
    g.add_tensor("x", &[100, 64], DType::I8, TensorKind::Input);
    g.add_tensor("w", &[64, 64], DType::I8, TensorKind::Weight);
    g.add_tensor("b", &[64], DType::I32, TensorKind::Weight);
    g.add_tensor("y", &[100, 64], DType::I8, TensorKind::Output);
    g.add_node(Node::new(
        "g0",
        Op::Gemm { act: Activation::Identity },
        &["x", "w", "b"],
        &["y"],
    ));
    deeploy::deploy_graph(g, Target::MultiCore).unwrap();
}

#[test]
fn tiny_l1_is_an_l1_budget_error() {
    // 8 KiB of TCDM cannot hold even one double-buffered 64^3 tile
    let mut cluster = ClusterConfig::default();
    cluster.tcdm_banks = 2;
    cluster.tcdm_bank_bytes = 4096;
    match Pipeline::new(cluster)
        .model(&MOBILEBERT)
        .target(Target::MultiCoreIta)
        .layers(1)
        .compile()
    {
        Err(DeployError::L1Budget { node, required, .. }) => {
            assert!(!node.is_empty(), "error must name the offending node");
            assert!(required > 8 * 1024);
        }
        other => panic!("expected L1Budget, got {:?}", other.err()),
    }
}

#[test]
fn unsplit_mha_is_unsupported_in_codegen() {
    let mut g = Graph::new("mha");
    g.add_tensor("x", &[128, 128], DType::I8, TensorKind::Input);
    g.add_tensor("wq", &[128, 128], DType::I8, TensorKind::Weight);
    g.add_tensor("wk", &[128, 128], DType::I8, TensorKind::Weight);
    g.add_tensor("y", &[128, 128], DType::I8, TensorKind::Output);
    g.add_node(Node::new(
        "mha0",
        Op::Mha { heads: 2, proj: 64 },
        &["x", "wq", "wk"],
        &["y"],
    ));
    for target in [Target::MultiCore, Target::MultiCoreIta] {
        match deeploy::deploy_graph(g.clone(), target) {
            Err(DeployError::UnsupportedOp { node, .. }) => assert_eq!(node, "mha0"),
            other => panic!("{target:?}: expected UnsupportedOp, got {:?}", other.err()),
        }
    }
}

#[test]
fn property_corrupted_graphs_never_panic() {
    // start from a real model layer and corrupt it in random ways; the
    // flow must return Ok or a typed error — any panic fails the test
    check(
        Config { cases: 60, seed: 0xE6607 },
        |rng: &mut XorShift64| (rng.next_u64(), rng.next_below(6) as usize),
        |_| Vec::new(),
        |&(seed, kind)| {
            let mut rng = XorShift64::new(seed);
            let mut g = models::build_graph_layers(&MOBILEBERT, 1);
            let n_nodes = g.nodes.len() as u64;
            match kind {
                0 => {
                    // drop a random node (breaks producer chains)
                    let idx = rng.next_below(n_nodes) as usize;
                    g.nodes.remove(idx);
                }
                1 => {
                    // rename a random input to an undeclared tensor
                    let idx = rng.next_below(n_nodes) as usize;
                    if !g.nodes[idx].inputs.is_empty() {
                        g.nodes[idx].inputs[0] = "undeclared".into();
                    }
                }
                2 => {
                    // un-pad a random tensor dim
                    let names: Vec<String> = g.tensors.keys().cloned().collect();
                    let name = &names[rng.next_below(names.len() as u64) as usize];
                    if let Some(t) = g.tensors.get_mut(name) {
                        if !t.shape.is_empty() {
                            t.shape[0] = t.shape[0].saturating_sub(1).max(1);
                        }
                    }
                }
                3 => {
                    // introduce a cycle between two adjacent nodes
                    let idx = (rng.next_below(n_nodes - 1)) as usize;
                    let later_out = g.nodes[idx + 1].outputs[0].clone();
                    g.nodes[idx].inputs.push(later_out);
                }
                4 => {
                    // truncate a node's inputs (arity violation)
                    let idx = rng.next_below(n_nodes) as usize;
                    g.nodes[idx].inputs.truncate(1);
                }
                _ => {
                    // shuffle the node order (must still deploy fine)
                    let swaps = 8;
                    for _ in 0..swaps {
                        let a = rng.next_below(n_nodes) as usize;
                        let b = rng.next_below(n_nodes) as usize;
                        g.nodes.swap(a, b);
                    }
                }
            }
            let target = if seed % 2 == 0 { Target::MultiCoreIta } else { Target::MultiCore };
            match deeploy::deploy_graph(g, target) {
                Ok(dep) => {
                    if dep.steps.is_empty() {
                        return Err("deployment with no steps".into());
                    }
                    Ok(())
                }
                // any typed error is acceptable; panics abort the test
                Err(_) => Ok(()),
            }
        },
    );
}

#[test]
fn shuffled_valid_graph_deploys_identically() {
    // node order must not matter: the flow normalizes the schedule
    let g = models::build_graph_layers(&MOBILEBERT, 1);
    let a = deeploy::deploy_graph(g.clone(), Target::MultiCoreIta).unwrap();
    let mut shuffled = g;
    shuffled.nodes.reverse();
    let b = deeploy::deploy_graph(shuffled, Target::MultiCoreIta).unwrap();
    assert_eq!(a.steps.len(), b.steps.len());
    assert_eq!(a.total_ops, b.total_ops);
    assert_eq!(a.l1_peak_bytes, b.l1_peak_bytes);
}
