//! The compiled-deployment cache must build each key exactly once under
//! concurrent compilation. This lives in its own integration binary (a
//! separate process) so the process-wide `cache_stats()` counters are
//! untouched by other tests and the assertions can be exact.

use std::thread;

use attn_tinyml::deeploy::Target;
use attn_tinyml::models::MOBILEBERT;
use attn_tinyml::pipeline::{self, Pipeline};
use attn_tinyml::sim::ClusterConfig;

#[test]
fn concurrent_compiles_of_one_key_miss_exactly_once() {
    let before = pipeline::cache_stats();
    assert_eq!(before.misses, 0, "fresh process must start with an empty cache");
    assert_eq!(before.hits, 0);

    const THREADS: usize = 8;
    let cycles: Vec<u64> = thread::scope(|s| {
        let handles: Vec<_> = (0..THREADS)
            .map(|_| {
                s.spawn(|| {
                    let c = Pipeline::new(ClusterConfig::default())
                        .model(&MOBILEBERT)
                        .target(Target::MultiCoreIta)
                        .layers(1)
                        .compile()
                        .unwrap();
                    // exercise the memoized simulation too: every thread
                    // must observe the same deterministic statistics
                    c.stats().cycles
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    assert!(cycles.windows(2).all(|w| w[0] == w[1]), "shared stats must agree");

    let after = pipeline::cache_stats();
    assert_eq!(
        after.misses, 1,
        "the same key from {THREADS} threads must compile exactly once"
    );
    assert_eq!(after.hits, THREADS as u64 - 1);
    assert_eq!(after.entries, 1);

    // and the winners really share one deployment: a fresh compile is a
    // hit that returns an Arc into the same entry
    let a = Pipeline::new(ClusterConfig::default())
        .model(&MOBILEBERT)
        .target(Target::MultiCoreIta)
        .layers(1)
        .compile()
        .unwrap();
    assert!(a.was_cached());
    let dep: *const _ = a.deployment();
    let b = Pipeline::new(ClusterConfig::default())
        .model(&MOBILEBERT)
        .target(Target::MultiCoreIta)
        .layers(1)
        .compile()
        .unwrap();
    assert!(std::ptr::eq(dep, b.deployment()), "cache must share one deployment");
}
