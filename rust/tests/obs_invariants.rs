//! The observability layer changed no observable result — and its own
//! outputs obey exact contracts:
//!
//! - **Bit-identity.** Attaching the event recorder (`Fleet::with_obs`)
//!   at *any* sampling rate leaves every core `ServeReport` field
//!   bit-identical to the unobserved run, propchecked across the same
//!   scheduler × arrival × fleet matrix as `serve_equivalence.rs`, with
//!   topology, fault and control legs layered on. The recorder is
//!   write-only, so this holds by construction — the propcheck keeps it
//!   true under refactoring.
//! - **Conservation.** Each shard's phase profile satisfies
//!   `busy + idle + parked + transition == horizon_cycles` by exact
//!   count, including under crashes (truncated transitions), parking
//!   and DVFS.
//! - **Sampling subset.** A sampled run's event stream is exactly a
//!   subsequence of the full run's stream (pure-function-of-id
//!   sampling), fleet-level events are never sampled away, and the
//!   reports still match bit-for-bit.
//! - **Exports.** Both exporters emit parseable JSON: the Chrome trace
//!   round-trips through `Json::parse` with monotone timestamps, the
//!   JSONL stream parses line by line with the stamped schema version.

use attn_tinyml::deeploy::Target;
use attn_tinyml::energy::operating_point::NOMINAL_INDEX;
use attn_tinyml::fault::FaultPlan;
use attn_tinyml::models::{DINOV2S, MOBILEBERT};
use attn_tinyml::net::Topology;
use attn_tinyml::obs::{chrome_trace, events_jsonl, ObsConfig, EVENTS_SCHEMA_VERSION};
use attn_tinyml::serve::{
    scheduler_by_name, FaultConfig, Fleet, RequestClass, ServeReport, SloDvfs, Workload,
    DEFAULT_CONTROL_CADENCE_CYCLES,
};
use attn_tinyml::sim::ClusterConfig;
use attn_tinyml::util::json::Json;
use attn_tinyml::util::prng::XorShift64;
use attn_tinyml::util::propcheck::{check, Config};

fn classes() -> Vec<RequestClass> {
    vec![RequestClass::new(&MOBILEBERT, 1), RequestClass::new(&DINOV2S, 1)]
}

/// Field-for-field equality of the core report, floats compared by bit
/// pattern (the same check `serve_equivalence.rs` holds the engine to).
fn reports_identical(a: &ServeReport, b: &ServeReport) -> Result<(), String> {
    let mut errs = Vec::new();
    let mut chk = |field: &str, same: bool| {
        if !same {
            errs.push(field.to_string());
        }
    };
    chk("scheduler", a.scheduler == b.scheduler);
    chk("clusters", a.clusters == b.clusters);
    chk("offered", a.offered == b.offered);
    chk("served", a.served == b.served);
    chk("makespan_cycles", a.makespan_cycles == b.makespan_cycles);
    chk("seconds", a.seconds.to_bits() == b.seconds.to_bits());
    chk("req_per_s", a.req_per_s.to_bits() == b.req_per_s.to_bits());
    chk("gops", a.gops.to_bits() == b.gops.to_bits());
    chk("energy_j", a.energy_j.to_bits() == b.energy_j.to_bits());
    chk("mj_per_req", a.mj_per_req.to_bits() == b.mj_per_req.to_bits());
    chk("gopj", a.gopj.to_bits() == b.gopj.to_bits());
    chk("p50_cycles", a.p50_cycles == b.p50_cycles);
    chk("p90_cycles", a.p90_cycles == b.p90_cycles);
    chk("p99_cycles", a.p99_cycles == b.p99_cycles);
    chk(
        "mean_latency_cycles",
        a.mean_latency_cycles.to_bits() == b.mean_latency_cycles.to_bits(),
    );
    chk(
        "mean_queue_depth",
        a.mean_queue_depth.to_bits() == b.mean_queue_depth.to_bits(),
    );
    chk("max_queue_depth", a.max_queue_depth == b.max_queue_depth);
    chk(
        "cluster_utilization",
        a.cluster_utilization.len() == b.cluster_utilization.len()
            && a
                .cluster_utilization
                .iter()
                .zip(&b.cluster_utilization)
                .all(|(x, y)| x.to_bits() == y.to_bits()),
    );
    chk("class_switches", a.class_switches == b.class_switches);
    chk("batches", a.batches == b.batches);
    chk("fairness_jain", a.fairness_jain.to_bits() == b.fairness_jain.to_bits());
    chk(
        "tenants",
        a.tenants.len() == b.tenants.len()
            && a.tenants.iter().zip(&b.tenants).all(|(x, y)| {
                x.tenant == y.tenant
                    && x.served == y.served
                    && x.req_per_s.to_bits() == y.req_per_s.to_bits()
                    && x.p50_cycles == y.p50_cycles
                    && x.p99_cycles == y.p99_cycles
                    && x.mean_latency_cycles.to_bits()
                        == y.mean_latency_cycles.to_bits()
                    && x.dominant_share.to_bits() == y.dominant_share.to_bits()
            }),
    );
    chk("freq_hz", a.freq_hz.to_bits() == b.freq_hz.to_bits());
    chk("final_queue_depth", a.final_queue_depth == b.final_queue_depth);
    if errs.is_empty() {
        Ok(())
    } else {
        Err(format!("fields differ: {}", errs.join(", ")))
    }
}

fn workload_for(kind: usize, rate: f64, requests: usize, seed: u64) -> Workload {
    match kind {
        0 => Workload::poisson(classes(), rate, requests, seed),
        1 => Workload::bursty(classes(), rate, 6.0, 0.02, requests, seed),
        2 => {
            let mut rng = XorShift64::new(seed);
            let entries: Vec<(u64, usize)> = (0..requests)
                .map(|_| {
                    (rng.next_below(2_000_000) / 4 * 4, rng.next_below(2) as usize)
                })
                .collect();
            Workload::trace(classes(), entries)
        }
        3 => Workload::closed_loop(
            classes(),
            1 + (seed % 5) as usize,
            (seed % 100_000).max(1),
            requests,
            seed,
        ),
        4 => Workload::diurnal(classes(), rate, 0.8, 0.1, requests, seed),
        _ => {
            let cls = classes();
            let class_seq: Vec<usize> = cls.iter().map(|c| c.bucket()).collect();
            let spec = attn_tinyml::trace::skewed_two_tenant(
                requests,
                rate * 10.0,
                &class_seq,
                seed,
            );
            let entries = attn_tinyml::trace::generate(spec).expect("valid spec");
            Workload::trace_entries(cls, entries)
        }
    }
}

/// A crash/recover + transient plan with deadlines and retries — the
/// fault leg of the matrix actually exercises the kill/expire/retry
/// event paths and the crash-truncation accounting.
fn faulty_config(seed: u64) -> FaultConfig {
    FaultConfig {
        plan: FaultPlan::empty()
            .crash(50_000, 0)
            .recover(2_000_000, 0)
            .transient(500)
            .seeded(seed),
        deadline_cycles: Some(5_000_000),
        max_retries: 2,
        ..FaultConfig::default()
    }
}

/// Run one leg of the matrix: optional topology, fault layer and
/// SLO-DVFS controller, with or without the event recorder attached.
fn run_leg(
    clusters: usize,
    w: &Workload,
    name: &str,
    topo: bool,
    faults: bool,
    control: bool,
    obs: Option<ObsConfig>,
) -> Result<ServeReport, String> {
    let mut fleet = Fleet::new(ClusterConfig::default(), Target::MultiCoreIta, clusters);
    if topo {
        fleet = fleet.with_topology(Topology::parse("pod:2x2x2").unwrap());
    }
    if let Some(cfg) = obs {
        fleet = fleet.with_obs(cfg);
    }
    let mut sched = scheduler_by_name(name).unwrap();
    let freq = ClusterConfig::default().freq_hz;
    let seed = w.seed;
    let r = match (control, faults) {
        (true, true) => fleet.serve_faulted_controlled(
            w,
            sched.as_mut(),
            &mut SloDvfs::from_ms(5.0, freq),
            DEFAULT_CONTROL_CADENCE_CYCLES,
            NOMINAL_INDEX,
            faulty_config(seed),
        ),
        (true, false) => fleet.serve_controlled(
            w,
            sched.as_mut(),
            &mut SloDvfs::from_ms(5.0, freq),
            DEFAULT_CONTROL_CADENCE_CYCLES,
            NOMINAL_INDEX,
        ),
        (false, true) => fleet.serve_faulted(w, sched.as_mut(), faulty_config(seed)),
        (false, false) => fleet.serve(w, sched.as_mut()),
    };
    r.map_err(|e| format!("serve failed: {e}"))
}

#[test]
fn recorder_is_invisible_at_any_sampling_rate() {
    let gen = |rng: &mut XorShift64| {
        (
            1 + rng.next_below(20) as usize,          // requests
            1 + rng.next_below(4) as usize,           // clusters 1..=4
            rng.next_below(3) as usize,               // scheduler
            rng.next_below(6) as usize,               // arrival kind
            50.0 * (1 + rng.next_below(20)) as f64,   // rate req/s
            rng.next_u64(),                           // workload seed
            rng.next_below(4) as usize,               // sampling rate index
            rng.next_below(8) as usize,               // topo/fault/control bits
        )
    };
    let shrink = |&(req, cl, s, k, rate, seed, sr, legs): &(
        usize,
        usize,
        usize,
        usize,
        f64,
        u64,
        usize,
        usize,
    )| {
        let mut c = Vec::new();
        if req > 1 {
            c.push((req / 2, cl, s, k, rate, seed, sr, legs));
        }
        if k > 0 {
            c.push((req, cl, s, 0, rate, seed, sr, legs));
        }
        if legs > 0 {
            c.push((req, cl, s, k, rate, seed, sr, 0));
        }
        c
    };
    check(
        Config { cases: 40, seed: 0x0B5_1DE7 },
        gen,
        shrink,
        |&(requests, clusters, sched_idx, kind, rate, seed, sr, legs)| {
            let name = ["fifo", "rr", "batch"][sched_idx];
            let every = [1u64, 2, 7, 1000][sr];
            let (topo, faults, control) =
                (legs & 1 != 0, legs & 2 != 0, legs & 4 != 0);
            let w = workload_for(kind, rate, requests, seed);
            let label = format!(
                "{name}/{kind} x{requests} on {clusters} (1/{every}, topo={topo}, \
                 faults={faults}, control={control})"
            );
            let plain = run_leg(clusters, &w, name, topo, faults, control, None)
                .map_err(|e| format!("{label}: {e}"))?;
            if plain.profile.is_some() {
                return Err(format!("{label}: unobserved run carries a profile"));
            }
            let cfg = ObsConfig { sample_every: every, ..ObsConfig::default() };
            let seen = run_leg(clusters, &w, name, topo, faults, control, Some(cfg))
                .map_err(|e| format!("{label}: {e}"))?;
            reports_identical(&seen, &plain)
                .map_err(|e| format!("{label}: recorder perturbed the run: {e}"))?;
            let p = seen
                .profile
                .as_ref()
                .ok_or_else(|| format!("{label}: observed run lost its profile"))?;
            if p.sample_every != every {
                return Err(format!("{label}: profile echoes rate {}", p.sample_every));
            }
            // conservation holds on every leg of the matrix
            for sh in &p.shards {
                if sh.accounted() != p.horizon_cycles {
                    return Err(format!(
                        "{label}: shard {} accounts {} of horizon {}",
                        sh.shard,
                        sh.accounted(),
                        p.horizon_cycles
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn per_shard_cycles_conserve_under_faults_and_control_by_exact_count() {
    // a directed heavy case: overloaded bursty traffic on a pod
    // topology, a mid-run crash + recovery, transients, deadlines and
    // the SLO-DVFS controller parking shards and switching corners —
    // every accounting path the profiler carves cycles out of
    let w = Workload::bursty(classes(), 4_000.0, 8.0, 0.02, 96, 0xC0_45E2);
    let r = run_leg(4, &w, "batch", true, true, true, Some(ObsConfig::default()))
        .expect("observed faulted controlled serve");
    let p = r.profile.as_ref().expect("profile attached");
    assert!(p.dispatched > 0, "nothing dispatched");
    assert!(p.total_events > 0, "nothing recorded");
    assert!(p.spans.total() > 0, "no cycles attributed");
    assert_eq!(p.shards.len(), 4);
    for sh in &p.shards {
        assert_eq!(
            sh.accounted(),
            p.horizon_cycles,
            "shard {} phases (busy {} + idle {} + parked {} + transition {}) \
             must equal the horizon exactly",
            sh.shard,
            sh.busy,
            sh.idle,
            sh.parked,
            sh.transition
        );
    }
    // the crash actually happened and is visible in the stream
    let labels: Vec<&str> = p.events.iter().map(|e| e.kind.label()).collect();
    assert!(labels.contains(&"shard_crash"), "no crash event recorded");
    assert!(labels.contains(&"recover"), "no recover event recorded");
}

#[test]
fn sampled_events_are_a_subsequence_with_an_identical_report() {
    let w = workload_for(5, 400.0, 64, 0x5A_3B1E);
    let full_cfg = ObsConfig::default();
    let sampled_cfg = ObsConfig { sample_every: 5, ..ObsConfig::default() };
    let full = run_leg(2, &w, "batch", false, true, false, Some(full_cfg)).unwrap();
    let sampled =
        run_leg(2, &w, "batch", false, true, false, Some(sampled_cfg)).unwrap();
    reports_identical(&full, &sampled).expect("sampling changed the report");
    let fp = full.profile.as_ref().unwrap();
    let sp = sampled.profile.as_ref().unwrap();
    assert_eq!(fp.dropped_events, 0, "ring dropped events; subset check needs all");
    assert_eq!(sp.dropped_events, 0);
    assert!(
        sp.total_events < fp.total_events,
        "1/5 sampling kept everything ({} of {})",
        sp.total_events,
        fp.total_events
    );
    // exact subsequence on (at, kind)
    let mut it = sp.events.iter();
    let mut cur = it.next();
    for e in &fp.events {
        if let Some(s) = cur {
            if s.at == e.at && s.kind == e.kind {
                cur = it.next();
            }
        }
    }
    assert!(
        cur.is_none(),
        "sampled stream is not a subsequence of the full stream (stuck at {cur:?})"
    );
    // fleet-level events are never sampled away
    let fleet_only = |p: &attn_tinyml::obs::ProfileSummary| -> Vec<(u64, String)> {
        p.events
            .iter()
            .filter(|e| e.kind.request_id().is_none())
            .map(|e| (e.at, e.kind.label().to_string()))
            .collect()
    };
    assert_eq!(fleet_only(fp), fleet_only(sp), "fleet-level events must all survive");
    // span attribution is exact, not sampled
    assert_eq!(fp.spans, sp.spans, "span totals must not depend on sampling");
    assert_eq!(fp.dispatched, sp.dispatched);
}

#[test]
fn exports_round_trip_as_valid_json_with_monotone_timestamps() {
    let w = workload_for(1, 2_000.0, 48, 0xE4_9027);
    let r = run_leg(4, &w, "batch", true, true, true, Some(ObsConfig::default()))
        .expect("observed run");

    // JSONL: every line parses and carries the stamped schema version
    let jsonl = events_jsonl(&r).expect("events stream");
    let mut lines = 0u64;
    for line in jsonl.lines() {
        let v = Json::parse(line).unwrap_or_else(|e| panic!("bad JSONL line {line}: {e}"));
        assert_eq!(
            v.get("schema_version").and_then(|s| s.as_f64()),
            Some(EVENTS_SCHEMA_VERSION as f64)
        );
        for key in ["seq", "at", "ev"] {
            assert!(v.get(key).is_some(), "line missing {key}: {line}");
        }
        lines += 1;
    }
    assert_eq!(lines, r.profile.as_ref().unwrap().recorded_events());

    // Chrome trace: round-trips through the parser, events sorted
    let doc = chrome_trace(&r).expect("chrome trace");
    let text = doc.to_string_pretty();
    let back = Json::parse(&text).expect("chrome trace must re-parse");
    assert_eq!(back.get("displayTimeUnit").and_then(|s| s.as_str()), Some("ms"));
    let meta = back.get("metadata").expect("metadata block");
    assert_eq!(
        meta.get("schema_version").and_then(|s| s.as_f64()),
        Some(EVENTS_SCHEMA_VERSION as f64)
    );
    let entries = back
        .get("traceEvents")
        .and_then(|t| t.as_arr())
        .expect("traceEvents array");
    assert!(!entries.is_empty());
    let mut last_ts = f64::NEG_INFINITY;
    let mut timed = 0usize;
    for e in entries {
        let ph = e.get("ph").and_then(|p| p.as_str()).expect("ph on every entry");
        if ph == "M" {
            continue; // metadata entries carry no timestamp
        }
        let ts = e.get("ts").and_then(|t| t.as_f64()).expect("ts on every event");
        assert!(
            ts >= last_ts,
            "timestamps must be monotone: {ts} after {last_ts}"
        );
        last_ts = ts;
        timed += 1;
    }
    assert!(timed > 0, "no timestamped events in the trace");
}
