//! Pareto / exploration invariants (propchecked through `util`'s
//! property harness):
//!
//! - the frontier never contains a dominated point, and everything it
//!   rejected is dominated by some frontier point,
//! - the frontier is insertion-order independent (a set, not a
//!   history),
//! - a fixed seed reproduces an exploration bit-for-bit (including the
//!   serialized JSON record and under thread fan-out),
//! - the paper's default-geometry point appears on the default-space
//!   frontier.

use attn_tinyml::energy::operating_point::NOMINAL_INDEX;
use attn_tinyml::explore::{
    explore, explore_json, Candidate, DesignSpace, Evaluation, ExploreConfig, Fidelity,
    Objective, Pareto, Strategy,
};
use attn_tinyml::util::prng::XorShift64;
use attn_tinyml::util::propcheck::{check, Config};

/// Synthetic evaluation: small integer-valued metrics so random cases
/// produce genuine ties and dominations.
fn eval(index: usize, gopj: f64, gops: f64, p99: f64, mm2: f64) -> Evaluation {
    Evaluation {
        candidate: Candidate {
            index,
            cores: 8,
            banks: 32,
            l1_kib: 128,
            ita_n: 16,
            ita_m: 64,
            op: NOMINAL_INDEX,
            layers: 1,
            fuse: true,
            fleet: 1,
            scheduler: "fifo",
            control: false,
            topology: "flat",
            admission: "admit-all",
        },
        fidelity: Fidelity::Screen,
        gops,
        gopj,
        p99_ms: p99,
        mm2,
        req_per_s: 0.0,
        mj_per_req: 0.0,
        events: 0,
    }
}

fn random_evals(rng: &mut XorShift64) -> Vec<Evaluation> {
    let n = 1 + rng.next_below(24) as usize;
    (0..n)
        .map(|i| {
            eval(
                i,
                rng.next_below(6) as f64,
                rng.next_below(6) as f64,
                rng.next_below(6) as f64,
                rng.next_below(6) as f64,
            )
        })
        .collect()
}

fn shrink_evals(evals: &[Evaluation]) -> Vec<Vec<Evaluation>> {
    let mut out = Vec::new();
    if evals.len() > 1 {
        out.push(evals[..evals.len() / 2].to_vec());
        out.push(evals[1..].to_vec());
    }
    out
}

#[test]
fn frontier_never_contains_a_dominated_point() {
    check(
        Config { cases: 200, seed: 0xFA57 },
        random_evals,
        |evals| shrink_evals(evals),
        |evals| {
            let mut p = Pareto::new(Objective::ALL.to_vec());
            for e in evals {
                p.insert(e.clone());
            }
            if p.is_empty() {
                return Err("frontier empty after finite insertions".into());
            }
            let keys: Vec<Vec<f64>> = p.points().iter().map(|e| p.score(e)).collect();
            for (i, a) in keys.iter().enumerate() {
                for (j, b) in keys.iter().enumerate() {
                    if i != j && attn_tinyml::explore::pareto::dominates(a, b) {
                        return Err(format!(
                            "frontier point {j} is dominated by {i}: {b:?} < {a:?}"
                        ));
                    }
                }
            }
            // completeness: every offered point is on the frontier or
            // dominated by (or tied with) something on it
            for e in evals {
                let k = p.score(e);
                let covered = keys
                    .iter()
                    .any(|f| f == &k || attn_tinyml::explore::pareto::dominates(f, &k));
                if !covered {
                    return Err(format!("point {k:?} neither kept nor dominated"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn frontier_is_insertion_order_independent() {
    check(
        Config { cases: 150, seed: 0x0DDE },
        |rng| {
            let evals = random_evals(rng);
            (evals, rng.next_u64())
        },
        |(evals, seed)| shrink_evals(evals).into_iter().map(|e| (e, *seed)).collect(),
        |(evals, seed)| {
            let frontier_ids = |order: &[Evaluation]| -> Vec<usize> {
                let mut p = Pareto::new(Objective::ALL.to_vec());
                for e in order {
                    p.insert(e.clone());
                }
                let mut ids: Vec<usize> =
                    p.points().iter().map(|e| e.candidate.index).collect();
                ids.sort_unstable();
                ids
            };
            let forward = frontier_ids(evals);
            // seeded Fisher-Yates shuffle
            let mut shuffled = evals.clone();
            let mut rng = XorShift64::new(*seed);
            for i in (1..shuffled.len()).rev() {
                let j = rng.next_below((i + 1) as u64) as usize;
                shuffled.swap(i, j);
            }
            let permuted = frontier_ids(&shuffled);
            if forward != permuted {
                return Err(format!(
                    "insertion order changed the frontier: {forward:?} vs {permuted:?}"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn fixed_seed_reproduces_an_exploration_bit_for_bit() {
    let space = DesignSpace::tiny();
    for strategy in [Strategy::Grid, Strategy::Random, Strategy::Halving] {
        let cfg = ExploreConfig {
            strategy,
            budget: 3,
            seed: 0xD5,
            objectives: Objective::ALL.to_vec(),
            threads: 0, // thread fan-out must not perturb the result
        };
        let a = explore(&space, &cfg).unwrap();
        let b = explore(&space, &cfg).unwrap();
        let ja = explore_json(&space, &a).to_string_pretty();
        let jb = explore_json(&space, &b).to_string_pretty();
        assert_eq!(ja, jb, "{} run is not reproducible", strategy.name());
        // and single-threaded evaluation agrees with the fan-out
        let serial = ExploreConfig { threads: 1, ..cfg };
        let c = explore(&space, &serial).unwrap();
        let jc = explore_json(&space, &c).to_string_pretty();
        assert_eq!(ja, jc, "{} threading changed the result", strategy.name());
    }
}

#[test]
fn default_geometry_point_is_on_the_default_space_frontier() {
    let space = DesignSpace::default_space();
    let cfg = ExploreConfig {
        strategy: Strategy::Grid,
        budget: space.len(), // exhaustive: every candidate fully served
        seed: 48879,
        objectives: Objective::ALL.to_vec(),
        threads: 0,
    };
    let r = explore(&space, &cfg).unwrap();
    assert!(!r.truncated);
    assert_eq!(r.evaluated + r.infeasible, space.len());
    assert!(!r.frontier.is_empty());
    assert!(
        r.frontier.iter().any(|e| e.candidate.is_paper_geometry()),
        "the paper's 8-core / 32-bank / N=16 / 0.65 V point must be non-dominated \
         in the default space"
    );
    // frontier points are a subset of the evaluations, and none is
    // dominated (cross-check against the Pareto type's own invariant)
    let mut p = Pareto::new(Objective::ALL.to_vec());
    for e in &r.evaluations {
        p.insert(e.clone());
    }
    assert_eq!(p.len(), r.frontier.len());
}

#[test]
fn halving_respects_the_budget_and_screens_first() {
    let space = DesignSpace::default_space();
    let cfg = ExploreConfig {
        strategy: Strategy::Halving,
        budget: 6,
        seed: 7,
        objectives: Objective::ALL.to_vec(),
        threads: 0,
    };
    let r = explore(&space, &cfg).unwrap();
    // every paper-silicon serving overlay is an always-promoted anchor
    let anchors = space.paper_indices().len();
    assert!(
        r.evaluated <= 6 + anchors,
        "halving served {} > budget + {anchors} anchors",
        r.evaluated
    );
    assert!(r.screened >= r.evaluated, "halving must screen before serving");
    assert!(!r.frontier.is_empty());
    assert!(
        r.frontier.iter().any(|e| e.candidate.is_paper_geometry()),
        "the calibration anchor must reach the halving frontier"
    );
    assert!(r.paper_screen.is_some());
    for e in &r.frontier {
        assert_eq!(e.fidelity, Fidelity::Serve);
        assert!(e.is_finite());
    }
}
