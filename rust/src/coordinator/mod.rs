//! The coordinator: orchestrates deploy -> simulate -> verify -> report.
//!
//! This is the L3 leader the CLI drives. For each evaluation network it
//! runs the deployment flow (deeploy), executes the generated command
//! stream on the cluster simulator (sim), evaluates the energy model
//! (energy), and can golden-check numerics by running the PJRT artifacts
//! against the rust functional model (runtime + forward).

pub mod forward;
pub mod report;

use crate::deeploy::{self, Target};
use crate::energy;
use crate::models::ModelConfig;
use crate::sim::{ClusterConfig, Engine};

pub use report::{ModelReport, Table1};

/// Simulate one network on one target; returns the paper-style metrics.
pub fn run_model(cfg: &ModelConfig, target: Target) -> ModelReport {
    run_model_layers(cfg, target, cfg.layers)
}

/// Like [`run_model`] but simulating only `layers` blocks and linearly
/// extrapolating — the paper itself measures each layer separately and
/// sums ("due to the extensive simulation time"). With identical blocks,
/// simulating one and scaling is exact up to the one-off input staging.
pub fn run_model_layers(cfg: &ModelConfig, target: Target, layers: usize) -> ModelReport {
    let cluster = ClusterConfig::default();
    let dep = deeploy::deploy_layers(cfg, target, layers);
    let engine = Engine::new(cluster.clone());
    let stats = engine.run(&dep.steps);
    let rep = energy::evaluate(&stats, cluster.freq_hz);

    let scale = cfg.layers as f64 / layers as f64;
    // the paper counts the footnote GOp figure as the workload
    let gop = cfg.gop_per_inference;
    let mut seconds = rep.seconds * scale;
    let mut energy_j = rep.total_j * scale;
    // the conv stem runs once per inference; when only a subset of the
    // (identical) encoder blocks was simulated it is not in `dep` — add
    // its once-off cost here
    if layers < cfg.layers {
        if let Some(stem) = crate::models::build_stem_graph(cfg) {
            let sdep = deeploy::deploy_graph(stem, target);
            let sstats = engine.run(&sdep.steps);
            let srep = energy::evaluate(&sstats, cluster.freq_hz);
            seconds += srep.seconds;
            energy_j += srep.total_j;
        }
    }
    ModelReport {
        model: cfg.name.to_string(),
        target,
        seconds,
        energy_j,
        gops: gop / seconds,
        gopj: gop / energy_j,
        power_w: energy_j / seconds,
        inf_per_s: 1.0 / seconds,
        mj_per_inf: energy_j * 1e3,
        ita_utilization: stats.ita_utilization(),
        ita_duty: stats.ita_duty(),
        cycles: (stats.cycles as f64 * scale) as u64,
        l1_peak_bytes: dep.l1_peak_bytes,
        l2_activation_bytes: dep.l2_activation_bytes,
    }
}

/// Produce the full Table I (both sub-tables) of the paper.
pub fn table1() -> Table1 {
    let mut rows = Vec::new();
    for cfg in crate::models::ALL_MODELS {
        // simulate a single layer per target and extrapolate, as the paper
        // does; all layers of these encoders are identical
        rows.push((
            run_model_layers(cfg, Target::MultiCore, 1),
            run_model_layers(cfg, Target::MultiCoreIta, 1),
        ));
    }
    Table1 { rows }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{DINOV2S, MOBILEBERT, WHISPER_TINY_ENC};

    #[test]
    fn mobilebert_e2e_matches_table1() {
        // paper Table I: multi-core 164 mJ / 0.16 Inf/s;
        // +ITA 1.60 mJ / 32.5 Inf/s
        let sw = run_model_layers(&MOBILEBERT, Target::MultiCore, 1);
        let acc = run_model_layers(&MOBILEBERT, Target::MultiCoreIta, 1);
        assert!((sw.inf_per_s - 0.16).abs() < 0.04, "sw Inf/s {}", sw.inf_per_s);
        assert!((sw.mj_per_inf - 164.0).abs() < 35.0, "sw mJ {}", sw.mj_per_inf);
        assert!((acc.inf_per_s - 32.5).abs() < 7.0, "acc Inf/s {}", acc.inf_per_s);
        assert!((acc.mj_per_inf - 1.60).abs() < 0.4, "acc mJ {}", acc.mj_per_inf);
    }

    #[test]
    fn dinov2_e2e_matches_table1() {
        // paper: 407 mJ / 0.06 Inf/s ; 7.31 mJ / 4.83 Inf/s
        let sw = run_model_layers(&DINOV2S, Target::MultiCore, 1);
        let acc = run_model_layers(&DINOV2S, Target::MultiCoreIta, 1);
        assert!((sw.inf_per_s - 0.06).abs() < 0.02, "sw Inf/s {}", sw.inf_per_s);
        assert!((acc.inf_per_s - 4.83).abs() < 1.2, "acc Inf/s {}", acc.inf_per_s);
        assert!((acc.mj_per_inf - 7.31).abs() < 1.8, "acc mJ {}", acc.mj_per_inf);
    }

    #[test]
    fn whisper_e2e_matches_table1() {
        // paper: 340 mJ / 0.08 Inf/s ; 5.55 mJ / 6.52 Inf/s
        let sw = run_model_layers(&WHISPER_TINY_ENC, Target::MultiCore, 1);
        let acc = run_model_layers(&WHISPER_TINY_ENC, Target::MultiCoreIta, 1);
        assert!((sw.inf_per_s - 0.08).abs() < 0.025, "sw Inf/s {}", sw.inf_per_s);
        assert!((acc.inf_per_s - 6.52).abs() < 1.6, "acc Inf/s {}", acc.inf_per_s);
        assert!((acc.mj_per_inf - 5.55).abs() < 1.4, "acc mJ {}", acc.mj_per_inf);
    }

    #[test]
    fn e2e_improvement_ratios_match_paper() {
        // paper: up to 208x throughput, 102x energy efficiency
        let mut best_thr: f64 = 0.0;
        let mut best_eff: f64 = 0.0;
        for cfg in crate::models::ALL_MODELS {
            let sw = run_model_layers(cfg, Target::MultiCore, 1);
            let acc = run_model_layers(cfg, Target::MultiCoreIta, 1);
            best_thr = best_thr.max(acc.gops / sw.gops);
            best_eff = best_eff.max(acc.gopj / sw.gopj);
        }
        assert!(best_thr > 120.0 && best_thr < 320.0, "thr ratio {best_thr}");
        assert!(best_eff > 60.0 && best_eff < 160.0, "eff ratio {best_eff}");
    }

    #[test]
    fn e2e_envelope_matches_paper() {
        // Table I: +ITA throughput 56-154 GOp/s, efficiency 1600-2960
        // GOp/J, power 35.2-52.0 mW
        for cfg in crate::models::ALL_MODELS {
            let acc = run_model_layers(cfg, Target::MultiCoreIta, 1);
            assert!(acc.gops > 40.0 && acc.gops < 200.0, "{}: {}", cfg.name, acc.gops);
            assert!(
                acc.gopj > 1200.0 && acc.gopj < 3700.0,
                "{}: {}",
                cfg.name,
                acc.gopj
            );
            let mw = acc.power_w * 1e3;
            assert!((25.0..70.0).contains(&mw), "{}: {mw} mW", cfg.name);
        }
    }
}
