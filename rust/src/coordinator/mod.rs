//! The coordinator: orchestrates deploy -> simulate -> verify -> report.
//!
//! This is the L3 leader the CLI drives. For each evaluation network it
//! runs the deployment flow (deeploy), executes the generated command
//! stream on the cluster simulator (sim), evaluates the energy model
//! (energy), and can golden-check numerics by running the PJRT artifacts
//! against the rust functional model (runtime + forward).

pub mod forward;
pub mod report;

use crate::deeploy::Target;
use crate::pipeline::Pipeline;
use crate::sim::ClusterConfig;

pub use report::{
    render_explore, render_serve, render_serve_warning, render_serve_with_host, ModelReport,
    Table1,
};

// The 0.1.0 free functions `run_model{,_layers}` were deprecated shims
// over the builder API through the 0.2.x series and are gone as of
// 0.3.0: use `Pipeline::new(cluster).model(cfg).target(t).layers(n)
// .compile()?.simulate()` (see README "Migrating").

/// Produce the full Table I (both sub-tables) of the paper. Compiled
/// deployments and their deterministic simulations are cached, so
/// repeated evaluations (benches, regression sweeps) pay the flow once.
pub fn table1() -> Table1 {
    let cluster = ClusterConfig::default();
    let mut rows = Vec::new();
    for cfg in crate::models::ALL_MODELS {
        // simulate a single layer per target and extrapolate, as the paper
        // does; all layers of these encoders are identical
        let run = |target| {
            Pipeline::new(cluster.clone())
                .model(cfg)
                .target(target)
                .layers(1)
                .compile()
                .unwrap_or_else(|e| panic!("{}: built-in model must deploy: {e}", cfg.name))
                .simulate()
        };
        rows.push((run(Target::MultiCore), run(Target::MultiCoreIta)));
    }
    Table1 { rows }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{ModelConfig, DINOV2S, MOBILEBERT, WHISPER_TINY_ENC};

    /// Test shim over the builder API (the default geometry, one layer).
    fn run_layers(cfg: &ModelConfig, target: Target, layers: usize) -> ModelReport {
        Pipeline::new(ClusterConfig::default())
            .model(cfg)
            .target(target)
            .layers(layers)
            .compile()
            .unwrap()
            .simulate()
    }

    #[test]
    fn mobilebert_e2e_matches_table1() {
        // paper Table I: multi-core 164 mJ / 0.16 Inf/s;
        // +ITA 1.60 mJ / 32.5 Inf/s
        let sw = run_layers(&MOBILEBERT, Target::MultiCore, 1);
        let acc = run_layers(&MOBILEBERT, Target::MultiCoreIta, 1);
        assert!((sw.inf_per_s - 0.16).abs() < 0.04, "sw Inf/s {}", sw.inf_per_s);
        assert!((sw.mj_per_inf - 164.0).abs() < 35.0, "sw mJ {}", sw.mj_per_inf);
        assert!((acc.inf_per_s - 32.5).abs() < 7.0, "acc Inf/s {}", acc.inf_per_s);
        assert!((acc.mj_per_inf - 1.60).abs() < 0.4, "acc mJ {}", acc.mj_per_inf);
    }

    #[test]
    fn dinov2_e2e_matches_table1() {
        // paper: 407 mJ / 0.06 Inf/s ; 7.31 mJ / 4.83 Inf/s
        let sw = run_layers(&DINOV2S, Target::MultiCore, 1);
        let acc = run_layers(&DINOV2S, Target::MultiCoreIta, 1);
        assert!((sw.inf_per_s - 0.06).abs() < 0.02, "sw Inf/s {}", sw.inf_per_s);
        assert!((acc.inf_per_s - 4.83).abs() < 1.2, "acc Inf/s {}", acc.inf_per_s);
        assert!((acc.mj_per_inf - 7.31).abs() < 1.8, "acc mJ {}", acc.mj_per_inf);
    }

    #[test]
    fn whisper_e2e_matches_table1() {
        // paper: 340 mJ / 0.08 Inf/s ; 5.55 mJ / 6.52 Inf/s
        let sw = run_layers(&WHISPER_TINY_ENC, Target::MultiCore, 1);
        let acc = run_layers(&WHISPER_TINY_ENC, Target::MultiCoreIta, 1);
        assert!((sw.inf_per_s - 0.08).abs() < 0.025, "sw Inf/s {}", sw.inf_per_s);
        assert!((acc.inf_per_s - 6.52).abs() < 1.6, "acc Inf/s {}", acc.inf_per_s);
        assert!((acc.mj_per_inf - 5.55).abs() < 1.4, "acc mJ {}", acc.mj_per_inf);
    }

    #[test]
    fn e2e_improvement_ratios_match_paper() {
        // paper: up to 208x throughput, 102x energy efficiency
        let mut best_thr: f64 = 0.0;
        let mut best_eff: f64 = 0.0;
        for cfg in crate::models::ALL_MODELS {
            let sw = run_layers(cfg, Target::MultiCore, 1);
            let acc = run_layers(cfg, Target::MultiCoreIta, 1);
            best_thr = best_thr.max(acc.gops / sw.gops);
            best_eff = best_eff.max(acc.gopj / sw.gopj);
        }
        assert!(best_thr > 120.0 && best_thr < 320.0, "thr ratio {best_thr}");
        assert!(best_eff > 60.0 && best_eff < 160.0, "eff ratio {best_eff}");
    }

    #[test]
    fn e2e_envelope_matches_paper() {
        // Table I: +ITA throughput 56-154 GOp/s, efficiency 1600-2960
        // GOp/J, power 35.2-52.0 mW
        for cfg in crate::models::ALL_MODELS {
            let acc = run_layers(cfg, Target::MultiCoreIta, 1);
            assert!(acc.gops > 40.0 && acc.gops < 200.0, "{}: {}", cfg.name, acc.gops);
            assert!(
                acc.gopj > 1200.0 && acc.gopj < 3700.0,
                "{}: {}",
                cfg.name,
                acc.gopj
            );
            let mw = acc.power_w * 1e3;
            assert!((25.0..70.0).contains(&mw), "{}: {mw} mW", cfg.name);
        }
    }
}
