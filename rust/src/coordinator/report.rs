//! Paper-style reporting: Table I and the microbenchmark section.

use crate::deeploy::Target;

/// Metrics of one (model, target) simulation — one Table I cell group.
#[derive(Debug, Clone)]
pub struct ModelReport {
    pub model: String,
    pub target: Target,
    pub seconds: f64,
    pub energy_j: f64,
    pub gops: f64,
    pub gopj: f64,
    pub power_w: f64,
    pub inf_per_s: f64,
    pub mj_per_inf: f64,
    pub ita_utilization: f64,
    pub ita_duty: f64,
    pub cycles: u64,
    pub l1_peak_bytes: usize,
    pub l2_activation_bytes: usize,
    /// Clock frequency of the cluster geometry this report was
    /// simulated with — reporting derives labels from it instead of
    /// hardcoding the paper's 425 MHz.
    pub freq_hz: f64,
}

impl ModelReport {
    pub fn target_name(&self) -> &'static str {
        match self.target {
            Target::MultiCore => "Multi-Core",
            Target::MultiCoreIta => "Multi-Core + ITA",
        }
    }
}

/// Table I of the paper: per-network rows, both targets.
pub struct Table1 {
    pub rows: Vec<(ModelReport, ModelReport)>,
}

/// Reported numbers of the commercial devices (Table I, as the paper
/// cites them — reported figures, not re-measured).
pub struct CommercialDevice {
    pub name: &'static str,
    pub gops: (f64, f64),
    pub gopj: (f64, f64),
}

pub const COMMERCIAL: [CommercialDevice; 3] = [
    CommercialDevice { name: "Syntiant NDP120", gops: (2.0, 7.0), gopj: (280.0, 400.0) },
    CommercialDevice { name: "AlifSemi E3", gops: (2.0, 45.0), gopj: (50.0, 560.0) },
    CommercialDevice { name: "GreenWaves GAP9", gops: (10.0, 60.0), gopj: (150.0, 650.0) },
];

impl Table1 {
    /// Render the table in the paper's layout.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str("END-TO-END NETWORK PERFORMANCE (paper Table I)\n");
        s.push_str(&format!(
            "{:<22} {:>12} {:>18} {:>12} {:>12}\n",
            "Metric", "Multi-Core", "Multi-Core + ITA", "range lo", "range hi"
        ));
        let (mut gops_lo, mut gops_hi) = (f64::MAX, 0.0f64);
        let (mut gopj_lo, mut gopj_hi) = (f64::MAX, 0.0f64);
        let (mut pw_lo, mut pw_hi) = (f64::MAX, 0.0f64);
        let mut sw_gops = 0.0;
        let mut sw_gopj = 0.0;
        let mut sw_pw = 0.0;
        for (sw, acc) in &self.rows {
            gops_lo = gops_lo.min(acc.gops);
            gops_hi = gops_hi.max(acc.gops);
            gopj_lo = gopj_lo.min(acc.gopj);
            gopj_hi = gopj_hi.max(acc.gopj);
            pw_lo = pw_lo.min(acc.power_w * 1e3);
            pw_hi = pw_hi.max(acc.power_w * 1e3);
            sw_gops = sw.gops.max(sw_gops);
            sw_gopj = sw.gopj.max(sw_gopj);
            sw_pw = (sw.power_w * 1e3).max(sw_pw);
        }
        s.push_str(&format!(
            "{:<22} {:>12.2} {:>18} {:>12.0} {:>12.0}\n",
            "Throughput [GOp/s]", sw_gops, "", gops_lo, gops_hi
        ));
        s.push_str(&format!(
            "{:<22} {:>12.1} {:>18} {:>12.0} {:>12.0}\n",
            "Energy Eff [GOp/J]", sw_gopj, "", gopj_lo, gopj_hi
        ));
        s.push_str(&format!(
            "{:<22} {:>12.1} {:>18} {:>12.1} {:>12.1}\n\n",
            "Power [mW]", sw_pw, "", pw_lo, pw_hi
        ));

        s.push_str(&format!(
            "{:<24} {:>14} {:>14} {:>14} {:>14}\n",
            "Network", "mJ/Inf (MC)", "Inf/s (MC)", "mJ/Inf (+ITA)", "Inf/s (+ITA)"
        ));
        for (sw, acc) in &self.rows {
            s.push_str(&format!(
                "{:<24} {:>14.2} {:>14.3} {:>14.2} {:>14.2}\n",
                sw.model, sw.mj_per_inf, sw.inf_per_s, acc.mj_per_inf, acc.inf_per_s
            ));
        }
        s.push('\n');
        s.push_str("COMMERCIAL DEVICES (reported figures)\n");
        for d in &COMMERCIAL {
            s.push_str(&format!(
                "{:<24} {:>6.0}-{:<6.0} GOp/s {:>6.0}-{:<6.0} GOp/J\n",
                d.name, d.gops.0, d.gops.1, d.gopj.0, d.gopj.1
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn commercial_figures_as_cited() {
        assert_eq!(COMMERCIAL[0].gops, (2.0, 7.0));
        assert_eq!(COMMERCIAL[2].gopj, (150.0, 650.0));
    }

    #[test]
    fn render_contains_all_networks() {
        let t = crate::coordinator::table1();
        let text = t.render();
        for name in ["mobilebert", "dinov2s", "whisper_tiny_enc"] {
            assert!(text.contains(name), "{text}");
        }
        assert!(text.contains("Syntiant"));
    }
}
