//! Paper-style reporting: Table I, the microbenchmark section, the
//! serving-side [`ServeReport`] rendering, and the design-space
//! exploration frontier table ([`render_explore`]).

use crate::deeploy::Target;
use crate::explore::ExploreResult;
use crate::serve::ServeReport;

/// Metrics of one (model, target) simulation — one Table I cell group.
#[derive(Debug, Clone)]
pub struct ModelReport {
    pub model: String,
    pub target: Target,
    pub seconds: f64,
    pub energy_j: f64,
    pub gops: f64,
    pub gopj: f64,
    pub power_w: f64,
    pub inf_per_s: f64,
    pub mj_per_inf: f64,
    pub ita_utilization: f64,
    pub ita_duty: f64,
    pub cycles: u64,
    pub l1_peak_bytes: usize,
    pub l2_activation_bytes: usize,
    /// Clock frequency of the cluster geometry this report was
    /// simulated with — reporting derives labels from it instead of
    /// hardcoding the paper's 425 MHz.
    pub freq_hz: f64,
}

impl ModelReport {
    pub fn target_name(&self) -> &'static str {
        match self.target {
            Target::MultiCore => "Multi-Core",
            Target::MultiCoreIta => "Multi-Core + ITA",
        }
    }
}

/// Table I of the paper: per-network rows, both targets.
pub struct Table1 {
    pub rows: Vec<(ModelReport, ModelReport)>,
}

/// Reported numbers of the commercial devices (Table I, as the paper
/// cites them — reported figures, not re-measured).
pub struct CommercialDevice {
    pub name: &'static str,
    pub gops: (f64, f64),
    pub gopj: (f64, f64),
}

pub const COMMERCIAL: [CommercialDevice; 3] = [
    CommercialDevice { name: "Syntiant NDP120", gops: (2.0, 7.0), gopj: (280.0, 400.0) },
    CommercialDevice { name: "AlifSemi E3", gops: (2.0, 45.0), gopj: (50.0, 560.0) },
    CommercialDevice { name: "GreenWaves GAP9", gops: (10.0, 60.0), gopj: (150.0, 650.0) },
];

impl Table1 {
    /// Render the table in the paper's layout.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str("END-TO-END NETWORK PERFORMANCE (paper Table I)\n");
        s.push_str(&format!(
            "{:<22} {:>12} {:>18} {:>12} {:>12}\n",
            "Metric", "Multi-Core", "Multi-Core + ITA", "range lo", "range hi"
        ));
        let (mut gops_lo, mut gops_hi) = (f64::MAX, 0.0f64);
        let (mut gopj_lo, mut gopj_hi) = (f64::MAX, 0.0f64);
        let (mut pw_lo, mut pw_hi) = (f64::MAX, 0.0f64);
        let mut sw_gops = 0.0;
        let mut sw_gopj = 0.0;
        let mut sw_pw = 0.0;
        for (sw, acc) in &self.rows {
            gops_lo = gops_lo.min(acc.gops);
            gops_hi = gops_hi.max(acc.gops);
            gopj_lo = gopj_lo.min(acc.gopj);
            gopj_hi = gopj_hi.max(acc.gopj);
            pw_lo = pw_lo.min(acc.power_w * 1e3);
            pw_hi = pw_hi.max(acc.power_w * 1e3);
            sw_gops = sw.gops.max(sw_gops);
            sw_gopj = sw.gopj.max(sw_gopj);
            sw_pw = (sw.power_w * 1e3).max(sw_pw);
        }
        s.push_str(&format!(
            "{:<22} {:>12.2} {:>18} {:>12.0} {:>12.0}\n",
            "Throughput [GOp/s]", sw_gops, "", gops_lo, gops_hi
        ));
        s.push_str(&format!(
            "{:<22} {:>12.1} {:>18} {:>12.0} {:>12.0}\n",
            "Energy Eff [GOp/J]", sw_gopj, "", gopj_lo, gopj_hi
        ));
        s.push_str(&format!(
            "{:<22} {:>12.1} {:>18} {:>12.1} {:>12.1}\n\n",
            "Power [mW]", sw_pw, "", pw_lo, pw_hi
        ));

        s.push_str(&format!(
            "{:<24} {:>14} {:>14} {:>14} {:>14}\n",
            "Network", "mJ/Inf (MC)", "Inf/s (MC)", "mJ/Inf (+ITA)", "Inf/s (+ITA)"
        ));
        for (sw, acc) in &self.rows {
            s.push_str(&format!(
                "{:<24} {:>14.2} {:>14.3} {:>14.2} {:>14.2}\n",
                sw.model, sw.mj_per_inf, sw.inf_per_s, acc.mj_per_inf, acc.inf_per_s
            ));
        }
        s.push('\n');
        s.push_str("COMMERCIAL DEVICES (reported figures)\n");
        for d in &COMMERCIAL {
            s.push_str(&format!(
                "{:<24} {:>6.0}-{:<6.0} GOp/s {:>6.0}-{:<6.0} GOp/J\n",
                d.name, d.gops.0, d.gops.1, d.gopj.0, d.gopj.1
            ));
        }
        s
    }
}

/// Render a serving run (the `serve` subcommand / serving benches).
pub fn render_serve(r: &ServeReport) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "MULTI-REQUEST SERVING  ({} scheduler, {} cluster{})\n",
        r.scheduler,
        r.clusters,
        if r.clusters == 1 { "" } else { "s" }
    ));
    s.push_str(&format!("requests     : {} served of {} offered\n", r.served, r.offered));
    s.push_str(&format!(
        "makespan     : {:.2} ms ({} cycles @ {:.0} MHz)\n",
        r.seconds * 1e3,
        r.makespan_cycles,
        r.freq_hz / 1e6
    ));
    s.push_str(&format!(
        "throughput   : {:.1} req/s   {:.1} GOp/s\n",
        r.req_per_s, r.gops
    ));
    s.push_str(&format!(
        "latency      : p50 {:.2} ms  p90 {:.2} ms  p99 {:.2} ms  (mean {:.2} ms)\n",
        r.p50_ms(),
        r.p90_ms(),
        r.p99_ms(),
        r.latency_ms(r.mean_latency_cycles as u64)
    ));
    s.push_str(&format!(
        "queue depth  : mean {:.1}  max {}\n",
        r.mean_queue_depth, r.max_queue_depth
    ));
    s.push_str(&format!(
        "energy       : {:.2} mJ total  {:.3} mJ/req  ({:.0} GOp/J)\n",
        r.energy_j * 1e3,
        r.mj_per_req,
        r.gopj
    ));
    let utils: Vec<String> =
        r.cluster_utilization.iter().map(|u| format!("{:.0}%", u * 100.0)).collect();
    s.push_str(&format!("fleet util   : [{}]\n", utils.join(" ")));
    s.push_str(&format!(
        "dispatches   : {} batches, {} class switches\n",
        r.batches, r.class_switches
    ));
    // interconnect block — only topology-attached runs carry one, so
    // linkless output is byte-identical to the historical rendering
    if let Some(n) = &r.net {
        s.push_str(&format!(
            "interconnect : {} topology  {} restages  locality {:.1}%\n",
            n.topology,
            n.restages,
            n.locality_rate * 100.0
        ));
        for l in &n.levels {
            s.push_str(&format!(
                "  {:<11}: {} links  {} transfers  {} B  {:.3} uJ  util {:.1}%\n",
                l.level,
                l.links,
                l.transfers,
                l.bytes,
                l.energy_j * 1e6,
                l.utilization * 100.0
            ));
        }
        if n.restage_fetch_cycles > 0 {
            s.push_str(&format!(
                "  weight DMA : {} cycles of re-staging fetch\n",
                n.restage_fetch_cycles
            ));
        }
        if !n.levels.is_empty() {
            s.push_str(&format!(
                "  net energy : {:.3} uJ folded into the energy total\n",
                n.energy_j * 1e6
            ));
        }
    }
    // degraded block — only fault-attached runs carry one, so the
    // un-faulted rendering is byte-identical to the historical output
    if let Some(f) = &r.fault {
        s.push_str(&format!(
            "degraded     : {} admission  availability {:.4}  goodput {:.1} GOp/s\n",
            f.admission, f.availability, f.goodput_gops
        ));
        s.push_str(&format!(
            "  dropped    : {} shed  {} expired ({} deadline, {} retry-exhausted)\n",
            f.shed, f.expired, f.expired_deadline, f.retry_exhausted
        ));
        if f.crashes + f.link_events > 0 || f.retried > 0 {
            s.push_str(&format!(
                "  faults     : {} crashes  {} recoveries  {} link events  \
                 {} killed in flight  {} transient\n",
                f.crashes,
                f.recoveries,
                f.link_events,
                f.killed_in_flight,
                f.transient_failures
            ));
            s.push_str(&format!(
                "  retries    : {} scheduled ({} failovers, budget {})\n",
                f.retried, f.failed_over, f.max_retries
            ));
        }
        if let Some(d) = f.deadline_cycles {
            s.push_str(&format!(
                "  deadline   : {:.2} ms per attempt ({} cycles)\n",
                d as f64 / r.freq_hz * 1e3,
                d
            ));
        }
    }
    // per-tenant fairness block — only multi-tenant (trace) runs carry
    // more than one tenant, so single-tenant output is unchanged
    if r.tenants.len() > 1 {
        s.push_str(&format!("fairness     : Jain {:.4}\n", r.fairness_jain));
        s.push_str("tenant       :   id   served    req/s    p50ms    p99ms  domshare\n");
        for t in &r.tenants {
            s.push_str(&format!(
                "               {:>4} {:>8} {:>8.1} {:>8.2} {:>8.2} {:>9.3}\n",
                t.tenant,
                t.served,
                t.req_per_s,
                r.latency_ms(t.p50_cycles),
                r.latency_ms(t.p99_cycles),
                t.dominant_share
            ));
        }
    }
    if let Some(c) = &r.control {
        s.push_str(&format!(
            "control      : {} every {:.1} ms ({} windows, {} DVFS transitions, \
             {} parks, {} wakes)\n",
            c.controller,
            c.cadence_cycles as f64 / r.freq_hz * 1e3,
            c.windows.len(),
            c.dvfs_transitions,
            c.parks,
            c.wakes
        ));
        if let Some(slo) = c.slo_p99_cycles {
            s.push_str(&format!(
                "SLO          : p99 <= {:.2} ms -> {}\n",
                slo as f64 / r.freq_hz * 1e3,
                match c.slo_met {
                    Some(true) => "met",
                    Some(false) => "MISSED",
                    None => "n/a",
                }
            ));
        }
        s.push_str(&format!(
            "energy saved : {:.3} mJ vs static nominal ({:.3} mJ -> {:.3} mJ)\n",
            c.energy_saved_j * 1e3,
            c.energy_j_static * 1e3,
            r.energy_j * 1e3
        ));
        // deterministic cap: the first windows show the ramp, the tail
        // line keeps million-window runs printable
        const SHOW: usize = 8;
        s.push_str("window       :   idx  op park  util    p99ms  done\n");
        for w in c.windows.iter().take(SHOW) {
            s.push_str(&format!(
                "               {:>5} {:>3} {:>4} {:>5.2} {:>8.3} {:>5}\n",
                w.index,
                w.op_index,
                w.parked,
                w.utilization,
                r.latency_ms(w.p99_cycles),
                w.completed
            ));
        }
        if c.windows.len() > SHOW {
            s.push_str(&format!(
                "               ... {} more windows (see --metrics-out)\n",
                c.windows.len() - SHOW
            ));
        }
    }
    // observability block — only observed runs carry one, so the
    // unobserved rendering is byte-identical to the historical output
    if let Some(p) = &r.profile {
        s.push_str(&format!(
            "observability: sampled 1/{}  {} events ({} ring-dropped)  {} dispatches\n",
            p.sample_every.max(1),
            p.total_events,
            p.dropped_events,
            p.dispatched
        ));
        s.push_str(&format!(
            "  spans      : queue {}  net {}  restage {}  compute {}  backoff {} cycles\n",
            p.spans.queue_wait,
            p.spans.net_dispatch,
            p.spans.restage,
            p.spans.compute,
            p.spans.backoff
        ));
        let fleet_cycles = (p.horizon_cycles.max(1) * p.shards.len().max(1) as u64) as f64;
        let pct = |c: u64| c as f64 / fleet_cycles * 100.0;
        let (mut busy, mut idle, mut parked, mut transition) = (0u64, 0u64, 0u64, 0u64);
        for sh in &p.shards {
            busy += sh.busy;
            idle += sh.idle;
            parked += sh.parked;
            transition += sh.transition;
        }
        s.push_str(&format!(
            "  phases     : busy {:.1}%  idle {:.1}%  parked {:.1}%  transition {:.1}%  \
             (horizon {} cycles)\n",
            pct(busy),
            pct(idle),
            pct(parked),
            pct(transition),
            p.horizon_cycles
        ));
    }
    s
}

/// The undrained-backlog warning for a serve run, if any. Kept out of
/// [`render_serve`]'s return so callers can route it to stderr — a
/// diagnostic must not corrupt stdout for pipelines consuming the
/// report (`serve ... | tee`).
pub fn render_serve_warning(r: &ServeReport) -> Option<String> {
    if r.final_queue_depth == 0 {
        return None;
    }
    Some(format!(
        "WARNING      : {} request{} still queued at the horizon — the run \
         ended with an undrained backlog",
        r.final_queue_depth,
        if r.final_queue_depth == 1 { "" } else { "s" }
    ))
}

/// Render a serving run plus host-side simulation throughput: how long
/// the (deterministic) serve run took in host wall time and how many
/// simulated requests per host second that is. The wall time is *not*
/// part of the [`ServeReport`] — reports stay pure functions of
/// (workload, geometry, scheduler) — so the CLI and benches measure it
/// around `serve()` and pass it in.
pub fn render_serve_with_host(r: &ServeReport, host_seconds: f64) -> String {
    let mut s = render_serve(r);
    let sim_rps = r.served as f64 / host_seconds.max(1e-9);
    s.push_str(&format!(
        "host sim     : {:.3} s wall ({}req/s simulated)\n",
        host_seconds,
        crate::util::eng(sim_rps)
    ));
    s
}

/// Render a design-space exploration run: the configuration header and
/// the Pareto frontier, one row per non-dominated point, flagging the
/// paper's published silicon when it appears. The paper anchor's
/// Table-I-comparable screening metrics close the table so the
/// calibration is visible next to the frontier.
pub fn render_explore(r: &ExploreResult) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "DESIGN-SPACE EXPLORATION  ({} space, {} strategy, seed {}, budget {})\n",
        r.space, r.strategy, r.seed, r.budget
    ));
    let objs: Vec<String> = r
        .objectives
        .iter()
        .map(|o| format!("{} {}", o.name(), o.direction()))
        .collect();
    s.push_str(&format!("objectives   : {}\n", objs.join(" · ")));
    s.push_str(&format!(
        "evaluated    : {} of {} candidates served in full ({} screened, {} infeasible{})\n",
        r.evaluated,
        r.space_len,
        r.screened,
        r.infeasible,
        if r.truncated { ", grid truncated by budget" } else { "" }
    ));
    s.push_str(&format!("frontier     : {} non-dominated points\n\n", r.frontier.len()));
    s.push_str(&format!(
        "{:<22} {:>6} {:>8} {:>6} {:>6} {:>9} {:>9} {:>9} {:>8}\n",
        "geometry", "Vdd", "MHz", "fleet", "sched", "GOp/s", "GOp/J", "p99 ms", "mm²"
    ));
    for e in &r.frontier {
        let c = &e.candidate;
        let op = c.operating_point();
        s.push_str(&format!(
            "{:<22} {:>6} {:>8.0} {:>6} {:>6} {:>9.1} {:>9.0} {:>9.3} {:>8.3}{}\n",
            c.label(),
            op.name,
            op.freq_hz / 1e6,
            c.fleet,
            &c.scheduler[..c.scheduler.len().min(5)],
            e.gops,
            e.gopj,
            e.p99_ms,
            e.mm2,
            if c.is_paper_geometry() { "  <- paper point" } else { "" }
        ));
    }
    if let Some(p) = &r.paper_screen {
        s.push_str(&format!(
            "\npaper anchor : {:.1} GOp/s, {:.0} GOp/J, {:.3} mm² at {} (screen fidelity; \
             paper: 154 GOp/s, 2960 GOp/J, 0.991 mm²)\n",
            p.gops,
            p.gopj,
            p.mm2,
            p.candidate.operating_point().name
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explore::{explore, DesignSpace, ExploreConfig, Strategy};
    use crate::models::MOBILEBERT;
    use crate::pipeline::Pipeline;
    use crate::serve::Workload;
    use crate::sim::ClusterConfig;

    #[test]
    fn commercial_figures_as_cited() {
        assert_eq!(COMMERCIAL[0].gops, (2.0, 7.0));
        assert_eq!(COMMERCIAL[2].gopj, (150.0, 650.0));
    }

    #[test]
    fn render_contains_all_networks() {
        let t = crate::coordinator::table1();
        let text = t.render();
        for name in ["mobilebert", "dinov2s", "whisper_tiny_enc"] {
            assert!(text.contains(name), "{text}");
        }
        assert!(text.contains("Syntiant"));
    }

    #[test]
    fn render_serve_lists_the_serving_facts() {
        let r = Pipeline::new(ClusterConfig::default())
            .fleet(2)
            .serve(&Workload::single(&MOBILEBERT, 1))
            .unwrap();
        let text = render_serve(&r);
        for needle in
            ["fifo scheduler", "2 clusters", "p50", "queue depth", "fleet util", "req/s"]
        {
            assert!(text.contains(needle), "missing {needle} in:\n{text}");
        }
        assert!(text.contains("1 served of 1 offered"), "{text}");
        // single-tenant runs keep the pre-trace layout: no fairness block
        assert!(!text.contains("fairness"), "{text}");
        assert!(!text.contains("tenant"), "{text}");
    }

    #[test]
    fn render_serve_adds_the_tenant_table_on_multi_tenant_runs() {
        use crate::serve::{RequestClass, Wfq};
        use crate::trace::TraceEntry;
        let e = |cycle, tenant| TraceEntry { cycle, tenant, class: 0, seq_len: 128 };
        let w = Workload::trace_entries(
            vec![RequestClass::new(&MOBILEBERT, 1)],
            vec![e(0, 0), e(0, 1), e(5, 0), e(9, 1)],
        );
        let r = Pipeline::new(ClusterConfig::default())
            .fleet(1)
            .serve_with(&w, &mut Wfq::default())
            .unwrap();
        let text = render_serve(&r);
        for needle in ["wfq scheduler", "fairness     : Jain", "tenant       :", "domshare"]
        {
            assert!(text.contains(needle), "missing {needle} in:\n{text}");
        }
    }

    #[test]
    fn render_serve_appends_the_interconnect_block_only_with_a_topology() {
        use crate::net::Topology;
        use crate::serve::RequestClass;
        let w = Workload::poisson(vec![RequestClass::new(&MOBILEBERT, 1)], 300.0, 8, 5);
        let plain =
            Pipeline::new(ClusterConfig::default()).fleet(2).serve(&w).unwrap();
        assert!(!render_serve(&plain).contains("interconnect"));
        let pod = Pipeline::new(ClusterConfig::default())
            .fleet(2)
            .topology(Topology::parse("pod:1x1x2").unwrap())
            .serve(&w)
            .unwrap();
        let text = render_serve(&pod);
        for needle in
            ["interconnect : pod:1x1x2 topology", "locality", "board", "links"]
        {
            assert!(text.contains(needle), "missing {needle} in:\n{text}");
        }
    }

    #[test]
    fn render_serve_appends_the_degraded_block_only_with_faults() {
        use crate::serve::{FaultConfig, RequestClass};
        let w = Workload::poisson(vec![RequestClass::new(&MOBILEBERT, 1)], 300.0, 8, 5);
        let plain =
            Pipeline::new(ClusterConfig::default()).fleet(2).serve(&w).unwrap();
        assert!(!render_serve(&plain).contains("degraded"));
        let faulted = Pipeline::new(ClusterConfig::default())
            .fleet(2)
            .faults(FaultConfig::default())
            .serve(&w)
            .unwrap();
        let text = render_serve(&faulted);
        for needle in
            ["degraded     :", "admit-all admission", "availability 1.0000", "dropped"]
        {
            assert!(text.contains(needle), "missing {needle} in:\n{text}");
        }
        // a clean run ends drained: no backlog warning
        assert!(!text.contains("WARNING"), "{text}");
    }

    #[test]
    fn undrained_backlog_warning_is_separate_from_the_report_body() {
        let mut r = Pipeline::new(ClusterConfig::default())
            .fleet(1)
            .serve(&Workload::single(&MOBILEBERT, 1))
            .unwrap();
        assert!(render_serve_warning(&r).is_none());
        r.final_queue_depth = 3;
        // the warning is a stderr diagnostic, never part of the report
        assert!(!render_serve(&r).contains("WARNING"));
        let warn = render_serve_warning(&r).unwrap();
        assert!(warn.contains("WARNING"), "{warn}");
        assert!(warn.contains("3 requests still queued at the horizon"), "{warn}");
    }

    #[test]
    fn render_serve_appends_the_observability_block_only_when_observed() {
        use crate::obs::ObsConfig;
        use crate::serve::RequestClass;
        let w = Workload::poisson(vec![RequestClass::new(&MOBILEBERT, 1)], 300.0, 8, 5);
        let plain =
            Pipeline::new(ClusterConfig::default()).fleet(2).serve(&w).unwrap();
        assert!(!render_serve(&plain).contains("observability"));
        let observed = Pipeline::new(ClusterConfig::default())
            .fleet(2)
            .observe(ObsConfig::default())
            .serve(&w)
            .unwrap();
        let text = render_serve(&observed);
        for needle in ["observability: sampled 1/1", "spans      :", "phases     : busy"] {
            assert!(text.contains(needle), "missing {needle} in:\n{text}");
        }
    }

    #[test]
    fn render_serve_appends_the_control_timeline_only_when_present() {
        use crate::serve::{RequestClass, StaticNominal};
        let w = Workload::poisson(vec![RequestClass::new(&MOBILEBERT, 1)], 300.0, 8, 5);
        let plain =
            Pipeline::new(ClusterConfig::default()).fleet(1).serve(&w).unwrap();
        assert!(!render_serve(&plain).contains("control"));
        let ctl = Pipeline::new(ClusterConfig::default())
            .fleet(1)
            .controller(Box::new(StaticNominal))
            .serve(&w)
            .unwrap();
        let text = render_serve(&ctl);
        for needle in ["control      :", "static-nominal", "energy saved", "window"] {
            assert!(text.contains(needle), "missing {needle} in:\n{text}");
        }
    }

    #[test]
    fn render_explore_lists_frontier_and_flags_the_paper_point() {
        let space = DesignSpace::tiny();
        let cfg = ExploreConfig {
            strategy: Strategy::Grid,
            budget: 8,
            threads: 1,
            ..ExploreConfig::default()
        };
        let r = explore(&space, &cfg).unwrap();
        let text = render_explore(&r);
        for needle in [
            "DESIGN-SPACE EXPLORATION",
            "tiny space",
            "grid strategy",
            "objectives",
            "frontier",
            "GOp/J",
            "mm²",
            "<- paper point",
            "paper anchor",
        ] {
            assert!(text.contains(needle), "missing {needle} in:\n{text}");
        }
    }

    #[test]
    fn render_serve_with_host_appends_sim_throughput() {
        let r = Pipeline::new(ClusterConfig::default())
            .fleet(1)
            .serve(&Workload::single(&MOBILEBERT, 1))
            .unwrap();
        let text = render_serve_with_host(&r, 0.5);
        assert!(text.contains("host sim"), "{text}");
        // 1 request / 0.5 s = 2 simulated req/s
        assert!(text.contains("2.000req/s simulated"), "{text}");
        // the deterministic body is unchanged
        assert!(text.starts_with(&render_serve(&r)), "{text}");
    }
}
