//! Rust functional forward pass of the encoder — mirrors
//! `python/compile/model.py::encoder_layer` operation-for-operation so
//! the PJRT artifacts (lowered from the Pallas/jnp model) and this
//! implementation must agree **bit-exactly** on the same synthetic
//! weights. That cross-language equality is the repo's strongest
//! correctness signal (rust/tests/golden_pjrt.rs).

use crate::ita::engine::{
    attention_head, gemm_rq, head_accumulate, ilayernorm, matmul_i32, residual_add, Mat,
};
use crate::ita::gelu::Act;
use crate::models::{rq_params, synth_tensor, ModelConfig, SynthKind};

/// Re-export: the i-GeLU input scale lives with the functional model
/// (`ita::engine::GELU_S`); kept here for callers that import it from
/// the forward pass.
pub use crate::ita::engine::GELU_S;

/// All weights of one encoder layer, generated identically to
/// `model.synth_layer_weights(cfg, layer_idx, seed=0)`.
pub struct LayerWeights {
    pub wq: Vec<i32>, // (H, E, P)
    pub wk: Vec<i32>,
    pub wv: Vec<i32>,
    pub wo: Vec<i32>, // (H, P, E)
    pub bq: Vec<i32>, // (H, P)
    pub bk: Vec<i32>,
    pub bv: Vec<i32>,
    pub bo: Vec<i32>, // (E,)
    pub w1: Vec<i32>, // (F, E, dff)
    pub b1: Vec<i32>, // (F, dff)
    pub w2: Vec<i32>, // (F, dff, E)
    pub b2: Vec<i32>, // (F, E)
    pub ln1_g: Vec<i32>,
    pub ln1_b: Vec<i32>,
    pub ln2_g: Vec<i32>, // (F, E)
    pub ln2_b: Vec<i32>,
}

/// Argument order of the encoder artifacts (matches
/// `model.layer_weight_shapes` / the AOT manifest).
pub const WEIGHT_ORDER: [&str; 16] = [
    "wq", "wk", "wv", "wo", "bq", "bk", "bv", "bo", "w1", "b1", "w2", "b2",
    "ln1_g", "ln1_b", "ln2_g", "ln2_b",
];

pub fn weight_shapes(cfg: &ModelConfig) -> Vec<(&'static str, Vec<usize>)> {
    let (e, p, h, f, dff) = (cfg.emb, cfg.proj, cfg.heads, cfg.ffn_stack, cfg.dff);
    vec![
        ("wq", vec![h, e, p]),
        ("wk", vec![h, e, p]),
        ("wv", vec![h, e, p]),
        ("wo", vec![h, p, e]),
        ("bq", vec![h, p]),
        ("bk", vec![h, p]),
        ("bv", vec![h, p]),
        ("bo", vec![e]),
        ("w1", vec![f, e, dff]),
        ("b1", vec![f, dff]),
        ("w2", vec![f, dff, e]),
        ("b2", vec![f, e]),
        ("ln1_g", vec![e]),
        ("ln1_b", vec![e]),
        ("ln2_g", vec![f, e]),
        ("ln2_b", vec![f, e]),
    ]
}

fn kind_of(name: &str) -> SynthKind {
    if name.ends_with("_g") {
        SynthKind::Gamma
    } else if name.starts_with("ln") && name.ends_with("_b") {
        SynthKind::Beta
    } else if name.starts_with('w') {
        SynthKind::Weight
    } else {
        SynthKind::Bias
    }
}

/// Generate the synthetic weights of one layer (seed 0, like python).
pub fn synth_layer_weights(cfg: &ModelConfig, layer_idx: usize) -> LayerWeights {
    let get = |name: &str, shape: &[usize]| {
        let key = format!("{}/L{layer_idx}/{name}", cfg.name);
        synth_tensor(&key, shape.iter().product(), kind_of(name), 0)
    };
    let shapes = weight_shapes(cfg);
    let s = |n: &str| shapes.iter().find(|(m, _)| *m == n).unwrap().1.clone();
    LayerWeights {
        wq: get("wq", &s("wq")),
        wk: get("wk", &s("wk")),
        wv: get("wv", &s("wv")),
        wo: get("wo", &s("wo")),
        bq: get("bq", &s("bq")),
        bk: get("bk", &s("bk")),
        bv: get("bv", &s("bv")),
        bo: get("bo", &s("bo")),
        w1: get("w1", &s("w1")),
        b1: get("b1", &s("b1")),
        w2: get("w2", &s("w2")),
        b2: get("b2", &s("b2")),
        ln1_g: get("ln1_g", &s("ln1_g")),
        ln1_b: get("ln1_b", &s("ln1_b")),
        ln2_g: get("ln2_g", &s("ln2_g")),
        ln2_b: get("ln2_b", &s("ln2_b")),
    }
}

fn slice_mat(data: &[i32], idx: usize, rows: usize, cols: usize) -> Mat {
    let n = rows * cols;
    Mat::new(rows, cols, data[idx * n..(idx + 1) * n].to_vec())
}

/// One encoder layer forward — mirrors model.encoder_layer exactly.
pub fn encoder_layer(cfg: &ModelConfig, x: &Mat, w: &LayerWeights) -> Mat {
    let rq = rq_params(cfg);
    let (e, p, h) = (cfg.emb, cfg.proj, cfg.heads);
    let act = match cfg.act {
        crate::deeploy::ir::Activation::Gelu => Act::Gelu,
        crate::deeploy::ir::Activation::Relu => Act::Relu,
        crate::deeploy::ir::Activation::Identity => Act::Identity,
    };

    // LN1 -> MHA -> residual
    let h1 = ilayernorm(x, &w.ln1_g, &w.ln1_b, rq.ln.0, rq.ln.1);
    let mut partials = Vec::with_capacity(h);
    for hd in 0..h {
        let wq = slice_mat(&w.wq, hd, e, p);
        let wk = slice_mat(&w.wk, hd, e, p);
        let wv = slice_mat(&w.wv, hd, e, p);
        let bq = &w.bq[hd * p..(hd + 1) * p];
        let bk = &w.bk[hd * p..(hd + 1) * p];
        let bv = &w.bv[hd * p..(hd + 1) * p];
        let q = gemm_rq(&h1, &wq, bq, rq.q.0, rq.q.1, Act::Identity, GELU_S);
        let k = gemm_rq(&h1, &wk, bk, rq.q.0, rq.q.1, Act::Identity, GELU_S);
        let v = gemm_rq(&h1, &wv, bv, rq.q.0, rq.q.1, Act::Identity, GELU_S);
        let (o, _, _) = attention_head(&q, &k, &v, rq.qk.0, rq.qk.1, rq.av.0, rq.av.1);
        let wo = slice_mat(&w.wo, hd, p, e);
        partials.push(matmul_i32(&o, &wo));
    }
    let attn = head_accumulate(&partials, &w.bo, rq.o.0, rq.o.1);
    let mut xcur = residual_add(x, &attn);

    // FFN stack
    for f in 0..cfg.ffn_stack {
        let g2 = &w.ln2_g[f * e..(f + 1) * e];
        let b2v = &w.ln2_b[f * e..(f + 1) * e];
        let hn = ilayernorm(&xcur, g2, b2v, rq.ln.0, rq.ln.1);
        let w1 = slice_mat(&w.w1, f, e, cfg.dff);
        let b1 = &w.b1[f * cfg.dff..(f + 1) * cfg.dff];
        let u = gemm_rq(&hn, &w1, b1, rq.ffn1.0, rq.ffn1.1, act, GELU_S);
        let w2 = slice_mat(&w.w2, f, cfg.dff, e);
        let b2 = &w.b2[f * e..(f + 1) * e];
        let d = gemm_rq(&u, &w2, b2, rq.ffn2.0, rq.ffn2.1, Act::Identity, GELU_S);
        xcur = residual_add(&xcur, &d);
    }
    xcur
}

/// Full-network forward over `layers` encoder blocks.
pub fn forward(cfg: &ModelConfig, layers: usize) -> Mat {
    let x0 = crate::models::synth_input(cfg);
    let mut x = Mat::new(cfg.seq, cfg.emb, x0);
    for l in 0..layers {
        let w = synth_layer_weights(cfg, l);
        x = encoder_layer(cfg, &x, &w);
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::MOBILEBERT;

    #[test]
    fn layer_preserves_shape_and_range() {
        let w = synth_layer_weights(&MOBILEBERT, 0);
        let x = Mat::new(
            MOBILEBERT.seq,
            MOBILEBERT.emb,
            crate::models::synth_input(&MOBILEBERT),
        );
        let y = encoder_layer(&MOBILEBERT, &x, &w);
        assert_eq!((y.rows, y.cols), (MOBILEBERT.seq, MOBILEBERT.emb));
        assert!(y.data.iter().all(|&v| (-128..=127).contains(&v)));
        // activations must stay alive
        let std = {
            let m = y.data.iter().map(|&v| v as f64).sum::<f64>() / y.data.len() as f64;
            (y.data.iter().map(|&v| (v as f64 - m).powi(2)).sum::<f64>()
                / y.data.len() as f64)
                .sqrt()
        };
        assert!(std > 5.0, "std {std}");
    }

    #[test]
    fn deterministic() {
        let w = synth_layer_weights(&MOBILEBERT, 0);
        let x = Mat::new(
            MOBILEBERT.seq,
            MOBILEBERT.emb,
            crate::models::synth_input(&MOBILEBERT),
        );
        let y1 = encoder_layer(&MOBILEBERT, &x, &w);
        let y2 = encoder_layer(&MOBILEBERT, &x, &w);
        assert_eq!(y1.data, y2.data);
    }

    #[test]
    fn layers_differ() {
        let w0 = synth_layer_weights(&MOBILEBERT, 0);
        let w1 = synth_layer_weights(&MOBILEBERT, 1);
        assert_ne!(w0.wq, w1.wq);
    }
}
