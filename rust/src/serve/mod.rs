//! Multi-request serving: workloads, schedulers, and a sharded fleet.
//!
//! The paper's headline numbers are single-inference figures; this
//! subsystem is the production-scale execution surface over the same
//! compiled deployments. A [`Workload`] describes a request stream
//! (deterministic Poisson / bursty / trace-replay / closed-loop), a
//! [`Scheduler`] ([`Fifo`], [`RoundRobin`], seq-len-bucketed
//! [`DynamicBatch`]) dispatches requests onto a [`Fleet`] of N clusters
//! — each wrapping a cached `Compiled` from the pipeline, shared across
//! shards through the process-wide deployment cache — and the
//! event-driven serve loop produces a [`ServeReport`] with throughput
//! (req/s, GOp/s), latency percentiles (p50/p90/p99), queue depth,
//! per-cluster utilization and energy.
//!
//! ```no_run
//! use attn_tinyml::pipeline::Pipeline;
//! use attn_tinyml::models::{MOBILEBERT, DINOV2S};
//! use attn_tinyml::serve::{DynamicBatch, RequestClass, Workload};
//! use attn_tinyml::sim::ClusterConfig;
//!
//! let classes = vec![RequestClass::new(&MOBILEBERT, 1), RequestClass::new(&DINOV2S, 1)];
//! let w = Workload::poisson(classes, 200.0, 64, 0x5EED);
//! let report = Pipeline::new(ClusterConfig::default())
//!     .fleet(4)
//!     .serve_with(&w, &mut DynamicBatch::default())
//!     .unwrap();
//! println!("{:.0} req/s, p99 {:.2} ms", report.req_per_s, report.p99_ms());
//! ```
//!
//! **Determinism contract:** serving never reads a wall clock. Arrivals
//! are derived from the workload seed through `util::prng`, service
//! times come from the deterministic cycle-level engine, and batch
//! interleaving is computed from [`crate::sim::Engine::run_spans`]
//! per-step timing — so a serve run is a pure function of (workload,
//! geometry, scheduler) and reproduces bit-identically. One request on
//! one cluster is the degenerate case: its makespan equals
//! `Compiled::stats().cycles` cycle-for-cycle, making
//! `Compiled::simulate()` a special case of `serve()`.
//!
//! **Million-request scale:** the serve hot path is engineered so the
//! simulator never becomes the bottleneck — arrivals stream lazily from
//! the seeded PRNG ([`workload::ArrivalStream`]), the waiting queue is
//! the bucketed [`QueueView`] (O(1) head/count lookups, O(batch)
//! takes), shard wake-ups pop from a min-heap, and latency percentiles
//! come from the bounded [`metrics::LatencyStore`]. The pre-optimization
//! loop survives in [`naive`] and `tests/serve_equivalence.rs` holds
//! both paths to bit-identical [`ServeReport`]s; `benches/perf_serve`
//! asserts the ≥10× wall-clock separation and records host-side
//! throughput in `BENCH_perf.json`.
//!
//! **Steppable engine + control plane:** the serve loop is the
//! [`ServeEngine`] — explicit state advanced one event at a time
//! (`step` / `run_until` / `drain`), with `serve()` as a thin driver.
//! A [`Controller`] ([`StaticNominal`], [`SloDvfs`]) attached through
//! [`Fleet::serve_controlled`] observes windowed [`WindowSnapshot`]
//! metrics on a fixed simulated-time cadence and may switch the FD-SOI
//! operating point (DVFS) or park/wake shards; the run stays a pure
//! function of (workload, geometry, scheduler, controller, cadence).
//! `benches/control_plane` records the SLO/energy outcome in
//! `BENCH_control.json`.
//!
//! **Multi-tenant fairness:** trace replay ([`crate::trace`]) tags every
//! request with a tenant id, the queue keeps per-(tenant, class) rings,
//! and two fairness-aware schedulers — weighted-fair queueing ([`Wfq`],
//! per-tenant virtual time) and a DRF-style dominant-share policy
//! ([`Drf`]) — dispatch across tenants. Reports carry one
//! [`TenantSummary`] per tenant plus [`metrics::jain`]'s fairness index
//! over delivered throughput; every legacy arrival shape is
//! single-tenant (tenant 0) and reports exactly as before.
//! `benches/trace_fairness` records the fairness outcome in
//! `BENCH_trace.json`.
//!
//! **Topology + locality (10k-shard fleets):** attaching a
//! [`crate::net::Topology`] via [`Fleet::with_topology`] places the
//! shards in a cluster → board → pod hierarchy and prices request
//! dispatch and weight re-staging DMA over per-level links with
//! deterministic busy-until contention (see [`crate::net`]). Reports
//! gain a [`crate::net::NetSummary`] block and windows a per-level
//! `net_util` vector; the [`LocalityAware`] scheduler wrapper steers
//! each batch at the shard already holding its class's weights,
//! falling back by hierarchy distance. The event core stays O(log n)
//! per event at 10k shards (`BTreeSet` free-scan + span range-probes);
//! a `Flat` topology is propcheck-held bit-identical to no topology at
//! all, and `benches/fleet_scaling` sweeps 1 → 10k shards into
//! `BENCH_fleet.json`.
//!
//! **Fault injection + graceful degradation:** a
//! [`crate::fault::FaultPlan`] (seeded, simulated-time-only schedule of
//! shard crash/recover events, link degradation/outage windows, and
//! transient request failures) attaches through a [`FaultConfig`]
//! ([`Fleet::serve_faulted`]) together with admission control
//! ([`AdmissionPolicy`]: admit-all / queue-depth threshold /
//! tenant-fair shedding), per-attempt request deadlines, and bounded
//! retry with exponential backoff — crash failovers re-enqueue through
//! the queue and pay weight re-staging through the router from the
//! nearest surviving holder. Reports gain a [`FaultSummary`] degraded
//! block (shed/expired/retried/failed-over counts, availability,
//! goodput) obeying `offered == served + shed + expired` on drained
//! runs; the empty plan under admit-all is propcheck-held bit-identical
//! to the un-faulted engine, and `benches/fault_tolerance` records the
//! availability/bounded-p99 outcome in `BENCH_fault.json`.
//!
//! **Observability:** attaching an [`crate::obs::ObsConfig`]
//! ([`Fleet::with_obs`], `Pipeline::observe`, `serve --events-out`)
//! threads a write-only structured event recorder through the whole
//! stack — request lifecycle (arrive/admit/shed/enqueue/dispatch/
//! commit), fault transitions (crash/recover/kill/expire/retry) and
//! control actions (DVFS/park/wake) land in a bounded ring with
//! deterministic seeded request sampling — plus cycle attribution:
//! exact per-request span totals and a per-shard phase profile
//! conserving `busy + idle + parked + transition == horizon`. The
//! report gains a [`crate::obs::ProfileSummary`]; every other field is
//! propcheck-held bit-identical at any sampling rate
//! (`tests/obs_invariants.rs`). Export via [`crate::obs::chrome_trace`]
//! / [`crate::obs::events_jsonl`].

pub mod control;
pub mod fault;
pub mod fleet;
pub mod metrics;
pub mod naive;
pub mod queue;
pub mod scheduler;
pub mod workload;

pub use control::{
    control_by_name, ControlAction, Controller, ControlState, SloDvfs, StaticNominal,
    DEFAULT_CONTROL_CADENCE_CYCLES, DVFS_TRANSITION_CYCLES,
};
pub use fault::{admission_by_name, AdmissionPolicy, FaultConfig, FaultSummary};
pub use fleet::{Fleet, ServeEngine};
pub use metrics::{
    jain, ControlSummary, LatencyStore, MetricsWindow, ServeReport, TenantSummary,
    WindowSnapshot, EXACT_CAP,
};
pub use queue::QueueView;
pub use scheduler::{
    by_name as scheduler_by_name, Drf, DynamicBatch, Fifo, LocalityAware, Queued,
    RoundRobin, Scheduler, Selection, Wfq,
};
pub use workload::{
    Arrivals, ArrivalStream, Request, RequestClass, Workload, DEFAULT_BURST_PERIOD_S,
    DEFAULT_DIURNAL_PERIOD_S,
};
