//! Request-stream descriptions for the serving layer.
//!
//! A [`Workload`] is a deterministic description of *who asks for what,
//! when*: a set of [`RequestClass`]es (each one compiled deployment) and
//! an arrival process. Every arrival is derived from the workload seed
//! through [`XorShift64`] — no wall clock anywhere — so a serve run is a
//! pure function of (workload, geometry, scheduler) and two runs with
//! the same inputs produce bit-identical [`super::ServeReport`]s.
//!
//! The arrival shapes cover the classic serving scenarios:
//!
//! - [`Arrivals::Poisson`] / [`Arrivals::Bursty`] — open-loop traffic.
//!   Inter-arrival gaps are exponential (`-ln(1-u)/rate`); the bursty
//!   variant modulates the rate with a square wave (on-half of each
//!   period at `rate x burst_factor`, off-half at `rate / burst_factor`),
//!   which is what makes batching schedulers earn their keep.
//! - [`Arrivals::Diurnal`] — sinusoid-modulated Poisson,
//!   `rate x (1 + depth·sin(2πt/period))`: the slow day/night swing the
//!   online control plane (DVFS + shard parking) is designed to ride.
//!   Sampled by thinning at the peak rate, which keeps the process
//!   exact and the stream state O(1).
//! - [`Arrivals::Trace`] — explicit tenant-tagged replay of
//!   [`TraceEntry`] rows (the legacy `(cycle, class)` constructor
//!   [`Workload::trace`] is a thin adapter that tags tenant 0).
//! - [`Arrivals::TraceFile`] — streamed replay of a CSV/JSONL trace
//!   file through `trace::TraceReader`: O(1) resident memory, validated
//!   once at construction by a single `trace::scan` pass.
//! - [`Arrivals::ClosedLoop`] — N clients, each issuing its next request
//!   `think_cycles` after its previous one completes (the fleet issues
//!   follow-ons from completions; only the first wave is pre-generated).
//!
//! Every request carries a tenant id (0 for the synthetic open/closed
//! -loop kinds) — the hook the fairness-aware schedulers and per-tenant
//! SLO accounting in `serve::metrics` key on.

use std::path::PathBuf;

use crate::deeploy::DeployError;
use crate::models::ModelConfig;
use crate::trace::{TraceEntry, TraceReader};
use crate::util::prng::XorShift64;

/// Default square-wave period of bursty workloads, seconds — the one
/// value shared by the `serve` CLI and the explorer's serving rung, so
/// both judge the same traffic shape.
pub const DEFAULT_BURST_PERIOD_S: f64 = 0.02;

/// Default period of the diurnal sinusoid, seconds. Deliberately slow
/// against the burst period (25x) so whole control windows sit inside
/// one phase of the swing — the regime where DVFS/parking decisions
/// have time to pay for their transition costs.
pub const DEFAULT_DIURNAL_PERIOD_S: f64 = 0.5;

/// One request kind: a network to infer, pre-compiled once per fleet.
/// Classes are bucketed by their padded sequence length ([`bucket`]),
/// the quantity the dynamic-batch scheduler groups on.
///
/// [`bucket`]: RequestClass::bucket
#[derive(Debug, Clone)]
pub struct RequestClass {
    pub model: ModelConfig,
    /// Encoder blocks to deploy (a request executes the compiled command
    /// stream once — deploy the full depth to serve full inferences).
    pub layers: usize,
}

impl RequestClass {
    pub fn new(model: &ModelConfig, layers: usize) -> RequestClass {
        RequestClass { model: model.clone(), layers }
    }

    /// Seq-len bucket of the class: the padded sequence length its
    /// deployment is compiled for. Requests in one bucket share a
    /// command stream and can run back-to-back as one batch.
    pub fn bucket(&self) -> usize {
        self.model.seq
    }
}

/// Arrival process of a workload (all times in cluster cycles once
/// materialized; rates are specified in requests/second and converted
/// at the fleet's clock frequency).
#[derive(Debug, Clone)]
pub enum Arrivals {
    /// Open-loop Poisson arrivals at a constant rate.
    Poisson { rate_rps: f64 },
    /// Square-wave-modulated Poisson: the first half of each period
    /// arrives at `rate_rps * burst_factor`, the second half at
    /// `rate_rps / burst_factor`. Exponential memorylessness makes
    /// advance-to-boundary-and-resample sampling exact.
    Bursty { rate_rps: f64, burst_factor: f64, period_s: f64 },
    /// Sinusoid-modulated Poisson: instantaneous rate
    /// `rate_rps * (1 + depth * sin(2πt / period_s))` with
    /// `0 <= depth < 1` (the rate never reaches zero). Sampled by
    /// thinning against the peak rate `rate_rps * (1 + depth)`.
    Diurnal { rate_rps: f64, depth: f64, period_s: f64 },
    /// Explicit in-memory replay of tenant-tagged trace rows.
    Trace(Vec<TraceEntry>),
    /// Streamed replay of an on-disk CSV/JSONL trace (timestamp-sorted;
    /// `tenants` is the tenant universe the construction-time scan
    /// derived). O(1) resident memory however long the trace is.
    TraceFile { path: PathBuf, tenants: usize },
    /// `clients` closed-loop clients; each issues its next request
    /// `think_cycles` after its previous one completes.
    ClosedLoop { clients: usize, think_cycles: u64 },
}

/// A deterministic request stream over a set of request classes.
#[derive(Debug, Clone)]
pub struct Workload {
    pub classes: Vec<RequestClass>,
    pub arrivals: Arrivals,
    /// Total requests offered (for traces: the trace length).
    pub requests: usize,
    pub seed: u64,
}

/// One materialized request.
#[derive(Debug, Clone, Copy)]
pub struct Request {
    pub id: usize,
    /// Index into [`Workload::classes`].
    pub class: usize,
    /// Arrival time in cluster cycles.
    pub arrival: u64,
    /// Tenant the request belongs to (0 for synthetic arrival kinds).
    pub tenant: usize,
}

impl Workload {
    pub fn poisson(
        classes: Vec<RequestClass>,
        rate_rps: f64,
        requests: usize,
        seed: u64,
    ) -> Workload {
        Workload { classes, arrivals: Arrivals::Poisson { rate_rps }, requests, seed }
    }

    pub fn bursty(
        classes: Vec<RequestClass>,
        rate_rps: f64,
        burst_factor: f64,
        period_s: f64,
        requests: usize,
        seed: u64,
    ) -> Workload {
        Workload {
            classes,
            arrivals: Arrivals::Bursty { rate_rps, burst_factor, period_s },
            requests,
            seed,
        }
    }

    pub fn diurnal(
        classes: Vec<RequestClass>,
        rate_rps: f64,
        depth: f64,
        period_s: f64,
        requests: usize,
        seed: u64,
    ) -> Workload {
        Workload {
            classes,
            arrivals: Arrivals::Diurnal { rate_rps, depth, period_s },
            requests,
            seed,
        }
    }

    /// Replay an explicit (cycle, class) trace — the legacy PR-3 shape,
    /// kept as a thin adapter over [`trace_entries`]: every pair is
    /// tagged tenant 0 and flows through the same replay path as file
    /// traces (one ingestion path, pinned by a draw-order unit test).
    ///
    /// [`trace_entries`]: Workload::trace_entries
    pub fn trace(classes: Vec<RequestClass>, entries: Vec<(u64, usize)>) -> Workload {
        let entries = entries
            .into_iter()
            .map(|(cycle, class)| TraceEntry {
                cycle,
                tenant: 0,
                class,
                seq_len: classes.get(class).map_or(0, |c| c.bucket()),
            })
            .collect();
        Workload::trace_entries(classes, entries)
    }

    /// Replay tenant-tagged trace rows held in memory (what
    /// `trace::generate` produces).
    pub fn trace_entries(classes: Vec<RequestClass>, entries: Vec<TraceEntry>) -> Workload {
        let requests = entries.len();
        Workload { classes, arrivals: Arrivals::Trace(entries), requests, seed: 0 }
    }

    /// Stream an on-disk CSV/JSONL trace. The file is validated here by
    /// one O(1)-memory `trace::scan` pass (row count, tenant/class
    /// universe, sorted-by-cycle contract); serving then re-streams it
    /// lazily, so a million-row trace never materializes.
    pub fn trace_file(
        classes: Vec<RequestClass>,
        path: impl Into<PathBuf>,
    ) -> Result<Workload, DeployError> {
        let path = path.into();
        let summary = crate::trace::scan(&path).map_err(|e| {
            DeployError::Builder(format!("trace {}: {e}", path.display()))
        })?;
        if summary.rows == 0 {
            return Err(DeployError::Builder(format!(
                "trace {} has no rows",
                path.display()
            )));
        }
        if summary.classes > classes.len() {
            return Err(DeployError::Builder(format!(
                "trace {} references class {} but only {} classes exist",
                path.display(),
                summary.classes - 1,
                classes.len()
            )));
        }
        Ok(Workload {
            classes,
            arrivals: Arrivals::TraceFile { path, tenants: summary.tenants },
            requests: summary.rows,
            seed: 0,
        })
    }

    pub fn closed_loop(
        classes: Vec<RequestClass>,
        clients: usize,
        think_cycles: u64,
        requests: usize,
        seed: u64,
    ) -> Workload {
        Workload {
            classes,
            arrivals: Arrivals::ClosedLoop { clients, think_cycles },
            requests,
            seed,
        }
    }

    /// The degenerate workload: one request of one model at cycle 0 —
    /// `serve()` on one cluster reproduces `Compiled::stats()`
    /// cycle-for-cycle.
    pub fn single(model: &ModelConfig, layers: usize) -> Workload {
        Workload::trace(vec![RequestClass::new(model, layers)], vec![(0, 0)])
    }

    /// Structural validation (rates, indices, counts). The fleet calls
    /// this before compiling anything.
    pub fn validate(&self) -> Result<(), DeployError> {
        let err = |m: String| Err(DeployError::Builder(m));
        if self.classes.is_empty() {
            return err("workload has no request classes".into());
        }
        if self.requests == 0 {
            return err("workload must offer at least one request".into());
        }
        for c in &self.classes {
            if c.layers == 0 {
                return err(format!("class {}: layers must be >= 1", c.model.name));
            }
        }
        match &self.arrivals {
            Arrivals::Poisson { rate_rps } => {
                if !rate_rps.is_finite() || *rate_rps <= 0.0 {
                    return err(format!("arrival rate must be positive, got {rate_rps}"));
                }
            }
            Arrivals::Bursty { rate_rps, burst_factor, period_s } => {
                if !rate_rps.is_finite() || *rate_rps <= 0.0 {
                    return err(format!("arrival rate must be positive, got {rate_rps}"));
                }
                if !burst_factor.is_finite() || *burst_factor < 1.0 {
                    return err(format!("burst factor must be >= 1, got {burst_factor}"));
                }
                if !period_s.is_finite() || *period_s <= 0.0 {
                    return err(format!("burst period must be positive, got {period_s}"));
                }
            }
            Arrivals::Diurnal { rate_rps, depth, period_s } => {
                if !rate_rps.is_finite() || *rate_rps <= 0.0 {
                    return err(format!("arrival rate must be positive, got {rate_rps}"));
                }
                if !depth.is_finite() || !(0.0..1.0).contains(depth) {
                    return err(format!("diurnal depth must be in [0, 1), got {depth}"));
                }
                if !period_s.is_finite() || *period_s <= 0.0 {
                    return err(format!("diurnal period must be positive, got {period_s}"));
                }
            }
            Arrivals::Trace(entries) => {
                if entries.is_empty() {
                    return err("trace workload has no entries".into());
                }
                if entries.len() != self.requests {
                    return err(format!(
                        "trace length {} != offered requests {}",
                        entries.len(),
                        self.requests
                    ));
                }
                if let Some(e) = entries.iter().find(|e| e.class >= self.classes.len()) {
                    return err(format!(
                        "trace references class {} but only {} classes exist",
                        e.class,
                        self.classes.len()
                    ));
                }
            }
            Arrivals::TraceFile { path, tenants } => {
                // the heavy validation (scan) ran at construction; keep
                // the structural invariants the constructor established
                if *tenants == 0 {
                    return err(format!(
                        "trace {} resolved to zero tenants",
                        path.display()
                    ));
                }
            }
            Arrivals::ClosedLoop { clients, .. } => {
                if *clients == 0 {
                    return err("closed-loop workload needs at least one client".into());
                }
            }
        }
        Ok(())
    }

    pub fn is_closed_loop(&self) -> bool {
        matches!(self.arrivals, Arrivals::ClosedLoop { .. })
    }

    /// Tenant universe of the workload (>= 1). Synthetic arrival kinds
    /// are single-tenant; replayed traces carry their own tenant tags.
    pub fn n_tenants(&self) -> usize {
        match &self.arrivals {
            Arrivals::Trace(entries) => {
                entries.iter().map(|e| e.tenant + 1).max().unwrap_or(1)
            }
            Arrivals::TraceFile { tenants, .. } => (*tenants).max(1),
            _ => 1,
        }
    }

    pub fn think_cycles(&self) -> u64 {
        match self.arrivals {
            Arrivals::ClosedLoop { think_cycles, .. } => think_cycles,
            _ => 0,
        }
    }

    /// The class-assignment PRNG stream. The fleet holds it across the
    /// run so closed-loop follow-ons continue the same deterministic
    /// sequence the first wave started.
    pub fn class_rng(&self) -> XorShift64 {
        XorShift64::new(self.seed ^ 0xC1A5_5E5)
    }

    /// Uniform class pick from the dedicated class stream.
    pub fn sample_class(&self, rng: &mut XorShift64) -> usize {
        rng.next_below(self.classes.len() as u64) as usize
    }

    /// Pre-known arrivals the stream will yield before any completion
    /// feedback: the full request count for open-loop processes, the
    /// first per-client wave for closed loop.
    pub fn seed_count(&self) -> usize {
        match &self.arrivals {
            Arrivals::ClosedLoop { clients, .. } => (*clients).min(self.requests),
            _ => self.requests,
        }
    }

    /// Lazy arrival stream (O(1) state, no materialization): yields the
    /// pre-known arrivals in (cycle, id) order, drawing gap and class
    /// randomness in exactly the order [`seed_requests`] does — the
    /// streamed and materialized paths are bit-identical.
    ///
    /// [`seed_requests`]: Workload::seed_requests
    pub fn stream(&self, freq_hz: f64) -> ArrivalStream {
        let n_classes = self.classes.len();
        match &self.arrivals {
            Arrivals::Poisson { rate_rps } => ArrivalStream::Poisson {
                rng: XorShift64::new(self.seed),
                t_s: 0.0,
                rate_rps: *rate_rps,
                freq_hz,
                n_classes,
                next_id: 0,
                total: self.requests,
            },
            Arrivals::Bursty { rate_rps, burst_factor, period_s } => {
                ArrivalStream::Bursty {
                    rng: XorShift64::new(self.seed),
                    t_s: 0.0,
                    rate_rps: *rate_rps,
                    burst_factor: *burst_factor,
                    period_s: *period_s,
                    freq_hz,
                    n_classes,
                    next_id: 0,
                    total: self.requests,
                }
            }
            Arrivals::Diurnal { rate_rps, depth, period_s } => ArrivalStream::Diurnal {
                rng: XorShift64::new(self.seed),
                t_s: 0.0,
                rate_rps: *rate_rps,
                depth: *depth,
                period_s: *period_s,
                freq_hz,
                n_classes,
                next_id: 0,
                total: self.requests,
            },
            Arrivals::Trace(entries) => {
                // traces are explicit data the caller already holds;
                // the stream only normalizes the order (stable sort:
                // equal cycles keep their written order, as before)
                let mut sorted: Vec<TraceEntry> = entries.clone();
                sorted.sort_by_key(|e| e.cycle);
                ArrivalStream::Replay {
                    cursor: ReplayCursor::Mem(sorted.into_iter()),
                    next_id: 0,
                }
            }
            Arrivals::TraceFile { path, .. } => {
                // the constructor's scan validated the file; a file that
                // vanishes or mutates between then and now fails loudly
                let reader = TraceReader::open(path).unwrap_or_else(|e| {
                    panic!("trace {} unreadable after validation: {e}", path.display())
                });
                ArrivalStream::Replay { cursor: ReplayCursor::File(reader), next_id: 0 }
            }
            Arrivals::ClosedLoop { .. } => ArrivalStream::ClosedLoop {
                n_classes,
                next_id: 0,
                first_wave: self.seed_count(),
            },
        }
    }

    /// Materialize the pre-known arrivals, sorted by (cycle, id) — the
    /// collected [`stream`](Workload::stream). Kept for tests and the
    /// retained naive serve loop; the optimized fleet pulls the stream
    /// lazily instead.
    pub fn seed_requests(&self, freq_hz: f64, class_rng: &mut XorShift64) -> Vec<Request> {
        let mut s = self.stream(freq_hz);
        std::iter::from_fn(|| s.next(class_rng)).collect()
    }
}

/// Replay source behind [`ArrivalStream::Replay`]: an in-memory row
/// list or a streaming file reader (O(1) resident memory either way —
/// the file arm never materializes the trace).
#[derive(Debug)]
pub enum ReplayCursor {
    Mem(std::vec::IntoIter<TraceEntry>),
    File(TraceReader<std::io::BufReader<std::fs::File>>),
}

impl ReplayCursor {
    fn next_entry(&mut self) -> Option<TraceEntry> {
        match self {
            ReplayCursor::Mem(it) => it.next(),
            ReplayCursor::File(reader) => reader.next_entry().map(|r| {
                // parse errors here mean the file changed after the
                // construction-time scan accepted it — fail loudly
                r.unwrap_or_else(|e| panic!("trace mutated after validation: {e}"))
            }),
        }
    }
}

/// Lazy arrival generator (see [`Workload::stream`]): O(1) state per
/// open-loop process, so million-request workloads never materialize.
/// Class draws happen at pull time from the caller's class PRNG —
/// requests are pulled in id order, so the draw sequence is identical
/// to the materialized path. (Replayed traces draw no randomness at
/// all: class and tenant are explicit per row.)
#[derive(Debug)]
pub enum ArrivalStream {
    Poisson {
        rng: XorShift64,
        t_s: f64,
        rate_rps: f64,
        freq_hz: f64,
        n_classes: usize,
        next_id: usize,
        total: usize,
    },
    Bursty {
        rng: XorShift64,
        t_s: f64,
        rate_rps: f64,
        burst_factor: f64,
        period_s: f64,
        freq_hz: f64,
        n_classes: usize,
        next_id: usize,
        total: usize,
    },
    Diurnal {
        rng: XorShift64,
        t_s: f64,
        rate_rps: f64,
        depth: f64,
        period_s: f64,
        freq_hz: f64,
        n_classes: usize,
        next_id: usize,
        total: usize,
    },
    Replay {
        cursor: ReplayCursor,
        next_id: usize,
    },
    ClosedLoop {
        n_classes: usize,
        next_id: usize,
        first_wave: usize,
    },
}

impl ArrivalStream {
    /// Next request in (arrival cycle, id) order, or `None` when the
    /// pre-known arrivals are exhausted. `class_rng` is the workload's
    /// class stream ([`Workload::class_rng`]) — the fleet holds it
    /// across the run so closed-loop follow-ons continue the same
    /// deterministic sequence.
    pub fn next(&mut self, class_rng: &mut XorShift64) -> Option<Request> {
        let draw = |rng: &mut XorShift64, n: usize| rng.next_below(n as u64) as usize;
        match self {
            ArrivalStream::Poisson {
                rng,
                t_s,
                rate_rps,
                freq_hz,
                n_classes,
                next_id,
                total,
            } => {
                if *next_id >= *total {
                    return None;
                }
                *t_s += exp_gap(rng, *rate_rps);
                let id = *next_id;
                *next_id += 1;
                Some(Request {
                    id,
                    class: draw(class_rng, *n_classes),
                    arrival: (*t_s * *freq_hz).round() as u64,
                    tenant: 0,
                })
            }
            ArrivalStream::Bursty {
                rng,
                t_s,
                rate_rps,
                burst_factor,
                period_s,
                freq_hz,
                n_classes,
                next_id,
                total,
            } => {
                if *next_id >= *total {
                    return None;
                }
                let half = *period_s / 2.0;
                loop {
                    let phase = t_s.rem_euclid(*period_s);
                    let on = phase < half;
                    let rate = if on {
                        *rate_rps * *burst_factor
                    } else {
                        *rate_rps / *burst_factor
                    };
                    let gap = exp_gap(rng, rate);
                    let boundary =
                        if on { *t_s - phase + half } else { *t_s - phase + *period_s };
                    if *t_s + gap >= boundary {
                        // crossed into the other phase: advance to the
                        // boundary and resample (exact, by memorylessness)
                        *t_s = boundary;
                    } else {
                        *t_s += gap;
                        let id = *next_id;
                        *next_id += 1;
                        return Some(Request {
                            id,
                            class: draw(class_rng, *n_classes),
                            arrival: (*t_s * *freq_hz).round() as u64,
                            tenant: 0,
                        });
                    }
                }
            }
            ArrivalStream::Diurnal {
                rng,
                t_s,
                rate_rps,
                depth,
                period_s,
                freq_hz,
                n_classes,
                next_id,
                total,
            } => {
                if *next_id >= *total {
                    return None;
                }
                // thinning: draw candidate gaps at the peak rate
                // rate*(1+depth), accept with probability λ(t)/λmax —
                // exact for an inhomogeneous Poisson process, and every
                // draw comes from the one workload PRNG stream
                let peak = *rate_rps * (1.0 + *depth);
                loop {
                    *t_s += exp_gap(rng, peak);
                    let lambda = *rate_rps
                        * (1.0
                            + *depth
                                * (2.0 * std::f64::consts::PI * *t_s / *period_s).sin());
                    if rng.next_f64() * peak <= lambda {
                        let id = *next_id;
                        *next_id += 1;
                        return Some(Request {
                            id,
                            class: draw(class_rng, *n_classes),
                            arrival: (*t_s * *freq_hz).round() as u64,
                            tenant: 0,
                        });
                    }
                }
            }
            ArrivalStream::Replay { cursor, next_id } => {
                cursor.next_entry().map(|e| {
                    let id = *next_id;
                    *next_id += 1;
                    Request { id, class: e.class, arrival: e.cycle, tenant: e.tenant }
                })
            }
            ArrivalStream::ClosedLoop { n_classes, next_id, first_wave } => {
                if *next_id >= *first_wave {
                    return None;
                }
                let id = *next_id;
                *next_id += 1;
                Some(Request {
                    id,
                    class: draw(class_rng, *n_classes),
                    arrival: 0,
                    tenant: 0,
                })
            }
        }
    }
}

/// One exponential inter-arrival gap in seconds. `next_f64` is in
/// [0, 1), so `1 - u` is in (0, 1] and the log is finite and <= 0.
fn exp_gap(rng: &mut XorShift64, rate_rps: f64) -> f64 {
    -(1.0 - rng.next_f64()).ln() / rate_rps
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{DINOV2S, MOBILEBERT};

    const FREQ: f64 = crate::energy::operating_point::NOMINAL_FREQ_HZ;

    fn classes() -> Vec<RequestClass> {
        vec![RequestClass::new(&MOBILEBERT, 1), RequestClass::new(&DINOV2S, 1)]
    }

    #[test]
    fn poisson_is_deterministic_sorted_and_rate_shaped() {
        let w = Workload::poisson(classes(), 100.0, 200, 7);
        let a = w.seed_requests(FREQ, &mut w.class_rng());
        let b = w.seed_requests(FREQ, &mut w.class_rng());
        assert_eq!(a.len(), 200);
        assert!(a.iter().zip(&b).all(|(x, y)| x.arrival == y.arrival && x.class == y.class));
        assert!(a.windows(2).all(|p| p[0].arrival <= p[1].arrival), "sorted");
        // 200 arrivals at 100 req/s ~ 2 s of stream (loose CLT bounds)
        let span_s = a.last().unwrap().arrival as f64 / FREQ;
        assert!((1.0..4.0).contains(&span_s), "span {span_s} s");
        // both classes appear
        assert!(a.iter().any(|r| r.class == 0) && a.iter().any(|r| r.class == 1));
    }

    #[test]
    fn bursty_concentrates_arrivals_in_on_phases() {
        let period = 0.02;
        let w = Workload::bursty(classes(), 200.0, 8.0, period, 400, 11);
        let a = w.seed_requests(FREQ, &mut w.class_rng());
        assert_eq!(a.len(), 400);
        assert!(a.windows(2).all(|p| p[0].arrival <= p[1].arrival));
        let on = a
            .iter()
            .filter(|r| (r.arrival as f64 / FREQ).rem_euclid(period) < period / 2.0)
            .count();
        // on-phase rate is 64x the off-phase rate: the on half must
        // carry the overwhelming majority of arrivals
        assert!(on > a.len() * 8 / 10, "only {on}/{} arrivals in bursts", a.len());
    }

    #[test]
    fn diurnal_concentrates_arrivals_in_the_high_half_of_the_sinusoid() {
        let period = DEFAULT_DIURNAL_PERIOD_S;
        let w = Workload::diurnal(classes(), 400.0, 0.9, period, 800, 23);
        let a = w.seed_requests(FREQ, &mut w.class_rng());
        assert_eq!(a.len(), 800);
        assert!(a.windows(2).all(|p| p[0].arrival <= p[1].arrival), "sorted");
        // sin is positive over the first half of each period: with
        // depth 0.9 the high half carries rate x(1..1.9) against
        // x(0.1..1), so well over half of all arrivals land there
        let high = a
            .iter()
            .filter(|r| (r.arrival as f64 / FREQ).rem_euclid(period) < period / 2.0)
            .count();
        assert!(high > a.len() * 6 / 10, "only {high}/{} arrivals in the peak", a.len());
        // mean rate stays near the nominal rate (the sinusoid averages
        // out): 800 arrivals at 400 req/s ~ 2 s of stream
        let span_s = a.last().unwrap().arrival as f64 / FREQ;
        assert!((1.0..4.0).contains(&span_s), "span {span_s} s");
    }

    #[test]
    fn trace_sorts_and_validates_class_indices() {
        let w = Workload::trace(classes(), vec![(500, 1), (0, 0), (250, 0)]);
        assert!(w.validate().is_ok());
        let a = w.seed_requests(FREQ, &mut w.class_rng());
        assert_eq!(a.len(), 3);
        assert_eq!((a[0].arrival, a[0].class), (0, 0));
        assert_eq!((a[2].arrival, a[2].class), (500, 1));

        let bad = Workload::trace(classes(), vec![(0, 9)]);
        assert!(matches!(bad.validate(), Err(DeployError::Builder(_))));
    }

    #[test]
    fn closed_loop_seeds_one_request_per_client() {
        let w = Workload::closed_loop(classes(), 3, 1000, 10, 5);
        let a = w.seed_requests(FREQ, &mut w.class_rng());
        assert_eq!(a.len(), 3);
        assert!(a.iter().all(|r| r.arrival == 0));
        assert!(w.is_closed_loop());
        assert_eq!(w.think_cycles(), 1000);
        // never seed more than the offered total
        let tiny = Workload::closed_loop(classes(), 8, 0, 2, 5);
        assert_eq!(tiny.seed_requests(FREQ, &mut tiny.class_rng()).len(), 2);
    }

    #[test]
    fn validation_rejects_degenerate_workloads() {
        assert!(Workload::poisson(vec![], 10.0, 4, 0).validate().is_err());
        assert!(Workload::poisson(classes(), 0.0, 4, 0).validate().is_err());
        assert!(Workload::poisson(classes(), 10.0, 0, 0).validate().is_err());
        assert!(Workload::bursty(classes(), 10.0, 0.5, 0.02, 4, 0).validate().is_err());
        assert!(Workload::closed_loop(classes(), 0, 10, 4, 0).validate().is_err());
        assert!(Workload::diurnal(classes(), 10.0, 1.0, 0.5, 4, 0).validate().is_err());
        assert!(Workload::diurnal(classes(), 10.0, -0.1, 0.5, 4, 0).validate().is_err());
        assert!(Workload::diurnal(classes(), 0.0, 0.5, 0.5, 4, 0).validate().is_err());
        assert!(Workload::diurnal(classes(), 10.0, 0.5, 0.0, 4, 0).validate().is_err());
        assert!(Workload::diurnal(classes(), 10.0, 0.5, 0.5, 4, 0).validate().is_ok());
        let zero_layers = Workload::poisson(
            vec![RequestClass { model: MOBILEBERT.clone(), layers: 0 }],
            10.0,
            4,
            0,
        );
        assert!(zero_layers.validate().is_err());
    }

    #[test]
    fn stream_is_bit_identical_to_materialization() {
        // the lazy stream must reproduce seed_requests exactly — same
        // arrivals, same ids, same class draws — for every arrival kind
        let workloads = vec![
            Workload::poisson(classes(), 150.0, 100, 3),
            Workload::bursty(classes(), 250.0, 6.0, 0.02, 100, 9),
            Workload::diurnal(classes(), 300.0, 0.7, 0.5, 100, 13),
            Workload::trace(classes(), vec![(500, 1), (0, 0), (250, 0), (250, 1)]),
            Workload::closed_loop(classes(), 5, 1000, 50, 17),
        ];
        for w in workloads {
            let materialized = w.seed_requests(FREQ, &mut w.class_rng());
            let mut crng = w.class_rng();
            let mut s = w.stream(FREQ);
            let mut streamed = Vec::new();
            while let Some(r) = s.next(&mut crng) {
                streamed.push(r);
            }
            assert_eq!(streamed.len(), materialized.len());
            assert_eq!(streamed.len(), w.seed_count());
            for (a, b) in streamed.iter().zip(&materialized) {
                assert_eq!((a.id, a.class, a.arrival), (b.id, b.class, b.arrival));
            }
        }
    }

    #[test]
    fn stream_state_is_constant_size() {
        // a million-request open-loop stream is pulled lazily: the
        // first pulls cost nothing proportional to the total
        let w = Workload::poisson(classes(), 1000.0, 1_000_000, 1);
        let mut crng = w.class_rng();
        let mut s = w.stream(FREQ);
        let first = s.next(&mut crng).unwrap();
        assert_eq!(first.id, 0);
        let second = s.next(&mut crng).unwrap();
        assert_eq!(second.id, 1);
        assert!(second.arrival >= first.arrival);
    }

    #[test]
    fn legacy_pair_trace_is_a_thin_adapter_over_trace_entries() {
        // satellite contract: the PR-3 (cycle, class) constructor must
        // route through the trace-entry replay path with tenant 0 and
        // the exact draw order it always had (no PRNG perturbation —
        // replay draws no class randomness at all)
        let pairs = vec![(500u64, 1usize), (0, 0), (250, 0), (250, 1)];
        let legacy = Workload::trace(classes(), pairs.clone());
        let explicit = Workload::trace_entries(
            classes(),
            pairs
                .iter()
                .map(|&(cycle, class)| TraceEntry {
                    cycle,
                    tenant: 0,
                    class,
                    seq_len: classes()[class].bucket(),
                })
                .collect(),
        );
        let mut crng = legacy.class_rng();
        let a = legacy.seed_requests(FREQ, &mut crng);
        let state_after = crng.next_u64();
        let b = explicit.seed_requests(FREQ, &mut explicit.class_rng());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(
                (x.id, x.class, x.arrival, x.tenant),
                (y.id, y.class, y.arrival, y.tenant)
            );
            assert_eq!(x.tenant, 0);
        }
        // the class PRNG was never advanced by the replay
        assert_eq!(state_after, legacy.class_rng().next_u64());
        assert_eq!(legacy.n_tenants(), 1);
    }

    #[test]
    fn tenant_tags_flow_from_trace_entries_to_requests() {
        let entries = vec![
            TraceEntry { cycle: 0, tenant: 1, class: 0, seq_len: 0 },
            TraceEntry { cycle: 10, tenant: 0, class: 1, seq_len: 0 },
            TraceEntry { cycle: 20, tenant: 2, class: 0, seq_len: 0 },
        ];
        let w = Workload::trace_entries(classes(), entries);
        assert!(w.validate().is_ok());
        assert_eq!(w.n_tenants(), 3);
        let a = w.seed_requests(FREQ, &mut w.class_rng());
        assert_eq!(
            a.iter().map(|r| r.tenant).collect::<Vec<_>>(),
            vec![1, 0, 2],
            "tenant tags survive replay in cycle order"
        );
        // open-loop kinds are single-tenant by construction
        let p = Workload::poisson(classes(), 100.0, 20, 3);
        assert_eq!(p.n_tenants(), 1);
        assert!(p
            .seed_requests(FREQ, &mut p.class_rng())
            .iter()
            .all(|r| r.tenant == 0));
    }

    #[test]
    fn trace_file_streams_bit_identically_to_in_memory_replay() {
        let spec = crate::trace::skewed_two_tenant(300, 5_000.0, &[128, 197], 21);
        let entries = crate::trace::generate(spec).unwrap();
        let path = std::env::temp_dir().join("attn_tinyml_workload_trace.csv");
        let mut buf = Vec::new();
        crate::trace::write_csv(&mut buf, entries.iter().copied()).unwrap();
        std::fs::write(&path, &buf).unwrap();

        let mem = Workload::trace_entries(classes(), entries);
        let file = Workload::trace_file(classes(), &path).unwrap();
        assert_eq!(file.requests, mem.requests);
        assert_eq!(file.n_tenants(), mem.n_tenants());
        let a = mem.seed_requests(FREQ, &mut mem.class_rng());
        let b = file.seed_requests(FREQ, &mut file.class_rng());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(
                (x.id, x.class, x.arrival, x.tenant),
                (y.id, y.class, y.arrival, y.tenant)
            );
        }
        // a trace naming classes the workload lacks is rejected
        let few = vec![RequestClass::new(&MOBILEBERT, 1)];
        assert!(Workload::trace_file(few, &path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn single_is_the_degenerate_trace() {
        let w = Workload::single(&MOBILEBERT, 1);
        assert!(w.validate().is_ok());
        assert_eq!(w.requests, 1);
        let a = w.seed_requests(FREQ, &mut w.class_rng());
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].arrival, 0);
        assert_eq!(w.classes[0].bucket(), MOBILEBERT.seq);
    }
}
