//! Request-stream descriptions for the serving layer.
//!
//! A [`Workload`] is a deterministic description of *who asks for what,
//! when*: a set of [`RequestClass`]es (each one compiled deployment) and
//! an arrival process. Every arrival is derived from the workload seed
//! through [`XorShift64`] — no wall clock anywhere — so a serve run is a
//! pure function of (workload, geometry, scheduler) and two runs with
//! the same inputs produce bit-identical [`super::ServeReport`]s.
//!
//! Four arrival shapes cover the classic serving scenarios:
//!
//! - [`Arrivals::Poisson`] / [`Arrivals::Bursty`] — open-loop traffic.
//!   Inter-arrival gaps are exponential (`-ln(1-u)/rate`); the bursty
//!   variant modulates the rate with a square wave (on-half of each
//!   period at `rate x burst_factor`, off-half at `rate / burst_factor`),
//!   which is what makes batching schedulers earn their keep.
//! - [`Arrivals::Diurnal`] — sinusoid-modulated Poisson,
//!   `rate x (1 + depth·sin(2πt/period))`: the slow day/night swing the
//!   online control plane (DVFS + shard parking) is designed to ride.
//!   Sampled by thinning at the peak rate, which keeps the process
//!   exact and the stream state O(1).
//! - [`Arrivals::Trace`] — explicit `(cycle, class)` replay.
//! - [`Arrivals::ClosedLoop`] — N clients, each issuing its next request
//!   `think_cycles` after its previous one completes (the fleet issues
//!   follow-ons from completions; only the first wave is pre-generated).

use crate::deeploy::DeployError;
use crate::models::ModelConfig;
use crate::util::prng::XorShift64;

/// Default square-wave period of bursty workloads, seconds — the one
/// value shared by the `serve` CLI and the explorer's serving rung, so
/// both judge the same traffic shape.
pub const DEFAULT_BURST_PERIOD_S: f64 = 0.02;

/// Default period of the diurnal sinusoid, seconds. Deliberately slow
/// against the burst period (25x) so whole control windows sit inside
/// one phase of the swing — the regime where DVFS/parking decisions
/// have time to pay for their transition costs.
pub const DEFAULT_DIURNAL_PERIOD_S: f64 = 0.5;

/// One request kind: a network to infer, pre-compiled once per fleet.
/// Classes are bucketed by their padded sequence length ([`bucket`]),
/// the quantity the dynamic-batch scheduler groups on.
///
/// [`bucket`]: RequestClass::bucket
#[derive(Debug, Clone)]
pub struct RequestClass {
    pub model: ModelConfig,
    /// Encoder blocks to deploy (a request executes the compiled command
    /// stream once — deploy the full depth to serve full inferences).
    pub layers: usize,
}

impl RequestClass {
    pub fn new(model: &ModelConfig, layers: usize) -> RequestClass {
        RequestClass { model: model.clone(), layers }
    }

    /// Seq-len bucket of the class: the padded sequence length its
    /// deployment is compiled for. Requests in one bucket share a
    /// command stream and can run back-to-back as one batch.
    pub fn bucket(&self) -> usize {
        self.model.seq
    }
}

/// Arrival process of a workload (all times in cluster cycles once
/// materialized; rates are specified in requests/second and converted
/// at the fleet's clock frequency).
#[derive(Debug, Clone)]
pub enum Arrivals {
    /// Open-loop Poisson arrivals at a constant rate.
    Poisson { rate_rps: f64 },
    /// Square-wave-modulated Poisson: the first half of each period
    /// arrives at `rate_rps * burst_factor`, the second half at
    /// `rate_rps / burst_factor`. Exponential memorylessness makes
    /// advance-to-boundary-and-resample sampling exact.
    Bursty { rate_rps: f64, burst_factor: f64, period_s: f64 },
    /// Sinusoid-modulated Poisson: instantaneous rate
    /// `rate_rps * (1 + depth * sin(2πt / period_s))` with
    /// `0 <= depth < 1` (the rate never reaches zero). Sampled by
    /// thinning against the peak rate `rate_rps * (1 + depth)`.
    Diurnal { rate_rps: f64, depth: f64, period_s: f64 },
    /// Explicit replay: (arrival cycle, class index) pairs.
    Trace(Vec<(u64, usize)>),
    /// `clients` closed-loop clients; each issues its next request
    /// `think_cycles` after its previous one completes.
    ClosedLoop { clients: usize, think_cycles: u64 },
}

/// A deterministic request stream over a set of request classes.
#[derive(Debug, Clone)]
pub struct Workload {
    pub classes: Vec<RequestClass>,
    pub arrivals: Arrivals,
    /// Total requests offered (for traces: the trace length).
    pub requests: usize,
    pub seed: u64,
}

/// One materialized request.
#[derive(Debug, Clone, Copy)]
pub struct Request {
    pub id: usize,
    /// Index into [`Workload::classes`].
    pub class: usize,
    /// Arrival time in cluster cycles.
    pub arrival: u64,
}

impl Workload {
    pub fn poisson(
        classes: Vec<RequestClass>,
        rate_rps: f64,
        requests: usize,
        seed: u64,
    ) -> Workload {
        Workload { classes, arrivals: Arrivals::Poisson { rate_rps }, requests, seed }
    }

    pub fn bursty(
        classes: Vec<RequestClass>,
        rate_rps: f64,
        burst_factor: f64,
        period_s: f64,
        requests: usize,
        seed: u64,
    ) -> Workload {
        Workload {
            classes,
            arrivals: Arrivals::Bursty { rate_rps, burst_factor, period_s },
            requests,
            seed,
        }
    }

    pub fn diurnal(
        classes: Vec<RequestClass>,
        rate_rps: f64,
        depth: f64,
        period_s: f64,
        requests: usize,
        seed: u64,
    ) -> Workload {
        Workload {
            classes,
            arrivals: Arrivals::Diurnal { rate_rps, depth, period_s },
            requests,
            seed,
        }
    }

    /// Replay an explicit (cycle, class) trace.
    pub fn trace(classes: Vec<RequestClass>, entries: Vec<(u64, usize)>) -> Workload {
        let requests = entries.len();
        Workload { classes, arrivals: Arrivals::Trace(entries), requests, seed: 0 }
    }

    pub fn closed_loop(
        classes: Vec<RequestClass>,
        clients: usize,
        think_cycles: u64,
        requests: usize,
        seed: u64,
    ) -> Workload {
        Workload {
            classes,
            arrivals: Arrivals::ClosedLoop { clients, think_cycles },
            requests,
            seed,
        }
    }

    /// The degenerate workload: one request of one model at cycle 0 —
    /// `serve()` on one cluster reproduces `Compiled::stats()`
    /// cycle-for-cycle.
    pub fn single(model: &ModelConfig, layers: usize) -> Workload {
        Workload::trace(vec![RequestClass::new(model, layers)], vec![(0, 0)])
    }

    /// Structural validation (rates, indices, counts). The fleet calls
    /// this before compiling anything.
    pub fn validate(&self) -> Result<(), DeployError> {
        let err = |m: String| Err(DeployError::Builder(m));
        if self.classes.is_empty() {
            return err("workload has no request classes".into());
        }
        if self.requests == 0 {
            return err("workload must offer at least one request".into());
        }
        for c in &self.classes {
            if c.layers == 0 {
                return err(format!("class {}: layers must be >= 1", c.model.name));
            }
        }
        match &self.arrivals {
            Arrivals::Poisson { rate_rps } => {
                if !rate_rps.is_finite() || *rate_rps <= 0.0 {
                    return err(format!("arrival rate must be positive, got {rate_rps}"));
                }
            }
            Arrivals::Bursty { rate_rps, burst_factor, period_s } => {
                if !rate_rps.is_finite() || *rate_rps <= 0.0 {
                    return err(format!("arrival rate must be positive, got {rate_rps}"));
                }
                if !burst_factor.is_finite() || *burst_factor < 1.0 {
                    return err(format!("burst factor must be >= 1, got {burst_factor}"));
                }
                if !period_s.is_finite() || *period_s <= 0.0 {
                    return err(format!("burst period must be positive, got {period_s}"));
                }
            }
            Arrivals::Diurnal { rate_rps, depth, period_s } => {
                if !rate_rps.is_finite() || *rate_rps <= 0.0 {
                    return err(format!("arrival rate must be positive, got {rate_rps}"));
                }
                if !depth.is_finite() || !(0.0..1.0).contains(depth) {
                    return err(format!("diurnal depth must be in [0, 1), got {depth}"));
                }
                if !period_s.is_finite() || *period_s <= 0.0 {
                    return err(format!("diurnal period must be positive, got {period_s}"));
                }
            }
            Arrivals::Trace(entries) => {
                if entries.is_empty() {
                    return err("trace workload has no entries".into());
                }
                if entries.len() != self.requests {
                    return err(format!(
                        "trace length {} != offered requests {}",
                        entries.len(),
                        self.requests
                    ));
                }
                if let Some((_, c)) = entries.iter().find(|(_, c)| *c >= self.classes.len()) {
                    return err(format!(
                        "trace references class {c} but only {} classes exist",
                        self.classes.len()
                    ));
                }
            }
            Arrivals::ClosedLoop { clients, .. } => {
                if *clients == 0 {
                    return err("closed-loop workload needs at least one client".into());
                }
            }
        }
        Ok(())
    }

    pub fn is_closed_loop(&self) -> bool {
        matches!(self.arrivals, Arrivals::ClosedLoop { .. })
    }

    pub fn think_cycles(&self) -> u64 {
        match self.arrivals {
            Arrivals::ClosedLoop { think_cycles, .. } => think_cycles,
            _ => 0,
        }
    }

    /// The class-assignment PRNG stream. The fleet holds it across the
    /// run so closed-loop follow-ons continue the same deterministic
    /// sequence the first wave started.
    pub fn class_rng(&self) -> XorShift64 {
        XorShift64::new(self.seed ^ 0xC1A5_5E5)
    }

    /// Uniform class pick from the dedicated class stream.
    pub fn sample_class(&self, rng: &mut XorShift64) -> usize {
        rng.next_below(self.classes.len() as u64) as usize
    }

    /// Pre-known arrivals the stream will yield before any completion
    /// feedback: the full request count for open-loop processes, the
    /// first per-client wave for closed loop.
    pub fn seed_count(&self) -> usize {
        match &self.arrivals {
            Arrivals::ClosedLoop { clients, .. } => (*clients).min(self.requests),
            _ => self.requests,
        }
    }

    /// Lazy arrival stream (O(1) state, no materialization): yields the
    /// pre-known arrivals in (cycle, id) order, drawing gap and class
    /// randomness in exactly the order [`seed_requests`] does — the
    /// streamed and materialized paths are bit-identical.
    ///
    /// [`seed_requests`]: Workload::seed_requests
    pub fn stream(&self, freq_hz: f64) -> ArrivalStream {
        let n_classes = self.classes.len();
        match &self.arrivals {
            Arrivals::Poisson { rate_rps } => ArrivalStream::Poisson {
                rng: XorShift64::new(self.seed),
                t_s: 0.0,
                rate_rps: *rate_rps,
                freq_hz,
                n_classes,
                next_id: 0,
                total: self.requests,
            },
            Arrivals::Bursty { rate_rps, burst_factor, period_s } => {
                ArrivalStream::Bursty {
                    rng: XorShift64::new(self.seed),
                    t_s: 0.0,
                    rate_rps: *rate_rps,
                    burst_factor: *burst_factor,
                    period_s: *period_s,
                    freq_hz,
                    n_classes,
                    next_id: 0,
                    total: self.requests,
                }
            }
            Arrivals::Diurnal { rate_rps, depth, period_s } => ArrivalStream::Diurnal {
                rng: XorShift64::new(self.seed),
                t_s: 0.0,
                rate_rps: *rate_rps,
                depth: *depth,
                period_s: *period_s,
                freq_hz,
                n_classes,
                next_id: 0,
                total: self.requests,
            },
            Arrivals::Trace(entries) => {
                // traces are explicit data the caller already holds;
                // the stream only normalizes the order (stable sort:
                // equal cycles keep their written order, as before)
                let mut sorted: Vec<(u64, usize)> = entries.clone();
                sorted.sort_by_key(|&(t, _)| t);
                ArrivalStream::Trace { entries: sorted.into_iter(), next_id: 0 }
            }
            Arrivals::ClosedLoop { .. } => ArrivalStream::ClosedLoop {
                n_classes,
                next_id: 0,
                first_wave: self.seed_count(),
            },
        }
    }

    /// Materialize the pre-known arrivals, sorted by (cycle, id) — the
    /// collected [`stream`](Workload::stream). Kept for tests and the
    /// retained naive serve loop; the optimized fleet pulls the stream
    /// lazily instead.
    pub fn seed_requests(&self, freq_hz: f64, class_rng: &mut XorShift64) -> Vec<Request> {
        let mut s = self.stream(freq_hz);
        std::iter::from_fn(|| s.next(class_rng)).collect()
    }
}

/// Lazy arrival generator (see [`Workload::stream`]): O(1) state per
/// open-loop process, so million-request workloads never materialize.
/// Class draws happen at pull time from the caller's class PRNG —
/// requests are pulled in id order, so the draw sequence is identical
/// to the materialized path.
#[derive(Debug, Clone)]
pub enum ArrivalStream {
    Poisson {
        rng: XorShift64,
        t_s: f64,
        rate_rps: f64,
        freq_hz: f64,
        n_classes: usize,
        next_id: usize,
        total: usize,
    },
    Bursty {
        rng: XorShift64,
        t_s: f64,
        rate_rps: f64,
        burst_factor: f64,
        period_s: f64,
        freq_hz: f64,
        n_classes: usize,
        next_id: usize,
        total: usize,
    },
    Diurnal {
        rng: XorShift64,
        t_s: f64,
        rate_rps: f64,
        depth: f64,
        period_s: f64,
        freq_hz: f64,
        n_classes: usize,
        next_id: usize,
        total: usize,
    },
    Trace {
        entries: std::vec::IntoIter<(u64, usize)>,
        next_id: usize,
    },
    ClosedLoop {
        n_classes: usize,
        next_id: usize,
        first_wave: usize,
    },
}

impl ArrivalStream {
    /// Next request in (arrival cycle, id) order, or `None` when the
    /// pre-known arrivals are exhausted. `class_rng` is the workload's
    /// class stream ([`Workload::class_rng`]) — the fleet holds it
    /// across the run so closed-loop follow-ons continue the same
    /// deterministic sequence.
    pub fn next(&mut self, class_rng: &mut XorShift64) -> Option<Request> {
        let draw = |rng: &mut XorShift64, n: usize| rng.next_below(n as u64) as usize;
        match self {
            ArrivalStream::Poisson {
                rng,
                t_s,
                rate_rps,
                freq_hz,
                n_classes,
                next_id,
                total,
            } => {
                if *next_id >= *total {
                    return None;
                }
                *t_s += exp_gap(rng, *rate_rps);
                let id = *next_id;
                *next_id += 1;
                Some(Request {
                    id,
                    class: draw(class_rng, *n_classes),
                    arrival: (*t_s * *freq_hz).round() as u64,
                })
            }
            ArrivalStream::Bursty {
                rng,
                t_s,
                rate_rps,
                burst_factor,
                period_s,
                freq_hz,
                n_classes,
                next_id,
                total,
            } => {
                if *next_id >= *total {
                    return None;
                }
                let half = *period_s / 2.0;
                loop {
                    let phase = t_s.rem_euclid(*period_s);
                    let on = phase < half;
                    let rate = if on {
                        *rate_rps * *burst_factor
                    } else {
                        *rate_rps / *burst_factor
                    };
                    let gap = exp_gap(rng, rate);
                    let boundary =
                        if on { *t_s - phase + half } else { *t_s - phase + *period_s };
                    if *t_s + gap >= boundary {
                        // crossed into the other phase: advance to the
                        // boundary and resample (exact, by memorylessness)
                        *t_s = boundary;
                    } else {
                        *t_s += gap;
                        let id = *next_id;
                        *next_id += 1;
                        return Some(Request {
                            id,
                            class: draw(class_rng, *n_classes),
                            arrival: (*t_s * *freq_hz).round() as u64,
                        });
                    }
                }
            }
            ArrivalStream::Diurnal {
                rng,
                t_s,
                rate_rps,
                depth,
                period_s,
                freq_hz,
                n_classes,
                next_id,
                total,
            } => {
                if *next_id >= *total {
                    return None;
                }
                // thinning: draw candidate gaps at the peak rate
                // rate*(1+depth), accept with probability λ(t)/λmax —
                // exact for an inhomogeneous Poisson process, and every
                // draw comes from the one workload PRNG stream
                let peak = *rate_rps * (1.0 + *depth);
                loop {
                    *t_s += exp_gap(rng, peak);
                    let lambda = *rate_rps
                        * (1.0
                            + *depth
                                * (2.0 * std::f64::consts::PI * *t_s / *period_s).sin());
                    if rng.next_f64() * peak <= lambda {
                        let id = *next_id;
                        *next_id += 1;
                        return Some(Request {
                            id,
                            class: draw(class_rng, *n_classes),
                            arrival: (*t_s * *freq_hz).round() as u64,
                        });
                    }
                }
            }
            ArrivalStream::Trace { entries, next_id } => {
                entries.next().map(|(arrival, class)| {
                    let id = *next_id;
                    *next_id += 1;
                    Request { id, class, arrival }
                })
            }
            ArrivalStream::ClosedLoop { n_classes, next_id, first_wave } => {
                if *next_id >= *first_wave {
                    return None;
                }
                let id = *next_id;
                *next_id += 1;
                Some(Request { id, class: draw(class_rng, *n_classes), arrival: 0 })
            }
        }
    }
}

/// One exponential inter-arrival gap in seconds. `next_f64` is in
/// [0, 1), so `1 - u` is in (0, 1] and the log is finite and <= 0.
fn exp_gap(rng: &mut XorShift64, rate_rps: f64) -> f64 {
    -(1.0 - rng.next_f64()).ln() / rate_rps
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{DINOV2S, MOBILEBERT};

    const FREQ: f64 = crate::energy::operating_point::NOMINAL_FREQ_HZ;

    fn classes() -> Vec<RequestClass> {
        vec![RequestClass::new(&MOBILEBERT, 1), RequestClass::new(&DINOV2S, 1)]
    }

    #[test]
    fn poisson_is_deterministic_sorted_and_rate_shaped() {
        let w = Workload::poisson(classes(), 100.0, 200, 7);
        let a = w.seed_requests(FREQ, &mut w.class_rng());
        let b = w.seed_requests(FREQ, &mut w.class_rng());
        assert_eq!(a.len(), 200);
        assert!(a.iter().zip(&b).all(|(x, y)| x.arrival == y.arrival && x.class == y.class));
        assert!(a.windows(2).all(|p| p[0].arrival <= p[1].arrival), "sorted");
        // 200 arrivals at 100 req/s ~ 2 s of stream (loose CLT bounds)
        let span_s = a.last().unwrap().arrival as f64 / FREQ;
        assert!((1.0..4.0).contains(&span_s), "span {span_s} s");
        // both classes appear
        assert!(a.iter().any(|r| r.class == 0) && a.iter().any(|r| r.class == 1));
    }

    #[test]
    fn bursty_concentrates_arrivals_in_on_phases() {
        let period = 0.02;
        let w = Workload::bursty(classes(), 200.0, 8.0, period, 400, 11);
        let a = w.seed_requests(FREQ, &mut w.class_rng());
        assert_eq!(a.len(), 400);
        assert!(a.windows(2).all(|p| p[0].arrival <= p[1].arrival));
        let on = a
            .iter()
            .filter(|r| (r.arrival as f64 / FREQ).rem_euclid(period) < period / 2.0)
            .count();
        // on-phase rate is 64x the off-phase rate: the on half must
        // carry the overwhelming majority of arrivals
        assert!(on > a.len() * 8 / 10, "only {on}/{} arrivals in bursts", a.len());
    }

    #[test]
    fn diurnal_concentrates_arrivals_in_the_high_half_of_the_sinusoid() {
        let period = DEFAULT_DIURNAL_PERIOD_S;
        let w = Workload::diurnal(classes(), 400.0, 0.9, period, 800, 23);
        let a = w.seed_requests(FREQ, &mut w.class_rng());
        assert_eq!(a.len(), 800);
        assert!(a.windows(2).all(|p| p[0].arrival <= p[1].arrival), "sorted");
        // sin is positive over the first half of each period: with
        // depth 0.9 the high half carries rate x(1..1.9) against
        // x(0.1..1), so well over half of all arrivals land there
        let high = a
            .iter()
            .filter(|r| (r.arrival as f64 / FREQ).rem_euclid(period) < period / 2.0)
            .count();
        assert!(high > a.len() * 6 / 10, "only {high}/{} arrivals in the peak", a.len());
        // mean rate stays near the nominal rate (the sinusoid averages
        // out): 800 arrivals at 400 req/s ~ 2 s of stream
        let span_s = a.last().unwrap().arrival as f64 / FREQ;
        assert!((1.0..4.0).contains(&span_s), "span {span_s} s");
    }

    #[test]
    fn trace_sorts_and_validates_class_indices() {
        let w = Workload::trace(classes(), vec![(500, 1), (0, 0), (250, 0)]);
        assert!(w.validate().is_ok());
        let a = w.seed_requests(FREQ, &mut w.class_rng());
        assert_eq!(a.len(), 3);
        assert_eq!((a[0].arrival, a[0].class), (0, 0));
        assert_eq!((a[2].arrival, a[2].class), (500, 1));

        let bad = Workload::trace(classes(), vec![(0, 9)]);
        assert!(matches!(bad.validate(), Err(DeployError::Builder(_))));
    }

    #[test]
    fn closed_loop_seeds_one_request_per_client() {
        let w = Workload::closed_loop(classes(), 3, 1000, 10, 5);
        let a = w.seed_requests(FREQ, &mut w.class_rng());
        assert_eq!(a.len(), 3);
        assert!(a.iter().all(|r| r.arrival == 0));
        assert!(w.is_closed_loop());
        assert_eq!(w.think_cycles(), 1000);
        // never seed more than the offered total
        let tiny = Workload::closed_loop(classes(), 8, 0, 2, 5);
        assert_eq!(tiny.seed_requests(FREQ, &mut tiny.class_rng()).len(), 2);
    }

    #[test]
    fn validation_rejects_degenerate_workloads() {
        assert!(Workload::poisson(vec![], 10.0, 4, 0).validate().is_err());
        assert!(Workload::poisson(classes(), 0.0, 4, 0).validate().is_err());
        assert!(Workload::poisson(classes(), 10.0, 0, 0).validate().is_err());
        assert!(Workload::bursty(classes(), 10.0, 0.5, 0.02, 4, 0).validate().is_err());
        assert!(Workload::closed_loop(classes(), 0, 10, 4, 0).validate().is_err());
        assert!(Workload::diurnal(classes(), 10.0, 1.0, 0.5, 4, 0).validate().is_err());
        assert!(Workload::diurnal(classes(), 10.0, -0.1, 0.5, 4, 0).validate().is_err());
        assert!(Workload::diurnal(classes(), 0.0, 0.5, 0.5, 4, 0).validate().is_err());
        assert!(Workload::diurnal(classes(), 10.0, 0.5, 0.0, 4, 0).validate().is_err());
        assert!(Workload::diurnal(classes(), 10.0, 0.5, 0.5, 4, 0).validate().is_ok());
        let zero_layers = Workload::poisson(
            vec![RequestClass { model: MOBILEBERT.clone(), layers: 0 }],
            10.0,
            4,
            0,
        );
        assert!(zero_layers.validate().is_err());
    }

    #[test]
    fn stream_is_bit_identical_to_materialization() {
        // the lazy stream must reproduce seed_requests exactly — same
        // arrivals, same ids, same class draws — for every arrival kind
        let workloads = vec![
            Workload::poisson(classes(), 150.0, 100, 3),
            Workload::bursty(classes(), 250.0, 6.0, 0.02, 100, 9),
            Workload::diurnal(classes(), 300.0, 0.7, 0.5, 100, 13),
            Workload::trace(classes(), vec![(500, 1), (0, 0), (250, 0), (250, 1)]),
            Workload::closed_loop(classes(), 5, 1000, 50, 17),
        ];
        for w in workloads {
            let materialized = w.seed_requests(FREQ, &mut w.class_rng());
            let mut crng = w.class_rng();
            let mut s = w.stream(FREQ);
            let mut streamed = Vec::new();
            while let Some(r) = s.next(&mut crng) {
                streamed.push(r);
            }
            assert_eq!(streamed.len(), materialized.len());
            assert_eq!(streamed.len(), w.seed_count());
            for (a, b) in streamed.iter().zip(&materialized) {
                assert_eq!((a.id, a.class, a.arrival), (b.id, b.class, b.arrival));
            }
        }
    }

    #[test]
    fn stream_state_is_constant_size() {
        // a million-request open-loop stream is pulled lazily: the
        // first pulls cost nothing proportional to the total
        let w = Workload::poisson(classes(), 1000.0, 1_000_000, 1);
        let mut crng = w.class_rng();
        let mut s = w.stream(FREQ);
        let first = s.next(&mut crng).unwrap();
        assert_eq!(first.id, 0);
        let second = s.next(&mut crng).unwrap();
        assert_eq!(second.id, 1);
        assert!(second.arrival >= first.arrival);
    }

    #[test]
    fn single_is_the_degenerate_trace() {
        let w = Workload::single(&MOBILEBERT, 1);
        assert!(w.validate().is_ok());
        assert_eq!(w.requests, 1);
        let a = w.seed_requests(FREQ, &mut w.class_rng());
        assert_eq!(a.len(), 1);
        assert_eq!(a[0].arrival, 0);
        assert_eq!(w.classes[0].bucket(), MOBILEBERT.seq);
    }
}
