//! The retained pre-optimization serve loop — the O(n²) reference.
//!
//! This module preserves the original serving algorithm exactly as the
//! optimized [`super::fleet`] replaced it, as a living reference for
//! (a) the equivalence propcheck in `tests/serve_equivalence.rs` —
//! proving the optimization changed no observable result — and (b) the
//! `benches/perf_serve` wall-clock comparison that the tentpole's ≥10×
//! speedup claim is asserted against. Its cost profile is the point:
//!
//! - **materializes every arrival upfront** (`Workload::seed_requests`
//!   into a `BinaryHeap`) — O(requests) memory before the first event,
//! - keeps the waiting queue as a **flat `Vec<Queued>`** and pays
//!   `Vec::remove` per dispatched request — O(n) each, O(n²) under
//!   backlog,
//! - schedulers **scan the full slice** per free shard per event
//!   (`position`/`filter` over the whole backlog), and the dispatch
//!   retry loop **recounts the free shards** per shard per pass,
//! - advances time by an **O(shards) min-scan** instead of a heap.
//!
//! The only deltas from the historical code are the metric definitions
//! both loops now share (the bounded [`LatencyStore`] and the
//! time-weighted `mean_queue_depth`), so a report from this loop is
//! field-for-field bit-identical to the optimized loop's — the
//! propcheck asserts exactly that.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::deeploy::DeployError;
use crate::energy;

use super::fleet::{class_runtimes, tenant_summaries, Fleet};
use super::metrics::{LatencyStore, ServeReport};
use super::scheduler::Queued;
use super::workload::Workload;

/// The pre-optimization dispatch policies, scanning a flat queue slice
/// (the historical `Scheduler` trait shape). Same decisions as the
/// [`super::scheduler`] implementations, expressed over `&[Queued]`.
#[derive(Debug, Clone)]
pub enum NaivePolicy {
    Fifo,
    RoundRobin,
    DynamicBatch { max_batch: usize },
}

impl NaivePolicy {
    /// CLI-style lookup, mirroring `scheduler::by_name`.
    pub fn by_name(name: &str) -> Option<NaivePolicy> {
        match name {
            "fifo" => Some(NaivePolicy::Fifo),
            "rr" | "round-robin" => Some(NaivePolicy::RoundRobin),
            "batch" | "dynamic-batch" => Some(NaivePolicy::DynamicBatch { max_batch: 8 }),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            NaivePolicy::Fifo => "fifo",
            NaivePolicy::RoundRobin => "round-robin",
            NaivePolicy::DynamicBatch { .. } => "dynamic-batch",
        }
    }

    /// The historical full-slice selection: indices into `queue`.
    fn select(&self, queue: &[Queued], cluster: usize, n_clusters: usize) -> Vec<usize> {
        match *self {
            NaivePolicy::Fifo => {
                if queue.is_empty() {
                    Vec::new()
                } else {
                    vec![0]
                }
            }
            NaivePolicy::RoundRobin => queue
                .iter()
                .position(|q| q.id % n_clusters.max(1) == cluster)
                .map(|i| vec![i])
                .unwrap_or_default(),
            NaivePolicy::DynamicBatch { max_batch } => {
                let Some(head) = queue.first() else {
                    return Vec::new();
                };
                let idx: Vec<usize> = queue
                    .iter()
                    .enumerate()
                    .filter(|(_, q)| q.bucket == head.bucket && q.class == head.class)
                    .map(|(i, _)| i)
                    .collect();
                let share = idx.len().div_ceil(n_clusters.max(1));
                let k = share.min(max_batch).max(1);
                idx[..k.min(idx.len())].to_vec()
            }
        }
    }
}

#[derive(Debug, Clone, Default)]
struct Shard {
    free_at: u64,
    class: Option<usize>,
    busy: u64,
}

/// Run the workload to completion with the pre-optimization loop.
/// Same inputs, same [`ServeReport`], quadratic host cost.
pub fn serve_naive(
    fleet: &Fleet,
    w: &Workload,
    policy: &NaivePolicy,
) -> Result<ServeReport, DeployError> {
    if fleet.n == 0 {
        return Err(DeployError::Builder("fleet size must be >= 1".into()));
    }
    w.validate()?;
    let freq = fleet.cluster.freq_hz;
    let classes = class_runtimes(fleet, w)?;

    // upfront materialization: the whole arrival stream into one heap.
    // (arrival, id) is unique, so the trailing tenant never orders.
    let mut crng = w.class_rng();
    let seeds = w.seed_requests(freq, &mut crng);
    let mut heap: BinaryHeap<Reverse<(u64, usize, usize, usize)>> =
        seeds.iter().map(|r| Reverse((r.arrival, r.id, r.class, r.tenant))).collect();
    let mut issued = seeds.len();
    let closed = w.is_closed_loop();
    let think = w.think_cycles();

    let mut queue: Vec<Queued> = Vec::new();
    let mut shards: Vec<Shard> = vec![Shard::default(); fleet.n];
    let mut lat = LatencyStore::new();
    let mut lat_by_tenant = vec![LatencyStore::new(); w.n_tenants()];
    let mut ops_by_tenant = vec![0u64; w.n_tenants()];
    let mut depth_cycles: u128 = 0;
    let mut depth_max = 0usize;
    let (mut switches, mut batches) = (0u64, 0u64);
    let mut active_j = 0.0f64;
    let mut ops_served = 0u64;
    let mut makespan = 0u64;
    let mut now = 0u64;

    loop {
        // admit everything due by now (heap pops in (cycle, id) order,
        // so the queue stays in arrival order)
        while let Some(&Reverse((t, id, class, tenant))) = heap.peek() {
            if t > now {
                break;
            }
            heap.pop();
            queue.push(Queued {
                id,
                class,
                bucket: w.classes[class].bucket(),
                arrival: t,
                first_arrival: t,
                tenant,
                attempts: 0,
            });
        }
        depth_max = depth_max.max(queue.len());

        // dispatch until no free shard selects anything
        loop {
            let mut dispatched = false;
            for si in 0..fleet.n {
                if shards[si].free_at > now || queue.is_empty() {
                    continue;
                }
                // the historical O(shards) free recount, per shard
                let _free = shards.iter().filter(|s| s.free_at <= now).count();
                let mut sel = policy.select(&queue, si, fleet.n);
                sel.retain(|&i| i < queue.len());
                sel.sort_unstable();
                sel.dedup();
                if sel.is_empty() {
                    continue;
                }
                // a batch is one class (one command stream)
                let class = queue[sel[0]].class;
                debug_assert!(
                    sel.iter().all(|&i| queue[i].class == class),
                    "{}: mixed-class batch",
                    policy.name()
                );
                sel.retain(|&i| queue[i].class == class);

                let rt = &classes[class];
                let mut cost_switch = 0u64;
                if let Some(cur) = shards[si].class {
                    if cur != class {
                        cost_switch = rt.switch_cycles;
                        switches += 1;
                    }
                }
                shards[si].class = Some(class);
                let start = now;
                let base = start + cost_switch + rt.first;
                let mut completion = base;
                for (j, &qi) in sel.iter().enumerate() {
                    let done = base + j as u64 * rt.steady;
                    completion = done;
                    lat.record(done - queue[qi].arrival);
                    let tenant = queue[qi].tenant;
                    if tenant >= lat_by_tenant.len() {
                        lat_by_tenant.resize(tenant + 1, LatencyStore::new());
                        ops_by_tenant.resize(tenant + 1, 0);
                    }
                    lat_by_tenant[tenant].record(done - queue[qi].arrival);
                    ops_by_tenant[tenant] += rt.ops;
                    if closed && issued < w.requests {
                        let id = issued;
                        issued += 1;
                        let next_class = w.sample_class(&mut crng);
                        // follow-ons stay tenant 0, as in the engine
                        heap.push(Reverse((done + think, id, next_class, 0)));
                    }
                }
                active_j += rt.active_j * sel.len() as f64;
                ops_served += rt.ops * sel.len() as u64;
                shards[si].free_at = completion;
                shards[si].busy += completion - start;
                batches += 1;
                makespan = makespan.max(completion);
                // the O(n²) heart of the old design: one O(n) memmove
                // per dispatched request
                for &qi in sel.iter().rev() {
                    queue.remove(qi);
                }
                dispatched = true;
            }
            if !dispatched {
                break;
            }
        }

        // advance to the next event: O(shards) min-scan
        let next_arrival = heap.peek().map(|&Reverse((t, _, _, _))| t);
        let next_free = shards.iter().map(|s| s.free_at).filter(|&f| f > now).min();
        let next = match (next_arrival, next_free) {
            (None, None) => break,
            (Some(a), None) => a,
            (None, Some(f)) => f,
            (Some(a), Some(f)) => a.min(f),
        };
        depth_cycles += queue.len() as u128 * (next - now) as u128;
        now = next;
    }

    let served = lat.count() as usize;
    let mean_latency_cycles = lat.mean();
    let total_time = now.max(1);
    let sec = makespan.max(1) as f64 / freq;
    let energy_j = active_j + energy::P_IDLE_W * sec * fleet.n as f64;
    let (tenants, fairness_jain) =
        tenant_summaries(&mut lat_by_tenant, &ops_by_tenant, sec);
    Ok(ServeReport {
        scheduler: policy.name().to_string(),
        clusters: fleet.n,
        offered: w.requests,
        served,
        makespan_cycles: makespan,
        seconds: sec,
        req_per_s: served as f64 / sec,
        gops: ops_served as f64 / 1e9 / sec,
        energy_j,
        mj_per_req: energy_j * 1e3 / (served.max(1)) as f64,
        gopj: ops_served as f64 / 1e9 / energy_j,
        p50_cycles: lat.percentile(0.50),
        p90_cycles: lat.percentile(0.90),
        p99_cycles: lat.percentile(0.99),
        mean_latency_cycles,
        mean_queue_depth: depth_cycles as f64 / total_time as f64,
        max_queue_depth: depth_max,
        cluster_utilization: shards
            .iter()
            .map(|s| s.busy as f64 / makespan.max(1) as f64)
            .collect(),
        class_switches: switches,
        batches,
        tenants,
        fairness_jain,
        freq_hz: freq,
        control: None,
        net: None,
        final_queue_depth: 0,
        fault: None,
        profile: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::deeploy::Target;
    use crate::models::MOBILEBERT;
    use crate::serve::scheduler::Fifo;
    use crate::serve::workload::RequestClass;
    use crate::sim::ClusterConfig;

    #[test]
    fn naive_matches_optimized_on_a_simple_trace() {
        let classes = vec![RequestClass::new(&MOBILEBERT, 1)];
        let w = Workload::trace(classes, vec![(0, 0), (0, 0), (1000, 0)]);
        let f = Fleet::new(ClusterConfig::default(), Target::MultiCoreIta, 2);
        let naive = serve_naive(&f, &w, &NaivePolicy::Fifo).unwrap();
        let opt = f.serve(&w, &mut Fifo).unwrap();
        assert_eq!(naive.makespan_cycles, opt.makespan_cycles);
        assert_eq!(naive.served, opt.served);
        assert_eq!(naive.batches, opt.batches);
        assert_eq!(naive.p99_cycles, opt.p99_cycles);
        assert_eq!(naive.energy_j.to_bits(), opt.energy_j.to_bits());
        assert_eq!(
            naive.mean_queue_depth.to_bits(),
            opt.mean_queue_depth.to_bits(),
            "time-weighted depth must agree"
        );
    }

    #[test]
    fn policy_lookup_mirrors_scheduler_names() {
        for (arg, want) in
            [("fifo", "fifo"), ("rr", "round-robin"), ("batch", "dynamic-batch")]
        {
            assert_eq!(NaivePolicy::by_name(arg).unwrap().name(), want);
        }
        assert!(NaivePolicy::by_name("lifo").is_none());
    }
}
