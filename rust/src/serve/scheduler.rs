//! Dispatch policies: which queued requests a free cluster runs next.
//!
//! A [`Scheduler`] sees the waiting queue through a [`QueueView`] —
//! O(1) head / per-class (= per-seq-len-bucket) count / pinned-shard
//! lookups instead of the full-slice scans of the pre-optimization
//! design — and answers with a [`Selection`]: which run of requests the
//! fleet should take, in O(batch), preserving exact head-of-line
//! arrival-order semantics. A batch is always **one class** (one
//! compiled command stream executed back-to-back), which the selection
//! vocabulary makes structurally impossible to violate: there is no way
//! to express a mixed-class batch.
//!
//! Three built-in policies:
//!
//! - [`Fifo`] — strict arrival order, one request per dispatch. The
//!   baseline every serving paper compares against.
//! - [`RoundRobin`] — static sharding: request `id % n_clusters` belongs
//!   to that cluster. Perfectly fair, but a burst of one class can
//!   strand work behind one shard while others idle.
//! - [`DynamicBatch`] — head-of-line seq-len-bucket batching: take the
//!   oldest waiter's class (each class is one seq-len bucket — the
//!   padded sequence length its command stream is compiled for) and
//!   coalesce its head run into one batch. Coalescing converts repeated
//!   cold dispatches into pipelined steady-state iterations and removes
//!   class switches (weight re-staging), which is where its throughput
//!   edge on bursty multi-class traffic comes from. The batch is capped
//!   both by `max_batch` and by an even fleet share of the bucket, so a
//!   draining queue degrades to single fifo-like dispatches instead of
//!   hoarding the last requests on one shard.

pub use super::queue::QueueView;

/// One waiting request as the queue stores it.
#[derive(Debug, Clone)]
pub struct Queued {
    pub id: usize,
    /// Index into the workload's class list.
    pub class: usize,
    /// Seq-len bucket of the class (its padded sequence length).
    pub bucket: usize,
    /// Arrival cycle.
    pub arrival: u64,
}

/// What a scheduler asks the fleet to dispatch on one free cluster.
/// The fleet performs the take (O(batch)); arrival order within the
/// selected run is preserved by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Selection {
    /// Leave this cluster idle until the next event.
    Idle,
    /// Dispatch the `take` oldest waiters of `class` as one batch
    /// (clamped to the class's live count; `take == 0` is `Idle`).
    Batch { class: usize, take: usize },
    /// Dispatch the oldest waiter pinned to this cluster
    /// (`id % n_clusters == cluster`), or nothing if none waits.
    Pinned,
}

/// A dispatch policy over the [`QueueView`] read surface.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Pick the batch for `cluster`, which is free at `now`. `free` is
    /// the number of currently free clusters (including this one),
    /// `n_clusters` the fleet size.
    fn select(
        &mut self,
        now: u64,
        queue: &QueueView,
        cluster: usize,
        free: usize,
        n_clusters: usize,
    ) -> Selection;
}

/// Strict arrival order, one request per dispatch.
pub struct Fifo;

impl Scheduler for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn select(
        &mut self,
        _now: u64,
        queue: &QueueView,
        _cluster: usize,
        _free: usize,
        _n_clusters: usize,
    ) -> Selection {
        // the overall head is its class's head, so a take of one from
        // that class is exactly the oldest waiter
        match queue.head() {
            Some(h) => Selection::Batch { class: h.class, take: 1 },
            None => Selection::Idle,
        }
    }
}

/// Static sharding: request `id % n_clusters` is pinned to that cluster.
pub struct RoundRobin;

impl Scheduler for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn select(
        &mut self,
        _now: u64,
        queue: &QueueView,
        cluster: usize,
        _free: usize,
        _n_clusters: usize,
    ) -> Selection {
        if queue.shard_head(cluster).is_some() {
            Selection::Pinned
        } else {
            Selection::Idle
        }
    }
}

/// Head-of-line seq-len-bucket batching (see the module docs).
pub struct DynamicBatch {
    /// Upper bound on one batch (HWPE context + L2 staging pragmatics).
    pub max_batch: usize,
}

impl DynamicBatch {
    pub fn new(max_batch: usize) -> DynamicBatch {
        DynamicBatch { max_batch: max_batch.max(1) }
    }
}

impl Default for DynamicBatch {
    fn default() -> Self {
        DynamicBatch::new(8)
    }
}

impl Scheduler for DynamicBatch {
    fn name(&self) -> &'static str {
        "dynamic-batch"
    }

    fn select(
        &mut self,
        _now: u64,
        queue: &QueueView,
        _cluster: usize,
        _free: usize,
        n_clusters: usize,
    ) -> Selection {
        // the oldest waiter picks the bucket (head-of-line, Fifo-fair);
        // its class's live count is an O(1) lookup, where the flat-queue
        // design scanned and collected the whole backlog per dispatch
        let Some(head) = queue.head() else {
            return Selection::Idle;
        };
        let class = head.class;
        // spread over the whole fleet: take at most an even share of
        // the bucket so a draining queue degrades to single dispatches
        // (fifo-like tail) instead of hoarding the last requests on one
        // shard while the others idle
        let share = queue.class_len(class).div_ceil(n_clusters.max(1));
        let take = share.min(self.max_batch).max(1);
        Selection::Batch { class, take }
    }
}

/// CLI lookup: `fifo`, `rr`/`round-robin`, `batch`/`dynamic-batch`.
pub fn by_name(name: &str) -> Option<Box<dyn Scheduler>> {
    match name {
        "fifo" => Some(Box::new(Fifo)),
        "rr" | "round-robin" => Some(Box::new(RoundRobin)),
        "batch" | "dynamic-batch" => Some(Box::new(DynamicBatch::default())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(id: usize, class: usize) -> Queued {
        Queued { id, class, bucket: 128 * (class + 1), arrival: id as u64 }
    }

    fn view(requests: &[(usize, usize)], n_shards: usize) -> QueueView {
        let n_classes = requests.iter().map(|&(_, c)| c + 1).max().unwrap_or(1);
        let mut v = QueueView::new(n_classes, n_shards);
        for &(id, class) in requests {
            v.push(q(id, class));
        }
        v
    }

    #[test]
    fn fifo_takes_the_head() {
        let mut s = Fifo;
        let empty = QueueView::new(2, 1);
        assert_eq!(s.select(0, &empty, 0, 1, 1), Selection::Idle);
        let v = view(&[(0, 1), (1, 0)], 1);
        // head is id 0 (class 1): one request of that class
        assert_eq!(s.select(0, &v, 0, 1, 1), Selection::Batch { class: 1, take: 1 });
    }

    #[test]
    fn round_robin_pins_requests_to_their_shard() {
        let mut s = RoundRobin;
        let v = view(&[(0, 0), (1, 0), (2, 0), (5, 1)], 2);
        assert_eq!(s.select(0, &v, 0, 2, 2), Selection::Pinned);
        assert_eq!(s.select(0, &v, 1, 2, 2), Selection::Pinned); // ids 1, 5
        // a shard with no assigned work stays idle
        let only_even = view(&[(0, 0), (2, 0)], 2);
        assert_eq!(only_even.shard_len(1), 0);
        assert_eq!(s.select(0, &only_even, 1, 2, 2), Selection::Idle);
    }

    #[test]
    fn dynamic_batch_coalesces_the_head_bucket() {
        let mut s = DynamicBatch::new(8);
        // head class 0; co-bucketed ids 0, 2, 3 coalesce past the
        // class-1 request at position 1
        let v = view(&[(0, 0), (1, 1), (2, 0), (3, 0)], 1);
        assert_eq!(s.select(0, &v, 0, 1, 1), Selection::Batch { class: 0, take: 3 });
        // spread over a 2-cluster fleet: take only the even share
        assert_eq!(s.select(0, &v, 0, 2, 2), Selection::Batch { class: 0, take: 2 });
        // max_batch caps the batch
        let mut tight = DynamicBatch::new(2);
        assert_eq!(tight.select(0, &v, 0, 1, 1), Selection::Batch { class: 0, take: 2 });
    }

    #[test]
    fn by_name_resolves_all_policies() {
        for (name, want) in
            [("fifo", "fifo"), ("rr", "round-robin"), ("batch", "dynamic-batch")]
        {
            assert_eq!(by_name(name).unwrap().name(), want);
        }
        assert!(by_name("lifo").is_none());
    }
}
