//! Dispatch policies: which queued requests a free cluster runs next.
//!
//! A [`Scheduler`] sees the waiting queue (always in arrival order) and
//! returns the indices of the requests to dispatch as **one batch** on
//! the free cluster — all of one class, because a batch executes a
//! single compiled command stream back-to-back. An empty selection
//! leaves the cluster idle until the next event.
//!
//! Three built-in policies:
//!
//! - [`Fifo`] — strict arrival order, one request per dispatch. The
//!   baseline every serving paper compares against.
//! - [`RoundRobin`] — static sharding: request `id % n_clusters` belongs
//!   to that cluster. Perfectly fair, but a burst of one class can
//!   strand work behind one shard while others idle.
//! - [`DynamicBatch`] — head-of-line seq-len-bucket batching: take the
//!   oldest waiter's bucket, narrowed to its class (a batch executes
//!   one compiled command stream), and coalesce those requests into
//!   one batch. Coalescing converts repeated cold dispatches into
//!   pipelined steady-state iterations and removes class switches
//!   (weight re-staging), which is where its throughput edge on bursty
//!   multi-class traffic comes from. The batch is capped both by
//!   `max_batch` and by an even share of the bucket over the whole
//!   fleet, so a draining queue degrades to single fifo-like dispatches
//!   instead of hoarding the last requests on one shard.

/// One waiting request as schedulers see it.
#[derive(Debug, Clone)]
pub struct Queued {
    pub id: usize,
    /// Index into the workload's class list.
    pub class: usize,
    /// Seq-len bucket of the class (its padded sequence length).
    pub bucket: usize,
    /// Arrival cycle.
    pub arrival: u64,
}

/// A dispatch policy. Implementations must return indices into `queue`
/// that all share one class (the fleet debug-asserts and defensively
/// filters mixed selections).
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Pick the batch for `cluster`, which is free at `now`. `free` is
    /// the number of currently free clusters (including this one),
    /// `n_clusters` the fleet size. Empty = leave this cluster idle.
    fn select(
        &mut self,
        now: u64,
        queue: &[Queued],
        cluster: usize,
        free: usize,
        n_clusters: usize,
    ) -> Vec<usize>;
}

/// Strict arrival order, one request per dispatch.
pub struct Fifo;

impl Scheduler for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn select(
        &mut self,
        _now: u64,
        queue: &[Queued],
        _cluster: usize,
        _free: usize,
        _n_clusters: usize,
    ) -> Vec<usize> {
        if queue.is_empty() {
            Vec::new()
        } else {
            vec![0]
        }
    }
}

/// Static sharding: request `id % n_clusters` is pinned to that cluster.
pub struct RoundRobin;

impl Scheduler for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn select(
        &mut self,
        _now: u64,
        queue: &[Queued],
        cluster: usize,
        _free: usize,
        n_clusters: usize,
    ) -> Vec<usize> {
        queue
            .iter()
            .position(|q| q.id % n_clusters.max(1) == cluster)
            .map(|i| vec![i])
            .unwrap_or_default()
    }
}

/// Head-of-line seq-len-bucket batching (see the module docs).
pub struct DynamicBatch {
    /// Upper bound on one batch (HWPE context + L2 staging pragmatics).
    pub max_batch: usize,
}

impl DynamicBatch {
    pub fn new(max_batch: usize) -> DynamicBatch {
        DynamicBatch { max_batch: max_batch.max(1) }
    }
}

impl Default for DynamicBatch {
    fn default() -> Self {
        DynamicBatch::new(8)
    }
}

impl Scheduler for DynamicBatch {
    fn name(&self) -> &'static str {
        "dynamic-batch"
    }

    fn select(
        &mut self,
        _now: u64,
        queue: &[Queued],
        _cluster: usize,
        _free: usize,
        n_clusters: usize,
    ) -> Vec<usize> {
        let Some(head) = queue.first() else {
            return Vec::new();
        };
        // the oldest waiter picks the seq-len bucket (head-of-line,
        // Fifo-fair), narrowed to its class: a batch executes one
        // command stream, so same-bucket requests of a different class
        // (same padded seq, different network/depth) wait their turn
        let idx: Vec<usize> = queue
            .iter()
            .enumerate()
            .filter(|(_, q)| q.bucket == head.bucket && q.class == head.class)
            .map(|(i, _)| i)
            .collect();
        // spread over the whole fleet: take at most an even share of
        // the bucket so a draining queue degrades to single dispatches
        // (fifo-like tail) instead of hoarding the last requests on one
        // shard while the others idle
        let share = idx.len().div_ceil(n_clusters.max(1));
        let k = share.min(self.max_batch).max(1);
        idx[..k.min(idx.len())].to_vec()
    }
}

/// CLI lookup: `fifo`, `rr`/`round-robin`, `batch`/`dynamic-batch`.
pub fn by_name(name: &str) -> Option<Box<dyn Scheduler>> {
    match name {
        "fifo" => Some(Box::new(Fifo)),
        "rr" | "round-robin" => Some(Box::new(RoundRobin)),
        "batch" | "dynamic-batch" => Some(Box::new(DynamicBatch::default())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(id: usize, class: usize) -> Queued {
        Queued { id, class, bucket: 128 * (class + 1), arrival: id as u64 }
    }

    #[test]
    fn fifo_takes_the_head() {
        let mut s = Fifo;
        assert!(s.select(0, &[], 0, 1, 1).is_empty());
        assert_eq!(s.select(0, &[q(0, 1), q(1, 0)], 0, 1, 1), vec![0]);
    }

    #[test]
    fn round_robin_pins_requests_to_their_shard() {
        let mut s = RoundRobin;
        let queue = [q(0, 0), q(1, 0), q(2, 0), q(5, 1)];
        assert_eq!(s.select(0, &queue, 0, 2, 2), vec![0]);
        assert_eq!(s.select(0, &queue, 1, 2, 2), vec![1]); // id 1 % 2 == 1
        // a shard with no assigned work stays idle
        let only_even = [q(0, 0), q(2, 0)];
        assert!(s.select(0, &only_even, 1, 2, 2).is_empty());
    }

    #[test]
    fn dynamic_batch_coalesces_the_head_bucket() {
        let mut s = DynamicBatch::new(8);
        // head class 0; co-bucketed ids 0, 2, 3 coalesce past the class-1
        // request at position 1
        let queue = [q(0, 0), q(1, 1), q(2, 0), q(3, 0)];
        assert_eq!(s.select(0, &queue, 0, 1, 1), vec![0, 2, 3]);
        // spread over a 2-cluster fleet: take only the even share
        assert_eq!(s.select(0, &queue, 0, 2, 2), vec![0, 2]);
        // max_batch caps the batch
        let mut tight = DynamicBatch::new(2);
        assert_eq!(tight.select(0, &queue, 0, 1, 1), vec![0, 2]);
    }

    #[test]
    fn by_name_resolves_all_policies() {
        for (name, want) in
            [("fifo", "fifo"), ("rr", "round-robin"), ("batch", "dynamic-batch")]
        {
            assert_eq!(by_name(name).unwrap().name(), want);
        }
        assert!(by_name("lifo").is_none());
    }
}
