//! Dispatch policies: which queued requests a free cluster runs next.
//!
//! A [`Scheduler`] sees the waiting queue through a [`QueueView`] —
//! O(1) head / per-class (= per-seq-len-bucket) count / pinned-shard
//! lookups instead of the full-slice scans of the pre-optimization
//! design — and answers with a [`Selection`]: which run of requests the
//! fleet should take, in O(batch), preserving exact head-of-line
//! arrival-order semantics. A batch is always **one class** (one
//! compiled command stream executed back-to-back), which the selection
//! vocabulary makes structurally impossible to violate: there is no way
//! to express a mixed-class batch.
//!
//! Five built-in policies:
//!
//! - [`Fifo`] — strict arrival order, one request per dispatch. The
//!   baseline every serving paper compares against.
//! - [`RoundRobin`] — static sharding: request `id % n_clusters` belongs
//!   to that cluster. Perfectly fair, but a burst of one class can
//!   strand work behind one shard while others idle.
//! - [`DynamicBatch`] — head-of-line seq-len-bucket batching: take the
//!   oldest waiter's class (each class is one seq-len bucket — the
//!   padded sequence length its command stream is compiled for) and
//!   coalesce its head run into one batch. Coalescing converts repeated
//!   cold dispatches into pipelined steady-state iterations and removes
//!   class switches (weight re-staging), which is where its throughput
//!   edge on bursty multi-class traffic comes from. The batch is capped
//!   both by `max_batch` and by an even fleet share of the bucket, so a
//!   draining queue degrades to single fifo-like dispatches instead of
//!   hoarding the last requests on one shard.
//! - [`Wfq`] — weighted-fair queueing across tenants: every tenant owns
//!   a virtual-time clock advanced by the (bucket-weighted) work it has
//!   been served, and dispatch always goes to the backlogged tenant
//!   whose clock trails furthest behind. A tenant that idles has its
//!   clock floored at the system virtual time on return, so sleeping
//!   never banks unbounded credit. All-integer, ties broken by tenant
//!   index — fully deterministic.
//! - [`Drf`] — dominant-resource fairness across tenants over two
//!   delivered resources (request slots and bucket-weighted compute):
//!   dispatch goes to the backlogged tenant whose *dominant* share —
//!   the larger of its two resource shares — is smallest, the DRF rule
//!   that degenerates to max-min fairness when everyone's mix matches.
//!
//! Both fairness policies batch within the chosen tenant exactly like
//! [`DynamicBatch`] does within the whole queue (head class of that
//! tenant, even fleet share, `max_batch` cap), so fairness costs
//! throughput only when the tenant mix forces extra class switches.

pub use super::queue::QueueView;

/// One waiting request as the queue stores it.
#[derive(Debug, Clone)]
pub struct Queued {
    pub id: usize,
    /// Index into the workload's class list.
    pub class: usize,
    /// Seq-len bucket of the class (its padded sequence length).
    pub bucket: usize,
    /// Arrival cycle.
    pub arrival: u64,
    /// Tenant the request belongs to (0 for synthetic workloads).
    pub tenant: usize,
}

/// What a scheduler asks the fleet to dispatch on one free cluster.
/// The fleet performs the take (O(batch)); arrival order within the
/// selected run is preserved by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Selection {
    /// Leave this cluster idle until the next event.
    Idle,
    /// Dispatch the `take` oldest waiters of `class` as one batch
    /// (clamped to the class's live count; `take == 0` is `Idle`).
    Batch { class: usize, take: usize },
    /// Dispatch the oldest waiter pinned to this cluster
    /// (`id % n_clusters == cluster`), or nothing if none waits.
    Pinned,
    /// Dispatch the `take` oldest waiters of `class` belonging to
    /// `tenant` as one batch — the fairness-aware policies' selection
    /// (head-of-line within the (tenant, class) ring).
    TenantBatch { tenant: usize, class: usize, take: usize },
}

/// A dispatch policy over the [`QueueView`] read surface.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Pick the batch for `cluster`, which is free at `now`. `free` is
    /// the number of currently free clusters (including this one),
    /// `n_clusters` the fleet size.
    fn select(
        &mut self,
        now: u64,
        queue: &QueueView,
        cluster: usize,
        free: usize,
        n_clusters: usize,
    ) -> Selection;
}

/// Strict arrival order, one request per dispatch.
pub struct Fifo;

impl Scheduler for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn select(
        &mut self,
        _now: u64,
        queue: &QueueView,
        _cluster: usize,
        _free: usize,
        _n_clusters: usize,
    ) -> Selection {
        // the overall head is its class's head, so a take of one from
        // that class is exactly the oldest waiter
        match queue.head() {
            Some(h) => Selection::Batch { class: h.class, take: 1 },
            None => Selection::Idle,
        }
    }
}

/// Static sharding: request `id % n_clusters` is pinned to that cluster.
pub struct RoundRobin;

impl Scheduler for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn select(
        &mut self,
        _now: u64,
        queue: &QueueView,
        cluster: usize,
        _free: usize,
        _n_clusters: usize,
    ) -> Selection {
        if queue.shard_head(cluster).is_some() {
            Selection::Pinned
        } else {
            Selection::Idle
        }
    }
}

/// Head-of-line seq-len-bucket batching (see the module docs).
pub struct DynamicBatch {
    /// Upper bound on one batch (HWPE context + L2 staging pragmatics).
    pub max_batch: usize,
}

impl DynamicBatch {
    pub fn new(max_batch: usize) -> DynamicBatch {
        DynamicBatch { max_batch: max_batch.max(1) }
    }
}

impl Default for DynamicBatch {
    fn default() -> Self {
        DynamicBatch::new(8)
    }
}

impl Scheduler for DynamicBatch {
    fn name(&self) -> &'static str {
        "dynamic-batch"
    }

    fn select(
        &mut self,
        _now: u64,
        queue: &QueueView,
        _cluster: usize,
        _free: usize,
        n_clusters: usize,
    ) -> Selection {
        // the oldest waiter picks the bucket (head-of-line, Fifo-fair);
        // its class's live count is an O(1) lookup, where the flat-queue
        // design scanned and collected the whole backlog per dispatch
        let Some(head) = queue.head() else {
            return Selection::Idle;
        };
        let class = head.class;
        // spread over the whole fleet: take at most an even share of
        // the bucket so a draining queue degrades to single dispatches
        // (fifo-like tail) instead of hoarding the last requests on one
        // shard while the others idle
        let share = queue.class_len(class).div_ceil(n_clusters.max(1));
        let take = share.min(self.max_batch).max(1);
        Selection::Batch { class, take }
    }
}

/// Batch within one tenant the way [`DynamicBatch`] batches within the
/// whole queue: the tenant's oldest waiter picks the class, the take is
/// capped by an even fleet share of that (tenant, class) backlog and by
/// `max_batch`. Returns `(class, bucket, take)`.
fn tenant_batch(
    queue: &QueueView,
    tenant: usize,
    max_batch: usize,
    n_clusters: usize,
) -> Option<(usize, usize, usize)> {
    let head = queue.tenant_head(tenant)?;
    let class = head.class;
    let bucket = head.bucket;
    let share = queue.tenant_class_len(tenant, class).div_ceil(n_clusters.max(1));
    Some((class, bucket, share.min(max_batch).max(1)))
}

/// Weighted-fair queueing across tenants (see the module docs): serve
/// the backlogged tenant with the least virtual time, then advance its
/// clock by the bucket-weighted work dispatched divided by its weight.
pub struct Wfq {
    /// Upper bound on one batch, as in [`DynamicBatch`].
    pub max_batch: usize,
    /// Per-tenant relative service weights; missing tenants default
    /// to weight 1. A tenant with weight `w` receives a `w / Σw` share
    /// of the fleet under sustained contention.
    pub weights: Vec<u64>,
    /// Per-tenant virtual time: weighted work served so far.
    vtime: Vec<u64>,
    /// System virtual time: the floor applied to a tenant returning
    /// from idle, so idling never banks unbounded credit.
    vnow: u64,
}

impl Wfq {
    pub fn new(max_batch: usize) -> Wfq {
        Wfq { max_batch: max_batch.max(1), weights: Vec::new(), vtime: Vec::new(), vnow: 0 }
    }

    /// Set per-tenant weights (index = tenant id).
    pub fn with_weights(mut self, weights: Vec<u64>) -> Wfq {
        self.weights = weights;
        self
    }

    fn weight(&self, tenant: usize) -> u64 {
        self.weights.get(tenant).copied().unwrap_or(1).max(1)
    }
}

impl Default for Wfq {
    fn default() -> Self {
        Wfq::new(8)
    }
}

impl Scheduler for Wfq {
    fn name(&self) -> &'static str {
        "wfq"
    }

    fn select(
        &mut self,
        _now: u64,
        queue: &QueueView,
        _cluster: usize,
        _free: usize,
        n_clusters: usize,
    ) -> Selection {
        if self.vtime.len() < queue.n_tenants() {
            self.vtime.resize(queue.n_tenants(), 0);
        }
        // floor returning tenants at the system virtual time (the
        // minimum clock among the backlogged set never moves backwards)
        let backlogged: Vec<usize> =
            (0..queue.n_tenants()).filter(|&t| queue.tenant_len(t) > 0).collect();
        if let Some(&min_v) = backlogged.iter().map(|&t| &self.vtime[t]).min() {
            self.vnow = self.vnow.max(min_v);
        }
        for &t in &backlogged {
            self.vtime[t] = self.vtime[t].max(self.vnow);
        }
        // least virtual time wins; ties go to the lowest tenant index
        let Some(&tenant) = backlogged.iter().min_by_key(|&&t| (self.vtime[t], t))
        else {
            return Selection::Idle;
        };
        let Some((class, bucket, take)) =
            tenant_batch(queue, tenant, self.max_batch, n_clusters)
        else {
            return Selection::Idle;
        };
        // charge the dispatched work to the tenant's clock up front —
        // deterministic, and the fleet takes exactly what we sized
        self.vtime[tenant] += (take * bucket) as u64 / self.weight(tenant);
        Selection::TenantBatch { tenant, class, take }
    }
}

/// DRF-style dominant-share scheduling (see the module docs): serve the
/// backlogged tenant whose dominant resource share is smallest.
pub struct Drf {
    /// Upper bound on one batch, as in [`DynamicBatch`].
    pub max_batch: usize,
    /// Request slots dispatched per tenant.
    reqs: Vec<u64>,
    /// Bucket-weighted compute dispatched per tenant.
    work: Vec<u64>,
}

impl Drf {
    pub fn new(max_batch: usize) -> Drf {
        Drf { max_batch: max_batch.max(1), reqs: Vec::new(), work: Vec::new() }
    }
}

impl Default for Drf {
    fn default() -> Self {
        Drf::new(8)
    }
}

impl Scheduler for Drf {
    fn name(&self) -> &'static str {
        "drf"
    }

    fn select(
        &mut self,
        _now: u64,
        queue: &QueueView,
        _cluster: usize,
        _free: usize,
        n_clusters: usize,
    ) -> Selection {
        if self.reqs.len() < queue.n_tenants() {
            self.reqs.resize(queue.n_tenants(), 0);
            self.work.resize(queue.n_tenants(), 0);
        }
        // dominant share of tenant t = max(reqs[t]/ΣR, work[t]/ΣW).
        // With the common denominator ΣR·ΣW the comparison reduces to
        // integer cross-products — no floats, no ties from rounding.
        let total_r: u64 = self.reqs.iter().sum();
        let total_w: u64 = self.work.iter().sum();
        let dominant = |t: usize| -> u128 {
            let r = self.reqs[t] as u128 * total_w as u128;
            let w = self.work[t] as u128 * total_r as u128;
            r.max(w)
        };
        let Some(tenant) = (0..queue.n_tenants())
            .filter(|&t| queue.tenant_len(t) > 0)
            .min_by_key(|&t| (dominant(t), t))
        else {
            return Selection::Idle;
        };
        let Some((class, bucket, take)) =
            tenant_batch(queue, tenant, self.max_batch, n_clusters)
        else {
            return Selection::Idle;
        };
        self.reqs[tenant] += take as u64;
        self.work[tenant] += (take * bucket) as u64;
        Selection::TenantBatch { tenant, class, take }
    }
}

/// CLI lookup: `fifo`, `rr`/`round-robin`, `batch`/`dynamic-batch`,
/// `wfq`, `drf`.
pub fn by_name(name: &str) -> Option<Box<dyn Scheduler>> {
    match name {
        "fifo" => Some(Box::new(Fifo)),
        "rr" | "round-robin" => Some(Box::new(RoundRobin)),
        "batch" | "dynamic-batch" => Some(Box::new(DynamicBatch::default())),
        "wfq" => Some(Box::new(Wfq::default())),
        "drf" => Some(Box::new(Drf::default())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(id: usize, class: usize) -> Queued {
        Queued { id, class, bucket: 128 * (class + 1), arrival: id as u64, tenant: 0 }
    }

    fn view(requests: &[(usize, usize)], n_shards: usize) -> QueueView {
        let n_classes = requests.iter().map(|&(_, c)| c + 1).max().unwrap_or(1);
        let mut v = QueueView::new(n_classes, n_shards, 1);
        for &(id, class) in requests {
            v.push(q(id, class));
        }
        v
    }

    /// Tenant-tagged view: (id, class, tenant) triples.
    fn tenant_view(requests: &[(usize, usize, usize)], n_tenants: usize) -> QueueView {
        let n_classes = requests.iter().map(|&(_, c, _)| c + 1).max().unwrap_or(1);
        let mut v = QueueView::new(n_classes, 1, n_tenants);
        for &(id, class, tenant) in requests {
            v.push(Queued {
                id,
                class,
                bucket: 128 * (class + 1),
                arrival: id as u64,
                tenant,
            });
        }
        v
    }

    #[test]
    fn fifo_takes_the_head() {
        let mut s = Fifo;
        let empty = QueueView::new(2, 1, 1);
        assert_eq!(s.select(0, &empty, 0, 1, 1), Selection::Idle);
        let v = view(&[(0, 1), (1, 0)], 1);
        // head is id 0 (class 1): one request of that class
        assert_eq!(s.select(0, &v, 0, 1, 1), Selection::Batch { class: 1, take: 1 });
    }

    #[test]
    fn round_robin_pins_requests_to_their_shard() {
        let mut s = RoundRobin;
        let v = view(&[(0, 0), (1, 0), (2, 0), (5, 1)], 2);
        assert_eq!(s.select(0, &v, 0, 2, 2), Selection::Pinned);
        assert_eq!(s.select(0, &v, 1, 2, 2), Selection::Pinned); // ids 1, 5
        // a shard with no assigned work stays idle
        let only_even = view(&[(0, 0), (2, 0)], 2);
        assert_eq!(only_even.shard_len(1), 0);
        assert_eq!(s.select(0, &only_even, 1, 2, 2), Selection::Idle);
    }

    #[test]
    fn dynamic_batch_coalesces_the_head_bucket() {
        let mut s = DynamicBatch::new(8);
        // head class 0; co-bucketed ids 0, 2, 3 coalesce past the
        // class-1 request at position 1
        let v = view(&[(0, 0), (1, 1), (2, 0), (3, 0)], 1);
        assert_eq!(s.select(0, &v, 0, 1, 1), Selection::Batch { class: 0, take: 3 });
        // spread over a 2-cluster fleet: take only the even share
        assert_eq!(s.select(0, &v, 0, 2, 2), Selection::Batch { class: 0, take: 2 });
        // max_batch caps the batch
        let mut tight = DynamicBatch::new(2);
        assert_eq!(tight.select(0, &v, 0, 1, 1), Selection::Batch { class: 0, take: 2 });
    }

    #[test]
    fn wfq_alternates_between_equal_weight_tenants() {
        let mut s = Wfq::new(1);
        // tenant 0 floods the queue; tenant 1 has one waiter per round.
        // with equal weights the clocks must alternate dispatch
        let v = tenant_view(&[(0, 0, 0), (1, 0, 0), (2, 0, 0), (3, 0, 1)], 2);
        let first = s.select(0, &v, 0, 1, 1);
        let Selection::TenantBatch { tenant: t0, take: 1, .. } = first else {
            panic!("expected a tenant batch, got {first:?}");
        };
        // whoever went first is now behind: the other tenant goes next
        let second = s.select(0, &v, 0, 1, 1);
        let Selection::TenantBatch { tenant: t1, .. } = second else {
            panic!("expected a tenant batch, got {second:?}");
        };
        assert_ne!(t0, t1, "equal-weight tenants alternate under contention");
        // empty queue is idle
        let empty = QueueView::new(1, 1, 2);
        assert_eq!(s.select(0, &empty, 0, 1, 1), Selection::Idle);
    }

    #[test]
    fn wfq_weights_bias_the_service_ratio() {
        // tenant 0 carries weight 3: over 4 single-request dispatches
        // from a saturated queue it must win 3
        let mut s = Wfq::new(1).with_weights(vec![3, 1]);
        let reqs: Vec<(usize, usize, usize)> =
            (0..16).map(|id| (id, 0, id % 2)).collect();
        let v = tenant_view(&reqs, 2);
        let mut wins = [0usize; 2];
        for _ in 0..4 {
            match s.select(0, &v, 0, 1, 1) {
                Selection::TenantBatch { tenant, .. } => wins[tenant] += 1,
                other => panic!("expected a tenant batch, got {other:?}"),
            }
        }
        assert_eq!(wins, [3, 1], "weight-3 tenant wins 3 of 4 dispatches");
    }

    #[test]
    fn drf_picks_the_smallest_dominant_share() {
        let mut s = Drf::new(1);
        let v = tenant_view(&[(0, 0, 0), (1, 0, 1), (2, 0, 0), (3, 0, 1)], 2);
        // fresh state: everyone at zero share, tie broken by index
        assert!(matches!(
            s.select(0, &v, 0, 1, 1),
            Selection::TenantBatch { tenant: 0, .. }
        ));
        // tenant 0 now holds all delivered resources: tenant 1 is next
        assert!(matches!(
            s.select(0, &v, 0, 1, 1),
            Selection::TenantBatch { tenant: 1, .. }
        ));
        let empty = QueueView::new(1, 1, 2);
        assert_eq!(s.select(0, &empty, 0, 1, 1), Selection::Idle);
    }

    #[test]
    fn fairness_batches_stay_within_one_tenant_class_ring() {
        // tenant 1's head class has a 3-deep backlog; a single-cluster
        // fleet coalesces it like DynamicBatch but never crosses tenants
        let mut s = Wfq::new(8);
        let v = tenant_view(&[(0, 0, 1), (1, 0, 0), (2, 0, 1), (3, 0, 1)], 2);
        let sel = s.select(0, &v, 0, 1, 1);
        match sel {
            Selection::TenantBatch { tenant, class: 0, take } => {
                assert!(take <= v.tenant_class_len(tenant, 0));
            }
            other => panic!("expected a tenant batch, got {other:?}"),
        }
    }

    #[test]
    fn by_name_resolves_all_policies() {
        for (name, want) in [
            ("fifo", "fifo"),
            ("rr", "round-robin"),
            ("batch", "dynamic-batch"),
            ("wfq", "wfq"),
            ("drf", "drf"),
        ] {
            assert_eq!(by_name(name).unwrap().name(), want);
        }
        assert!(by_name("lifo").is_none());
    }
}
