//! Dispatch policies: which queued requests a free cluster runs next.
//!
//! A [`Scheduler`] sees the waiting queue through a [`QueueView`] —
//! O(1) head / per-class (= per-seq-len-bucket) count / pinned-shard
//! lookups instead of the full-slice scans of the pre-optimization
//! design — and answers with a [`Selection`]: which run of requests the
//! fleet should take, in O(batch), preserving exact head-of-line
//! arrival-order semantics. A batch is always **one class** (one
//! compiled command stream executed back-to-back), which the selection
//! vocabulary makes structurally impossible to violate: there is no way
//! to express a mixed-class batch.
//!
//! Scheduling outcomes are observable end to end when the run carries
//! an event recorder ([`crate::obs`]): every selection materializes as
//! `Enqueued` → `Dispatched{shard, net_delay, queue_wait, span}`
//! events, so queue-wait attribution per policy falls out of the
//! exported trace rather than ad-hoc instrumentation.
//!
//! Five built-in policies:
//!
//! - [`Fifo`] — strict arrival order, one request per dispatch. The
//!   baseline every serving paper compares against.
//! - [`RoundRobin`] — static sharding: request `id % n_clusters` belongs
//!   to that cluster. Perfectly fair, but a burst of one class can
//!   strand work behind one shard while others idle.
//! - [`DynamicBatch`] — head-of-line seq-len-bucket batching: take the
//!   oldest waiter's class (each class is one seq-len bucket — the
//!   padded sequence length its command stream is compiled for) and
//!   coalesce its head run into one batch. Coalescing converts repeated
//!   cold dispatches into pipelined steady-state iterations and removes
//!   class switches (weight re-staging), which is where its throughput
//!   edge on bursty multi-class traffic comes from. The batch is capped
//!   both by `max_batch` and by an even fleet share of the bucket, so a
//!   draining queue degrades to single fifo-like dispatches instead of
//!   hoarding the last requests on one shard.
//! - [`Wfq`] — weighted-fair queueing across tenants: every tenant owns
//!   a virtual-time clock advanced by the (bucket-weighted) work it has
//!   been served, and dispatch always goes to the backlogged tenant
//!   whose clock trails furthest behind. A tenant that idles has its
//!   clock floored at the system virtual time on return, so sleeping
//!   never banks unbounded credit. All-integer, ties broken by tenant
//!   index — fully deterministic.
//! - [`Drf`] — dominant-resource fairness across tenants over two
//!   delivered resources (request slots and bucket-weighted compute):
//!   dispatch goes to the backlogged tenant whose *dominant* share —
//!   the larger of its two resource shares — is smallest, the DRF rule
//!   that degenerates to max-min fairness when everyone's mix matches.
//!
//! Both fairness policies batch within the chosen tenant exactly like
//! [`DynamicBatch`] does within the whole queue (head class of that
//! tenant, even fleet share, `max_batch` cap), so fairness costs
//! throughput only when the tenant mix forces extra class switches.

use std::collections::BTreeSet;

use crate::net::Topology;

pub use super::queue::QueueView;

/// One waiting request as the queue stores it.
#[derive(Debug, Clone)]
pub struct Queued {
    pub id: usize,
    /// Index into the workload's class list.
    pub class: usize,
    /// Seq-len bucket of the class (its padded sequence length).
    pub bucket: usize,
    /// Admission cycle of this attempt — the cycle the entry joined
    /// the queue (a retry re-enters with its ready cycle here, keeping
    /// the queue's (arrival, id) push order intact).
    pub arrival: u64,
    /// Tenant the request belongs to (0 for synthetic workloads).
    pub tenant: usize,
    /// Original arrival cycle — end-to-end latency is measured from
    /// here. Equal to `arrival` for fresh requests.
    pub first_arrival: u64,
    /// Dispatch attempts that already failed (0 for fresh requests);
    /// the fault layer's retry budget counts against this.
    pub attempts: u32,
}

/// What a scheduler asks the fleet to dispatch on one free cluster.
/// The fleet performs the take (O(batch)); arrival order within the
/// selected run is preserved by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Selection {
    /// Leave this cluster idle until the next event.
    Idle,
    /// Dispatch the `take` oldest waiters of `class` as one batch
    /// (clamped to the class's live count; `take == 0` is `Idle`).
    Batch { class: usize, take: usize },
    /// Dispatch the oldest waiter pinned to this cluster
    /// (`id % n_clusters == cluster`), or nothing if none waits.
    Pinned,
    /// Dispatch the `take` oldest waiters of `class` belonging to
    /// `tenant` as one batch — the fairness-aware policies' selection
    /// (head-of-line within the (tenant, class) ring).
    TenantBatch { tenant: usize, class: usize, take: usize },
}

/// A dispatch policy over the [`QueueView`] read surface.
///
/// Beyond `select`, the engine feeds placement-aware policies three
/// defaulted no-op hooks — fleet attach, shard free/busy transitions,
/// and weight-residency changes — plus a *pure* `peek_class` probe.
/// The built-in policies ignore the hooks (they are placement-blind);
/// [`LocalityAware`] consumes all four to steer batches at the shards
/// already holding their weights.
pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Pick the batch for `cluster`, which is free at `now`. `free` is
    /// the number of currently free clusters (including this one),
    /// `n_clusters` the fleet size.
    fn select(
        &mut self,
        now: u64,
        queue: &QueueView,
        cluster: usize,
        free: usize,
        n_clusters: usize,
    ) -> Selection;

    /// Called once by the engine before the first event, with the
    /// fleet size — stateful policies size their tracking here.
    fn on_attach(&mut self, n_shards: usize) {
        let _ = n_shards;
    }

    /// Shard `shard` became free (`true`) or busy/parked (`false`).
    fn note_free(&mut self, shard: usize, free: bool) {
        let _ = (shard, free);
    }

    /// Shard `shard` now holds `class`'s staged weights (`None` =
    /// evicted, e.g. a parked shard powering down its copy).
    fn note_staged(&mut self, shard: usize, class: Option<usize>) {
        let _ = (shard, class);
    }

    /// The class this policy would dispatch next, **without mutating
    /// any accounting** — a pure replica of `select`'s choice, used by
    /// [`LocalityAware`] to plan placement before committing. `None`
    /// means the choice is not class-shaped (e.g. [`RoundRobin`]'s
    /// pinning) and the wrapper must pass offers straight through.
    fn peek_class(&self, queue: &QueueView) -> Option<usize> {
        let _ = queue;
        None
    }
}

/// Strict arrival order, one request per dispatch.
pub struct Fifo;

impl Scheduler for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn select(
        &mut self,
        _now: u64,
        queue: &QueueView,
        _cluster: usize,
        _free: usize,
        _n_clusters: usize,
    ) -> Selection {
        // the overall head is its class's head, so a take of one from
        // that class is exactly the oldest waiter
        match queue.head() {
            Some(h) => Selection::Batch { class: h.class, take: 1 },
            None => Selection::Idle,
        }
    }

    fn peek_class(&self, queue: &QueueView) -> Option<usize> {
        queue.head().map(|h| h.class)
    }
}

/// Static sharding: request `id % n_clusters` is pinned to that cluster.
pub struct RoundRobin;

impl Scheduler for RoundRobin {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn select(
        &mut self,
        _now: u64,
        queue: &QueueView,
        cluster: usize,
        _free: usize,
        _n_clusters: usize,
    ) -> Selection {
        if queue.shard_head(cluster).is_some() {
            Selection::Pinned
        } else {
            Selection::Idle
        }
    }
}

/// Head-of-line seq-len-bucket batching (see the module docs).
pub struct DynamicBatch {
    /// Upper bound on one batch (HWPE context + L2 staging pragmatics).
    pub max_batch: usize,
}

impl DynamicBatch {
    pub fn new(max_batch: usize) -> DynamicBatch {
        DynamicBatch { max_batch: max_batch.max(1) }
    }
}

impl Default for DynamicBatch {
    fn default() -> Self {
        DynamicBatch::new(8)
    }
}

impl Scheduler for DynamicBatch {
    fn name(&self) -> &'static str {
        "dynamic-batch"
    }

    fn select(
        &mut self,
        _now: u64,
        queue: &QueueView,
        _cluster: usize,
        _free: usize,
        n_clusters: usize,
    ) -> Selection {
        // the oldest waiter picks the bucket (head-of-line, Fifo-fair);
        // its class's live count is an O(1) lookup, where the flat-queue
        // design scanned and collected the whole backlog per dispatch
        let Some(head) = queue.head() else {
            return Selection::Idle;
        };
        let class = head.class;
        // spread over the whole fleet: take at most an even share of
        // the bucket so a draining queue degrades to single dispatches
        // (fifo-like tail) instead of hoarding the last requests on one
        // shard while the others idle
        let share = queue.class_len(class).div_ceil(n_clusters.max(1));
        let take = share.min(self.max_batch).max(1);
        Selection::Batch { class, take }
    }

    fn peek_class(&self, queue: &QueueView) -> Option<usize> {
        queue.head().map(|h| h.class)
    }
}

/// Batch within one tenant the way [`DynamicBatch`] batches within the
/// whole queue: the tenant's oldest waiter picks the class, the take is
/// capped by an even fleet share of that (tenant, class) backlog and by
/// `max_batch`. Returns `(class, bucket, take)`.
fn tenant_batch(
    queue: &QueueView,
    tenant: usize,
    max_batch: usize,
    n_clusters: usize,
) -> Option<(usize, usize, usize)> {
    let head = queue.tenant_head(tenant)?;
    let class = head.class;
    let bucket = head.bucket;
    let share = queue.tenant_class_len(tenant, class).div_ceil(n_clusters.max(1));
    Some((class, bucket, share.min(max_batch).max(1)))
}

/// Weighted-fair queueing across tenants (see the module docs): serve
/// the backlogged tenant with the least virtual time, then advance its
/// clock by the bucket-weighted work dispatched divided by its weight.
pub struct Wfq {
    /// Upper bound on one batch, as in [`DynamicBatch`].
    pub max_batch: usize,
    /// Per-tenant relative service weights; missing tenants default
    /// to weight 1. A tenant with weight `w` receives a `w / Σw` share
    /// of the fleet under sustained contention.
    pub weights: Vec<u64>,
    /// Per-tenant virtual time: weighted work served so far.
    vtime: Vec<u64>,
    /// System virtual time: the floor applied to a tenant returning
    /// from idle, so idling never banks unbounded credit.
    vnow: u64,
}

impl Wfq {
    pub fn new(max_batch: usize) -> Wfq {
        Wfq { max_batch: max_batch.max(1), weights: Vec::new(), vtime: Vec::new(), vnow: 0 }
    }

    /// Set per-tenant weights (index = tenant id).
    pub fn with_weights(mut self, weights: Vec<u64>) -> Wfq {
        self.weights = weights;
        self
    }

    fn weight(&self, tenant: usize) -> u64 {
        self.weights.get(tenant).copied().unwrap_or(1).max(1)
    }
}

impl Default for Wfq {
    fn default() -> Self {
        Wfq::new(8)
    }
}

impl Scheduler for Wfq {
    fn name(&self) -> &'static str {
        "wfq"
    }

    fn select(
        &mut self,
        _now: u64,
        queue: &QueueView,
        _cluster: usize,
        _free: usize,
        n_clusters: usize,
    ) -> Selection {
        if self.vtime.len() < queue.n_tenants() {
            self.vtime.resize(queue.n_tenants(), 0);
        }
        // floor returning tenants at the system virtual time (the
        // minimum clock among the backlogged set never moves backwards)
        let backlogged: Vec<usize> =
            (0..queue.n_tenants()).filter(|&t| queue.tenant_len(t) > 0).collect();
        if let Some(&min_v) = backlogged.iter().map(|&t| &self.vtime[t]).min() {
            self.vnow = self.vnow.max(min_v);
        }
        for &t in &backlogged {
            self.vtime[t] = self.vtime[t].max(self.vnow);
        }
        // least virtual time wins; ties go to the lowest tenant index
        let Some(&tenant) = backlogged.iter().min_by_key(|&&t| (self.vtime[t], t))
        else {
            return Selection::Idle;
        };
        let Some((class, bucket, take)) =
            tenant_batch(queue, tenant, self.max_batch, n_clusters)
        else {
            return Selection::Idle;
        };
        // charge the dispatched work to the tenant's clock up front —
        // deterministic, and the fleet takes exactly what we sized
        self.vtime[tenant] += (take * bucket) as u64 / self.weight(tenant);
        Selection::TenantBatch { tenant, class, take }
    }

    fn peek_class(&self, queue: &QueueView) -> Option<usize> {
        // pure replica of select's argmin: unsized vtime entries read
        // as 0 (what the resize would write) and the idle-return floor
        // is applied to the comparison key instead of the stored clock,
        // so the (vtime, tenant) ordering matches select exactly
        let vt = |t: usize| self.vtime.get(t).copied().unwrap_or(0);
        let backlogged: Vec<usize> =
            (0..queue.n_tenants()).filter(|&t| queue.tenant_len(t) > 0).collect();
        let min_v = backlogged.iter().map(|&t| vt(t)).min()?;
        let vnow = self.vnow.max(min_v);
        let tenant =
            backlogged.iter().copied().min_by_key(|&t| (vt(t).max(vnow), t))?;
        queue.tenant_head(tenant).map(|h| h.class)
    }
}

/// DRF-style dominant-share scheduling (see the module docs): serve the
/// backlogged tenant whose **weight-normalized** dominant resource
/// share is smallest. Weights generalize the rule the same way WFQ's
/// do: a tenant with weight `w` is entitled to a `w / Σw` dominant
/// share, so dispatch goes to the tenant minimizing `dominant(t) /
/// weight(t)` — compared by integer cross-multiplication, no floats.
/// All-ones weights (the default) reduce exactly to classic DRF.
pub struct Drf {
    /// Upper bound on one batch, as in [`DynamicBatch`].
    pub max_batch: usize,
    /// Per-tenant entitlement weights; missing tenants default to 1.
    pub weights: Vec<u64>,
    /// Request slots dispatched per tenant.
    reqs: Vec<u64>,
    /// Bucket-weighted compute dispatched per tenant.
    work: Vec<u64>,
}

impl Drf {
    pub fn new(max_batch: usize) -> Drf {
        Drf {
            max_batch: max_batch.max(1),
            weights: Vec::new(),
            reqs: Vec::new(),
            work: Vec::new(),
        }
    }

    /// Set per-tenant entitlement weights (index = tenant id).
    pub fn with_weights(mut self, weights: Vec<u64>) -> Drf {
        self.weights = weights;
        self
    }

    fn weight(&self, tenant: usize) -> u64 {
        self.weights.get(tenant).copied().unwrap_or(1).max(1)
    }

    /// The backlogged tenant with the smallest weight-normalized
    /// dominant share — the pure core shared by `select` and
    /// `peek_class`. `dominant(t)/weight(t) < dominant(b)/weight(b)`
    /// is compared as `dominant(t)·weight(b) < dominant(b)·weight(t)`
    /// (saturating: both sides capping at u128::MAX ties, keeping the
    /// earlier index, exactly like an exact tie). Strict `<` keeps the
    /// lower index on ties — the unweighted case therefore reproduces
    /// `min_by_key(|t| (dominant(t), t))` decision for decision.
    fn pick_tenant(&self, queue: &QueueView) -> Option<usize> {
        let reqs = |t: usize| self.reqs.get(t).copied().unwrap_or(0);
        let work = |t: usize| self.work.get(t).copied().unwrap_or(0);
        let total_r: u64 = self.reqs.iter().sum();
        let total_w: u64 = self.work.iter().sum();
        // dominant share of tenant t = max(reqs[t]/ΣR, work[t]/ΣW);
        // with the common denominator ΣR·ΣW it is an integer
        let dominant = |t: usize| -> u128 {
            let r = reqs(t) as u128 * total_w as u128;
            let w = work(t) as u128 * total_r as u128;
            r.max(w)
        };
        (0..queue.n_tenants())
            .filter(|&t| queue.tenant_len(t) > 0)
            .fold(None, |best, t| match best {
                None => Some(t),
                Some(b) => {
                    let challenger = dominant(t).saturating_mul(self.weight(b) as u128);
                    let incumbent = dominant(b).saturating_mul(self.weight(t) as u128);
                    if challenger < incumbent {
                        Some(t)
                    } else {
                        Some(b)
                    }
                }
            })
    }
}

impl Default for Drf {
    fn default() -> Self {
        Drf::new(8)
    }
}

impl Scheduler for Drf {
    fn name(&self) -> &'static str {
        "drf"
    }

    fn select(
        &mut self,
        _now: u64,
        queue: &QueueView,
        _cluster: usize,
        _free: usize,
        n_clusters: usize,
    ) -> Selection {
        if self.reqs.len() < queue.n_tenants() {
            self.reqs.resize(queue.n_tenants(), 0);
            self.work.resize(queue.n_tenants(), 0);
        }
        let Some(tenant) = self.pick_tenant(queue) else {
            return Selection::Idle;
        };
        let Some((class, bucket, take)) =
            tenant_batch(queue, tenant, self.max_batch, n_clusters)
        else {
            return Selection::Idle;
        };
        self.reqs[tenant] += take as u64;
        self.work[tenant] += (take * bucket) as u64;
        Selection::TenantBatch { tenant, class, take }
    }

    fn peek_class(&self, queue: &QueueView) -> Option<usize> {
        let tenant = self.pick_tenant(queue)?;
        queue.tenant_head(tenant).map(|h| h.class)
    }
}

/// Locality-aware placement wrapper: let the wrapped policy pick *what*
/// to run ([`Scheduler::peek_class`]), then steer the batch at the free
/// shard already holding that class's weights — falling back by
/// hierarchy distance (same board as a holder, same pod, anywhere) when
/// no free holder exists. Offers to every other free shard are deferred
/// (`Selection::Idle`): the engine walks free shards in ascending id
/// order and re-sweeps after every dispatch, so the deferred work lands
/// on the best-placed shard within the same dispatch pass.
///
/// The probe is O(log n) at any fleet size: free holders per class are
/// a `BTreeSet` `first()`, and the distance fallbacks anchor on the
/// **lowest-id holder** and range-probe the free set over that holder's
/// contiguous board/pod spans. Anchoring on one holder (rather than
/// scanning all of them) is what keeps the probe logarithmic; it is a
/// deterministic, documented policy choice, not an approximation the
/// engine depends on.
///
/// Liveness: between dispatches the best shard for a class is constant,
/// it is always free (the fallback returns *some* free shard whenever
/// one exists), and it accepts its own offer — so every sweep over a
/// non-empty queue with a free shard dispatches at least once, and the
/// wrapper never strands work. Policies whose choice is not
/// class-shaped (`peek_class() == None`, e.g. [`RoundRobin`]) pass
/// through untouched.
pub struct LocalityAware<'a> {
    inner: &'a mut dyn Scheduler,
    topo: Topology,
    /// Per shard: class whose weights it holds (mirrors the router's
    /// residency map, driven by the same `note_staged` events).
    resident: Vec<Option<usize>>,
    /// Free shard ids, ordered (for the span range-probes).
    free: BTreeSet<usize>,
    /// Per class: free shards holding that class.
    free_holders: Vec<BTreeSet<usize>>,
    /// Per class: all shards holding that class, busy included.
    holders: Vec<BTreeSet<usize>>,
}

impl<'a> LocalityAware<'a> {
    pub fn new(
        inner: &'a mut dyn Scheduler,
        topo: Topology,
        n_classes: usize,
    ) -> LocalityAware<'a> {
        LocalityAware {
            inner,
            topo,
            resident: Vec::new(),
            free: BTreeSet::new(),
            free_holders: vec![BTreeSet::new(); n_classes],
            holders: vec![BTreeSet::new(); n_classes],
        }
    }

    /// Best free shard for `class`: a free holder, else a free shard on
    /// the lowest-id holder's board, else one in its pod, else the
    /// lowest-id free shard. `None` only when nothing is free.
    fn best_shard(&self, class: usize) -> Option<usize> {
        if let Some(&s) = self.free_holders[class].iter().next() {
            return Some(s);
        }
        if let Some(&h) = self.holders[class].iter().next() {
            let board = self.topo.board_span(self.topo.board_of(h));
            if let Some(&s) = self.free.range(board).next() {
                return Some(s);
            }
            let pod = self.topo.pod_span(self.topo.pod_of(h));
            if let Some(&s) = self.free.range(pod).next() {
                return Some(s);
            }
        }
        self.free.iter().next().copied()
    }
}

impl Scheduler for LocalityAware<'_> {
    fn name(&self) -> &'static str {
        "locality"
    }

    fn on_attach(&mut self, n_shards: usize) {
        self.resident = vec![None; n_shards];
        self.free = (0..n_shards).collect();
        for h in &mut self.free_holders {
            h.clear();
        }
        for h in &mut self.holders {
            h.clear();
        }
        self.inner.on_attach(n_shards);
    }

    fn note_free(&mut self, shard: usize, free: bool) {
        if free {
            self.free.insert(shard);
        } else {
            self.free.remove(&shard);
        }
        if let Some(c) = self.resident[shard] {
            if free {
                self.free_holders[c].insert(shard);
            } else {
                self.free_holders[c].remove(&shard);
            }
        }
        self.inner.note_free(shard, free);
    }

    fn note_staged(&mut self, shard: usize, class: Option<usize>) {
        if let Some(old) = self.resident[shard] {
            self.holders[old].remove(&shard);
            self.free_holders[old].remove(&shard);
        }
        self.resident[shard] = class;
        if let Some(new) = class {
            self.holders[new].insert(shard);
            if self.free.contains(&shard) {
                self.free_holders[new].insert(shard);
            }
        }
        self.inner.note_staged(shard, class);
    }

    fn peek_class(&self, queue: &QueueView) -> Option<usize> {
        self.inner.peek_class(queue)
    }

    fn select(
        &mut self,
        now: u64,
        queue: &QueueView,
        cluster: usize,
        free: usize,
        n_clusters: usize,
    ) -> Selection {
        let Some(class) = self.inner.peek_class(queue) else {
            return self.inner.select(now, queue, cluster, free, n_clusters);
        };
        match self.best_shard(class) {
            // defer: a better-placed free shard gets this batch when
            // its offer comes around in the same dispatch pass
            Some(best) if best != cluster => Selection::Idle,
            // this is the best-placed shard (or nothing is free, which
            // cannot happen on an offer): commit through the inner
            // policy so its accounting is charged exactly once
            _ => self.inner.select(now, queue, cluster, free, n_clusters),
        }
    }
}

/// CLI lookup: `fifo`, `rr`/`round-robin`, `batch`/`dynamic-batch`,
/// `wfq`, `drf`.
pub fn by_name(name: &str) -> Option<Box<dyn Scheduler>> {
    match name {
        "fifo" => Some(Box::new(Fifo)),
        "rr" | "round-robin" => Some(Box::new(RoundRobin)),
        "batch" | "dynamic-batch" => Some(Box::new(DynamicBatch::default())),
        "wfq" => Some(Box::new(Wfq::default())),
        "drf" => Some(Box::new(Drf::default())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(id: usize, class: usize) -> Queued {
        Queued {
            id,
            class,
            bucket: 128 * (class + 1),
            arrival: id as u64,
            tenant: 0,
            first_arrival: id as u64,
            attempts: 0,
        }
    }

    fn view(requests: &[(usize, usize)], n_shards: usize) -> QueueView {
        let n_classes = requests.iter().map(|&(_, c)| c + 1).max().unwrap_or(1);
        let mut v = QueueView::new(n_classes, n_shards, 1);
        for &(id, class) in requests {
            v.push(q(id, class));
        }
        v
    }

    /// Tenant-tagged view: (id, class, tenant) triples.
    fn tenant_view(requests: &[(usize, usize, usize)], n_tenants: usize) -> QueueView {
        let n_classes = requests.iter().map(|&(_, c, _)| c + 1).max().unwrap_or(1);
        let mut v = QueueView::new(n_classes, 1, n_tenants);
        for &(id, class, tenant) in requests {
            v.push(Queued {
                id,
                class,
                bucket: 128 * (class + 1),
                arrival: id as u64,
                tenant,
                first_arrival: id as u64,
                attempts: 0,
            });
        }
        v
    }

    #[test]
    fn fifo_takes_the_head() {
        let mut s = Fifo;
        let empty = QueueView::new(2, 1, 1);
        assert_eq!(s.select(0, &empty, 0, 1, 1), Selection::Idle);
        let v = view(&[(0, 1), (1, 0)], 1);
        // head is id 0 (class 1): one request of that class
        assert_eq!(s.select(0, &v, 0, 1, 1), Selection::Batch { class: 1, take: 1 });
    }

    #[test]
    fn round_robin_pins_requests_to_their_shard() {
        let mut s = RoundRobin;
        let v = view(&[(0, 0), (1, 0), (2, 0), (5, 1)], 2);
        assert_eq!(s.select(0, &v, 0, 2, 2), Selection::Pinned);
        assert_eq!(s.select(0, &v, 1, 2, 2), Selection::Pinned); // ids 1, 5
        // a shard with no assigned work stays idle
        let only_even = view(&[(0, 0), (2, 0)], 2);
        assert_eq!(only_even.shard_len(1), 0);
        assert_eq!(s.select(0, &only_even, 1, 2, 2), Selection::Idle);
    }

    #[test]
    fn dynamic_batch_coalesces_the_head_bucket() {
        let mut s = DynamicBatch::new(8);
        // head class 0; co-bucketed ids 0, 2, 3 coalesce past the
        // class-1 request at position 1
        let v = view(&[(0, 0), (1, 1), (2, 0), (3, 0)], 1);
        assert_eq!(s.select(0, &v, 0, 1, 1), Selection::Batch { class: 0, take: 3 });
        // spread over a 2-cluster fleet: take only the even share
        assert_eq!(s.select(0, &v, 0, 2, 2), Selection::Batch { class: 0, take: 2 });
        // max_batch caps the batch
        let mut tight = DynamicBatch::new(2);
        assert_eq!(tight.select(0, &v, 0, 1, 1), Selection::Batch { class: 0, take: 2 });
    }

    #[test]
    fn wfq_alternates_between_equal_weight_tenants() {
        let mut s = Wfq::new(1);
        // tenant 0 floods the queue; tenant 1 has one waiter per round.
        // with equal weights the clocks must alternate dispatch
        let v = tenant_view(&[(0, 0, 0), (1, 0, 0), (2, 0, 0), (3, 0, 1)], 2);
        let first = s.select(0, &v, 0, 1, 1);
        let Selection::TenantBatch { tenant: t0, take: 1, .. } = first else {
            panic!("expected a tenant batch, got {first:?}");
        };
        // whoever went first is now behind: the other tenant goes next
        let second = s.select(0, &v, 0, 1, 1);
        let Selection::TenantBatch { tenant: t1, .. } = second else {
            panic!("expected a tenant batch, got {second:?}");
        };
        assert_ne!(t0, t1, "equal-weight tenants alternate under contention");
        // empty queue is idle
        let empty = QueueView::new(1, 1, 2);
        assert_eq!(s.select(0, &empty, 0, 1, 1), Selection::Idle);
    }

    #[test]
    fn wfq_weights_bias_the_service_ratio() {
        // tenant 0 carries weight 3: over 4 single-request dispatches
        // from a saturated queue it must win 3
        let mut s = Wfq::new(1).with_weights(vec![3, 1]);
        let reqs: Vec<(usize, usize, usize)> =
            (0..16).map(|id| (id, 0, id % 2)).collect();
        let v = tenant_view(&reqs, 2);
        let mut wins = [0usize; 2];
        for _ in 0..4 {
            match s.select(0, &v, 0, 1, 1) {
                Selection::TenantBatch { tenant, .. } => wins[tenant] += 1,
                other => panic!("expected a tenant batch, got {other:?}"),
            }
        }
        assert_eq!(wins, [3, 1], "weight-3 tenant wins 3 of 4 dispatches");
    }

    #[test]
    fn drf_picks_the_smallest_dominant_share() {
        let mut s = Drf::new(1);
        let v = tenant_view(&[(0, 0, 0), (1, 0, 1), (2, 0, 0), (3, 0, 1)], 2);
        // fresh state: everyone at zero share, tie broken by index
        assert!(matches!(
            s.select(0, &v, 0, 1, 1),
            Selection::TenantBatch { tenant: 0, .. }
        ));
        // tenant 0 now holds all delivered resources: tenant 1 is next
        assert!(matches!(
            s.select(0, &v, 0, 1, 1),
            Selection::TenantBatch { tenant: 1, .. }
        ));
        let empty = QueueView::new(1, 1, 2);
        assert_eq!(s.select(0, &empty, 0, 1, 1), Selection::Idle);
    }

    #[test]
    fn drf_weights_bias_the_dominant_share() {
        // hand-computed: weights [3, 1], one class of bucket 128,
        // single-request dispatches from a saturated two-tenant queue.
        // After k_t dispatches to tenant t: reqs[t]=k_t, work[t]=128·k_t,
        // so dominant(t) = k_t·ΣR·ΣW/Σ... reduces to k_t (both resource
        // shares are equal), and the rule serves the tenant minimizing
        // k_t / weight_t:
        //   d1: 0/3 vs 0/1 -> tie -> tenant 0        (k = [1, 0])
        //   d2: 1/3 vs 0/1 -> tenant 1               (k = [1, 1])
        //   d3: 1/3 vs 1/1 -> tenant 0               (k = [2, 1])
        //   d4: 2/3 vs 1/1 -> tenant 0               (k = [3, 1])
        // so the dispatch sequence is exactly [0, 1, 0, 0]
        let mut s = Drf::new(1).with_weights(vec![3, 1]);
        let reqs: Vec<(usize, usize, usize)> =
            (0..8).map(|id| (id, 0, id % 2)).collect();
        let v = tenant_view(&reqs, 2);
        let mut order = Vec::new();
        for _ in 0..4 {
            match s.select(0, &v, 0, 1, 1) {
                Selection::TenantBatch { tenant, take: 1, .. } => order.push(tenant),
                other => panic!("expected a tenant batch, got {other:?}"),
            }
        }
        assert_eq!(order, vec![0, 1, 0, 0], "weight-3 tenant wins 3 of 4");
    }

    #[test]
    fn peek_class_is_a_pure_replica_of_select() {
        // peek then select across evolving accounting: same class every
        // round, and peeking twice changes nothing
        let reqs: Vec<(usize, usize, usize)> =
            (0..16).map(|id| (id, id % 2, id % 2)).collect();
        let v = tenant_view(&reqs, 2);
        let mut wfq = Wfq::new(1).with_weights(vec![3, 1]);
        for _ in 0..6 {
            let peeked = wfq.peek_class(&v).expect("backlogged queue peeks Some");
            assert_eq!(wfq.peek_class(&v), Some(peeked), "peek must not mutate");
            match wfq.select(0, &v, 0, 1, 1) {
                Selection::TenantBatch { class, .. } => assert_eq!(class, peeked),
                other => panic!("expected a tenant batch, got {other:?}"),
            }
        }
        let mut drf = Drf::new(1).with_weights(vec![2, 1]);
        for _ in 0..6 {
            let peeked = drf.peek_class(&v).expect("backlogged queue peeks Some");
            match drf.select(0, &v, 0, 1, 1) {
                Selection::TenantBatch { class, .. } => assert_eq!(class, peeked),
                other => panic!("expected a tenant batch, got {other:?}"),
            }
        }
        // the head-of-line policies peek their head's class
        assert_eq!(Fifo.peek_class(&v), Some(v.head().unwrap().class));
        assert_eq!(DynamicBatch::default().peek_class(&v), Some(0));
        // pinned policies are not class-shaped
        assert_eq!(RoundRobin.peek_class(&v), None);
        let empty = QueueView::new(1, 1, 2);
        assert_eq!(Fifo.peek_class(&empty), None);
        assert_eq!(Wfq::default().peek_class(&empty), None);
        assert_eq!(Drf::default().peek_class(&empty), None);
    }

    #[test]
    fn locality_wrapper_steers_to_the_free_holder() {
        let topo = Topology::parse("pod:1x2x2").unwrap(); // 4 shards
        let mut inner = Fifo;
        let mut s = LocalityAware::new(&mut inner, topo, 2);
        s.on_attach(4);
        s.note_staged(2, Some(0)); // shard 2 holds class 0, everyone free
        let v = view(&[(0, 0)], 4);
        assert_eq!(s.select(0, &v, 0, 4, 4), Selection::Idle, "0 defers to 2");
        assert_eq!(s.select(0, &v, 1, 4, 4), Selection::Idle);
        assert_eq!(s.select(0, &v, 2, 4, 4), Selection::Batch { class: 0, take: 1 });
    }

    #[test]
    fn locality_wrapper_falls_back_by_hierarchy_distance() {
        let topo = Topology::parse("pod:2x2x2").unwrap(); // 8 shards
        let mut inner = Fifo;
        let mut s = LocalityAware::new(&mut inner, topo, 1);
        s.on_attach(8);
        // the only holder (shard 1) is busy: its board-mate 0 is best
        s.note_staged(1, Some(0));
        s.note_free(1, false);
        let v = view(&[(0, 0)], 8);
        assert_eq!(s.select(0, &v, 3, 7, 8), Selection::Idle);
        assert_eq!(s.select(0, &v, 0, 7, 8), Selection::Batch { class: 0, take: 1 });
        // board 0 fully busy -> same pod (shard 2)
        s.note_free(0, false);
        assert_eq!(s.select(0, &v, 4, 6, 8), Selection::Idle);
        assert_eq!(s.select(0, &v, 2, 6, 8), Selection::Batch { class: 0, take: 1 });
        // pod 0 fully busy -> lowest-id free shard anywhere (4)
        s.note_free(2, false);
        s.note_free(3, false);
        assert_eq!(s.select(0, &v, 5, 4, 8), Selection::Idle);
        assert_eq!(s.select(0, &v, 4, 4, 8), Selection::Batch { class: 0, take: 1 });
        // eviction drops residency: with no holder at all, the
        // lowest-id free shard takes it directly
        s.note_staged(1, None);
        assert_eq!(s.select(0, &v, 4, 4, 8), Selection::Batch { class: 0, take: 1 });
    }

    #[test]
    fn locality_wrapper_passes_pinned_policies_through() {
        let mut inner = RoundRobin;
        let mut s = LocalityAware::new(&mut inner, Topology::Flat, 1);
        s.on_attach(2);
        let v = view(&[(0, 0), (1, 0)], 2);
        assert_eq!(s.name(), "locality");
        assert_eq!(s.select(0, &v, 0, 2, 2), Selection::Pinned);
        assert_eq!(s.select(0, &v, 1, 2, 2), Selection::Pinned);
    }

    #[test]
    fn fairness_batches_stay_within_one_tenant_class_ring() {
        // tenant 1's head class has a 3-deep backlog; a single-cluster
        // fleet coalesces it like DynamicBatch but never crosses tenants
        let mut s = Wfq::new(8);
        let v = tenant_view(&[(0, 0, 1), (1, 0, 0), (2, 0, 1), (3, 0, 1)], 2);
        let sel = s.select(0, &v, 0, 1, 1);
        match sel {
            Selection::TenantBatch { tenant, class: 0, take } => {
                assert!(take <= v.tenant_class_len(tenant, 0));
            }
            other => panic!("expected a tenant batch, got {other:?}"),
        }
    }

    #[test]
    fn by_name_resolves_all_policies() {
        for (name, want) in [
            ("fifo", "fifo"),
            ("rr", "round-robin"),
            ("batch", "dynamic-batch"),
            ("wfq", "wfq"),
            ("drf", "drf"),
        ] {
            assert_eq!(by_name(name).unwrap().name(), want);
        }
        assert!(by_name("lifo").is_none());
    }
}
