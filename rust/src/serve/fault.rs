//! Serve-side fault execution: admission control, deadlines, bounded
//! retry/failover, and the engine-facing fault state.
//!
//! The schedule itself (what fails when) is pure data in
//! [`crate::fault::FaultPlan`]; this module owns everything the
//! [`ServeEngine`] needs to *execute* a plan:
//!
//! - [`AdmissionPolicy`] — load shedding at admission time:
//!   [`AdmitAll`] (the identity), queue-depth [`Threshold`], and
//!   [`TenantFair`] shedding that only drops tenants exceeding their
//!   fair queue share, protecting minority-tenant SLOs under a noisy
//!   neighbor's overload.
//! - [`FaultConfig`] — the plan plus the degradation knobs (admission
//!   policy, per-attempt deadline, retry budget, backoff base).
//! - [`FaultSummary`] — the `degraded` block of a [`ServeReport`]:
//!   crash/shed/expired/retry accounting, availability and goodput.
//! - [`FaultCtx`] (crate-private) — the engine's live fault state:
//!   down-shard bitmap, deferred in-flight batches, the retry heap and
//!   the deadline-expiry queue, plus the transient-failure RNG.
//!
//! Every fault transition is visible to the observability layer when
//! one is attached (`crate::obs`): crashes/recoveries surface as
//! `ShardCrash`/`Recover` events, killed in-flight work as `Killed`,
//! deadline expiries and exhausted retry budgets as `Expired`, and
//! each backoff hop as `Retried` — the recorder is write-only, so the
//! fault path's determinism contract is untouched.
//!
//! **Determinism:** the transient RNG is seeded from the plan (never
//! the workload), drawn exactly once per dispatched request *only when*
//! `transient_ppm > 0`, and every other mechanism is integer cycle
//! arithmetic over sorted schedules — so a faulted run is a pure
//! function of (workload, geometry, scheduler, fault config) and
//! reproduces bit-identically. With the empty plan and [`AdmitAll`],
//! no draw ever happens, dispatch commits immediately, and the run is
//! bit-identical to an engine with no fault layer at all
//! (`tests/serve_equivalence.rs` propchecks exactly that).
//!
//! [`ServeEngine`]: super::ServeEngine
//! [`ServeReport`]: super::ServeReport
//! [`AdmitAll`]: AdmissionPolicy::AdmitAll
//! [`Threshold`]: AdmissionPolicy::Threshold
//! [`TenantFair`]: AdmissionPolicy::TenantFair

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::fault::{FaultPlan, LinkEvent, ShardEvent};
use crate::util::prng::XorShift64;

use super::queue::QueueView;

/// Retry budget when the config does not set one.
pub const DEFAULT_MAX_RETRIES: u32 = 3;
/// Backoff base when the config does not set one: attempt `k` waits
/// `backoff << k` cycles before re-admission (exponential, in cycles).
pub const DEFAULT_RETRY_BACKOFF_CYCLES: u64 = 10_000;
/// Queue-depth bound when `threshold` / `tenant-fair` is named without
/// an explicit `:depth`.
pub const DEFAULT_ADMISSION_DEPTH: usize = 256;

/// Load-shedding policy applied when a fresh request reaches the
/// queue. Retries are never re-admitted through this gate — a request
/// the fleet already accepted keeps its admission (shedding it later
/// would double-count it against the conservation invariant).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AdmissionPolicy {
    /// Admit everything (the identity policy; overload queues
    /// unboundedly exactly as before).
    AdmitAll,
    /// Shed any arrival that would grow the queue past `max_depth`
    /// waiters — bounded queueing delay, tenant-blind.
    Threshold { max_depth: usize },
    /// Shed only when the queue is past `max_depth` **and** the
    /// arriving tenant already holds at least its fair share
    /// (`1/n_tenants`) of the backlog — a flooding tenant is shed
    /// first, a minority tenant keeps landing until the overload is
    /// everyone's fault.
    TenantFair { max_depth: usize },
}

impl AdmissionPolicy {
    /// CLI/report label (`admit-all`, `threshold:256`, …).
    pub fn label(&self) -> String {
        match self {
            AdmissionPolicy::AdmitAll => "admit-all".to_string(),
            AdmissionPolicy::Threshold { max_depth } => format!("threshold:{max_depth}"),
            AdmissionPolicy::TenantFair { max_depth } => format!("tenant-fair:{max_depth}"),
        }
    }

    /// Whether a fresh arrival of `tenant` is admitted given the
    /// current queue state.
    pub(crate) fn admits(&self, queue: &QueueView, tenant: usize) -> bool {
        match *self {
            AdmissionPolicy::AdmitAll => true,
            AdmissionPolicy::Threshold { max_depth } => queue.len() < max_depth,
            AdmissionPolicy::TenantFair { max_depth } => {
                queue.len() < max_depth
                    || queue.tenant_len(tenant) * queue.n_tenants() < max_depth
            }
        }
    }
}

/// CLI lookup: `admit-all`, `threshold[:depth]`, `tenant-fair[:depth]`
/// (depth defaults to [`DEFAULT_ADMISSION_DEPTH`]).
pub fn admission_by_name(name: &str) -> Option<AdmissionPolicy> {
    let (head, depth) = match name.split_once(':') {
        Some((h, d)) => (h, d.parse::<usize>().ok().filter(|&d| d > 0)?),
        None => (name, DEFAULT_ADMISSION_DEPTH),
    };
    match head {
        "admit-all" if name == "admit-all" => Some(AdmissionPolicy::AdmitAll),
        "threshold" => Some(AdmissionPolicy::Threshold { max_depth: depth }),
        "tenant-fair" => Some(AdmissionPolicy::TenantFair { max_depth: depth }),
        _ => None,
    }
}

/// Everything the fault layer needs for one run: the schedule plus the
/// graceful-degradation knobs. `FaultConfig::default()` is the
/// provably-inert configuration (empty plan, admit-all, no deadline).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultConfig {
    /// The fault schedule (validated against the fleet on attach).
    pub plan: FaultPlan,
    /// Load shedding applied to fresh arrivals.
    pub admission: AdmissionPolicy,
    /// Per-attempt queueing deadline, cycles: an entry still queued
    /// `deadline_cycles` after its admission expires unserved. `None`
    /// disables deadlines entirely.
    pub deadline_cycles: Option<u64>,
    /// Dispatch attempts allowed **after** the first (0 = fail fast).
    pub max_retries: u32,
    /// Backoff base: failed attempt `k` (0-based) re-admits after
    /// `retry_backoff_cycles << k`.
    pub retry_backoff_cycles: u64,
}

impl Default for FaultConfig {
    fn default() -> FaultConfig {
        FaultConfig {
            plan: FaultPlan::empty(),
            admission: AdmissionPolicy::AdmitAll,
            deadline_cycles: None,
            max_retries: DEFAULT_MAX_RETRIES,
            retry_backoff_cycles: DEFAULT_RETRY_BACKOFF_CYCLES,
        }
    }
}

impl FaultConfig {
    /// Config carrying just a plan, every degradation knob at default.
    pub fn with_plan(plan: FaultPlan) -> FaultConfig {
        FaultConfig { plan, ..FaultConfig::default() }
    }
}

/// The `degraded` block of a [`ServeReport`](super::ServeReport):
/// honest accounting of everything that did *not* go perfectly.
/// On a faulted drained run the conservation invariant
/// `offered == served + shed + expired` holds by exact count
/// (`expired` = deadline expiries + exhausted retry budgets).
#[derive(Debug, Clone, PartialEq)]
pub struct FaultSummary {
    /// Admission policy label ([`AdmissionPolicy::label`]).
    pub admission: String,
    /// Shard crash events that fired.
    pub crashes: u64,
    /// Shard recover events that fired.
    pub recoveries: u64,
    /// Link degrade/outage events that fired.
    pub link_events: u64,
    /// Requests whose in-flight attempt died with a crashing shard.
    pub killed_in_flight: u64,
    /// Requests that drew a transient failure at completion.
    pub transient_failures: u64,
    /// Fresh arrivals dropped by admission control.
    pub shed: u64,
    /// Requests admitted but never served: `expired_deadline +
    /// retry_exhausted`.
    pub expired: u64,
    /// Queue entries cancelled by their per-attempt deadline.
    pub expired_deadline: u64,
    /// Failed requests dropped with an exhausted retry budget.
    pub retry_exhausted: u64,
    /// Retry attempts scheduled (transient + crash failovers).
    pub retried: u64,
    /// Retries caused by a shard crash (re-dispatched elsewhere,
    /// re-staging weights from the nearest surviving holder).
    pub failed_over: u64,
    /// `served / offered` (1.0 when nothing was offered).
    pub availability: f64,
    /// Committed-work throughput, GOp/s — work killed mid-flight burns
    /// energy but never counts here.
    pub goodput_gops: f64,
    /// Shed counts split by tenant id (index = tenant).
    pub shed_by_tenant: Vec<u64>,
    /// Deadline in force, echoed from the config.
    pub deadline_cycles: Option<u64>,
    /// Retry budget in force, echoed from the config.
    pub max_retries: u32,
}

/// One request riding a deferred (not-yet-committed) batch.
#[derive(Debug, Clone)]
pub(crate) struct InFlightReq {
    pub(crate) id: usize,
    /// Completion cycle this attempt would finish at.
    pub(crate) done: u64,
    /// Original arrival (end-to-end latency base).
    pub(crate) arrival: u64,
    pub(crate) tenant: usize,
    /// Failed attempts before this one.
    pub(crate) attempts: u32,
}

/// A dispatched batch whose results are withheld until its wake
/// commits — the window in which a crash can kill it.
#[derive(Debug, Clone)]
pub(crate) struct InFlight {
    pub(crate) class: usize,
    /// Dispatch start cycle.
    pub(crate) start: u64,
    /// Batch completion cycle (== the shard's wake).
    pub(crate) completion: u64,
    /// Simulated ops per request of this class.
    pub(crate) ops_per_req: u64,
    /// Router-priced dispatch transit the batch waited out (observed
    /// by the profiler's crash accounting; 0 without a topology).
    pub(crate) net_delay: u64,
    /// DVFS transition cycles this dispatch paid (observed by the
    /// profiler's crash accounting; 0 on uncontrolled runs).
    pub(crate) penalty: u64,
    pub(crate) reqs: Vec<InFlightReq>,
}

/// A retry waiting out its backoff: ordered by (ready cycle, id) so
/// the heap pops merge deterministically with the arrival stream.
/// Fields: (ready, id, class, first_arrival, tenant, attempts).
pub(crate) type RetryEntry = (u64, usize, usize, u64, usize, u32);

/// Live fault state of one engine run (see the module docs).
#[derive(Debug)]
pub(crate) struct FaultCtx {
    pub(crate) cfg: FaultConfig,
    /// Transient-failure RNG; drawn only when `transient_ppm > 0`.
    rng: XorShift64,
    /// Next unprocessed index into `cfg.plan.shard_events`.
    pub(crate) shard_cursor: usize,
    /// Next unprocessed index into `cfg.plan.link_events`.
    pub(crate) link_cursor: usize,
    /// Per-shard crashed flag.
    pub(crate) down: Vec<bool>,
    pub(crate) n_down: usize,
    /// Deferred batch per shard (`Some` while dispatched-not-committed;
    /// only used when [`FaultCtx::defers`] is true).
    pub(crate) in_flight: Vec<Option<InFlight>>,
    /// Failed requests waiting out their backoff.
    pub(crate) retry: BinaryHeap<Reverse<RetryEntry>>,
    /// Deadline queue: (expiry cycle, queue slot, generation), pushed
    /// in admission order — monotone in expiry because the deadline is
    /// a constant offset from the (monotone) admission cycle.
    pub(crate) expiry: VecDeque<(u64, u32, u32)>,
    // ---- counters (mirrored into FaultSummary) ----
    pub(crate) crashes: u64,
    pub(crate) recoveries: u64,
    pub(crate) link_events: u64,
    pub(crate) killed_in_flight: u64,
    pub(crate) transient_failures: u64,
    pub(crate) shed: u64,
    pub(crate) expired_deadline: u64,
    pub(crate) retry_exhausted: u64,
    pub(crate) retried: u64,
    pub(crate) failed_over: u64,
    pub(crate) shed_by_tenant: Vec<u64>,
}

impl FaultCtx {
    pub(crate) fn new(cfg: FaultConfig, n_shards: usize, n_tenants: usize) -> FaultCtx {
        let rng = XorShift64::new(cfg.plan.seed);
        FaultCtx {
            rng,
            shard_cursor: 0,
            link_cursor: 0,
            down: vec![false; n_shards],
            n_down: 0,
            in_flight: vec![None; n_shards],
            retry: BinaryHeap::new(),
            expiry: VecDeque::new(),
            crashes: 0,
            recoveries: 0,
            link_events: 0,
            killed_in_flight: 0,
            transient_failures: 0,
            shed: 0,
            expired_deadline: 0,
            retry_exhausted: 0,
            retried: 0,
            failed_over: 0,
            shed_by_tenant: vec![0; n_tenants.max(1)],
            cfg,
        }
    }

    /// Whether dispatches must defer their results to commit-at-wake.
    /// Only shard crashes and transient failures can invalidate a
    /// dispatched batch; link faults merely delay its start, so a
    /// link-only plan keeps the immediate-commit path (and the empty
    /// plan keeps it trivially — the bit-identity leg).
    pub(crate) fn defers(&self) -> bool {
        !self.cfg.plan.shard_events.is_empty() || self.cfg.plan.transient_ppm > 0
    }

    /// Draw one transient-failure decision. Never called (and never
    /// advances the RNG) when `transient_ppm == 0`.
    pub(crate) fn transient_fails(&mut self) -> bool {
        debug_assert!(self.cfg.plan.transient_ppm > 0);
        self.rng.next_u64() % 1_000_000 < self.cfg.plan.transient_ppm as u64
    }

    /// Backoff before retry attempt `attempts` (1-based at call time):
    /// exponential in cycles, never zero.
    pub(crate) fn backoff(&self, attempts: u32) -> u64 {
        (self.cfg.retry_backoff_cycles << attempts.min(32)).max(1)
    }

    /// Cycle of the next unprocessed plan event, if any.
    pub(crate) fn next_plan_event(&self) -> Option<u64> {
        let s = self.cfg.plan.shard_events.get(self.shard_cursor).map(|e| e.at_cycles);
        let l = self.cfg.plan.link_events.get(self.link_cursor).map(|e| e.at_cycles);
        match (s, l) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (x, None) => x,
            (None, y) => y,
        }
    }

    /// Next shard event due at or before `now`, advancing the cursor.
    pub(crate) fn pop_shard_event(&mut self, now: u64) -> Option<ShardEvent> {
        let ev = *self.cfg.plan.shard_events.get(self.shard_cursor)?;
        if ev.at_cycles > now {
            return None;
        }
        self.shard_cursor += 1;
        Some(ev)
    }

    /// Next link event due at or before `now`, advancing the cursor.
    pub(crate) fn pop_link_event(&mut self, now: u64) -> Option<LinkEvent> {
        let ev = *self.cfg.plan.link_events.get(self.link_cursor)?;
        if ev.at_cycles > now {
            return None;
        }
        self.link_cursor += 1;
        Some(ev)
    }

    /// Ready cycle of the most urgent pending retry.
    pub(crate) fn next_retry_ready(&self) -> Option<u64> {
        self.retry.peek().map(|Reverse(e)| e.0)
    }

    /// Record one shed arrival.
    pub(crate) fn note_shed(&mut self, tenant: usize) {
        self.shed += 1;
        if tenant >= self.shed_by_tenant.len() {
            self.shed_by_tenant.resize(tenant + 1, 0);
        }
        self.shed_by_tenant[tenant] += 1;
    }

    /// Build the report block. `served`/`offered` are request counts,
    /// `ops_served` counts committed work only, `sec` is the makespan.
    pub(crate) fn summary(
        &self,
        offered: usize,
        served: usize,
        ops_served: u64,
        sec: f64,
    ) -> FaultSummary {
        FaultSummary {
            admission: self.cfg.admission.label(),
            crashes: self.crashes,
            recoveries: self.recoveries,
            link_events: self.link_events,
            killed_in_flight: self.killed_in_flight,
            transient_failures: self.transient_failures,
            shed: self.shed,
            expired: self.expired_deadline + self.retry_exhausted,
            expired_deadline: self.expired_deadline,
            retry_exhausted: self.retry_exhausted,
            retried: self.retried,
            failed_over: self.failed_over,
            availability: if offered == 0 {
                1.0
            } else {
                served as f64 / offered as f64
            },
            goodput_gops: ops_served as f64 / 1e9 / sec,
            shed_by_tenant: self.shed_by_tenant.clone(),
            deadline_cycles: self.cfg.deadline_cycles,
            max_retries: self.cfg.max_retries,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::scheduler::Queued;

    fn queue_with(n: usize, tenant: usize, n_tenants: usize) -> QueueView {
        let mut v = QueueView::new(1, 1, n_tenants);
        for id in 0..n {
            v.push(Queued {
                id,
                class: 0,
                bucket: 128,
                arrival: id as u64,
                tenant,
                first_arrival: id as u64,
                attempts: 0,
            });
        }
        v
    }

    #[test]
    fn admission_names_parse_and_label_round_trips() {
        assert_eq!(admission_by_name("admit-all"), Some(AdmissionPolicy::AdmitAll));
        assert_eq!(
            admission_by_name("threshold"),
            Some(AdmissionPolicy::Threshold { max_depth: DEFAULT_ADMISSION_DEPTH })
        );
        assert_eq!(
            admission_by_name("threshold:64"),
            Some(AdmissionPolicy::Threshold { max_depth: 64 })
        );
        assert_eq!(
            admission_by_name("tenant-fair:32"),
            Some(AdmissionPolicy::TenantFair { max_depth: 32 })
        );
        for bad in ["drop-all", "threshold:0", "threshold:x", "admit-all:5", ""] {
            assert!(admission_by_name(bad).is_none(), "{bad:?} must not parse");
        }
        for name in ["admit-all", "threshold:64", "tenant-fair:32"] {
            assert_eq!(admission_by_name(name).unwrap().label(), name);
        }
    }

    #[test]
    fn threshold_sheds_past_the_depth() {
        let p = AdmissionPolicy::Threshold { max_depth: 2 };
        assert!(p.admits(&queue_with(0, 0, 1), 0));
        assert!(p.admits(&queue_with(1, 0, 1), 0));
        assert!(!p.admits(&queue_with(2, 0, 1), 0));
        assert!(AdmissionPolicy::AdmitAll.admits(&queue_with(1000, 0, 1), 0));
    }

    #[test]
    fn tenant_fair_protects_the_minority_tenant() {
        // queue of 4, all tenant 0, two tenants, bound 4: tenant 0 is
        // over its fair share (4*2 >= 4) and sheds, tenant 1 holds
        // nothing (0*2 < 4) and is still admitted
        let p = AdmissionPolicy::TenantFair { max_depth: 4 };
        let q = queue_with(4, 0, 2);
        assert!(!p.admits(&q, 0), "flooding tenant sheds");
        assert!(p.admits(&q, 1), "minority tenant keeps landing");
        // under the depth bound nobody sheds
        assert!(p.admits(&queue_with(3, 0, 2), 0));
    }

    #[test]
    fn default_config_is_the_inert_one() {
        let c = FaultConfig::default();
        assert!(c.plan.is_empty());
        assert_eq!(c.admission, AdmissionPolicy::AdmitAll);
        assert_eq!(c.deadline_cycles, None);
        let ctx = FaultCtx::new(c, 4, 1);
        assert!(!ctx.defers(), "empty plan keeps the immediate-commit path");
        assert_eq!(ctx.next_plan_event(), None);
        assert_eq!(ctx.next_retry_ready(), None);
    }

    #[test]
    fn defers_only_for_crash_or_transient_plans() {
        let link_only = FaultConfig::with_plan(FaultPlan::empty().degrade_link(0, 1, 4));
        assert!(!FaultCtx::new(link_only, 2, 1).defers(), "link faults only delay");
        let crashy = FaultConfig::with_plan(FaultPlan::empty().crash(0, 0).recover(9, 0));
        assert!(FaultCtx::new(crashy, 2, 1).defers());
        let flaky = FaultConfig::with_plan(FaultPlan::empty().transient(10));
        assert!(FaultCtx::new(flaky, 2, 1).defers());
    }

    #[test]
    fn transient_draws_are_seed_deterministic() {
        let cfg = FaultConfig::with_plan(FaultPlan::empty().transient(500_000).seeded(42));
        let mut a = FaultCtx::new(cfg.clone(), 1, 1);
        let mut b = FaultCtx::new(cfg, 1, 1);
        let da: Vec<bool> = (0..64).map(|_| a.transient_fails()).collect();
        let db: Vec<bool> = (0..64).map(|_| b.transient_fails()).collect();
        assert_eq!(da, db, "same seed, same draw sequence");
        // at 50% ppm both outcomes appear
        assert!(da.iter().any(|&x| x) && da.iter().any(|&x| !x));
    }

    #[test]
    fn backoff_is_exponential_and_never_zero() {
        let ctx = FaultCtx::new(FaultConfig::default(), 1, 1);
        assert_eq!(ctx.backoff(0), DEFAULT_RETRY_BACKOFF_CYCLES);
        assert_eq!(ctx.backoff(1), DEFAULT_RETRY_BACKOFF_CYCLES * 2);
        assert_eq!(ctx.backoff(3), DEFAULT_RETRY_BACKOFF_CYCLES * 8);
        // a zero base still waits at least one cycle
        let mut zero = FaultCtx::new(FaultConfig::default(), 1, 1);
        zero.cfg.retry_backoff_cycles = 0;
        assert_eq!(zero.backoff(2), 1);
        // and absurd attempt counts saturate instead of overflowing
        assert!(ctx.backoff(200) > 0);
    }

    #[test]
    fn plan_event_cursors_pop_in_order() {
        let plan = FaultPlan::empty().crash(100, 0).recover(300, 0).degrade_link(200, 0, 2);
        let mut ctx = FaultCtx::new(FaultConfig::with_plan(plan), 2, 1);
        assert_eq!(ctx.next_plan_event(), Some(100));
        assert!(ctx.pop_shard_event(50).is_none(), "not due yet");
        let ev = ctx.pop_shard_event(100).unwrap();
        assert_eq!((ev.at_cycles, ev.shard), (100, 0));
        assert_eq!(ctx.next_plan_event(), Some(200));
        assert!(ctx.pop_link_event(250).is_some());
        assert_eq!(ctx.next_plan_event(), Some(300));
        assert!(ctx.pop_shard_event(300).is_some());
        assert_eq!(ctx.next_plan_event(), None);
    }

    #[test]
    fn summary_mirrors_the_counters() {
        let mut ctx = FaultCtx::new(FaultConfig::default(), 2, 2);
        ctx.note_shed(1);
        ctx.note_shed(1);
        ctx.expired_deadline = 3;
        ctx.retry_exhausted = 2;
        let s = ctx.summary(100, 93, 930_000_000_000, 2.0);
        assert_eq!(s.shed, 2);
        assert_eq!(s.shed_by_tenant, vec![0, 2]);
        assert_eq!(s.expired, 5);
        assert_eq!(s.availability, 0.93);
        assert_eq!(s.goodput_gops, 465.0);
        assert_eq!(s.admission, "admit-all");
        // nothing offered is trivially available
        assert_eq!(ctx.summary(0, 0, 0, 1.0).availability, 1.0);
    }
}
