//! The waiting-request index: per-(class, bucket) and per-shard ring
//! deques over a recycled slab.
//!
//! The pre-optimization serve loop kept one flat `Vec<Queued>` and paid
//! `Vec::remove` per dispatched request — O(n) each, O(n²) under
//! backlog, which made the *simulator* the bottleneck long before the
//! modeled hardware. [`QueueView`] replaces it with:
//!
//! - a **slab** of open requests with recycled slots and per-slot
//!   generation counters (O(1) memory per *open* request — a million
//!   -request run allocates only the peak backlog),
//! - one arrival-ordered **ring deque per request class** (each class
//!   is one seq-len bucket: the bucket is the padded sequence length
//!   its deployment is compiled for, so per-class *is* per-(class,
//!   bucket)),
//! - one arrival-ordered **ring deque per shard residue** (`id %
//!   n_clusters`), serving the round-robin policy's pinned lookups,
//! - one arrival-ordered **ring deque per (tenant, class)** pair,
//!   serving the fairness-aware policies' per-tenant head/count lookups
//!   (single-tenant workloads pay one extra deque per class and nothing
//!   else).
//!
//! A request lives in exactly one slot but is indexed by three deques;
//! taking it through one leaves stale `(slot, generation)` entries in
//! the others, which are skipped lazily and reclaimed by [`tidy`]
//! (front-popping plus amortized compaction once a deque is mostly
//! dead). Every scheduler-facing lookup — overall head, class head and
//! live count, shard head, tenant head — is O(1) after a tidy (tenant
//! heads are O(n_classes)); a take is O(batch). Head-of-line
//! arrival-order semantics are exact: deques are pushed in admission
//! order, and admission order is (arrival cycle, id) order.
//!
//! [`tidy`]: QueueView::tidy

use std::collections::VecDeque;

use super::scheduler::Queued;

/// A deque entry: slab slot plus the generation it was created under.
/// Stale entries (the slot was freed, or freed and recycled since) have
/// a mismatched generation and are skipped.
#[derive(Debug, Clone, Copy)]
struct Entry {
    slot: u32,
    gen: u32,
}

#[derive(Debug, Clone)]
struct Slot {
    q: Queued,
    gen: u32,
}

/// The scheduler-facing view of the waiting queue (see module docs).
/// Read accessors are public; mutation (push/take) is fleet-internal.
#[derive(Debug)]
pub struct QueueView {
    slots: Vec<Slot>,
    free_slots: Vec<u32>,
    by_class: Vec<VecDeque<Entry>>,
    by_shard: Vec<VecDeque<Entry>>,
    /// Indexed `tenant * n_classes + class`.
    by_tenant_class: Vec<VecDeque<Entry>>,
    class_live: Vec<usize>,
    shard_live: Vec<usize>,
    tenant_class_live: Vec<usize>,
    tenant_live: Vec<usize>,
    live: usize,
}

impl QueueView {
    pub(crate) fn new(n_classes: usize, n_shards: usize, n_tenants: usize) -> QueueView {
        let n_tenants = n_tenants.max(1);
        QueueView {
            slots: Vec::new(),
            free_slots: Vec::new(),
            by_class: (0..n_classes).map(|_| VecDeque::new()).collect(),
            by_shard: (0..n_shards.max(1)).map(|_| VecDeque::new()).collect(),
            by_tenant_class: (0..n_tenants * n_classes).map(|_| VecDeque::new()).collect(),
            class_live: vec![0; n_classes],
            shard_live: vec![0; n_shards.max(1)],
            tenant_class_live: vec![0; n_tenants * n_classes],
            tenant_live: vec![0; n_tenants],
            live: 0,
        }
    }

    /// Waiting requests (live entries only).
    pub fn len(&self) -> usize {
        self.live
    }

    pub fn is_empty(&self) -> bool {
        self.live == 0
    }

    /// Request classes this queue indexes (== the workload's classes).
    pub fn n_classes(&self) -> usize {
        self.by_class.len()
    }

    /// Shard residues this queue indexes (== the fleet size).
    pub fn n_shards(&self) -> usize {
        self.by_shard.len()
    }

    /// Live waiters of one class (== one seq-len bucket). O(1).
    pub fn class_len(&self, class: usize) -> usize {
        self.class_live.get(class).copied().unwrap_or(0)
    }

    /// Live waiters pinned to one shard residue. O(1).
    pub fn shard_len(&self, shard: usize) -> usize {
        self.shard_live.get(shard).copied().unwrap_or(0)
    }

    /// Tenant universe this queue indexes (== the workload's tenants).
    pub fn n_tenants(&self) -> usize {
        self.tenant_live.len()
    }

    /// Live waiters of one tenant across all classes. O(1).
    pub fn tenant_len(&self, tenant: usize) -> usize {
        self.tenant_live.get(tenant).copied().unwrap_or(0)
    }

    /// Live waiters of one (tenant, class) pair. O(1).
    pub fn tenant_class_len(&self, tenant: usize, class: usize) -> usize {
        if class >= self.by_class.len() {
            return 0;
        }
        self.tenant_class_live
            .get(tenant * self.by_class.len() + class)
            .copied()
            .unwrap_or(0)
    }

    fn entry_live(&self, e: Entry) -> bool {
        self.slots[e.slot as usize].gen == e.gen
    }

    fn front_of<'a>(&'a self, dq: &'a VecDeque<Entry>) -> Option<&'a Queued> {
        dq.iter()
            .find(|&&e| self.entry_live(e))
            .map(|e| &self.slots[e.slot as usize].q)
    }

    /// Oldest waiter of one class, in arrival order. O(1) after
    /// [`tidy`](QueueView::tidy); skips stale entries otherwise.
    pub fn class_head(&self, class: usize) -> Option<&Queued> {
        self.by_class.get(class).and_then(|dq| self.front_of(dq))
    }

    /// Oldest waiter pinned to `shard` (`id % n_shards == shard`).
    pub fn shard_head(&self, shard: usize) -> Option<&Queued> {
        self.by_shard.get(shard).and_then(|dq| self.front_of(dq))
    }

    /// Oldest waiter of one (tenant, class) pair, in arrival order.
    pub fn tenant_class_head(&self, tenant: usize, class: usize) -> Option<&Queued> {
        if class >= self.by_class.len() {
            return None;
        }
        self.by_tenant_class
            .get(tenant * self.by_class.len() + class)
            .and_then(|dq| self.front_of(dq))
    }

    /// Oldest waiter of one tenant: the minimum per-class head by
    /// (arrival, id). O(n_classes), like [`head`](QueueView::head).
    pub fn tenant_head(&self, tenant: usize) -> Option<&Queued> {
        (0..self.by_class.len())
            .filter_map(|c| self.tenant_class_head(tenant, c))
            .min_by_key(|q| (q.arrival, q.id))
    }

    /// Oldest waiter overall: the minimum class head by (arrival, id).
    /// O(n_classes) — classes are few and fixed, not O(queue).
    pub fn head(&self) -> Option<&Queued> {
        (0..self.by_class.len())
            .filter_map(|c| self.class_head(c))
            .min_by_key(|q| (q.arrival, q.id))
    }

    /// Admit one request. Amortized O(1). Must be called in (arrival,
    /// id) order — the deques materialize that order, they don't sort.
    /// Returns the entry's `(slot, generation)` handle, which
    /// [`cancel`](QueueView::cancel) accepts later (the fault layer's
    /// deadline expiry uses it; everyone else may ignore it).
    pub(crate) fn push(&mut self, q: Queued) -> (u32, u32) {
        let class = q.class;
        let shard = q.id % self.by_shard.len();
        let tenant = q.tenant;
        let tc = tenant * self.by_class.len() + class;
        let slot = match self.free_slots.pop() {
            Some(s) => {
                self.slots[s as usize].q = q;
                s
            }
            None => {
                let s = self.slots.len() as u32;
                self.slots.push(Slot { q, gen: 0 });
                s
            }
        };
        let e = Entry { slot, gen: self.slots[slot as usize].gen };
        self.by_class[class].push_back(e);
        self.by_shard[shard].push_back(e);
        self.by_tenant_class[tc].push_back(e);
        self.class_live[class] += 1;
        self.shard_live[shard] += 1;
        self.tenant_class_live[tc] += 1;
        self.tenant_live[tenant] += 1;
        self.live += 1;
        (e.slot, e.gen)
    }

    /// Free a slot: bump its generation (staling every deque entry that
    /// still points at it) and recycle it.
    fn kill(&mut self, slot: u32) -> Queued {
        let s = &mut self.slots[slot as usize];
        s.gen = s.gen.wrapping_add(1);
        let q = s.q.clone();
        self.free_slots.push(slot);
        self.class_live[q.class] -= 1;
        self.shard_live[q.id % self.by_shard.len()] -= 1;
        self.tenant_class_live[q.tenant * self.by_class.len() + q.class] -= 1;
        self.tenant_live[q.tenant] -= 1;
        self.live -= 1;
        q
    }

    /// Remove one still-waiting entry by its push handle. `Some` with
    /// the removed request if the handle is still live (the deadline
    /// expired before dispatch); `None` if the entry already left the
    /// queue — its slot was freed, or freed and recycled, since (the
    /// generation mismatch detects both). O(1); the deque twins go
    /// stale and are reclaimed lazily like any other removal.
    pub(crate) fn cancel(&mut self, slot: u32, gen: u32) -> Option<Queued> {
        match self.slots.get(slot as usize) {
            Some(s) if s.gen == gen => Some(self.kill(slot)),
            _ => None,
        }
    }

    /// Take the `n` oldest waiters of `class` (head-of-line within the
    /// class), appending them to `out` in arrival order. O(n) plus the
    /// stale entries it reclaims along the way.
    pub(crate) fn take_class(&mut self, class: usize, n: usize, out: &mut Vec<Queued>) {
        if class >= self.by_class.len() {
            return;
        }
        let mut taken = 0;
        while taken < n {
            let Some(e) = self.by_class[class].pop_front() else {
                break;
            };
            if !self.entry_live(e) {
                continue; // reclaim a stale twin left by a shard take
            }
            out.push(self.kill(e.slot));
            taken += 1;
        }
    }

    /// Take the `n` oldest waiters of one (tenant, class) pair,
    /// appending them to `out` in arrival order — the fairness-aware
    /// policies' take path. O(n) plus reclaimed stale entries.
    pub(crate) fn take_tenant_class(
        &mut self,
        tenant: usize,
        class: usize,
        n: usize,
        out: &mut Vec<Queued>,
    ) {
        if class >= self.by_class.len() {
            return;
        }
        let Some(tc) = tenant
            .checked_mul(self.by_class.len())
            .map(|b| b + class)
            .filter(|&tc| tc < self.by_tenant_class.len())
        else {
            return;
        };
        let mut taken = 0;
        while taken < n {
            let Some(e) = self.by_tenant_class[tc].pop_front() else {
                break;
            };
            if !self.entry_live(e) {
                continue; // reclaim a stale twin left by another take path
            }
            out.push(self.kill(e.slot));
            taken += 1;
        }
    }

    /// Take the oldest waiter pinned to `shard`, if any.
    pub(crate) fn take_shard(&mut self, shard: usize) -> Option<Queued> {
        if shard >= self.by_shard.len() {
            return None;
        }
        while let Some(e) = self.by_shard[shard].pop_front() {
            if self.entry_live(e) {
                return Some(self.kill(e.slot));
            }
        }
        None
    }

    /// Reclaim stale entries: pop dead fronts of every deque (so the
    /// read accessors are O(1)) and compact any deque that has gone
    /// mostly dead in the middle (amortized O(1) per push — each entry
    /// is compacted away at most once per constant number of pushes).
    pub(crate) fn tidy(&mut self) {
        let Self {
            slots,
            by_class,
            by_shard,
            by_tenant_class,
            class_live,
            shard_live,
            tenant_class_live,
            ..
        } = self;
        for (dq, &live) in by_class.iter_mut().zip(class_live.iter()) {
            tidy_one(slots, dq, live);
        }
        for (dq, &live) in by_shard.iter_mut().zip(shard_live.iter()) {
            tidy_one(slots, dq, live);
        }
        for (dq, &live) in by_tenant_class.iter_mut().zip(tenant_class_live.iter()) {
            tidy_one(slots, dq, live);
        }
    }

    /// Peak slab size: the high-water mark of simultaneously open
    /// requests (what "O(1) memory per open request" is measured by).
    pub fn peak_open(&self) -> usize {
        self.slots.len()
    }
}

/// Front-clean one deque, then compact it if it has gone mostly dead.
fn tidy_one(slots: &[Slot], dq: &mut VecDeque<Entry>, live: usize) {
    while let Some(&e) = dq.front() {
        if slots[e.slot as usize].gen == e.gen {
            break;
        }
        dq.pop_front();
    }
    if dq.len() > 2 * live + 8 {
        dq.retain(|e| slots[e.slot as usize].gen == e.gen);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(id: usize, class: usize, arrival: u64) -> Queued {
        qt(id, class, arrival, 0)
    }

    fn qt(id: usize, class: usize, arrival: u64, tenant: usize) -> Queued {
        Queued {
            id,
            class,
            bucket: 128 * (class + 1),
            arrival,
            tenant,
            first_arrival: arrival,
            attempts: 0,
        }
    }

    #[test]
    fn arrival_order_is_preserved_per_class_and_overall() {
        let mut v = QueueView::new(2, 2, 1);
        v.push(q(0, 1, 5));
        v.push(q(1, 0, 7));
        v.push(q(2, 1, 9));
        assert_eq!(v.len(), 3);
        assert_eq!(v.head().unwrap().id, 0, "overall head is the oldest");
        assert_eq!(v.class_head(0).unwrap().id, 1);
        assert_eq!(v.class_head(1).unwrap().id, 0);
        assert_eq!(v.class_len(1), 2);
        // shard residues: id 0 and 2 pin to shard 0, id 1 to shard 1
        assert_eq!(v.shard_head(0).unwrap().id, 0);
        assert_eq!(v.shard_head(1).unwrap().id, 1);
        assert_eq!(v.shard_len(0), 2);
    }

    #[test]
    fn take_class_pops_the_head_run_in_order() {
        let mut v = QueueView::new(2, 1, 1);
        for (id, class) in [(0, 0), (1, 1), (2, 0), (3, 0)] {
            v.push(q(id, class, id as u64));
        }
        let mut out = Vec::new();
        v.take_class(0, 2, &mut out);
        assert_eq!(out.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(v.len(), 2);
        assert_eq!(v.class_len(0), 1);
        // asking for more than live yields what exists
        out.clear();
        v.take_class(0, 99, &mut out);
        assert_eq!(out.iter().map(|r| r.id).collect::<Vec<_>>(), vec![3]);
        assert_eq!(v.class_len(0), 0);
        assert_eq!(v.head().unwrap().id, 1);
    }

    #[test]
    fn shard_take_skips_entries_taken_through_the_class_deque() {
        let mut v = QueueView::new(1, 2, 1);
        v.push(q(0, 0, 0));
        v.push(q(1, 0, 1));
        v.push(q(2, 0, 2));
        // take id 0 via the class path: its twin in shard deque 0 goes
        // stale and the next shard-0 take must skip to id 2
        let mut out = Vec::new();
        v.take_class(0, 1, &mut out);
        assert_eq!(out[0].id, 0);
        assert_eq!(v.take_shard(0).unwrap().id, 2);
        assert!(v.take_shard(0).is_none());
        assert_eq!(v.take_shard(1).unwrap().id, 1);
        assert!(v.is_empty());
    }

    #[test]
    fn slots_are_recycled_and_generations_prevent_aliasing() {
        let mut v = QueueView::new(1, 1, 1);
        let mut out = Vec::new();
        for round in 0..100usize {
            v.push(q(round, 0, round as u64));
            out.clear();
            v.take_class(0, 1, &mut out);
            assert_eq!(out[0].id, round);
            v.tidy();
        }
        // a drained ping-pong queue reuses one slot, not a hundred
        assert!(v.peak_open() <= 2, "slab grew to {}", v.peak_open());
        assert!(v.is_empty());
    }

    #[test]
    fn tidy_compacts_mostly_dead_deques() {
        let mut v = QueueView::new(2, 1, 1);
        // one old class-1 waiter, then a long run of class-0 requests
        v.push(q(0, 1, 0));
        for id in 1..200usize {
            v.push(q(id, 0, id as u64));
        }
        let mut out = Vec::new();
        v.take_class(0, 199, &mut out);
        assert_eq!(out.len(), 199);
        // the shard deque is now 199/200 stale behind a live front
        v.tidy();
        assert_eq!(v.shard_head(0).unwrap().id, 0);
        assert!(
            v.by_shard[0].len() <= 2 * v.shard_live[0] + 8,
            "compaction left {} entries for 1 live",
            v.by_shard[0].len()
        );
    }

    #[test]
    fn out_of_range_lookups_are_empty_not_panics() {
        let mut v = QueueView::new(1, 1, 1);
        assert_eq!(v.class_len(5), 0);
        assert!(v.class_head(5).is_none());
        assert!(v.shard_head(5).is_none());
        assert!(v.take_shard(5).is_none());
        let mut out = Vec::new();
        v.take_class(5, 1, &mut out);
        assert!(out.is_empty());
        assert!(v.head().is_none());
        // tenant lookups follow the same convention
        assert_eq!(v.tenant_len(7), 0);
        assert_eq!(v.tenant_class_len(7, 0), 0);
        assert!(v.tenant_class_head(7, 0).is_none());
        assert!(v.tenant_head(7).is_none());
        v.take_tenant_class(7, 0, 1, &mut out);
        v.take_tenant_class(0, 9, 1, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn cancel_by_handle_is_exact_and_generation_safe() {
        let mut v = QueueView::new(1, 1, 1);
        let (s0, g0) = v.push(q(0, 0, 0));
        let (s1, g1) = v.push(q(1, 0, 1));
        // a live handle cancels exactly its request
        assert_eq!(v.cancel(s0, g0).unwrap().id, 0);
        assert_eq!(v.len(), 1);
        // cancelling again is a no-op (slot freed, generation bumped)
        assert!(v.cancel(s0, g0).is_none());
        // a handle whose request was dispatched meanwhile is dead too
        let mut out = Vec::new();
        v.take_class(0, 1, &mut out);
        assert_eq!(out[0].id, 1);
        assert!(v.cancel(s1, g1).is_none());
        // recycling the slot must not revive the stale handle
        let (s2, g2) = v.push(q(2, 0, 2));
        assert_eq!(s2, s1, "freed slot is recycled");
        assert_ne!(g2, g1, "generation advanced");
        assert!(v.cancel(s1, g1).is_none());
        assert_eq!(v.len(), 1);
        // out-of-range slots are dead handles, not panics
        assert!(v.cancel(999, 0).is_none());
        // the cancelled entry's deque twins are stale, not live
        v.tidy();
        assert_eq!(v.head().unwrap().id, 2);
    }

    #[test]
    fn tenant_rings_track_per_tenant_arrival_order() {
        let mut v = QueueView::new(2, 1, 2);
        v.push(qt(0, 0, 0, 1));
        v.push(qt(1, 0, 1, 0));
        v.push(qt(2, 1, 2, 1));
        v.push(qt(3, 0, 3, 1));
        assert_eq!(v.n_tenants(), 2);
        assert_eq!(v.tenant_len(0), 1);
        assert_eq!(v.tenant_len(1), 3);
        assert_eq!(v.tenant_class_len(1, 0), 2);
        assert_eq!(v.tenant_class_head(1, 0).unwrap().id, 0);
        assert_eq!(v.tenant_head(1).unwrap().id, 0, "oldest across classes");
        assert_eq!(v.tenant_head(0).unwrap().id, 1);
        // the take path honors (tenant, class) head-of-line order
        let mut out = Vec::new();
        v.take_tenant_class(1, 0, 9, &mut out);
        assert_eq!(out.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 3]);
        assert_eq!(v.tenant_len(1), 1);
        assert_eq!(v.tenant_head(1).unwrap().id, 2);
        assert_eq!(v.len(), 2);
    }

    #[test]
    fn tenant_takes_stale_the_class_and_shard_twins() {
        let mut v = QueueView::new(1, 2, 2);
        v.push(qt(0, 0, 0, 0));
        v.push(qt(1, 0, 1, 1));
        v.push(qt(2, 0, 2, 0));
        // take tenant 0's head through the tenant ring: its twins in
        // the class and shard deques go stale and must be skipped
        let mut out = Vec::new();
        v.take_tenant_class(0, 0, 1, &mut out);
        assert_eq!(out[0].id, 0);
        assert_eq!(v.class_head(0).unwrap().id, 1);
        assert_eq!(v.take_shard(0).unwrap().id, 2);
        v.tidy();
        assert_eq!(v.tenant_len(0), 0);
        assert_eq!(v.tenant_len(1), 1);
        // and the reverse: a class take stales the tenant twin
        let mut out = Vec::new();
        v.take_class(0, 1, &mut out);
        assert_eq!(out[0].id, 1);
        assert!(v.tenant_head(1).is_none());
        assert!(v.is_empty());
    }
}
