//! Serving metrics: the [`ServeReport`], its percentile machinery, the
//! bounded-memory [`LatencyStore`], and the rolling
//! [`MetricsWindow`] / [`WindowSnapshot`] pair the online control plane
//! samples mid-run.
//!
//! Multi-tenant runs (trace replay tags every request with a tenant id)
//! additionally carry one [`TenantSummary`] per tenant — its own
//! `LatencyStore` percentiles, delivered throughput, and dominant
//! share — plus [`jain`]'s fairness index over delivered per-tenant
//! throughput. Single-tenant runs report one summary and a Jain index
//! of exactly 1.0, and every legacy arrival shape is single-tenant by
//! construction, so the pre-trace reports are unchanged.
//!
//! The store is what lets a million-request serve run keep O(1) memory
//! for latency accounting: up to [`EXACT_CAP`] samples it is a plain
//! `Vec<u64>` (sorted once at query time — small runs, and every
//! pre-existing test, stay **bit-identical** to the old grow-and-sort
//! path, including the 1-request degenerate identity). Past the cap it
//! folds into a fixed-size log₂-linear histogram (HdrHistogram-style:
//! 128 linear sub-buckets per power of two), whose percentile answers
//! carry a guaranteed **sub-1% relative error**: a bucket holding value
//! `v` spans at most `v/128` (0.79%), and the reported value is the
//! bucket's lower bound clamped into the observed `[min, max]` range —
//! so percentiles stay monotone in `q` and never exceed the true
//! maximum (the `p99 <= makespan` invariant survives the switch).

/// Samples kept exactly before the store folds into the histogram.
/// 8192 × 8 B = 64 KiB, comfortably above every test/bench workload
/// that asserts exact percentiles.
pub const EXACT_CAP: usize = 8192;

/// Linear sub-buckets per power-of-two range (the histogram's
/// resolution contract: relative error < 1/SUB_BUCKETS = 0.79%).
const SUB_BUCKETS: usize = 128;
const SUB_BITS: u32 = 7; // log2(SUB_BUCKETS)
/// Values below SUB_BUCKETS are their own bucket (exact); above, each
/// power-of-two range [2^k, 2^(k+1)) for k in 7..=63 splits into
/// SUB_BUCKETS linear buckets.
const BUCKETS: usize = SUB_BUCKETS + (64 - SUB_BITS as usize) * SUB_BUCKETS;

/// Bucket index of a value (log₂-linear, exact below SUB_BUCKETS).
fn bucket_of(v: u64) -> usize {
    if v < SUB_BUCKETS as u64 {
        return v as usize;
    }
    let msb = 63 - v.leading_zeros(); // >= SUB_BITS here
    let sub = ((v >> (msb - SUB_BITS)) & (SUB_BUCKETS as u64 - 1)) as usize;
    SUB_BUCKETS + (msb - SUB_BITS) as usize * SUB_BUCKETS + sub
}

/// Lower bound of a bucket (the reported representative).
fn bucket_lower(b: usize) -> u64 {
    if b < SUB_BUCKETS {
        return b as u64;
    }
    let e = (b - SUB_BUCKETS) / SUB_BUCKETS;
    let sub = (b - SUB_BUCKETS) % SUB_BUCKETS;
    ((SUB_BUCKETS + sub) as u64) << e
}

/// Bounded-memory latency accumulator: exact up to [`EXACT_CAP`]
/// samples, log₂-linear histogram beyond (see the module docs).
#[derive(Debug, Clone)]
pub struct LatencyStore {
    exact: Vec<u64>,
    sorted: bool,
    hist: Option<Box<[u64]>>,
    count: u64,
    sum: u128,
    min: u64,
    max: u64,
    cap: usize,
}

impl Default for LatencyStore {
    fn default() -> Self {
        LatencyStore::new()
    }
}

impl LatencyStore {
    pub fn new() -> LatencyStore {
        LatencyStore::with_cap(EXACT_CAP)
    }

    /// Custom exact-mode capacity (tests force the histogram path with
    /// a tiny cap; production uses [`EXACT_CAP`]).
    pub fn with_cap(cap: usize) -> LatencyStore {
        LatencyStore {
            exact: Vec::new(),
            sorted: true,
            hist: None,
            count: 0,
            sum: 0,
            min: u64::MAX,
            max: 0,
            cap,
        }
    }

    /// Record one latency sample.
    pub fn record(&mut self, v: u64) {
        self.count += 1;
        self.sum += v as u128;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
        match &mut self.hist {
            Some(h) => h[bucket_of(v)] += 1,
            None => {
                self.exact.push(v);
                self.sorted = false;
                if self.exact.len() > self.cap {
                    // fold into the fixed-size histogram and stay there
                    let mut h = vec![0u64; BUCKETS].into_boxed_slice();
                    for &x in &self.exact {
                        h[bucket_of(x)] += 1;
                    }
                    self.exact = Vec::new();
                    self.sorted = true;
                    self.hist = Some(h);
                }
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact running sum (independent of the storage mode).
    pub fn sum(&self) -> u128 {
        self.sum
    }

    /// Exact mean (the sum and count are tracked exactly in both modes).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Whether percentiles are currently exact (below the cap) or
    /// histogram-approximated (sub-1% relative error).
    pub fn is_exact(&self) -> bool {
        self.hist.is_none()
    }

    /// Nearest-rank percentile. Exact below the cap (identical to
    /// [`percentile`] over the sorted samples); histogram-approximated
    /// beyond it, monotone in `q` and clamped into `[min, max]`.
    pub fn percentile(&mut self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        match &self.hist {
            None => {
                if !self.sorted {
                    self.exact.sort_unstable();
                    self.sorted = true;
                }
                percentile(&self.exact, q)
            }
            Some(h) => {
                let n = self.count;
                let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
                let mut cum = 0u64;
                for (b, &c) in h.iter().enumerate() {
                    cum += c;
                    if cum >= rank {
                        return bucket_lower(b).clamp(self.min, self.max);
                    }
                }
                self.max
            }
        }
    }
}

/// Jain's fairness index over per-tenant delivered throughput:
/// `(Σx)² / (n · Σx²)`. 1.0 means perfectly even service, `1/n` means
/// one tenant got everything. Degenerate inputs (no tenants, or nothing
/// delivered at all) report 1.0 — an empty system is trivially fair.
pub fn jain(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if sq == 0.0 {
        return 1.0;
    }
    sum * sum / (xs.len() as f64 * sq)
}

/// Per-tenant slice of a [`ServeReport`]: the same latency/throughput
/// accounting the run-level report carries, restricted to one tenant's
/// completions. Built from a per-tenant [`LatencyStore`], so the
/// percentiles obey the same exact-below-[`EXACT_CAP`] /
/// sub-1%-beyond contract.
#[derive(Debug, Clone)]
pub struct TenantSummary {
    /// Tenant id (trace column `tenant`; 0 for every legacy workload).
    pub tenant: usize,
    /// Requests of this tenant served.
    pub served: usize,
    /// Served requests per second of makespan.
    pub req_per_s: f64,
    /// Latency percentiles over this tenant's completions, cycles.
    pub p50_cycles: u64,
    pub p99_cycles: u64,
    pub mean_latency_cycles: f64,
    /// DRF-style dominant share: the larger of this tenant's share of
    /// served requests and its share of simulated ops. In `[0, 1]`;
    /// 1.0 for the single-tenant degenerate case.
    pub dominant_share: f64,
}

/// One closed metrics window: the cheap mid-run snapshot a
/// [`super::control::Controller`] decides on, and the record streamed
/// to `serve --metrics-out`. All quantities cover exactly
/// `[start_cycles, end_cycles)` of simulated time.
#[derive(Debug, Clone)]
pub struct WindowSnapshot {
    /// Window ordinal, 0-based.
    pub index: usize,
    /// Window bounds in fleet cycles (half-open).
    pub start_cycles: u64,
    pub end_cycles: u64,
    /// Requests completed inside the window.
    pub completed: u64,
    /// Latency percentiles over the window's completions, cycles
    /// (0 when nothing completed).
    pub p50_cycles: u64,
    pub p99_cycles: u64,
    /// Busy shard-cycles / (unparked shards x window cycles): the
    /// fleet's busy fraction over the window.
    pub utilization: f64,
    /// Time-weighted mean queue depth over the window.
    pub mean_queue_depth: f64,
    /// Instantaneous queue depth at window close.
    pub queue_depth: usize,
    /// Active (dispatch) energy charged inside the window, J.
    pub active_j: f64,
    /// FD-SOI operating-point index in force at window close.
    pub op_index: usize,
    /// Parked shards at window close.
    pub parked: usize,
    /// Crashed (fault-injected, not yet recovered) shards at window
    /// close — what lets a controller distinguish a crash-induced
    /// backlog from plain overload and wake parked shards to absorb it.
    /// Always 0 without a fault plan.
    pub shards_down: usize,
    /// Completions inside the window split by tenant id (index =
    /// tenant), grown on demand as tenants complete. Sums to
    /// `completed` when every completion went through
    /// [`MetricsWindow::record_tenant`]; empty when a window closed
    /// with no completions.
    pub tenant_completed: Vec<u64>,
    /// Per-level interconnect utilization over the window (index
    /// order: board, pod, root — `net::LEVEL_NAMES`). Empty when the
    /// fleet has no topology attached (including `Flat`, which has no
    /// links to occupy).
    pub net_util: Vec<f64>,
}

/// Rolling accumulator behind [`WindowSnapshot`]: a per-window
/// [`LatencyStore`] plus exact integer busy/depth integrals. The serve
/// engine feeds it at the same points it feeds the run-level metrics,
/// so a window costs O(1) per event on top of the uncontrolled loop.
#[derive(Debug, Clone)]
pub struct MetricsWindow {
    start: u64,
    index: usize,
    lat: LatencyStore,
    busy_cycles: u128,
    depth_cycles: u128,
    active_j: f64,
    tenant_completed: Vec<u64>,
    /// Links per interconnect level (empty = no topology attached).
    net_links: Vec<u64>,
    /// Cumulative per-level link busy cycles at the window's start.
    net_busy_start: Vec<u64>,
    /// Latest cumulative per-level link busy cycles observed.
    net_busy_now: Vec<u64>,
}

impl MetricsWindow {
    pub fn new(start: u64) -> MetricsWindow {
        MetricsWindow {
            start,
            index: 0,
            lat: LatencyStore::new(),
            busy_cycles: 0,
            depth_cycles: 0,
            active_j: 0.0,
            tenant_completed: Vec::new(),
            net_links: Vec::new(),
            net_busy_start: Vec::new(),
            net_busy_now: Vec::new(),
        }
    }

    /// Declare the interconnect shape: links per level. Windows closed
    /// after this carry a `net_util` entry per level with at least one
    /// link (levels with zero links are skipped, mirroring
    /// `NetSummary::levels`).
    pub fn configure_net(&mut self, links: &[u64]) {
        self.net_links = links.to_vec();
        self.net_busy_start = vec![0; links.len()];
        self.net_busy_now = vec![0; links.len()];
    }

    /// Note the router's cumulative per-level busy cycles. The engine
    /// calls this right before every window close; utilization diffs
    /// consecutive readings, so the counters never reset.
    pub fn note_net_busy(&mut self, cum_busy: &[u64]) {
        self.net_busy_now.clear();
        self.net_busy_now.extend_from_slice(cum_busy);
    }

    /// Start of the currently open window, cycles.
    pub fn start(&self) -> u64 {
        self.start
    }

    /// Record one completion latency into the current window.
    pub fn record(&mut self, latency_cycles: u64) {
        self.lat.record(latency_cycles);
    }

    /// Record one completion latency, attributed to `tenant`. The
    /// per-tenant counter grows on demand, so the window never needs to
    /// know the tenant universe upfront.
    pub fn record_tenant(&mut self, latency_cycles: u64, tenant: usize) {
        self.lat.record(latency_cycles);
        if tenant >= self.tenant_completed.len() {
            self.tenant_completed.resize(tenant + 1, 0);
        }
        self.tenant_completed[tenant] += 1;
    }

    /// Integrate `dcycles` of simulated time with `busy` busy shards
    /// and `depth` queued requests.
    pub fn advance(&mut self, dcycles: u64, busy: usize, depth: usize) {
        self.busy_cycles += busy as u128 * dcycles as u128;
        self.depth_cycles += depth as u128 * dcycles as u128;
    }

    /// Charge active dispatch energy to the current window.
    pub fn add_active_j(&mut self, j: f64) {
        self.active_j += j;
    }

    /// Close the window at `end`, emit its snapshot, and reset the
    /// accumulator for the next window (which starts at `end`).
    pub fn close(
        &mut self,
        end: u64,
        alive_shards: usize,
        queue_depth: usize,
        op_index: usize,
        parked: usize,
        shards_down: usize,
    ) -> WindowSnapshot {
        let span = end.saturating_sub(self.start);
        let denom = alive_shards as u128 * span as u128;
        let net_util: Vec<f64> = self
            .net_links
            .iter()
            .zip(self.net_busy_now.iter().zip(self.net_busy_start.iter()))
            .filter(|&(&links, _)| links > 0)
            .map(|(&links, (&now, &at_start))| {
                let d = links as u128 * span as u128;
                if d == 0 {
                    0.0
                } else {
                    now.saturating_sub(at_start) as f64 / d as f64
                }
            })
            .collect();
        let snap = WindowSnapshot {
            index: self.index,
            start_cycles: self.start,
            end_cycles: end,
            completed: self.lat.count(),
            p50_cycles: self.lat.percentile(0.50),
            p99_cycles: self.lat.percentile(0.99),
            utilization: if denom == 0 {
                0.0
            } else {
                self.busy_cycles as f64 / denom as f64
            },
            mean_queue_depth: if span == 0 {
                0.0
            } else {
                self.depth_cycles as f64 / span as f64
            },
            queue_depth,
            active_j: self.active_j,
            op_index,
            parked,
            shards_down,
            tenant_completed: std::mem::take(&mut self.tenant_completed),
            net_util,
        };
        self.start = end;
        self.index += 1;
        self.lat = LatencyStore::new();
        self.busy_cycles = 0;
        self.depth_cycles = 0;
        self.active_j = 0.0;
        self.net_busy_start.clone_from(&self.net_busy_now);
        snap
    }
}

/// Control-plane addendum to a [`ServeReport`]: what the controller
/// did, window by window, and what it bought against the static-nominal
/// baseline. `None` on uncontrolled runs.
#[derive(Debug, Clone)]
pub struct ControlSummary {
    /// Controller that ran (`Controller::name`).
    pub controller: String,
    /// Decision cadence, fleet cycles.
    pub cadence_cycles: u64,
    /// Closed windows, in simulated-time order.
    pub windows: Vec<WindowSnapshot>,
    /// Operating-point switches the controller performed.
    pub dvfs_transitions: u64,
    /// Shard park / wake actions performed.
    pub parks: u64,
    pub wakes: u64,
    /// The p99 SLO held, if the policy declares one, cycles.
    pub slo_p99_cycles: Option<u64>,
    /// Whether the run-level p99 met that SLO.
    pub slo_met: Option<bool>,
    /// Energy the identical run costs at static nominal with no
    /// parking (the uncontrolled closed form), J.
    pub energy_j_static: f64,
    /// `energy_j_static - energy_j` — positive when the control plane
    /// saved energy.
    pub energy_saved_j: f64,
}

/// Aggregate result of one serve run — the serving-side analogue of
/// `coordinator::report::ModelReport`. Rendered by
/// `coordinator::report::render_serve`.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Scheduler that produced this run (`Scheduler::name`).
    pub scheduler: String,
    /// Fleet size.
    pub clusters: usize,
    /// Requests the workload offered.
    pub offered: usize,
    /// Requests actually served (== offered for the built-in
    /// schedulers; a custom scheduler that strands work serves fewer).
    pub served: usize,
    /// Cycle of the last completion.
    pub makespan_cycles: u64,
    /// Makespan in seconds at `freq_hz`.
    pub seconds: f64,
    /// Served requests per second.
    pub req_per_s: f64,
    /// Simulated-op throughput across the fleet.
    pub gops: f64,
    /// Total energy: per-request active energy + fleet idle floor.
    pub energy_j: f64,
    pub mj_per_req: f64,
    pub gopj: f64,
    /// Request latency (arrival -> completion) percentiles, in cycles.
    /// Exact up to [`EXACT_CAP`] served requests; beyond that,
    /// histogram-approximated with sub-1% relative error.
    pub p50_cycles: u64,
    pub p90_cycles: u64,
    pub p99_cycles: u64,
    pub mean_latency_cycles: f64,
    /// Time-weighted mean queue depth: depth integrated over the cycles
    /// between events, divided by the total simulated time.
    pub mean_queue_depth: f64,
    pub max_queue_depth: usize,
    /// Busy fraction of each cluster over the makespan.
    pub cluster_utilization: Vec<f64>,
    /// Class switches paid (weight re-staging between buckets).
    pub class_switches: u64,
    /// Dispatches issued (batches of >= 1 request).
    pub batches: u64,
    /// Per-tenant slice of the run, one entry per tenant id in the
    /// workload's tenant universe (a single entry for every legacy
    /// single-tenant arrival shape).
    pub tenants: Vec<TenantSummary>,
    /// Jain's fairness index over per-tenant served counts
    /// ([`jain`]); exactly 1.0 for single-tenant runs.
    pub fairness_jain: f64,
    pub freq_hz: f64,
    /// Control-plane timeline and savings summary; `None` when the run
    /// had no controller attached.
    pub control: Option<ControlSummary>,
    /// Interconnect block: per-level utilization plus routing/locality
    /// counters. `None` when the fleet has no topology attached; a
    /// `Flat` topology yields a summary with no levels and zero fetch
    /// cycles (the bit-identity contract, `tests/serve_equivalence.rs`).
    pub net: Option<crate::net::NetSummary>,
    /// Requests still waiting when the run ended. 0 on every drained
    /// run; nonzero means the horizon cut mid-backlog (a `run_until` +
    /// `finish` measurement) or work stranded behind permanent faults —
    /// either way throughput/latency figures describe a truncated
    /// stream and `render_serve_warning` yields a stderr diagnostic.
    pub final_queue_depth: usize,
    /// Fault/degradation block: admission, shed/expired/retry
    /// accounting and availability. `None` when the run had no fault
    /// layer attached; the empty-plan + `AdmitAll` configuration yields
    /// the all-zero summary with availability 1.0 while every other
    /// field stays bit-identical (the fault identity contract,
    /// `tests/serve_equivalence.rs`).
    pub fault: Option<super::fault::FaultSummary>,
    /// Observability block: the retained event stream, exact span
    /// totals and the per-shard phase conservation rows. `None` when
    /// the run was not observed; attaching it at any sampling rate
    /// changes no other field (the obs identity contract,
    /// `tests/obs_invariants.rs`).
    pub profile: Option<crate::obs::ProfileSummary>,
}

impl ServeReport {
    pub fn latency_ms(&self, cycles: u64) -> f64 {
        cycles as f64 / self.freq_hz * 1e3
    }

    pub fn p50_ms(&self) -> f64 {
        self.latency_ms(self.p50_cycles)
    }

    pub fn p90_ms(&self) -> f64 {
        self.latency_ms(self.p90_cycles)
    }

    pub fn p99_ms(&self) -> f64 {
        self.latency_ms(self.p99_cycles)
    }
}

/// Nearest-rank percentile over an ascending-sorted slice: the smallest
/// element whose rank covers fraction `q` of the population. Monotone
/// in `q` by construction, so p50 <= p90 <= p99 always holds.
pub fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let n = sorted.len();
    let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
    sorted[rank - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_rank_matches_hand_values() {
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 0.50), 50);
        assert_eq!(percentile(&v, 0.90), 90);
        assert_eq!(percentile(&v, 0.99), 99);
        assert_eq!(percentile(&v, 1.0), 100);
        let two = [10u64, 20];
        assert_eq!(percentile(&two, 0.50), 10);
        assert_eq!(percentile(&two, 0.99), 20);
        assert_eq!(percentile(&[7], 0.5), 7);
        assert_eq!(percentile(&[], 0.5), 0);
    }

    #[test]
    fn percentiles_are_monotone_in_q() {
        let v = [3u64, 3, 5, 9, 9, 14, 20, 20, 21, 40];
        let mut last = 0;
        for q in [0.01, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0] {
            let p = percentile(&v, q);
            assert!(p >= last, "q={q}: {p} < {last}");
            last = p;
        }
    }

    #[test]
    fn store_below_cap_is_bit_identical_to_sorting() {
        let mut s = LatencyStore::new();
        let mut v: Vec<u64> = (0..500).map(|i| (i * 7919 + 13) % 100_000).collect();
        for &x in &v {
            s.record(x);
        }
        v.sort_unstable();
        assert!(s.is_exact());
        assert_eq!(s.count(), 500);
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            assert_eq!(s.percentile(q), percentile(&v, q), "q={q}");
        }
        let mean = v.iter().sum::<u64>() as f64 / v.len() as f64;
        assert_eq!(s.mean().to_bits(), mean.to_bits());
    }

    #[test]
    fn store_beyond_cap_is_within_one_percent() {
        // tiny cap forces the histogram path; values span several
        // powers of two so every bucket shape is exercised
        let mut s = LatencyStore::with_cap(64);
        let mut v: Vec<u64> = (0..10_000u64).map(|i| 50 + (i * i) % 3_000_000).collect();
        for &x in &v {
            s.record(x);
        }
        assert!(!s.is_exact());
        v.sort_unstable();
        for q in [0.01, 0.5, 0.9, 0.99, 1.0] {
            let exact = percentile(&v, q);
            let approx = s.percentile(q);
            let rel = (exact as f64 - approx as f64).abs() / exact.max(1) as f64;
            assert!(rel < 0.01, "q={q}: exact {exact} vs approx {approx} ({rel:.4})");
            assert!(approx <= *v.last().unwrap(), "q={q}: approx beyond max");
        }
        // mean and count stay exact in histogram mode
        let mean = v.iter().sum::<u64>() as f64 / v.len() as f64;
        assert_eq!(s.mean().to_bits(), mean.to_bits());
        assert_eq!(s.count(), 10_000);
    }

    #[test]
    fn store_percentiles_stay_monotone_past_the_cap() {
        let mut s = LatencyStore::with_cap(16);
        for i in 0..2_000u64 {
            s.record(1 + (i * 2_654_435_761) % 1_000_000);
        }
        let mut last = 0;
        for q in [0.01, 0.1, 0.5, 0.75, 0.9, 0.99, 0.999, 1.0] {
            let p = s.percentile(q);
            assert!(p >= last, "q={q}: {p} < {last}");
            last = p;
        }
    }

    #[test]
    fn store_degenerate_single_value_is_exact() {
        let mut s = LatencyStore::new();
        s.record(12345);
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(s.percentile(q), 12345);
        }
        assert_eq!(s.mean(), 12345.0);
        let mut empty = LatencyStore::new();
        assert_eq!(empty.percentile(0.5), 0);
        assert_eq!(empty.mean(), 0.0);
    }

    #[test]
    fn jain_matches_hand_values() {
        // perfectly even -> 1.0, bit for bit
        assert_eq!(jain(&[5.0, 5.0, 5.0]).to_bits(), 1.0f64.to_bits());
        assert_eq!(jain(&[42.0]).to_bits(), 1.0f64.to_bits());
        // one tenant starved of n -> 1/n
        let skew = jain(&[10.0, 0.0]);
        assert!((skew - 0.5).abs() < 1e-12, "{skew}");
        // 9:1 split -> (10)^2 / (2 * 82) ~ 0.6098
        let nine_one = jain(&[9.0, 1.0]);
        assert!((nine_one - 100.0 / 164.0).abs() < 1e-12, "{nine_one}");
        // degenerate inputs are trivially fair
        assert_eq!(jain(&[]).to_bits(), 1.0f64.to_bits());
        assert_eq!(jain(&[0.0, 0.0]).to_bits(), 1.0f64.to_bits());
    }

    #[test]
    fn record_tenant_splits_the_window_count() {
        let mut w = MetricsWindow::new(0);
        w.record_tenant(100, 0);
        w.record_tenant(200, 2); // grows past the unseen tenant 1
        w.record_tenant(300, 0);
        let snap = w.close(1000, 1, 0, 2, 0, 0);
        assert_eq!(snap.completed, 3);
        assert_eq!(snap.tenant_completed, vec![2, 0, 1]);
        // the close reset the per-tenant counters with everything else
        w.record_tenant(50, 1);
        let next = w.close(2000, 1, 0, 2, 0, 0);
        assert_eq!(next.tenant_completed, vec![0, 1]);
    }

    #[test]
    fn window_net_util_diffs_cumulative_busy() {
        let mut w = MetricsWindow::new(0);
        w.configure_net(&[4, 4, 2]); // boards, board uplinks, pod uplinks
        w.note_net_busy(&[400, 100, 0]);
        let a = w.close(1000, 1, 0, 2, 0, 0);
        assert_eq!(a.net_util.len(), 3);
        assert_eq!(a.net_util[0], 400.0 / 4000.0);
        assert_eq!(a.net_util[1], 100.0 / 4000.0);
        assert_eq!(a.net_util[2], 0.0);
        // the counters are cumulative: the next window diffs against
        // the reading taken at its open
        w.note_net_busy(&[400, 100, 50]);
        let b = w.close(2000, 1, 0, 2, 0, 0);
        assert_eq!(b.net_util[0], 0.0);
        assert_eq!(b.net_util[2], 50.0 / 2000.0);
        // no topology configured -> no entries at all
        let mut plain = MetricsWindow::new(0);
        let c = plain.close(1000, 1, 0, 2, 0, 0);
        assert!(c.net_util.is_empty());
    }

    #[test]
    fn window_close_resets_every_accumulator() {
        let mut w = MetricsWindow::new(0);
        w.record(100);
        w.record(300);
        w.advance(50, 2, 4);
        w.add_active_j(1.5);
        let a = w.close(1000, 2, 3, 2, 0, 1);
        assert_eq!(a.index, 0);
        assert_eq!((a.start_cycles, a.end_cycles), (0, 1000));
        assert_eq!(a.completed, 2);
        assert_eq!(a.active_j, 1.5);
        assert_eq!(a.queue_depth, 3);
        assert_eq!(a.shards_down, 1, "close passes the down count through");
        // the next window starts where the last ended, fully cleared
        let b = w.close(2000, 2, 0, 2, 0, 0);
        assert_eq!(b.index, 1);
        assert_eq!(b.shards_down, 0);
        assert_eq!((b.start_cycles, b.end_cycles), (1000, 2000));
        assert_eq!(b.completed, 0);
        assert_eq!(b.p50_cycles, 0);
        assert_eq!(b.p99_cycles, 0);
        assert_eq!(b.active_j, 0.0);
        assert_eq!(b.utilization, 0.0);
        assert_eq!(b.mean_queue_depth, 0.0);
    }

    #[test]
    fn two_window_p99_trace_matches_hand_computation() {
        // window 0: latencies 1..=100 -> nearest-rank p99 = 99, p50 = 50
        // window 1: latencies {1000, 2000} -> p99 = 2000, p50 = 1000
        let mut w = MetricsWindow::new(0);
        for v in 1..=100u64 {
            w.record(v);
        }
        // 400 of 1000 cycles busy on 1 of 2 shards, depth 3 throughout
        w.advance(400, 1, 3);
        w.advance(600, 0, 3);
        let first = w.close(1000, 2, 0, 2, 0, 0);
        assert_eq!(first.p50_cycles, 50);
        assert_eq!(first.p99_cycles, 99);
        assert_eq!(first.utilization, 400.0 / 2000.0);
        assert_eq!(first.mean_queue_depth, 3.0);
        w.record(1000);
        w.record(2000);
        w.advance(500, 2, 0);
        let second = w.close(1500, 2, 0, 2, 0, 0);
        assert_eq!(second.p50_cycles, 1000);
        assert_eq!(second.p99_cycles, 2000);
        assert_eq!(second.utilization, 1.0);
        assert_eq!(second.completed, 2);
    }

    #[test]
    fn window_snapshots_are_deterministic_across_thread_counts() {
        // the same event feed must close to bit-identical snapshots no
        // matter how many OS threads compute them — windows hold no
        // global state, so fan-out (the explorer's) cannot perturb them
        fn run() -> Vec<(u64, u64, u64, u64, u64)> {
            let mut w = MetricsWindow::new(0);
            let mut out = Vec::new();
            for i in 0..5_000u64 {
                w.record(1 + (i * 2_654_435_761) % 1_000_000);
                w.advance(7, (i % 3) as usize, (i % 11) as usize);
                if i % 500 == 499 {
                    let s = w.close((i + 1) * 7, 3, (i % 11) as usize, 2, 0, 0);
                    out.push((
                        s.p50_cycles,
                        s.p99_cycles,
                        s.completed,
                        s.utilization.to_bits(),
                        s.mean_queue_depth.to_bits(),
                    ));
                }
            }
            out
        }
        let serial = run();
        for threads in [2usize, 4] {
            let handles: Vec<_> = (0..threads).map(|_| std::thread::spawn(run)).collect();
            for h in handles {
                assert_eq!(h.join().unwrap(), serial, "{threads}-thread run diverged");
            }
        }
    }

    #[test]
    fn bucket_layout_is_exact_below_subbuckets_and_bounded_above() {
        // small values are their own bucket
        for v in 0..SUB_BUCKETS as u64 {
            assert_eq!(bucket_lower(bucket_of(v)), v);
        }
        // larger values: the lower bound is <= v and within 1/128
        for v in [128u64, 129, 255, 256, 1000, 65_535, 1 << 30, u64::MAX / 2] {
            let b = bucket_of(v);
            let lo = bucket_lower(b);
            assert!(lo <= v, "v={v}: lower {lo}");
            assert!(
                (v - lo) as f64 / v as f64 < 1.0 / SUB_BUCKETS as f64,
                "v={v}: lower {lo} off by more than 1/128"
            );
            // and bucket boundaries are consistent: the lower bound of
            // a bucket maps back into the same bucket
            assert_eq!(bucket_of(lo), b, "v={v}");
        }
    }
}
