//! Serving metrics: the [`ServeReport`] and its percentile machinery.

/// Aggregate result of one serve run — the serving-side analogue of
/// `coordinator::report::ModelReport`. Rendered by
/// `coordinator::report::render_serve`.
#[derive(Debug, Clone)]
pub struct ServeReport {
    /// Scheduler that produced this run (`Scheduler::name`).
    pub scheduler: String,
    /// Fleet size.
    pub clusters: usize,
    /// Requests the workload offered.
    pub offered: usize,
    /// Requests actually served (== offered for the built-in
    /// schedulers; a custom scheduler that strands work serves fewer).
    pub served: usize,
    /// Cycle of the last completion.
    pub makespan_cycles: u64,
    /// Makespan in seconds at `freq_hz`.
    pub seconds: f64,
    /// Served requests per second.
    pub req_per_s: f64,
    /// Simulated-op throughput across the fleet.
    pub gops: f64,
    /// Total energy: per-request active energy + fleet idle floor.
    pub energy_j: f64,
    pub mj_per_req: f64,
    pub gopj: f64,
    /// Request latency (arrival -> completion) percentiles, in cycles.
    pub p50_cycles: u64,
    pub p90_cycles: u64,
    pub p99_cycles: u64,
    pub mean_latency_cycles: f64,
    /// Queue depth sampled at every event time (after admission,
    /// before dispatch).
    pub mean_queue_depth: f64,
    pub max_queue_depth: usize,
    /// Busy fraction of each cluster over the makespan.
    pub cluster_utilization: Vec<f64>,
    /// Class switches paid (weight re-staging between buckets).
    pub class_switches: u64,
    /// Dispatches issued (batches of >= 1 request).
    pub batches: u64,
    pub freq_hz: f64,
}

impl ServeReport {
    pub fn latency_ms(&self, cycles: u64) -> f64 {
        cycles as f64 / self.freq_hz * 1e3
    }

    pub fn p50_ms(&self) -> f64 {
        self.latency_ms(self.p50_cycles)
    }

    pub fn p90_ms(&self) -> f64 {
        self.latency_ms(self.p90_cycles)
    }

    pub fn p99_ms(&self) -> f64 {
        self.latency_ms(self.p99_cycles)
    }
}

/// Nearest-rank percentile over an ascending-sorted slice: the smallest
/// element whose rank covers fraction `q` of the population. Monotone
/// in `q` by construction, so p50 <= p90 <= p99 always holds.
pub fn percentile(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let n = sorted.len();
    let rank = ((q * n as f64).ceil() as usize).clamp(1, n);
    sorted[rank - 1]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_rank_matches_hand_values() {
        let v: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&v, 0.50), 50);
        assert_eq!(percentile(&v, 0.90), 90);
        assert_eq!(percentile(&v, 0.99), 99);
        assert_eq!(percentile(&v, 1.0), 100);
        let two = [10u64, 20];
        assert_eq!(percentile(&two, 0.50), 10);
        assert_eq!(percentile(&two, 0.99), 20);
        assert_eq!(percentile(&[7], 0.5), 7);
        assert_eq!(percentile(&[], 0.5), 0);
    }

    #[test]
    fn percentiles_are_monotone_in_q() {
        let v = [3u64, 3, 5, 9, 9, 14, 20, 20, 21, 40];
        let mut last = 0;
        for q in [0.01, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0] {
            let p = percentile(&v, q);
            assert!(p >= last, "q={q}: {p} < {last}");
            last = p;
        }
    }
}
