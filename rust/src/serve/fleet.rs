//! The cluster fleet: N shards of the heterogeneous cluster serving one
//! request stream under a [`Scheduler`].
//!
//! Each shard wraps a cached [`crate::pipeline::Compiled`] per request
//! class — the process-wide compiled-deployment cache means N shards
//! (and repeated `serve()` calls) share one deployment, one memoized
//! simulation, and one set of memoized serving constants per class
//! ([`crate::pipeline::Compiled::serve_constants`]): the second serve
//! of a class does **zero** engine work.
//!
//! The serve loop is event-driven over integer cycles and engineered
//! for million-request sweeps in seconds of host time with O(1) memory
//! per *open* request:
//!
//! - arrivals **stream lazily** from the seeded PRNG
//!   ([`Workload::stream`]) instead of materializing upfront; only
//!   closed-loop follow-ons go through a heap,
//! - waiting requests live in the bucketed [`QueueView`] (per-class and
//!   per-shard ring deques over a recycled slab) — admission, head
//!   lookups and O(batch) takes replace the flat `Vec` + `remove`
//!   (O(n) per dispatch, O(n²) under backlog) of the original design,
//! - shard wake-ups pop from a **min-heap** keyed by completion cycle,
//!   with the free count maintained incrementally instead of recounted
//!   per shard per event,
//! - latency percentiles come from the bounded
//!   [`super::metrics::LatencyStore`] (exact small runs, log₂-linear
//!   histogram beyond — sub-1% relative error) instead of a
//!   grow-sort-percentile `Vec`.
//!
//! Per-class service timing (derived once, memoized in the pipeline
//! cache):
//!
//! - `first` — cycles of one cold pass of the command stream
//!   (`Compiled::stats().cycles`).
//! - `steady` — the incremental cycles of one more request of the same
//!   class inside a batch. The serving runtime double-buffers request
//!   boundaries: request j+1's input staging (the stream's no-dep lead-in
//!   DMAs) prefetches under request j's compute, and request j's output
//!   writeback (the trailing `DmaOut`s) drains under request j+1's
//!   compute. Off the solo span schedule: `steady = max(compute_end -
//!   lead_in_end, busiest-resource cycles)`, clamped to `[1, first]` —
//!   the hidden lead/tail shrink the increment, while the bottleneck
//!   resource's busy time floors it (no resource can be oversubscribed).
//! - `switch` — weight re-staging DMA paid when a shard changes request
//!   class (a cold shard pays nothing: weights are staged at deploy
//!   time, which keeps the one-request/one-cluster case identical to
//!   `Compiled::simulate()`).
//!
//! Energy is per-request active energy (cores + ITA + DMA activity of
//! the class) plus the always-on idle floor over the whole fleet for
//! the whole makespan. `mean_queue_depth` is time-weighted: depth
//! integrated over the cycles between events, divided by the total
//! simulated time. The determinism contract is untouched — a serve run
//! is a pure function of (workload, geometry, scheduler), and the
//! retained pre-optimization loop ([`super::naive`]) is propcheck-held
//! to produce identical [`ServeReport`]s.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::deeploy::{DeployError, Target};
use crate::energy;
use crate::pipeline::{Pipeline, ServeConstants};
use crate::sim::ClusterConfig;

use super::metrics::{LatencyStore, ServeReport};
use super::queue::QueueView;
use super::scheduler::{Queued, Scheduler, Selection};
use super::workload::{Request, Workload};

/// Compile every request class of a workload through the (cached)
/// pipeline and return its serving constants. Shared with the retained
/// naive reference loop so both paths price requests identically.
pub(crate) fn class_runtimes(
    fleet: &Fleet,
    w: &Workload,
) -> Result<Vec<ServeConstants>, DeployError> {
    let mut classes = Vec::with_capacity(w.classes.len());
    for c in &w.classes {
        let mut pipeline = Pipeline::new(fleet.cluster.clone())
            .model(&c.model)
            .target(fleet.target)
            .layers(c.layers)
            .fuse_mha(fleet.fuse);
        if !fleet.use_cache {
            pipeline = pipeline.uncached();
        }
        let compiled = pipeline.compile()?;
        classes.push(compiled.serve_constants().clone());
    }
    Ok(classes)
}

#[derive(Debug, Clone, Default)]
struct Shard {
    class: Option<usize>,
    busy: u64,
}

/// N clusters of one geometry serving one workload.
pub struct Fleet {
    pub(crate) cluster: ClusterConfig,
    pub(crate) target: Target,
    pub(crate) n: usize,
    pub(crate) fuse: bool,
    pub(crate) use_cache: bool,
}

impl Fleet {
    /// A fleet of `n` identical clusters (geometry is first-class, as
    /// everywhere in the pipeline).
    pub fn new(cluster: ClusterConfig, target: Target, n: usize) -> Fleet {
        Fleet { cluster, target, n, fuse: true, use_cache: true }
    }

    /// Toggle the MHA fusion pass for every class compilation.
    pub fn fuse_mha(mut self, on: bool) -> Fleet {
        self.fuse = on;
        self
    }

    /// Bypass the compiled-deployment cache for every class compilation
    /// (mirrors `Pipeline::uncached` — geometry sweeps stay out of the
    /// never-evicting process-wide cache).
    pub fn uncached(mut self) -> Fleet {
        self.use_cache = false;
        self
    }

    pub fn clusters(&self) -> usize {
        self.n
    }

    /// Run the workload to completion under `sched` and report.
    pub fn serve(
        &self,
        w: &Workload,
        sched: &mut dyn Scheduler,
    ) -> Result<ServeReport, DeployError> {
        if self.n == 0 {
            return Err(DeployError::Builder("fleet size must be >= 1".into()));
        }
        w.validate()?;
        let freq = self.cluster.freq_hz;
        let classes = class_runtimes(self, w)?;

        // the arrival side: pre-known arrivals stream lazily in
        // (cycle, id) order; closed-loop follow-ons (issued from
        // completions) merge in through a heap, keyed the same way
        let mut crng = w.class_rng();
        let mut stream = w.stream(freq);
        let mut next_arrival: Option<Request> = stream.next(&mut crng);
        let mut followups: BinaryHeap<Reverse<(u64, usize, usize)>> = BinaryHeap::new();
        let mut issued = w.seed_count();
        let closed = w.is_closed_loop();
        let think = w.think_cycles();

        let mut queue = QueueView::new(w.classes.len(), self.n);
        let mut shards: Vec<Shard> = vec![Shard::default(); self.n];
        let mut shard_free: Vec<bool> = vec![true; self.n];
        let mut n_free = self.n;
        // busy shards wake through a min-heap of (completion, shard);
        // each busy shard is in the heap exactly once
        let mut wake: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();

        let mut lat = LatencyStore::new();
        let mut depth_cycles: u128 = 0;
        let mut depth_max = 0usize;
        let (mut switches, mut batches) = (0u64, 0u64);
        let mut active_j = 0.0f64;
        let mut ops_served = 0u64;
        let mut makespan = 0u64;
        let mut now = 0u64;
        let mut batch_buf: Vec<Queued> = Vec::new();

        loop {
            // wake every shard whose batch completed by now
            while let Some(&Reverse((t, si))) = wake.peek() {
                if t > now {
                    break;
                }
                wake.pop();
                shard_free[si] = true;
                n_free += 1;
            }

            // admit everything due by now, merging the lazy stream with
            // closed-loop follow-ons by (cycle, id) so the queue stays
            // in exact arrival order
            loop {
                let from_stream = match (&next_arrival, followups.peek()) {
                    (Some(r), Some(&Reverse((t, id, _)))) => (r.arrival, r.id) <= (t, id),
                    (Some(_), None) => true,
                    (None, Some(_)) => false,
                    (None, None) => break,
                };
                if from_stream {
                    let r = next_arrival.as_ref().unwrap();
                    if r.arrival > now {
                        break;
                    }
                    queue.push(Queued {
                        id: r.id,
                        class: r.class,
                        bucket: w.classes[r.class].bucket(),
                        arrival: r.arrival,
                    });
                    next_arrival = stream.next(&mut crng);
                } else {
                    let &Reverse((t, id, class)) = followups.peek().unwrap();
                    if t > now {
                        break;
                    }
                    followups.pop();
                    queue.push(Queued {
                        id,
                        class,
                        bucket: w.classes[class].bucket(),
                        arrival: t,
                    });
                }
            }
            depth_max = depth_max.max(queue.len());

            // dispatch until no free shard selects anything
            if n_free > 0 && !queue.is_empty() {
                loop {
                    let mut dispatched = false;
                    for si in 0..self.n {
                        if !shard_free[si] || queue.is_empty() {
                            continue;
                        }
                        queue.tidy();
                        let sel = sched.select(now, &queue, si, n_free, self.n);
                        batch_buf.clear();
                        match sel {
                            Selection::Idle => {}
                            Selection::Batch { class, take } => {
                                queue.take_class(class, take, &mut batch_buf);
                            }
                            Selection::Pinned => {
                                if let Some(q) = queue.take_shard(si) {
                                    batch_buf.push(q);
                                }
                            }
                        }
                        if batch_buf.is_empty() {
                            continue;
                        }
                        let class = batch_buf[0].class;
                        let rt = &classes[class];
                        let mut cost_switch = 0u64;
                        if let Some(cur) = shards[si].class {
                            if cur != class {
                                cost_switch = rt.switch_cycles;
                                switches += 1;
                            }
                        }
                        // cold shard: weights staged at deploy time —
                        // free, matching Compiled::simulate() semantics
                        shards[si].class = Some(class);
                        let start = now;
                        let base = start + cost_switch + rt.first;
                        let mut completion = base;
                        for (j, q) in batch_buf.iter().enumerate() {
                            let done = base + j as u64 * rt.steady;
                            completion = done;
                            lat.record(done - q.arrival);
                            if closed && issued < w.requests {
                                let id = issued;
                                issued += 1;
                                let next_class = w.sample_class(&mut crng);
                                followups.push(Reverse((done + think, id, next_class)));
                            }
                        }
                        active_j += rt.active_j * batch_buf.len() as f64;
                        ops_served += rt.ops * batch_buf.len() as u64;
                        shards[si].busy += completion - start;
                        shard_free[si] = false;
                        n_free -= 1;
                        wake.push(Reverse((completion, si)));
                        batches += 1;
                        makespan = makespan.max(completion);
                        dispatched = true;
                    }
                    if !dispatched || n_free == 0 {
                        break;
                    }
                }
            }

            // advance to the next event; every candidate is strictly in
            // the future (everything due was admitted or woken above),
            // so time always progresses
            let next_arr = match (&next_arrival, followups.peek()) {
                (Some(r), Some(&Reverse((t, _, _)))) => Some(r.arrival.min(t)),
                (Some(r), None) => Some(r.arrival),
                (None, Some(&Reverse((t, _, _)))) => Some(t),
                (None, None) => None,
            };
            let next_wake = wake.peek().map(|&Reverse((t, _))| t);
            let next = match (next_arr, next_wake) {
                (None, None) => break,
                (Some(a), None) => a,
                (None, Some(f)) => f,
                (Some(a), Some(f)) => a.min(f),
            };
            // time-weighted depth: the queue holds len() requests for
            // the whole [now, next) interval
            depth_cycles += queue.len() as u128 * (next - now) as u128;
            now = next;
        }

        let served = lat.count() as usize;
        let mean_latency_cycles = lat.mean();
        let total_time = now.max(1);
        let sec = makespan.max(1) as f64 / freq;
        let energy_j = active_j + energy::P_IDLE_W * sec * self.n as f64;
        Ok(ServeReport {
            scheduler: sched.name().to_string(),
            clusters: self.n,
            offered: w.requests,
            served,
            makespan_cycles: makespan,
            seconds: sec,
            req_per_s: served as f64 / sec,
            gops: ops_served as f64 / 1e9 / sec,
            energy_j,
            mj_per_req: energy_j * 1e3 / (served.max(1)) as f64,
            gopj: ops_served as f64 / 1e9 / energy_j,
            p50_cycles: lat.percentile(0.50),
            p90_cycles: lat.percentile(0.90),
            p99_cycles: lat.percentile(0.99),
            mean_latency_cycles,
            mean_queue_depth: depth_cycles as f64 / total_time as f64,
            max_queue_depth: depth_max,
            cluster_utilization: shards
                .iter()
                .map(|s| s.busy as f64 / makespan.max(1) as f64)
                .collect(),
            class_switches: switches,
            batches,
            freq_hz: freq,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{DINOV2S, MOBILEBERT};
    use crate::serve::scheduler::{DynamicBatch, Fifo, RoundRobin};
    use crate::serve::workload::RequestClass;

    fn fleet(n: usize) -> Fleet {
        Fleet::new(ClusterConfig::default(), Target::MultiCoreIta, n)
    }

    fn first_cycles(model: &crate::models::ModelConfig) -> u64 {
        Pipeline::new(ClusterConfig::default())
            .model(model)
            .target(Target::MultiCoreIta)
            .layers(1)
            .compile()
            .unwrap()
            .stats()
            .cycles
    }

    #[test]
    fn batching_two_same_class_requests_beats_fifo() {
        let classes = vec![RequestClass::new(&MOBILEBERT, 1)];
        let w = Workload::trace(classes, vec![(0, 0), (0, 0)]);
        let fifo = fleet(1).serve(&w, &mut Fifo).unwrap();
        let batch = fleet(1).serve(&w, &mut DynamicBatch::default()).unwrap();
        let first = first_cycles(&MOBILEBERT);
        // fifo: two cold passes back to back, no switch
        assert_eq!(fifo.makespan_cycles, 2 * first);
        assert_eq!(fifo.served, 2);
        assert_eq!(fifo.class_switches, 0);
        // batch: one cold pass + one steady-state increment (< first:
        // the lead-in staging and writeback tail hide in the batch)
        assert_eq!(batch.served, 2);
        assert_eq!(batch.batches, 1);
        assert!(
            batch.makespan_cycles < fifo.makespan_cycles,
            "batched {} !< fifo {}",
            batch.makespan_cycles,
            fifo.makespan_cycles
        );
        assert!(batch.makespan_cycles > first, "steady increment must cost > 0");
    }

    #[test]
    fn round_robin_runs_two_shards_in_parallel() {
        let classes = vec![RequestClass::new(&MOBILEBERT, 1)];
        let w = Workload::trace(classes, vec![(0, 0), (0, 0)]);
        let r = fleet(2).serve(&w, &mut RoundRobin).unwrap();
        assert_eq!(r.served, 2);
        assert_eq!(r.makespan_cycles, first_cycles(&MOBILEBERT));
        assert_eq!(r.cluster_utilization.len(), 2);
        assert!(r.cluster_utilization.iter().all(|&u| (u - 1.0).abs() < 1e-9));
    }

    #[test]
    fn class_switch_is_charged_between_buckets() {
        let classes =
            vec![RequestClass::new(&MOBILEBERT, 1), RequestClass::new(&DINOV2S, 1)];
        let w = Workload::trace(classes, vec![(0, 0), (0, 1)]);
        let r = fleet(1).serve(&w, &mut Fifo).unwrap();
        assert_eq!(r.served, 2);
        assert_eq!(r.class_switches, 1);
        let sum_first = first_cycles(&MOBILEBERT) + first_cycles(&DINOV2S);
        assert!(
            r.makespan_cycles > sum_first,
            "switch DMA must add cycles: {} <= {sum_first}",
            r.makespan_cycles
        );
    }

    #[test]
    fn zero_fleet_is_a_builder_error() {
        let w = Workload::single(&MOBILEBERT, 1);
        let r = Fleet::new(ClusterConfig::default(), Target::MultiCoreIta, 0)
            .serve(&w, &mut Fifo);
        assert!(matches!(r, Err(DeployError::Builder(_))));
    }

    #[test]
    fn mean_queue_depth_is_time_weighted() {
        // two simultaneous arrivals on one fifo cluster: request 1 runs
        // over [0, first) while request 2 waits (depth 1); request 2
        // then runs over [first, 2*first) with an empty queue (depth 0).
        // time-weighted mean = (1 * first + 0 * first) / 2*first = 0.5 —
        // the old event-weighted sampling had no such closed form
        let classes = vec![RequestClass::new(&MOBILEBERT, 1)];
        let w = Workload::trace(classes, vec![(0, 0), (0, 0)]);
        let r = fleet(1).serve(&w, &mut Fifo).unwrap();
        assert_eq!(r.served, 2);
        assert!(
            (r.mean_queue_depth - 0.5).abs() < 1e-12,
            "time-weighted mean depth {} != 0.5",
            r.mean_queue_depth
        );
        assert_eq!(r.max_queue_depth, 2, "both requests queued at t=0");

        // three arrivals: depths 2 then 1 then 0 over equal service
        // intervals -> mean (2 + 1 + 0) / 3 = 1
        let classes = vec![RequestClass::new(&MOBILEBERT, 1)];
        let w3 = Workload::trace(classes, vec![(0, 0), (0, 0), (0, 0)]);
        let r3 = fleet(1).serve(&w3, &mut Fifo).unwrap();
        assert!(
            (r3.mean_queue_depth - 1.0).abs() < 1e-12,
            "mean depth {} != 1.0",
            r3.mean_queue_depth
        );
    }

    #[test]
    fn second_serve_of_a_class_does_zero_engine_work() {
        // distinctive geometry: this test owns its cache entry
        let mut cluster = ClusterConfig::default();
        cluster.freq_hz = 423.875e6;
        let classes = vec![RequestClass::new(&MOBILEBERT, 1)];
        let w = Workload::trace(classes, vec![(0, 0), (40_000_000, 0)]);
        let f = Fleet::new(cluster.clone(), Target::MultiCoreIta, 1);
        let a = f.serve(&w, &mut Fifo).unwrap();
        let compiled = Pipeline::new(cluster)
            .model(&MOBILEBERT)
            .target(Target::MultiCoreIta)
            .layers(1)
            .compile()
            .unwrap();
        let after_first = compiled.sim_runs();
        assert!(
            (1..=2).contains(&after_first),
            "first serve runs the engine at most twice (stats + spans), saw {after_first}"
        );
        let b = f.serve(&w, &mut Fifo).unwrap();
        assert_eq!(
            compiled.sim_runs(),
            after_first,
            "second serve of a cached class must do zero engine work"
        );
        assert_eq!(a.makespan_cycles, b.makespan_cycles);
        assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
    }

    #[test]
    fn million_scale_streaming_keeps_queue_memory_at_the_backlog() {
        // not a perf bench (that's benches/perf_serve) — just the
        // structural guarantee that a large open-loop run streams: a
        // fast-draining workload never holds more than a few open
        // requests no matter how many it offers
        let classes = vec![RequestClass::new(&MOBILEBERT, 1)];
        // ~40 req/s against a ~780 inf/s single-layer class: no backlog
        let w = Workload::poisson(classes, 40.0, 4_000, 0x5EED);
        let r = fleet(1).serve(&w, &mut Fifo).unwrap();
        assert_eq!(r.served, 4_000);
        assert!(
            r.max_queue_depth < 64,
            "underloaded stream should never backlog: depth {}",
            r.max_queue_depth
        );
    }
}
