//! The cluster fleet: N shards of the heterogeneous cluster serving one
//! request stream under a [`Scheduler`].
//!
//! Each shard wraps a cached [`crate::pipeline::Compiled`] per request
//! class — the process-wide compiled-deployment cache means N shards
//! (and repeated `serve()` calls) share one deployment, one memoized
//! simulation, and one set of memoized serving constants per class
//! ([`crate::pipeline::Compiled::serve_constants`]): the second serve
//! of a class does **zero** engine work.
//!
//! The serve loop is event-driven over integer cycles and engineered
//! for million-request sweeps in seconds of host time with O(1) memory
//! per *open* request:
//!
//! - arrivals **stream lazily** from the seeded PRNG
//!   ([`Workload::stream`]) instead of materializing upfront; only
//!   closed-loop follow-ons go through a heap,
//! - waiting requests live in the bucketed [`QueueView`] (per-class and
//!   per-shard ring deques over a recycled slab) — admission, head
//!   lookups and O(batch) takes replace the flat `Vec` + `remove`
//!   (O(n) per dispatch, O(n²) under backlog) of the original design,
//! - shard wake-ups pop from a **min-heap** keyed by completion cycle,
//!   with the free count maintained incrementally instead of recounted
//!   per shard per event,
//! - latency percentiles come from the bounded
//!   [`super::metrics::LatencyStore`] (exact small runs, log₂-linear
//!   histogram beyond — sub-1% relative error) instead of a
//!   grow-sort-percentile `Vec`.
//!
//! Per-class service timing (derived once, memoized in the pipeline
//! cache):
//!
//! - `first` — cycles of one cold pass of the command stream
//!   (`Compiled::stats().cycles`).
//! - `steady` — the incremental cycles of one more request of the same
//!   class inside a batch. The serving runtime double-buffers request
//!   boundaries: request j+1's input staging (the stream's no-dep lead-in
//!   DMAs) prefetches under request j's compute, and request j's output
//!   writeback (the trailing `DmaOut`s) drains under request j+1's
//!   compute. Off the solo span schedule: `steady = max(compute_end -
//!   lead_in_end, busiest-resource cycles)`, clamped to `[1, first]` —
//!   the hidden lead/tail shrink the increment, while the bottleneck
//!   resource's busy time floors it (no resource can be oversubscribed).
//! - `switch` — weight re-staging DMA paid when a shard changes request
//!   class (a cold shard pays nothing: weights are staged at deploy
//!   time, which keeps the one-request/one-cluster case identical to
//!   `Compiled::simulate()`).
//!
//! Energy is per-request active energy (cores + ITA + DMA activity of
//! the class) plus the always-on idle floor over the whole fleet for
//! the whole makespan. `mean_queue_depth` is time-weighted: depth
//! integrated over the cycles between events, divided by the total
//! simulated time. The determinism contract is untouched — a serve run
//! is a pure function of (workload, geometry, scheduler), and the
//! retained pre-optimization loop ([`super::naive`]) is propcheck-held
//! to produce identical [`ServeReport`]s.
//!
//! ## The steppable engine
//!
//! The event loop lives in [`ServeEngine`]: one loop iteration is one
//! [`ServeEngine::step`] (wake due shards → admit due arrivals →
//! dispatch → advance to the next event), and [`Fleet::serve`] is a
//! thin driver (`new` → [`drain`](ServeEngine::drain) →
//! [`finish`](ServeEngine::finish)) that reproduces the pre-refactor
//! monolith **bit-identically** — `tests/serve_equivalence.rs`
//! propchecks the engine against the retained naive loop.
//! [`ServeEngine::run_until`] pauses *between* events at an arbitrary
//! simulated cycle: the time-weighted depth integral splits exactly
//! (integer arithmetic), the extra scheduler probe at the pause point
//! is a no-op for the time-invariant built-in schedulers, and nothing
//! else observes the pause — which is what lets
//! [`Fleet::serve_controlled`] interleave a
//! [`Controller`](super::control::Controller) on a fixed cadence
//! without perturbing the runs it leaves alone ([`StaticNominal`]
//! included).
//!
//! Controlled runs add a DVFS + autoscaling model on top (see
//! `serve/control.rs`): service cycles scale by the operating points'
//! clock ratio (intrinsic cycles are voltage-independent; the timeline
//! stays in base-clock cycles, `ceil`-scaled in exact integer math so
//! the base point is the identity), active energy scales as V², idle
//! power as V²·f integrated interval-by-interval over the *unparked*
//! shards, an operating-point switch charges each awake shard a one-off
//! [`DVFS_TRANSITION_CYCLES`] on its next dispatch, and a woken shard
//! re-stages weights (the class switch cost) on its next dispatch.
//! A run that never deviates from its base point with nothing parked
//! keeps the uncontrolled closed-form energy, bit for bit.
//!
//! [`StaticNominal`]: super::control::StaticNominal

use std::cmp::Reverse;
use std::collections::{BTreeSet, BinaryHeap};

use crate::deeploy::{DeployError, Target};
use crate::energy;
use crate::energy::operating_point::{NOMINAL_INDEX, OPERATING_POINTS};
use crate::fault::{LinkFault, ShardFault};
use crate::net::{Router, Topology};
use crate::obs::{EventKind, ObsConfig, ObsCtx};
use crate::pipeline::{Pipeline, ServeConstants};
use crate::sim::ClusterConfig;

use super::control::{ControlAction, ControlState, Controller, DVFS_TRANSITION_CYCLES};
use super::fault::{FaultConfig, FaultCtx, InFlight, InFlightReq};
use super::metrics::{
    jain, ControlSummary, LatencyStore, MetricsWindow, ServeReport, TenantSummary,
    WindowSnapshot,
};
use super::queue::QueueView;
use super::scheduler::{Queued, Scheduler, Selection};
use super::workload::{ArrivalStream, Request, Workload};

/// Compile every request class of a workload through the (cached)
/// pipeline and return its serving constants. Shared with the retained
/// naive reference loop so both paths price requests identically.
pub(crate) fn class_runtimes(
    fleet: &Fleet,
    w: &Workload,
) -> Result<Vec<ServeConstants>, DeployError> {
    let mut classes = Vec::with_capacity(w.classes.len());
    for c in &w.classes {
        let mut pipeline = Pipeline::new(fleet.cluster.clone())
            .model(&c.model)
            .target(fleet.target)
            .layers(c.layers)
            .fuse_mha(fleet.fuse);
        if !fleet.use_cache {
            pipeline = pipeline.uncached();
        }
        let compiled = pipeline.compile()?;
        classes.push(compiled.serve_constants().clone());
    }
    Ok(classes)
}

#[derive(Debug, Clone, Default)]
struct Shard {
    class: Option<usize>,
    busy: u64,
    /// Wake-up re-staging owed: the shard's next dispatch pays the
    /// class switch cost whatever class runs (the weights left the
    /// shard while it was parked, or died with it in a crash). Never
    /// set on uncontrolled, un-faulted runs.
    restage: bool,
    /// One-off DVFS transition penalty owed on the next dispatch.
    /// Never set on uncontrolled runs.
    dvfs_penalty: bool,
}

/// Scale intrinsic cycles onto the base-clock timeline for an
/// operating point: `ceil(cycles * base_hz / op_hz)` in exact integer
/// math — the identity when the frequencies match, more base-clock
/// cycles when the point runs slower.
fn scale_cycles(cycles: u64, base_hz: u64, op_hz: u64) -> u64 {
    if base_hz == op_hz {
        return cycles;
    }
    ((cycles as u128 * base_hz as u128).div_ceil(op_hz as u128)) as u64
}

/// Control-plane state of a controlled engine (absent on plain runs).
struct ControlCtx {
    cadence: u64,
    next_decision: u64,
    /// Operating point the timeline's clock corresponds to.
    base_op: usize,
    /// Operating point currently in force.
    op_index: usize,
    parked: Vec<bool>,
    n_parked: usize,
    window: MetricsWindow,
    windows: Vec<WindowSnapshot>,
    /// Idle energy integrated interval-by-interval at the in-force
    /// point over the unparked shards, J.
    idle_j: f64,
    /// Active energy with each batch scaled by its dispatch-time V², J.
    active_j_scaled: f64,
    dvfs_transitions: u64,
    parks: u64,
    wakes: u64,
    /// Whether the run ever left the base point or parked a shard —
    /// while false (and the base point is nominal), `finish` keeps the
    /// uncontrolled closed-form energy bit-for-bit.
    deviated: bool,
}

/// N clusters of one geometry serving one workload.
pub struct Fleet {
    pub(crate) cluster: ClusterConfig,
    pub(crate) target: Target,
    pub(crate) n: usize,
    pub(crate) fuse: bool,
    pub(crate) use_cache: bool,
    pub(crate) topology: Option<Topology>,
    pub(crate) obs: Option<ObsConfig>,
}

impl Fleet {
    /// A fleet of `n` identical clusters (geometry is first-class, as
    /// everywhere in the pipeline).
    pub fn new(cluster: ClusterConfig, target: Target, n: usize) -> Fleet {
        Fleet {
            cluster,
            target,
            n,
            fuse: true,
            use_cache: true,
            topology: None,
            obs: None,
        }
    }

    /// Attach the observability layer (see `crate::obs`): a structured
    /// event recorder plus cycle-attribution profiling. The recorder
    /// is write-only, so every serve driver stays bit-identical with
    /// it attached at any sampling rate — the report just gains a
    /// `profile` block (`tests/obs_invariants.rs` propchecks both).
    pub fn with_obs(mut self, cfg: ObsConfig) -> Fleet {
        self.obs = Some(cfg);
        self
    }

    /// Place the shards in an interconnect hierarchy (see `net`):
    /// request dispatch and weight re-staging DMA are then priced over
    /// the topology's links, and the report carries a `net` block.
    /// [`Topology::Flat`] attaches a linkless router whose paths cost
    /// nothing — the core report stays bit-identical to a fleet with no
    /// topology at all (propchecked in `tests/serve_equivalence.rs`).
    pub fn with_topology(mut self, topo: Topology) -> Fleet {
        self.topology = Some(topo);
        self
    }

    /// Toggle the MHA fusion pass for every class compilation.
    pub fn fuse_mha(mut self, on: bool) -> Fleet {
        self.fuse = on;
        self
    }

    /// Bypass the compiled-deployment cache for every class compilation
    /// (mirrors `Pipeline::uncached` — geometry sweeps stay out of the
    /// never-evicting process-wide cache).
    pub fn uncached(mut self) -> Fleet {
        self.use_cache = false;
        self
    }

    pub fn clusters(&self) -> usize {
        self.n
    }

    /// Run the workload to completion under `sched` and report — a
    /// thin driver over [`ServeEngine`], bit-identical to the
    /// pre-refactor monolithic loop.
    pub fn serve(
        &self,
        w: &Workload,
        sched: &mut dyn Scheduler,
    ) -> Result<ServeReport, DeployError> {
        let mut engine = ServeEngine::new(self, w, sched)?;
        engine.drain();
        Ok(engine.finish())
    }

    /// Run the workload with `controller` deciding every
    /// `cadence_cycles` of simulated time (see `serve/control.rs`).
    /// `base_op` is the operating-point table index the fleet clock
    /// corresponds to (the CLI's default geometry is the nominal
    /// corner, [`NOMINAL_INDEX`]; explore candidates pass their own).
    pub fn serve_controlled(
        &self,
        w: &Workload,
        sched: &mut dyn Scheduler,
        controller: &mut dyn Controller,
        cadence_cycles: u64,
        base_op: usize,
    ) -> Result<ServeReport, DeployError> {
        let mut engine = ServeEngine::new(self, w, sched)?;
        engine.enable_control(base_op, cadence_cycles);
        while let Some(t) = engine.next_decision() {
            if !engine.run_until(t) {
                break;
            }
            engine.control_decide(controller);
        }
        Ok(engine.finish_controlled(controller))
    }

    /// Run the workload under a fault/degradation config (see
    /// `serve/fault.rs`): plan-scheduled shard crashes and link
    /// faults, admission control, per-attempt deadlines and bounded
    /// retry/failover. `FaultConfig::default()` is provably inert —
    /// the report is bit-identical to [`Fleet::serve`]
    /// (`tests/serve_equivalence.rs` propchecks it).
    pub fn serve_faulted(
        &self,
        w: &Workload,
        sched: &mut dyn Scheduler,
        cfg: FaultConfig,
    ) -> Result<ServeReport, DeployError> {
        let mut engine = ServeEngine::new(self, w, sched)?;
        engine.enable_faults(cfg)?;
        engine.drain();
        Ok(engine.finish())
    }

    /// Faults plus the control plane on one run: the controller sees
    /// crash windows through [`WindowSnapshot::shards_down`] and (for
    /// `SloDvfs`) wakes parked shards to absorb failover backlog.
    pub fn serve_faulted_controlled(
        &self,
        w: &Workload,
        sched: &mut dyn Scheduler,
        controller: &mut dyn Controller,
        cadence_cycles: u64,
        base_op: usize,
        cfg: FaultConfig,
    ) -> Result<ServeReport, DeployError> {
        let mut engine = ServeEngine::new(self, w, sched)?;
        engine.enable_control(base_op, cadence_cycles);
        engine.enable_faults(cfg)?;
        while let Some(t) = engine.next_decision() {
            if !engine.run_until(t) {
                break;
            }
            engine.control_decide(controller);
        }
        Ok(engine.finish_controlled(controller))
    }
}

/// The steppable serve loop: all state of one run, advanced one event
/// at a time. `step()` executes exactly one iteration of the original
/// event loop — wake due shards, admit due arrivals, dispatch until no
/// free shard selects anything, advance to the next event — so
/// `new` + `drain` + `finish` is the pre-refactor `serve()`
/// bit-for-bit. `run_until(t)` additionally pauses *between* events at
/// cycle `t` (splitting the time-weighted integrals exactly), which is
/// the control plane's hook.
pub struct ServeEngine<'a> {
    fleet: &'a Fleet,
    w: &'a Workload,
    sched: &'a mut dyn Scheduler,
    classes: Vec<ServeConstants>,
    freq: f64,
    crng: crate::util::prng::XorShift64,
    stream: ArrivalStream,
    next_arrival: Option<Request>,
    followups: BinaryHeap<Reverse<(u64, usize, usize)>>,
    issued: usize,
    closed: bool,
    think: u64,
    queue: QueueView,
    shards: Vec<Shard>,
    shard_free: Vec<bool>,
    /// Free shard ids, ordered — `dispatch` walks it with a range
    /// cursor, reproducing the original ascending `0..n` offer scan at
    /// O(log n) per offer (the 10k-shard scaling requirement).
    free_set: BTreeSet<usize>,
    n_free: usize,
    wake: BinaryHeap<Reverse<(u64, usize)>>,
    lat: LatencyStore,
    /// Per-tenant latency stores (index = tenant id), sized to the
    /// workload's tenant universe and grown on demand — the stores are
    /// order-independent, which keeps the per-tenant percentiles
    /// bit-identical between this loop and the naive reference.
    lat_by_tenant: Vec<LatencyStore>,
    /// Per-tenant simulated ops served (the DRF work dimension).
    ops_by_tenant: Vec<u64>,
    depth_cycles: u128,
    depth_max: usize,
    switches: u64,
    batches: u64,
    active_j: f64,
    ops_served: u64,
    makespan: u64,
    now: u64,
    batch_buf: Vec<Queued>,
    done: bool,
    control: Option<ControlCtx>,
    /// Interconnect pricing + weight residency; `None` when the fleet
    /// has no topology attached (every path free, exactly as before).
    net: Option<Router>,
    /// Fault-injection state; `None` on un-faulted runs (no branch of
    /// the hot path does any fault arithmetic then).
    fault: Option<FaultCtx>,
    /// Observability state; `None` keeps the engine event-blind (the
    /// zero-cost default). Strictly write-only when present: no
    /// decision ever reads it, which is what makes observed runs
    /// bit-identical by construction.
    obs: Option<ObsCtx>,
}

impl<'a> ServeEngine<'a> {
    /// Validate and set up a run (compiles every class through the
    /// cached pipeline). No simulated time passes until `step()`.
    pub fn new(
        fleet: &'a Fleet,
        w: &'a Workload,
        sched: &'a mut dyn Scheduler,
    ) -> Result<ServeEngine<'a>, DeployError> {
        if fleet.n == 0 {
            return Err(DeployError::Builder("fleet size must be >= 1".into()));
        }
        if let Some(topo) = &fleet.topology {
            if let Some(cap) = topo.capacity() {
                if fleet.n > cap {
                    return Err(DeployError::Builder(format!(
                        "fleet of {} shards exceeds topology {} capacity {cap}",
                        fleet.n,
                        topo.label(),
                    )));
                }
            }
        }
        w.validate()?;
        let freq = fleet.cluster.freq_hz;
        let classes = class_runtimes(fleet, w)?;
        let net = fleet.topology.clone().map(|t| {
            Router::new(t, fleet.n, w.classes.len(), fleet.cluster.wide_axi_bytes)
        });
        sched.on_attach(fleet.n);
        let obs = fleet.obs.clone().map(|cfg| ObsCtx::new(cfg, fleet.n));
        // the arrival side: pre-known arrivals stream lazily in
        // (cycle, id) order; closed-loop follow-ons (issued from
        // completions) merge in through a heap, keyed the same way
        let mut crng = w.class_rng();
        let mut stream = w.stream(freq);
        let next_arrival = stream.next(&mut crng);
        Ok(ServeEngine {
            fleet,
            classes,
            freq,
            crng,
            stream,
            next_arrival,
            followups: BinaryHeap::new(),
            issued: w.seed_count(),
            closed: w.is_closed_loop(),
            think: w.think_cycles(),
            queue: QueueView::new(w.classes.len(), fleet.n, w.n_tenants()),
            shards: vec![Shard::default(); fleet.n],
            shard_free: vec![true; fleet.n],
            free_set: (0..fleet.n).collect(),
            n_free: fleet.n,
            wake: BinaryHeap::new(),
            lat: LatencyStore::new(),
            lat_by_tenant: vec![LatencyStore::new(); w.n_tenants()],
            ops_by_tenant: vec![0; w.n_tenants()],
            depth_cycles: 0,
            depth_max: 0,
            switches: 0,
            batches: 0,
            active_j: 0.0,
            ops_served: 0,
            makespan: 0,
            now: 0,
            batch_buf: Vec::new(),
            done: false,
            w,
            control: None,
            net,
            fault: None,
            obs,
        })
    }

    /// Attach control-plane bookkeeping (windowed metrics, DVFS and
    /// parking state). Call before the first `step()`.
    pub fn enable_control(&mut self, base_op: usize, cadence_cycles: u64) {
        let base = base_op.min(OPERATING_POINTS.len() - 1);
        let cadence = cadence_cycles.max(1);
        let mut window = MetricsWindow::new(self.now);
        if let Some(r) = &self.net {
            window.configure_net(&r.link_counts());
        }
        self.control = Some(ControlCtx {
            cadence,
            next_decision: self.now + cadence,
            base_op: base,
            op_index: base,
            parked: vec![false; self.fleet.n],
            n_parked: 0,
            window,
            windows: Vec::new(),
            idle_j: 0.0,
            active_j_scaled: 0.0,
            dvfs_transitions: 0,
            parks: 0,
            wakes: 0,
            deviated: false,
        });
    }

    /// Attach the fault layer (see `serve/fault.rs`). Call before the
    /// first `step()`. Validates the plan against the fleet size and
    /// rejects link events when no topology is attached (there are no
    /// links to fault).
    pub fn enable_faults(&mut self, cfg: FaultConfig) -> Result<(), DeployError> {
        cfg.plan.validate(self.fleet.n)?;
        if !cfg.plan.link_events.is_empty() && self.net.is_none() {
            return Err(DeployError::Builder(
                "fault plan schedules link events but the fleet has no topology \
                 (attach one with with_topology / --topology)"
                    .into(),
            ));
        }
        self.fault = Some(FaultCtx::new(cfg, self.fleet.n, self.w.n_tenants()));
        Ok(())
    }

    /// Attach the observability layer directly (the drivers pick it up
    /// from [`Fleet::with_obs`] automatically). Call before the first
    /// `step()`.
    pub fn enable_obs(&mut self, cfg: ObsConfig) {
        self.obs = Some(ObsCtx::new(cfg, self.fleet.n));
    }

    /// Current simulated time, cycles.
    pub fn now(&self) -> u64 {
        self.now
    }

    /// Whether every event has been processed.
    pub fn is_done(&self) -> bool {
        self.done
    }

    /// Next control decision point, when control is enabled.
    pub fn next_decision(&self) -> Option<u64> {
        self.control.as_ref().map(|c| c.next_decision)
    }

    /// One event-loop iteration. Returns `false` once the run is done.
    pub fn step(&mut self) -> bool {
        self.step_bounded(None)
    }

    /// Run every remaining event to completion.
    pub fn drain(&mut self) {
        while self.step() {}
    }

    /// Step until simulated time reaches `t` (pausing between events
    /// exactly at `t`; events *at* `t` belong to the next window).
    /// Returns `false` once the run is done.
    pub fn run_until(&mut self, t: u64) -> bool {
        while self.now < t {
            if !self.step_bounded(Some(t)) {
                return false;
            }
        }
        !self.done
    }

    /// One iteration, advancing at most to `limit`: when the next
    /// event lies beyond it, only the clock (and the time-weighted
    /// integrals) move — state is otherwise untouched, and the resumed
    /// iteration at `limit` re-probes the scheduler against an
    /// unchanged queue (a no-op for the time-invariant built-ins).
    fn step_bounded(&mut self, limit: Option<u64>) -> bool {
        if self.done {
            return false;
        }
        // wake every shard whose batch completed by now. Under a
        // deferring fault plan a wake is live only while the shard's
        // in-flight batch still completes at exactly this cycle — a
        // crash takes the batch and strands its wake, which is then
        // swallowed here without freeing anything
        while let Some(&Reverse((t, si))) = self.wake.peek() {
            if t > self.now {
                break;
            }
            self.wake.pop();
            if let Some(f) = &self.fault {
                if f.defers() {
                    let live = matches!(&f.in_flight[si], Some(fl) if fl.completion == t);
                    if !live {
                        continue;
                    }
                }
            }
            self.commit_shard(si);
            self.shard_free[si] = true;
            self.free_set.insert(si);
            self.n_free += 1;
            self.sched.note_free(si, true);
        }
        // plan events apply after the wakes: a batch completing at the
        // crash cycle commits first — the crash kills strictly
        // unfinished work only
        self.fault_events_due();
        self.admit_due();
        self.expire_due();
        self.depth_max = self.depth_max.max(self.queue.len());
        if self.n_free > 0 && !self.queue.is_empty() {
            self.dispatch();
        }
        // advance to the next event; every candidate is strictly in
        // the future (everything due was admitted, woken, applied or
        // expired above), so time always progresses
        let next_arr = match (&self.next_arrival, self.followups.peek()) {
            (Some(r), Some(&Reverse((t, _, _)))) => Some(r.arrival.min(t)),
            (Some(r), None) => Some(r.arrival),
            (None, Some(&Reverse((t, _, _)))) => Some(t),
            (None, None) => None,
        };
        // retries re-enter through admission once their backoff elapses
        let next_arr = match (next_arr, self.fault.as_ref().and_then(|f| f.next_retry_ready()))
        {
            (Some(a), Some(r)) => Some(a.min(r)),
            (x, None) => x,
            (None, y) => y,
        };
        let next_wake = self.wake.peek().map(|&Reverse((t, _))| t);
        // deadline expiries and plan events wake the loop too — but a
        // plan tail scheduled after the last request (nothing queued,
        // nothing arriving, nothing in flight) must not keep the clock
        // running; those events simply never fire
        let next_fault = match &self.fault {
            Some(f) if next_arr.is_some() || next_wake.is_some() || !self.queue.is_empty() => {
                let exp = f.expiry.front().map(|&(t, _, _)| t);
                match (exp, f.next_plan_event()) {
                    (Some(a), Some(b)) => Some(a.min(b)),
                    (x, None) => x,
                    (None, y) => y,
                }
            }
            _ => None,
        };
        let next = match [next_arr, next_wake, next_fault]
            .into_iter()
            .flatten()
            .min()
        {
            None => {
                self.done = true;
                return false;
            }
            Some(t) => t,
        };
        let target = match limit {
            Some(l) if next > l => l,
            _ => next,
        };
        self.advance_to(target);
        true
    }

    /// Admit everything due by now, merging the lazy stream,
    /// closed-loop follow-ons and backoff-expired retries by
    /// (cycle, id) so the queue stays in exact arrival order. Fresh
    /// arrivals pass the admission gate; retries never do — a request
    /// the fleet already accepted keeps its admission.
    fn admit_due(&mut self) {
        loop {
            let s = self.next_arrival.as_ref().map(|r| (r.arrival, r.id));
            let fu = self.followups.peek().map(|&Reverse((t, id, _))| (t, id));
            let rt = self.fault.as_ref().and_then(|f| {
                f.retry.peek().map(|&Reverse((t, id, _, _, _, _))| (t, id))
            });
            // source priority on an exact (cycle, id) tie:
            // stream, then follow-up, then retry
            let mut best: Option<((u64, usize), u8)> = None;
            for (key, src) in [(s, 0u8), (fu, 1), (rt, 2)] {
                if let Some(k) = key {
                    if best.map_or(true, |(bk, _)| k < bk) {
                        best = Some((k, src));
                    }
                }
            }
            let Some(((t, _), src)) = best else { break };
            if t > self.now {
                break;
            }
            match src {
                0 => {
                    let r = self.next_arrival.as_ref().unwrap();
                    let (id, class, arrival, tenant) = (r.id, r.class, r.arrival, r.tenant);
                    self.next_arrival = self.stream.next(&mut self.crng);
                    self.enqueue_fresh(id, class, arrival, tenant);
                }
                1 => {
                    let Reverse((t, id, class)) = self.followups.pop().unwrap();
                    // closed-loop follow-ons are single-tenant by
                    // construction (traces are open-loop)
                    self.enqueue_fresh(id, class, t, 0);
                }
                _ => {
                    let Reverse((ready, id, class, first_arrival, tenant, attempts)) =
                        self.fault.as_mut().unwrap().retry.pop().unwrap();
                    let q = Queued {
                        id,
                        class,
                        bucket: self.w.classes[class].bucket(),
                        arrival: ready,
                        first_arrival,
                        tenant,
                        attempts,
                    };
                    self.push_with_deadline(q, ready);
                    if let Some(o) = &mut self.obs {
                        let depth = self.queue.len();
                        o.record(ready, EventKind::Enqueued { id, depth });
                    }
                }
            }
        }
    }

    /// One fresh arrival: through the admission gate (a shed issues
    /// the closed-loop replacement so the run still offers exactly
    /// `requests` ids), then into the queue with its deadline armed.
    fn enqueue_fresh(&mut self, id: usize, class: usize, t: u64, tenant: usize) {
        if let Some(o) = &mut self.obs {
            o.record(t, EventKind::Arrived { id, class, tenant });
        }
        if let Some(f) = &mut self.fault {
            if !f.cfg.admission.admits(&self.queue, tenant) {
                f.note_shed(tenant);
                if let Some(o) = &mut self.obs {
                    o.record(t, EventKind::Shed { id, tenant });
                }
                if self.closed && self.issued < self.w.requests {
                    let nid = self.issued;
                    self.issued += 1;
                    let next_class = self.w.sample_class(&mut self.crng);
                    self.followups.push(Reverse((t + self.think, nid, next_class)));
                }
                return;
            }
        }
        let q = Queued {
            id,
            class,
            bucket: self.w.classes[class].bucket(),
            arrival: t,
            first_arrival: t,
            tenant,
            attempts: 0,
        };
        if let Some(o) = &mut self.obs {
            o.record(t, EventKind::Admitted { id });
        }
        self.push_with_deadline(q, t);
        if let Some(o) = &mut self.obs {
            let depth = self.queue.len();
            o.record(t, EventKind::Enqueued { id, depth });
        }
    }

    /// Push one entry, arming its per-attempt deadline. Admissions pop
    /// in (cycle, id) order, so the expiry deque stays monotone — a
    /// plain pop-front scan suffices.
    fn push_with_deadline(&mut self, q: Queued, t: u64) {
        let (slot, gen) = self.queue.push(q);
        if let Some(f) = &mut self.fault {
            if let Some(d) = f.cfg.deadline_cycles {
                f.expiry.push_back((t.saturating_add(d), slot, gen));
            }
        }
    }

    /// Cancel every queued entry whose deadline passed. A dead handle
    /// (generation mismatch) means the entry dispatched in time — the
    /// pop is free.
    fn expire_due(&mut self) {
        if self.fault.is_none() {
            return;
        }
        loop {
            let front = self.fault.as_ref().unwrap().expiry.front().copied();
            let Some((at, slot, gen)) = front else { break };
            if at > self.now {
                break;
            }
            self.fault.as_mut().unwrap().expiry.pop_front();
            if let Some(q) = self.queue.cancel(slot, gen) {
                if let Some(o) = &mut self.obs {
                    o.record(at, EventKind::Expired { id: q.id });
                }
                self.fault.as_mut().unwrap().expired_deadline += 1;
                if self.closed && self.issued < self.w.requests {
                    let nid = self.issued;
                    self.issued += 1;
                    let next_class = self.w.sample_class(&mut self.crng);
                    self.followups.push(Reverse((at + self.think, nid, next_class)));
                }
            }
        }
    }

    /// Apply every plan event due by now: shard crash/recover, then
    /// link degrade/outage (validated against the attached topology).
    fn fault_events_due(&mut self) {
        if self.fault.is_none() {
            return;
        }
        while let Some(ev) = self.fault.as_mut().unwrap().pop_shard_event(self.now) {
            match ev.kind {
                ShardFault::Crash => self.crash_shard(ev.shard),
                ShardFault::Recover => self.recover_shard(ev.shard),
            }
        }
        while let Some(ev) = self.fault.as_mut().unwrap().pop_link_event(self.now) {
            self.fault.as_mut().unwrap().link_events += 1;
            let router = self
                .net
                .as_mut()
                .expect("enable_faults rejects link events without a topology");
            match ev.kind {
                LinkFault::Degrade { slowdown } => router.set_link_slowdown(ev.level, slowdown),
                LinkFault::Outage { until_cycles } => {
                    router.set_link_outage(ev.level, until_cycles)
                }
            }
        }
    }

    /// A shard dies: its weight residency evaporates, finished work on
    /// the in-flight batch commits, the unfinished tail fails over.
    fn crash_shard(&mut self, si: usize) {
        if let Some(o) = &mut self.obs {
            o.record(self.now, EventKind::ShardCrash { shard: si });
        }
        // a parked shard crashes too — unpark its bookkeeping first so
        // parked and down never overlap (recovery puts it in the free
        // pool; the controller may re-park it at a later decision)
        if let Some(ctl) = &mut self.control {
            if ctl.parked[si] {
                ctl.parked[si] = false;
                ctl.n_parked -= 1;
                if let Some(o) = &mut self.obs {
                    o.note_woken(si, self.now);
                }
            }
        }
        let f = self.fault.as_mut().unwrap();
        f.down[si] = true;
        f.n_down += 1;
        f.crashes += 1;
        // weight residency dies with the shard
        if let Some(r) = &mut self.net {
            r.note_staged(si, None);
        }
        self.sched.note_staged(si, None);
        self.shards[si].class = None;
        if self.shard_free[si] {
            self.shard_free[si] = false;
            self.free_set.remove(&si);
            self.n_free -= 1;
            self.sched.note_free(si, false);
            return;
        }
        // busy crash: requests already finished (done <= now) commit,
        // the rest fail over; the stranded wake is swallowed when it
        // pops (its completion no longer matches any in-flight batch)
        let fl = self.fault.as_mut().unwrap().in_flight[si].take();
        if let Some(fl) = fl {
            let now = self.now;
            debug_assert!(fl.start <= now && now < fl.completion);
            let (class, ops) = (fl.class, fl.ops_per_req);
            if let Some(o) = &mut self.obs {
                // only the elapsed slice of the batch's transition
                // penalty stays attributed — the engine rolls the rest
                // of the interval back just below
                o.note_transition_truncated(si, fl.start + fl.net_delay, fl.penalty, now);
            }
            let mut killed = 0u64;
            for r in fl.reqs {
                if r.done <= now {
                    self.commit_request(class, ops, r);
                } else {
                    killed += 1;
                    if let Some(o) = &mut self.obs {
                        o.record(now, EventKind::Killed { id: r.id, shard: si });
                    }
                    self.route_retry(r.id, class, r.arrival, r.tenant, r.attempts + 1, now, true);
                }
            }
            self.fault.as_mut().unwrap().killed_in_flight += killed;
            // release the cycles the killed tail would have burned
            // (utilization reflects work the shard actually did; the
            // batch's energy stays charged — killed work burns joules)
            self.shards[si].busy -= fl.completion - now;
        }
    }

    /// A crashed shard comes back: cold (no weights), free, and owing
    /// a re-stage on its next dispatch — fetched from the nearest
    /// surviving holder, or the root weight store when the crash took
    /// the only copy.
    fn recover_shard(&mut self, si: usize) {
        if let Some(o) = &mut self.obs {
            o.record(self.now, EventKind::Recover { shard: si });
        }
        let f = self.fault.as_mut().unwrap();
        f.down[si] = false;
        f.n_down -= 1;
        f.recoveries += 1;
        self.shard_free[si] = true;
        self.free_set.insert(si);
        self.n_free += 1;
        self.sched.note_free(si, true);
        self.shards[si].restage = true;
    }

    /// Commit a deferred batch at its wake: every request settles
    /// (latency, ops, tenant metrics, closed-loop follow-on) unless a
    /// transient draw fails it into the retry path.
    fn commit_shard(&mut self, si: usize) {
        let fl = match &mut self.fault {
            Some(f) => f.in_flight[si].take(),
            None => return,
        };
        let Some(fl) = fl else { return };
        let (class, ops) = (fl.class, fl.ops_per_req);
        for r in fl.reqs {
            self.commit_request(class, ops, r);
        }
    }

    /// Settle one deferred request at its completion cycle.
    fn commit_request(&mut self, class: usize, ops: u64, r: InFlightReq) {
        let f = self.fault.as_mut().unwrap();
        if f.cfg.plan.transient_ppm > 0 && f.transient_fails() {
            f.transient_failures += 1;
            self.route_retry(r.id, class, r.arrival, r.tenant, r.attempts + 1, r.done, false);
            return;
        }
        self.lat.record(r.done - r.arrival);
        if let Some(o) = &mut self.obs {
            o.record(r.done, EventKind::Committed { id: r.id, latency: r.done - r.arrival });
        }
        if r.tenant >= self.lat_by_tenant.len() {
            self.lat_by_tenant.resize(r.tenant + 1, LatencyStore::new());
            self.ops_by_tenant.resize(r.tenant + 1, 0);
        }
        self.lat_by_tenant[r.tenant].record(r.done - r.arrival);
        self.ops_by_tenant[r.tenant] += ops;
        if let Some(ctl) = &mut self.control {
            ctl.window.record_tenant(r.done - r.arrival, r.tenant);
        }
        self.ops_served += ops;
        self.makespan = self.makespan.max(r.done);
        if self.closed && self.issued < self.w.requests {
            let id = self.issued;
            self.issued += 1;
            let next_class = self.w.sample_class(&mut self.crng);
            self.followups.push(Reverse((r.done + self.think, id, next_class)));
        }
    }

    /// Route one failed attempt: fail over to the retry heap with
    /// exponential backoff, or drop it with an exhausted budget (a
    /// closed loop issues the replacement either way at the end).
    #[allow(clippy::too_many_arguments)]
    fn route_retry(
        &mut self,
        id: usize,
        class: usize,
        first_arrival: u64,
        tenant: usize,
        attempts: u32,
        at: u64,
        crash_caused: bool,
    ) {
        let f = self.fault.as_mut().unwrap();
        if crash_caused {
            f.failed_over += 1;
        }
        if attempts > f.cfg.max_retries {
            f.retry_exhausted += 1;
            if let Some(o) = &mut self.obs {
                o.record(at, EventKind::Expired { id });
            }
            if self.closed && self.issued < self.w.requests {
                let nid = self.issued;
                self.issued += 1;
                let next_class = self.w.sample_class(&mut self.crng);
                self.followups.push(Reverse((at + self.think, nid, next_class)));
            }
            return;
        }
        let ready = at + f.backoff(attempts - 1);
        f.retried += 1;
        f.retry.push(Reverse((ready, id, class, first_arrival, tenant, attempts)));
        if let Some(o) = &mut self.obs {
            o.note_backoff(ready - at);
            let attempt = attempts as usize;
            o.record(at, EventKind::Retried { id, attempt, backoff: ready - at });
        }
    }

    /// Dispatch until no free shard selects anything. Free shards are
    /// offered in ascending id order through a `BTreeSet` range cursor:
    /// the exact offer sequence of the original `for si in 0..n` scan
    /// over free shards (the queue only shrinks inside a pass, so the
    /// original's empty-queue `continue` is this loop's `break`), at
    /// O(log n) per offer instead of O(n) — the event core stays
    /// O(log n) at 10k shards.
    fn dispatch(&mut self) {
        loop {
            let mut dispatched = false;
            let mut cursor = 0usize;
            while let Some(&si) = self.free_set.range(cursor..).next() {
                cursor = si + 1;
                if self.queue.is_empty() {
                    break;
                }
                self.queue.tidy();
                let sel =
                    self.sched.select(self.now, &self.queue, si, self.n_free, self.fleet.n);
                self.batch_buf.clear();
                match sel {
                    Selection::Idle => {}
                    Selection::Batch { class, take } => {
                        self.queue.take_class(class, take, &mut self.batch_buf);
                    }
                    Selection::TenantBatch { tenant, class, take } => {
                        self.queue.take_tenant_class(tenant, class, take, &mut self.batch_buf);
                    }
                    Selection::Pinned => {
                        if let Some(q) = self.queue.take_shard(si) {
                            self.batch_buf.push(q);
                        }
                    }
                }
                if self.batch_buf.is_empty() {
                    continue;
                }
                let class = self.batch_buf[0].class;
                let rt = &self.classes[class];
                // locality hit iff the shard already holds the class's
                // weights and owes no wake-up re-stage (read before the
                // flags mutate below)
                let hit = self.shards[si].class == Some(class) && !self.shards[si].restage;
                // DVFS: service cycles scale by the clock ratio
                // (identity at the base point), energy by V²
                let (first, steady, switch_cost, escale) = match &self.control {
                    Some(c) => {
                        let fb = OPERATING_POINTS[c.base_op].freq_hz as u64;
                        let fo = OPERATING_POINTS[c.op_index].freq_hz as u64;
                        (
                            scale_cycles(rt.first, fb, fo),
                            scale_cycles(rt.steady, fb, fo),
                            scale_cycles(rt.switch_cycles, fb, fo),
                            OPERATING_POINTS[c.op_index].energy_scale(),
                        )
                    }
                    None => (rt.first, rt.steady, rt.switch_cycles, 1.0),
                };
                let mut cost_switch = 0u64;
                if self.shards[si].restage {
                    // waking re-staged the weights: pay the staging DMA
                    // whatever class runs next (not a class switch)
                    self.shards[si].restage = false;
                    cost_switch = switch_cost;
                } else if let Some(cur) = self.shards[si].class {
                    if cur != class {
                        cost_switch = switch_cost;
                        self.switches += 1;
                    }
                }
                let mut penalty = 0u64;
                if self.shards[si].dvfs_penalty {
                    self.shards[si].dvfs_penalty = false;
                    penalty = DVFS_TRANSITION_CYCLES;
                }
                // cold shard: weights staged at deploy time —
                // free, matching Compiled::simulate() semantics
                self.shards[si].class = Some(class);
                let start = self.now;
                // interconnect: the batch's token ids ride the dispatch
                // path, and a re-stage fetches the weights from the
                // nearest holder — the dispatch starts once both have
                // landed. Links update dispatch-then-restage, a fixed
                // order, so contention is deterministic. `Flat` prices
                // both paths to `start` and touches no link.
                // the re-stage fetch path for the observability
                // event — read before `note_staged` below makes this
                // shard its own nearest holder
                let restage_hops = match (&self.net, &self.obs) {
                    (Some(router), Some(_)) if cost_switch > 0 => {
                        router.restage_hops(class, si)
                    }
                    _ => 0,
                };
                let mut net_delay = 0u64;
                if let Some(router) = &mut self.net {
                    let tokens =
                        (self.batch_buf.len() * self.batch_buf[0].bucket * 4) as u64;
                    let t_req = router.dispatch_arrival(si, tokens, start);
                    let t_weights = if cost_switch > 0 {
                        let bytes = cost_switch * self.fleet.cluster.wide_axi_bytes as u64;
                        router.restage_arrival(si, class, bytes, start)
                    } else {
                        start
                    };
                    net_delay = t_req.max(t_weights) - start;
                    router.record_dispatch(hit);
                    router.note_staged(si, Some(class));
                }
                let base = start + net_delay + penalty + cost_switch + first;
                if let Some(o) = &mut self.obs {
                    o.note_transition(si, penalty);
                    if cost_switch > 0 {
                        let kind = EventKind::Restaged {
                            shard: si,
                            class,
                            hops: restage_hops,
                            cycles: cost_switch,
                        };
                        o.record(start, kind);
                    }
                }
                let mut completion = base;
                let defer = self.fault.as_ref().map_or(false, |f| f.defers());
                if defer {
                    // deferred commit: results are withheld until the
                    // wake pops — the window in which a crash or
                    // transient failure can void them. Latency, ops
                    // and follow-ons settle per request at commit;
                    // energy stays charged at dispatch below (killed
                    // work burns real joules)
                    let mut reqs = Vec::with_capacity(self.batch_buf.len());
                    for (j, q) in self.batch_buf.iter().enumerate() {
                        let done = base + j as u64 * steady;
                        completion = done;
                        if let Some(o) = &mut self.obs {
                            let queue_wait = start - q.arrival;
                            let compute = first + j as u64 * steady;
                            o.note_request_dispatch(queue_wait, net_delay, cost_switch, compute);
                            let kind = EventKind::Dispatched {
                                id: q.id,
                                shard: si,
                                net_delay,
                                queue_wait,
                                span: done - start,
                            };
                            o.record(start, kind);
                        }
                        reqs.push(InFlightReq {
                            id: q.id,
                            done,
                            arrival: q.first_arrival,
                            tenant: q.tenant,
                            attempts: q.attempts,
                        });
                    }
                    self.fault.as_mut().unwrap().in_flight[si] = Some(InFlight {
                        class,
                        start,
                        completion,
                        ops_per_req: rt.ops,
                        net_delay,
                        penalty,
                        reqs,
                    });
                } else {
                    for (j, q) in self.batch_buf.iter().enumerate() {
                        let done = base + j as u64 * steady;
                        completion = done;
                        if let Some(o) = &mut self.obs {
                            let queue_wait = start - q.arrival;
                            let compute = first + j as u64 * steady;
                            o.note_request_dispatch(queue_wait, net_delay, cost_switch, compute);
                            let kind = EventKind::Dispatched {
                                id: q.id,
                                shard: si,
                                net_delay,
                                queue_wait,
                                span: done - start,
                            };
                            o.record(start, kind);
                            let latency = done - q.arrival;
                            o.record(done, EventKind::Committed { id: q.id, latency });
                        }
                        self.lat.record(done - q.arrival);
                        if q.tenant >= self.lat_by_tenant.len() {
                            self.lat_by_tenant.resize(q.tenant + 1, LatencyStore::new());
                            self.ops_by_tenant.resize(q.tenant + 1, 0);
                        }
                        self.lat_by_tenant[q.tenant].record(done - q.arrival);
                        self.ops_by_tenant[q.tenant] += rt.ops;
                        if let Some(ctl) = &mut self.control {
                            ctl.window.record_tenant(done - q.arrival, q.tenant);
                        }
                        if self.closed && self.issued < self.w.requests {
                            let id = self.issued;
                            self.issued += 1;
                            let next_class = self.w.sample_class(&mut self.crng);
                            self.followups.push(Reverse((done + self.think, id, next_class)));
                        }
                    }
                }
                let batch_j = rt.active_j * self.batch_buf.len() as f64;
                self.active_j += batch_j;
                if let Some(ctl) = &mut self.control {
                    ctl.active_j_scaled += batch_j * escale;
                    ctl.window.add_active_j(batch_j * escale);
                }
                if !defer {
                    self.ops_served += rt.ops * self.batch_buf.len() as u64;
                }
                self.shards[si].busy += completion - start;
                self.shard_free[si] = false;
                self.free_set.remove(&si);
                self.n_free -= 1;
                self.sched.note_free(si, false);
                self.sched.note_staged(si, Some(class));
                self.wake.push(Reverse((completion, si)));
                self.batches += 1;
                if !defer {
                    self.makespan = self.makespan.max(completion);
                }
                dispatched = true;
            }
            if !dispatched || self.n_free == 0 {
                break;
            }
        }
    }

    /// Move the clock to `t`, integrating the time-weighted metrics
    /// over `[now, t)`. Splitting one interval at a pause point is
    /// exact: the integrals are integer-valued.
    fn advance_to(&mut self, t: u64) {
        let d = t - self.now;
        self.depth_cycles += self.queue.len() as u128 * d as u128;
        let n_down = self.fault.as_ref().map_or(0, |f| f.n_down);
        if let Some(ctl) = &mut self.control {
            // down shards are neither free nor parked, but they do no
            // work — utilization counts live shards only. They stay in
            // the idle-power floor below: a conservative choice (a
            // crashed node's PSU typically still burns idle watts)
            let busy = self.fleet.n - self.n_free - ctl.n_parked - n_down;
            ctl.window.advance(d, busy, self.queue.len());
            let alive = (self.fleet.n - ctl.n_parked) as f64;
            ctl.idle_j += OPERATING_POINTS[ctl.op_index].idle_power_w()
                * (d as f64 / self.freq)
                * alive;
        }
        self.now = t;
    }

    /// Close the current metrics window, let `controller` decide, and
    /// apply its action at this window boundary.
    pub fn control_decide(&mut self, controller: &mut dyn Controller) {
        let state = {
            let Some(ctl) = &self.control else { return };
            ControlState {
                now_cycles: self.now,
                op_index: ctl.op_index,
                parked: ctl.n_parked,
                shards: self.fleet.n,
                queue_depth: self.queue.len(),
            }
        };
        let action = {
            let queue_depth = self.queue.len();
            let n = self.fleet.n;
            let net_busy = self.net.as_ref().map(|r| r.cum_busy());
            let n_down = self.fault.as_ref().map_or(0, |f| f.n_down);
            let ctl = self.control.as_mut().unwrap();
            if let Some(b) = &net_busy {
                ctl.window.note_net_busy(b);
            }
            let alive = n - ctl.n_parked;
            let snap = ctl.window.close(
                state.now_cycles,
                alive,
                queue_depth,
                ctl.op_index,
                ctl.n_parked,
                n_down,
            );
            let action = controller.decide(&snap, &state);
            ctl.windows.push(snap);
            ctl.next_decision = ctl.next_decision.saturating_add(ctl.cadence);
            action
        };
        self.apply(action);
    }

    /// Clamp and apply a control action: switch the operating point
    /// (penalty on every awake shard's next dispatch) and park/wake
    /// shards (park free shards only, highest index first; wake lowest
    /// first, owing a weight re-stage; one shard always stays awake).
    fn apply(&mut self, action: ControlAction) {
        let n = self.fleet.n;
        let now = self.now;
        let Some(ctl) = &mut self.control else { return };
        let op = action.op_index.min(OPERATING_POINTS.len() - 1);
        if op != ctl.op_index {
            let from = ctl.op_index;
            ctl.op_index = op;
            ctl.dvfs_transitions += 1;
            ctl.deviated = true;
            if let Some(o) = &mut self.obs {
                o.record(now, EventKind::DvfsTransition { from, to: op });
            }
            for si in 0..n {
                if !ctl.parked[si] {
                    self.shards[si].dvfs_penalty = true;
                }
            }
        }
        let want = action.parked.min(n.saturating_sub(1));
        while ctl.n_parked < want {
            // busy shards finish their batch and stay awake until a
            // later decision finds them free
            let found =
                (0..n).rev().find(|&si| !ctl.parked[si] && self.shard_free[si]);
            let Some(si) = found else { break };
            ctl.parked[si] = true;
            ctl.n_parked += 1;
            self.shard_free[si] = false;
            self.free_set.remove(&si);
            self.n_free -= 1;
            self.sched.note_free(si, false);
            // a parked shard powers down its weight copy: evict it from
            // the residency maps (the wake re-stage pays to bring the
            // weights back, whatever class runs next)
            if let Some(r) = &mut self.net {
                r.note_staged(si, None);
            }
            self.sched.note_staged(si, None);
            if let Some(o) = &mut self.obs {
                o.note_parked(si, now);
                o.record(now, EventKind::Park { shard: si });
            }
            ctl.parks += 1;
            ctl.deviated = true;
        }
        while ctl.n_parked > want {
            let si = (0..n).find(|&si| ctl.parked[si]).unwrap();
            ctl.parked[si] = false;
            ctl.n_parked -= 1;
            self.shard_free[si] = true;
            self.free_set.insert(si);
            self.n_free += 1;
            self.sched.note_free(si, true);
            self.shards[si].restage = true;
            if let Some(o) = &mut self.obs {
                o.note_woken(si, now);
                o.record(now, EventKind::Wake { shard: si });
            }
            ctl.wakes += 1;
            ctl.deviated = true;
        }
    }

    /// Finish an uncontrolled run: the report of the pre-refactor
    /// loop, field for field.
    pub fn finish(mut self) -> ServeReport {
        self.build_report(None)
    }

    /// Finish a controlled run, attaching the [`ControlSummary`].
    pub fn finish_controlled(mut self, controller: &dyn Controller) -> ServeReport {
        self.build_report(Some((controller.name(), controller.slo_p99_cycles())))
    }

    fn build_report(&mut self, meta: Option<(&str, Option<u64>)>) -> ServeReport {
        // close the trailing partial window
        let net_busy = self.net.as_ref().map(|r| r.cum_busy());
        let n_down = self.fault.as_ref().map_or(0, |f| f.n_down);
        if let Some(ctl) = &mut self.control {
            if self.now > ctl.window.start() {
                if let Some(b) = &net_busy {
                    ctl.window.note_net_busy(b);
                }
                let alive = self.fleet.n - ctl.n_parked;
                let snap = ctl.window.close(
                    self.now,
                    alive,
                    self.queue.len(),
                    ctl.op_index,
                    ctl.n_parked,
                    n_down,
                );
                ctl.windows.push(snap);
            }
        }
        let served = self.lat.count() as usize;
        let mean_latency_cycles = self.lat.mean();
        let total_time = self.now.max(1);
        let sec = self.makespan.max(1) as f64 / self.freq;
        let net_summary = self.net.as_ref().map(|r| r.summary(self.makespan));
        // interconnect transfer energy joins the report total whenever
        // real links moved bytes; a Flat (linkless) topology adds an
        // exact 0.0, preserving the bit-identity contract
        let net_j = match &net_summary {
            Some(n) if !n.levels.is_empty() => n.energy_j,
            _ => 0.0,
        };
        let energy_static =
            self.active_j + energy::P_IDLE_W * sec * self.fleet.n as f64 + net_j;
        // a run that never deviated from the nominal base keeps the
        // uncontrolled closed form bit-for-bit; anything else uses the
        // integrated per-interval accounting
        let energy_j = match &self.control {
            Some(ctl) if ctl.deviated || ctl.base_op != NOMINAL_INDEX => {
                ctl.active_j_scaled + ctl.idle_j + net_j
            }
            _ => energy_static,
        };
        let p50_cycles = self.lat.percentile(0.50);
        let p90_cycles = self.lat.percentile(0.90);
        let p99_cycles = self.lat.percentile(0.99);
        let (tenants, fairness_jain) =
            tenant_summaries(&mut self.lat_by_tenant, &self.ops_by_tenant, sec);
        let control = match (&mut self.control, meta) {
            (Some(ctl), Some((name, slo))) => Some(ControlSummary {
                controller: name.to_string(),
                cadence_cycles: ctl.cadence,
                windows: std::mem::take(&mut ctl.windows),
                dvfs_transitions: ctl.dvfs_transitions,
                parks: ctl.parks,
                wakes: ctl.wakes,
                slo_p99_cycles: slo,
                slo_met: slo.map(|s| p99_cycles <= s),
                energy_j_static: energy_static,
                energy_saved_j: energy_static - energy_j,
            }),
            _ => None,
        };
        let final_queue_depth = self.queue.len();
        let fault = self.fault.as_ref().map(|f| {
            let s = f.summary(self.w.requests, served, self.ops_served, sec);
            // conservation: on a drained faulted run every offered id
            // lands in exactly one terminal bucket. A run that ends
            // with work stranded in the queue (e.g. a pinned scheduler
            // whose shard never recovers) is exempt — the backlog is
            // surfaced through final_queue_depth instead
            if self.done && final_queue_depth == 0 {
                debug_assert_eq!(
                    self.w.requests as u64,
                    served as u64 + s.shed + s.expired,
                    "offered == served + shed + expired must hold on a drained run"
                );
            }
            s
        });
        let profile = self.obs.take().map(|o| {
            let busy: Vec<u64> = self.shards.iter().map(|sh| sh.busy).collect();
            o.finish(&busy, self.now, self.done)
        });
        ServeReport {
            scheduler: self.sched.name().to_string(),
            clusters: self.fleet.n,
            offered: self.w.requests,
            served,
            makespan_cycles: self.makespan,
            seconds: sec,
            req_per_s: served as f64 / sec,
            gops: self.ops_served as f64 / 1e9 / sec,
            energy_j,
            mj_per_req: energy_j * 1e3 / (served.max(1)) as f64,
            gopj: self.ops_served as f64 / 1e9 / energy_j,
            p50_cycles,
            p90_cycles,
            p99_cycles,
            mean_latency_cycles,
            mean_queue_depth: self.depth_cycles as f64 / total_time as f64,
            max_queue_depth: self.depth_max,
            cluster_utilization: self
                .shards
                .iter()
                .map(|s| s.busy as f64 / self.makespan.max(1) as f64)
                .collect(),
            class_switches: self.switches,
            batches: self.batches,
            tenants,
            fairness_jain,
            freq_hz: self.freq,
            control,
            net: net_summary,
            final_queue_depth,
            fault,
            profile,
        }
    }
}

/// Fold the per-tenant latency stores and op counters into the
/// [`TenantSummary`] vec and Jain index of a [`ServeReport`]. Shared
/// with the retained naive loop — identical arithmetic in identical
/// order is what makes the per-tenant report bit-identical between the
/// two paths.
pub(crate) fn tenant_summaries(
    stores: &mut [LatencyStore],
    ops: &[u64],
    seconds: f64,
) -> (Vec<TenantSummary>, f64) {
    let total_req: u64 = stores.iter().map(|s| s.count()).sum();
    let total_ops: u64 = ops.iter().sum();
    let mut tenants = Vec::with_capacity(stores.len());
    for (t, store) in stores.iter_mut().enumerate() {
        let served = store.count();
        let req_share =
            if total_req == 0 { 0.0 } else { served as f64 / total_req as f64 };
        let ops_share =
            if total_ops == 0 { 0.0 } else { ops[t] as f64 / total_ops as f64 };
        tenants.push(TenantSummary {
            tenant: t,
            served: served as usize,
            req_per_s: served as f64 / seconds,
            p50_cycles: store.percentile(0.50),
            p99_cycles: store.percentile(0.99),
            mean_latency_cycles: store.mean(),
            dominant_share: req_share.max(ops_share),
        });
    }
    let delivered: Vec<f64> = tenants.iter().map(|t| t.served as f64).collect();
    (tenants, jain(&delivered))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{DINOV2S, MOBILEBERT};
    use crate::serve::scheduler::{DynamicBatch, Fifo, RoundRobin};
    use crate::serve::workload::RequestClass;

    fn fleet(n: usize) -> Fleet {
        Fleet::new(ClusterConfig::default(), Target::MultiCoreIta, n)
    }

    fn first_cycles(model: &crate::models::ModelConfig) -> u64 {
        Pipeline::new(ClusterConfig::default())
            .model(model)
            .target(Target::MultiCoreIta)
            .layers(1)
            .compile()
            .unwrap()
            .stats()
            .cycles
    }

    #[test]
    fn batching_two_same_class_requests_beats_fifo() {
        let classes = vec![RequestClass::new(&MOBILEBERT, 1)];
        let w = Workload::trace(classes, vec![(0, 0), (0, 0)]);
        let fifo = fleet(1).serve(&w, &mut Fifo).unwrap();
        let batch = fleet(1).serve(&w, &mut DynamicBatch::default()).unwrap();
        let first = first_cycles(&MOBILEBERT);
        // fifo: two cold passes back to back, no switch
        assert_eq!(fifo.makespan_cycles, 2 * first);
        assert_eq!(fifo.served, 2);
        assert_eq!(fifo.class_switches, 0);
        // batch: one cold pass + one steady-state increment (< first:
        // the lead-in staging and writeback tail hide in the batch)
        assert_eq!(batch.served, 2);
        assert_eq!(batch.batches, 1);
        assert!(
            batch.makespan_cycles < fifo.makespan_cycles,
            "batched {} !< fifo {}",
            batch.makespan_cycles,
            fifo.makespan_cycles
        );
        assert!(batch.makespan_cycles > first, "steady increment must cost > 0");
    }

    #[test]
    fn round_robin_runs_two_shards_in_parallel() {
        let classes = vec![RequestClass::new(&MOBILEBERT, 1)];
        let w = Workload::trace(classes, vec![(0, 0), (0, 0)]);
        let r = fleet(2).serve(&w, &mut RoundRobin).unwrap();
        assert_eq!(r.served, 2);
        assert_eq!(r.makespan_cycles, first_cycles(&MOBILEBERT));
        assert_eq!(r.cluster_utilization.len(), 2);
        assert!(r.cluster_utilization.iter().all(|&u| (u - 1.0).abs() < 1e-9));
    }

    #[test]
    fn class_switch_is_charged_between_buckets() {
        let classes =
            vec![RequestClass::new(&MOBILEBERT, 1), RequestClass::new(&DINOV2S, 1)];
        let w = Workload::trace(classes, vec![(0, 0), (0, 1)]);
        let r = fleet(1).serve(&w, &mut Fifo).unwrap();
        assert_eq!(r.served, 2);
        assert_eq!(r.class_switches, 1);
        let sum_first = first_cycles(&MOBILEBERT) + first_cycles(&DINOV2S);
        assert!(
            r.makespan_cycles > sum_first,
            "switch DMA must add cycles: {} <= {sum_first}",
            r.makespan_cycles
        );
    }

    #[test]
    fn single_tenant_runs_report_one_summary_and_perfect_fairness() {
        let classes = vec![RequestClass::new(&MOBILEBERT, 1)];
        let w = Workload::poisson(classes, 100.0, 50, 0xFA1);
        let r = fleet(1).serve(&w, &mut Fifo).unwrap();
        assert_eq!(r.tenants.len(), 1);
        assert_eq!(r.fairness_jain.to_bits(), 1.0f64.to_bits());
        let t = &r.tenants[0];
        assert_eq!(t.tenant, 0);
        assert_eq!(t.served, r.served);
        assert_eq!(t.p99_cycles, r.p99_cycles);
        assert_eq!(t.req_per_s.to_bits(), r.req_per_s.to_bits());
        assert_eq!(t.dominant_share.to_bits(), 1.0f64.to_bits());
    }

    #[test]
    fn tenant_tags_split_the_report_per_tenant() {
        use crate::trace::TraceEntry;
        let classes = vec![RequestClass::new(&MOBILEBERT, 1)];
        let e = |cycle, tenant| TraceEntry { cycle, tenant, class: 0, seq_len: 128 };
        let w = Workload::trace_entries(
            classes,
            vec![e(0, 0), e(0, 1), e(10, 0), e(20, 1)],
        );
        let r = fleet(1).serve(&w, &mut Fifo).unwrap();
        assert_eq!(r.served, 4);
        assert_eq!(r.tenants.len(), 2);
        assert_eq!(r.tenants[0].served, 2);
        assert_eq!(r.tenants[1].served, 2);
        // even delivery -> perfect Jain, and equal dominant shares
        assert_eq!(r.fairness_jain.to_bits(), 1.0f64.to_bits());
        assert_eq!(
            r.tenants[0].dominant_share.to_bits(),
            r.tenants[1].dominant_share.to_bits()
        );
    }

    #[test]
    fn zero_fleet_is_a_builder_error() {
        let w = Workload::single(&MOBILEBERT, 1);
        let r = Fleet::new(ClusterConfig::default(), Target::MultiCoreIta, 0)
            .serve(&w, &mut Fifo);
        assert!(matches!(r, Err(DeployError::Builder(_))));
    }

    #[test]
    fn mean_queue_depth_is_time_weighted() {
        // two simultaneous arrivals on one fifo cluster: request 1 runs
        // over [0, first) while request 2 waits (depth 1); request 2
        // then runs over [first, 2*first) with an empty queue (depth 0).
        // time-weighted mean = (1 * first + 0 * first) / 2*first = 0.5 —
        // the old event-weighted sampling had no such closed form
        let classes = vec![RequestClass::new(&MOBILEBERT, 1)];
        let w = Workload::trace(classes, vec![(0, 0), (0, 0)]);
        let r = fleet(1).serve(&w, &mut Fifo).unwrap();
        assert_eq!(r.served, 2);
        assert!(
            (r.mean_queue_depth - 0.5).abs() < 1e-12,
            "time-weighted mean depth {} != 0.5",
            r.mean_queue_depth
        );
        assert_eq!(r.max_queue_depth, 2, "both requests queued at t=0");

        // three arrivals: depths 2 then 1 then 0 over equal service
        // intervals -> mean (2 + 1 + 0) / 3 = 1
        let classes = vec![RequestClass::new(&MOBILEBERT, 1)];
        let w3 = Workload::trace(classes, vec![(0, 0), (0, 0), (0, 0)]);
        let r3 = fleet(1).serve(&w3, &mut Fifo).unwrap();
        assert!(
            (r3.mean_queue_depth - 1.0).abs() < 1e-12,
            "mean depth {} != 1.0",
            r3.mean_queue_depth
        );
    }

    #[test]
    fn second_serve_of_a_class_does_zero_engine_work() {
        // distinctive geometry: this test owns its cache entry
        let mut cluster = ClusterConfig::default();
        cluster.freq_hz = 423.875e6;
        let classes = vec![RequestClass::new(&MOBILEBERT, 1)];
        let w = Workload::trace(classes, vec![(0, 0), (40_000_000, 0)]);
        let f = Fleet::new(cluster.clone(), Target::MultiCoreIta, 1);
        let a = f.serve(&w, &mut Fifo).unwrap();
        let compiled = Pipeline::new(cluster)
            .model(&MOBILEBERT)
            .target(Target::MultiCoreIta)
            .layers(1)
            .compile()
            .unwrap();
        let after_first = compiled.sim_runs();
        assert!(
            (1..=2).contains(&after_first),
            "first serve runs the engine at most twice (stats + spans), saw {after_first}"
        );
        let b = f.serve(&w, &mut Fifo).unwrap();
        assert_eq!(
            compiled.sim_runs(),
            after_first,
            "second serve of a cached class must do zero engine work"
        );
        assert_eq!(a.makespan_cycles, b.makespan_cycles);
        assert_eq!(a.energy_j.to_bits(), b.energy_j.to_bits());
    }

    #[test]
    fn steppable_engine_matches_one_shot_serve() {
        // drive the engine through many arbitrary pause points and
        // check the report is bit-identical to the one-shot drain —
        // pausing between events must be observationally free
        let classes =
            vec![RequestClass::new(&MOBILEBERT, 1), RequestClass::new(&DINOV2S, 1)];
        let w = Workload::poisson(classes, 250.0, 600, 0xA11CE);
        let f = fleet(2);
        let whole = f.serve(&w, &mut DynamicBatch::default()).unwrap();

        let mut sched = DynamicBatch::default();
        let mut engine = ServeEngine::new(&f, &w, &mut sched).unwrap();
        let mut t = 0u64;
        loop {
            t += 1_700_000; // ~4ms slices, deliberately unaligned
            if !engine.run_until(t) {
                break;
            }
        }
        assert!(engine.is_done());
        let stepped = engine.finish();

        assert_eq!(whole.served, stepped.served);
        assert_eq!(whole.makespan_cycles, stepped.makespan_cycles);
        assert_eq!(whole.batches, stepped.batches);
        assert_eq!(whole.class_switches, stepped.class_switches);
        assert_eq!(whole.p50_cycles, stepped.p50_cycles);
        assert_eq!(whole.p99_cycles, stepped.p99_cycles);
        assert_eq!(whole.max_queue_depth, stepped.max_queue_depth);
        assert_eq!(whole.energy_j.to_bits(), stepped.energy_j.to_bits());
        assert_eq!(
            whole.mean_queue_depth.to_bits(),
            stepped.mean_queue_depth.to_bits(),
            "pausing must split the depth integral exactly"
        );
        assert_eq!(
            whole.mean_latency_cycles.to_bits(),
            stepped.mean_latency_cycles.to_bits()
        );
        assert!(whole.control.is_none() && stepped.control.is_none());
    }

    #[test]
    fn static_nominal_controller_is_a_provable_no_op() {
        use crate::serve::control::{StaticNominal, DEFAULT_CONTROL_CADENCE_CYCLES};
        let classes = vec![RequestClass::new(&MOBILEBERT, 1)];
        let w = Workload::diurnal(classes, 300.0, 0.7, 0.5, 500, 0xD1A);
        let f = fleet(2);
        let plain = f.serve(&w, &mut Fifo).unwrap();
        let ctl = f
            .serve_controlled(
                &w,
                &mut Fifo,
                &mut StaticNominal,
                DEFAULT_CONTROL_CADENCE_CYCLES,
                NOMINAL_INDEX,
            )
            .unwrap();
        assert_eq!(plain.served, ctl.served);
        assert_eq!(plain.makespan_cycles, ctl.makespan_cycles);
        assert_eq!(plain.batches, ctl.batches);
        assert_eq!(plain.class_switches, ctl.class_switches);
        assert_eq!(plain.p99_cycles, ctl.p99_cycles);
        assert_eq!(plain.energy_j.to_bits(), ctl.energy_j.to_bits());
        assert_eq!(plain.mean_queue_depth.to_bits(), ctl.mean_queue_depth.to_bits());
        let summary = ctl.control.expect("controlled run must attach a summary");
        assert_eq!(summary.controller, "static-nominal");
        assert_eq!(summary.dvfs_transitions, 0);
        assert_eq!(summary.parks, 0);
        assert_eq!(summary.wakes, 0);
        assert_eq!(summary.energy_saved_j.to_bits(), 0.0f64.to_bits());
        assert!(
            !summary.windows.is_empty(),
            "a multi-second run must close at least one 10ms window"
        );
        assert!(plain.control.is_none());
    }

    #[test]
    fn slo_dvfs_saves_energy_on_a_diurnal_lull() {
        use crate::serve::control::{SloDvfs, DEFAULT_CONTROL_CADENCE_CYCLES};
        let classes = vec![RequestClass::new(&MOBILEBERT, 1)];
        // ~200 rps average against ~1560 inf/s of nominal capacity:
        // deep lulls the controller can spend at a lower corner
        let w = Workload::diurnal(classes, 200.0, 0.8, 0.5, 400, 0x10AD);
        let f = fleet(2);
        let freq = ClusterConfig::default().freq_hz;
        let run = |f: &Fleet| {
            f.serve_controlled(
                &w,
                &mut Fifo,
                &mut SloDvfs::from_ms(50.0, freq),
                DEFAULT_CONTROL_CADENCE_CYCLES,
                NOMINAL_INDEX,
            )
            .unwrap()
        };
        let r = run(&f);
        let summary = r.control.as_ref().unwrap();
        assert_eq!(summary.controller, "slo-dvfs");
        assert!(summary.dvfs_transitions >= 1, "an underloaded run must downshift");
        assert_eq!(summary.slo_met, Some(true), "p99 {} cycles", r.p99_cycles);
        assert!(
            r.energy_j < summary.energy_j_static,
            "DVFS must beat static nominal: {} !< {}",
            r.energy_j,
            summary.energy_j_static
        );
        assert!(
            (summary.energy_saved_j - (summary.energy_j_static - r.energy_j)).abs()
                < 1e-12
        );
        // same seed, same decisions, bit for bit
        let again = run(&f);
        assert_eq!(r.energy_j.to_bits(), again.energy_j.to_bits());
        assert_eq!(r.p99_cycles, again.p99_cycles);
        assert_eq!(
            summary.windows.len(),
            again.control.as_ref().unwrap().windows.len()
        );
    }

    #[test]
    fn flat_topology_serves_bit_identically_with_an_empty_net_block() {
        let classes =
            vec![RequestClass::new(&MOBILEBERT, 1), RequestClass::new(&DINOV2S, 1)];
        let w = Workload::poisson(classes, 400.0, 300, 0xF1A7);
        let plain = fleet(2).serve(&w, &mut Fifo).unwrap();
        let flat = fleet(2).with_topology(Topology::Flat).serve(&w, &mut Fifo).unwrap();
        assert_eq!(plain.makespan_cycles, flat.makespan_cycles);
        assert_eq!(plain.class_switches, flat.class_switches);
        assert_eq!(plain.p99_cycles, flat.p99_cycles);
        assert_eq!(plain.energy_j.to_bits(), flat.energy_j.to_bits());
        assert!(plain.net.is_none());
        let net = flat.net.expect("topology-attached run must carry a net block");
        assert_eq!(net.topology, "flat");
        assert!(net.levels.is_empty(), "flat has no links");
        assert_eq!(net.restage_fetch_cycles, 0);
        assert_eq!(net.dispatches, flat.batches);
    }

    #[test]
    fn pod_topology_prices_dispatch_and_restaging() {
        let classes =
            vec![RequestClass::new(&MOBILEBERT, 1), RequestClass::new(&DINOV2S, 1)];
        let w = Workload::trace(classes, vec![(0, 0), (0, 1)]);
        let plain = fleet(1).serve(&w, &mut Fifo).unwrap();
        let run = || {
            fleet(1)
                .with_topology(Topology::parse("pod:1x1x1").unwrap())
                .serve(&w, &mut Fifo)
                .unwrap()
        };
        let pod = run();
        let net = pod.net.as_ref().unwrap();
        assert_eq!(net.topology, "pod:1x1x1");
        assert_eq!(net.dispatches, pod.batches);
        assert_eq!(net.restages, 1, "the class switch re-stages once");
        assert!(net.restage_fetch_cycles > 0, "weights crossed real links");
        assert_eq!(net.locality_hits, 0, "cold then switched: never resident");
        assert!(
            pod.makespan_cycles > plain.makespan_cycles,
            "link latency must lengthen the run: {} <= {}",
            pod.makespan_cycles,
            plain.makespan_cycles
        );
        assert!(net.levels.iter().all(|l| l.links >= 1 && l.transfers > 0));
        // same seed, same topology: bit-identical, net block included
        let again = run();
        assert_eq!(pod.makespan_cycles, again.makespan_cycles);
        assert_eq!(pod.energy_j.to_bits(), again.energy_j.to_bits());
        assert_eq!(net, again.net.as_ref().unwrap());
    }

    #[test]
    fn locality_wrapper_cuts_switches_and_restage_traffic() {
        use crate::serve::scheduler::LocalityAware;
        // two classes with identical service time (same model, same
        // layers) and one shard per pod, so the dispatch paths are
        // link-disjoint and both shards free simultaneously every
        // round. The trace's head class alternates per pair: a
        // locality-blind fifo re-tags both shards every round (paying
        // cross-pod weight fetches), while the wrapper defers each
        // offer to the shard already holding the class
        let classes =
            vec![RequestClass::new(&MOBILEBERT, 1), RequestClass::new(&MOBILEBERT, 1)];
        let mut arrivals = Vec::new();
        for pair in 0..20 {
            let (a, b) = if pair % 2 == 0 { (0, 1) } else { (1, 0) };
            arrivals.push((0, a));
            arrivals.push((0, b));
        }
        let w = Workload::trace(classes, arrivals);
        let topo = || Topology::parse("pod:2x1x1").unwrap();
        let blind = fleet(2).with_topology(topo()).serve(&w, &mut Fifo).unwrap();
        let mut inner = Fifo;
        let mut wrapped = LocalityAware::new(&mut inner, topo(), 2);
        let smart = fleet(2).with_topology(topo()).serve(&w, &mut wrapped).unwrap();
        assert_eq!(smart.served, blind.served);
        assert_eq!(smart.scheduler, "locality");
        assert!(
            smart.class_switches < blind.class_switches,
            "locality must cut switches: {} !< {}",
            smart.class_switches,
            blind.class_switches
        );
        let (bn, sn) = (blind.net.unwrap(), smart.net.unwrap());
        assert!(
            sn.restage_fetch_cycles < bn.restage_fetch_cycles,
            "locality must cut restage DMA: {} !< {}",
            sn.restage_fetch_cycles,
            bn.restage_fetch_cycles
        );
        assert!(
            sn.locality_rate > bn.locality_rate,
            "locality rate {} !> {}",
            sn.locality_rate,
            bn.locality_rate
        );
    }

    #[test]
    fn fleet_exceeding_topology_capacity_is_a_builder_error() {
        let w = Workload::single(&MOBILEBERT, 1);
        let r = fleet(9)
            .with_topology(Topology::parse("pod:1x2x4").unwrap())
            .serve(&w, &mut Fifo);
        assert!(matches!(r, Err(DeployError::Builder(_))));
        // exactly at capacity is fine
        let ok = fleet(8)
            .with_topology(Topology::parse("pod:1x2x4").unwrap())
            .serve(&w, &mut Fifo);
        assert!(ok.is_ok());
    }

    #[test]
    fn crash_failover_retries_and_still_serves_everything() {
        use crate::fault::FaultPlan;
        use crate::serve::fault::FaultConfig;
        let classes = vec![RequestClass::new(&MOBILEBERT, 1)];
        let w = Workload::trace(classes, vec![(0, 0); 40]);
        // shard 1 dies at cycle 1 (mid-batch: service takes far
        // longer), comes back much later
        let cfg = FaultConfig::with_plan(
            FaultPlan::empty().crash(1, 1).recover(10_000_000, 1),
        );
        let run = || fleet(2).serve_faulted(&w, &mut Fifo, cfg.clone()).unwrap();
        let r = run();
        assert_eq!(r.served, 40, "every request lands despite the crash");
        assert_eq!(r.final_queue_depth, 0);
        let f = r.fault.as_ref().unwrap();
        assert_eq!((f.crashes, f.recoveries), (1, 1));
        assert_eq!(f.killed_in_flight, 1, "shard 1's single in-flight request dies");
        assert_eq!(f.failed_over, 1);
        assert!(f.retried >= 1);
        assert_eq!((f.shed, f.expired), (0, 0));
        assert_eq!(f.availability.to_bits(), 1.0f64.to_bits());
        // same plan, same seed: bit-identical
        let again = run();
        assert_eq!(r.makespan_cycles, again.makespan_cycles);
        assert_eq!(r.energy_j.to_bits(), again.energy_j.to_bits());
        assert_eq!(r.p99_cycles, again.p99_cycles);
        assert_eq!(r.fault, again.fault);
    }

    #[test]
    fn threshold_admission_sheds_exactly_the_overflow() {
        use crate::serve::fault::{AdmissionPolicy, FaultConfig};
        let classes = vec![RequestClass::new(&MOBILEBERT, 1)];
        let w = Workload::trace(classes, vec![(0, 0); 100]);
        let cfg = FaultConfig {
            admission: AdmissionPolicy::Threshold { max_depth: 8 },
            ..FaultConfig::default()
        };
        let r = fleet(1).serve_faulted(&w, &mut Fifo, cfg).unwrap();
        let f = r.fault.as_ref().unwrap();
        // 100 simultaneous arrivals against a bound of 8 waiters:
        // 8 admitted, 92 shed, queue depth capped at the bound
        assert_eq!(r.served, 8);
        assert_eq!(f.shed, 92);
        assert_eq!(f.shed_by_tenant, vec![92]);
        assert_eq!(r.max_queue_depth, 8);
        assert_eq!(f.admission, "threshold:8");
        assert_eq!(f.availability.to_bits(), (8.0f64 / 100.0).to_bits());
    }

    #[test]
    fn transient_failures_retry_and_conserve_requests() {
        use crate::fault::FaultPlan;
        use crate::serve::fault::FaultConfig;
        let classes = vec![RequestClass::new(&MOBILEBERT, 1)];
        let w = Workload::trace(classes, vec![(0, 0); 30]);
        // a brutally flaky fleet: half of all completions fail
        let cfg =
            FaultConfig::with_plan(FaultPlan::empty().transient(500_000).seeded(7));
        let run = || fleet(1).serve_faulted(&w, &mut Fifo, cfg.clone()).unwrap();
        let r = run();
        let f = r.fault.as_ref().unwrap();
        assert!(f.transient_failures > 0, "50% ppm must fail something");
        assert!(f.retried > 0);
        assert_eq!(f.shed, 0);
        // conservation (also debug-asserted inside build_report):
        // what wasn't served ran out of retry budget
        assert_eq!(r.served as u64 + f.expired, 30);
        assert_eq!(f.expired, f.retry_exhausted);
        let again = run();
        assert_eq!(r.fault, again.fault);
        assert_eq!(r.makespan_cycles, again.makespan_cycles);
        assert_eq!(r.energy_j.to_bits(), again.energy_j.to_bits());
    }

    #[test]
    fn link_degradation_slows_a_topology_run() {
        use crate::fault::FaultPlan;
        use crate::serve::fault::FaultConfig;
        let classes =
            vec![RequestClass::new(&MOBILEBERT, 1), RequestClass::new(&DINOV2S, 1)];
        let w = Workload::trace(classes, vec![(0, 0), (0, 1)]);
        let topo = || Topology::parse("pod:1x1x1").unwrap();
        let healthy =
            fleet(1).with_topology(topo()).serve(&w, &mut Fifo).unwrap();
        let cfg = FaultConfig::with_plan(
            FaultPlan::empty().degrade_link(0, 0, 100).link_outage(0, 2, 5_000),
        );
        let hurt = fleet(1)
            .with_topology(topo())
            .serve_faulted(&w, &mut Fifo, cfg)
            .unwrap();
        assert_eq!(hurt.served, 2);
        assert_eq!(hurt.fault.as_ref().unwrap().link_events, 2);
        assert!(
            hurt.makespan_cycles > healthy.makespan_cycles,
            "a 100x board slowdown plus a root outage must cost cycles: {} <= {}",
            hurt.makespan_cycles,
            healthy.makespan_cycles
        );
        // link-only plans keep the immediate-commit path: nothing is
        // killed, shed or retried
        let f = hurt.fault.as_ref().unwrap();
        assert_eq!((f.killed_in_flight, f.shed, f.retried, f.expired), (0, 0, 0, 0));
    }

    #[test]
    fn invalid_fault_configs_are_builder_errors() {
        use crate::fault::FaultPlan;
        use crate::serve::fault::FaultConfig;
        let classes = vec![RequestClass::new(&MOBILEBERT, 1)];
        let w = Workload::trace(classes, vec![(0, 0)]);
        // link events need a topology to fault
        let r = fleet(2).serve_faulted(
            &w,
            &mut Fifo,
            FaultConfig::with_plan(FaultPlan::empty().degrade_link(0, 1, 4)),
        );
        assert!(matches!(r, Err(DeployError::Builder(_))));
        // shard index out of the fleet's range
        let r = fleet(2).serve_faulted(
            &w,
            &mut Fifo,
            FaultConfig::with_plan(FaultPlan::empty().crash(0, 5).recover(9, 5)),
        );
        assert!(matches!(r, Err(DeployError::Builder(_))));
    }

    #[test]
    fn million_scale_streaming_keeps_queue_memory_at_the_backlog() {
        // not a perf bench (that's benches/perf_serve) — just the
        // structural guarantee that a large open-loop run streams: a
        // fast-draining workload never holds more than a few open
        // requests no matter how many it offers
        let classes = vec![RequestClass::new(&MOBILEBERT, 1)];
        // ~40 req/s against a ~780 inf/s single-layer class: no backlog
        let w = Workload::poisson(classes, 40.0, 4_000, 0x5EED);
        let r = fleet(1).serve(&w, &mut Fifo).unwrap();
        assert_eq!(r.served, 4_000);
        assert!(
            r.max_queue_depth < 64,
            "underloaded stream should never backlog: depth {}",
            r.max_queue_depth
        );
    }
}
