//! The cluster fleet: N shards of the heterogeneous cluster serving one
//! request stream under a [`Scheduler`].
//!
//! Each shard wraps a cached [`crate::pipeline::Compiled`] per request
//! class — the process-wide compiled-deployment cache means N shards
//! (and repeated `serve()` calls) share one deployment and one memoized
//! simulation per class. The serve loop is event-driven over integer
//! cycles: arrivals enter a queue, free shards ask the scheduler for a
//! batch, and batch completions are derived from the engine's per-step
//! timing ([`Engine::run_spans`]), not re-simulated per request:
//!
//! - `first` — cycles of one cold pass of the command stream
//!   (`Compiled::stats().cycles`).
//! - `steady` — the incremental cycles of one more request of the same
//!   class inside a batch. The serving runtime double-buffers request
//!   boundaries: request j+1's input staging (the stream's no-dep lead-in
//!   DMAs) prefetches under request j's compute, and request j's output
//!   writeback (the trailing `DmaOut`s) drains under request j+1's
//!   compute. Off the solo span schedule: `steady = max(compute_end -
//!   lead_in_end, busiest-resource cycles)`, clamped to `[1, first]` —
//!   the hidden lead/tail shrink the increment, while the bottleneck
//!   resource's busy time floors it (no resource can be oversubscribed).
//! - `switch` — weight re-staging DMA paid when a shard changes request
//!   class (a cold shard pays nothing: weights are staged at deploy
//!   time, which keeps the one-request/one-cluster case identical to
//!   `Compiled::simulate()`).
//!
//! Energy is per-request active energy (cores + ITA + DMA activity of
//! the class) plus the always-on idle floor over the whole fleet for
//! the whole makespan.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::deeploy::ir::TensorKind;
use crate::deeploy::{DeployError, Target};
use crate::energy;
use crate::pipeline::Pipeline;
use crate::sim::dma::DmaModel;
use crate::sim::{ClusterConfig, Cmd, Engine};

use super::metrics::{percentile, ServeReport};
use super::scheduler::{Queued, Scheduler};
use super::workload::{RequestClass, Workload};

/// Per-class serving parameters, derived once per serve run from the
/// cached compiled deployment.
struct ClassRuntime {
    /// Cycles of one cold pass of the command stream.
    first: u64,
    /// Incremental cycles of one extra back-to-back pass in a batch.
    steady: u64,
    /// Weight re-staging cycles when a shard switches to this class.
    switch: u64,
    /// Active (non-idle) energy of one pass, joules.
    active_j: f64,
    /// Simulated ops of one pass.
    ops: u64,
}

impl ClassRuntime {
    fn build(fleet: &Fleet, class: &RequestClass) -> Result<ClassRuntime, DeployError> {
        let mut pipeline = Pipeline::new(fleet.cluster.clone())
            .model(&class.model)
            .target(fleet.target)
            .layers(class.layers)
            .fuse_mha(fleet.fuse);
        if !fleet.use_cache {
            pipeline = pipeline.uncached();
        }
        let compiled = pipeline.compile()?;
        let stats = compiled.stats();
        let first = stats.cycles.max(1);
        let e = energy::evaluate(stats, fleet.cluster.freq_hz);
        let active_j = (e.total_j - e.idle_j).max(0.0);
        let ops = stats.total_ops();

        // steady-state increment from the solo per-step schedule (see
        // the module docs): lead-in staging and writeback tail hide
        // under neighboring requests; the bottleneck resource floors it
        let steps = &compiled.deployment().steps;
        let engine = Engine::new(compiled.cluster().clone());
        let (span_stats, spans) = engine.run_spans(steps);
        debug_assert_eq!(span_stats.cycles, first, "{}: span/stats drift", class.model.name);
        let lead_in_end = steps
            .iter()
            .zip(&spans)
            .filter(|(s, _)| s.deps.is_empty() && matches!(s.cmd, Cmd::DmaIn { .. }))
            .map(|(_, sp)| sp.end)
            .max()
            .unwrap_or(0);
        let compute_end = steps
            .iter()
            .zip(&spans)
            .filter(|(s, _)| !matches!(s.cmd, Cmd::DmaOut { .. }))
            .map(|(_, sp)| sp.end)
            .max()
            .unwrap_or(first);
        let bottleneck = stats.busy.values().copied().max().unwrap_or(first);
        let steady =
            compute_end.saturating_sub(lead_in_end).max(bottleneck).clamp(1, first);

        // class switch: re-stage the network's weights into L2 over the
        // wide AXI before the first request of a different bucket
        let weight_bytes: u64 = compiled
            .deployment()
            .graph
            .tensors
            .values()
            .filter(|t| t.kind == TensorKind::Weight)
            .map(|t| t.bytes() as u64)
            .sum();
        let switch = DmaModel::new(fleet.cluster.wide_axi_bytes).transfer_1d(weight_bytes);
        Ok(ClassRuntime { first, steady, switch, active_j, ops })
    }
}

#[derive(Debug, Clone, Default)]
struct Shard {
    free_at: u64,
    class: Option<usize>,
    busy: u64,
}

/// N clusters of one geometry serving one workload.
pub struct Fleet {
    cluster: ClusterConfig,
    target: Target,
    n: usize,
    fuse: bool,
    use_cache: bool,
}

impl Fleet {
    /// A fleet of `n` identical clusters (geometry is first-class, as
    /// everywhere in the pipeline).
    pub fn new(cluster: ClusterConfig, target: Target, n: usize) -> Fleet {
        Fleet { cluster, target, n, fuse: true, use_cache: true }
    }

    /// Toggle the MHA fusion pass for every class compilation.
    pub fn fuse_mha(mut self, on: bool) -> Fleet {
        self.fuse = on;
        self
    }

    /// Bypass the compiled-deployment cache for every class compilation
    /// (mirrors `Pipeline::uncached` — geometry sweeps stay out of the
    /// never-evicting process-wide cache).
    pub fn uncached(mut self) -> Fleet {
        self.use_cache = false;
        self
    }

    pub fn clusters(&self) -> usize {
        self.n
    }

    /// Run the workload to completion under `sched` and report.
    pub fn serve(
        &self,
        w: &Workload,
        sched: &mut dyn Scheduler,
    ) -> Result<ServeReport, DeployError> {
        if self.n == 0 {
            return Err(DeployError::Builder("fleet size must be >= 1".into()));
        }
        w.validate()?;
        let freq = self.cluster.freq_hz;
        let mut classes = Vec::with_capacity(w.classes.len());
        for c in &w.classes {
            classes.push(ClassRuntime::build(self, c)?);
        }

        let mut crng = w.class_rng();
        let seeds = w.seed_requests(freq, &mut crng);
        let mut heap: BinaryHeap<Reverse<(u64, usize, usize)>> =
            seeds.iter().map(|r| Reverse((r.arrival, r.id, r.class))).collect();
        let mut issued = seeds.len();
        let closed = w.is_closed_loop();
        let think = w.think_cycles();

        let mut queue: Vec<Queued> = Vec::new();
        let mut shards: Vec<Shard> = vec![Shard::default(); self.n];
        let mut latencies: Vec<u64> = Vec::with_capacity(w.requests);
        let (mut depth_sum, mut depth_samples) = (0u64, 0u64);
        let mut depth_max = 0usize;
        let (mut switches, mut batches) = (0u64, 0u64);
        let mut active_j = 0.0f64;
        let mut ops_served = 0u64;
        let mut makespan = 0u64;
        let mut now = 0u64;

        loop {
            // admit everything due by now (heap pops in (cycle, id) order,
            // so the queue stays in arrival order)
            while let Some(&Reverse((t, id, class))) = heap.peek() {
                if t > now {
                    break;
                }
                heap.pop();
                queue.push(Queued {
                    id,
                    class,
                    bucket: w.classes[class].bucket(),
                    arrival: t,
                });
            }
            depth_sum += queue.len() as u64;
            depth_samples += 1;
            depth_max = depth_max.max(queue.len());

            // dispatch until no free shard selects anything
            loop {
                let mut dispatched = false;
                for si in 0..self.n {
                    if shards[si].free_at > now || queue.is_empty() {
                        continue;
                    }
                    let free = shards.iter().filter(|s| s.free_at <= now).count();
                    let mut sel = sched.select(now, &queue, si, free, self.n);
                    sel.retain(|&i| i < queue.len());
                    sel.sort_unstable();
                    sel.dedup();
                    if sel.is_empty() {
                        continue;
                    }
                    // a batch is one class (one command stream); filter
                    // defensively if a custom scheduler mixes classes
                    let class = queue[sel[0]].class;
                    debug_assert!(
                        sel.iter().all(|&i| queue[i].class == class),
                        "{}: mixed-class batch",
                        sched.name()
                    );
                    sel.retain(|&i| queue[i].class == class);

                    let rt = &classes[class];
                    let mut cost_switch = 0u64;
                    if let Some(cur) = shards[si].class {
                        if cur != class {
                            cost_switch = rt.switch;
                            switches += 1;
                        }
                    }
                    // cold shard: weights staged at deploy time — free,
                    // matching Compiled::simulate() semantics
                    shards[si].class = Some(class);
                    let start = now;
                    let base = start + cost_switch + rt.first;
                    let mut completion = base;
                    for (j, &qi) in sel.iter().enumerate() {
                        let done = base + j as u64 * rt.steady;
                        completion = done;
                        latencies.push(done - queue[qi].arrival);
                        if closed && issued < w.requests {
                            let id = issued;
                            issued += 1;
                            let next_class = w.sample_class(&mut crng);
                            heap.push(Reverse((done + think, id, next_class)));
                        }
                    }
                    active_j += rt.active_j * sel.len() as f64;
                    ops_served += rt.ops * sel.len() as u64;
                    shards[si].free_at = completion;
                    shards[si].busy += completion - start;
                    batches += 1;
                    makespan = makespan.max(completion);
                    for &qi in sel.iter().rev() {
                        queue.remove(qi);
                    }
                    dispatched = true;
                }
                if !dispatched {
                    break;
                }
            }

            // advance to the next event; both candidates are strictly
            // in the future, so time always progresses
            let next_arrival = heap.peek().map(|&Reverse((t, _, _))| t);
            let next_free = shards.iter().map(|s| s.free_at).filter(|&f| f > now).min();
            now = match (next_arrival, next_free) {
                (None, None) => break,
                (Some(a), None) => a,
                (None, Some(f)) => f,
                (Some(a), Some(f)) => a.min(f),
            };
        }

        let served = latencies.len();
        let mean_latency_cycles = if served == 0 {
            0.0
        } else {
            latencies.iter().sum::<u64>() as f64 / served as f64
        };
        latencies.sort_unstable();
        let sorted = latencies;
        let sec = makespan.max(1) as f64 / freq;
        let energy_j = active_j + energy::P_IDLE_W * sec * self.n as f64;
        Ok(ServeReport {
            scheduler: sched.name().to_string(),
            clusters: self.n,
            offered: w.requests,
            served,
            makespan_cycles: makespan,
            seconds: sec,
            req_per_s: served as f64 / sec,
            gops: ops_served as f64 / 1e9 / sec,
            energy_j,
            mj_per_req: energy_j * 1e3 / (served.max(1)) as f64,
            gopj: ops_served as f64 / 1e9 / energy_j,
            p50_cycles: percentile(&sorted, 0.50),
            p90_cycles: percentile(&sorted, 0.90),
            p99_cycles: percentile(&sorted, 0.99),
            mean_latency_cycles,
            mean_queue_depth: depth_sum as f64 / depth_samples.max(1) as f64,
            max_queue_depth: depth_max,
            cluster_utilization: shards
                .iter()
                .map(|s| s.busy as f64 / makespan.max(1) as f64)
                .collect(),
            class_switches: switches,
            batches,
            freq_hz: freq,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{DINOV2S, MOBILEBERT};
    use crate::serve::scheduler::{DynamicBatch, Fifo, RoundRobin};

    fn fleet(n: usize) -> Fleet {
        Fleet::new(ClusterConfig::default(), Target::MultiCoreIta, n)
    }

    fn first_cycles(model: &crate::models::ModelConfig) -> u64 {
        Pipeline::new(ClusterConfig::default())
            .model(model)
            .target(Target::MultiCoreIta)
            .layers(1)
            .compile()
            .unwrap()
            .stats()
            .cycles
    }

    #[test]
    fn batching_two_same_class_requests_beats_fifo() {
        let classes = vec![RequestClass::new(&MOBILEBERT, 1)];
        let w = Workload::trace(classes, vec![(0, 0), (0, 0)]);
        let fifo = fleet(1).serve(&w, &mut Fifo).unwrap();
        let batch = fleet(1).serve(&w, &mut DynamicBatch::default()).unwrap();
        let first = first_cycles(&MOBILEBERT);
        // fifo: two cold passes back to back, no switch
        assert_eq!(fifo.makespan_cycles, 2 * first);
        assert_eq!(fifo.served, 2);
        assert_eq!(fifo.class_switches, 0);
        // batch: one cold pass + one steady-state increment (< first:
        // the lead-in staging and writeback tail hide in the batch)
        assert_eq!(batch.served, 2);
        assert_eq!(batch.batches, 1);
        assert!(
            batch.makespan_cycles < fifo.makespan_cycles,
            "batched {} !< fifo {}",
            batch.makespan_cycles,
            fifo.makespan_cycles
        );
        assert!(batch.makespan_cycles > first, "steady increment must cost > 0");
    }

    #[test]
    fn round_robin_runs_two_shards_in_parallel() {
        let classes = vec![RequestClass::new(&MOBILEBERT, 1)];
        let w = Workload::trace(classes, vec![(0, 0), (0, 0)]);
        let r = fleet(2).serve(&w, &mut RoundRobin).unwrap();
        assert_eq!(r.served, 2);
        assert_eq!(r.makespan_cycles, first_cycles(&MOBILEBERT));
        assert_eq!(r.cluster_utilization.len(), 2);
        assert!(r.cluster_utilization.iter().all(|&u| (u - 1.0).abs() < 1e-9));
    }

    #[test]
    fn class_switch_is_charged_between_buckets() {
        let classes =
            vec![RequestClass::new(&MOBILEBERT, 1), RequestClass::new(&DINOV2S, 1)];
        let w = Workload::trace(classes, vec![(0, 0), (0, 1)]);
        let r = fleet(1).serve(&w, &mut Fifo).unwrap();
        assert_eq!(r.served, 2);
        assert_eq!(r.class_switches, 1);
        let sum_first = first_cycles(&MOBILEBERT) + first_cycles(&DINOV2S);
        assert!(
            r.makespan_cycles > sum_first,
            "switch DMA must add cycles: {} <= {sum_first}",
            r.makespan_cycles
        );
    }

    #[test]
    fn zero_fleet_is_a_builder_error() {
        let w = Workload::single(&MOBILEBERT, 1);
        let r = Fleet::new(ClusterConfig::default(), Target::MultiCoreIta, 0)
            .serve(&w, &mut Fifo);
        assert!(matches!(r, Err(DeployError::Builder(_))));
    }
}
