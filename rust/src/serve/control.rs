//! The online control plane: deterministic mid-run policies over the
//! serving fleet's FD-SOI operating point and shard pool.
//!
//! A [`Controller`] is invoked by the steppable serve engine
//! ([`super::fleet::ServeEngine`]) on a fixed simulated-time cadence
//! ([`DEFAULT_CONTROL_CADENCE_CYCLES`]). At each decision point the
//! engine closes a metrics window ([`super::WindowSnapshot`]) and hands
//! it to the controller together with the live [`ControlState`]; the
//! controller answers with a [`ControlAction`] — the operating-point
//! index it wants ([`energy::operating_point::OPERATING_POINTS`]) and
//! how many shards should be parked. The engine applies the action at
//! the window boundary:
//!
//! - **DVFS**: service time scales as `f_nominal / f_op` (timing in
//!   *intrinsic* cycles is voltage-independent; the timeline stays in
//!   nominal-clock cycles), active energy scales as `V²`
//!   ([`OperatingPoint::energy_scale`]), idle power as `V²·f`. A
//!   switch charges each unparked shard a one-off
//!   [`DVFS_TRANSITION_CYCLES`] pipeline-refill penalty on its next
//!   dispatch — in-flight batches finish at the point they started at.
//! - **Autoscaling**: parked shards leave the dispatch pool and stop
//!   burning idle power. Waking a shard re-stages its weights: the
//!   next dispatch pays the class switch cost (the same
//!   weight-staging constant `serve` already charges between buckets).
//!   At least one shard always stays awake.
//!
//! Determinism: controllers see only window snapshots and engine state
//! — quantities derived from the seeded workload — and the cadence is
//! simulated time, so a controlled run is exactly as reproducible as an
//! uncontrolled one. [`StaticNominal`] holds whatever state it finds
//! (provably a no-op: the engine skips all controlled-path accounting
//! when nothing ever deviates, keeping reports bit-identical to the
//! uncontrolled loop). [`SloDvfs`] holds a p99 SLO at minimum
//! J/request via hysteresis down the V/f table and over the parked
//! count.
//!
//! Every applied action is visible to the observability layer when one
//! is attached ([`crate::obs`]): operating-point switches surface as
//! `DvfsTransition` events and pool changes as `Park`/`Wake`, with
//! parked intervals folded into the per-shard phase profile. The
//! recorder is write-only — controllers never see it, so the
//! determinism contract above is untouched.

use crate::energy::operating_point::{OperatingPoint, NOMINAL_INDEX, OPERATING_POINTS};

use super::metrics::WindowSnapshot;

/// Default decision cadence, fleet cycles: 10 ms at the nominal
/// 425 MHz clock — long against service times (~1 ms per MobileBERT
/// layer-1 inference), short against the diurnal period (0.5 s), so a
/// window averages many requests yet the controller still tracks the
/// swing.
pub const DEFAULT_CONTROL_CADENCE_CYCLES: u64 = 4_250_000;

/// One-off penalty per unparked shard on its first dispatch after an
/// operating-point switch (~100 µs at 425 MHz): FLL re-lock plus
/// pipeline refill while the voltage regulator settles.
pub const DVFS_TRANSITION_CYCLES: u64 = 42_500;

/// Live engine state handed to a controller next to the closed window.
#[derive(Debug, Clone, Copy)]
pub struct ControlState {
    /// Decision time, fleet cycles.
    pub now_cycles: u64,
    /// Current operating-point index into [`OPERATING_POINTS`].
    pub op_index: usize,
    /// Currently parked shards.
    pub parked: usize,
    /// Total shards in the fleet.
    pub shards: usize,
    /// Instantaneous queue depth.
    pub queue_depth: usize,
}

/// What a controller wants the fleet to look like for the next window.
/// The engine clamps: `op_index` into the table, `parked` to
/// `shards - 1` (one shard always stays awake).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ControlAction {
    pub op_index: usize,
    pub parked: usize,
}

impl ControlAction {
    /// The action that changes nothing relative to `state`.
    pub fn hold(state: &ControlState) -> ControlAction {
        ControlAction { op_index: state.op_index, parked: state.parked }
    }
}

/// A deterministic mid-run policy (see the module docs). Implementors
/// must derive every decision from the arguments alone — no wall
/// clock, no interior randomness — or controlled runs stop being
/// reproducible.
pub trait Controller {
    fn name(&self) -> &'static str;

    /// The p99 SLO this policy holds, if any, in fleet cycles — echoed
    /// into [`super::ControlSummary`] so reports and benches can check
    /// it against the run-level p99.
    fn slo_p99_cycles(&self) -> Option<u64> {
        None
    }

    /// One decision: the just-closed window plus live state in, the
    /// desired fleet configuration out.
    fn decide(&mut self, window: &WindowSnapshot, state: &ControlState) -> ControlAction;
}

/// The baseline policy: hold whatever operating point and parked count
/// the run started with. Attaching it must change nothing — the
/// equivalence propcheck asserts a `StaticNominal` run is bit-identical
/// to the uncontrolled loop.
#[derive(Debug, Clone, Default)]
pub struct StaticNominal;

impl Controller for StaticNominal {
    fn name(&self) -> &'static str {
        "static-nominal"
    }

    fn decide(&mut self, _window: &WindowSnapshot, state: &ControlState) -> ControlAction {
        ControlAction::hold(state)
    }
}

/// Hysteresis thresholds of [`SloDvfs`], fractions of the SLO: react
/// *up* (wake/boost) well before the SLO is actually violated, react
/// *down* (slow/park) only when latencies sit far below it — the gap
/// between the two is what prevents oscillation at the cadence.
const HOT_FRACTION: f64 = 0.70;
const COLD_FRACTION: f64 = 0.35;
/// Consecutive calm windows required before any downward action.
const CALM_WINDOWS: u32 = 2;
/// Fleet busy fraction below which a calm fleet may park a shard.
const PARK_UTILIZATION: f64 = 0.10;

/// Hold a p99 SLO at minimum J/request: hysteresis over the V/f table
/// and the parked-shard count.
///
/// - **Hot** (window p99 above [`HOT_FRACTION`]·SLO, or backlog more
///   than twice the awake shards): wake a parked shard first; if the
///   window actually breached the SLO, also step one operating point
///   up. Reacting on the 70% line means the fleet speeds up while the
///   p99 still has 30% headroom.
/// - **Cold** (window p99 under [`COLD_FRACTION`]·SLO *and* the queue
///   drained): after [`CALM_WINDOWS`] consecutive such windows, step
///   one operating point down; once already at the floor, park a shard
///   if fleet utilization fell under [`PARK_UTILIZATION`]. One action
///   per window, and the calm streak restarts after each — downward
///   moves are deliberately slow.
/// - Otherwise: hold, and restart the calm streak.
#[derive(Debug, Clone)]
pub struct SloDvfs {
    slo_p99_cycles: u64,
    calm: u32,
}

impl SloDvfs {
    pub fn new(slo_p99_cycles: u64) -> SloDvfs {
        SloDvfs { slo_p99_cycles: slo_p99_cycles.max(1), calm: 0 }
    }

    /// SLO given in milliseconds, converted at the fleet clock.
    pub fn from_ms(slo_p99_ms: f64, freq_hz: f64) -> SloDvfs {
        SloDvfs::new((slo_p99_ms / 1e3 * freq_hz).round() as u64)
    }
}

impl Controller for SloDvfs {
    fn name(&self) -> &'static str {
        "slo-dvfs"
    }

    fn slo_p99_cycles(&self) -> Option<u64> {
        Some(self.slo_p99_cycles)
    }

    fn decide(&mut self, window: &WindowSnapshot, state: &ControlState) -> ControlAction {
        let slo = self.slo_p99_cycles as f64;
        let p99 = window.p99_cycles as f64;
        let alive = state.shards - state.parked;
        // a crash window is hot by definition: capacity just vanished,
        // so wake a parked shard to absorb the failover backlog before
        // the p99 even has time to breach
        let hot = p99 > HOT_FRACTION * slo
            || state.queue_depth > 2 * alive
            || window.shards_down > 0;
        let calm = p99 <= COLD_FRACTION * slo && state.queue_depth == 0;
        let mut action = ControlAction::hold(state);
        if hot {
            self.calm = 0;
            if state.parked > 0 {
                action.parked = state.parked - 1;
            }
            if p99 > slo && state.op_index + 1 < OPERATING_POINTS.len() {
                action.op_index = state.op_index + 1;
            }
            return action;
        }
        if !calm {
            self.calm = 0;
            return action;
        }
        self.calm += 1;
        if self.calm < CALM_WINDOWS {
            return action;
        }
        self.calm = 0;
        if state.op_index > 0 {
            action.op_index = state.op_index - 1;
        } else if window.utilization < PARK_UTILIZATION && alive > 1 {
            action.parked = state.parked + 1;
        }
        action
    }
}

/// CLI-style policy lookup, mirroring `scheduler_by_name`. The SLO is
/// only read by SLO-driven policies.
pub fn control_by_name(name: &str, slo_p99_cycles: u64) -> Option<Box<dyn Controller>> {
    match name {
        "static" | "static-nominal" => Some(Box::new(StaticNominal)),
        "slo-dvfs" | "dvfs" => Some(Box::new(SloDvfs::new(slo_p99_cycles))),
        _ => None,
    }
}

/// The operating point a controlled run executes at, by table index.
pub fn op_at(index: usize) -> &'static OperatingPoint {
    &OPERATING_POINTS[index.min(OPERATING_POINTS.len() - 1)]
}

/// Nominal table index re-exported for the serve layer.
pub const BASE_OP_INDEX: usize = NOMINAL_INDEX;

#[cfg(test)]
mod tests {
    use super::*;

    fn window(p99: u64, utilization: f64, queue_depth: usize) -> WindowSnapshot {
        WindowSnapshot {
            index: 0,
            start_cycles: 0,
            end_cycles: DEFAULT_CONTROL_CADENCE_CYCLES,
            completed: 10,
            p50_cycles: p99 / 2,
            p99_cycles: p99,
            utilization,
            mean_queue_depth: queue_depth as f64,
            queue_depth,
            active_j: 0.0,
            op_index: NOMINAL_INDEX,
            parked: 0,
            shards_down: 0,
            tenant_completed: Vec::new(),
            net_util: Vec::new(),
        }
    }

    fn state(op_index: usize, parked: usize, shards: usize, depth: usize) -> ControlState {
        ControlState {
            now_cycles: DEFAULT_CONTROL_CADENCE_CYCLES,
            op_index,
            parked,
            shards,
            queue_depth: depth,
        }
    }

    #[test]
    fn static_nominal_holds_any_state_it_finds() {
        let mut c = StaticNominal;
        for (op, parked) in [(NOMINAL_INDEX, 0), (0, 3), (4, 1)] {
            let s = state(op, parked, 4, 7);
            let a = c.decide(&window(1_000_000, 0.5, 7), &s);
            assert_eq!(a, ControlAction::hold(&s), "static policy must not act");
        }
        assert_eq!(c.slo_p99_cycles(), None);
    }

    #[test]
    fn slo_dvfs_wakes_then_boosts_when_hot() {
        let slo = 1_000_000u64;
        let mut c = SloDvfs::new(slo);
        // 70% line crossed but SLO not breached, shards parked: wake one
        let a = c.decide(&window(800_000, 0.9, 0), &state(NOMINAL_INDEX, 2, 4, 0));
        assert_eq!(a.parked, 1);
        assert_eq!(a.op_index, NOMINAL_INDEX, "no breach, no boost");
        // outright breach with nothing parked: step the V/f table up
        let b = c.decide(&window(2_000_000, 1.0, 4), &state(NOMINAL_INDEX, 0, 4, 4));
        assert_eq!(b.op_index, NOMINAL_INDEX + 1);
        assert_eq!(b.parked, 0);
        // breach at the top of the table: clamp
        let t = c.decide(&window(2_000_000, 1.0, 4), &state(4, 0, 4, 4));
        assert_eq!(t.op_index, 4);
        // deep backlog alone counts as hot even with a tiny p99
        let d = c.decide(&window(10, 1.0, 9), &state(NOMINAL_INDEX, 1, 4, 9));
        assert_eq!(d.parked, 0);
    }

    #[test]
    fn slo_dvfs_needs_consecutive_calm_windows_to_step_down() {
        let mut c = SloDvfs::new(1_000_000);
        let cold = window(100_000, 0.05, 0);
        let s = state(NOMINAL_INDEX, 0, 4, 0);
        // first calm window: hold
        assert_eq!(c.decide(&cold, &s), ControlAction::hold(&s));
        // second consecutive: step down
        let a = c.decide(&cold, &s);
        assert_eq!(a.op_index, NOMINAL_INDEX - 1);
        // a hot window resets the streak
        let _ = c.decide(&cold, &s);
        let _ = c.decide(&window(999_999_999, 1.0, 20), &s);
        assert_eq!(c.decide(&cold, &s), ControlAction::hold(&s), "streak must restart");
    }

    #[test]
    fn slo_dvfs_parks_only_at_the_voltage_floor_and_never_the_last_shard() {
        let mut c = SloDvfs::new(1_000_000);
        let cold = window(100_000, 0.05, 0);
        // at op 0 with idle fleet: park instead of stepping down
        let s = state(0, 0, 4, 0);
        let _ = c.decide(&cold, &s);
        let a = c.decide(&cold, &s);
        assert_eq!(a.parked, 1);
        assert_eq!(a.op_index, 0);
        // 3 of 4 already parked: the last awake shard stays awake
        let last = state(0, 3, 4, 0);
        let _ = c.decide(&cold, &last);
        let b = c.decide(&cold, &last);
        assert_eq!(b.parked, 3, "must never park the last shard");
        // busy-but-calm fleet at the floor: no park either
        let busy_calm = window(100_000, 0.8, 0);
        let _ = c.decide(&busy_calm, &s);
        let d = c.decide(&busy_calm, &s);
        assert_eq!(d.parked, 0, "utilization gate must hold the shard");
    }

    #[test]
    fn slo_dvfs_wakes_a_parked_shard_on_a_crash_window() {
        let mut c = SloDvfs::new(1_000_000);
        // latencies and queue are pristine, but a shard just crashed:
        // the crash window alone is hot and a parked shard wakes
        let mut w = window(10, 0.2, 0);
        w.shards_down = 1;
        let a = c.decide(&w, &state(NOMINAL_INDEX, 2, 4, 0));
        assert_eq!(a.parked, 1, "crash window wakes a parked shard");
        assert_eq!(a.op_index, NOMINAL_INDEX, "no SLO breach, no boost");
        // same window with nothing parked: nothing to wake, hold
        let b = c.decide(&w, &state(NOMINAL_INDEX, 0, 4, 0));
        assert_eq!(b, ControlAction::hold(&state(NOMINAL_INDEX, 0, 4, 0)));
        // and it also resets any calm streak
        let cold = window(100_000, 0.05, 0);
        let s = state(NOMINAL_INDEX, 0, 4, 0);
        let _ = c.decide(&cold, &s);
        let _ = c.decide(&w, &s);
        assert_eq!(c.decide(&cold, &s), ControlAction::hold(&s), "streak restarted");
    }

    #[test]
    fn policy_lookup_mirrors_scheduler_names() {
        assert_eq!(control_by_name("static", 1).unwrap().name(), "static-nominal");
        assert_eq!(control_by_name("static-nominal", 1).unwrap().name(), "static-nominal");
        let c = control_by_name("slo-dvfs", 42).unwrap();
        assert_eq!(c.name(), "slo-dvfs");
        assert_eq!(c.slo_p99_cycles(), Some(42));
        assert!(control_by_name("pid", 1).is_none());
    }

    #[test]
    fn from_ms_converts_at_the_fleet_clock() {
        let c = SloDvfs::from_ms(10.0, 425.0e6);
        assert_eq!(c.slo_p99_cycles(), Some(4_250_000));
    }
}
