//! Pluggable objectives: what "better" means on the frontier.
//!
//! Each [`Objective`] reads one metric off an [`Evaluation`] and knows
//! its direction. Dominance and ranking never touch raw metrics
//! directly — they go through [`Objective::key`], the canonical
//! bigger-is-better orientation (minimized objectives are negated), so
//! [`super::pareto`] and the search ranking share one definition of
//! dominance.

use super::operating::Evaluation;

/// One optimization objective over an [`Evaluation`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    /// Energy efficiency, GOp/J — maximize.
    GopJ,
    /// Throughput, GOp/s — maximize.
    GopS,
    /// p99 latency, ms — minimize.
    P99,
    /// Silicon area, mm² — minimize.
    Mm2,
}

impl Objective {
    /// Every objective, in the canonical reporting order.
    pub const ALL: [Objective; 4] =
        [Objective::GopJ, Objective::GopS, Objective::P99, Objective::Mm2];

    pub fn name(&self) -> &'static str {
        match self {
            Objective::GopJ => "gopj",
            Objective::GopS => "gops",
            Objective::P99 => "p99",
            Objective::Mm2 => "mm2",
        }
    }

    /// Human-readable direction tag for tables.
    pub fn direction(&self) -> &'static str {
        if self.maximize() {
            "max"
        } else {
            "min"
        }
    }

    pub fn maximize(&self) -> bool {
        matches!(self, Objective::GopJ | Objective::GopS)
    }

    pub fn by_name(name: &str) -> Option<Objective> {
        match name {
            "gopj" | "gop/j" | "efficiency" => Some(Objective::GopJ),
            "gops" | "gop/s" | "throughput" => Some(Objective::GopS),
            "p99" | "p99_ms" | "latency" => Some(Objective::P99),
            "mm2" | "area" => Some(Objective::Mm2),
            _ => None,
        }
    }

    /// Parse a comma-separated objective list (`gopj,gops,p99,mm2`),
    /// deduplicating while preserving order.
    pub fn parse_list(csv: &str) -> Result<Vec<Objective>, String> {
        let mut out: Vec<Objective> = Vec::new();
        for raw in csv.split(',') {
            let raw = raw.trim();
            if raw.is_empty() {
                continue;
            }
            let o = Objective::by_name(&raw.to_ascii_lowercase()).ok_or_else(|| {
                format!(
                    "unknown objective {raw:?}; available: gopj, gops, p99, mm2"
                )
            })?;
            if !out.contains(&o) {
                out.push(o);
            }
        }
        if out.is_empty() {
            return Err("objective list is empty".to_string());
        }
        Ok(out)
    }

    /// The objective's raw value on an evaluation, in its natural unit.
    pub fn value(&self, e: &Evaluation) -> f64 {
        match self {
            Objective::GopJ => e.gopj,
            Objective::GopS => e.gops,
            Objective::P99 => e.p99_ms,
            Objective::Mm2 => e.mm2,
        }
    }

    /// Canonical bigger-is-better dominance key (minimized objectives
    /// are negated).
    pub fn key(&self, e: &Evaluation) -> f64 {
        if self.maximize() {
            self.value(e)
        } else {
            -self.value(e)
        }
    }
}

/// The canonical key vector of an evaluation under a set of objectives.
pub fn keys_of(objectives: &[Objective], e: &Evaluation) -> Vec<f64> {
    objectives.iter().map(|o| o.key(e)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip() {
        for o in Objective::ALL {
            assert_eq!(Objective::by_name(o.name()), Some(o));
        }
        assert_eq!(Objective::by_name("area"), Some(Objective::Mm2));
        assert!(Objective::by_name("qps").is_none());
    }

    #[test]
    fn parse_list_dedupes_and_errors() {
        let v = Objective::parse_list("gopj, gops,gopj,MM2").unwrap();
        assert_eq!(v, vec![Objective::GopJ, Objective::GopS, Objective::Mm2]);
        assert!(Objective::parse_list("gopj,warp").is_err());
        assert!(Objective::parse_list(" , ").is_err());
    }

    #[test]
    fn directions() {
        assert!(Objective::GopJ.maximize() && Objective::GopS.maximize());
        assert!(!Objective::P99.maximize() && !Objective::Mm2.maximize());
        assert_eq!(Objective::P99.direction(), "min");
        assert_eq!(Objective::GopJ.direction(), "max");
    }
}
