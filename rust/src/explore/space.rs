//! The design space: what the explorer sweeps.
//!
//! A [`DesignSpace`] is a cross product of axis value lists over the
//! architectural template — cluster geometry (worker cores, TCDM
//! banks/capacity, ITA N/M), the FD-SOI operating point
//! (`energy::operating_point`), deployment knobs (encoder blocks,
//! MHA fusion) and serving configuration (fleet size, scheduler) —
//! plus one [`ServeSpec`] describing the workload every candidate is
//! judged against. A [`Candidate`] is one fully specified point; its
//! `index` is its position in the deterministic mixed-radix
//! enumeration, which doubles as the tie-break identity everywhere in
//! the search (rankings, frontier ordering, reports).
//!
//! The enumeration is the determinism backbone: `nth(i)` is a pure
//! mixed-radix decode, so grid order, seeded-random sampling
//! (`nth(rng.next_below(len))`) and the paper-anchor lookup all agree
//! on what candidate `i` *is* without materializing the space.

use crate::deeploy::DeployError;
use crate::energy::operating_point::{self, OperatingPoint, OPERATING_POINTS};
use crate::ita::ItaConfig;
use crate::models::{ModelConfig, DINOV2S, MOBILEBERT, WHISPER_TINY_ENC};
use crate::net::Topology;
use crate::serve::{admission_by_name, scheduler_by_name};
use crate::sim::ClusterConfig;

/// The workload every candidate's full-fidelity evaluation serves:
/// request classes (one per model, at the candidate's layer count) and
/// an open-loop arrival process. The workload seed comes from the
/// search configuration, not from here — `explore --seed N` varies the
/// draw the same way `serve --seed` does.
#[derive(Debug, Clone)]
pub struct ServeSpec {
    /// Request-class models. The cheap screen rung evaluates every
    /// class single-stream and aggregates (ops-weighted throughput and
    /// efficiency, worst-case p99).
    pub models: Vec<&'static ModelConfig>,
    /// Requests offered per full-fidelity evaluation.
    pub requests: usize,
    /// Open-loop Poisson arrival rate, req/s.
    pub rate_rps: f64,
    /// Square-wave burst factor (bursty Poisson when set).
    pub burst_factor: Option<f64>,
    /// p99 latency SLO handed to the control plane when the candidate's
    /// `control` knob is on (the `SloDvfs` controller holds it at
    /// minimum J/request).
    pub slo_p99_ms: f64,
}

/// One fully specified design point.
#[derive(Debug, Clone, PartialEq)]
pub struct Candidate {
    /// Position in the space's deterministic enumeration — the
    /// candidate's identity for rankings and tie-breaks.
    pub index: usize,
    /// Worker Snitch cores (the +1 DMA core is always present).
    pub cores: usize,
    /// TCDM banks.
    pub banks: usize,
    /// Total L1 capacity, KiB.
    pub l1_kib: usize,
    /// ITA dot-product units (N).
    pub ita_n: usize,
    /// ITA vector length (M).
    pub ita_m: usize,
    /// Index into [`OPERATING_POINTS`].
    pub op: usize,
    /// Encoder blocks per compiled request class.
    pub layers: usize,
    /// MHA fusion pass on/off.
    pub fuse: bool,
    /// Fleet size for serving.
    pub fleet: usize,
    /// Scheduler name (`serve::scheduler_by_name`).
    pub scheduler: &'static str,
    /// Online control plane on/off: when on, the serving evaluation
    /// runs under the `SloDvfs` controller at the spec's p99 SLO.
    pub control: bool,
    /// Interconnect topology label (`Topology::parse` shape): `"flat"`
    /// attaches nothing — the historical free interconnect — while a
    /// `"pod:PxBxC"` label prices serving over `crate::net` links.
    pub topology: &'static str,
    /// Admission policy label (`serve::admission_by_name` shape):
    /// `"admit-all"` attaches nothing — the historical fault-free
    /// serving path — while `"threshold:D"` / `"tenant-fair:D"`
    /// evaluate the candidate under load shedding.
    pub admission: &'static str,
}

impl Candidate {
    pub fn operating_point(&self) -> &'static OperatingPoint {
        &OPERATING_POINTS[self.op]
    }

    /// The cluster geometry this candidate instantiates. HWPE port
    /// provisioning follows the datapath's "two M-byte operand vectors
    /// per cycle" requirement (paper Section IV-B): `2·M / 8` ports —
    /// 16 at M=64, so the paper candidate reproduces
    /// `ClusterConfig::default()` field-for-field (and shares its cache
    /// entries).
    pub fn cluster(&self) -> ClusterConfig {
        let ita = ItaConfig {
            n_units: self.ita_n,
            m_vec: self.ita_m,
            ..ItaConfig::default()
        };
        let l1_bytes = self.l1_kib * 1024;
        ClusterConfig {
            n_cores: self.cores,
            tcdm_banks: self.banks,
            tcdm_bank_bytes: l1_bytes / self.banks.max(1),
            hwpe_ports: (2 * self.ita_m).div_ceil(8).max(4),
            freq_hz: self.operating_point().freq_hz,
            ita,
            ..ClusterConfig::default()
        }
    }

    /// Whether this candidate is the paper's published silicon point:
    /// the 8+1-core / 32-bank 128 KiB / N=16 M=64 cluster at the
    /// 0.65 V / 425 MHz corner with MHA fusion on. Serving overlays
    /// (fleet size, scheduler) are ours, not the paper's, so they do
    /// not participate in the flag.
    pub fn is_paper_geometry(&self) -> bool {
        self.cores == 8
            && self.banks == 32
            && self.l1_kib == 128
            && self.ita_n == 16
            && self.ita_m == 64
            && self.op == operating_point::NOMINAL_INDEX
            && self.fuse
    }

    /// Compact geometry label for tables.
    pub fn label(&self) -> String {
        format!(
            "{}c/{}b/{}KiB N{}M{}",
            self.cores, self.banks, self.l1_kib, self.ita_n, self.ita_m
        )
    }
}

/// A cross-product design space (see the module docs).
#[derive(Debug, Clone)]
pub struct DesignSpace {
    pub name: &'static str,
    pub cores: Vec<usize>,
    pub banks: Vec<usize>,
    pub l1_kib: Vec<usize>,
    pub ita_n: Vec<usize>,
    pub ita_m: Vec<usize>,
    /// Indices into [`OPERATING_POINTS`].
    pub ops: Vec<usize>,
    pub layers: Vec<usize>,
    pub fuse: Vec<bool>,
    pub fleets: Vec<usize>,
    pub schedulers: Vec<&'static str>,
    /// Control-plane knob values (`[false]` keeps the axis inert).
    pub control: Vec<bool>,
    /// Interconnect topology labels (`["flat"]` keeps the axis inert —
    /// radix 1, no serving-path change, index semantics preserved).
    pub topologies: Vec<&'static str>,
    /// Admission policy labels (`["admit-all"]` keeps the axis inert —
    /// radix 1, the fault layer is never attached, index semantics
    /// preserved).
    pub admissions: Vec<&'static str>,
    pub serve: ServeSpec,
}

impl DesignSpace {
    /// Number of candidates in the cross product.
    pub fn len(&self) -> usize {
        self.cores.len()
            * self.banks.len()
            * self.l1_kib.len()
            * self.ita_n.len()
            * self.ita_m.len()
            * self.ops.len()
            * self.layers.len()
            * self.fuse.len()
            * self.fleets.len()
            * self.schedulers.len()
            * self.control.len()
            * self.topologies.len()
            * self.admissions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Deterministic mixed-radix decode of candidate `i` (0-based,
    /// `i < len()`): the admission axis varies fastest, cores slowest.
    /// (Singleton `control: [false]` / `topologies: ["flat"]` /
    /// `admissions: ["admit-all"]` axes are radix 1 and keep index
    /// semantics identical to the enumerations that predate them.)
    pub fn nth(&self, index: usize) -> Candidate {
        let mut i = index;
        let mut pick = |len: usize| {
            let k = i % len;
            i /= len;
            k
        };
        let admission = self.admissions[pick(self.admissions.len())];
        let topology = self.topologies[pick(self.topologies.len())];
        let control = self.control[pick(self.control.len())];
        let scheduler = self.schedulers[pick(self.schedulers.len())];
        let fleet = self.fleets[pick(self.fleets.len())];
        let fuse = self.fuse[pick(self.fuse.len())];
        let layers = self.layers[pick(self.layers.len())];
        let op = self.ops[pick(self.ops.len())];
        let ita_m = self.ita_m[pick(self.ita_m.len())];
        let ita_n = self.ita_n[pick(self.ita_n.len())];
        let l1_kib = self.l1_kib[pick(self.l1_kib.len())];
        let banks = self.banks[pick(self.banks.len())];
        let cores = self.cores[pick(self.cores.len())];
        Candidate {
            index,
            cores,
            banks,
            l1_kib,
            ita_n,
            ita_m,
            op,
            layers,
            fuse,
            fleet,
            scheduler,
            control,
            topology,
            admission,
        }
    }

    /// Lowest-index candidate with the paper's silicon, if the space
    /// contains one — the explorer's calibration anchor.
    pub fn paper_index(&self) -> Option<usize> {
        (0..self.len()).find(|&i| self.nth(i).is_paper_geometry())
    }

    /// Every candidate with the paper's silicon (one per serving
    /// overlay — fleet × scheduler). The search promotes all of them to
    /// full evaluation so the published point is measurable on every
    /// frontier under its best serving configuration, not just the
    /// enumeration-first one.
    pub fn paper_indices(&self) -> Vec<usize> {
        (0..self.len()).filter(|&i| self.nth(i).is_paper_geometry()).collect()
    }

    /// Structural validation: every axis non-empty and in range, the
    /// banking divides the capacity, schedulers resolve, and the serve
    /// spec is a valid workload shape.
    pub fn validate(&self) -> Result<(), DeployError> {
        let err = |m: String| Err(DeployError::Builder(m));
        if self.is_empty() {
            return err(format!("design space {}: an axis is empty", self.name));
        }
        for &op in &self.ops {
            if op >= OPERATING_POINTS.len() {
                return err(format!(
                    "design space {}: operating point {op} out of range (table has {})",
                    self.name,
                    OPERATING_POINTS.len()
                ));
            }
        }
        for &b in &self.banks {
            if b == 0 {
                return err(format!("design space {}: 0 TCDM banks", self.name));
            }
            for &kib in &self.l1_kib {
                if (kib * 1024) % b != 0 {
                    return err(format!(
                        "design space {}: {kib} KiB L1 does not divide into {b} banks",
                        self.name
                    ));
                }
            }
        }
        if self.cores.contains(&0) || self.layers.contains(&0) || self.fleets.contains(&0) {
            return err(format!(
                "design space {}: cores, layers and fleets must be >= 1",
                self.name
            ));
        }
        if self.ita_n.contains(&0) || self.ita_m.contains(&0) {
            return err(format!("design space {}: ITA N/M must be >= 1", self.name));
        }
        for s in &self.schedulers {
            if scheduler_by_name(s).is_none() {
                return err(format!("design space {}: unknown scheduler {s}", self.name));
            }
        }
        for t in &self.topologies {
            let Some(topo) = Topology::parse(t) else {
                return err(format!("design space {}: unknown topology {t}", self.name));
            };
            if let Some(cap) = topo.capacity() {
                for &fleet in &self.fleets {
                    if fleet > cap {
                        return err(format!(
                            "design space {}: fleet {fleet} exceeds topology {t} \
                             capacity {cap}",
                            self.name
                        ));
                    }
                }
            }
        }
        for a in &self.admissions {
            if admission_by_name(a).is_none() {
                return err(format!(
                    "design space {}: unknown admission policy {a}",
                    self.name
                ));
            }
        }
        if self.serve.models.is_empty() {
            return err(format!("design space {}: serve spec has no models", self.name));
        }
        if self.serve.requests == 0 {
            return err(format!("design space {}: serve spec offers 0 requests", self.name));
        }
        if !self.serve.rate_rps.is_finite() || self.serve.rate_rps <= 0.0 {
            return err(format!(
                "design space {}: arrival rate must be positive",
                self.name
            ));
        }
        if let Some(b) = self.serve.burst_factor {
            if !b.is_finite() || b < 1.0 {
                return err(format!(
                    "design space {}: burst factor must be >= 1",
                    self.name
                ));
            }
        }
        if !self.serve.slo_p99_ms.is_finite() || self.serve.slo_p99_ms <= 0.0 {
            return err(format!(
                "design space {}: the p99 SLO must be a positive duration",
                self.name
            ));
        }
        Ok(())
    }

    /// Named presets for the CLI (`--space`).
    pub fn preset(name: &str) -> Option<DesignSpace> {
        match name {
            "default" => Some(Self::default_space()),
            "tiny" => Some(Self::tiny()),
            "mix" => Some(Self::mix()),
            "full" => Some(Self::full()),
            _ => None,
        }
    }

    /// The default exploration space: banks × ITA N × three operating
    /// points × fleet × scheduler around the paper's silicon (108
    /// candidates), judged on an overloaded single-class MobileBERT
    /// stream so scheduling quality shows. Contains the paper point.
    pub fn default_space() -> DesignSpace {
        DesignSpace {
            name: "default",
            cores: vec![8],
            banks: vec![16, 32, 64],
            l1_kib: vec![128],
            ita_n: vec![8, 16, 32],
            ita_m: vec![64],
            ops: vec![0, operating_point::NOMINAL_INDEX, 4],
            layers: vec![1],
            fuse: vec![true],
            fleets: vec![1, 2],
            schedulers: vec!["fifo", "batch"],
            control: vec![false],
            topologies: vec!["flat"],
            admissions: vec!["admit-all"],
            serve: ServeSpec {
                models: vec![&MOBILEBERT],
                requests: 64,
                rate_rps: 2000.0,
                burst_factor: None,
                slo_p99_ms: 10.0,
            },
        }
    }

    /// Smoke-test space: four candidates (ITA N ∈ {8,16} at two
    /// operating points), a 16-request stream — `make explore-smoke`.
    pub fn tiny() -> DesignSpace {
        DesignSpace {
            name: "tiny",
            cores: vec![8],
            banks: vec![32],
            l1_kib: vec![128],
            ita_n: vec![8, 16],
            ita_m: vec![64],
            ops: vec![0, operating_point::NOMINAL_INDEX],
            layers: vec![1],
            fuse: vec![true],
            fleets: vec![1],
            schedulers: vec!["fifo"],
            control: vec![false],
            topologies: vec!["flat"],
            admissions: vec!["admit-all"],
            serve: ServeSpec {
                models: vec![&MOBILEBERT],
                requests: 16,
                rate_rps: 2000.0,
                burst_factor: None,
                slo_p99_ms: 10.0,
            },
        }
    }

    /// Multi-model serving mix: all three evaluation networks as
    /// request classes on a bursty stream, with all three schedulers in
    /// the space — where dynamic batching earns its frontier seats.
    pub fn mix() -> DesignSpace {
        DesignSpace {
            name: "mix",
            cores: vec![8],
            banks: vec![32],
            l1_kib: vec![128],
            ita_n: vec![8, 16, 32],
            ita_m: vec![64],
            ops: vec![0, operating_point::NOMINAL_INDEX, 4],
            layers: vec![1],
            fuse: vec![true],
            fleets: vec![1, 4],
            schedulers: vec!["fifo", "rr", "batch"],
            control: vec![false, true],
            topologies: vec!["flat"],
            admissions: vec!["admit-all"],
            serve: ServeSpec {
                models: vec![&MOBILEBERT, &DINOV2S, &WHISPER_TINY_ENC],
                requests: 96,
                rate_rps: 2000.0,
                burst_factor: Some(4.0),
                slo_p99_ms: 10.0,
            },
        }
    }

    /// The wide space for budgeted search (19440 candidates): every
    /// template axis open, all five operating points, control plane on
    /// and off — pair it with `--strategy halving --budget N`.
    pub fn full() -> DesignSpace {
        DesignSpace {
            name: "full",
            cores: vec![4, 8, 12],
            banks: vec![16, 32, 64],
            l1_kib: vec![64, 128, 256],
            ita_n: vec![8, 16, 32],
            ita_m: vec![64],
            ops: vec![0, 1, 2, 3, 4],
            layers: vec![1],
            fuse: vec![true, false],
            fleets: vec![1, 2, 4, 8],
            schedulers: vec!["fifo", "rr", "batch"],
            control: vec![false, true],
            topologies: vec!["flat"],
            admissions: vec!["admit-all"],
            serve: ServeSpec {
                models: vec![&MOBILEBERT],
                requests: 64,
                rate_rps: 2000.0,
                burst_factor: Some(4.0),
                slo_p99_ms: 10.0,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn enumeration_is_a_bijection() {
        let s = DesignSpace::default_space();
        assert_eq!(s.len(), 108);
        let mut seen = std::collections::BTreeSet::new();
        for i in 0..s.len() {
            let c = s.nth(i);
            assert_eq!(c.index, i);
            // the full tuple is unique across the enumeration
            let key = (
                c.cores, c.banks, c.l1_kib, c.ita_n, c.ita_m, c.op, c.layers, c.fuse,
                c.fleet, c.scheduler, c.control, c.topology, c.admission,
            );
            assert!(seen.insert(key), "candidate {i} repeats {key:?}");
        }
    }

    #[test]
    fn control_axis_varies_fastest_and_stays_inert_when_singleton() {
        // default space: singleton [false] — every candidate uncontrolled,
        // size and index semantics unchanged from the pre-control space
        let d = DesignSpace::default_space();
        assert!((0..d.len()).all(|i| !d.nth(i).control));
        // mix space: the control bit is the fastest mixed-radix digit
        let m = DesignSpace::mix();
        assert!(!m.nth(0).control);
        assert!(m.nth(1).control);
        let (c0, c1) = (m.nth(0), m.nth(1));
        assert_eq!(c0.scheduler, c1.scheduler);
        assert_eq!(c0.fleet, c1.fleet);
    }

    #[test]
    fn every_preset_validates_and_names_resolve() {
        for name in ["default", "tiny", "mix", "full"] {
            let s = DesignSpace::preset(name).unwrap();
            assert_eq!(s.name, name);
            s.validate().unwrap();
            assert!(!s.is_empty());
        }
        assert!(DesignSpace::preset("galactic").is_none());
    }

    #[test]
    fn paper_candidate_reproduces_the_default_cluster() {
        let s = DesignSpace::default_space();
        let i = s.paper_index().expect("default space contains the paper silicon");
        let c = s.nth(i);
        assert!(c.is_paper_geometry());
        let cluster = c.cluster();
        let reference = ClusterConfig::default();
        // field-for-field: the paper candidate must share the repo-wide
        // default geometry (and therefore its pipeline cache entries)
        assert_eq!(cluster.n_cores, reference.n_cores);
        assert_eq!(cluster.tcdm_banks, reference.tcdm_banks);
        assert_eq!(cluster.tcdm_bank_bytes, reference.tcdm_bank_bytes);
        assert_eq!(cluster.hwpe_ports, reference.hwpe_ports);
        assert_eq!(cluster.freq_hz, reference.freq_hz);
        assert_eq!(cluster.ita, reference.ita);
        assert_eq!(cluster.l1_bytes(), 128 * 1024);
    }

    #[test]
    fn tiny_space_has_two_operating_points() {
        let s = DesignSpace::tiny();
        assert_eq!(s.len(), 4);
        assert_eq!(s.ops.len(), 2);
        assert!(s.paper_index().is_some());
    }

    #[test]
    fn validation_rejects_broken_spaces() {
        let mut s = DesignSpace::tiny();
        s.banks = vec![48]; // 128 KiB does not divide into 48 banks
        assert!(s.validate().is_err());

        let mut s = DesignSpace::tiny();
        s.ops = vec![99];
        assert!(s.validate().is_err());

        let mut s = DesignSpace::tiny();
        s.schedulers = vec!["lifo"];
        assert!(s.validate().is_err());

        let mut s = DesignSpace::tiny();
        s.fleets = vec![];
        assert!(s.validate().is_err());

        let mut s = DesignSpace::tiny();
        s.serve.rate_rps = 0.0;
        assert!(s.validate().is_err());

        let mut s = DesignSpace::tiny();
        s.serve.slo_p99_ms = 0.0;
        assert!(s.validate().is_err());

        let mut s = DesignSpace::tiny();
        s.topologies = vec!["mesh"];
        assert!(s.validate().is_err());

        let mut s = DesignSpace::tiny();
        s.admissions = vec!["drop-everything"];
        assert!(s.validate().is_err());

        // admit-all takes no depth suffix (admission_by_name contract)
        let mut s = DesignSpace::tiny();
        s.admissions = vec!["admit-all:5"];
        assert!(s.validate().is_err());

        // a topology too small for the fleet axis is structural, caught
        // at validation rather than per-candidate evaluation
        let mut s = DesignSpace::tiny();
        s.topologies = vec!["pod:1x1x1"];
        s.fleets = vec![2];
        assert!(s.validate().is_err());
    }

    #[test]
    fn singleton_flat_topology_axis_is_inert() {
        // every preset keeps the historical index semantics: the
        // topology digit has radix 1 and every candidate decodes "flat"
        for name in ["default", "tiny", "mix", "full"] {
            let s = DesignSpace::preset(name).unwrap();
            assert_eq!(s.topologies, vec!["flat"]);
            assert!((0..s.len()).all(|i| s.nth(i).topology == "flat"));
        }
        // and the default space's size is unchanged by the new axis
        assert_eq!(DesignSpace::default_space().len(), 108);
    }

    #[test]
    fn singleton_admit_all_axis_is_inert() {
        // radix-1 admission axis: every preset candidate decodes
        // "admit-all", sizes and indices unchanged from the pre-fault
        // enumerations
        for name in ["default", "tiny", "mix", "full"] {
            let s = DesignSpace::preset(name).unwrap();
            assert_eq!(s.admissions, vec!["admit-all"]);
            assert!((0..s.len()).all(|i| s.nth(i).admission == "admit-all"));
        }
        assert_eq!(DesignSpace::tiny().len(), 4);
        // a widened axis multiplies the space and is the fastest digit
        let mut s = DesignSpace::tiny();
        s.admissions = vec!["admit-all", "threshold:8"];
        s.validate().unwrap();
        assert_eq!(s.len(), 8);
        assert_eq!(s.nth(0).admission, "admit-all");
        assert_eq!(s.nth(1).admission, "threshold:8");
        assert_eq!(s.nth(0).ita_n, s.nth(1).ita_n);
    }
}
