//! The Pareto frontier: incremental non-dominated insertion.
//!
//! A [`Pareto`] holds the maximal set of [`Evaluation`]s under a fixed
//! objective list. Dominance is the standard weak form on the
//! canonical bigger-is-better keys ([`Objective::key`]): `a` dominates
//! `b` iff `a ≥ b` on every objective and `a > b` on at least one.
//! Points with identical key vectors do not dominate each other, so
//! genuine ties coexist on the frontier.
//!
//! Invariants (propchecked in `tests/explore_invariants.rs`):
//!
//! - **No dominated point survives**: inserting rejects dominated
//!   newcomers and evicts every incumbent the newcomer dominates.
//! - **Insertion-order independence**: the final frontier is exactly
//!   the maximal-element set of everything ever offered — a set, not a
//!   history.
//! - **Determinism**: [`Pareto::sorted`] orders by the first
//!   objective's key (descending, `total_cmp`) with the candidate
//!   index as the tie-break, so rendering and JSON are stable.
//! - Non-finite evaluations are rejected outright (a NaN never
//!   dominates and would otherwise squat on the frontier forever).

use super::objective::{keys_of, Objective};
use super::operating::Evaluation;

/// `a` dominates `b` on canonical (bigger-is-better) key vectors.
pub fn dominates(a: &[f64], b: &[f64]) -> bool {
    debug_assert_eq!(a.len(), b.len());
    let mut strictly = false;
    for (x, y) in a.iter().zip(b) {
        if x < y {
            return false;
        }
        if x > y {
            strictly = true;
        }
    }
    strictly
}

/// An incrementally maintained non-dominated set (see module docs).
#[derive(Debug, Clone)]
pub struct Pareto {
    objectives: Vec<Objective>,
    points: Vec<Evaluation>,
    keys: Vec<Vec<f64>>,
}

impl Pareto {
    pub fn new(objectives: Vec<Objective>) -> Pareto {
        assert!(!objectives.is_empty(), "a frontier needs at least one objective");
        Pareto { objectives, points: Vec::new(), keys: Vec::new() }
    }

    pub fn objectives(&self) -> &[Objective] {
        &self.objectives
    }

    /// The canonical key vector of an evaluation under this frontier's
    /// objectives (exposed for the invariant tests).
    pub fn score(&self, e: &Evaluation) -> Vec<f64> {
        keys_of(&self.objectives, e)
    }

    /// Offer one evaluation. Returns `true` if it joined the frontier
    /// (evicting whatever it dominates), `false` if it was dominated by
    /// an incumbent or non-finite.
    pub fn insert(&mut self, e: Evaluation) -> bool {
        if !e.is_finite() {
            return false;
        }
        let k = self.score(&e);
        if self.keys.iter().any(|inc| dominates(inc, &k)) {
            return false;
        }
        // evict everything the newcomer dominates (walk both vectors in
        // lockstep so points/keys stay aligned)
        let mut i = 0;
        while i < self.points.len() {
            if dominates(&k, &self.keys[i]) {
                self.points.swap_remove(i);
                self.keys.swap_remove(i);
            } else {
                i += 1;
            }
        }
        self.points.push(e);
        self.keys.push(k);
        true
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Unordered view of the frontier.
    pub fn points(&self) -> &[Evaluation] {
        &self.points
    }

    /// Deterministically ordered frontier: first objective's key
    /// descending (`total_cmp`), candidate index ascending as the
    /// tie-break.
    pub fn sorted(&self) -> Vec<Evaluation> {
        let mut out = self.points.clone();
        let first = self.objectives[0];
        out.sort_by(|a, b| {
            first
                .key(b)
                .total_cmp(&first.key(a))
                .then_with(|| a.candidate.index.cmp(&b.candidate.index))
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explore::operating::Fidelity;
    use crate::explore::space::Candidate;

    fn eval(index: usize, gopj: f64, gops: f64, p99: f64, mm2: f64) -> Evaluation {
        Evaluation {
            candidate: Candidate {
                index,
                cores: 8,
                banks: 32,
                l1_kib: 128,
                ita_n: 16,
                ita_m: 64,
                op: crate::energy::operating_point::NOMINAL_INDEX,
                layers: 1,
                fuse: true,
                fleet: 1,
                scheduler: "fifo",
                control: false,
                topology: "flat",
                admission: "admit-all",
            },
            fidelity: Fidelity::Screen,
            gops,
            gopj,
            p99_ms: p99,
            mm2,
            req_per_s: 0.0,
            mj_per_req: 0.0,
            events: 0,
        }
    }

    fn frontier() -> Pareto {
        Pareto::new(Objective::ALL.to_vec())
    }

    #[test]
    fn dominance_matches_hand_cases() {
        assert!(dominates(&[2.0, 2.0], &[1.0, 2.0]));
        assert!(!dominates(&[1.0, 2.0], &[2.0, 2.0]));
        assert!(!dominates(&[2.0, 1.0], &[1.0, 2.0]), "incomparable");
        assert!(!dominates(&[1.0, 1.0], &[1.0, 1.0]), "equal vectors tie");
    }

    #[test]
    fn insert_evicts_dominated_and_rejects_dominated() {
        let mut p = frontier();
        assert!(p.insert(eval(0, 100.0, 10.0, 5.0, 1.0)));
        // strictly better everywhere: evicts the incumbent
        assert!(p.insert(eval(1, 200.0, 20.0, 4.0, 0.9)));
        assert_eq!(p.len(), 1);
        assert_eq!(p.points()[0].candidate.index, 1);
        // strictly worse everywhere: rejected
        assert!(!p.insert(eval(2, 150.0, 15.0, 4.5, 0.95)));
        // incomparable trade-off (more efficient, slower): joins
        assert!(p.insert(eval(3, 400.0, 5.0, 8.0, 0.9)));
        assert_eq!(p.len(), 2);
    }

    #[test]
    fn equal_points_coexist() {
        let mut p = frontier();
        assert!(p.insert(eval(0, 100.0, 10.0, 5.0, 1.0)));
        assert!(p.insert(eval(1, 100.0, 10.0, 5.0, 1.0)));
        assert_eq!(p.len(), 2);
    }

    #[test]
    fn non_finite_rejected() {
        let mut p = frontier();
        assert!(!p.insert(eval(0, f64::NAN, 10.0, 5.0, 1.0)));
        assert!(!p.insert(eval(1, f64::INFINITY, 10.0, 5.0, 1.0)));
        assert!(p.is_empty());
    }

    #[test]
    fn sorted_is_deterministic_and_key_ordered() {
        let mut p = frontier();
        p.insert(eval(5, 100.0, 30.0, 5.0, 1.0));
        p.insert(eval(2, 300.0, 10.0, 5.0, 1.0));
        p.insert(eval(9, 200.0, 20.0, 5.0, 1.0));
        let s = p.sorted();
        let idx: Vec<usize> = s.iter().map(|e| e.candidate.index).collect();
        assert_eq!(idx, vec![2, 9, 5], "gopj-descending order");
    }
}
