//! Candidate evaluation at its FD-SOI operating point — the bridge
//! from a [`Candidate`] to the numbers the objectives judge.
//!
//! Two fidelities, the successive-halving ladder's rungs:
//!
//! - [`screen`] — **cheap single-stream screening**: compile the spec's
//!   first model through the (process-wide cached) pipeline, reuse the
//!   memoized `Compiled::stats()`, evaluate the energy model at the
//!   candidate's operating point (`energy::operating_point`, E ∝ V²),
//!   and extrapolate the simulated blocks to the full network exactly
//!   the way `Compiled::simulate()` does. The resulting GOp/s and
//!   GOp/J are Table-I-comparable (the paper anchor's acceptance
//!   tolerances are checked against these); `p99_ms` degenerates to
//!   the single-inference latency and `mm2` is **one** cluster —
//!   fleet/scheduler axes deliberately do not differentiate at this
//!   fidelity, so serving variants of one silicon tie instead of
//!   shadowing each other out of the pool.
//! - [`serve_eval`] — **full multi-request serving**: the spec's
//!   workload on the candidate's fleet under its scheduler, via
//!   `Pipeline::serve_with` (same cached deployments and memoized
//!   serving constants). Throughput/latency come from the
//!   [`crate::serve::ServeReport`]; energy is re-based to the
//!   operating point by splitting the report into active + idle parts
//!   and applying the V² / V²·f scales; `mm2` is the whole fleet's
//!   silicon.
//!
//! Both are pure functions of the candidate (plus spec, requests,
//! seed): no wall clock, no global state beyond the deterministic
//! pipeline cache — which is what lets the search fan them out across
//! threads and still reproduce bit-for-bit.

use crate::deeploy::{DeployError, Target};
use crate::energy::{self, area, operating_point};
use crate::pipeline::Pipeline;
use crate::serve::{scheduler_by_name, RequestClass, Workload, DEFAULT_BURST_PERIOD_S};

use super::space::{Candidate, ServeSpec};

/// Which rung of the evaluation ladder produced an [`Evaluation`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fidelity {
    /// Single-stream screening (Table-I-comparable extrapolation).
    Screen,
    /// Full multi-request serving.
    Serve,
}

impl Fidelity {
    pub fn name(&self) -> &'static str {
        match self {
            Fidelity::Screen => "screen",
            Fidelity::Serve => "serve",
        }
    }
}

/// One evaluated design point: the candidate plus the metric vector
/// the objectives read. Semantics differ by fidelity (see the module
/// docs): screen numbers are full-network single-inference
/// extrapolations on one cluster; serve numbers are fleet-level
/// workload measurements.
#[derive(Debug, Clone)]
pub struct Evaluation {
    pub candidate: Candidate,
    pub fidelity: Fidelity,
    /// Throughput, GOp/s.
    pub gops: f64,
    /// Energy efficiency, GOp/J, at the candidate's operating point.
    pub gopj: f64,
    /// p99 request latency (serve) / single-inference latency (screen),
    /// milliseconds.
    pub p99_ms: f64,
    /// Silicon area: the fleet for serve, one cluster for screen, mm².
    pub mm2: f64,
    /// Served req/s (serve) / inferences per second (screen).
    pub req_per_s: f64,
    /// Energy per request (serve) / per inference (screen), mJ.
    pub mj_per_req: f64,
}

impl Evaluation {
    /// All metrics finite — non-finite evaluations never reach the
    /// frontier.
    pub fn is_finite(&self) -> bool {
        self.gops.is_finite()
            && self.gopj.is_finite()
            && self.p99_ms.is_finite()
            && self.mm2.is_finite()
            && self.req_per_s.is_finite()
            && self.mj_per_req.is_finite()
    }
}

/// Cheap screening rung (see the module docs).
pub fn screen(c: &Candidate, spec: &ServeSpec) -> Result<Evaluation, DeployError> {
    let model = spec.models[0];
    let compiled = Pipeline::new(c.cluster())
        .model(model)
        .target(Target::MultiCoreIta)
        .layers(c.layers)
        .fuse_mha(c.fuse)
        .compile()?;
    let op = c.operating_point();
    let e = operating_point::evaluate_at(compiled.stats(), op);
    // extrapolate the simulated blocks to the full network — the
    // paper's own per-layer measurement strategy (conv stems are
    // excluded at this fidelity, matching the serving layer's
    // per-class command streams)
    let scale = model.layers as f64 / c.layers as f64;
    let seconds = e.seconds * scale;
    let energy_j = e.total_j * scale;
    let gop = model.gop_per_inference;
    Ok(Evaluation {
        candidate: c.clone(),
        fidelity: Fidelity::Screen,
        gops: gop / seconds,
        gopj: gop / energy_j,
        p99_ms: seconds * 1e3,
        mm2: area::cluster_mm2(&c.cluster()),
        req_per_s: 1.0 / seconds,
        mj_per_req: energy_j * 1e3,
    })
}

/// Full serving rung (see the module docs). `requests` overrides the
/// spec's count so the halving ladder can run reduced-fidelity rungs;
/// `seed` is the workload seed (the search passes its own through).
pub fn serve_eval(
    c: &Candidate,
    spec: &ServeSpec,
    requests: usize,
    seed: u64,
) -> Result<Evaluation, DeployError> {
    let classes: Vec<RequestClass> =
        spec.models.iter().map(|m| RequestClass::new(m, c.layers)).collect();
    let w = match spec.burst_factor {
        Some(b) => Workload::bursty(
            classes,
            spec.rate_rps,
            b,
            DEFAULT_BURST_PERIOD_S,
            requests,
            seed,
        ),
        None => Workload::poisson(classes, spec.rate_rps, requests, seed),
    };
    let mut sched = scheduler_by_name(c.scheduler).ok_or_else(|| {
        DeployError::Builder(format!("unknown scheduler {}", c.scheduler))
    })?;
    let r = Pipeline::new(c.cluster())
        .target(Target::MultiCoreIta)
        .fuse_mha(c.fuse)
        .fleet(c.fleet)
        .serve_with(&w, sched.as_mut())?;

    // re-base the report's energy to the candidate's operating point:
    // split off the nominal idle floor the fleet charged, scale the
    // active part by V² and the idle part by the point's V²·f power
    let op = c.operating_point();
    let fleet = c.fleet as f64;
    let idle_ref = energy::P_IDLE_W * r.seconds * fleet;
    let active_j = (r.energy_j - idle_ref).max(0.0);
    let energy_j = active_j * op.energy_scale() + op.idle_power_w() * r.seconds * fleet;
    let gop_served = r.gops * r.seconds;
    Ok(Evaluation {
        candidate: c.clone(),
        fidelity: Fidelity::Serve,
        gops: r.gops,
        gopj: gop_served / energy_j,
        p99_ms: r.p99_ms(),
        mm2: area::cluster_mm2(&c.cluster()) * fleet,
        req_per_s: r.req_per_s,
        mj_per_req: energy_j * 1e3 / (r.served.max(1)) as f64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explore::space::DesignSpace;

    fn paper_candidate() -> Candidate {
        let s = DesignSpace::default_space();
        s.nth(s.paper_index().unwrap())
    }

    #[test]
    fn paper_screen_matches_table1_anchors() {
        // the acceptance anchor (DESIGN.md §6): the published silicon
        // screens to 154 GOp/s and 2960 GOp/J within the calibrated
        // tolerances (±25% throughput, −26%/+35% efficiency)
        let e = screen(&paper_candidate(), &DesignSpace::default_space().serve).unwrap();
        assert_eq!(e.fidelity, Fidelity::Screen);
        assert!(e.gops > 115.0 && e.gops < 195.0, "GOp/s {}", e.gops);
        assert!(e.gopj > 2200.0 && e.gopj < 4000.0, "GOp/J {}", e.gopj);
        assert!((e.mm2 - 0.991).abs() < 1e-9, "mm² {}", e.mm2);
        assert!(e.is_finite());
    }

    #[test]
    fn lower_voltage_screens_more_efficient_but_slower() {
        let spec = DesignSpace::default_space().serve;
        let paper = paper_candidate();
        let mut low = paper.clone();
        low.op = 0; // 0.50 V
        let a = screen(&paper, &spec).unwrap();
        let b = screen(&low, &spec).unwrap();
        assert!(b.gopj > a.gopj, "0.50 V must be more efficient");
        assert!(b.gops < a.gops, "0.50 V must be slower");
        assert_eq!(a.mm2.to_bits(), b.mm2.to_bits(), "voltage costs no area");
    }

    fn default_spec() -> ServeSpec {
        DesignSpace::default_space().serve
    }

    #[test]
    fn serve_eval_scales_area_with_the_fleet_and_stays_finite() {
        let spec = default_spec();
        let paper = paper_candidate();
        let mut two = paper.clone();
        two.fleet = 2;
        two.scheduler = "batch";
        let a = serve_eval(&paper, &spec, 16, 0xA5).unwrap();
        let b = serve_eval(&two, &spec, 16, 0xA5).unwrap();
        assert_eq!(a.fidelity, Fidelity::Serve);
        assert!(a.is_finite() && b.is_finite());
        assert!((b.mm2 - 2.0 * a.mm2).abs() < 1e-12);
        assert!(b.gops >= a.gops, "two clusters cannot serve slower");
    }

    #[test]
    fn serve_eval_at_nominal_stays_positive_and_finite() {
        let spec = default_spec();
        let paper = paper_candidate();
        let e = serve_eval(&paper, &spec, 8, 0x5EED).unwrap();
        assert!(e.gopj > 0.0 && e.mj_per_req > 0.0);
        assert!(e.is_finite());
    }
}
