//! Candidate evaluation at its FD-SOI operating point — the bridge
//! from a [`Candidate`] to the numbers the objectives judge.
//!
//! Two fidelities, the successive-halving ladder's rungs:
//!
//! - [`screen`] — **cheap single-stream screening**: compile every
//!   request-class model in the spec through the (process-wide cached)
//!   pipeline, reuse the memoized `Compiled::stats()`, evaluate the
//!   energy model at the candidate's operating point
//!   (`energy::operating_point`, E ∝ V²), extrapolate the simulated
//!   blocks to each full network exactly the way
//!   `Compiled::simulate()` does, and aggregate: throughput and
//!   efficiency as total GOp over total seconds/joules, `p99_ms` as
//!   the worst single-inference latency across classes. For a
//!   single-model spec this reduces bit-for-bit to the one-model
//!   screen, so the Table-I-comparable paper-anchor tolerances still
//!   apply. `mm2` is **one** cluster — fleet/scheduler/control axes
//!   deliberately do not differentiate at this fidelity, so serving
//!   variants of one silicon tie instead of shadowing each other out
//!   of the pool.
//! - [`serve_eval`] — **full multi-request serving**: the spec's
//!   workload on the candidate's fleet under its scheduler, via
//!   `Pipeline::serve_with` (same cached deployments and memoized
//!   serving constants). Throughput/latency come from the
//!   [`crate::serve::ServeReport`]; energy is re-based to the
//!   operating point by splitting the report into active + idle parts
//!   and applying the V² / V²·f scales; `mm2` is the whole fleet's
//!   silicon. Candidates with the `control` knob on instead run
//!   [`crate::serve::Fleet::serve_controlled`] under `SloDvfs` at the
//!   spec's p99 SLO with the candidate's own corner as the base
//!   operating point — the engine's per-interval accounting already
//!   reports energy on the same absolute (vs-nominal) scale the
//!   re-basing would produce, so the report energy is taken directly.
//!
//! Both are pure functions of the candidate (plus spec, requests,
//! seed): no wall clock, no global state beyond the deterministic
//! pipeline cache — which is what lets the search fan them out across
//! threads and still reproduce bit-for-bit.

use crate::deeploy::{DeployError, Target};
use crate::energy::{self, area, operating_point};
use crate::net::Topology;
use crate::obs::ObsConfig;
use crate::pipeline::Pipeline;
use crate::serve::{
    admission_by_name, scheduler_by_name, FaultConfig, Fleet, RequestClass, SloDvfs,
    Workload, DEFAULT_BURST_PERIOD_S, DEFAULT_CONTROL_CADENCE_CYCLES,
};

use super::space::{Candidate, ServeSpec};

/// The serve rung's observability attachment: full sampling into a
/// small ring (the event *count* is the metric; the stream itself is
/// discarded), fixed seed so evaluations stay pure functions of the
/// candidate + spec + workload seed.
const EXPLORE_OBS: ObsConfig = ObsConfig { sample_every: 1, capacity: 1024, seed: 0xE5EED };

/// Which rung of the evaluation ladder produced an [`Evaluation`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fidelity {
    /// Single-stream screening (Table-I-comparable extrapolation).
    Screen,
    /// Full multi-request serving.
    Serve,
}

impl Fidelity {
    pub fn name(&self) -> &'static str {
        match self {
            Fidelity::Screen => "screen",
            Fidelity::Serve => "serve",
        }
    }
}

/// One evaluated design point: the candidate plus the metric vector
/// the objectives read. Semantics differ by fidelity (see the module
/// docs): screen numbers are full-network single-inference
/// extrapolations on one cluster; serve numbers are fleet-level
/// workload measurements.
#[derive(Debug, Clone)]
pub struct Evaluation {
    pub candidate: Candidate,
    pub fidelity: Fidelity,
    /// Throughput, GOp/s.
    pub gops: f64,
    /// Energy efficiency, GOp/J, at the candidate's operating point.
    pub gopj: f64,
    /// p99 request latency (serve) / single-inference latency (screen),
    /// milliseconds.
    pub p99_ms: f64,
    /// Silicon area: the fleet for serve, one cluster for screen, mm².
    pub mm2: f64,
    /// Served req/s (serve) / inferences per second (screen).
    pub req_per_s: f64,
    /// Energy per request (serve) / per inference (screen), mJ.
    pub mj_per_req: f64,
    /// Lifecycle events the serve rung emitted under its always-on
    /// observability attachment ([`crate::obs`]) — a deterministic
    /// activity measure per candidate (0 at screen fidelity, which
    /// runs no serve loop). Host wall-clock stays out: evaluations
    /// must serialize bit-identically across same-seed runs.
    pub events: u64,
}

impl Evaluation {
    /// All metrics finite — non-finite evaluations never reach the
    /// frontier.
    pub fn is_finite(&self) -> bool {
        self.gops.is_finite()
            && self.gopj.is_finite()
            && self.p99_ms.is_finite()
            && self.mm2.is_finite()
            && self.req_per_s.is_finite()
            && self.mj_per_req.is_finite()
    }
}

/// Cheap screening rung (see the module docs): one single-stream
/// evaluation per request-class model, aggregated over the whole mix.
pub fn screen(c: &Candidate, spec: &ServeSpec) -> Result<Evaluation, DeployError> {
    let op = c.operating_point();
    let mut sec_sum = 0.0f64;
    let mut j_sum = 0.0f64;
    let mut gop_sum = 0.0f64;
    let mut worst_sec = 0.0f64;
    for model in &spec.models {
        let compiled = Pipeline::new(c.cluster())
            .model(model)
            .target(Target::MultiCoreIta)
            .layers(c.layers)
            .fuse_mha(c.fuse)
            .compile()?;
        let e = operating_point::evaluate_at(compiled.stats(), op);
        // extrapolate the simulated blocks to the full network — the
        // paper's own per-layer measurement strategy (conv stems are
        // excluded at this fidelity, matching the serving layer's
        // per-class command streams)
        let scale = model.layers as f64 / c.layers as f64;
        let seconds = e.seconds * scale;
        sec_sum += seconds;
        j_sum += e.total_j * scale;
        gop_sum += model.gop_per_inference;
        worst_sec = worst_sec.max(seconds);
    }
    let n = spec.models.len() as f64;
    Ok(Evaluation {
        candidate: c.clone(),
        fidelity: Fidelity::Screen,
        gops: gop_sum / sec_sum,
        gopj: gop_sum / j_sum,
        p99_ms: worst_sec * 1e3,
        mm2: area::cluster_mm2(&c.cluster()),
        req_per_s: n / sec_sum,
        mj_per_req: j_sum * 1e3 / n,
        events: 0,
    })
}

/// Full serving rung (see the module docs). `requests` overrides the
/// spec's count so the halving ladder can run reduced-fidelity rungs;
/// `seed` is the workload seed (the search passes its own through).
pub fn serve_eval(
    c: &Candidate,
    spec: &ServeSpec,
    requests: usize,
    seed: u64,
) -> Result<Evaluation, DeployError> {
    let classes: Vec<RequestClass> =
        spec.models.iter().map(|m| RequestClass::new(m, c.layers)).collect();
    let w = match spec.burst_factor {
        Some(b) => Workload::bursty(
            classes,
            spec.rate_rps,
            b,
            DEFAULT_BURST_PERIOD_S,
            requests,
            seed,
        ),
        None => Workload::poisson(classes, spec.rate_rps, requests, seed),
    };
    let mut sched = scheduler_by_name(c.scheduler).ok_or_else(|| {
        DeployError::Builder(format!("unknown scheduler {}", c.scheduler))
    })?;
    let op = c.operating_point();
    let fleet = c.fleet as f64;
    // "flat" attaches nothing — the axis is strictly inert there, so a
    // singleton ["flat"] space reproduces the pre-topology numbers
    // bit-for-bit. Any other label prices serving over net/ links.
    let topology = match c.topology {
        "flat" => None,
        label => Some(Topology::parse(label).ok_or_else(|| {
            DeployError::Builder(format!("unknown topology {label}"))
        })?),
    };
    // "admit-all" attaches nothing — the fault layer is never even
    // consulted, so a singleton ["admit-all"] axis reproduces the
    // pre-fault numbers bit-for-bit. Any other label evaluates the
    // candidate under load shedding (empty fault plan, no deadline).
    let fault: Option<FaultConfig> = match c.admission {
        "admit-all" => None,
        label => {
            let admission = admission_by_name(label).ok_or_else(|| {
                DeployError::Builder(format!("unknown admission policy {label}"))
            })?;
            Some(FaultConfig { admission, ..FaultConfig::default() })
        }
    };
    let (r, energy_j) = if c.control {
        // control-plane candidate: run under SloDvfs with the
        // candidate's corner as the base operating point. The engine
        // integrates active energy at absolute V² scale and idle power
        // at the live corner per interval — exactly what the static
        // re-basing below computes for an uncontrolled run — so the
        // report's energy is already on the comparable scale
        let mut f = Fleet::new(c.cluster(), Target::MultiCoreIta, c.fleet)
            .fuse_mha(c.fuse)
            .with_obs(EXPLORE_OBS);
        if let Some(t) = topology {
            f = f.with_topology(t);
        }
        let mut ctl = SloDvfs::from_ms(spec.slo_p99_ms, c.cluster().freq_hz);
        let r = match fault {
            Some(cfg) => f.serve_faulted_controlled(
                &w,
                sched.as_mut(),
                &mut ctl,
                DEFAULT_CONTROL_CADENCE_CYCLES,
                c.op,
                cfg,
            )?,
            None => f.serve_controlled(
                &w,
                sched.as_mut(),
                &mut ctl,
                DEFAULT_CONTROL_CADENCE_CYCLES,
                c.op,
            )?,
        };
        let energy_j = r.energy_j;
        (r, energy_j)
    } else {
        let mut pipe = Pipeline::new(c.cluster())
            .target(Target::MultiCoreIta)
            .fuse_mha(c.fuse)
            .fleet(c.fleet)
            .observe(EXPLORE_OBS);
        if let Some(t) = topology {
            pipe = pipe.topology(t);
        }
        if let Some(cfg) = fault {
            pipe = pipe.faults(cfg);
        }
        let r = pipe.serve_with(&w, sched.as_mut())?;
        // re-base the report's energy to the candidate's operating
        // point: split off the nominal idle floor the fleet charged,
        // scale the active part by V² and the idle part by the point's
        // V²·f power
        let idle_ref = energy::P_IDLE_W * r.seconds * fleet;
        let active_j = (r.energy_j - idle_ref).max(0.0);
        let energy_j =
            active_j * op.energy_scale() + op.idle_power_w() * r.seconds * fleet;
        (r, energy_j)
    };
    let gop_served = r.gops * r.seconds;
    Ok(Evaluation {
        candidate: c.clone(),
        fidelity: Fidelity::Serve,
        gops: r.gops,
        gopj: gop_served / energy_j,
        p99_ms: r.p99_ms(),
        mm2: area::cluster_mm2(&c.cluster()) * fleet,
        req_per_s: r.req_per_s,
        mj_per_req: energy_j * 1e3 / (r.served.max(1)) as f64,
        events: r.profile.as_ref().map_or(0, |p| p.total_events),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explore::space::DesignSpace;

    fn paper_candidate() -> Candidate {
        let s = DesignSpace::default_space();
        s.nth(s.paper_index().unwrap())
    }

    #[test]
    fn paper_screen_matches_table1_anchors() {
        // the acceptance anchor (DESIGN.md §6): the published silicon
        // screens to 154 GOp/s and 2960 GOp/J within the calibrated
        // tolerances (±25% throughput, −26%/+35% efficiency)
        let e = screen(&paper_candidate(), &DesignSpace::default_space().serve).unwrap();
        assert_eq!(e.fidelity, Fidelity::Screen);
        assert!(e.gops > 115.0 && e.gops < 195.0, "GOp/s {}", e.gops);
        assert!(e.gopj > 2200.0 && e.gopj < 4000.0, "GOp/J {}", e.gopj);
        assert!((e.mm2 - 0.991).abs() < 1e-9, "mm² {}", e.mm2);
        assert!(e.is_finite());
    }

    #[test]
    fn lower_voltage_screens_more_efficient_but_slower() {
        let spec = DesignSpace::default_space().serve;
        let paper = paper_candidate();
        let mut low = paper.clone();
        low.op = 0; // 0.50 V
        let a = screen(&paper, &spec).unwrap();
        let b = screen(&low, &spec).unwrap();
        assert!(b.gopj > a.gopj, "0.50 V must be more efficient");
        assert!(b.gops < a.gops, "0.50 V must be slower");
        assert_eq!(a.mm2.to_bits(), b.mm2.to_bits(), "voltage costs no area");
    }

    fn default_spec() -> ServeSpec {
        DesignSpace::default_space().serve
    }

    #[test]
    fn serve_eval_scales_area_with_the_fleet_and_stays_finite() {
        let spec = default_spec();
        let paper = paper_candidate();
        let mut two = paper.clone();
        two.fleet = 2;
        two.scheduler = "batch";
        let a = serve_eval(&paper, &spec, 16, 0xA5).unwrap();
        let b = serve_eval(&two, &spec, 16, 0xA5).unwrap();
        assert_eq!(a.fidelity, Fidelity::Serve);
        assert!(a.is_finite() && b.is_finite());
        assert!((b.mm2 - 2.0 * a.mm2).abs() < 1e-12);
        assert!(b.gops >= a.gops, "two clusters cannot serve slower");
    }

    #[test]
    fn serve_eval_at_nominal_stays_positive_and_finite() {
        let spec = default_spec();
        let paper = paper_candidate();
        let e = serve_eval(&paper, &spec, 8, 0x5EED).unwrap();
        assert!(e.gopj > 0.0 && e.mj_per_req > 0.0);
        assert!(e.is_finite());
    }

    #[test]
    fn screen_aggregates_every_class_in_a_mix() {
        // regression for the models[0]-only screen: a multi-model mix
        // must aggregate across all classes, pinned against per-model
        // single-stream screens recombined by hand
        let spec = DesignSpace::mix().serve;
        assert_eq!(spec.models.len(), 3);
        let s = DesignSpace::mix();
        let c = s.nth(s.paper_index().unwrap());
        let agg = screen(&c, &spec).unwrap();
        let (mut sec, mut j, mut gop, mut worst) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        for m in &spec.models {
            let solo_spec = ServeSpec { models: vec![m], ..spec.clone() };
            let solo = screen(&c, &solo_spec).unwrap();
            let solo_sec = m.gop_per_inference / solo.gops;
            sec += solo_sec;
            j += m.gop_per_inference / solo.gopj;
            gop += m.gop_per_inference;
            worst = worst.max(solo.p99_ms);
        }
        assert!((agg.gops - gop / sec).abs() / agg.gops < 1e-12, "gops {}", agg.gops);
        assert!((agg.gopj - gop / j).abs() / agg.gopj < 1e-12, "gopj {}", agg.gopj);
        assert!((agg.p99_ms - worst).abs() / agg.p99_ms < 1e-12);
        assert!((agg.req_per_s - 3.0 / sec).abs() / agg.req_per_s < 1e-12);
        // and it must differ from the old first-model-only behavior
        let first_only = ServeSpec { models: vec![spec.models[0]], ..spec.clone() };
        let old = screen(&c, &first_only).unwrap();
        assert!(agg.gopj != old.gopj, "mix aggregate cannot equal models[0] alone");
        assert!(agg.p99_ms > old.p99_ms, "worst-class p99 must dominate");
    }

    #[test]
    fn pod_topology_candidate_prices_the_interconnect() {
        // a non-flat label threads a net/ topology through serving:
        // dispatch DMA rides real links, so latency can only grow
        // against the flat twin, and the evaluation stays deterministic
        let spec = default_spec();
        let mut c = paper_candidate();
        c.fleet = 2;
        c.scheduler = "batch";
        c.topology = "pod:1x1x2";
        let pod = serve_eval(&c, &spec, 16, 0xA5).unwrap();
        assert!(pod.is_finite());
        let mut flat = c.clone();
        flat.topology = "flat";
        let free = serve_eval(&flat, &spec, 16, 0xA5).unwrap();
        assert!(pod.p99_ms >= free.p99_ms, "links cannot make serving faster");
        let pod2 = serve_eval(&c, &spec, 16, 0xA5).unwrap();
        assert_eq!(pod.p99_ms.to_bits(), pod2.p99_ms.to_bits());
        assert_eq!(pod.gopj.to_bits(), pod2.gopj.to_bits());
    }

    #[test]
    fn admission_candidate_sheds_under_overload_and_stays_deterministic() {
        // the default spec's 2000 req/s stream overloads one cluster: a
        // bounded queue keeps served-request p99 at a few service times
        // where admit-all lets it grow with the backlog
        let spec = default_spec();
        let mut c = paper_candidate();
        c.admission = "threshold:2";
        let shed = serve_eval(&c, &spec, 32, 0xA5).unwrap();
        assert!(shed.is_finite());
        let mut open = c.clone();
        open.admission = "admit-all";
        let all = serve_eval(&open, &spec, 32, 0xA5).unwrap();
        assert!(
            shed.p99_ms <= all.p99_ms,
            "a bounded queue cannot raise served p99: {} > {}",
            shed.p99_ms,
            all.p99_ms
        );
        // determinism: the shedding evaluation reproduces bit-for-bit
        let shed2 = serve_eval(&c, &spec, 32, 0xA5).unwrap();
        assert_eq!(shed.gopj.to_bits(), shed2.gopj.to_bits());
        assert_eq!(shed.p99_ms.to_bits(), shed2.p99_ms.to_bits());
        assert!(admission_by_name("nonsense").is_none());
    }

    #[test]
    fn control_candidate_serves_under_slo_dvfs_and_stays_comparable() {
        // a lightly loaded control candidate must stay finite and spend
        // no more energy per request than its uncontrolled twin (the
        // engine's accounting shares the re-basing scale, so the two
        // numbers are directly comparable)
        let spec = ServeSpec { rate_rps: 200.0, ..default_spec() };
        let mut ctl = paper_candidate();
        ctl.control = true;
        let mut plain = ctl.clone();
        plain.control = false;
        let a = serve_eval(&ctl, &spec, 48, 0xC0DE).unwrap();
        let b = serve_eval(&plain, &spec, 48, 0xC0DE).unwrap();
        assert!(a.is_finite() && b.is_finite());
        assert!(
            a.mj_per_req <= b.mj_per_req,
            "SloDvfs must not spend more than static: {} > {}",
            a.mj_per_req,
            b.mj_per_req
        );
        // determinism: the controlled evaluation reproduces bit-for-bit
        let a2 = serve_eval(&ctl, &spec, 48, 0xC0DE).unwrap();
        assert_eq!(a.gopj.to_bits(), a2.gopj.to_bits());
        assert_eq!(a.p99_ms.to_bits(), a2.p99_ms.to_bits());
    }
}
