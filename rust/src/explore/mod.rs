//! Deterministic design-space exploration over the architectural
//! template.
//!
//! The paper's headline numbers — 2960 GOp/J and 154 GOp/s at 0.65 V in
//! 0.991 mm² — are **one instantiation** (8+1 cores, 32-bank 128 KiB
//! TCDM, N=16/M=64 ITA) of a parametric template. The repo can compile
//! (`pipeline`), simulate (`sim`/`energy`) and serve (`serve`) any
//! geometry; this subsystem *searches* that space:
//!
//! ```text
//! DesignSpace ──nth(i)──▶ Candidate ──screen──▶ Evaluation (cheap rung)
//!  (axes × ServeSpec)        │                       │ Pareto-ranked
//!                            │                       ▼ promotion
//!                            └───serve_eval──▶ Evaluation (full rung)
//!                                                    │
//!                              Pareto (GOp/J · GOp/s · p99 · mm²)
//!                                                    │
//!                       render_explore / BENCH_explore.json
//! ```
//!
//! - [`space`] — the cross-product [`DesignSpace`] (cluster geometry,
//!   FD-SOI operating point, deployment knobs, serving config) with a
//!   deterministic mixed-radix enumeration; [`Candidate`] is one point
//!   and knows whether it is the paper's published silicon.
//! - [`operating`] — candidate evaluation at its voltage/frequency
//!   point (`energy::operating_point`, E ∝ V²): the cheap
//!   single-stream [`operating::screen`] rung (aggregated over every
//!   class of the serving mix) and the full multi-request
//!   [`operating::serve_eval`] rung — with the online control plane
//!   (`serve::SloDvfs`) attached when the candidate's `control` axis
//!   is on — both pure functions fanned out across threads through the
//!   process-wide pipeline cache.
//! - [`objective`] — pluggable [`Objective`]s (GOp/J, GOp/s, p99
//!   latency, mm² via `energy::area::cluster_mm2`) with one canonical
//!   dominance orientation.
//! - [`pareto`] — the [`Pareto`] frontier type: incremental
//!   non-dominated insertion, order-independent, deterministic output
//!   ordering.
//! - [`search`] — [`explore`]: exhaustive grid, seeded-random
//!   sampling, and successive halving (screen → reduced serve → full
//!   serve), seeded exclusively through `util::prng` — a fixed seed
//!   reproduces `BENCH_explore.json` bit-for-bit. The paper's silicon
//!   is always promoted to full evaluation as the calibration anchor.
//! - [`report`] — the machine-readable JSON record.
//!
//! The CLI front end is `attn-tinyml explore` (`--space`, `--strategy`,
//! `--budget`, `--objectives`, `--seed`); `coordinator::render_explore`
//! renders the frontier table and flags the paper's point on it.

pub mod objective;
pub mod operating;
pub mod pareto;
pub mod report;
pub mod search;
pub mod space;

pub use objective::Objective;
pub use operating::{Evaluation, Fidelity};
pub use pareto::Pareto;
pub use report::explore_json;
pub use search::{explore, ExploreConfig, ExploreResult, Strategy};
pub use space::{Candidate, DesignSpace, ServeSpec};
