//! Machine-readable exploration records (`BENCH_explore.json`).
//!
//! [`explore_json`] lowers an [`ExploreResult`] to the repo's
//! deterministic JSON (`util::json`: BTreeMap objects, stable number
//! formatting). Because the result itself is a pure function of
//! (space, config) — no wall clock anywhere in the search — serializing
//! two same-seed runs yields **bit-identical** documents; the
//! `explore` CLI and `benches/explore_pareto` both write this shape
//! and the bench asserts the reproduction.

use crate::util::json::Json;

use super::operating::Evaluation;
use super::search::ExploreResult;
use super::space::DesignSpace;

/// One evaluated point as a JSON object.
fn point_json(e: &Evaluation) -> Json {
    let c = &e.candidate;
    let op = c.operating_point();
    Json::obj(vec![
        ("index", Json::num(c.index as f64)),
        ("label", Json::str(c.label())),
        ("cores", Json::num(c.cores as f64)),
        ("banks", Json::num(c.banks as f64)),
        ("l1_kib", Json::num(c.l1_kib as f64)),
        ("ita_n", Json::num(c.ita_n as f64)),
        ("ita_m", Json::num(c.ita_m as f64)),
        ("operating_point", Json::str(op.name)),
        ("vdd", Json::num(op.vdd)),
        ("freq_mhz", Json::num(op.freq_hz / 1e6)),
        ("layers", Json::num(c.layers as f64)),
        ("fuse", Json::Bool(c.fuse)),
        ("fleet", Json::num(c.fleet as f64)),
        ("scheduler", Json::str(c.scheduler)),
        ("control", Json::Bool(c.control)),
        ("topology", Json::str(c.topology)),
        ("admission", Json::str(c.admission)),
        ("fidelity", Json::str(e.fidelity.name())),
        ("gops", Json::num(e.gops)),
        ("gopj", Json::num(e.gopj)),
        ("p99_ms", Json::num(e.p99_ms)),
        ("mm2", Json::num(e.mm2)),
        ("req_per_s", Json::num(e.req_per_s)),
        ("mj_per_req", Json::num(e.mj_per_req)),
        ("events", Json::num(e.events as f64)),
        ("paper_point", Json::Bool(c.is_paper_geometry())),
    ])
}

/// The full exploration record: configuration echo, counts, the paper
/// anchor's screening metrics, the frontier, and every full-fidelity
/// evaluation.
pub fn explore_json(space: &DesignSpace, r: &ExploreResult) -> Json {
    let objectives: Vec<Json> = r.objectives.iter().map(|o| Json::str(o.name())).collect();
    let models: Vec<Json> = space.serve.models.iter().map(|m| Json::str(m.name)).collect();
    let burst = space.serve.burst_factor.map(Json::Num).unwrap_or(Json::Null);
    let paper = r.paper_screen.as_ref().map(point_json).unwrap_or(Json::Null);
    Json::obj(vec![
        ("bench", Json::str("explore_pareto")),
        ("space", Json::str(r.space)),
        ("space_len", Json::num(r.space_len as f64)),
        ("strategy", Json::str(r.strategy)),
        // the seed is a full u64; JSON numbers are f64-backed, which
        // would silently round seeds above 2^53 in the one file whose
        // job is exact reproduction — record it as a string
        ("seed", Json::str(r.seed.to_string())),
        ("budget", Json::num(r.budget as f64)),
        ("objectives", Json::Arr(objectives)),
        ("requests", Json::num(space.serve.requests as f64)),
        ("rate_rps", Json::num(space.serve.rate_rps)),
        ("burst_factor", burst),
        ("slo_p99_ms", Json::num(space.serve.slo_p99_ms)),
        ("models", Json::Arr(models)),
        ("screened", Json::num(r.screened as f64)),
        ("evaluated", Json::num(r.evaluated as f64)),
        ("infeasible", Json::num(r.infeasible as f64)),
        ("truncated", Json::Bool(r.truncated)),
        ("paper_screen", paper),
        ("frontier", Json::Arr(r.frontier.iter().map(point_json).collect())),
        ("evaluations", Json::Arr(r.evaluations.iter().map(point_json).collect())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explore::search::{explore, ExploreConfig, Strategy};

    #[test]
    fn json_echoes_the_run_and_reparses() {
        let space = DesignSpace::tiny();
        let cfg = ExploreConfig {
            strategy: Strategy::Grid,
            budget: 8,
            threads: 1,
            ..ExploreConfig::default()
        };
        let r = explore(&space, &cfg).unwrap();
        let doc = explore_json(&space, &r);
        let text = doc.to_string_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("space").unwrap().as_str(), Some("tiny"));
        assert_eq!(back.get("strategy").unwrap().as_str(), Some("grid"));
        assert_eq!(
            back.get("frontier").unwrap().as_arr().unwrap().len(),
            r.frontier.len()
        );
        let first = &back.get("frontier").unwrap().as_arr().unwrap()[0];
        for key in [
            "gops",
            "gopj",
            "p99_ms",
            "mm2",
            "operating_point",
            "paper_point",
            "control",
            "topology",
            "admission",
            "events",
        ] {
            assert!(first.get(key).is_some(), "frontier point missing {key}");
        }
    }
}
