//! Search strategies over a [`DesignSpace`]: exhaustive grid,
//! seeded-random sampling, and successive halving.
//!
//! [`explore`] is the one entry point. It is a **pure function of
//! (space, config)**: candidate identity comes from the space's
//! deterministic enumeration, every random draw comes from
//! `util::prng` seeded with the config's seed (never a wall clock),
//! and evaluations are deterministic simulator/serving runs — so a
//! fixed seed reproduces the whole [`ExploreResult`] (and the JSON
//! `BENCH_explore.json` derived from it) bit-for-bit, including under
//! thread fan-out: workers race only over *which* slot they compute,
//! and every slot's value is order-independent.
//!
//! Strategies:
//!
//! - **grid** — full-fidelity serving evaluation of the first
//!   `budget` candidates in enumeration order (`truncated` is set when
//!   the budget clips the space).
//! - **random** — full evaluation of `budget` distinct seeded-random
//!   candidates.
//! - **halving** — the multi-fidelity ladder: a pool of up to
//!   `4×budget` candidates is screened through the cheap single-stream
//!   rung (`operating::screen`, memoized stats through the pipeline
//!   cache), the Pareto-ranked top `2×budget` are promoted to a
//!   reduced-request serving rung, and the top `budget` of those get
//!   the full workload. Ranking peels non-dominated fronts
//!   ([`pareto::dominates`] on the objective keys) and breaks ties by
//!   pool position, so promotion is deterministic.
//!
//! Whatever the strategy, if the space contains the paper's silicon,
//! every candidate carrying it (one per serving overlay) is promoted
//! to full evaluation (the **calibration anchors**): the published
//! point must be measurable — under its best serving configuration —
//! on every frontier the explorer reports, so `budget` can be exceeded
//! by at most the anchor count. The lowest-index anchor's screening
//! metrics are recorded in [`ExploreResult::paper_screen`] for the
//! Table-I tolerance check.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::deeploy::DeployError;
use crate::util::prng::XorShift64;

use super::objective::{keys_of, Objective};
use super::operating::{self, Evaluation};
use super::pareto::{dominates, Pareto};
use super::space::{Candidate, DesignSpace};

/// Search strategy selector (CLI: `--strategy`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    Grid,
    Random,
    Halving,
}

impl Strategy {
    pub fn name(&self) -> &'static str {
        match self {
            Strategy::Grid => "grid",
            Strategy::Random => "random",
            Strategy::Halving => "halving",
        }
    }

    pub fn by_name(name: &str) -> Option<Strategy> {
        match name {
            "grid" | "exhaustive" => Some(Strategy::Grid),
            "random" | "sample" => Some(Strategy::Random),
            "halving" | "sha" => Some(Strategy::Halving),
            _ => None,
        }
    }
}

/// Search configuration.
#[derive(Debug, Clone)]
pub struct ExploreConfig {
    pub strategy: Strategy,
    /// Candidates promoted to full-fidelity serving evaluation.
    pub budget: usize,
    /// Seeds both the sampling PRNG and the evaluation workloads.
    pub seed: u64,
    pub objectives: Vec<Objective>,
    /// Worker threads for the evaluation fan-out; 0 = auto
    /// (`available_parallelism`, capped at 8).
    pub threads: usize,
}

impl Default for ExploreConfig {
    fn default() -> Self {
        ExploreConfig {
            strategy: Strategy::Halving,
            budget: 16,
            seed: 48879,
            objectives: Objective::ALL.to_vec(),
            threads: 0,
        }
    }
}

/// Everything one search produced (see `explore::report` for the
/// JSON rendering and `coordinator::render_explore` for the table).
#[derive(Debug, Clone)]
pub struct ExploreResult {
    pub space: &'static str,
    pub space_len: usize,
    pub strategy: &'static str,
    pub seed: u64,
    pub budget: usize,
    pub objectives: Vec<Objective>,
    /// Cheap screening evaluations performed (halving only).
    pub screened: usize,
    /// Full-fidelity serving evaluations performed.
    pub evaluated: usize,
    /// Candidates whose compilation/serving failed (e.g. L1 budget or
    /// ITA constraint violations on small geometries) — skipped, never
    /// fatal.
    pub infeasible: usize,
    /// Grid only: the budget clipped the enumeration.
    pub truncated: bool,
    /// The non-dominated set, deterministically ordered
    /// ([`Pareto::sorted`]).
    pub frontier: Vec<Evaluation>,
    /// Every full-fidelity evaluation, in pool order.
    pub evaluations: Vec<Evaluation>,
    /// Screening metrics of the paper's silicon (Table-I-comparable),
    /// when the space contains it.
    pub paper_screen: Option<Evaluation>,
}

/// Run one design-space search (see the module docs).
pub fn explore(space: &DesignSpace, cfg: &ExploreConfig) -> Result<ExploreResult, DeployError> {
    space.validate()?;
    if cfg.budget == 0 {
        return Err(DeployError::Builder("explore budget must be >= 1".into()));
    }
    if cfg.objectives.is_empty() {
        return Err(DeployError::Builder("explore needs at least one objective".into()));
    }
    let len = space.len();
    let threads = effective_threads(cfg.threads);
    let paper = space.paper_indices();
    let paper_screen = paper
        .first()
        .map(|&i| operating::screen(&space.nth(i), &space.serve))
        .transpose()
        .unwrap_or_default();

    let mut screened = 0usize;
    let mut infeasible = 0usize;
    let mut truncated = false;

    // --- pool selection + promotion ladder, per strategy -----------------
    let pool: Vec<Candidate> = match cfg.strategy {
        Strategy::Grid => {
            truncated = len > cfg.budget;
            let mut idx: Vec<usize> = (0..len.min(cfg.budget)).collect();
            anchor(&mut idx, &paper);
            idx.into_iter().map(|i| space.nth(i)).collect()
        }
        Strategy::Random => {
            let mut rng = XorShift64::new(cfg.seed ^ 0x5A3C_E0DE);
            let mut idx = sample_distinct(len, cfg.budget.min(len), &mut rng);
            anchor(&mut idx, &paper);
            idx.into_iter().map(|i| space.nth(i)).collect()
        }
        Strategy::Halving => {
            let cap = cfg.budget.saturating_mul(4).max(cfg.budget);
            let mut idx: Vec<usize> = if len <= cap {
                (0..len).collect()
            } else {
                let mut rng = XorShift64::new(cfg.seed ^ 0x5A3C_E0DE);
                sample_distinct(len, cap, &mut rng)
            };
            anchor(&mut idx, &paper);
            let mut pool: Vec<Candidate> =
                idx.into_iter().map(|i| space.nth(i)).collect();

            // rung 0: cheap screening. When the workload is so small
            // that a "reduced" serving rung would re-run the full
            // request count (requests <= 8), the mid rung is pure
            // duplication — cut straight to the budget on the screen
            // ranking instead.
            let reduced = (space.serve.requests / 4).max(8).min(space.serve.requests);
            let has_mid_rung = reduced < space.serve.requests;
            let first_cut = if has_mid_rung {
                cfg.budget.saturating_mul(2)
            } else {
                cfg.budget
            };
            let evals = par_eval(&pool, threads, |c| operating::screen(c, &space.serve));
            screened = pool.len();
            let (kept, evals, dropped) = keep_feasible(pool, evals);
            infeasible += dropped;
            pool = select_top(kept, &evals, &cfg.objectives, first_cut, &paper);

            // rung 1: reduced-request serving (skipped when the pool
            // already fits the budget)
            if has_mid_rung && pool.len() > cfg.budget {
                let seed = cfg.seed;
                let evals = par_eval(&pool, threads, |c| {
                    operating::serve_eval(c, &space.serve, reduced, seed)
                });
                let (kept, evals, dropped) = keep_feasible(pool, evals);
                infeasible += dropped;
                pool = select_top(kept, &evals, &cfg.objectives, cfg.budget, &paper);
            }
            pool
        }
    };

    // --- final full-fidelity evaluation ----------------------------------
    let seed = cfg.seed;
    let finals = par_eval(&pool, threads, |c| {
        operating::serve_eval(c, &space.serve, space.serve.requests, seed)
    });
    let (_, evaluations, dropped) = keep_feasible(pool, finals);
    infeasible += dropped;

    let mut frontier = Pareto::new(cfg.objectives.clone());
    for e in &evaluations {
        frontier.insert(e.clone());
    }

    Ok(ExploreResult {
        space: space.name,
        space_len: len,
        strategy: cfg.strategy.name(),
        seed: cfg.seed,
        budget: cfg.budget,
        objectives: cfg.objectives.clone(),
        screened,
        evaluated: evaluations.len(),
        infeasible,
        truncated,
        frontier: frontier.sorted(),
        evaluations,
        paper_screen,
    })
}

/// Ensure every calibration-anchor candidate is in the index pool
/// (sorted insert, dedup) — every strategy fully evaluates the paper's
/// silicon, under each of its serving overlays, when the space
/// contains it.
fn anchor(idx: &mut Vec<usize>, paper: &[usize]) {
    let mut added = false;
    for &p in paper {
        if !idx.contains(&p) {
            idx.push(p);
            added = true;
        }
    }
    if added {
        idx.sort_unstable();
    }
}

/// `want` distinct indices in `[0, len)` by seeded rejection sampling
/// (draw order defines pool order, so the sample is reproducible).
fn sample_distinct(len: usize, want: usize, rng: &mut XorShift64) -> Vec<usize> {
    if want >= len {
        return (0..len).collect();
    }
    let mut seen = std::collections::BTreeSet::new();
    let mut out = Vec::with_capacity(want);
    while out.len() < want {
        let i = rng.next_below(len as u64) as usize;
        if seen.insert(i) {
            out.push(i);
        }
    }
    out
}

/// Drop infeasible candidates, keeping pool and evaluations aligned.
/// Returns (survivors, their evaluations, dropped count).
fn keep_feasible(
    pool: Vec<Candidate>,
    evals: Vec<Result<Evaluation, DeployError>>,
) -> (Vec<Candidate>, Vec<Evaluation>, usize) {
    let mut kept = Vec::with_capacity(pool.len());
    let mut out = Vec::with_capacity(pool.len());
    let mut dropped = 0usize;
    for (c, r) in pool.into_iter().zip(evals) {
        match r {
            Ok(e) if e.is_finite() => {
                kept.push(c);
                out.push(e);
            }
            _ => dropped += 1,
        }
    }
    (kept, out, dropped)
}

/// Non-dominated-front ranking over aligned (pool, evals): peel fronts
/// on the objective keys, order within a front by pool position, keep
/// the top `k` — then restore pool order among the survivors. The
/// paper anchor, when present in the pool, is always retained.
fn select_top(
    pool: Vec<Candidate>,
    evals: &[Evaluation],
    objectives: &[Objective],
    k: usize,
    paper: &[usize],
) -> Vec<Candidate> {
    if pool.len() <= k {
        return pool;
    }
    let keys: Vec<Vec<f64>> = evals.iter().map(|e| keys_of(objectives, e)).collect();
    let order = pareto_order(&keys);
    let mut chosen: Vec<usize> = order.into_iter().take(k).collect();
    for (pos, c) in pool.iter().enumerate() {
        if paper.contains(&c.index) && !chosen.contains(&pos) {
            chosen.push(pos);
        }
    }
    chosen.sort_unstable();
    let mut keep = vec![false; pool.len()];
    for &pos in &chosen {
        keep[pos] = true;
    }
    pool.into_iter()
        .zip(keep)
        .filter_map(|(c, keep)| keep.then_some(c))
        .collect()
}

/// Positions `0..keys.len()` ordered by non-dominated front (front 0
/// first), position-ascending within each front.
pub(crate) fn pareto_order(keys: &[Vec<f64>]) -> Vec<usize> {
    let n = keys.len();
    let mut remaining: Vec<usize> = (0..n).collect();
    let mut out = Vec::with_capacity(n);
    while !remaining.is_empty() {
        let mut front: Vec<usize> = remaining
            .iter()
            .copied()
            .filter(|&i| {
                !remaining.iter().any(|&j| j != i && dominates(&keys[j], &keys[i]))
            })
            .collect();
        if front.is_empty() {
            // unreachable for finite keys (strict partial orders have
            // maximal elements); terminate defensively anyway
            front = remaining.clone();
        }
        out.extend(front.iter().copied());
        remaining.retain(|i| !front.contains(i));
    }
    out
}

fn effective_threads(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).clamp(1, 8)
}

/// Evaluate every candidate, fanning out over `threads` workers through
/// the process-wide pipeline cache. Results return slot-aligned, so the
/// outcome is independent of which worker computed what.
fn par_eval<F>(cands: &[Candidate], threads: usize, f: F) -> Vec<Result<Evaluation, DeployError>>
where
    F: Fn(&Candidate) -> Result<Evaluation, DeployError> + Sync,
{
    let n = cands.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return cands.iter().map(&f).collect();
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<(usize, Result<Evaluation, DeployError>)> = std::thread::scope(|s| {
        let next = &next;
        let f = &f;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                s.spawn(move || {
                    let mut out = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        out.push((i, f(&cands[i])));
                    }
                    out
                })
            })
            .collect();
        handles
            .into_iter()
            .flat_map(|h| h.join().expect("explore worker panicked"))
            .collect()
    });
    slots.sort_by_key(|&(i, _)| i);
    slots.into_iter().map(|(_, r)| r).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strategy_names_resolve() {
        for (n, s) in [
            ("grid", Strategy::Grid),
            ("random", Strategy::Random),
            ("halving", Strategy::Halving),
            ("sha", Strategy::Halving),
        ] {
            assert_eq!(Strategy::by_name(n), Some(s));
        }
        assert!(Strategy::by_name("anneal").is_none());
        assert_eq!(Strategy::Halving.name(), "halving");
    }

    #[test]
    fn sample_distinct_is_deterministic_and_distinct() {
        let mut a = XorShift64::new(7);
        let mut b = XorShift64::new(7);
        let x = sample_distinct(100, 20, &mut a);
        let y = sample_distinct(100, 20, &mut b);
        assert_eq!(x, y);
        let set: std::collections::BTreeSet<usize> = x.iter().copied().collect();
        assert_eq!(set.len(), 20);
        assert!(set.iter().all(|&i| i < 100));
        // want >= len collapses to the identity
        assert_eq!(sample_distinct(5, 9, &mut a), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn pareto_order_peels_fronts_position_stable() {
        // keys: 0 and 2 are maximal (incomparable); 1 is dominated by 0;
        // 3 is dominated by everything
        let keys = vec![
            vec![3.0, 1.0],
            vec![2.0, 0.5],
            vec![1.0, 3.0],
            vec![0.5, 0.25],
        ];
        assert_eq!(pareto_order(&keys), vec![0, 2, 1, 3]);
    }

    #[test]
    fn zero_budget_and_empty_objectives_error() {
        let space = DesignSpace::tiny();
        let mut cfg = ExploreConfig { budget: 0, ..ExploreConfig::default() };
        assert!(explore(&space, &cfg).is_err());
        cfg.budget = 1;
        cfg.objectives = vec![];
        assert!(explore(&space, &cfg).is_err());
    }

    #[test]
    fn tiny_grid_explore_produces_a_frontier_with_the_paper_point() {
        let space = DesignSpace::tiny();
        let cfg = ExploreConfig {
            strategy: Strategy::Grid,
            budget: 16,
            threads: 1,
            ..ExploreConfig::default()
        };
        let r = explore(&space, &cfg).unwrap();
        assert!(!r.truncated, "budget 16 covers the 4-candidate tiny space");
        assert_eq!(r.evaluated, 4);
        assert!(!r.frontier.is_empty());
        assert!(r.frontier.iter().any(|e| e.candidate.is_paper_geometry()));
        assert!(r.paper_screen.is_some());
        // every frontier point is one of the evaluations
        for e in &r.frontier {
            assert!(r.evaluations.iter().any(|x| x.candidate.index == e.candidate.index));
        }
    }
}
