//! Builder-style compile pipeline over the deploy → simulate → verify
//! seam.
//!
//! The deployment flow is a reusable compiler, not a one-shot script:
//! a [`Pipeline`] is configured with an explicit cluster geometry, a
//! source (a built-in/custom [`ModelConfig`] or an imported
//! [`Graph`]), a [`Target`] and a layer count, and `compile()` runs the
//! full flow once, returning a [`Compiled`] that owns the
//! [`Deployment`] plus its reusable simulation [`Engine`]:
//!
//! ```no_run
//! use attn_tinyml::pipeline::Pipeline;
//! use attn_tinyml::deeploy::Target;
//! use attn_tinyml::models::MOBILEBERT;
//! use attn_tinyml::sim::ClusterConfig;
//!
//! let compiled = Pipeline::new(ClusterConfig::default())
//!     .model(&MOBILEBERT)
//!     .target(Target::MultiCoreIta)
//!     .layers(1)
//!     .compile()
//!     .unwrap();
//! let report = compiled.simulate(); // paper-style Table I metrics
//! ```
//!
//! Model-sourced compilations are memoized in a process-wide cache
//! keyed by (model config, target, layers, cluster geometry, fusion):
//! `table1()`, the benches, and repeated evaluations reuse the passes /
//! tiling / allocation / codegen work — and the deterministic
//! simulation statistics — instead of re-running them. Concurrent
//! compilations of the same key serialize on a per-key slot, so each
//! key is built exactly once no matter how many threads race for it.
//! Graph-sourced compilations are never cached (hashing an arbitrary
//! graph would cost as much as deploying it). The cache grows by one
//! entry per distinct key and never evicts — a long-lived process
//! sweeping many geometries should call [`clear_cache`] between sweeps.
//!
//! The run side scales past one inference: `.fleet(n)` plus
//! [`Pipeline::serve`] / [`Pipeline::serve_with`] dispatch a
//! multi-request [`Workload`] across n clusters (see [`crate::serve`]);
//! `Compiled::simulate()` is the degenerate one-request/one-cluster
//! case.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::coordinator::forward;
use crate::coordinator::report::ModelReport;
use crate::deeploy::ir::{Graph, TensorKind};
use crate::deeploy::{self, DeployError, Deployment, Target};
use crate::energy;
use crate::ita::engine::Mat;
use crate::ita::ItaConfig;
use crate::models::{self, ModelConfig};
use crate::runtime::{Runtime, RuntimeError, TensorIn};
use crate::energy::operating_point::NOMINAL_INDEX;
use crate::net::Topology;
use crate::obs::ObsConfig;
use crate::serve::{
    Controller, FaultConfig, Fifo, Fleet, LocalityAware, RequestClass, Scheduler,
    ServeReport, Workload, DEFAULT_CONTROL_CADENCE_CYCLES,
};
use crate::sim::dma::DmaModel;
use crate::sim::{ClusterConfig, Cmd, Engine, RunStats};

// --- cache ------------------------------------------------------------------

/// Identity of a model config for cache keying: the name alone is not
/// enough (sweeps build custom configs under one name), so every field
/// that shapes the deployment graph participates.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct ModelKey {
    name: String,
    seq: usize,
    seq_logical: usize,
    emb: usize,
    proj: usize,
    heads: usize,
    layers: usize,
    dff: usize,
    ffn_stack: usize,
    act: u8,
    gop_bits: u64,
    conv_stem: bool,
}

impl ModelKey {
    fn of(cfg: &ModelConfig) -> ModelKey {
        // exhaustive destructuring (no `..`): adding a field to
        // ModelConfig without extending the cache key is a compile error
        let ModelConfig {
            name,
            seq,
            seq_logical,
            emb,
            proj,
            heads,
            layers,
            dff,
            ffn_stack,
            act,
            gop_per_inference,
            conv_stem,
        } = cfg;
        ModelKey {
            name: name.to_string(),
            seq: *seq,
            seq_logical: *seq_logical,
            emb: *emb,
            proj: *proj,
            heads: *heads,
            layers: *layers,
            dff: *dff,
            ffn_stack: *ffn_stack,
            act: *act as u8,
            gop_bits: gop_per_inference.to_bits(),
            conv_stem: *conv_stem,
        }
    }
}

/// Cluster-geometry fingerprint: every field that influences the
/// deployment (L1 tile budget) or the simulation (timing, energy).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct GeomKey {
    n_cores: usize,
    dma_core: bool,
    tcdm_banks: usize,
    tcdm_bank_bytes: usize,
    tcdm_port_bytes: usize,
    hwpe_ports: usize,
    wide_axi_bytes: usize,
    narrow_axi_bytes: usize,
    icache_bytes: usize,
    freq_bits: u64,
    ita_units: usize,
    ita_m_vec: usize,
    ita_acc_bits: u32,
    ita_max_dim: usize,
}

impl GeomKey {
    fn of(c: &ClusterConfig) -> GeomKey {
        // exhaustive destructuring (no `..`): adding a field to
        // ClusterConfig/ItaConfig without extending the cache key is a
        // compile error — silently-stale cache hits are worse than the
        // one-line update this forces
        let ClusterConfig {
            n_cores,
            dma_core,
            tcdm_banks,
            tcdm_bank_bytes,
            tcdm_port_bytes,
            hwpe_ports,
            wide_axi_bytes,
            narrow_axi_bytes,
            icache_bytes,
            freq_hz,
            ita,
        } = c;
        let ItaConfig { n_units, m_vec, acc_bits, max_dim } = *ita;
        GeomKey {
            n_cores: *n_cores,
            dma_core: *dma_core,
            tcdm_banks: *tcdm_banks,
            tcdm_bank_bytes: *tcdm_bank_bytes,
            tcdm_port_bytes: *tcdm_port_bytes,
            hwpe_ports: *hwpe_ports,
            wide_axi_bytes: *wide_axi_bytes,
            narrow_axi_bytes: *narrow_axi_bytes,
            icache_bytes: *icache_bytes,
            freq_bits: freq_hz.to_bits(),
            ita_units: n_units,
            ita_m_vec: m_vec,
            ita_acc_bits: acc_bits,
            ita_max_dim: max_dim,
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq, Hash)]
struct CacheKey {
    model: ModelKey,
    /// true for the standalone conv-stem deployment of a model.
    stem: bool,
    target: Target,
    layers: usize,
    fuse: bool,
    geom: GeomKey,
}

/// Per-class serving constants, derived once from a compiled deployment
/// and memoized in the cache entry alongside [`RunStats`]: repeated
/// `serve()` / fleet runs of a cached class skip the engine re-simulation
/// entirely (asserted by `serve::fleet` tests via
/// [`Compiled::sim_runs`]). See `serve::fleet` module docs for the
/// serving-time semantics of each constant.
#[derive(Debug, Clone)]
pub struct ServeConstants {
    /// Cycles of one cold pass of the command stream.
    pub first: u64,
    /// Incremental cycles of one extra back-to-back pass in a batch.
    pub steady: u64,
    /// Weight re-staging cycles when a shard switches to this class.
    pub switch_cycles: u64,
    /// Active (non-idle) energy of one pass, joules.
    pub active_j: f64,
    /// Simulated ops of one pass.
    pub ops: u64,
}

/// One compiled deployment + its memoized (deterministic) simulation
/// and serving constants.
struct Entry {
    deployment: Deployment,
    stats: OnceLock<RunStats>,
    serve: OnceLock<ServeConstants>,
    /// Engine invocations performed for this entry (stats + serving
    /// constants) — observability for the zero-rework memoization
    /// contract.
    sim_runs: AtomicU64,
}

impl Entry {
    fn new(deployment: Deployment) -> Arc<Entry> {
        Arc::new(Entry {
            deployment,
            stats: OnceLock::new(),
            serve: OnceLock::new(),
            sim_runs: AtomicU64::new(0),
        })
    }

    fn stats(&self, engine: &Engine) -> &RunStats {
        self.stats.get_or_init(|| {
            self.sim_runs.fetch_add(1, Ordering::Relaxed);
            engine.run(&self.deployment.steps)
        })
    }
}

/// One cache slot: a per-key build lock around the (eventually
/// populated) entry. The first compiler of a key builds while holding
/// the slot lock; racers on the *same* key block on the slot — not on
/// the map — and wake up to a hit, so each key is compiled exactly
/// once. Unrelated keys never serialize: the map lock is only held for
/// the slot lookup.
type Slot = Arc<Mutex<Option<Arc<Entry>>>>;

fn cache() -> &'static Mutex<HashMap<CacheKey, Slot>> {
    static CACHE: OnceLock<Mutex<HashMap<CacheKey, Slot>>> = OnceLock::new();
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

static HITS: AtomicU64 = AtomicU64::new(0);
static MISSES: AtomicU64 = AtomicU64::new(0);

/// Process-wide cache counters (cumulative; `clear_cache` drops the
/// entries but keeps the counters running).
#[derive(Debug, Clone, Copy)]
pub struct CacheStats {
    pub entries: usize,
    pub hits: u64,
    pub misses: u64,
}

pub fn cache_stats() -> CacheStats {
    // in-flight compilations (slot locked, not yet populated) and slots
    // whose build errored do not count as entries
    let entries = cache()
        .lock()
        .unwrap()
        .values()
        .filter(|slot| slot.try_lock().map(|g| g.is_some()).unwrap_or(false))
        .count();
    CacheStats {
        entries,
        hits: HITS.load(Ordering::Relaxed),
        misses: MISSES.load(Ordering::Relaxed),
    }
}

/// Drop every cached deployment (benchmarks use this to measure the
/// cold path).
pub fn clear_cache() {
    cache().lock().unwrap().clear();
}

/// Compile-or-lookup. Returns (entry, was_cache_hit). A failed build
/// leaves the slot empty, so the next caller retries (and counts its
/// own miss).
fn compile_cached(
    key: CacheKey,
    build: impl FnOnce() -> Result<Deployment, DeployError>,
) -> Result<(Arc<Entry>, bool), DeployError> {
    let slot: Slot = cache()
        .lock()
        .unwrap()
        .entry(key)
        .or_insert_with(|| Arc::new(Mutex::new(None)))
        .clone();
    let mut guard = slot.lock().unwrap();
    if let Some(entry) = guard.as_ref() {
        HITS.fetch_add(1, Ordering::Relaxed);
        return Ok((entry.clone(), true));
    }
    MISSES.fetch_add(1, Ordering::Relaxed);
    let entry = Entry::new(build()?);
    *guard = Some(entry.clone());
    Ok((entry, false))
}

// --- builder ----------------------------------------------------------------

enum Source {
    Unset,
    Model(ModelConfig),
    Graph(Box<Graph>),
}

/// Builder for one deployment compilation. See the module docs for the
/// canonical call shape.
pub struct Pipeline {
    cluster: ClusterConfig,
    source: Source,
    target: Target,
    layers: Option<usize>,
    fuse: bool,
    use_cache: bool,
    fleet: usize,
    controller: Option<Box<dyn Controller>>,
    control_cadence: u64,
    topology: Option<Topology>,
    locality: bool,
    fault: Option<FaultConfig>,
    observe: Option<ObsConfig>,
}

impl Default for Pipeline {
    fn default() -> Self {
        Pipeline::new(ClusterConfig::default())
    }
}

impl Pipeline {
    /// Start a pipeline over an explicit cluster geometry — the
    /// geometry is a first-class input, never an implicit default.
    pub fn new(cluster: ClusterConfig) -> Pipeline {
        Pipeline {
            cluster,
            source: Source::Unset,
            target: Target::MultiCoreIta,
            layers: None,
            fuse: true,
            use_cache: true,
            fleet: 1,
            controller: None,
            control_cadence: DEFAULT_CONTROL_CADENCE_CYCLES,
            topology: None,
            locality: false,
            fault: None,
            observe: None,
        }
    }

    /// Deploy one of the evaluation networks (or a custom config).
    pub fn model(mut self, cfg: &ModelConfig) -> Pipeline {
        self.source = Source::Model(cfg.clone());
        self
    }

    /// Deploy an imported graph (never cached).
    pub fn graph(mut self, g: Graph) -> Pipeline {
        self.source = Source::Graph(Box::new(g));
        self
    }

    /// Code-generation target (default: `MultiCoreIta`).
    pub fn target(mut self, t: Target) -> Pipeline {
        self.target = t;
        self
    }

    /// Simulate only `n` encoder blocks and extrapolate linearly — the
    /// paper's own per-layer measurement strategy. Default: all layers.
    /// Only meaningful for model sources.
    pub fn layers(mut self, n: usize) -> Pipeline {
        self.layers = Some(n);
        self
    }

    /// Toggle the MHA fusion pass (the collaborative-execution ablation
    /// leaves ITAMax on the cluster cores). Default: on.
    pub fn fuse_mha(mut self, on: bool) -> Pipeline {
        self.fuse = on;
        self
    }

    /// Bypass the compiled-deployment cache for this compilation.
    pub fn uncached(mut self) -> Pipeline {
        self.use_cache = false;
        self
    }

    /// Shard count for [`serve`](Pipeline::serve): the workload is
    /// dispatched across `n` identical clusters of this geometry.
    /// Default: 1.
    pub fn fleet(mut self, n: usize) -> Pipeline {
        self.fleet = n;
        self
    }

    /// Attach an online [`Controller`] to the serve run: it observes
    /// windowed metrics every control cadence of simulated time and may
    /// switch the FD-SOI operating point or park/wake shards. Default:
    /// none (the uncontrolled event loop).
    pub fn controller(mut self, c: Box<dyn Controller>) -> Pipeline {
        self.controller = Some(c);
        self
    }

    /// Simulated-time control decision cadence, fleet-clock cycles.
    /// Default: [`DEFAULT_CONTROL_CADENCE_CYCLES`] (10 ms at 425 MHz).
    pub fn control_cadence(mut self, cycles: u64) -> Pipeline {
        self.control_cadence = cycles;
        self
    }

    /// Place the serve fleet in an interconnect [`Topology`]
    /// (cluster → board → pod, see [`crate::net`]): dispatch and weight
    /// re-staging are priced over its links and the report carries a
    /// `net` block. Default: none — the historical free interconnect.
    pub fn topology(mut self, topo: Topology) -> Pipeline {
        self.topology = Some(topo);
        self
    }

    /// Wrap the serve scheduler in [`LocalityAware`]: batches are
    /// steered at the shard already holding their class's weights,
    /// falling back by hierarchy distance. Meaningful with
    /// [`topology`](Pipeline::topology); without one, placement falls
    /// back to [`Topology::Flat`] (free-holder steering only).
    pub fn locality(mut self, on: bool) -> Pipeline {
        self.locality = on;
        self
    }

    /// Attach a fault/degradation config to the serve run (see
    /// [`crate::fault`] and `serve/fault.rs`): a seeded plan of shard
    /// crashes and link faults, admission control, per-attempt
    /// deadlines and bounded retry/failover. `FaultConfig::default()`
    /// is provably inert — the report is bit-identical to an
    /// un-faulted run. Default: none (the fault layer is not even
    /// consulted).
    pub fn faults(mut self, cfg: FaultConfig) -> Pipeline {
        self.fault = Some(cfg);
        self
    }

    /// Attach the observability layer to the serve run (see
    /// [`crate::obs`]): a structured lifecycle-event recorder with
    /// deterministic seeded request sampling plus cycle-attribution
    /// profiling, surfaced as `ServeReport::profile` and exportable to
    /// Chrome/Perfetto (`obs::chrome_trace`) or JSONL
    /// (`obs::events_jsonl`). Strictly write-only: every other report
    /// field stays bit-identical at any sampling rate. Default: none
    /// (zero cost — the engine holds no recorder at all).
    pub fn observe(mut self, cfg: ObsConfig) -> Pipeline {
        self.observe = Some(cfg);
        self
    }

    /// Serve a multi-request workload on the configured fleet under the
    /// FIFO scheduler. `Compiled::simulate()` is the degenerate case:
    /// a single-request workload on one cluster reproduces
    /// `Compiled::stats()` cycle-for-cycle.
    pub fn serve(self, w: &Workload) -> Result<ServeReport, DeployError> {
        self.serve_with(w, &mut Fifo)
    }

    /// Serve a multi-request workload under an explicit [`Scheduler`].
    /// The workload's classes compile through the cached pipeline; if
    /// the workload has no classes, the builder's `.model()` /
    /// `.layers()` become the single request class.
    pub fn serve_with(
        self,
        w: &Workload,
        sched: &mut dyn Scheduler,
    ) -> Result<ServeReport, DeployError> {
        let Pipeline {
            cluster,
            source,
            target,
            layers,
            fuse,
            use_cache,
            fleet,
            mut controller,
            control_cadence,
            topology,
            locality,
            fault,
            observe,
        } = self;
        let filled: Option<Workload> = if w.classes.is_empty() {
            match source {
                Source::Model(cfg) => {
                    let layers = layers.unwrap_or(cfg.layers);
                    let mut with_class = w.clone();
                    with_class.classes = vec![RequestClass::new(&cfg, layers)];
                    Some(with_class)
                }
                _ => {
                    return Err(DeployError::Builder(
                        "serve needs workload classes or a .model() source".into(),
                    ))
                }
            }
        } else {
            None
        };
        let w = filled.as_ref().unwrap_or(w);
        let mut f = Fleet::new(cluster, target, fleet).fuse_mha(fuse);
        if !use_cache {
            f = f.uncached();
        }
        if let Some(t) = &topology {
            f = f.with_topology(t.clone());
        }
        if let Some(cfg) = observe {
            f = f.with_obs(cfg);
        }
        let mut wrapped;
        let sched: &mut dyn Scheduler = if locality {
            let topo = topology.unwrap_or(Topology::Flat);
            wrapped = LocalityAware::new(sched, topo, w.classes.len());
            &mut wrapped
        } else {
            sched
        };
        match (controller.as_deref_mut(), fault) {
            (Some(c), Some(cfg)) => f.serve_faulted_controlled(
                w,
                sched,
                c,
                control_cadence,
                NOMINAL_INDEX,
                cfg,
            ),
            (Some(c), None) => {
                f.serve_controlled(w, sched, c, control_cadence, NOMINAL_INDEX)
            }
            (None, Some(cfg)) => f.serve_faulted(w, sched, cfg),
            (None, None) => f.serve(w, sched),
        }
    }

    /// Run the deployment flow (or fetch the memoized result).
    pub fn compile(self) -> Result<Compiled, DeployError> {
        let Pipeline {
            cluster,
            source,
            target,
            layers,
            fuse,
            use_cache,
            fleet: _,
            controller: _,
            control_cadence: _,
            topology: _,
            locality: _,
            fault: _,
            observe: _,
        } = self;
        // MHA fusion only exists on the ITA path; canonicalize the flag
        // so MultiCore compilations share one cache entry regardless of
        // the toggle (deploy_graph_opts ignores it for MultiCore)
        let fuse = fuse || target == Target::MultiCore;
        match source {
            Source::Unset => Err(DeployError::Builder(
                "no source: call .model(&cfg) or .graph(g) before .compile()".into(),
            )),
            Source::Graph(g) => {
                if layers.is_some() {
                    return Err(DeployError::Builder(
                        ".layers() applies to model sources only".into(),
                    ));
                }
                let dep = deeploy::deploy_graph_opts(*g, target, &cluster, fuse)?;
                let engine = Engine::new(cluster);
                Ok(Compiled {
                    engine,
                    model: None,
                    layers: 1,
                    entry: Entry::new(dep),
                    stem: None,
                    cache_hit: false,
                })
            }
            Source::Model(cfg) => {
                let layers = layers.unwrap_or(cfg.layers);
                // values above cfg.layers deploy extra identical blocks
                // and scale the report down — permitted for parity with
                // the 0.1.0 free functions; zero blocks is meaningless
                if layers == 0 {
                    return Err(DeployError::Builder(format!(
                        "layers must be >= 1 for {} (its full depth is {})",
                        cfg.name, cfg.layers
                    )));
                }
                let geom = GeomKey::of(&cluster);
                let key = CacheKey {
                    model: ModelKey::of(&cfg),
                    stem: false,
                    target,
                    layers,
                    fuse,
                    geom: geom.clone(),
                };
                let build = || {
                    let g = models::build_graph_layers(&cfg, layers);
                    deeploy::deploy_graph_opts(g, target, &cluster, fuse)
                };
                let (entry, cache_hit) = if use_cache {
                    compile_cached(key, build)?
                } else {
                    (Entry::new(build()?), false)
                };
                // the conv stem runs once per inference; the full-depth
                // graph embeds it, but any other block count (fewer for
                // extrapolation, more for over-deploy) does not — compile
                // it separately so the report always covers it
                let stem = if layers != cfg.layers && cfg.conv_stem {
                    let skey = CacheKey {
                        model: ModelKey::of(&cfg),
                        stem: true,
                        target,
                        layers: 1,
                        fuse,
                        geom,
                    };
                    let sbuild = || {
                        let g = models::build_stem_graph(&cfg)
                            .expect("conv_stem models have a stem graph");
                        deeploy::deploy_graph_opts(g, target, &cluster, fuse)
                    };
                    let (sentry, _) = if use_cache {
                        compile_cached(skey, sbuild)?
                    } else {
                        (Entry::new(sbuild()?), false)
                    };
                    Some(sentry)
                } else {
                    None
                };
                let engine = Engine::new(cluster);
                Ok(Compiled {
                    engine,
                    model: Some(cfg),
                    layers,
                    entry,
                    stem,
                    cache_hit,
                })
            }
        }
    }
}

// --- compiled artifact ------------------------------------------------------

/// A compiled deployment bound to its cluster geometry: owns the
/// [`Deployment`] (possibly shared through the cache) and a reusable
/// simulation [`Engine`], and exposes the evaluate surface.
pub struct Compiled {
    engine: Engine,
    model: Option<ModelConfig>,
    /// Encoder blocks actually deployed (model sources).
    layers: usize,
    entry: Arc<Entry>,
    stem: Option<Arc<Entry>>,
    cache_hit: bool,
}

impl Compiled {
    /// The deployment artifact (graph, command stream, memory layout).
    pub fn deployment(&self) -> &Deployment {
        &self.entry.deployment
    }

    /// The cluster geometry this compilation is bound to (owned by the
    /// reusable simulation engine — the single source of truth).
    pub fn cluster(&self) -> &ClusterConfig {
        &self.engine.cfg
    }

    /// Whether `compile()` was served from the deployment cache.
    pub fn was_cached(&self) -> bool {
        self.cache_hit
    }

    /// Simulation statistics of the deployed command stream (memoized:
    /// the discrete-event simulation is deterministic for a fixed
    /// geometry, so repeated calls — and other `Compiled` instances
    /// sharing the cache entry — reuse the first run).
    pub fn stats(&self) -> &RunStats {
        self.entry.stats(&self.engine)
    }

    /// Per-class serving constants (`first`/`steady`/`switch`/
    /// `active_j`/`ops`), memoized with the cache entry: the per-step
    /// span re-simulation (`Engine::run_spans`) and the weight-byte
    /// walk run once per (model, target, layers, geometry, fusion) key
    /// — every later `serve()` of the class does zero engine work.
    ///
    /// Semantics (see `serve::fleet` module docs for the full story):
    /// `steady` is the solo span schedule's compute end minus the
    /// hideable no-dep lead-in DMAs, floored at the busiest resource's
    /// cycles and clamped to `[1, first]`; `switch_cycles` re-stages
    /// the graph's weight bytes over the wide AXI.
    pub fn serve_constants(&self) -> &ServeConstants {
        self.entry.serve.get_or_init(|| {
            let stats = self.stats();
            let first = stats.cycles.max(1);
            let e = energy::evaluate(stats, self.engine.cfg.freq_hz);
            let active_j = (e.total_j - e.idle_j).max(0.0);
            let ops = stats.total_ops();

            // steady-state increment from the solo per-step schedule:
            // lead-in staging and writeback tail hide under neighboring
            // requests; the bottleneck resource floors it
            let steps = &self.entry.deployment.steps;
            self.entry.sim_runs.fetch_add(1, Ordering::Relaxed);
            let (span_stats, spans) = self.engine.run_spans(steps);
            debug_assert_eq!(
                span_stats.cycles, first,
                "{}: span/stats drift",
                self.entry.deployment.graph.name
            );
            let lead_in_end = steps
                .iter()
                .zip(&spans)
                .filter(|(s, _)| s.deps.is_empty() && matches!(s.cmd, Cmd::DmaIn { .. }))
                .map(|(_, sp)| sp.end)
                .max()
                .unwrap_or(0);
            let compute_end = steps
                .iter()
                .zip(&spans)
                .filter(|(s, _)| !matches!(s.cmd, Cmd::DmaOut { .. }))
                .map(|(_, sp)| sp.end)
                .max()
                .unwrap_or(first);
            let bottleneck = stats.busy.values().copied().max().unwrap_or(first);
            let steady =
                compute_end.saturating_sub(lead_in_end).max(bottleneck).clamp(1, first);

            // class switch: re-stage the network's weights into L2 over
            // the wide AXI before the first request of a different bucket
            let weight_bytes: u64 = self
                .entry
                .deployment
                .graph
                .tensors
                .values()
                .filter(|t| t.kind == TensorKind::Weight)
                .map(|t| t.bytes() as u64)
                .sum();
            let switch_cycles =
                DmaModel::new(self.engine.cfg.wide_axi_bytes).transfer_1d(weight_bytes);
            ServeConstants { first, steady, switch_cycles, active_j, ops }
        })
    }

    /// Engine invocations performed for this compilation's cache entry
    /// so far (full-stream stats + serving-constant span runs). Shared
    /// through the cache: once a class's stats and serve constants are
    /// memoized this stops moving — the observable form of "a second
    /// serve does zero engine work".
    pub fn sim_runs(&self) -> u64 {
        self.entry.sim_runs.load(Ordering::Relaxed)
    }

    /// Simulate and report the paper-style metrics, extrapolating the
    /// simulated blocks to the full network and adding the one-off conv
    /// stem where applicable (the paper's own measurement strategy).
    pub fn simulate(&self) -> ModelReport {
        let stats = self.stats();
        let rep = energy::evaluate(stats, self.engine.cfg.freq_hz);
        let (name, gop, scale) = match &self.model {
            Some(cfg) => (
                cfg.name.to_string(),
                cfg.gop_per_inference,
                cfg.layers as f64 / self.layers as f64,
            ),
            None => (
                self.entry.deployment.graph.name.clone(),
                self.entry.deployment.total_ops as f64 / 1e9,
                1.0,
            ),
        };
        let mut seconds = rep.seconds * scale;
        let mut energy_j = rep.total_j * scale;
        if let Some(stem) = &self.stem {
            let srep = energy::evaluate(stem.stats(&self.engine), self.engine.cfg.freq_hz);
            seconds += srep.seconds;
            energy_j += srep.total_j;
        }
        ModelReport {
            model: name,
            target: self.entry.deployment.target,
            seconds,
            energy_j,
            gops: gop / seconds,
            gopj: gop / energy_j,
            power_w: energy_j / seconds,
            inf_per_s: 1.0 / seconds,
            mj_per_inf: energy_j * 1e3,
            ita_utilization: stats.ita_utilization(),
            ita_duty: stats.ita_duty(),
            cycles: (stats.cycles as f64 * scale) as u64,
            l1_peak_bytes: self.entry.deployment.l1_peak_bytes,
            l2_activation_bytes: self.entry.deployment.l2_activation_bytes,
            freq_hz: self.engine.cfg.freq_hz,
        }
    }

    /// Golden-check the compiled model's **numerics**: execute its
    /// encoder artifact on the runtime backend and compare bit-exactly
    /// against the rust functional model on the shared synthetic
    /// weights. This checks the network the deployment was compiled
    /// from — not the command stream itself, whose invariants are
    /// enforced by `compile()` and exercised by `simulate()`. Returns
    /// the number of output values compared.
    pub fn verify(&self, rt: &Runtime) -> Result<usize, RuntimeError> {
        let Some(cfg) = &self.model else {
            return Err(RuntimeError::Usage(
                "verify needs a model-sourced pipeline (imported graphs have no \
                 golden artifact)"
                    .to_string(),
            ));
        };
        let name = format!("encoder_{}", cfg.name);
        let w = forward::synth_layer_weights(cfg, 0);
        let x = models::synth_input(cfg);
        let mut inputs: Vec<TensorIn> =
            vec![TensorIn { data: &x, shape: vec![cfg.seq, cfg.emb] }];
        let shapes = forward::weight_shapes(cfg);
        let datas: Vec<&Vec<i32>> = vec![
            &w.wq, &w.wk, &w.wv, &w.wo, &w.bq, &w.bk, &w.bv, &w.bo, &w.w1, &w.b1,
            &w.w2, &w.b2, &w.ln1_g, &w.ln1_b, &w.ln2_g, &w.ln2_b,
        ];
        for (d, (_, s)) in datas.iter().zip(&shapes) {
            inputs.push(TensorIn { data: d, shape: s.clone() });
        }
        let got = rt.execute(&name, &inputs)?;
        let want = forward::encoder_layer(cfg, &Mat::new(cfg.seq, cfg.emb, x.clone()), &w);
        if got[0] != want.data {
            let diff = got[0].iter().zip(&want.data).filter(|(a, b)| a != b).count();
            return Err(RuntimeError::Backend(format!(
                "{name}: {diff}/{} values differ from the rust functional model",
                want.data.len()
            )));
        }
        Ok(want.data.len())
    }

    /// Human-readable deployment summary (the `deploy` subcommand).
    pub fn report(&self) -> String {
        let dep = &self.entry.deployment;
        let budget = deeploy::l1_tile_budget(&self.engine.cfg);
        let ita = dep
            .steps
            .iter()
            .filter(|s| matches!(s.cmd, Cmd::ItaGemm { .. } | Cmd::ItaAttention { .. }))
            .count();
        let core = dep.steps.iter().filter(|s| matches!(s.cmd, Cmd::Core { .. })).count();
        let dma = dep
            .steps
            .iter()
            .filter(|s| matches!(s.cmd, Cmd::DmaIn { .. } | Cmd::DmaOut { .. }))
            .count();
        let mut s = String::new();
        let layers = match &self.model {
            Some(cfg) => format!("{}/{} layers deployed", self.layers, cfg.layers),
            None => "imported graph".to_string(),
        };
        s.push_str(&format!("model        : {} ({layers})\n", dep.graph.name));
        s.push_str(&format!("target       : {:?}\n", dep.target));
        s.push_str(&format!("graph nodes  : {}\n", dep.graph.nodes.len()));
        s.push_str(&format!("total ops    : {:.3} GOp\n", dep.total_ops as f64 / 1e9));
        s.push_str(&format!("command steps: {}\n", dep.steps.len()));
        s.push_str(&format!(
            "L1 tile peak : {} B of {budget} B budget ({} KiB TCDM)\n",
            dep.l1_peak_bytes,
            self.engine.cfg.l1_bytes() / 1024
        ));
        s.push_str(&format!("L2 act arena : {} B\n", dep.l2_activation_bytes));
        s.push_str(&format!("step mix     : {ita} ITA, {core} cluster, {dma} DMA\n"));
        s.push_str(&format!(
            "compile      : {}\n",
            if self.cache_hit { "deployment cache hit" } else { "cold" }
        ));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{DINOV2S, MOBILEBERT, WHISPER_TINY_ENC};

    #[test]
    fn builder_without_source_errors() {
        match Pipeline::new(ClusterConfig::default()).compile() {
            Err(DeployError::Builder(m)) => assert!(m.contains("source"), "{m}"),
            other => panic!("expected Builder error, got {:?}", other.err()),
        }
    }

    #[test]
    fn builder_rejects_zero_layers_but_allows_overdeploy() {
        let r = Pipeline::new(ClusterConfig::default())
            .model(&MOBILEBERT)
            .layers(0)
            .compile();
        assert!(matches!(r, Err(DeployError::Builder(_))));
        // 0.1.0 parity: more blocks than the model's depth deploys them
        // and scales the extrapolation below 1
        let mut cluster = ClusterConfig::default();
        cluster.freq_hz = 424.875e6;
        let over = Pipeline::new(cluster)
            .model(&DINOV2S)
            .layers(DINOV2S.layers + 1)
            .compile()
            .unwrap();
        assert!(over.simulate().seconds > 0.0);
    }

    #[test]
    fn pipeline_matches_paper_shape() {
        let c = Pipeline::new(ClusterConfig::default())
            .model(&MOBILEBERT)
            .target(Target::MultiCoreIta)
            .layers(1)
            .compile()
            .unwrap();
        let r = c.simulate();
        assert!((r.inf_per_s - 32.5).abs() < 7.0, "Inf/s {}", r.inf_per_s);
        assert!((r.freq_hz - 425.0e6).abs() < 1.0);
    }

    #[test]
    fn second_compile_hits_cache_and_shares_stats() {
        // use a distinctive geometry so concurrent tests cannot collide
        let mut cluster = ClusterConfig::default();
        cluster.freq_hz = 424.125e6;
        let build = || {
            Pipeline::new(cluster.clone())
                .model(&DINOV2S)
                .target(Target::MultiCoreIta)
                .layers(1)
                .compile()
                .unwrap()
        };
        let a = build();
        assert!(!a.was_cached());
        let r1 = a.simulate();
        let b = build();
        assert!(b.was_cached(), "second compile must hit the cache");
        assert!(
            Arc::ptr_eq(&a.entry, &b.entry),
            "cache must share one deployment entry"
        );
        // the memoized stats are already populated for the second caller
        assert!(b.entry.stats.get().is_some());
        let r2 = b.simulate();
        assert_eq!(r1.cycles, r2.cycles);
        assert_eq!(r1.mj_per_inf, r2.mj_per_inf);
    }

    #[test]
    fn uncached_compile_is_isolated() {
        let mut cluster = ClusterConfig::default();
        cluster.freq_hz = 424.5e6;
        let a = Pipeline::new(cluster.clone())
            .model(&MOBILEBERT)
            .layers(1)
            .uncached()
            .compile()
            .unwrap();
        let b = Pipeline::new(cluster)
            .model(&MOBILEBERT)
            .layers(1)
            .uncached()
            .compile()
            .unwrap();
        assert!(!a.was_cached() && !b.was_cached());
        assert!(!Arc::ptr_eq(&a.entry, &b.entry));
    }

    #[test]
    fn geometry_is_part_of_the_key() {
        let mut c1 = ClusterConfig::default();
        c1.freq_hz = 424.25e6;
        let mut c2 = c1.clone();
        c2.tcdm_banks = 64;
        c2.tcdm_bank_bytes = 2048; // same 128 KiB, different banking
        let a = Pipeline::new(c1).model(&MOBILEBERT).layers(1).compile().unwrap();
        let b = Pipeline::new(c2).model(&MOBILEBERT).layers(1).compile().unwrap();
        assert!(!Arc::ptr_eq(&a.entry, &b.entry));
        // fewer conflicts at 64 banks: the 64-bank geometry cannot be slower
        assert!(b.stats().cycles <= a.stats().cycles);
    }

    #[test]
    fn whisper_stem_compiled_once_per_geometry() {
        let mut cluster = ClusterConfig::default();
        cluster.freq_hz = 424.75e6;
        let a = Pipeline::new(cluster.clone())
            .model(&WHISPER_TINY_ENC)
            .layers(1)
            .compile()
            .unwrap();
        let b = Pipeline::new(cluster)
            .model(&WHISPER_TINY_ENC)
            .layers(2)
            .compile()
            .unwrap();
        let (sa, sb) = (a.stem.as_ref().unwrap(), b.stem.as_ref().unwrap());
        assert!(Arc::ptr_eq(sa, sb), "stem deployment must be shared");
        // full-network deployment embeds the stem; no separate entry
        let full = Pipeline::new(ClusterConfig::default())
            .model(&WHISPER_TINY_ENC)
            .compile()
            .unwrap();
        assert!(full.stem.is_none());
    }

    #[test]
    fn graph_source_simulates_with_graph_identity() {
        let g = models::build_graph_layers(&MOBILEBERT, 1);
        let c = Pipeline::new(ClusterConfig::default())
            .graph(g)
            .target(Target::MultiCoreIta)
            .compile()
            .unwrap();
        assert!(!c.was_cached());
        let r = c.simulate();
        assert_eq!(r.model, "mobilebert");
        // graph-source GOp accounting comes from the graph itself
        assert!(r.gops > 0.0 && r.seconds > 0.0);
        let rep = c.report();
        assert!(rep.contains("imported graph"), "{rep}");
    }

    #[test]
    fn graph_source_rejects_layers_option() {
        let g = models::build_graph_layers(&MOBILEBERT, 1);
        let r = Pipeline::new(ClusterConfig::default()).graph(g).layers(1).compile();
        assert!(matches!(r, Err(DeployError::Builder(_))));
    }

    #[test]
    fn small_l1_geometry_is_a_typed_budget_error() {
        let mut cluster = ClusterConfig::default();
        cluster.tcdm_banks = 2;
        cluster.tcdm_bank_bytes = 4096; // 8 KiB L1 < minimum tile
        let r = Pipeline::new(cluster).model(&MOBILEBERT).layers(1).compile();
        match r {
            Err(DeployError::L1Budget { budget, required, .. }) => {
                assert_eq!(budget, 0); // 8 KiB - 16 KiB reserve saturates
                assert!(required > 0);
            }
            other => panic!("expected L1Budget, got {:?}", other.err()),
        }
    }

    #[test]
    fn report_lists_deployment_facts() {
        let c = Pipeline::new(ClusterConfig::default())
            .model(&MOBILEBERT)
            .layers(1)
            .compile()
            .unwrap();
        let rep = c.report();
        for needle in ["mobilebert", "command steps", "step mix", "L1 tile peak"] {
            assert!(rep.contains(needle), "missing {needle} in:\n{rep}");
        }
    }

    #[test]
    fn verify_graph_source_is_usage_error() {
        let g = models::build_graph_layers(&MOBILEBERT, 1);
        let c = Pipeline::new(ClusterConfig::default()).graph(g).compile().unwrap();
        let rt = Runtime::reference();
        assert!(matches!(c.verify(&rt), Err(RuntimeError::Usage(_))));
    }

    #[test]
    fn verify_model_against_reference_backend() {
        let c = Pipeline::new(ClusterConfig::default())
            .model(&MOBILEBERT)
            .layers(1)
            .compile()
            .unwrap();
        let rt = Runtime::reference();
        let n = c.verify(&rt).unwrap();
        assert_eq!(n, MOBILEBERT.seq * MOBILEBERT.emb);
    }

    #[test]
    fn serve_without_source_or_classes_errors() {
        let w = Workload::poisson(vec![], 100.0, 4, 1);
        let r = Pipeline::new(ClusterConfig::default()).serve(&w);
        assert!(matches!(r, Err(DeployError::Builder(_))));
        let zero_fleet = Pipeline::new(ClusterConfig::default())
            .model(&MOBILEBERT)
            .layers(1)
            .fleet(0)
            .serve(&Workload::single(&MOBILEBERT, 1));
        assert!(matches!(zero_fleet, Err(DeployError::Builder(_))));
    }

    #[test]
    fn serve_fills_the_class_from_the_model_source() {
        // an empty-class workload borrows the builder's model + layers
        let w = Workload::poisson(vec![], 500.0, 3, 42);
        let r = Pipeline::new(ClusterConfig::default())
            .model(&MOBILEBERT)
            .layers(1)
            .fleet(2)
            .serve(&w)
            .unwrap();
        assert_eq!(r.served, 3);
        assert_eq!(r.clusters, 2);
        assert_eq!(r.scheduler, "fifo");
    }

    #[test]
    fn builder_controller_hook_attaches_a_summary_and_changes_nothing_else() {
        use crate::serve::StaticNominal;
        let w = Workload::poisson(vec![], 400.0, 16, 7);
        let build = || {
            Pipeline::new(ClusterConfig::default()).model(&MOBILEBERT).layers(1).fleet(2)
        };
        let plain = build().serve(&w).unwrap();
        let controlled =
            build().controller(Box::new(StaticNominal)).serve(&w).unwrap();
        assert!(plain.control.is_none());
        let summary = controlled.control.as_ref().unwrap();
        assert_eq!(summary.controller, "static-nominal");
        assert_eq!(plain.makespan_cycles, controlled.makespan_cycles);
        assert_eq!(plain.energy_j.to_bits(), controlled.energy_j.to_bits());
    }

    #[test]
    fn builder_fault_hook_with_inert_config_changes_nothing_else() {
        let w = Workload::poisson(vec![], 400.0, 12, 11);
        let build = || {
            Pipeline::new(ClusterConfig::default()).model(&MOBILEBERT).layers(1).fleet(2)
        };
        let plain = build().serve(&w).unwrap();
        let faulted = build().faults(FaultConfig::default()).serve(&w).unwrap();
        assert!(plain.fault.is_none());
        let fs = faulted.fault.as_ref().unwrap();
        assert_eq!(fs.admission, "admit-all");
        assert_eq!((fs.crashes, fs.shed, fs.expired, fs.retried), (0, 0, 0, 0));
        assert_eq!(fs.availability.to_bits(), 1.0f64.to_bits());
        assert_eq!(plain.makespan_cycles, faulted.makespan_cycles);
        assert_eq!(plain.energy_j.to_bits(), faulted.energy_j.to_bits());
        assert_eq!(plain.p99_cycles, faulted.p99_cycles);
        assert_eq!(faulted.final_queue_depth, 0);
    }

    #[test]
    fn fuse_toggle_changes_the_deployment() {
        let mut cluster = ClusterConfig::default();
        cluster.freq_hz = 425.5e6;
        let fused = Pipeline::new(cluster.clone())
            .model(&MOBILEBERT)
            .layers(1)
            .compile()
            .unwrap();
        let unfused = Pipeline::new(cluster)
            .model(&MOBILEBERT)
            .layers(1)
            .fuse_mha(false)
            .compile()
            .unwrap();
        assert!(!Arc::ptr_eq(&fused.entry, &unfused.entry));
        // unfused softmax runs on the cores: strictly slower
        assert!(unfused.stats().cycles > fused.stats().cycles);
    }
}
