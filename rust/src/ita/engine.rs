//! ITA datapath: int8 GEMM with requant/activation, single-head attention
//! with streaming ITAMax, plus the *cluster-side* integer auxiliary
//! operators (i-LayerNorm, head accumulation, saturating residual add)
//! that the Snitch cores execute in the paper.
//!
//! Bit-identical to `python/compile/kernels/ref.py` + `model.py`.

use super::gelu::{self, Act, GeluConsts};
use super::quant::{clip_i8, requant};
use super::softmax;

/// The i-GeLU input scale fixed by the quantized L2 model
/// (`python/compile/model.py::GELU_S`). Every caller that feeds
/// [`gemm_rq`] a GeLU activation must pass this same scale — the golden
/// checks compare backend output against the functional model built
/// from it, so both sides of the comparison reference this constant.
pub const GELU_S: f64 = 0.1;

/// Row-major int32 matrix carrying int8/intermediate values.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<i32>,
}

impl Mat {
    pub fn new(rows: usize, cols: usize, data: Vec<i32>) -> Mat {
        assert_eq!(rows * cols, data.len());
        Mat { rows, cols, data }
    }

    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0; rows * cols] }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> i32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: i32) {
        self.data[r * self.cols + c] = v;
    }

    pub fn row(&self, r: usize) -> &[i32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }
}

/// Integer matmul with i32 accumulation: C = A x B (A: MxK, B: KxN).
///
/// ikj loop order (row-major B streams through cache) with a zero-skip,
/// parallelized over row blocks with scoped threads for large problems —
/// the golden-model hot path (Whisper layers run 300M-MAC GEMMs).
pub fn matmul_i32(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols, b.rows, "matmul dims {}x{} x {}x{}", a.rows, a.cols, b.rows, b.cols);
    let mut c = Mat::zeros(a.rows, b.cols);
    let macs = a.rows * a.cols * b.cols;
    let workers = if macs < (1 << 22) {
        1
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(a.rows)
    };
    let rows_per = a.rows.div_ceil(workers);
    std::thread::scope(|scope| {
        for (block_idx, c_block) in c.data.chunks_mut(rows_per * b.cols).enumerate() {
            let row0 = block_idx * rows_per;
            scope.spawn(move || {
                for (bi, crow) in c_block.chunks_mut(b.cols).enumerate() {
                    let i = row0 + bi;
                    for k in 0..a.cols {
                        let av = a.at(i, k);
                        if av == 0 {
                            continue;
                        }
                        let brow = &b.data[k * b.cols..(k + 1) * b.cols];
                        for (cv, &bv) in crow.iter_mut().zip(brow) {
                            *cv += av * bv;
                        }
                    }
                }
            });
        }
    });
    c
}

/// ITA GEMM mode: int8 GEMM + bias + requant + activation.
/// Matches `ref.gemm_rq` / the `ita_gemm` Pallas kernel.
pub fn gemm_rq(
    x: &Mat,
    w: &Mat,
    bias: &[i32],
    mult: i32,
    shift: u32,
    act: Act,
    gelu_s: f64,
) -> Mat {
    assert_eq!(bias.len(), w.cols);
    let mut acc = matmul_i32(x, w);
    let gc = if act == Act::Gelu {
        gelu::gelu_consts(gelu_s)
    } else {
        GeluConsts { b_int: 0, c_int: 0, sig_mult: 0, sig_shift: 0 }
    };
    for r in 0..acc.rows {
        for c in 0..acc.cols {
            let v = requant(acc.at(r, c) + bias[c], mult, shift, 0);
            acc.set(r, c, gelu::apply(act, v, &gc));
        }
    }
    acc
}

/// Single-head quantized attention: QK requant -> ITAMax -> AV requant.
/// Matches `ref.attention_head` / the Pallas `attention_head`.
/// Returns (O, QK, A) so the simulator and tests can inspect each stage.
pub fn attention_head(
    q: &Mat,
    k: &Mat,
    v: &Mat,
    qk_mult: i32,
    qk_shift: u32,
    av_mult: i32,
    av_shift: u32,
) -> (Mat, Mat, Mat) {
    // QK^T: (S x P) x (P x S_kv)
    let kt = transpose(k);
    let qk_acc = matmul_i32(q, &kt);
    let qk = Mat::new(
        qk_acc.rows,
        qk_acc.cols,
        qk_acc.data.iter().map(|&a| requant(a, qk_mult, qk_shift, 0)).collect(),
    );
    let a = Mat::new(qk.rows, qk.cols, softmax::itamax(&qk.data, qk.cols));
    let av_acc = matmul_i32(&a, v);
    let o = Mat::new(
        av_acc.rows,
        av_acc.cols,
        av_acc.data.iter().map(|&x| requant(x, av_mult, av_shift, 0)).collect(),
    );
    (o, qk, a)
}

pub fn transpose(m: &Mat) -> Mat {
    let mut t = Mat::zeros(m.cols, m.rows);
    for r in 0..m.rows {
        for c in 0..m.cols {
            t.set(c, r, m.at(r, c));
        }
    }
    t
}

// --- cluster-side auxiliary operators (run on Snitch cores in the paper) ---

/// Fixed-iteration integer Newton sqrt — bit-identical to `quant.isqrt`.
pub fn isqrt(n: i32) -> i32 {
    debug_assert!(n >= 0);
    let mut x: i32 = 1 << 15;
    for _ in 0..16 {
        let xs = x.max(1);
        x = (xs + n / xs) >> 1;
    }
    if x as i64 * x as i64 > n as i64 {
        x -= 1;
    }
    x.max(1)
}

/// Integer LayerNorm over each row — bit-identical to `quant.ilayernorm`.
pub fn ilayernorm(x: &Mat, gamma: &[i32], beta: &[i32], mult: i32, shift: u32) -> Mat {
    assert_eq!(gamma.len(), x.cols);
    assert_eq!(beta.len(), x.cols);
    let e = x.cols as i32;
    let mut out = Mat::zeros(x.rows, x.cols);
    for r in 0..x.rows {
        let row = x.row(r);
        let sum: i32 = row.iter().sum();
        let mu = sum.div_euclid(e);
        let var: i32 = row.iter().map(|&v| (v - mu) * (v - mu)).sum::<i32>() / e;
        let sigma = isqrt(var);
        for c in 0..x.cols {
            let d = x.at(r, c) - mu;
            let n = (d * 128).div_euclid(sigma);
            let y = requant(n * gamma[c], mult, shift, 0);
            out.set(r, c, clip_i8(y + beta[c]));
        }
    }
    out
}

/// Saturating int8 residual add (the cluster's requant-add).
pub fn residual_add(a: &Mat, b: &Mat) -> Mat {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    Mat::new(
        a.rows,
        a.cols,
        a.data.iter().zip(&b.data).map(|(&x, &y)| clip_i8(x + y)).collect(),
    )
}

/// Head accumulation: sum per-head partial output projections (int32)
/// then requantize once — the paper's cluster-side accumulation layer.
pub fn head_accumulate(partials: &[Mat], bias: &[i32], mult: i32, shift: u32) -> Mat {
    let (r, c) = (partials[0].rows, partials[0].cols);
    let mut acc = Mat::zeros(r, c);
    for p in partials {
        for (a, &v) in acc.data.iter_mut().zip(&p.data) {
            *a += v;
        }
    }
    for row in 0..r {
        for col in 0..c {
            let v = requant(acc.at(row, col) + bias[col], mult, shift, 0);
            acc.set(row, col, v);
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::XorShift64;

    fn rand_mat(rng: &mut XorShift64, r: usize, c: usize) -> Mat {
        Mat::new(r, c, rng.tensor_i8(r * c))
    }

    #[test]
    fn matmul_identity() {
        let mut eye = Mat::zeros(3, 3);
        for i in 0..3 {
            eye.set(i, i, 1);
        }
        let mut rng = XorShift64::new(1);
        let a = rand_mat(&mut rng, 3, 3);
        assert_eq!(matmul_i32(&a, &eye), a);
    }

    #[test]
    fn matmul_known_values() {
        let a = Mat::new(2, 2, vec![1, 2, 3, 4]);
        let b = Mat::new(2, 2, vec![5, 6, 7, 8]);
        assert_eq!(matmul_i32(&a, &b).data, vec![19, 22, 43, 50]);
    }

    #[test]
    fn gemm_saturation() {
        // python test_gemm_bias_zero_and_saturation
        let x = Mat::new(4, 4, vec![127; 16]);
        let w = Mat::new(4, 4, vec![127; 16]);
        let b = vec![0; 4];
        let g = gemm_rq(&x, &w, &b, 1 << 8, 8, Act::Identity, 0.1);
        assert!(g.data.iter().all(|&v| v == 127));
        let wn = Mat::new(4, 4, vec![-127; 16]);
        let g2 = gemm_rq(&x, &wn, &b, 1 << 8, 8, Act::Identity, 0.1);
        assert!(g2.data.iter().all(|&v| v == -128));
    }

    #[test]
    fn attention_uniform_rows() {
        // all logits equal -> uniform A -> O = requant(sum(V)/S * 128)
        let s = 64;
        let q = Mat::zeros(s, 64);
        let k = Mat::zeros(s, 64);
        let v = Mat::new(s, 64, vec![100; s * 64]);
        let (o, _, a) = attention_head(&q, &k, &v, 15, 14, 8, 14);
        let a0 = a.at(0, 0);
        assert!(a.data.iter().all(|&x| x == a0), "uniform A");
        assert!(o.data.iter().all(|&x| x == o.at(0, 0)));
    }

    #[test]
    fn isqrt_is_floor_sqrt() {
        for n in [0, 1, 2, 3, 4, 15, 16, 17, 100, 10_000, 1 << 30] {
            let want = (n as f64).sqrt().floor() as i32;
            assert_eq!(isqrt(n), want.max(1), "n={n}");
        }
    }

    #[test]
    fn ilayernorm_beta_offset() {
        // python test_ilayernorm_beta_offset: zero input -> output = beta
        let x = Mat::zeros(2, 64);
        let g = vec![64; 64];
        let b = vec![7; 64];
        let y = ilayernorm(&x, &g, &b, 16, 12);
        assert!(y.data.iter().all(|&v| v == 7));
    }

    #[test]
    fn ilayernorm_normalizes() {
        let mut rng = XorShift64::new(2);
        let x = rand_mat(&mut rng, 8, 128);
        let g = vec![64; 128];
        let b = vec![0; 128];
        let y = ilayernorm(&x, &g, &b, 16, 12);
        // scale: 32 * (d/sigma) -> row mean ~0, magnitude < 128
        let mean: f64 = y.data.iter().map(|&v| v as f64).sum::<f64>() / y.data.len() as f64;
        assert!(mean.abs() < 2.0, "mean {mean}");
        assert!(y.data.iter().all(|&v| (-128..=127).contains(&v)));
    }

    #[test]
    fn residual_add_saturates() {
        let a = Mat::new(1, 2, vec![120, -120]);
        let b = Mat::new(1, 2, vec![100, -100]);
        assert_eq!(residual_add(&a, &b).data, vec![127, -128]);
    }

    #[test]
    fn head_accumulate_requants_once() {
        let p1 = Mat::new(1, 2, vec![1000, -1000]);
        let p2 = Mat::new(1, 2, vec![500, 500]);
        let out = head_accumulate(&[p1, p2], &[0, 0], 16, 8);
        // (1500 * 16 + 128) >> 8 = 94 ; (-500*16+128)>>8 = -31
        assert_eq!(out.data, vec![94, -31]);
    }
}
