//! Bit-exact functional model of the Integer Transformer Accelerator (ITA).
//!
//! This is the rust twin of `python/compile/kernels/quant.py` — the single
//! integer-arithmetic specification implemented three times (jnp oracle,
//! Pallas kernels, this module) and cross-checked end-to-end by executing
//! the AOT artifacts through PJRT and comparing bit-for-bit
//! (`rust/tests/golden_pjrt.rs`).
//!
//! Module map (mirrors Fig. 2 of the paper):
//!   [`quant`]   — requantization (the PULP RQS operator)
//!   [`softmax`] — ITAMax: streaming DA -> DI -> EN integer softmax
//!   [`gelu`]    — i-GeLU / ReLU integer activation unit
//!   [`engine`]  — dot-product datapath: GEMM + single-head attention
//!   [`config`]  — the accelerator geometry (N=16, M=64, D=26)

pub mod config;
pub mod engine;
pub mod gelu;
pub mod quant;
pub mod softmax;

pub use config::ItaConfig;
