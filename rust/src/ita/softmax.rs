//! ITAMax: the streaming integer softmax (DA -> DI -> EN stages).
//!
//! Numeric spec: see `python/compile/kernels/quant.py` — base-2 softmax
//! with F=5 fractional bits, 32-entry EXP2 LUT, 16-element DA chunks,
//! LUT-multiply renormalization on running-max updates, 2^24 denominator
//! inversion, and 7-bit probability outputs. Everything here is
//! bit-identical to the jnp oracle / Pallas kernels.

/// Fractional bits of the base-2 exponent.
pub const ITA_F: u32 = 5;
/// DA stage chunk width (the N=16 dot units emit 16 elements per cycle).
pub const DA_CHUNK: usize = 16;
/// Denominator-Inversion precision: inv = floor(2^24 / den).
pub const INV_BITS: u32 = 24;
/// Element-Normalization output shift -> A scale = 1/128.
pub const EN_SHIFT: u32 = 17;
/// Maximum attention probability value (7-bit).
pub const A_MAX: i32 = 127;
/// Initial running maximum is -M0.
pub const M0: i32 = 1 << 20;

/// EXP2_LUT[f] = round(256 * 2^(-f/32)), f in 0..32.
pub const EXP2_LUT: [i32; 32] = exp2_lut();

const fn exp2_lut() -> [i32; 32] {
    // const-fn-safe: precomputed table (checked against the formula in
    // tests and against python test_exp2_lut_values golden).
    [
        256, 251, 245, 240, 235, 230, 225, 220, 215, 211, 206, 202, 197, 193,
        189, 185, 181, 177, 173, 170, 166, 162, 159, 156, 152, 149, 146, 143,
        140, 137, 134, 131,
    ]
}

/// Numerator of the base-2 softmax for non-negative diff = max - x.
#[inline]
pub fn exp2_num(diff: i32) -> i32 {
    debug_assert!(diff >= 0);
    let shift = ((diff >> ITA_F) as u32).min(31);
    let frac = (diff & 31) as usize;
    EXP2_LUT[frac] >> shift
}

/// Streaming DA renormalization: acc * 2^(-delta/32), one multiply+shift.
#[inline]
pub fn renorm_den(acc: i32, delta: i32) -> i32 {
    debug_assert!(delta >= 0);
    let shift = (8 + (delta >> ITA_F) as u32).min(31);
    (acc.wrapping_mul(EXP2_LUT[(delta & 31) as usize])) >> shift
}

/// Carry state of the DA stage for one row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RowStats {
    pub max: i32,
    pub den: i32,
}

impl Default for RowStats {
    fn default() -> Self {
        Self { max: -M0, den: 0 }
    }
}

/// DA stage: fold one 16-element chunk into the running (max, den).
pub fn da_step(stats: RowStats, chunk: &[i32]) -> RowStats {
    debug_assert_eq!(chunk.len(), DA_CHUNK);
    let lm = chunk.iter().copied().max().unwrap();
    let m_new = stats.max.max(lm);
    let delta = m_new - stats.max;
    let mut den = renorm_den(stats.den, delta);
    for &x in chunk {
        den += exp2_num(m_new - x);
    }
    RowStats { max: m_new, den }
}

/// DA over a full row (length must be a multiple of DA_CHUNK).
pub fn da_row(row: &[i32]) -> RowStats {
    assert_eq!(row.len() % DA_CHUNK, 0, "row length {}", row.len());
    row.chunks(DA_CHUNK).fold(RowStats::default(), da_step)
}

/// DI stage: inv = floor(2^24 / den).
#[inline]
pub fn di(den: i32) -> i32 {
    debug_assert!(den > 0);
    (1 << INV_BITS) / den
}

/// EN stage: one normalized probability in [0, 127].
#[inline]
pub fn en(x: i32, max: i32, inv: i32) -> i32 {
    let num = exp2_num(max - x);
    ((num.wrapping_mul(inv)) >> EN_SHIFT).min(A_MAX)
}

/// Full ITAMax over a row: returns quantized probabilities (scale 1/128).
pub fn itamax_row(row: &[i32]) -> Vec<i32> {
    let stats = da_row(row);
    let inv = di(stats.den);
    row.iter().map(|&x| en(x, stats.max, inv)).collect()
}

/// ITAMax over each row of a (rows x cols) matrix (row-major).
pub fn itamax(x: &[i32], cols: usize) -> Vec<i32> {
    assert_eq!(x.len() % cols, 0);
    x.chunks(cols).flat_map(itamax_row).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::XorShift64;

    #[test]
    fn lut_matches_formula() {
        for (i, &v) in EXP2_LUT.iter().enumerate() {
            let f = 256.0 * f64::powf(2.0, -(i as f64) / 32.0);
            assert_eq!(v, f.round() as i32, "LUT[{i}]");
        }
        assert_eq!(EXP2_LUT[0], 256);
        assert_eq!(EXP2_LUT[31], 131); // python golden
    }

    #[test]
    fn exp2_num_monotone() {
        let mut prev = i32::MAX;
        for d in 0..1024 {
            let n = exp2_num(d);
            assert!(n <= prev);
            prev = n;
        }
        assert_eq!(exp2_num(0), 256);
        assert_eq!(exp2_num(1023), 0);
    }

    #[test]
    fn all_equal_row() {
        // python golden: x = [-128; 16] -> max -128, den 16*256
        let row = [-128; 16];
        let s = da_row(&row);
        assert_eq!(s.max, -128);
        assert_eq!(s.den, 16 * 256);
    }

    #[test]
    fn peaked_short_row_golden() {
        // python test_itamax_peaked_short_row golden: a[3] == 120
        let mut row = [-128i32; 16];
        row[3] = 127;
        let a = itamax_row(&row);
        assert_eq!(a[3], 120);
        assert_eq!(a[0], 0);
    }

    #[test]
    fn uniform_long_row_underflows() {
        let row = [0i32; 512];
        let a = itamax_row(&row);
        assert!(a.iter().all(|&v| v == 0));
    }

    #[test]
    fn invariant_to_constant_shift() {
        let mut rng = XorShift64::new(5);
        let row: Vec<i32> = (0..64).map(|_| rng.next_range(-100, 21)).collect();
        let shifted: Vec<i32> = row.iter().map(|&x| x + 27).collect();
        assert_eq!(itamax_row(&row), itamax_row(&shifted));
    }

    #[test]
    fn rows_never_exceed_mass() {
        let mut rng = XorShift64::new(17);
        for _ in 0..50 {
            let cols = [16usize, 64, 128][rng.next_below(3) as usize];
            let row: Vec<i32> = (0..cols).map(|_| rng.next_range(-128, 128)).collect();
            let a = itamax_row(&row);
            assert!(a.iter().all(|&v| (0..=127).contains(&v)));
            assert!(a.iter().sum::<i32>() <= 128);
        }
    }

    #[test]
    fn streaming_equals_chunked_manual_scan() {
        // cross-checks da_row against the explicit per-chunk recurrence
        // (mirrors python test_itamax_streaming_chunk_order_matters)
        let mut rng = XorShift64::new(9);
        let row: Vec<i32> = (0..128).map(|_| rng.next_range(-128, 128)).collect();
        let got = da_row(&row);
        let mut m = -M0;
        let mut den = 0i32;
        for ch in row.chunks(16) {
            let lm = *ch.iter().max().unwrap();
            let m_new = m.max(lm);
            let delta = m_new - m;
            let shift = (8 + (delta >> 5) as u32).min(31);
            den = (den * EXP2_LUT[(delta & 31) as usize]) >> shift;
            for &x in ch {
                let d = m_new - x;
                den += EXP2_LUT[(d & 31) as usize] >> ((d >> 5) as u32).min(31);
            }
            m = m_new;
        }
        assert_eq!(got, RowStats { max: m, den });
    }

    #[test]
    fn approximates_float_softmax() {
        let mut rng = XorShift64::new(23);
        for _ in 0..20 {
            let row: Vec<i32> = (0..128).map(|_| rng.next_range(-128, 128)).collect();
            let a = itamax_row(&row);
            let xf: Vec<f64> = row.iter().map(|&x| x as f64 / 32.0).collect();
            let m = xf.iter().cloned().fold(f64::MIN, f64::max);
            let e: Vec<f64> = xf.iter().map(|&x| (x - m).exp2()).collect();
            let s: f64 = e.iter().sum();
            for (ai, ei) in a.iter().zip(&e) {
                assert!(
                    ((*ai as f64) / 128.0 - ei / s).abs() < 0.02,
                    "a={ai} f={}",
                    ei / s
                );
            }
        }
    }
}
