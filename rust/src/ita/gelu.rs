//! Integer activation unit: Identity / ReLU / i-GeLU (I-BERT).
//!
//! Bit-identical to `kernels.quant.igelu` — same constants derivation from
//! the input scale, same i32 arithmetic, same saturation.

/// Activation selection (the HWPE configuration field).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    Identity,
    Relu,
    Gelu,
}

impl Act {
    pub fn from_str(s: &str) -> Option<Act> {
        match s {
            "identity" => Some(Act::Identity),
            "relu" => Some(Act::Relu),
            "gelu" => Some(Act::Gelu),
            _ => None,
        }
    }
}

/// i-GeLU polynomial constants (I-BERT, Kim et al. 2021).
pub const IGELU_A: f64 = -0.2888;
pub const IGELU_B: f64 = -1.769;

/// Integer constants of i-GeLU for a given input scale.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GeluConsts {
    pub b_int: i32,
    pub c_int: i32,
    pub sig_mult: i32,
    pub sig_shift: u32,
}

/// Derive the integer constants — mirrors `quant.igelu_consts`.
pub fn gelu_consts(s_in: f64) -> GeluConsts {
    let s_erf = s_in / std::f64::consts::SQRT_2;
    let b_int = (IGELU_B / s_erf).floor() as i32;
    let c_int = (1.0 / (IGELU_A * s_erf * s_erf)).floor() as i32;
    let s_out = s_in * (IGELU_A * s_erf * s_erf) / 2.0;
    let ratio = s_out / s_in;
    let sig_shift = 20u32;
    let sig_mult = (ratio * (1u64 << sig_shift) as f64).round() as i32;
    assert!(
        128i64 * 2 * (c_int.unsigned_abs() as i64) * (sig_mult.unsigned_abs() as i64)
            < (1i64 << 31),
        "igelu constants overflow i32 for s_in={s_in}"
    );
    GeluConsts { b_int, c_int, sig_mult, sig_shift }
}

/// i-GeLU on one int8-range value; output int8-range at the input scale.
#[inline]
pub fn igelu(q: i32, c: &GeluConsts) -> i32 {
    let sgn = q.signum();
    let q_abs = q.abs();
    let q_clip = q_abs.min(-c.b_int);
    let t = q_clip + c.b_int; // <= 0
    let q_erf = sgn * (t * t + c.c_int);
    let q_one = c.c_int;
    let acc = q * (q_erf + q_one);
    let out = acc.wrapping_mul(c.sig_mult) >> c.sig_shift;
    out.clamp(-128, 127)
}

/// Apply the activation unit to one value.
#[inline]
pub fn apply(act: Act, q: i32, c: &GeluConsts) -> i32 {
    match act {
        Act::Identity => q,
        Act::Relu => q.max(0),
        Act::Gelu => igelu(q, c),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn float_gelu(x: f64) -> f64 {
        // x * Phi(x) via erf
        x * 0.5 * (1.0 + libm_erf(x / std::f64::consts::SQRT_2))
    }

    // minimal erf (Abramowitz-Stegun 7.1.26) for the tolerance test
    fn libm_erf(x: f64) -> f64 {
        let sgn = if x < 0.0 { -1.0 } else { 1.0 };
        let x = x.abs();
        let t = 1.0 / (1.0 + 0.3275911 * x);
        let y = 1.0
            - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t
                - 0.284496736)
                * t
                + 0.254829592)
                * t
                * (-x * x).exp();
        sgn * y
    }

    #[test]
    fn consts_for_standard_scale() {
        let c = gelu_consts(0.1);
        // b_int = floor(-1.769 / 0.0707) = floor(-25.01..) = -26
        assert_eq!(c.b_int, -26);
        assert!(c.c_int < 0);
        assert!(c.sig_mult < 0); // negative scale flips back to positive
    }

    #[test]
    fn fixed_points() {
        let c = gelu_consts(0.1);
        assert_eq!(igelu(0, &c), 0);
        assert!((igelu(127, &c) - 127).abs() <= 1); // gelu(12.7) ~ 12.7
        assert!(igelu(-128, &c).abs() <= 1); // gelu(-12.8) ~ 0
    }

    #[test]
    fn matches_float_gelu_within_2lsb() {
        let c = gelu_consts(0.1);
        for q in -128..128 {
            let got = igelu(q, &c) as f64;
            let want = float_gelu(q as f64 * 0.1) / 0.1;
            assert!((got - want).abs() <= 2.0, "q={q} got={got} want={want}");
        }
    }

    #[test]
    fn relu_and_identity() {
        let c = gelu_consts(0.1);
        assert_eq!(apply(Act::Relu, -5, &c), 0);
        assert_eq!(apply(Act::Relu, 5, &c), 5);
        assert_eq!(apply(Act::Identity, -5, &c), -5);
    }

    #[test]
    fn monotone_nondecreasing() {
        let c = gelu_consts(0.1);
        let mut prev = -1000;
        for q in -128..128 {
            let v = igelu(q, &c);
            assert!(v >= prev - 1, "q={q}"); // allow 1 LSB quantization jitter
            prev = v;
        }
    }
}
