//! Requantization: the PULP RQS operator, bit-identical to
//! `kernels.quant.requant` (jnp) and the Pallas kernels.

/// Clip an i32 into int8 value range.
#[inline]
pub fn clip_i8(x: i32) -> i32 {
    x.clamp(-128, 127)
}

/// `(acc * mult + round) >> shift`, clipped to int8, with half-up rounding.
///
/// Contract: |acc * mult| < 2^31 (callers keep accumulators in 26-bit
/// hardware range and mult is 8-bit scale), matching the jnp int32 math.
#[inline]
pub fn requant(acc: i32, mult: i32, shift: u32, zero: i32) -> i32 {
    let prod = acc.wrapping_mul(mult);
    let rnd = if shift > 0 { 1i32 << (shift - 1) } else { 0 };
    let shifted = (prod.wrapping_add(rnd)) >> shift;
    clip_i8(shifted + zero)
}

/// Requantize a whole buffer in place semantics (returns new vec).
pub fn requant_vec(acc: &[i32], mult: i32, shift: u32, zero: i32) -> Vec<i32> {
    acc.iter().map(|&a| requant(a, mult, shift, zero)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, Config};
    use crate::util::prng::XorShift64;

    #[test]
    fn rounding_half_up() {
        // matches python test_requant_rounding_half_up
        assert_eq!(requant(1, 1, 1, 0), 1); // (1 + 1) >> 1 = 1
        assert_eq!(requant(-1, 1, 1, 0), 0); // (-1 + 1) >> 1 = 0
    }

    #[test]
    fn clipping() {
        assert_eq!(requant(1 << 20, 1 << 8, 8, 0), 127);
        assert_eq!(requant(-(1 << 20), 1 << 8, 8, 0), -128);
        assert_eq!(clip_i8(127), 127);
        assert_eq!(clip_i8(128), 127);
        assert_eq!(clip_i8(-129), -128);
    }

    #[test]
    fn zero_point_applied_after_shift() {
        assert_eq!(requant(0, 5, 4, 7), 7);
        assert_eq!(requant(0, 5, 4, 200), 127);
    }

    #[test]
    fn property_matches_scalar_spec() {
        // same contract as python test_requant_matches_scalar_spec
        check(
            Config { cases: 500, seed: 0x51C2 },
            |rng: &mut XorShift64| {
                (
                    rng.next_range(-(1 << 25), 1 << 25),
                    rng.next_range(1, 256),
                    rng.next_range(1, 21) as u32,
                )
            },
            |&(a, m, s)| {
                let mut c = Vec::new();
                if a != 0 {
                    c.push((a / 2, m, s));
                }
                if m > 1 {
                    c.push((a, m / 2, s));
                }
                c
            },
            |&(acc, mult, shift)| {
                let prod = (acc as i64) * (mult as i64);
                if prod.abs() >= 1 << 31 {
                    return Ok(()); // outside contract
                }
                let want =
                    (((prod + (1i64 << (shift - 1))) >> shift) as i32).clamp(-128, 127);
                let got = requant(acc, mult, shift, 0);
                if got == want {
                    Ok(())
                } else {
                    Err(format!("got {got}, want {want}"))
                }
            },
        );
    }
}
