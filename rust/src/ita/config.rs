//! ITA geometry constants (Section IV-B of the paper).

/// Hardware geometry of one ITA instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ItaConfig {
    /// Number of dot-product units (N). Each emits one output per cycle.
    pub n_units: usize,
    /// Vector length per dot-product unit (M).
    pub m_vec: usize,
    /// Accumulator width in bits (D).
    pub acc_bits: u32,
    /// Maximum supported matrix dimension.
    pub max_dim: usize,
}

impl Default for ItaConfig {
    fn default() -> Self {
        // the paper's instantiation: N=16, M=64, D=26, dims up to 512
        Self { n_units: 16, m_vec: 64, acc_bits: 26, max_dim: 512 }
    }
}

impl ItaConfig {
    /// MACs retired per cycle at full utilization.
    pub fn macs_per_cycle(&self) -> usize {
        self.n_units * self.m_vec
    }

    /// Ops (multiply + add counted separately) per cycle at peak.
    pub fn ops_per_cycle(&self) -> usize {
        2 * self.macs_per_cycle()
    }

    /// Cycles to produce one `m_vec x m_vec` output tile with a full
    /// `m_vec`-deep reduction: (64*64 outputs x 64 MACs) / (16*64 MACs/cy)
    /// = 256 cycles — "to produce one output tile, ITA takes at least 256
    /// cycles" (paper Section IV-B).
    pub fn cycles_per_tile(&self) -> usize {
        (self.m_vec * self.m_vec * self.m_vec) / self.macs_per_cycle()
    }

    /// Accumulator range check: K <= max_dim keeps int8 x int8 dot
    /// products inside the D-bit accumulator.
    pub fn acc_fits(&self, k_dim: usize) -> bool {
        // worst case |sum| = K * 128 * 128 must fit in (acc_bits-1) bits
        (k_dim as i64) * 128 * 128 <= (1i64 << (self.acc_bits - 1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_geometry() {
        let c = ItaConfig::default();
        assert_eq!(c.macs_per_cycle(), 1024);
        assert_eq!(c.ops_per_cycle(), 2048);
        assert_eq!(c.cycles_per_tile(), 256);
    }

    #[test]
    fn peak_throughput_at_425mhz() {
        // 2048 op/cycle * 425 MHz = 870.4 GOp/s; the paper's 741 GOp/s
        // peak GEMM corresponds to 85.1% utilization of this figure.
        let c = ItaConfig::default();
        let peak = c.ops_per_cycle() as f64 * 425.0e6;
        assert!((peak - 870.4e9).abs() < 1e6);
        assert!((0.851 * peak - 741.0e9).abs() < 1.0e9);
    }

    #[test]
    fn accumulator_bounds() {
        let c = ItaConfig::default();
        assert!(c.acc_fits(512));
        assert!(!c.acc_fits(4096));
    }
}
