//! Deterministic fault schedules for the serve subsystem.
//!
//! A [`FaultPlan`] is a pure-data description of every fault a serve
//! run will experience: shard crash/recover events, per-level link
//! degradation or outage windows, and a transient request-failure
//! rate. Plans live in simulated time only — every event fires at an
//! absolute cycle count, the transient draws come from a seeded
//! `util::prng::XorShift64`, and no wall clock is ever consulted — so
//! the same plan against the same workload reproduces bit-identically.
//! The serve-side machinery that executes a plan (admission control,
//! deadlines, retry/failover) lives in [`crate::serve::fault`]; this
//! module owns only the schedule format, its JSON codec, and its
//! validation rules.
//!
//! JSON schema (all fields optional; missing ⇒ empty/zero):
//!
//! ```json
//! {
//!   "seed": 7,
//!   "transient_ppm": 500,
//!   "shard_events": [
//!     {"at_cycles": 100000, "shard": 3, "kind": "crash"},
//!     {"at_cycles": 900000, "shard": 3, "kind": "recover"}
//!   ],
//!   "link_events": [
//!     {"at_cycles": 200000, "level": "pod", "kind": "degrade", "slowdown": 4},
//!     {"at_cycles": 400000, "level": "root", "kind": "outage", "until_cycles": 450000}
//!   ]
//! }
//! ```
//!
//! Validation (`FaultPlan::validate`) enforces the invariants the
//! engine's event cursors depend on: both event lists sorted by
//! `at_cycles`, shard indices in range, per-shard strict crash/recover
//! alternation starting with a crash, at least one shard up after the
//! final event (a fully-dead fleet can never drain), link levels
//! naming one of the three hierarchy levels, `slowdown >= 1`, and
//! outage windows with `until_cycles > at_cycles`.

use crate::deeploy::DeployError;
use crate::net::link::LEVEL_NAMES;
use crate::util::json::Json;

/// What happens to a shard at a scheduled instant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardFault {
    /// The shard dies: in-flight work is killed (completed requests in
    /// the batch keep their results), staged weights are lost, and the
    /// shard leaves the dispatchable pool.
    Crash,
    /// The shard returns to the pool cold: its next dispatch pays a
    /// full weight re-stage.
    Recover,
}

/// One scheduled shard event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardEvent {
    /// Absolute simulated cycle the event fires at.
    pub at_cycles: u64,
    /// Shard index (`0..fleet.n`).
    pub shard: usize,
    pub kind: ShardFault,
}

/// What happens to a link level at a scheduled instant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkFault {
    /// Every transfer at this level serializes `slowdown`× slower
    /// until the next degrade event (`slowdown: 1` restores nominal).
    Degrade { slowdown: u64 },
    /// The level carries nothing before `until_cycles`: transfers
    /// queue behind the outage and drain when it lifts.
    Outage { until_cycles: u64 },
}

/// One scheduled link-level event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinkEvent {
    /// Absolute simulated cycle the event fires at.
    pub at_cycles: u64,
    /// Link level index (`0` board, `1` pod, `2` root).
    pub level: usize,
    pub kind: LinkFault,
}

/// A complete, validated-on-attach fault schedule.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Shard crash/recover events, sorted by `at_cycles`.
    pub shard_events: Vec<ShardEvent>,
    /// Link degrade/outage events, sorted by `at_cycles`.
    pub link_events: Vec<LinkEvent>,
    /// Transient failure probability per dispatched request, in parts
    /// per million (0 ⇒ no transient faults, no RNG draws at all).
    pub transient_ppm: u32,
    /// Seed for the transient-failure RNG (independent of the
    /// workload seed, so the arrival stream never shifts).
    pub seed: u64,
}

impl Default for FaultPlan {
    fn default() -> FaultPlan {
        FaultPlan::empty()
    }
}

impl FaultPlan {
    /// The no-fault plan: serving under it is bit-identical to serving
    /// with no fault layer at all (the propchecked identity leg).
    pub fn empty() -> FaultPlan {
        FaultPlan { shard_events: Vec::new(), link_events: Vec::new(), transient_ppm: 0, seed: 0 }
    }

    /// True when the plan schedules nothing and injects nothing.
    pub fn is_empty(&self) -> bool {
        self.shard_events.is_empty() && self.link_events.is_empty() && self.transient_ppm == 0
    }

    /// Append a shard crash at `at_cycles`.
    pub fn crash(mut self, at_cycles: u64, shard: usize) -> FaultPlan {
        self.shard_events.push(ShardEvent { at_cycles, shard, kind: ShardFault::Crash });
        self
    }

    /// Append a shard recovery at `at_cycles`.
    pub fn recover(mut self, at_cycles: u64, shard: usize) -> FaultPlan {
        self.shard_events.push(ShardEvent { at_cycles, shard, kind: ShardFault::Recover });
        self
    }

    /// Append a link-level degradation (`slowdown: 1` restores).
    pub fn degrade_link(mut self, at_cycles: u64, level: usize, slowdown: u64) -> FaultPlan {
        self.link_events.push(LinkEvent { at_cycles, level, kind: LinkFault::Degrade { slowdown } });
        self
    }

    /// Append a link-level outage lasting until `until_cycles`.
    pub fn link_outage(mut self, at_cycles: u64, level: usize, until_cycles: u64) -> FaultPlan {
        self.link_events
            .push(LinkEvent { at_cycles, level, kind: LinkFault::Outage { until_cycles } });
        self
    }

    /// Set the transient request-failure rate (parts per million).
    pub fn transient(mut self, ppm: u32) -> FaultPlan {
        self.transient_ppm = ppm;
        self
    }

    /// Set the transient-RNG seed.
    pub fn seeded(mut self, seed: u64) -> FaultPlan {
        self.seed = seed;
        self
    }

    /// Check every schedule invariant against a fleet of `n_shards`.
    pub fn validate(&self, n_shards: usize) -> Result<(), DeployError> {
        let bad = |msg: String| Err(DeployError::Builder(msg));
        for w in self.shard_events.windows(2) {
            if w[1].at_cycles < w[0].at_cycles {
                return bad(format!(
                    "fault plan: shard events not sorted by at_cycles ({} after {})",
                    w[1].at_cycles, w[0].at_cycles
                ));
            }
        }
        for w in self.link_events.windows(2) {
            if w[1].at_cycles < w[0].at_cycles {
                return bad(format!(
                    "fault plan: link events not sorted by at_cycles ({} after {})",
                    w[1].at_cycles, w[0].at_cycles
                ));
            }
        }
        // replay the shard schedule: indices in range, strict
        // crash/recover alternation per shard, and the fleet never left
        // permanently empty
        let mut down = vec![false; n_shards];
        let mut n_down = 0usize;
        for ev in &self.shard_events {
            if ev.shard >= n_shards {
                return bad(format!(
                    "fault plan: shard {} out of range for a fleet of {n_shards}",
                    ev.shard
                ));
            }
            match ev.kind {
                ShardFault::Crash => {
                    if down[ev.shard] {
                        return bad(format!(
                            "fault plan: shard {} crashes at cycle {} while already down",
                            ev.shard, ev.at_cycles
                        ));
                    }
                    down[ev.shard] = true;
                    n_down += 1;
                }
                ShardFault::Recover => {
                    if !down[ev.shard] {
                        return bad(format!(
                            "fault plan: shard {} recovers at cycle {} while already up",
                            ev.shard, ev.at_cycles
                        ));
                    }
                    down[ev.shard] = false;
                    n_down -= 1;
                }
            }
        }
        if n_shards > 0 && n_down == n_shards {
            return bad("fault plan: final state leaves every shard down — \
                        the fleet could never drain"
                .into());
        }
        for ev in &self.link_events {
            if ev.level >= LEVEL_NAMES.len() {
                return bad(format!(
                    "fault plan: link level {} out of range (0..{})",
                    ev.level,
                    LEVEL_NAMES.len()
                ));
            }
            match ev.kind {
                LinkFault::Degrade { slowdown } => {
                    if slowdown == 0 {
                        return bad(format!(
                            "fault plan: degrade at cycle {} needs slowdown >= 1",
                            ev.at_cycles
                        ));
                    }
                }
                LinkFault::Outage { until_cycles } => {
                    if until_cycles <= ev.at_cycles {
                        return bad(format!(
                            "fault plan: outage at cycle {} must end after it starts \
                             (until_cycles {until_cycles})",
                            ev.at_cycles
                        ));
                    }
                }
            }
        }
        Ok(())
    }

    /// Parse a plan from its JSON text form (schema in the module doc).
    pub fn from_json(text: &str) -> Result<FaultPlan, DeployError> {
        let bad = |msg: String| DeployError::Builder(msg);
        let j = Json::parse(text)
            .map_err(|e| bad(format!("fault plan: {e}")))?;
        let obj = j.as_obj().ok_or_else(|| bad("fault plan: top level must be an object".into()))?;
        for key in obj.keys() {
            if !matches!(key.as_str(), "seed" | "transient_ppm" | "shard_events" | "link_events") {
                return Err(bad(format!("fault plan: unknown field {key:?}")));
            }
        }
        let u64_field = |j: &Json, field: &str, what: &str| -> Result<u64, DeployError> {
            j.get(field)
                .and_then(Json::as_f64)
                .filter(|n| *n >= 0.0 && n.fract() == 0.0)
                .map(|n| n as u64)
                .ok_or_else(|| bad(format!("fault plan: {what} needs integer {field:?}")))
        };

        let mut plan = FaultPlan::empty();
        if let Some(s) = j.get("seed") {
            plan.seed = s
                .as_f64()
                .filter(|n| *n >= 0.0 && n.fract() == 0.0)
                .map(|n| n as u64)
                .ok_or_else(|| bad("fault plan: \"seed\" must be a non-negative integer".into()))?;
        }
        if let Some(p) = j.get("transient_ppm") {
            plan.transient_ppm = p
                .as_f64()
                .filter(|n| *n >= 0.0 && n.fract() == 0.0 && *n <= 1_000_000.0)
                .map(|n| n as u32)
                .ok_or_else(|| {
                    bad("fault plan: \"transient_ppm\" must be an integer in 0..=1000000".into())
                })?;
        }
        if let Some(events) = j.get("shard_events") {
            let arr = events
                .as_arr()
                .ok_or_else(|| bad("fault plan: \"shard_events\" must be an array".into()))?;
            for ev in arr {
                let at_cycles = u64_field(ev, "at_cycles", "shard event")?;
                let shard = ev
                    .get("shard")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| bad("fault plan: shard event needs \"shard\"".into()))?;
                let kind = match ev.get("kind").and_then(Json::as_str) {
                    Some("crash") => ShardFault::Crash,
                    Some("recover") => ShardFault::Recover,
                    other => {
                        return Err(bad(format!(
                            "fault plan: shard event kind must be \"crash\" or \"recover\", \
                             got {other:?}"
                        )))
                    }
                };
                plan.shard_events.push(ShardEvent { at_cycles, shard, kind });
            }
        }
        if let Some(events) = j.get("link_events") {
            let arr = events
                .as_arr()
                .ok_or_else(|| bad("fault plan: \"link_events\" must be an array".into()))?;
            for ev in arr {
                let at_cycles = u64_field(ev, "at_cycles", "link event")?;
                let name = ev
                    .get("level")
                    .and_then(Json::as_str)
                    .ok_or_else(|| bad("fault plan: link event needs \"level\"".into()))?;
                let level = LEVEL_NAMES
                    .iter()
                    .position(|n| *n == name)
                    .ok_or_else(|| {
                        bad(format!(
                            "fault plan: link level must be one of {LEVEL_NAMES:?}, got {name:?}"
                        ))
                    })?;
                let kind = match ev.get("kind").and_then(Json::as_str) {
                    Some("degrade") => {
                        LinkFault::Degrade { slowdown: u64_field(ev, "slowdown", "degrade event")? }
                    }
                    Some("outage") => LinkFault::Outage {
                        until_cycles: u64_field(ev, "until_cycles", "outage event")?,
                    },
                    other => {
                        return Err(bad(format!(
                            "fault plan: link event kind must be \"degrade\" or \"outage\", \
                             got {other:?}"
                        )))
                    }
                };
                plan.link_events.push(LinkEvent { at_cycles, level, kind });
            }
        }
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_composes_a_valid_plan() {
        let p = FaultPlan::empty()
            .crash(100, 1)
            .degrade_link(150, 1, 4)
            .recover(900, 1)
            .link_outage(1000, 2, 2000)
            .transient(250)
            .seeded(7);
        assert!(!p.is_empty());
        assert_eq!(p.shard_events.len(), 2);
        assert_eq!(p.link_events.len(), 2);
        assert_eq!(p.transient_ppm, 250);
        assert_eq!(p.seed, 7);
        p.validate(4).expect("well-formed plan validates");
    }

    #[test]
    fn empty_plan_is_empty_and_always_valid() {
        let p = FaultPlan::default();
        assert!(p.is_empty());
        p.validate(1).unwrap();
        p.validate(10_000).unwrap();
    }

    #[test]
    fn validation_rejects_broken_schedules() {
        // unsorted shard events
        let p = FaultPlan::empty().crash(200, 0).recover(100, 0);
        assert!(p.validate(2).is_err());
        // unsorted link events
        let p = FaultPlan::empty().degrade_link(200, 0, 2).degrade_link(100, 0, 1);
        assert!(p.validate(2).is_err());
        // shard out of range
        assert!(FaultPlan::empty().crash(0, 2).validate(2).is_err());
        // double crash without a recover in between
        assert!(FaultPlan::empty().crash(0, 0).crash(10, 0).validate(2).is_err());
        // recover of a shard that never crashed
        assert!(FaultPlan::empty().recover(0, 0).validate(2).is_err());
        // every shard left down forever
        assert!(FaultPlan::empty().crash(0, 0).crash(0, 1).validate(2).is_err());
        // …but the same schedule is fine if someone comes back
        FaultPlan::empty().crash(0, 0).crash(0, 1).recover(50, 0).validate(2).unwrap();
        // link level out of range
        assert!(FaultPlan::empty().degrade_link(0, 3, 2).validate(2).is_err());
        // zero slowdown
        assert!(FaultPlan::empty().degrade_link(0, 0, 0).validate(2).is_err());
        // outage that ends before it starts
        assert!(FaultPlan::empty().link_outage(100, 0, 100).validate(2).is_err());
    }

    #[test]
    fn json_round_trips_the_documented_schema() {
        let text = r#"{
            "seed": 7,
            "transient_ppm": 500,
            "shard_events": [
                {"at_cycles": 100000, "shard": 3, "kind": "crash"},
                {"at_cycles": 900000, "shard": 3, "kind": "recover"}
            ],
            "link_events": [
                {"at_cycles": 200000, "level": "pod", "kind": "degrade", "slowdown": 4},
                {"at_cycles": 400000, "level": "root", "kind": "outage", "until_cycles": 450000}
            ]
        }"#;
        let p = FaultPlan::from_json(text).unwrap();
        let want = FaultPlan::empty()
            .seeded(7)
            .transient(500)
            .crash(100_000, 3)
            .recover(900_000, 3)
            .degrade_link(200_000, 1, 4)
            .link_outage(400_000, 2, 450_000);
        assert_eq!(p, want);
        p.validate(8).unwrap();
    }

    #[test]
    fn json_defaults_every_missing_field() {
        let p = FaultPlan::from_json("{}").unwrap();
        assert_eq!(p, FaultPlan::empty());
        let p = FaultPlan::from_json(r#"{"transient_ppm": 10}"#).unwrap();
        assert_eq!(p, FaultPlan::empty().transient(10));
    }

    #[test]
    fn json_rejects_malformed_plans() {
        assert!(FaultPlan::from_json("[]").is_err());
        assert!(FaultPlan::from_json("{").is_err());
        assert!(FaultPlan::from_json(r#"{"bogus": 1}"#).is_err());
        assert!(FaultPlan::from_json(r#"{"seed": -1}"#).is_err());
        assert!(FaultPlan::from_json(r#"{"transient_ppm": 2000000}"#).is_err());
        assert!(FaultPlan::from_json(r#"{"shard_events": [{"at_cycles": 1}]}"#).is_err());
        assert!(FaultPlan::from_json(
            r#"{"shard_events": [{"at_cycles": 1, "shard": 0, "kind": "melt"}]}"#
        )
        .is_err());
        assert!(FaultPlan::from_json(
            r#"{"link_events": [{"at_cycles": 1, "level": "rack", "kind": "degrade", "slowdown": 2}]}"#
        )
        .is_err());
        assert!(FaultPlan::from_json(
            r#"{"link_events": [{"at_cycles": 1, "level": "pod", "kind": "degrade"}]}"#
        )
        .is_err());
    }
}
