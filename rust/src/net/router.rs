//! The [`Router`]: prices request dispatch and weight re-staging DMA
//! over the topology's links, and tracks which shard holds which
//! request class's weights.
//!
//! Two priced paths:
//!
//! - **Dispatch** — a request batch's token payload travels from the
//!   front door on the spine down to the chosen shard:
//!   `Root(pod) → Pod(board) → Board(board)`. Payloads are token ids
//!   (a few hundred bytes), so dispatch traffic is light.
//! - **Re-staging** — when a shard must switch request classes it
//!   fetches the class's weights from the **nearest holder**: a shard
//!   on the same board (board bus only), else one in the same pod
//!   (up and down the board uplinks), else any holder (through the
//!   spine), else the root weight store. Weights are megabytes, so
//!   re-staging dominates interconnect traffic — which is exactly the
//!   traffic locality-aware scheduling avoids.
//!
//! Holder lookups are `BTreeSet::range` probes over the contiguous
//! board/pod shard spans — O(log n) at 10k shards. The router never
//! draws randomness and owns all link state, so it sits inside the
//! serve determinism contract. With a `Flat` topology every path
//! prices to zero delay and no link is touched: the core serve report
//! stays bit-identical to an un-networked fleet.

use std::collections::BTreeSet;

use super::link::{Level, Links};
use super::metrics::{LevelSummary, NetSummary};
use super::topology::Topology;

/// Per-fleet routing state: link occupancy + weight-residency map.
#[derive(Debug, Clone)]
pub struct Router {
    topo: Topology,
    links: Links,
    /// Per class: shards currently holding that class's weights
    /// (busy shards included — their L2 copy is still fetchable).
    holders: Vec<BTreeSet<usize>>,
    /// Per shard: the class its staged weights belong to.
    resident: Vec<Option<usize>>,
    dispatches: u64,
    restages: u64,
    /// Total extra cycles requests waited on re-staging fetch DMA.
    restage_fetch_cycles: u64,
    /// Dispatches that landed on a shard already holding the class.
    locality_hits: u64,
}

impl Router {
    pub fn new(topo: Topology, n_shards: usize, n_classes: usize, wide_axi_bytes: usize) -> Router {
        let links = Links::new(&topo, wide_axi_bytes);
        Router {
            topo,
            links,
            holders: vec![BTreeSet::new(); n_classes],
            resident: vec![None; n_shards],
            dispatches: 0,
            restages: 0,
            restage_fetch_cycles: 0,
            locality_hits: 0,
        }
    }

    pub fn topology(&self) -> &Topology {
        &self.topo
    }

    /// Links per level — the denominators of window utilization.
    pub fn link_counts(&self) -> [u64; 3] {
        self.links.counts()
    }

    /// Cumulative per-level serialization cycles (window metrics diff
    /// consecutive readings).
    pub fn cum_busy(&self) -> [u64; 3] {
        self.links.busy_cycles()
    }

    /// Price a request batch's trip from the spine front door to shard
    /// `dst`, earliest start `at`. Returns the arrival cycle.
    pub fn dispatch_arrival(&mut self, dst: usize, bytes: u64, at: u64) -> u64 {
        if !self.links.any() {
            return at;
        }
        let (pod, board) = (self.topo.pod_of(dst), self.topo.board_of(dst));
        let t = self.links.transfer(Level::Root, pod, bytes, at);
        let t = self.links.transfer(Level::Pod, board, bytes, t);
        self.links.transfer(Level::Board, board, bytes, t)
    }

    /// Nearest shard holding `class`'s weights, by hierarchy distance
    /// from `dst` (same board, then same pod, then anywhere). `None`
    /// means no shard holds them — fetch from the root weight store.
    pub fn nearest_holder(&self, class: usize, dst: usize) -> Option<usize> {
        let h = &self.holders[class];
        if let Some(&s) = h.range(self.topo.board_span(self.topo.board_of(dst))).next() {
            return Some(s);
        }
        if let Some(&s) = h.range(self.topo.pod_span(self.topo.pod_of(dst))).next() {
            return Some(s);
        }
        h.iter().next().copied()
    }

    /// Price re-staging `class`'s weights (`bytes` of DMA) into shard
    /// `dst` from the nearest holder, earliest start `at`. Returns the
    /// cycle the weights land; the shard's local staging cost
    /// (`ServeConstants::switch_cycles`) is charged by the engine on
    /// top, exactly as without a topology.
    pub fn restage_arrival(&mut self, dst: usize, class: usize, bytes: u64, at: u64) -> u64 {
        self.restages += 1;
        if !self.links.any() {
            return at;
        }
        let (pd, bd) = (self.topo.pod_of(dst), self.topo.board_of(dst));
        let arrival = match self.nearest_holder(class, dst) {
            Some(src) => match self.topo.level_between(src, dst) {
                0 => self.links.transfer(Level::Board, bd, bytes, at),
                1 => {
                    let bs = self.topo.board_of(src);
                    let t = self.links.transfer(Level::Board, bs, bytes, at);
                    let t = self.links.transfer(Level::Pod, bs, bytes, t);
                    let t = self.links.transfer(Level::Pod, bd, bytes, t);
                    self.links.transfer(Level::Board, bd, bytes, t)
                }
                _ => {
                    let (ps, bs) = (self.topo.pod_of(src), self.topo.board_of(src));
                    let t = self.links.transfer(Level::Board, bs, bytes, at);
                    let t = self.links.transfer(Level::Pod, bs, bytes, t);
                    let t = self.links.transfer(Level::Root, ps, bytes, t);
                    let t = self.links.transfer(Level::Root, pd, bytes, t);
                    let t = self.links.transfer(Level::Pod, bd, bytes, t);
                    self.links.transfer(Level::Board, bd, bytes, t)
                }
            },
            // cold start: nobody holds the class — root weight store
            None => {
                let t = self.links.transfer(Level::Root, pd, bytes, at);
                let t = self.links.transfer(Level::Pod, bd, bytes, t);
                self.links.transfer(Level::Board, bd, bytes, t)
            }
        };
        self.restage_fetch_cycles += arrival - at;
        arrival
    }

    /// Hop count of the re-stage fetch path [`restage_arrival`] would
    /// price for `class` into `dst` right now: link transfers walked
    /// from the nearest holder (1 same-board, 4 via the pod switch, 6
    /// across the root), or 3 from the root weight store when nobody
    /// holds the class. Read-only — the observability layer stamps it
    /// on `Restaged` events; call it *before* `note_staged` marks the
    /// destination a holder. 0 on linkless (`Flat`) topologies.
    ///
    /// [`restage_arrival`]: Router::restage_arrival
    pub fn restage_hops(&self, class: usize, dst: usize) -> u64 {
        if !self.links.any() {
            return 0;
        }
        match self.nearest_holder(class, dst) {
            Some(src) => match self.topo.level_between(src, dst) {
                0 => 1,
                1 => 4,
                _ => 6,
            },
            None => 3,
        }
    }

    /// Count one dispatched batch; `hit` = the shard already held the
    /// batch's class (no re-staging needed).
    pub fn record_dispatch(&mut self, hit: bool) {
        self.dispatches += 1;
        if hit {
            self.locality_hits += 1;
        }
    }

    /// Fault injection pass-through: degrade one link level's
    /// serialization by an integer factor (`1` restores full speed).
    pub fn set_link_slowdown(&mut self, level: usize, slowdown: u64) {
        self.links.set_slowdown(level, slowdown);
    }

    /// Fault injection pass-through: black out one link level until
    /// `until_cycles` (outage windows max-merge, never shorten).
    pub fn set_link_outage(&mut self, level: usize, until_cycles: u64) {
        self.links.set_outage(level, until_cycles);
    }

    /// Residency change: shard `shard` now holds `class`'s weights
    /// (`None` evicts, e.g. a parked shard powering down its copy).
    pub fn note_staged(&mut self, shard: usize, class: Option<usize>) {
        if let Some(old) = self.resident[shard] {
            self.holders[old].remove(&shard);
        }
        self.resident[shard] = class;
        if let Some(new) = class {
            self.holders[new].insert(shard);
        }
    }

    /// Fold the run's routing activity into a report summary.
    pub fn summary(&self, makespan_cycles: u64) -> NetSummary {
        let counts = self.links.counts();
        let busy = self.links.busy_cycles();
        let transfers = self.links.transfers();
        let bytes = self.links.bytes();
        let energy = self.links.energy_j();
        let levels: Vec<LevelSummary> = (0..3)
            .filter(|&i| counts[i] > 0)
            .map(|i| LevelSummary {
                level: super::link::LEVEL_NAMES[i],
                links: counts[i],
                transfers: transfers[i],
                busy_cycles: busy[i],
                bytes: bytes[i],
                energy_j: energy[i],
                utilization: if makespan_cycles > 0 {
                    busy[i] as f64 / (counts[i] * makespan_cycles) as f64
                } else {
                    0.0
                },
            })
            .collect();
        let energy_j = levels.iter().map(|l| l.energy_j).sum();
        NetSummary {
            topology: self.topo.label(),
            levels,
            dispatches: self.dispatches,
            restages: self.restages,
            restage_fetch_cycles: self.restage_fetch_cycles,
            locality_hits: self.locality_hits,
            locality_rate: if self.dispatches > 0 {
                self.locality_hits as f64 / self.dispatches as f64
            } else {
                0.0
            },
            energy_j,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router() -> Router {
        // 2 pods × 2 boards × 4 clusters = 16 shards, 64 B/cy AXI
        Router::new(Topology::Pod { pods: 2, boards: 2, clusters: 4 }, 16, 2, 64)
    }

    #[test]
    fn flat_routing_is_free_and_linkless() {
        let mut r = Router::new(Topology::Flat, 4, 2, 64);
        assert_eq!(r.dispatch_arrival(3, 512, 1000), 1000);
        assert_eq!(r.restage_arrival(3, 0, 1 << 20, 1000), 1000);
        assert_eq!(r.restage_fetch_cycles, 0);
        assert_eq!(r.restages, 1);
        let s = r.summary(10_000);
        assert_eq!(s.topology, "flat");
        assert!(s.levels.is_empty());
    }

    #[test]
    fn dispatch_descends_root_pod_board() {
        let mut r = router();
        // 512 B: root 512/4=128 cy + 512 lat, pod 32 + 64, board 8 + 8
        let t = r.dispatch_arrival(0, 512, 0);
        assert_eq!(t, (128 + 512) + (32 + 64) + (8 + 8));
        let busy = r.cum_busy();
        assert_eq!(busy, [8, 32, 128]);
    }

    #[test]
    fn nearest_holder_prefers_board_then_pod() {
        let mut r = router();
        r.note_staged(1, Some(0)); // board 0, pod 0
        r.note_staged(5, Some(0)); // board 1, pod 0
        r.note_staged(9, Some(0)); // board 2, pod 1
        assert_eq!(r.nearest_holder(0, 2), Some(1)); // same board wins
        assert_eq!(r.nearest_holder(0, 6), Some(5)); // its own board's holder
        r.note_staged(5, Some(1)); // retag shard 5: class 0 leaves board 1
        assert_eq!(r.nearest_holder(0, 6), Some(1)); // same pod, other board
        assert_eq!(r.nearest_holder(0, 12), Some(9)); // pod 1 holder
        assert_eq!(r.nearest_holder(1, 12), Some(5)); // cross-pod fallback
        r.note_staged(5, None);
        r.note_staged(1, None);
        r.note_staged(9, None);
        assert_eq!(r.nearest_holder(0, 6), None); // root store
    }

    #[test]
    fn restage_cost_grows_with_hierarchy_distance() {
        let bytes = 1 << 16; // 64 KiB of weights
        // same board: board bus only
        let mut a = router();
        a.note_staged(1, Some(0));
        let near = a.restage_arrival(2, 0, bytes, 0);
        // same pod: up and down the board uplinks
        let mut b = router();
        b.note_staged(5, Some(0));
        let mid = b.restage_arrival(2, 0, bytes, 0);
        // cross pod: through the spine
        let mut c = router();
        c.note_staged(9, Some(0));
        let far = c.restage_arrival(2, 0, bytes, 0);
        // cold: root weight store (descend-only path)
        let mut d = router();
        let cold = d.restage_arrival(2, 0, bytes, 0);
        assert!(near < mid, "board {near} !< pod {mid}");
        assert!(mid < far, "pod {mid} !< cross-pod {far}");
        assert!(cold < far, "root store {cold} !< cross-pod {far}");
        assert_eq!(a.restage_fetch_cycles, near);
    }

    #[test]
    fn summary_counts_and_rates() {
        let mut r = router();
        r.record_dispatch(false);
        r.record_dispatch(true);
        r.record_dispatch(true);
        r.dispatch_arrival(0, 512, 0);
        let s = r.summary(100_000);
        assert_eq!(s.dispatches, 3);
        assert_eq!(s.locality_hits, 2);
        assert!((s.locality_rate - 2.0 / 3.0).abs() < 1e-12);
        assert_eq!(s.levels.len(), 3);
        assert_eq!(s.levels[0].level, "board");
        assert_eq!(s.levels[0].links, 4);
        assert_eq!(s.levels[2].links, 2);
        assert!(s.levels.iter().all(|l| l.utilization > 0.0 && l.utilization < 1.0));
        // one 512 B dispatch crossed every level once
        assert!(s.levels.iter().all(|l| l.bytes == 512));
        let expect: f64 = (512.0 * 2.0 + 512.0 * 10.0 + 512.0 * 40.0) * 1e-12;
        assert_eq!(s.energy_j.to_bits(), expect.to_bits());
    }

    #[test]
    fn link_faults_route_through_the_router() {
        let mut r = router();
        r.set_link_slowdown(super::super::link::Level::Root as usize, 8);
        let degraded = r.dispatch_arrival(0, 512, 0);
        // healthy: (128+512)+(32+64)+(8+8); root ser ×8 adds 128·7
        assert_eq!(degraded, (128 * 8 + 512) + (32 + 64) + (8 + 8));
        r.set_link_slowdown(super::super::link::Level::Root as usize, 1);
        r.set_link_outage(super::super::link::Level::Pod as usize, 10_000);
        let blocked = r.dispatch_arrival(0, 512, 0);
        // root leg lands at 640 + 128 (contention), pod leg waits for
        // cycle 10_000, board follows immediately after
        assert!(blocked >= 10_000 + 32 + 64, "outage must gate the pod hop");
    }
}
