//! Hierarchical fleet topology + deterministic interconnect model.
//!
//! Today's `serve::Fleet` is N shards with a free interconnect; this
//! module makes the network a first-class costed layer so fleets can
//! scale to the tinyML-swarm sizes (10k clusters) the paper's template
//! implies:
//!
//! - [`Topology`] — cluster → board → pod hierarchy (plus the
//!   degenerate [`Topology::Flat`]) with a contiguous shard → position
//!   mapping that keeps every locality query O(log n).
//! - [`Links`] / [`Level`] — per-level bandwidth/latency constants
//!   derived from the cluster's wide AXI width, with deterministic
//!   per-link busy-until contention (integer cycles, no wall clock).
//! - [`Router`] — prices request dispatch (spine → shard) and weight
//!   re-staging DMA (nearest holder → shard) over real links, and
//!   tracks per-class weight residency for locality queries.
//! - [`NetSummary`] / [`LevelSummary`] — the per-level interconnect
//!   metrics attached to `ServeReport` (and, per window, to
//!   `WindowSnapshot.net_util`).
//!
//! Attach a topology with `Fleet::with_topology` (CLI:
//! `serve --topology pod:PxBxC`); pair it with the locality-aware
//! scheduler wrapper (`serve::LocalityAware`, CLI `--locality`) to
//! route dispatches at shards that already hold the class's weights.
//! A `Flat` topology prices every path to zero and is propchecked
//! bit-identical to a fleet with no topology at all
//! (`tests/serve_equivalence.rs`); see DESIGN.md §11 for the link
//! model and the determinism contract.

pub mod link;
pub mod metrics;
pub mod router;
pub mod topology;

pub use link::{level_specs, Level, LinkSpec, Links, LEVEL_NAMES};
pub use metrics::{LevelSummary, NetSummary};
pub use router::Router;
pub use topology::Topology;
