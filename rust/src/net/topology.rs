//! Fleet topology: where each shard sits in the interconnect hierarchy.
//!
//! A [`Topology`] assigns every shard (cluster) a position in a
//! cluster → board → pod tree. Shard ids map **contiguously**:
//! shard `s` lives on board `s / clusters_per_board` (global board
//! index) inside pod `s / (boards_per_pod · clusters_per_board)`.
//! Contiguity is what keeps every locality query O(log n): the shards
//! of one board (or pod) form a contiguous id range, so "is there a
//! weight holder on this board?" is a single `BTreeSet::range` probe.
//!
//! `Flat` is the degenerate single-board topology: every shard is
//! local to every other and no links exist, so a `Flat` fleet is
//! bit-identical to a fleet with no topology attached at all
//! (propchecked in `tests/serve_equivalence.rs`).

use std::ops::Range;

/// Hierarchy position of a fleet's shards. See the module docs for the
/// contiguous shard → (board, pod) mapping.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Topology {
    /// One board holding every shard; no links, zero network cost.
    Flat,
    /// `pods` pods × `boards` boards/pod × `clusters` clusters/board.
    Pod { pods: usize, boards: usize, clusters: usize },
}

impl Topology {
    /// Parse a CLI/explore topology spec: `flat` or `pod:PxBxC`
    /// (e.g. `pod:2x4x8` = 2 pods of 4 boards of 8 clusters).
    pub fn parse(s: &str) -> Option<Topology> {
        if s == "flat" {
            return Some(Topology::Flat);
        }
        let spec = s.strip_prefix("pod:")?;
        let mut it = spec.split('x');
        let pods: usize = it.next()?.parse().ok()?;
        let boards: usize = it.next()?.parse().ok()?;
        let clusters: usize = it.next()?.parse().ok()?;
        if it.next().is_some() || pods == 0 || boards == 0 || clusters == 0 {
            return None;
        }
        Some(Topology::Pod { pods, boards, clusters })
    }

    /// Maximum shard count the hierarchy can seat (`None` = unbounded).
    pub fn capacity(&self) -> Option<usize> {
        match self {
            Topology::Flat => None,
            Topology::Pod { pods, boards, clusters } => Some(pods * boards * clusters),
        }
    }

    /// Canonical spec string (`parse(label())` round-trips).
    pub fn label(&self) -> String {
        match self {
            Topology::Flat => "flat".to_string(),
            Topology::Pod { pods, boards, clusters } => {
                format!("pod:{pods}x{boards}x{clusters}")
            }
        }
    }

    /// Shards per board (usize::MAX for `Flat`: one all-holding board).
    fn board_width(&self) -> usize {
        match self {
            Topology::Flat => usize::MAX,
            Topology::Pod { clusters, .. } => *clusters,
        }
    }

    /// Shards per pod.
    fn pod_width(&self) -> usize {
        match self {
            Topology::Flat => usize::MAX,
            Topology::Pod { boards, clusters, .. } => boards * clusters,
        }
    }

    /// Global board index of a shard.
    pub fn board_of(&self, shard: usize) -> usize {
        match self {
            Topology::Flat => 0,
            Topology::Pod { .. } => shard / self.board_width(),
        }
    }

    /// Pod index of a shard.
    pub fn pod_of(&self, shard: usize) -> usize {
        match self {
            Topology::Flat => 0,
            Topology::Pod { .. } => shard / self.pod_width(),
        }
    }

    /// Contiguous shard-id range of a global board index.
    pub fn board_span(&self, board: usize) -> Range<usize> {
        match self {
            Topology::Flat => 0..usize::MAX,
            Topology::Pod { .. } => {
                let w = self.board_width();
                board * w..(board + 1) * w
            }
        }
    }

    /// Contiguous shard-id range of a pod.
    pub fn pod_span(&self, pod: usize) -> Range<usize> {
        match self {
            Topology::Flat => 0..usize::MAX,
            Topology::Pod { .. } => {
                let w = self.pod_width();
                pod * w..(pod + 1) * w
            }
        }
    }

    /// Total boards in the hierarchy (1 for `Flat`).
    pub fn n_boards(&self) -> usize {
        match self {
            Topology::Flat => 1,
            Topology::Pod { pods, boards, .. } => pods * boards,
        }
    }

    /// Total pods in the hierarchy (1 for `Flat`).
    pub fn n_pods(&self) -> usize {
        match self {
            Topology::Flat => 1,
            Topology::Pod { pods, .. } => *pods,
        }
    }

    /// Hierarchy distance between two shards: 0 = same board,
    /// 1 = same pod (board-to-board hop), 2 = cross-pod.
    pub fn level_between(&self, a: usize, b: usize) -> usize {
        if self.board_of(a) == self.board_of(b) {
            0
        } else if self.pod_of(a) == self.pod_of(b) {
            1
        } else {
            2
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_and_rejects_garbage() {
        assert_eq!(Topology::parse("flat"), Some(Topology::Flat));
        let t = Topology::parse("pod:2x4x8").unwrap();
        assert_eq!(t, Topology::Pod { pods: 2, boards: 4, clusters: 8 });
        assert_eq!(Topology::parse(&t.label()), Some(t));
        assert_eq!(Topology::parse(&Topology::Flat.label()), Some(Topology::Flat));
        for bad in ["pod:0x4x8", "pod:2x4", "pod:2x4x8x1", "ring:4", "pod:ax2x2", ""] {
            assert!(Topology::parse(bad).is_none(), "{bad} parsed");
        }
    }

    #[test]
    fn contiguous_shard_mapping() {
        let t = Topology::Pod { pods: 2, boards: 4, clusters: 8 };
        assert_eq!(t.capacity(), Some(64));
        assert_eq!(t.n_boards(), 8);
        assert_eq!(t.n_pods(), 2);
        // shard 0..8 on board 0 / pod 0; shard 32 opens pod 1
        assert_eq!(t.board_of(0), 0);
        assert_eq!(t.board_of(7), 0);
        assert_eq!(t.board_of(8), 1);
        assert_eq!(t.pod_of(31), 0);
        assert_eq!(t.pod_of(32), 1);
        assert_eq!(t.board_span(1), 8..16);
        assert_eq!(t.pod_span(1), 32..64);
        // distances
        assert_eq!(t.level_between(0, 7), 0);
        assert_eq!(t.level_between(0, 8), 1);
        assert_eq!(t.level_between(0, 32), 2);
    }

    #[test]
    fn flat_is_one_all_holding_board() {
        let t = Topology::Flat;
        assert_eq!(t.capacity(), None);
        assert_eq!(t.board_of(123_456), 0);
        assert_eq!(t.pod_of(123_456), 0);
        assert_eq!(t.level_between(0, 123_456), 0);
        assert!(t.board_span(0).contains(&123_456));
    }
}
