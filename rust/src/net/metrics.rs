//! Interconnect summaries attached to serve reports.

/// One link level's traffic over a run (or window).
#[derive(Debug, Clone, PartialEq)]
pub struct LevelSummary {
    /// Level name: `"board"`, `"pod"` or `"root"`.
    pub level: &'static str,
    /// Links at this level (board buses / board uplinks / pod uplinks).
    pub links: u64,
    /// Transfers that crossed this level.
    pub transfers: u64,
    /// Total serialization cycles across the level's links.
    pub busy_cycles: u64,
    /// Bytes moved across this level.
    pub bytes: u64,
    /// Transfer energy at this level in joules
    /// (`bytes · ENERGY_PJ_PER_BYTE[level] · 1e-12`).
    pub energy_j: f64,
    /// `busy_cycles / (links · makespan)` — mean level occupancy.
    pub utilization: f64,
}

/// Interconnect block of a [`ServeReport`]: per-level utilization plus
/// routing/locality counters. Present whenever the fleet has a
/// topology attached; `Flat` runs carry an empty `levels` list and
/// zero fetch cycles (the bit-identity contract).
///
/// [`ServeReport`]: crate::serve::ServeReport
#[derive(Debug, Clone, PartialEq)]
pub struct NetSummary {
    /// Topology spec label (`"flat"`, `"pod:2x4x8"`).
    pub topology: String,
    /// Per-level traffic, leaf to spine; empty for `Flat`.
    pub levels: Vec<LevelSummary>,
    /// Dispatched batches priced through the router.
    pub dispatches: u64,
    /// Weight re-stagings (class switches + post-wake restages).
    pub restages: u64,
    /// Total cycles dispatches waited on weight-fetch DMA.
    pub restage_fetch_cycles: u64,
    /// Dispatches that landed on a shard already holding the class.
    pub locality_hits: u64,
    /// `locality_hits / dispatches` (0.0 when nothing dispatched).
    pub locality_rate: f64,
    /// Total interconnect transfer energy (sum of per-level
    /// `energy_j`). Folded into `ServeReport::energy_j` whenever the
    /// topology has links; exactly 0.0 for `Flat`.
    pub energy_j: f64,
}
