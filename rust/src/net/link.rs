//! Deterministic link-contention model: per-link busy-until cycle
//! tracking, no wall clock.
//!
//! A [`Topology::Pod`] hierarchy has three link levels, each derived
//! from the cluster's wide AXI port width (`ClusterConfig::
//! wide_axi_bytes`, the same constant `ServeConstants::switch_cycles`
//! prices weight re-staging DMA with):
//!
//! | level   | one link per    | bandwidth        | latency  |
//! |---------|-----------------|------------------|----------|
//! | `Board` | board (bus)     | `wide_axi` B/cy  |   8 cy   |
//! | `Pod`   | board (uplink)  | `wide_axi/4`     |  64 cy   |
//! | `Root`  | pod (uplink)    | `wide_axi/16`    | 512 cy   |
//!
//! A transfer of `bytes` over a link serializes for
//! `ceil(bytes / bw)` cycles starting at `max(at, busy_until)`, then
//! lands `latency` cycles later; multi-hop paths are store-and-forward
//! (each hop starts when the previous one lands). Everything is
//! integer cycle arithmetic on state owned by the router, so identical
//! transfer sequences always price identically — the network sits
//! inside the serve determinism contract.
//!
//! [`Topology::Pod`]: super::Topology

use super::topology::Topology;

/// Link levels, leaf to spine. `LEVELS[i].0` names index `i` in every
/// per-level metrics vector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Level {
    /// Intra-board bus: shard ↔ shard on one board, and the last hop
    /// of every inbound path.
    Board = 0,
    /// Board ↔ pod-switch uplink.
    Pod = 1,
    /// Pod ↔ spine uplink (the front door requests arrive through).
    Root = 2,
}

/// Level names in index order (`Level as usize`).
pub const LEVEL_NAMES: [&str; 3] = ["board", "pod", "root"];

/// Bandwidth/latency of one link level.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LinkSpec {
    /// Bytes moved per cycle once serialization starts.
    pub bw_bytes_per_cycle: u64,
    /// Propagation latency added after serialization completes.
    pub latency_cycles: u64,
}

/// Per-level propagation latencies (cycles).
const LATENCY_CYCLES: [u64; 3] = [8, 64, 512];
/// Per-level bandwidth divisors applied to `wide_axi_bytes`.
const BW_DIVISOR: [u64; 3] = [1, 4, 16];
/// Per-level transfer energy (pJ moved per byte). On-board wires are
/// cheap; each level up crosses longer traces / SerDes and costs more.
pub const ENERGY_PJ_PER_BYTE: [f64; 3] = [2.0, 10.0, 40.0];

/// Derive the three level specs from the cluster's wide AXI width.
pub fn level_specs(wide_axi_bytes: usize) -> [LinkSpec; 3] {
    let base = wide_axi_bytes.max(1) as u64;
    let mut specs = [LinkSpec { bw_bytes_per_cycle: 1, latency_cycles: 0 }; 3];
    for (i, spec) in specs.iter_mut().enumerate() {
        *spec = LinkSpec {
            bw_bytes_per_cycle: (base / BW_DIVISOR[i]).max(1),
            latency_cycles: LATENCY_CYCLES[i],
        };
    }
    specs
}

/// All links of one topology: a busy-until cycle per link, plus
/// cumulative per-level traffic counters.
#[derive(Debug, Clone)]
pub struct Links {
    specs: [LinkSpec; 3],
    /// Busy-until per board bus (`n_boards` entries; empty for Flat).
    board: Vec<u64>,
    /// Busy-until per board→pod uplink (`n_boards` entries).
    pod: Vec<u64>,
    /// Busy-until per pod→spine uplink (`n_pods` entries).
    root: Vec<u64>,
    /// Cycles each level spent serializing, cumulative.
    busy_cycles: [u64; 3],
    /// Transfers per level, cumulative.
    transfers: [u64; 3],
    /// Bytes moved per level, cumulative (prices interconnect energy).
    bytes: [u64; 3],
    /// Serialization multiplier per level (fault injection; 1 = healthy).
    slowdown: [u64; 3],
    /// No transfer at a level may start before this cycle (fault
    /// injection outage window; 0 = no outage).
    blocked_until: [u64; 3],
}

impl Links {
    /// Build the link set for a topology. `Flat` has no links.
    pub fn new(topo: &Topology, wide_axi_bytes: usize) -> Links {
        let (n_boards, n_pods) = match topo {
            Topology::Flat => (0, 0),
            Topology::Pod { .. } => (topo.n_boards(), topo.n_pods()),
        };
        Links {
            specs: level_specs(wide_axi_bytes),
            board: vec![0; n_boards],
            pod: vec![0; n_boards],
            root: vec![0; n_pods],
            busy_cycles: [0; 3],
            transfers: [0; 3],
            bytes: [0; 3],
            slowdown: [1; 3],
            blocked_until: [0; 3],
        }
    }

    /// Whether the topology has any links at all (false for `Flat`).
    pub fn any(&self) -> bool {
        !self.board.is_empty()
    }

    /// Links per level (`[boards, boards, pods]`; zeros for `Flat`).
    pub fn counts(&self) -> [u64; 3] {
        [self.board.len() as u64, self.pod.len() as u64, self.root.len() as u64]
    }

    /// Cumulative serialization cycles per level.
    pub fn busy_cycles(&self) -> [u64; 3] {
        self.busy_cycles
    }

    /// Cumulative transfers per level.
    pub fn transfers(&self) -> [u64; 3] {
        self.transfers
    }

    /// Cumulative bytes moved per level.
    pub fn bytes(&self) -> [u64; 3] {
        self.bytes
    }

    /// Transfer energy per level in joules:
    /// `bytes · ENERGY_PJ_PER_BYTE · 1e-12`.
    pub fn energy_j(&self) -> [f64; 3] {
        let mut e = [0.0; 3];
        for i in 0..3 {
            e[i] = self.bytes[i] as f64 * ENERGY_PJ_PER_BYTE[i] * 1e-12;
        }
        e
    }

    /// Fault injection: multiply this level's serialization time by
    /// `slowdown` for all future transfers (`1` restores full speed).
    pub fn set_slowdown(&mut self, level: usize, slowdown: u64) {
        self.slowdown[level] = slowdown.max(1);
    }

    /// Fault injection: block all transfers at this level until
    /// `until_cycles`. Outage windows only ever extend (max-merge), so
    /// overlapping plan events compose deterministically.
    pub fn set_outage(&mut self, level: usize, until_cycles: u64) {
        self.blocked_until[level] = self.blocked_until[level].max(until_cycles);
    }

    /// Spec of one level.
    pub fn spec(&self, level: Level) -> LinkSpec {
        self.specs[level as usize]
    }

    /// Move `bytes` over link `idx` of `level`, earliest start `at`.
    /// Returns the arrival cycle and advances the link's busy-until.
    pub fn transfer(&mut self, level: Level, idx: usize, bytes: u64, at: u64) -> u64 {
        let lvl = level as usize;
        let spec = self.specs[lvl];
        let ser = bytes.div_ceil(spec.bw_bytes_per_cycle).max(1) * self.slowdown[lvl];
        let blocked = self.blocked_until[lvl];
        let busy = match level {
            Level::Board => &mut self.board[idx],
            Level::Pod => &mut self.pod[idx],
            Level::Root => &mut self.root[idx],
        };
        let start = at.max(*busy).max(blocked);
        *busy = start + ser;
        self.busy_cycles[lvl] += ser;
        self.transfers[lvl] += 1;
        self.bytes[lvl] += bytes;
        start + ser + spec.latency_cycles
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pod_links() -> Links {
        Links::new(&Topology::Pod { pods: 2, boards: 2, clusters: 4 }, 64)
    }

    #[test]
    fn specs_derive_from_wide_axi() {
        let s = level_specs(64);
        assert_eq!(s[Level::Board as usize].bw_bytes_per_cycle, 64);
        assert_eq!(s[Level::Pod as usize].bw_bytes_per_cycle, 16);
        assert_eq!(s[Level::Root as usize].bw_bytes_per_cycle, 4);
        assert!(s[0].latency_cycles < s[1].latency_cycles);
        assert!(s[1].latency_cycles < s[2].latency_cycles);
        // degenerate widths still give a usable (1 B/cy) link
        assert_eq!(level_specs(0)[Level::Root as usize].bw_bytes_per_cycle, 1);
    }

    #[test]
    fn transfer_serializes_and_adds_latency() {
        let mut l = pod_links();
        // 128 B over a 64 B/cy board bus: 2 cycles + 8 latency
        assert_eq!(l.transfer(Level::Board, 0, 128, 100), 110);
        assert_eq!(l.busy_cycles()[0], 2);
        assert_eq!(l.transfers()[0], 1);
        // zero-byte transfers still occupy one cycle (header beat)
        assert_eq!(l.transfer(Level::Board, 1, 0, 0), 9);
    }

    #[test]
    fn contention_queues_on_busy_until() {
        let mut l = pod_links();
        // first transfer holds the bus until cycle 102
        assert_eq!(l.transfer(Level::Board, 0, 128, 100), 110);
        // a second transfer asking for cycle 100 waits for the bus:
        // starts at 102, serializes 2, lands at 112
        assert_eq!(l.transfer(Level::Board, 0, 128, 100), 112);
        // a different board's bus is free
        assert_eq!(l.transfer(Level::Board, 1, 128, 100), 110);
        assert_eq!(l.busy_cycles()[0], 6);
    }

    #[test]
    fn bytes_and_energy_accumulate_per_level() {
        let mut l = pod_links();
        l.transfer(Level::Board, 0, 1000, 0);
        l.transfer(Level::Root, 0, 500, 0);
        assert_eq!(l.bytes(), [1000, 0, 500]);
        let e = l.energy_j();
        assert_eq!(e[0].to_bits(), (1000.0 * 2.0e-12f64).to_bits());
        assert_eq!(e[1].to_bits(), 0.0f64.to_bits());
        assert_eq!(e[2].to_bits(), (500.0 * 40.0e-12f64).to_bits());
    }

    #[test]
    fn slowdown_multiplies_serialization_and_restores() {
        let mut l = pod_links();
        l.set_slowdown(Level::Board as usize, 4);
        // 128 B at 64 B/cy is 2 cy healthy, 8 cy degraded: 100+8+8
        assert_eq!(l.transfer(Level::Board, 0, 128, 100), 116);
        assert_eq!(l.busy_cycles()[0], 8);
        l.set_slowdown(Level::Board as usize, 1);
        assert_eq!(l.transfer(Level::Board, 1, 128, 100), 110);
        // slowdown 0 clamps to 1 (a "0×" link is a plan bug, not a hang)
        l.set_slowdown(Level::Pod as usize, 0);
        assert_eq!(l.transfer(Level::Pod, 0, 16, 0), 1 + 64);
    }

    #[test]
    fn outage_defers_start_and_max_merges() {
        let mut l = pod_links();
        l.set_outage(Level::Board as usize, 500);
        // an earlier (stale) outage never shortens the window
        l.set_outage(Level::Board as usize, 200);
        assert_eq!(l.transfer(Level::Board, 0, 128, 100), 500 + 2 + 8);
        // after the window, transfers start on time again
        assert_eq!(l.transfer(Level::Board, 1, 128, 600), 610);
    }

    #[test]
    fn flat_has_no_links() {
        let l = Links::new(&Topology::Flat, 64);
        assert!(!l.any());
        assert_eq!(l.counts(), [0, 0, 0]);
    }
}
