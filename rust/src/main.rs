//! attn-tinyml CLI — the L3 leader entrypoint.
//!
//! Subcommands:
//!   table1              reproduce the paper's Table I (all networks)
//!   simulate            one network/target: latency, energy, utilization
//!   serve               multi-request serving on a cluster fleet
//!   trace               generate seeded multi-tenant arrival traces (CSV/JSONL)
//!   explore             design-space exploration: Pareto frontier over the template
//!   micro               microbenchmarks (Section V-A): GEMM + attention
//!   verify              golden-check the runtime backend vs the rust ITA model
//!   deploy              show the deployment artifacts (tiling, memory)
//!   export              dump a model graph as ONNX-like JSON
//!
//! Examples:
//!   attn-tinyml table1
//!   attn-tinyml simulate --model mobilebert --target ita
//!   attn-tinyml simulate --model dinov2s --freq-mhz 500 --banks 64
//!   attn-tinyml serve --requests 64 --arrival-rate 200 --clusters 4 --scheduler batch
//!   attn-tinyml serve --requests 1000000 --arrival-rate 50000 --clusters 8 --scheduler batch --burst 8
//!   attn-tinyml serve --arrival diurnal --requests 20000 --clusters 4 --control slo-dvfs --slo-p99-ms 10 --metrics-out windows.jsonl
//!   attn-tinyml trace gen --rows 10000 --skew --out trace.csv
//!   attn-tinyml serve --trace trace.csv --clusters 2 --scheduler wfq
//!   attn-tinyml serve --help
//!   attn-tinyml explore --space default --strategy halving --budget 16 --seed 7
//!   attn-tinyml explore --space full --strategy halving --budget 24 --objectives gopj,mm2
//!   attn-tinyml verify --artifacts artifacts
//!   attn-tinyml deploy --model dinov2s

use attn_tinyml::coordinator;
use attn_tinyml::deeploy::Target;
use attn_tinyml::fault::FaultPlan;
use attn_tinyml::explore::{
    explore, explore_json, DesignSpace, ExploreConfig, Objective, Strategy,
};
use attn_tinyml::models;
use attn_tinyml::net::Topology;
use attn_tinyml::obs::{self, ObsConfig};
use attn_tinyml::pipeline::Pipeline;
use attn_tinyml::runtime::{Runtime, RuntimeError, TensorIn};
use attn_tinyml::serve::{
    admission_by_name, control_by_name, scheduler_by_name, Controller, FaultConfig,
    RequestClass, StaticNominal, WindowSnapshot, Workload, DEFAULT_BURST_PERIOD_S,
    DEFAULT_DIURNAL_PERIOD_S,
};
use attn_tinyml::sim::{ClusterConfig, Cmd, Engine, Step};
use attn_tinyml::trace::{
    generate, skewed_two_tenant, symmetric, write_csv, write_jsonl, TraceFormat,
};
use attn_tinyml::util::cli::Args;
use attn_tinyml::util::json::Json;

type Result<T> = std::result::Result<T, RuntimeError>;

const SUBCOMMANDS: [&str; 9] = [
    "table1", "simulate", "serve", "trace", "explore", "micro", "verify", "deploy",
    "export",
];

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv, &SUBCOMMANDS);
    match args.subcommand.as_deref() {
        Some("table1") => cmd_table1(),
        Some("simulate") => cmd_simulate(&args),
        Some("serve") => cmd_serve(&args),
        Some("trace") => cmd_trace(&args),
        Some("explore") => cmd_explore(&args),
        Some("micro") => cmd_micro(),
        Some("verify") => cmd_verify(&args),
        Some("deploy") => cmd_deploy(&args),
        Some("export") => cmd_export(&args),
        _ => {
            eprintln!("usage: attn-tinyml <{}> [--flags]", SUBCOMMANDS.join("|"));
            eprintln!("       see README.md for details");
            Ok(())
        }
    }
}

/// Strict `--seed` parsing: a malformed seed is a usage error, never a
/// silent fall-back to the default draw.
fn seed_flag(args: &Args, default: u64) -> Result<u64> {
    match args.flag("seed") {
        Some(raw) => raw.parse::<u64>().map_err(|_| {
            RuntimeError::Usage(format!(
                "--seed expects an unsigned integer, got {raw:?}"
            ))
        }),
        None => Ok(default),
    }
}

fn model_flag(args: &Args) -> Result<&'static models::ModelConfig> {
    let name = args.flag_or("model", "mobilebert");
    models::by_name(&name).ok_or_else(|| {
        RuntimeError::Usage(format!(
            "unknown model {name}; available: {}",
            models::ALL_MODELS.iter().map(|m| m.name).collect::<Vec<_>>().join(", ")
        ))
    })
}

fn target_flag(args: &Args) -> Target {
    match args.flag_or("target", "ita").as_str() {
        "multicore" | "mc" => Target::MultiCore,
        _ => Target::MultiCoreIta,
    }
}

/// Request-class universe from `--model` / `--layers`: `mix` (the
/// default) compiles all three evaluation networks as classes 0..2,
/// a single model name compiles one class. Shared by `serve` (request
/// pricing) and `trace gen` (per-class seq-len column).
fn classes_flag(args: &Args, layers: usize) -> Result<Vec<RequestClass>> {
    match args.flag_or("model", "mix").as_str() {
        "mix" => {
            Ok(models::ALL_MODELS.iter().map(|m| RequestClass::new(m, layers)).collect())
        }
        name => {
            let cfg = models::by_name(name).ok_or_else(|| {
                RuntimeError::Usage(format!(
                    "unknown model {name}; available: mix, {}",
                    models::ALL_MODELS
                        .iter()
                        .map(|m| m.name)
                        .collect::<Vec<_>>()
                        .join(", ")
                ))
            })?;
            Ok(vec![RequestClass::new(cfg, layers)])
        }
    }
}

/// Cluster geometry from CLI flags: the paper's default, with the
/// frequency (and TCDM banking) overridable so reports derive from the
/// geometry actually simulated.
fn cluster_flag(args: &Args) -> Result<ClusterConfig> {
    let mut cluster = ClusterConfig::default();
    if let Some(raw) = args.flag("freq-mhz") {
        let mhz: f64 = raw.parse().map_err(|_| {
            RuntimeError::Usage(format!("--freq-mhz expects a number, got {raw:?}"))
        })?;
        if !mhz.is_finite() || mhz <= 0.0 {
            return Err(RuntimeError::Usage(format!(
                "--freq-mhz must be a positive frequency, got {mhz}"
            )));
        }
        cluster.freq_hz = mhz * 1e6;
    }
    if let Some(raw) = args.flag("banks") {
        let banks: usize = raw.parse().map_err(|_| {
            RuntimeError::Usage(format!("--banks expects an integer, got {raw:?}"))
        })?;
        if banks == 0 {
            return Err(RuntimeError::Usage("--banks must be >= 1".to_string()));
        }
        cluster.tcdm_bank_bytes = cluster.l1_bytes() / banks;
        cluster.tcdm_banks = banks;
    }
    Ok(cluster)
}

fn cmd_table1() -> Result<()> {
    println!("{}", coordinator::table1().render());
    Ok(())
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let cfg = model_flag(args)?;
    let target = target_flag(args);
    let layers = args.flag_usize("layers", 1);
    let compiled = Pipeline::new(cluster_flag(args)?)
        .model(cfg)
        .target(target)
        .layers(layers)
        .compile()?;
    let r = compiled.simulate();
    println!("model        : {} ({})", r.model, r.target_name());
    println!("GOp/inf      : {:.2}", cfg.gop_per_inference);
    // the frequency label derives from the geometry actually simulated
    println!(
        "latency      : {:.2} ms ({} cycles @ {:.0} MHz)",
        r.seconds * 1e3,
        r.cycles,
        r.freq_hz / 1e6
    );
    println!("throughput   : {:.1} GOp/s", r.gops);
    println!("energy       : {:.2} mJ/inf  ({:.0} GOp/J)", r.mj_per_inf, r.gopj);
    println!("power        : {:.1} mW", r.power_w * 1e3);
    println!("inference/s  : {:.2}", r.inf_per_s);
    println!("ITA util     : {:.1} %  (duty {:.1} %)", r.ita_utilization * 100.0, r.ita_duty * 100.0);
    println!("L1 peak      : {} B (tile buffers)", r.l1_peak_bytes);
    println!("L2 activat.  : {} B (static arena)", r.l2_activation_bytes);
    Ok(())
}

/// Multi-request serving on a fleet of clusters.
///
/// Flags: --requests N (64), --arrival-rate RPS (200), --clusters N (1),
/// --scheduler fifo|rr|batch (fifo), --model mix|<name> (mix = all three
/// networks), --layers N (1), --seed S, --arrival poisson|bursty|diurnal,
/// --burst FACTOR (implies bursty; square-wave bursty Poisson with a
/// 20 ms period), --control static|slo-dvfs with --slo-p99-ms,
/// --metrics-out PATH (JSONL of per-window snapshots), --topology
/// flat|pod:PxBxC (price dispatch + weight re-staging over the
/// interconnect), --locality (steer batches at weight-holding
/// shards), --faults PLAN.json with --deadline-ms / --admission /
/// --max-retries (deterministic fault injection + graceful
/// degradation), --events-out/--profile/--sample (structured event
/// tracing, cycle-attribution profiling and Chrome-trace/JSONL
/// export), plus the usual geometry flags. `--requests` takes million-scale counts: arrivals
/// stream lazily from the seeded PRNG (nothing is materialized upfront)
/// and the report adds host-side simulation throughput. `--help` prints
/// this.
const SERVE_HELP: &str = "\
usage: attn-tinyml serve [--flags]

multi-request serving on a fleet of identical clusters

  --requests N        requests to offer (default 64). Million-scale
                      counts are fine: arrivals stream lazily from the
                      seeded PRNG, nothing is materialized upfront, and
                      queue memory stays proportional to the backlog
  --arrival-rate RPS  open-loop Poisson arrival rate (default 200)
  --arrival KIND      poisson | bursty | diurnal (default poisson;
                      diurnal modulates the rate by a slow sinusoid)
  --burst FACTOR      square-wave bursty Poisson: on-half of each 20 ms
                      period at rate*FACTOR, off-half at rate/FACTOR
                      (implies --arrival bursty)
  --depth D           diurnal modulation depth in [0, 1) (default 0.8)
  --period-ms MS      diurnal sinusoid period (default 500)
  --trace PATH        replay a multi-tenant arrival trace (CSV or JSONL,
                      see `attn-tinyml trace --help`) instead of a
                      synthetic arrival shape; --requests/--arrival-rate
                      are ignored, tenants come from the trace rows
  --clusters N        fleet size (default 1)
  --scheduler S       fifo | rr | batch | wfq | drf (default fifo;
                      wfq = per-tenant weighted-fair queueing, drf =
                      dominant-share fairness — both matter under
                      multi-tenant traces)
  --model M           mix = all three evaluation networks (default),
                      or one of mobilebert | dinov2s | whisper_tiny_enc
  --layers N          encoder blocks per request class (default 1)
  --seed S            workload seed (default 48879)
  --freq-mhz F        cluster clock (default 425)
  --banks N           TCDM banking (default 32)
  --control C         online control plane: static | slo-dvfs (off by
                      default). slo-dvfs holds the p99 SLO at minimum
                      J/request via DVFS over the FD-SOI operating
                      points plus shard parking, deciding every 10 ms
                      of simulated time
  --slo-p99-ms MS     p99 latency SLO for slo-dvfs (default 10)
  --metrics-out PATH  stream windowed metrics snapshots as JSON lines
                      (attaches the static controller if --control is
                      not given, so windows exist to record)
  --topology T        flat, or pod:PxBxC — place the fleet in a
                      cluster -> board -> pod hierarchy and price
                      request dispatch and weight re-staging DMA over
                      per-level links with deterministic contention.
                      flat keeps today's free interconnect but adds the
                      net block to the report; the fleet must fit
                      P*B*C shards
  --locality          wrap the scheduler in locality-aware steering:
                      each batch prefers a free shard already holding
                      its class's weights, falling back by hierarchy
                      distance (board, then pod, then anywhere)
  --faults PATH       JSON fault plan (schema in src/fault/): scheduled
                      shard crash/recover with weight-residency loss,
                      per-level link degrade/outage (needs --topology),
                      and a seeded transient-failure rate. the same
                      seed + plan replays bit-identically
  --deadline-ms MS    per-attempt queueing deadline: a request still
                      queued MS after admission is dropped and counted
                      as expired (default: none)
  --admission P       admit-all | threshold[:D] | tenant-fair[:D] —
                      shed fresh arrivals once the queue holds D
                      entries (default depth 256); tenant-fair sheds
                      only tenants at/above their fair share of the
                      backlog. retries bypass admission
  --max-retries N     dispatch attempts allowed after the first for
                      crash-killed or transiently-failed requests, with
                      exponential backoff between attempts (default 3)
  --events-out PATH   record the structured lifecycle event stream and
                      write it after the run: .jsonl streams one
                      versioned JSON object per event, anything else
                      gets the Chrome trace_event document (open in
                      chrome://tracing or ui.perfetto.dev). attaching
                      the recorder never changes the report: it is
                      write-only and propcheck-held bit-identical
  --profile           print the cycle-attribution block (per-request
                      span totals, per-shard busy/idle/parked/
                      transition conservation) and attach the recorder
                      if --events-out did not already
  --sample N          deterministic request sampling: keep per-request
                      events for ids with splitmix64(seed ^ id) % N ==
                      0 (default 1 = every request). fleet-level
                      events (crash/recover/park/wake/DVFS) are always
                      kept; span totals stay exact at any rate

the report includes latency percentiles (exact up to 8192 served
requests, log2-linear histogram with sub-1% relative error beyond),
time-weighted queue depth, host-side simulation throughput, and — when
a controller is attached — the per-window control timeline with the
energy saved against the static-nominal baseline. multi-tenant runs
add a per-tenant table (served, req/s, p50/p99, dominant share) and
Jain's fairness index over delivered throughput; topology runs add the
interconnect block (per-level utilization, bytes/energy, re-staging
traffic and the locality hit rate); fault runs add the degraded block
(availability, shed/expired/failed-over counts — offered == served +
shed + expired by exact count); observed runs (--events-out /
--profile) add the observability block and can export the event
stream for timeline UIs
";

/// One metrics window as a compact JSON object (one `--metrics-out`
/// line). Cycle quantities stay integral; f64 metrics serialize with
/// Rust's shortest-roundtrip formatting, so the line is reproducible
/// bit-for-bit from the seed. Stamped with
/// [`obs::WINDOWS_SCHEMA_VERSION`] (line formats: DESIGN.md §13).
fn window_json(w: &WindowSnapshot) -> Json {
    Json::obj(vec![
        ("schema_version", Json::num(obs::WINDOWS_SCHEMA_VERSION as f64)),
        ("window", Json::num(w.index as f64)),
        ("start_cycles", Json::num(w.start_cycles as f64)),
        ("end_cycles", Json::num(w.end_cycles as f64)),
        ("completed", Json::num(w.completed as f64)),
        ("p50_cycles", Json::num(w.p50_cycles as f64)),
        ("p99_cycles", Json::num(w.p99_cycles as f64)),
        ("utilization", Json::num(w.utilization)),
        ("mean_queue_depth", Json::num(w.mean_queue_depth)),
        ("queue_depth", Json::num(w.queue_depth as f64)),
        ("active_j", Json::num(w.active_j)),
        ("op_index", Json::num(w.op_index as f64)),
        ("parked", Json::num(w.parked as f64)),
        ("shards_down", Json::num(w.shards_down as f64)),
        (
            "tenant_completed",
            Json::Arr(w.tenant_completed.iter().map(|&c| Json::num(c as f64)).collect()),
        ),
        (
            "net_util",
            Json::Arr(w.net_util.iter().map(|&u| Json::num(u)).collect()),
        ),
    ])
}

fn cmd_serve(args: &Args) -> Result<()> {
    if args.has("help") {
        print!("{SERVE_HELP}");
        return Ok(());
    }
    let cluster = cluster_flag(args)?;
    let target = target_flag(args);
    let requests = args.flag_usize("requests", 64);
    let clusters = args.flag_usize("clusters", 1);
    let rate = args.flag_f64("arrival-rate", 200.0);
    let layers = args.flag_usize("layers", 1);
    let seed = seed_flag(args, 48879)?;
    let sched_name = args.flag_or("scheduler", "fifo");
    let mut sched = scheduler_by_name(&sched_name).ok_or_else(|| {
        RuntimeError::Usage(format!(
            "unknown scheduler {sched_name}; available: fifo, rr, batch, wfq, drf"
        ))
    })?;
    let classes = classes_flag(args, layers)?;
    let arrival_default = if args.has("burst") { "bursty" } else { "poisson" };
    let workload = if let Some(path) = args.flag("trace") {
        Workload::trace_file(classes, std::path::PathBuf::from(path))?
    } else {
        match args.flag_or("arrival", arrival_default).as_str() {
            "poisson" => Workload::poisson(classes, rate, requests, seed),
            "bursty" => {
                let factor = match args.flag("burst") {
                    Some(raw) => raw.parse::<f64>().map_err(|_| {
                        RuntimeError::Usage(format!(
                            "--burst expects a number, got {raw:?}"
                        ))
                    })?,
                    None => 8.0,
                };
                Workload::bursty(
                    classes,
                    rate,
                    factor,
                    DEFAULT_BURST_PERIOD_S,
                    requests,
                    seed,
                )
            }
            "diurnal" => {
                let depth = args.flag_f64("depth", 0.8);
                let period_s =
                    args.flag_f64("period-ms", DEFAULT_DIURNAL_PERIOD_S * 1e3) / 1e3;
                Workload::diurnal(classes, rate, depth, period_s, requests, seed)
            }
            other => {
                return Err(RuntimeError::Usage(format!(
                    "unknown arrival kind {other}; available: poisson, bursty, diurnal"
                )))
            }
        }
    };
    let slo_ms = args.flag_f64("slo-p99-ms", 10.0);
    let slo_cycles = (slo_ms / 1e3 * cluster.freq_hz).round() as u64;
    let metrics_out = args.flag("metrics-out").map(str::to_string);
    let controller: Option<Box<dyn Controller>> = match args.flag("control") {
        Some(name) => Some(control_by_name(name, slo_cycles).ok_or_else(|| {
            RuntimeError::Usage(format!(
                "unknown controller {name}; available: static, slo-dvfs"
            ))
        })?),
        // --metrics-out alone still needs windows: attach the no-op
        None if metrics_out.is_some() => Some(Box::new(StaticNominal)),
        None => None,
    };
    // any fault/degradation flag attaches the fault layer; absent all
    // four, the layer is never consulted (bit-identical to pre-fault
    // serving)
    let fault_cfg: Option<FaultConfig> = if args.has("faults")
        || args.has("deadline-ms")
        || args.has("admission")
        || args.has("max-retries")
    {
        let mut cfg = FaultConfig::default();
        if let Some(path) = args.flag("faults") {
            let text = std::fs::read_to_string(path)?;
            cfg.plan = FaultPlan::from_json(&text)?;
        }
        if let Some(name) = args.flag("admission") {
            cfg.admission = admission_by_name(name).ok_or_else(|| {
                RuntimeError::Usage(format!(
                    "unknown admission policy {name}; available: admit-all, \
                     threshold[:depth], tenant-fair[:depth]"
                ))
            })?;
        }
        if args.has("deadline-ms") {
            let ms = args.flag_f64("deadline-ms", 0.0);
            if !ms.is_finite() || ms < 0.0 {
                return Err(RuntimeError::Usage(format!(
                    "--deadline-ms must be a non-negative duration, got {ms}"
                )));
            }
            cfg.deadline_cycles = Some((ms / 1e3 * cluster.freq_hz).round() as u64);
        }
        if args.has("max-retries") {
            cfg.max_retries =
                args.flag_usize("max-retries", cfg.max_retries as usize) as u32;
        }
        Some(cfg)
    } else {
        None
    };
    let events_out = args.flag("events-out").map(str::to_string);
    let want_profile = args.has("profile");
    let sample_every = args.flag_usize("sample", 1) as u64;
    if sample_every == 0 {
        return Err(RuntimeError::Usage(
            "--sample expects a keep rate of 1 or more (1 = every request)".to_string(),
        ));
    }
    let t0 = std::time::Instant::now();
    let mut pipe = Pipeline::new(cluster).target(target).fleet(clusters);
    if events_out.is_some() || want_profile || args.has("sample") {
        pipe = pipe.observe(ObsConfig { sample_every, ..ObsConfig::default() });
    }
    if let Some(c) = controller {
        pipe = pipe.controller(c);
    }
    if let Some(raw) = args.flag("topology") {
        let topo = Topology::parse(raw).ok_or_else(|| {
            RuntimeError::Usage(format!(
                "--topology expects flat or pod:PxBxC (nonzero dims), got {raw:?}"
            ))
        })?;
        pipe = pipe.topology(topo);
    }
    if args.has("locality") {
        pipe = pipe.locality(true);
    }
    if let Some(cfg) = fault_cfg {
        pipe = pipe.faults(cfg);
    }
    let report = pipe.serve_with(&workload, sched.as_mut())?;
    let host_s = t0.elapsed().as_secs_f64();
    print!("{}", coordinator::render_serve_with_host(&report, host_s));
    // diagnostics go to stderr: stdout stays a clean report for pipes
    if let Some(warn) = coordinator::render_serve_warning(&report) {
        eprintln!("{warn}");
    }
    if let Some(path) = events_out {
        if path.ends_with(".jsonl") {
            let lines = obs::events_jsonl(&report).expect("events-out attaches the recorder");
            std::fs::write(&path, lines)?;
        } else {
            let doc = obs::chrome_trace(&report).expect("events-out attaches the recorder");
            std::fs::write(&path, doc.to_string_pretty())?;
        }
        let p = report.profile.as_ref().expect("events-out attaches the recorder");
        println!(
            "wrote {} events ({} ring-dropped, sampled 1/{}) to {path}",
            p.recorded_events(),
            p.dropped_events,
            p.sample_every.max(1)
        );
    }
    if let Some(path) = metrics_out {
        let summary = report.control.as_ref().expect("metrics-out attaches a controller");
        let mut lines = String::new();
        for w in &summary.windows {
            lines.push_str(&window_json(w).to_string());
            lines.push('\n');
        }
        std::fs::write(&path, lines)?;
        println!("wrote {} window snapshots to {path}", summary.windows.len());
    }
    Ok(())
}

/// Seeded multi-tenant trace generation.
const TRACE_HELP: &str = "\
usage: attn-tinyml trace gen [--flags]

generate a seeded, deterministic multi-tenant arrival trace — serving
runs and CI never need external datacenter data. rows are
`cycle,tenant,class,seq_len`, non-decreasing in cycle; replay with
`attn-tinyml serve --trace PATH --scheduler wfq`

  --out PATH      output file (default trace.csv; a .jsonl/.ndjson/.json
                  extension writes JSON lines, anything else CSV)
  --rows N        rows to generate (default 10000)
  --tenants N     symmetric tenants with equal arrival weights
                  (default 2; must be >= 1)
  --skew          two tenants at 9:1 arrival weights instead of
                  symmetric — the fairness benchmark's overload shape
  --rate RPS      aggregate arrival rate across tenants (default 2000;
                  must be a positive finite rate)
  --model M       mix (default) or one model name: defines the class
                  universe the rows draw from
  --layers N      encoder blocks per request class (default 1)
  --seed S        generator seed (default 48879)

the same (flags, seed) always writes a byte-identical file
";

fn cmd_trace(args: &Args) -> Result<()> {
    if args.has("help") {
        print!("{TRACE_HELP}");
        return Ok(());
    }
    if args.positional.first().map(String::as_str) != Some("gen") {
        return Err(RuntimeError::Usage(
            "trace expects the `gen` action; try \
             `attn-tinyml trace gen --rows 10000 --skew --out trace.csv` \
             (or trace --help)"
                .to_string(),
        ));
    }
    let rows = args.flag_usize("rows", 10_000);
    let rate = args.flag_f64("rate", 2_000.0);
    // a zero/negative rate would put every row at cycle 0 (or hang the
    // inter-arrival draw); zero tenants would generate an empty weight
    // vector. Both are usage errors, never silent defaults.
    if rate <= 0.0 || !rate.is_finite() {
        return Err(RuntimeError::Usage(format!(
            "--rate must be a positive finite arrival rate, got {rate}"
        )));
    }
    let n_tenants = args.flag_usize("tenants", 2);
    if n_tenants == 0 {
        return Err(RuntimeError::Usage(
            "--tenants must be >= 1: a trace needs at least one tenant issuing \
             requests"
                .to_string(),
        ));
    }
    let seed = seed_flag(args, 48879)?;
    let layers = args.flag_usize("layers", 1);
    let classes = classes_flag(args, layers)?;
    let class_seq: Vec<usize> = classes.iter().map(|c| c.bucket()).collect();
    let spec = if args.has("skew") {
        skewed_two_tenant(rows, rate, &class_seq, seed)
    } else {
        symmetric(rows, n_tenants, rate, &class_seq, seed)
    };
    let tenants = spec.tenant_weights.len();
    let entries = generate(spec)?;
    let out = args.flag_or("out", "trace.csv");
    let path = std::path::Path::new(&out);
    let mut buf = Vec::new();
    match TraceFormat::from_path(path) {
        TraceFormat::Csv => write_csv(&mut buf, entries.iter().copied())?,
        TraceFormat::Jsonl => write_jsonl(&mut buf, entries.iter().copied())?,
    }
    std::fs::write(path, &buf)?;
    println!(
        "wrote {} rows ({} tenants, {} classes) to {out}",
        entries.len(),
        tenants,
        class_seq.len()
    );
    Ok(())
}

/// Design-space exploration over the architectural template.
const EXPLORE_HELP: &str = "\
usage: attn-tinyml explore [--flags]

deterministic design-space exploration: sweep the template (geometry,
FD-SOI operating point, deployment and serving knobs), evaluate every
candidate through the cached pipeline + serving layers, and report the
Pareto frontier. A fixed --seed reproduces the run (and the JSON it
writes) bit-for-bit.

  --space S           default | tiny | mix | full (default: default)
  --strategy S        grid | random | halving (default: halving)
  --budget N          candidates promoted to full serving evaluation
                      (default 16; halving screens up to 4x this)
  --objectives CSV    any of gopj,gops,p99,mm2 (default: all four)
  --seed N            sampling + workload seed (default 48879)
  --requests N        override the space's per-evaluation request count
  --arrival-rate RPS  override the space's arrival rate
  --threads N         evaluation fan-out (default: host parallelism)
  --out PATH          JSON record (default BENCH_explore.json)

the frontier table flags the paper's published silicon (8+1 cores,
32-bank 128 KiB, N=16/M=64 ITA at 0.65 V / 425 MHz) when it is
non-dominated, and the paper-anchor line reports its screening metrics
against the published 154 GOp/s / 2960 GOp/J / 0.991 mm2
";

fn cmd_explore(args: &Args) -> Result<()> {
    if args.has("help") {
        print!("{EXPLORE_HELP}");
        return Ok(());
    }
    let space_name = args.flag_or("space", "default");
    let mut space = DesignSpace::preset(&space_name).ok_or_else(|| {
        RuntimeError::Usage(format!(
            "unknown space {space_name}; available: default, tiny, mix, full"
        ))
    })?;
    if args.has("requests") {
        space.serve.requests = args.flag_usize("requests", space.serve.requests);
    }
    if args.has("arrival-rate") {
        space.serve.rate_rps = args.flag_f64("arrival-rate", space.serve.rate_rps);
    }
    let strategy_name = args.flag_or("strategy", "halving");
    let strategy = Strategy::by_name(&strategy_name).ok_or_else(|| {
        RuntimeError::Usage(format!(
            "unknown strategy {strategy_name}; available: grid, random, halving"
        ))
    })?;
    let objectives = match args.flag("objectives") {
        Some(csv) => Objective::parse_list(csv).map_err(RuntimeError::Usage)?,
        None => Objective::ALL.to_vec(),
    };
    let cfg = ExploreConfig {
        strategy,
        budget: args.flag_usize("budget", 16),
        seed: seed_flag(args, 48879)?,
        objectives,
        threads: args.flag_usize("threads", 0),
    };
    let t0 = std::time::Instant::now();
    let result = explore(&space, &cfg)
        .map_err(|e| RuntimeError::Usage(format!("explore failed: {e}")))?;
    let host_s = t0.elapsed().as_secs_f64();
    if result.frontier.is_empty() {
        return Err(RuntimeError::Usage(
            "explore produced an empty frontier: every candidate was infeasible \
             for the workload (try a larger geometry axis or fewer layers)"
                .to_string(),
        ));
    }
    print!("{}", coordinator::render_explore(&result));
    let evaluated = (result.screened + result.evaluated).max(1);
    println!(
        "host wall    : {host_s:.3} s for {evaluated} evaluations \
         ({:.1} cand/s)",
        evaluated as f64 / host_s.max(1e-9)
    );
    let out = args.flag_or("out", "BENCH_explore.json");
    let mut doc = explore_json(&space, &result);
    // host timing joins the written record CLI-side only — the
    // explore_json document itself stays a pure function of the seed
    // (benches/explore_pareto asserts bit-identical serialization)
    if let Json::Obj(map) = &mut doc {
        map.insert(
            "host".to_string(),
            Json::obj(vec![
                ("wall_seconds", Json::num(host_s)),
                (
                    "candidates_per_s",
                    Json::num(evaluated as f64 / host_s.max(1e-9)),
                ),
            ]),
        );
    }
    std::fs::write(&out, doc.to_string_pretty())?;
    println!("\nwrote {out}");
    Ok(())
}

fn cmd_micro() -> Result<()> {
    let cluster = ClusterConfig::default();
    let engine = Engine::new(cluster.clone());
    // GEMM micro (paper Section V-A)
    let tile_bytes = 2 * 64 * 64 + 64 * 3 + 64 * 64;
    let mut steps = vec![Step::new(Cmd::DmaIn { rows: 512, row_bytes: tile_bytes }, vec![])];
    for i in 0..256usize {
        let dep = steps.len() - 1;
        steps.push(Step::new(Cmd::ItaGemm { m: 512, k: 512, n: 512 }, vec![dep]));
        if i + 1 < 256 {
            steps.push(Step::new(Cmd::DmaIn { rows: 512, row_bytes: tile_bytes }, vec![dep]));
        }
    }
    let s = engine.run(&steps);
    let e = attn_tinyml::energy::evaluate(&s, cluster.freq_hz);
    println!("GEMM  (ITA) : {:.0} GOp/s  {:.2} TOp/J  util {:.1}%", e.gops, e.gopj / 1e3, s.ita_utilization() * 100.0);

    let attn_steps = |n: usize| -> Vec<Step> {
        (0..n)
            .map(|i| {
                let deps = if i == 0 { vec![] } else { vec![i - 1] };
                Step::new(Cmd::ItaAttention { s_q: 512, s_kv: 512, p: 64 }, deps)
            })
            .collect()
    };
    let s = engine.run(&attn_steps(64));
    let e = attn_tinyml::energy::evaluate(&s, cluster.freq_hz);
    println!("Attn  (ITA) : {:.0} GOp/s  {:.2} TOp/J  util {:.1}%", e.gops, e.gopj / 1e3, s.ita_utilization() * 100.0);

    let engine_sa = Engine::standalone(cluster.clone());
    let s = engine_sa.run(&attn_steps(64));
    println!("Attn (standalone accelerator): util {:.1}%", s.ita_utilization() * 100.0);

    let steps = vec![Step::new(
        Cmd::Core { kind: attn_tinyml::sim::CoreOp::GemmI8, elems: 1 << 26 },
        vec![],
    )];
    let s = engine.run(&steps);
    let e = attn_tinyml::energy::evaluate(&s, cluster.freq_hz);
    println!("GEMM (multi-core SW): {:.2} GOp/s  {:.1} GOp/J  {:.1} mW", e.gops, e.gopj, e.avg_power_w * 1e3);
    Ok(())
}

fn cmd_verify(args: &Args) -> Result<()> {
    let dir = args.flag_or("artifacts", "artifacts");
    let path = std::path::Path::new(&dir);
    let on_disk = path.join("manifest.json").exists();
    // an explicitly named artifacts dir must exist — silently verifying
    // the built-in manifest instead would be a vacuous pass
    if args.has("artifacts") && !on_disk {
        return Err(RuntimeError::Usage(format!(
            "no manifest.json in {dir}; run `make artifacts`, or omit --artifacts \
             to verify against the built-in reference manifest"
        )));
    }
    let rt = Runtime::new(path)?;
    println!(
        "backend      : {} (AOT artifacts in {dir}: {})",
        rt.backend_name(),
        if on_disk { "yes" } else { "no" }
    );
    verify_all(&rt)
}

/// Golden check: every artifact vs the rust functional model, bit-exact.
fn verify_all(rt: &Runtime) -> Result<()> {
    use attn_tinyml::ita::engine::{gemm_rq, Mat, GELU_S};
    use attn_tinyml::ita::gelu::Act;
    use attn_tinyml::util::prng::XorShift64;

    // GEMM artifacts
    for (name, act) in [("gemm", Act::Identity), ("gemm_relu", Act::Relu), ("gemm_gelu", Act::Gelu)] {
        let entry = &rt.manifest.artifacts[name];
        let (mult, shift) = (entry.rq["mult"] as i32, entry.rq["shift"] as u32);
        let mut rng = XorShift64::new(0xBEEF);
        let x = rng.tensor_i8(128 * 128);
        let w = rng.tensor_i8(128 * 128);
        let b: Vec<i32> = (0..128).map(|_| rng.next_range(-2048, 2048)).collect();
        let got = rt.execute(
            name,
            &[
                TensorIn { data: &x, shape: vec![128, 128] },
                TensorIn { data: &w, shape: vec![128, 128] },
                TensorIn { data: &b, shape: vec![128] },
            ],
        )?;
        // GELU_S names the i-GeLU input scale both the backend and the
        // functional model derive their integer constants from
        let want = gemm_rq(
            &Mat::new(128, 128, x.clone()),
            &Mat::new(128, 128, w.clone()),
            &b,
            mult,
            shift,
            act,
            GELU_S,
        );
        if got[0] != want.data {
            return Err(RuntimeError::Backend(format!(
                "{name}: backend output != rust functional model"
            )));
        }
        println!("{name:>24}: bit-exact ({} values)", want.data.len());
    }

    // attention head
    {
        let entry = &rt.manifest.artifacts["attn_head"];
        let (qkm, qks) = (entry.rq["qk_mult"] as i32, entry.rq["qk_shift"] as u32);
        let (avm, avs) = (entry.rq["av_mult"] as i32, entry.rq["av_shift"] as u32);
        let mut rng = XorShift64::new(0xA77E);
        let q = rng.tensor_i8(128 * 64);
        let k = rng.tensor_i8(128 * 64);
        let v = rng.tensor_i8(128 * 64);
        let got = rt.execute(
            "attn_head",
            &[
                TensorIn { data: &q, shape: vec![128, 64] },
                TensorIn { data: &k, shape: vec![128, 64] },
                TensorIn { data: &v, shape: vec![128, 64] },
            ],
        )?;
        let (o, _, _) = attn_tinyml::ita::engine::attention_head(
            &Mat::new(128, 64, q.clone()),
            &Mat::new(128, 64, k.clone()),
            &Mat::new(128, 64, v.clone()),
            qkm,
            qks,
            avm,
            avs,
        );
        if got[0] != o.data {
            return Err(RuntimeError::Backend(
                "attn_head: backend output != rust functional model".to_string(),
            ));
        }
        println!("{:>24}: bit-exact ({} values)", "attn_head", o.data.len());
    }

    // one full encoder layer per network, through the compile pipeline
    // (the deployment is cached; verify golden-checks the encoder
    // artifact against the rust functional model)
    for cfg in models::ALL_MODELS {
        let compiled = Pipeline::new(ClusterConfig::default())
            .model(cfg)
            .target(Target::MultiCoreIta)
            .layers(1)
            .compile()?;
        let values = compiled.verify(rt)?;
        let name = format!("encoder_{}", cfg.name);
        println!("{name:>24}: bit-exact ({values} values)");
    }
    println!(
        "all artifacts verified: {} backend == rust ITA functional model",
        rt.backend_name()
    );
    Ok(())
}

fn cmd_deploy(args: &Args) -> Result<()> {
    let cfg = model_flag(args)?;
    let target = target_flag(args);
    let layers = args.flag_usize("layers", 1);
    let compiled = Pipeline::new(cluster_flag(args)?)
        .model(cfg)
        .target(target)
        .layers(layers)
        .compile()?;
    print!("{}", compiled.report());
    Ok(())
}

fn cmd_export(args: &Args) -> Result<()> {
    let cfg = model_flag(args)?;
    let layers = args.flag_usize("layers", 1);
    let g = models::build_graph_layers(cfg, layers);
    let json = attn_tinyml::deeploy::onnx::export(&g);
    match args.flag("out") {
        Some(path) => {
            std::fs::write(path, json.to_string_pretty())?;
            println!("wrote {path}");
        }
        None => println!("{}", json.to_string_pretty()),
    }
    Ok(())
}
