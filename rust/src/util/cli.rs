//! Tiny CLI argument parser (clap substitute, offline environment).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, and
//! positional arguments, with generated usage text.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse argv (excluding the program name). `subcommands` lists the
    /// recognized first tokens; anything else is positional.
    pub fn parse(argv: &[String], subcommands: &[&str]) -> Args {
        let mut args = Args {
            subcommand: None,
            flags: BTreeMap::new(),
            positional: Vec::new(),
        };
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if subcommands.contains(&first.as_str()) {
                args.subcommand = Some(it.next().unwrap().clone());
            }
        }
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    args.flags
                        .insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    args.flags
                        .insert(stripped.to_string(), it.next().unwrap().clone());
                } else {
                    args.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                args.positional.push(a.clone());
            }
        }
        args
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn flag_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn flag_usize(&self, name: &str, default: usize) -> usize {
        self.flag(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn flag_f64(&self, name: &str, default: f64) -> f64 {
        self.flag(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_positional() {
        let a = Args::parse(
            &sv(&["simulate", "--model", "mobilebert", "--fast", "pos1", "--k=v"]),
            &["simulate", "deploy"],
        );
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.flag("model"), Some("mobilebert"));
        assert_eq!(a.flag("fast"), Some("pos1")); // greedy value binding
        assert_eq!(a.flag("k"), Some("v"));
    }

    #[test]
    fn boolean_flags_at_end() {
        let a = Args::parse(&sv(&["--verbose"]), &[]);
        assert!(a.has("verbose"));
        assert_eq!(a.flag("verbose"), Some("true"));
    }

    #[test]
    fn typed_accessors() {
        let a = Args::parse(&sv(&["--n", "42", "--x", "1.5"]), &[]);
        assert_eq!(a.flag_usize("n", 0), 42);
        assert_eq!(a.flag_f64("x", 0.0), 1.5);
        assert_eq!(a.flag_usize("missing", 7), 7);
    }

    #[test]
    fn no_subcommand_is_positional() {
        let a = Args::parse(&sv(&["other", "--f"]), &["simulate"]);
        assert_eq!(a.subcommand, None);
        assert_eq!(a.positional, vec!["other".to_string()]);
    }
}
