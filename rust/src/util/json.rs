//! Minimal JSON parser + serializer.
//!
//! The offline build environment vendors only the `xla` crate's dependency
//! closure (no serde), so graph interchange, the artifact manifest, and
//! config files use this hand-rolled implementation. It supports the full
//! JSON grammar except exotic number forms; numbers are kept as f64 with
//! an exact-integer fast path.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use BTreeMap for deterministic ordering.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|n| if n >= 0.0 { Some(n as usize) } else { None })
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access: `j.get("key")`.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Chained path access: `j.path(&["a", "b", "c"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    // -- builders ---------------------------------------------------------

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // -- serializer -------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !a.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if indent.is_some() && !o.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent.unwrap() * depth));
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // decode one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"m": 3, "arr": [1.5, "two", false], "nest": {"x": [-1]}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
        let j3 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j3);
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""éA""#).unwrap();
        assert_eq!(j.as_str(), Some("éA"));
    }

    #[test]
    fn escapes_roundtrip_exactly() {
        // every escape class the serializer emits must survive
        // parse(to_string(v)) — quotes, backslashes, whitespace
        // controls, raw control bytes, and multi-byte UTF-8
        let hairy = "quote:\" backslash:\\ nl:\n cr:\r tab:\t bell:\u{7} nul:\u{0} é➤";
        let v = Json::Obj(
            [
                ("k\"ey".to_string(), Json::Str(hairy.to_string())),
                ("arr".to_string(), Json::Arr(vec![Json::Str("a\\b/c".into()), Json::Null])),
            ]
            .into_iter()
            .collect(),
        );
        let compact = Json::parse(&v.to_string()).unwrap();
        assert_eq!(compact, v);
        let pretty = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(pretty, v);
        // control characters must be emitted as escapes, never raw
        assert!(!v.to_string().contains('\u{7}'));
        assert!(v.to_string().contains("\\u0007"));
    }

    #[test]
    fn deep_nesting_roundtrip() {
        // nested objects inside arrays inside objects, five levels deep
        let src = r#"{"a":{"b":[{"c":[1,[2,[3,{"d":"x\ny"}]]]}],"e":{}},"f":[]}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&j.to_string()).unwrap(), j);
        assert_eq!(Json::parse(&j.to_string_pretty()).unwrap(), j);
        assert_eq!(
            j.path(&["a", "b"]).unwrap().as_arr().unwrap()[0]
                .get("c")
                .unwrap()
                .as_arr()
                .unwrap()
                .len(),
            2
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn integer_exactness() {
        let j = Json::parse("16294208416").unwrap();
        assert_eq!(j.as_i64(), Some(16294208416));
        assert_eq!(j.to_string(), "16294208416");
    }

    #[test]
    fn property_roundtrip_random_values() {
        // random JSON trees: parse(to_string(v)) == v
        use crate::util::propcheck::{check, Config};
        use crate::util::prng::XorShift64;

        fn gen_value(rng: &mut XorShift64, depth: usize) -> Json {
            match if depth == 0 { rng.next_below(4) } else { rng.next_below(6) } {
                0 => Json::Null,
                1 => Json::Bool(rng.next_below(2) == 0),
                2 => Json::Num((rng.next_range(-1_000_000, 1_000_000)) as f64),
                3 => {
                    let n = rng.next_below(8) as usize;
                    Json::Str(
                        (0..n)
                            .map(|_| {
                                let c = rng.next_below(96) as u8 + 32;
                                c as char
                            })
                            .collect(),
                    )
                }
                4 => Json::Arr(
                    (0..rng.next_below(4)).map(|_| gen_value(rng, depth - 1)).collect(),
                ),
                _ => Json::Obj(
                    (0..rng.next_below(4))
                        .map(|i| (format!("k{i}"), gen_value(rng, depth - 1)))
                        .collect(),
                ),
            }
        }

        check(
            Config { cases: 200, seed: 0x2505 },
            |rng: &mut XorShift64| gen_value(rng, 3),
            |_| Vec::new(),
            |v| {
                let compact = Json::parse(&v.to_string())
                    .map_err(|e| format!("compact: {e}"))?;
                if &compact != v {
                    return Err(format!("compact mismatch: {v:?}"));
                }
                let pretty = Json::parse(&v.to_string_pretty())
                    .map_err(|e| format!("pretty: {e}"))?;
                if &pretty != v {
                    return Err(format!("pretty mismatch: {v:?}"));
                }
                Ok(())
            },
        );
    }
}
