//! Minimal property-based testing harness (proptest substitute).
//!
//! The offline environment has no proptest crate, so coordinator/compiler
//! invariants are checked with this harness: a deterministic PRNG drives
//! value generators; on failure the case is re-run with binary-search
//! shrinking over integer parameters and the minimal failing case is
//! reported in the panic message.

use super::prng::XorShift64;

/// Configuration of a property run.
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 100, seed: 0xDEC0DE }
    }
}

/// Run `prop` against `cases` random parameter vectors drawn by `gen`.
///
/// `gen` draws an arbitrary case from the PRNG; `prop` returns Err(msg) on
/// violation. On failure we attempt shrinking via `shrink` (which proposes
/// smaller cases) and panic with the minimal reproduction.
pub fn check<T: Clone + std::fmt::Debug>(
    cfg: Config,
    mut gen: impl FnMut(&mut XorShift64) -> T,
    mut shrink: impl FnMut(&T) -> Vec<T>,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = XorShift64::new(cfg.seed);
    for case_idx in 0..cfg.cases {
        let case = gen(&mut rng);
        if let Err(msg) = prop(&case) {
            // shrink loop: steepest-descent over proposals
            let mut best = case.clone();
            let mut best_msg = msg;
            let mut improved = true;
            let mut rounds = 0;
            while improved && rounds < 200 {
                improved = false;
                rounds += 1;
                for cand in shrink(&best) {
                    if let Err(m) = prop(&cand) {
                        best = cand;
                        best_msg = m;
                        improved = true;
                        break;
                    }
                }
            }
            panic!(
                "property failed (case {case_idx}, seed {:#x}):\n  minimal case: {:?}\n  violation: {}",
                cfg.seed, best, best_msg
            );
        }
    }
}

/// Convenience: property over a single usize in [lo, hi) with halving shrink.
pub fn check_usize(
    cfg: Config,
    lo: usize,
    hi: usize,
    mut prop: impl FnMut(usize) -> Result<(), String>,
) {
    check(
        cfg,
        |rng| lo + rng.next_below((hi - lo) as u64) as usize,
        |&n| {
            // delta-debugging steps: try removing geometrically shrinking
            // amounts so the loop converges in O(log^2) proposals.
            let mut c = Vec::new();
            let mut d = (n - lo) / 2;
            while d > 0 {
                c.push(n - d);
                d /= 2;
            }
            if n > lo {
                c.push(n - 1);
            }
            c
        },
        |&n| prop(n),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check_usize(Config { cases: 50, seed: 1 }, 0, 1000, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "minimal case: 500")]
    fn shrinks_to_minimal_failure() {
        // property fails for n >= 500; shrinker must land exactly on 500
        check_usize(Config { cases: 200, seed: 3 }, 0, 1000, |n| {
            if n >= 500 {
                Err(format!("{n} too big"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn tuple_generator_shrinks() {
        // a passing tuple property exercising the generic path
        check(
            Config { cases: 30, seed: 9 },
            |rng| (rng.next_below(64) as usize, rng.next_below(64) as usize),
            |&(a, b)| {
                let mut c = Vec::new();
                if a > 0 {
                    c.push((a / 2, b));
                }
                if b > 0 {
                    c.push((a, b / 2));
                }
                c
            },
            |&(a, b)| {
                if a + b < 1000 {
                    Ok(())
                } else {
                    Err("unreachable".into())
                }
            },
        );
    }
}
