//! Substrate utilities for the std-only offline environment: JSON, CLI
//! parsing, deterministic PRNGs, and a property-testing harness.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prng;
pub mod propcheck;

/// Format a quantity with engineering suffix (k/M/G/T) for reports.
///
/// The suffix is chosen on the value as it will *print* at three
/// decimals, so boundary values never render as four integer digits:
/// `eng(999.9996)` is `"1.000k"`, not `"1000.000"`. Negative values
/// carry the sign through unchanged.
pub fn eng(v: f64) -> String {
    if !v.is_finite() {
        return format!("{v}");
    }
    const SUFFIXES: [&str; 5] = ["", "k", "M", "G", "T"];
    let mut idx = 0;
    let mut scaled = v;
    while scaled.abs() >= 1e3 && idx + 1 < SUFFIXES.len() {
        scaled /= 1e3;
        idx += 1;
    }
    // rounding to three decimals can push the magnitude to exactly
    // 1000.000 — bump one more tier so the mantissa stays < 1000
    if (scaled.abs() * 1e3).round() >= 1e6 && idx + 1 < SUFFIXES.len() {
        scaled /= 1e3;
        idx += 1;
    }
    format!("{scaled:.3}{}", SUFFIXES[idx])
}

#[cfg(test)]
mod tests {
    use super::eng;

    #[test]
    fn eng_suffixes() {
        assert_eq!(eng(741.0e9), "741.000G");
        assert_eq!(eng(5.42e12), "5.420T");
        assert_eq!(eng(12.0), "12.000");
        assert_eq!(eng(0.0), "0.000");
    }

    #[test]
    fn eng_exact_boundaries() {
        assert_eq!(eng(1e3), "1.000k");
        assert_eq!(eng(1e6), "1.000M");
        assert_eq!(eng(1e9), "1.000G");
        assert_eq!(eng(1e12), "1.000T");
    }

    #[test]
    fn eng_rounding_never_prints_four_integer_digits() {
        // just below each boundary, three-decimal rounding used to
        // produce "1000.000" with no suffix bump
        assert_eq!(eng(999.9996), "1.000k");
        assert_eq!(eng(999.9996e3), "1.000M");
        assert_eq!(eng(999.4), "999.400");
        assert_eq!(eng(999.99949e9), "999.999G");
    }

    #[test]
    fn eng_negative_values() {
        assert_eq!(eng(-12.0), "-12.000");
        assert_eq!(eng(-1e3), "-1.000k");
        assert_eq!(eng(-999.9996), "-1.000k");
        assert_eq!(eng(-741.0e9), "-741.000G");
    }

    #[test]
    fn eng_beyond_tera_saturates_suffix() {
        assert_eq!(eng(5.0e15), "5000.000T");
    }
}
