//! Substrate utilities for the std-only offline environment: JSON, CLI
//! parsing, deterministic PRNGs, and a property-testing harness.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prng;
pub mod propcheck;

/// Format a quantity with engineering suffix (k/M/G/T) for reports.
pub fn eng(v: f64) -> String {
    let (div, suf) = if v.abs() >= 1e12 {
        (1e12, "T")
    } else if v.abs() >= 1e9 {
        (1e9, "G")
    } else if v.abs() >= 1e6 {
        (1e6, "M")
    } else if v.abs() >= 1e3 {
        (1e3, "k")
    } else {
        (1.0, "")
    };
    format!("{:.3}{}", v / div, suf)
}

#[cfg(test)]
mod tests {
    #[test]
    fn eng_suffixes() {
        assert_eq!(super::eng(741.0e9), "741.000G");
        assert_eq!(super::eng(5.42e12), "5.420T");
        assert_eq!(super::eng(12.0), "12.000");
    }
}
