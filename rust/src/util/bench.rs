//! Minimal benchmark harness (criterion substitute, offline environment).
//!
//! `cargo bench` targets use `harness = false` and drive this: each
//! benchmark times a closure over several iterations, reports
//! median/min/max wall time, and prints paper-style result rows.

use std::time::Instant;

/// Timing result of one benchmark case.
#[derive(Debug, Clone)]
pub struct Timing {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

/// Time `f` for `iters` iterations (after one warmup) and report.
pub fn bench<T>(name: &str, iters: usize, mut f: impl FnMut() -> T) -> Timing {
    let _warmup = f();
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        let out = f();
        samples.push(t0.elapsed().as_secs_f64());
        std::hint::black_box(out);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let t = Timing {
        name: name.to_string(),
        iters,
        median_s: samples[samples.len() / 2],
        min_s: samples[0],
        max_s: *samples.last().unwrap(),
    };
    println!(
        "  [wall] {:<40} median {:>9.3} ms  (min {:.3}, max {:.3}, n={})",
        t.name,
        t.median_s * 1e3,
        t.min_s * 1e3,
        t.max_s * 1e3,
        t.iters
    );
    t
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_ordered_stats() {
        let t = bench("noop", 5, || 42);
        assert!(t.min_s <= t.median_s && t.median_s <= t.max_s);
        assert_eq!(t.iters, 5);
    }
}
