//! Deterministic PRNG primitives shared with the Python build path.
//!
//! `splitmix64` is a *pure function of the index*, so synthetic tensors can
//! be generated identically (and in any order) by `python/compile/model.py`
//! and `models::synth_tensor` — the cross-language golden contract.
//! `XorShift64` is a tiny stateful generator for test/bench workloads.

pub const SPLITMIX_GAMMA: u64 = 0x9E3779B97F4A7C15;

/// splitmix64 finalizer — bit-identical to model.splitmix64.
#[inline]
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(SPLITMIX_GAMMA);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// FNV-1a 64-bit string hash — bit-identical to model.fnv1a.
pub fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xCBF29CE484222325;
    for b in s.as_bytes() {
        h = (h ^ (*b as u64)).wrapping_mul(0x100000001B3);
    }
    h
}

/// xorshift64* — fast stateful PRNG for workload generation.
#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    pub fn new(seed: u64) -> Self {
        // avoid the all-zero fixed point
        Self { state: splitmix64(seed) | 1 }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, n).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        self.next_u64() % n.max(1)
    }

    /// Uniform i32 in [lo, hi).
    pub fn next_range(&mut self, lo: i32, hi: i32) -> i32 {
        lo + (self.next_below((hi - lo) as u64) as i32)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// int8-range tensor of length n (as i32 container).
    pub fn tensor_i8(&mut self, n: usize) -> Vec<i32> {
        (0..n).map(|_| self.next_range(-128, 128)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_golden_matches_python() {
        // same constants as python/tests/test_model.py::test_splitmix_golden
        assert_eq!(splitmix64(0), 16294208416658607535);
        assert_eq!(splitmix64(1), 10451216379200822465);
        assert_eq!(splitmix64(2), 10905525725756348110);
        assert_eq!(splitmix64(3), 2092789425003139053);
    }

    #[test]
    fn fnv1a_stable() {
        assert_ne!(fnv1a("a"), fnv1a("b"));
        assert_eq!(fnv1a(""), 0xCBF29CE484222325);
    }

    #[test]
    fn xorshift_deterministic() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn ranges_respected() {
        let mut r = XorShift64::new(7);
        for _ in 0..1000 {
            let v = r.next_range(-128, 128);
            assert!((-128..128).contains(&v));
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn distribution_roughly_uniform() {
        let mut r = XorShift64::new(1);
        let mut counts = [0usize; 16];
        for _ in 0..16000 {
            counts[r.next_below(16) as usize] += 1;
        }
        for c in counts {
            assert!((700..1300).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn seeds_decorrelate() {
        // nearby seeds must not produce overlapping streams (splitmix
        // seeding); identical seeds must (determinism, tested above)
        let a: Vec<u64> = {
            let mut r = XorShift64::new(1);
            (0..64).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = XorShift64::new(2);
            (0..64).map(|_| r.next_u64()).collect()
        };
        assert_ne!(a, b);
        let common = a.iter().filter(|&v| b.contains(v)).count();
        assert_eq!(common, 0, "streams share {common} values");
    }

    #[test]
    fn tensor_i8_distribution_smoke() {
        // int8 tensors drive every synthetic workload: the full value
        // range must appear, both signs roughly balanced, mean near 0
        let mut r = XorShift64::new(0xD157);
        let t = r.tensor_i8(64 * 1024);
        assert!(t.iter().all(|&v| (-128..=127).contains(&v)));
        assert!(t.contains(&-128) && t.contains(&127), "range endpoints missing");
        let neg = t.iter().filter(|&&v| v < 0).count() as f64 / t.len() as f64;
        assert!((0.45..0.55).contains(&neg), "negative fraction {neg}");
        let mean = t.iter().map(|&v| v as f64).sum::<f64>() / t.len() as f64;
        assert!(mean.abs() < 1.5, "mean {mean}");
        // no runaway repetition (a stuck generator repeats one value)
        let first = t[0];
        assert!(t.iter().filter(|&&v| v == first).count() < t.len() / 64);
    }

    #[test]
    fn next_f64_covers_unit_interval() {
        let mut r = XorShift64::new(99);
        let mut lo = 1.0f64;
        let mut hi = 0.0f64;
        let mut sum = 0.0;
        let n = 10_000;
        for _ in 0..n {
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
            lo = lo.min(f);
            hi = hi.max(f);
            sum += f;
        }
        assert!(lo < 0.01 && hi > 0.99, "range [{lo}, {hi}]");
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }
}
