//! Deterministic PRNG primitives shared with the Python build path.
//!
//! `splitmix64` is a *pure function of the index*, so synthetic tensors can
//! be generated identically (and in any order) by `python/compile/model.py`
//! and `models::synth_tensor` — the cross-language golden contract.
//! `XorShift64` is a tiny stateful generator for test/bench workloads.

pub const SPLITMIX_GAMMA: u64 = 0x9E3779B97F4A7C15;

/// splitmix64 finalizer — bit-identical to model.splitmix64.
#[inline]
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(SPLITMIX_GAMMA);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// FNV-1a 64-bit string hash — bit-identical to model.fnv1a.
pub fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xCBF29CE484222325;
    for b in s.as_bytes() {
        h = (h ^ (*b as u64)).wrapping_mul(0x100000001B3);
    }
    h
}

/// xorshift64* — fast stateful PRNG for workload generation.
#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    pub fn new(seed: u64) -> Self {
        // avoid the all-zero fixed point
        Self { state: splitmix64(seed) | 1 }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Uniform in [0, n).
    #[inline]
    pub fn next_below(&mut self, n: u64) -> u64 {
        self.next_u64() % n.max(1)
    }

    /// Uniform i32 in [lo, hi).
    pub fn next_range(&mut self, lo: i32, hi: i32) -> i32 {
        lo + (self.next_below((hi - lo) as u64) as i32)
    }

    /// Uniform f64 in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// int8-range tensor of length n (as i32 container).
    pub fn tensor_i8(&mut self, n: usize) -> Vec<i32> {
        (0..n).map(|_| self.next_range(-128, 128)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_golden_matches_python() {
        // same constants as python/tests/test_model.py::test_splitmix_golden
        assert_eq!(splitmix64(0), 16294208416658607535);
        assert_eq!(splitmix64(1), 10451216379200822465);
        assert_eq!(splitmix64(2), 10905525725756348110);
        assert_eq!(splitmix64(3), 2092789425003139053);
    }

    #[test]
    fn fnv1a_stable() {
        assert_ne!(fnv1a("a"), fnv1a("b"));
        assert_eq!(fnv1a(""), 0xCBF29CE484222325);
    }

    #[test]
    fn xorshift_deterministic() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn ranges_respected() {
        let mut r = XorShift64::new(7);
        for _ in 0..1000 {
            let v = r.next_range(-128, 128);
            assert!((-128..128).contains(&v));
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn distribution_roughly_uniform() {
        let mut r = XorShift64::new(1);
        let mut counts = [0usize; 16];
        for _ in 0..16000 {
            counts[r.next_below(16) as usize] += 1;
        }
        for c in counts {
            assert!((700..1300).contains(&c), "bucket count {c}");
        }
    }
}
