//! The evaluation networks as deployment graphs + synthetic weights.
//!
//! Mirrors `python/compile/model.py`: the same three configs (paper
//! footnotes 4-6), the same requant-parameter derivation, and the same
//! splitmix64-keyed synthetic tensors (bit-identical across languages —
//! pinned by `test_splitmix_golden` on the python side and
//! `prng::tests::splitmix_golden_matches_python` here).
//!
//! The graph builders emit the network the way a quantized ONNX export
//! looks *before* acceleration passes: per-head attention chains with
//! standalone Softmax nodes, LayerNorm/Add on generic operators. The
//! deployment flow (deeploy::passes) then fuses the MHA pattern,
//! head-splits it onto ITA, and maps the rest.

use crate::deeploy::ir::{Activation, DType, Graph, Node, Op, TensorKind};
use crate::util::prng::{fnv1a, splitmix64, SPLITMIX_GAMMA};

/// Geometry of one evaluation network (mirrors model.ModelConfig).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub name: &'static str,
    pub seq: usize,
    pub seq_logical: usize,
    pub emb: usize,
    pub proj: usize,
    pub heads: usize,
    pub layers: usize,
    pub dff: usize,
    pub ffn_stack: usize,
    pub act: Activation,
    /// Paper-reported GOp per inference (footnotes 4-6).
    pub gop_per_inference: f64,
    /// Convolutional stem before the encoder blocks (Whisper: two k=3
    /// Conv1d layers, 80 mel bins -> E channels, second with stride 2).
    pub conv_stem: bool,
}

pub const MOBILEBERT: ModelConfig = ModelConfig {
    name: "mobilebert",
    seq: 128,
    seq_logical: 128,
    emb: 128,
    proj: 64,
    heads: 4,
    layers: 24,
    dff: 512,
    ffn_stack: 4,
    act: Activation::Relu,
    gop_per_inference: 4.74,
    conv_stem: false,
};

pub const DINOV2S: ModelConfig = ModelConfig {
    name: "dinov2s",
    seq: 256,
    seq_logical: 241,
    emb: 384,
    proj: 64,
    heads: 6,
    layers: 12,
    dff: 1536,
    ffn_stack: 1,
    act: Activation::Gelu,
    gop_per_inference: 11.7,
    conv_stem: false,
};

pub const WHISPER_TINY_ENC: ModelConfig = ModelConfig {
    name: "whisper_tiny_enc",
    seq: 512,
    seq_logical: 512,
    emb: 384,
    proj: 64,
    heads: 6,
    layers: 4,
    dff: 1536,
    ffn_stack: 1,
    act: Activation::Gelu,
    gop_per_inference: 9.74,
    conv_stem: true,
};

pub const ALL_MODELS: [&ModelConfig; 3] = [&MOBILEBERT, &DINOV2S, &WHISPER_TINY_ENC];

pub fn by_name(name: &str) -> Option<&'static ModelConfig> {
    ALL_MODELS.iter().find(|c| c.name == name).copied()
}

/// Requant (mult, shift) for a GEMM with reduction dim k — mirrors
/// model.rq_for exactly (same float math, same rounding).
pub fn rq_for(k_dim: usize, target_std: f64) -> (i32, u32) {
    let acc_std = (k_dim as f64).sqrt() * 74.0 * 74.0;
    let ratio = target_std / acc_std;
    let shift = 14u32;
    let mult = ((ratio * (1u64 << shift) as f64).round() as i32).max(1);
    (mult, shift)
}

/// All requant params of one encoder layer — mirrors model.rq_params.
#[derive(Debug, Clone, Copy)]
pub struct RqParams {
    pub q: (i32, u32),
    pub qk: (i32, u32),
    pub av: (i32, u32),
    pub o: (i32, u32),
    pub ffn1: (i32, u32),
    pub ffn2: (i32, u32),
    pub ln: (i32, u32),
}

pub fn rq_params(cfg: &ModelConfig) -> RqParams {
    RqParams {
        q: rq_for(cfg.emb, 30.0),
        qk: rq_for(cfg.proj, 40.0),
        av: rq_for(128, 30.0),
        o: rq_for(cfg.proj * cfg.heads, 30.0),
        ffn1: rq_for(cfg.emb, 30.0),
        ffn2: rq_for(cfg.dff, 30.0),
        ln: (16, 12),
    }
}

// --- synthetic tensors (bit-identical to model.synth_tensor) ----------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SynthKind {
    Weight,
    Bias,
    Gamma,
    Beta,
}

/// Deterministic synthetic tensor: value_i = f(seed, name, i).
pub fn synth_tensor(name: &str, n: usize, kind: SynthKind, seed: u64) -> Vec<i32> {
    let key = fnv1a(name) ^ seed.wrapping_mul(SPLITMIX_GAMMA);
    (0..n as u64)
        .map(|i| {
            let r = splitmix64(i.wrapping_add(key));
            match kind {
                SynthKind::Weight => ((r & 0xFF) as i64 - 128) as i32,
                SynthKind::Bias => ((r & 0xFFF) as i64 - 2048) as i32,
                SynthKind::Gamma => ((r & 0x3F) as i64 + 32) as i32,
                SynthKind::Beta => ((r & 0x1F) as i64 - 16) as i32,
            }
        })
        .collect()
}

/// The synthetic network input — mirrors model.synth_input(seed=1).
pub fn synth_input(cfg: &ModelConfig) -> Vec<i32> {
    synth_tensor(
        &format!("{}/input", cfg.name),
        cfg.seq * cfg.emb,
        SynthKind::Weight,
        1,
    )
}

// --- graph builders ----------------------------------------------------------

/// Build the full deployment graph of a network: `layers` encoder blocks
/// in the unfused per-head form.
pub fn build_graph(cfg: &ModelConfig) -> Graph {
    build_graph_layers(cfg, cfg.layers)
}

/// Build a graph with an overridden layer count (fast tests / sweeps).
/// The conv stem (if any) is included only for the full network — it
/// runs once per inference, unlike the identical encoder blocks.
pub fn build_graph_layers(cfg: &ModelConfig, layers: usize) -> Graph {
    let mut g = Graph::new(cfg.name);
    let mut x = if cfg.conv_stem && layers == cfg.layers {
        build_conv_stem(&mut g, cfg)
    } else {
        g.add_tensor("x0", &[cfg.seq, cfg.emb], DType::I8, TensorKind::Input);
        "x0".to_string()
    };
    for l in 0..layers {
        x = build_layer(&mut g, cfg, l, &x);
    }
    if let Some(t) = g.tensors.get_mut(&x) {
        t.kind = TensorKind::Output;
    }
    g
}

/// The stem as a standalone graph (simulated once by the coordinator).
pub fn build_stem_graph(cfg: &ModelConfig) -> Option<Graph> {
    if !cfg.conv_stem {
        return None;
    }
    let mut g = Graph::new(&format!("{}_stem", cfg.name));
    let out = build_conv_stem(&mut g, cfg);
    if let Some(t) = g.tensors.get_mut(&out) {
        t.kind = TensorKind::Output;
    }
    Some(g)
}

/// Whisper's convolutional stem: mel (2S, 80) -> Conv1d k3 s1 (-> E) ->
/// GeLU -> Conv1d k3 s2 (-> E, S) -> GeLU. Returns the output tensor.
/// Weight tensors use the im2col layout (k*cin, cout) directly.
pub fn build_conv_stem(g: &mut Graph, cfg: &ModelConfig) -> String {
    let (s, e) = (cfg.seq, cfg.emb);
    let t_in = 2 * s; // mel frames before the stride-2 conv
    let c_mel = 80;
    g.add_tensor("mel", &[t_in, c_mel], DType::I8, TensorKind::Input);

    g.add_tensor("stem/w1", &[3 * c_mel, e], DType::I8, TensorKind::Weight);
    g.add_tensor("stem/b1", &[e], DType::I32, TensorKind::Weight);
    g.add_tensor("stem/c1", &[t_in, e], DType::I8, TensorKind::Activation);
    let rq1 = rq_for(3 * c_mel, 30.0);
    g.add_node(
        Node::new(
            "stem/conv1.op",
            Op::Conv1d { kernel: 3, stride: 1 },
            &["mel", "stem/w1", "stem/b1"],
            &["stem/c1"],
        )
        .with_rq(rq1.0, rq1.1),
    );
    g.add_tensor("stem/a1", &[t_in, e], DType::I8, TensorKind::Activation);
    g.add_node(Node::new(
        "stem/gelu1.op",
        Op::Act { act: Activation::Gelu },
        &["stem/c1"],
        &["stem/a1"],
    ));

    g.add_tensor("stem/w2", &[3 * e, e], DType::I8, TensorKind::Weight);
    g.add_tensor("stem/b2", &[e], DType::I32, TensorKind::Weight);
    g.add_tensor("stem/c2", &[s, e], DType::I8, TensorKind::Activation);
    let rq2 = rq_for(3 * e, 30.0);
    g.add_node(
        Node::new(
            "stem/conv2.op",
            Op::Conv1d { kernel: 3, stride: 2 },
            &["stem/a1", "stem/w2", "stem/b2"],
            &["stem/c2"],
        )
        .with_rq(rq2.0, rq2.1),
    );
    g.add_tensor("stem/a2", &[s, e], DType::I8, TensorKind::Activation);
    g.add_node(Node::new(
        "stem/gelu2.op",
        Op::Act { act: Activation::Gelu },
        &["stem/c2"],
        &["stem/a2"],
    ));
    "stem/a2".to_string()
}

/// Append one encoder layer reading tensor `x`; returns the output name.
pub fn build_layer(g: &mut Graph, cfg: &ModelConfig, l: usize, x: &str) -> String {
    let rq = rq_params(cfg);
    let (s, e, p, h) = (cfg.seq, cfg.emb, cfg.proj, cfg.heads);
    let t = |n: &str| format!("L{l}/{n}");

    fn act_t(g: &mut Graph, name: &str, shape: &[usize]) {
        g.add_tensor(name, shape, DType::I8, TensorKind::Activation);
    }
    fn w_t(g: &mut Graph, name: &str, shape: &[usize], dt: DType) {
        g.add_tensor(name, shape, dt, TensorKind::Weight);
    }

    // LayerNorm 1
    w_t(g, &t("ln1_g"), &[e], DType::I8);
    w_t(g, &t("ln1_b"), &[e], DType::I8);
    act_t(g, &t("ln1"), &[s, e]);
    g.add_node(
        Node::new(&t("ln1.op"), Op::LayerNorm, &[x, &t("ln1_g"), &t("ln1_b")], &[&t("ln1")])
            .with_rq(rq.ln.0, rq.ln.1),
    );

    // per-head attention chains (the raw ONNX-ish pattern)
    let mut partials: Vec<String> = Vec::new();
    for hd in 0..h {
        for nm in ["q", "k", "v"] {
            w_t(g, &t(&format!("w{nm}{hd}")), &[e, p], DType::I8);
            w_t(g, &t(&format!("b{nm}{hd}")), &[p], DType::I32);
            act_t(g, &t(&format!("{nm}{hd}")), &[s, p]);
            g.add_node(
                Node::new(
                    &t(&format!("{nm}{hd}.proj")),
                    Op::Gemm { act: Activation::Identity },
                    &[&t("ln1"), &t(&format!("w{nm}{hd}")), &t(&format!("b{nm}{hd}"))],
                    &[&t(&format!("{nm}{hd}"))],
                )
                .with_rq(rq.q.0, rq.q.1),
            );
        }
        act_t(g, &t(&format!("kT{hd}")), &[p, s]);
        g.add_node(Node::new(
            &t(&format!("kT{hd}.op")),
            Op::Transpose,
            &[&t(&format!("k{hd}"))],
            &[&t(&format!("kT{hd}"))],
        ));
        act_t(g, &t(&format!("s{hd}")), &[s, s]);
        g.add_node(
            Node::new(
                &t(&format!("qk{hd}.op")),
                Op::MatMul,
                &[&t(&format!("q{hd}")), &t(&format!("kT{hd}"))],
                &[&t(&format!("s{hd}"))],
            )
            .with_rq(rq.qk.0, rq.qk.1),
        );
        act_t(g, &t(&format!("a{hd}")), &[s, s]);
        g.add_node(Node::new(
            &t(&format!("sm{hd}.op")),
            Op::Softmax,
            &[&t(&format!("s{hd}"))],
            &[&t(&format!("a{hd}"))],
        ));
        act_t(g, &t(&format!("c{hd}")), &[s, p]);
        g.add_node(
            Node::new(
                &t(&format!("av{hd}.op")),
                Op::MatMul,
                &[&t(&format!("a{hd}")), &t(&format!("v{hd}"))],
                &[&t(&format!("c{hd}"))],
            )
            .with_rq(rq.av.0, rq.av.1),
        );
        // partial output projection (int32, accumulated by HeadAcc)
        w_t(g, &t(&format!("wo{hd}")), &[p, e], DType::I8);
        g.add_tensor(
            &t(&format!("po{hd}")),
            &[s, e],
            DType::I32,
            TensorKind::Activation,
        );
        g.add_node(Node::new(
            &t(&format!("po{hd}.op")),
            Op::MatMul,
            &[&t(&format!("c{hd}")), &t(&format!("wo{hd}"))],
            &[&t(&format!("po{hd}"))],
        ));
        partials.push(t(&format!("po{hd}")));
    }

    // head accumulation (cluster)
    w_t(g, &t("bo"), &[e], DType::I32);
    act_t(g, &t("attn"), &[s, e]);
    let bo = t("bo");
    let mut acc_inputs: Vec<&str> = partials.iter().map(|s| s.as_str()).collect();
    acc_inputs.push(&bo);
    let attn = t("attn");
    g.add_node(
        Node::new(&t("headacc.op"), Op::HeadAcc { heads: h }, &acc_inputs, &[&attn])
            .with_rq(rq.o.0, rq.o.1),
    );

    // residual 1
    act_t(g, &t("res0"), &[s, e]);
    g.add_node(Node::new(&t("add0.op"), Op::Add, &[x, &t("attn")], &[&t("res0")]));

    // FFN stack
    let mut cur = t("res0");
    for f in 0..cfg.ffn_stack {
        let tf = |n: &str| format!("L{l}/F{f}/{n}");
        w_t(g, &tf("ln2_g"), &[e], DType::I8);
        w_t(g, &tf("ln2_b"), &[e], DType::I8);
        act_t(g, &tf("ln2"), &[s, e]);
        g.add_node(
            Node::new(
                &tf("ln2.op"),
                Op::LayerNorm,
                &[&cur, &tf("ln2_g"), &tf("ln2_b")],
                &[&tf("ln2")],
            )
            .with_rq(rq.ln.0, rq.ln.1),
        );
        w_t(g, &tf("w1"), &[e, cfg.dff], DType::I8);
        w_t(g, &tf("b1"), &[cfg.dff], DType::I32);
        act_t(g, &tf("u"), &[s, cfg.dff]);
        g.add_node(
            Node::new(
                &tf("ffn1.op"),
                Op::Gemm { act: cfg.act },
                &[&tf("ln2"), &tf("w1"), &tf("b1")],
                &[&tf("u")],
            )
            .with_rq(rq.ffn1.0, rq.ffn1.1),
        );
        w_t(g, &tf("w2"), &[cfg.dff, e], DType::I8);
        w_t(g, &tf("b2"), &[e], DType::I32);
        act_t(g, &tf("d"), &[s, e]);
        g.add_node(
            Node::new(
                &tf("ffn2.op"),
                Op::Gemm { act: Activation::Identity },
                &[&tf("u"), &tf("w2"), &tf("b2")],
                &[&tf("d")],
            )
            .with_rq(rq.ffn2.0, rq.ffn2.1),
        );
        let res = tf("res");
        act_t(g, &res, &[s, e]);
        g.add_node(Node::new(&tf("add.op"), Op::Add, &[&cur, &tf("d")], &[&res]));
        cur = res;
    }
    cur
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn configs_match_paper_footnotes() {
        assert_eq!(MOBILEBERT.layers, 24);
        assert_eq!(MOBILEBERT.ffn_stack, 4);
        assert_eq!(DINOV2S.seq_logical, 241);
        assert_eq!(DINOV2S.seq, 256); // padded to ITA tiling constraint
        assert_eq!(WHISPER_TINY_ENC.layers, 4);
        for c in ALL_MODELS {
            assert_eq!(c.proj, 64);
            assert_eq!(c.seq % 64, 0);
        }
    }

    #[test]
    fn graphs_validate() {
        for cfg in ALL_MODELS {
            let g = build_graph(cfg);
            g.validate().unwrap_or_else(|e| panic!("{}: {e}", cfg.name));
        }
    }

    #[test]
    fn graph_ops_match_paper_gop() {
        // within 30% of the footnote figures: the graphs count padded
        // dims + auxiliary operators, the footnotes count logical MACs
        for cfg in ALL_MODELS {
            let g = build_graph(cfg);
            let gop = g.total_ops() as f64 / 1e9;
            let scale = cfg.seq_logical as f64 / cfg.seq as f64;
            let adj = gop * scale;
            let err = (adj - cfg.gop_per_inference).abs() / cfg.gop_per_inference;
            assert!(err < 0.30, "{}: {adj:.2} vs {}", cfg.name, cfg.gop_per_inference);
        }
    }

    #[test]
    fn rq_matches_python_values() {
        // golden: python model.rq_for(128) == (8, 14), rq_for(64, 40) == (15, 14)
        assert_eq!(rq_for(128, 30.0), (8, 14));
        assert_eq!(rq_for(64, 40.0), (15, 14));
    }

    #[test]
    fn synth_tensor_ranges() {
        let w = synth_tensor("t/w", 1000, SynthKind::Weight, 0);
        assert!(w.iter().all(|&v| (-128..=127).contains(&v)));
        let g = synth_tensor("t/g", 1000, SynthKind::Gamma, 0);
        assert!(g.iter().all(|&v| (32..96).contains(&v)));
        // determinism + keying
        assert_eq!(w, synth_tensor("t/w", 1000, SynthKind::Weight, 0));
        assert_ne!(w, synth_tensor("t/w2", 1000, SynthKind::Weight, 0));
    }

    #[test]
    fn whisper_stem_only_whisper() {
        assert!(build_stem_graph(&WHISPER_TINY_ENC).is_some());
        assert!(build_stem_graph(&MOBILEBERT).is_none());
        assert!(build_stem_graph(&DINOV2S).is_none());
    }

    #[test]
    fn whisper_stem_ops_match_footnote_gap() {
        // conv stem ~ 0.84 GOp: the difference between the linear-only
        // encoder (8.85 GOp) and the paper's 9.74 GOp footnote
        let g = build_stem_graph(&WHISPER_TINY_ENC).unwrap();
        g.validate().unwrap();
        let gop = g.total_ops() as f64 / 1e9;
        assert!((0.5..1.1).contains(&gop), "stem GOp {gop}");
        // full graph (stem + 4 layers) lands on the footnote
        let full = build_graph(&WHISPER_TINY_ENC);
        let total = full.total_ops() as f64 / 1e9;
        assert!((total - 9.74).abs() / 9.74 < 0.10, "whisper total {total}");
    }

    #[test]
    fn layer_node_count() {
        let g = build_graph(&MOBILEBERT);
        // per layer: 1 LN + 4 heads x 8 nodes (3 proj + transpose + QK +
        // softmax + AV + partial-out) + headacc + add + 4 FFNs x (LN +
        // 2 gemm + add) = 1 + 32 + 2 + 16 = 51
        assert_eq!(g.nodes.len(), 51 * 24);
    }
}
