//! Fully static memory allocation (offline, conflict-free).
//!
//! Greedy best-fit over live intervals: tensors whose lifetimes do not
//! overlap may share memory. This produces the static L2 activation
//! arena layout; tile buffers inside L1 use fixed double-buffer slots
//! assigned by the tiler. The no-overlap invariant is property-tested.

use super::lifetime::Interval;
use std::collections::BTreeMap;

/// Final allocation: byte offset per tensor + arena peak.
#[derive(Debug, Clone, Default)]
pub struct Allocation {
    pub offsets: BTreeMap<String, usize>,
    pub peak_bytes: usize,
}

/// Word alignment of every placement.
pub const ALIGN: usize = 8;

fn align_up(x: usize) -> usize {
    x.div_ceil(ALIGN) * ALIGN
}

/// Greedy best-fit: process intervals in start order; for each, scan the
/// already-placed tensors whose lifetime overlaps and find the lowest
/// gap large enough.
pub fn allocate(intervals: &[Interval]) -> Allocation {
    #[derive(Clone)]
    struct Placed {
        start: usize,
        end: usize,
        off: usize,
        size: usize,
    }
    let mut placed: Vec<Placed> = Vec::new();
    let mut alloc = Allocation::default();

    for iv in intervals {
        let size = align_up(iv.bytes);
        // collect live conflicts sorted by offset
        let mut conflicts: Vec<&Placed> = placed
            .iter()
            .filter(|p| !(p.end < iv.start || p.start > iv.end))
            .collect();
        conflicts.sort_by_key(|p| p.off);
        // find first gap
        let mut best = 0usize;
        for c in &conflicts {
            if best + size <= c.off {
                break;
            }
            best = best.max(c.off + c.size);
        }
        placed.push(Placed { start: iv.start, end: iv.end, off: best, size });
        alloc.offsets.insert(iv.tensor.clone(), best);
        alloc.peak_bytes = alloc.peak_bytes.max(best + size);
    }
    alloc
}

/// Check the fundamental invariant: tensors overlapping in time never
/// overlap in memory. Returns the offending pair on violation.
pub fn verify(intervals: &[Interval], alloc: &Allocation) -> Result<(), (String, String)> {
    for (i, a) in intervals.iter().enumerate() {
        for b in intervals.iter().skip(i + 1) {
            let time_overlap = !(a.end < b.start || b.end < a.start);
            if !time_overlap {
                continue;
            }
            let (oa, ob) = (alloc.offsets[&a.tensor], alloc.offsets[&b.tensor]);
            let (sa, sb) = (align_up(a.bytes), align_up(b.bytes));
            let mem_overlap = !(oa + sa <= ob || ob + sb <= oa);
            if mem_overlap {
                return Err((a.tensor.clone(), b.tensor.clone()));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{check, Config};
    use crate::util::prng::XorShift64;

    fn iv(name: &str, start: usize, end: usize, bytes: usize) -> Interval {
        Interval { tensor: name.into(), start, end, bytes }
    }

    #[test]
    fn disjoint_lifetimes_share_memory() {
        let ivs = vec![iv("a", 0, 1, 1024), iv("b", 2, 3, 1024)];
        let a = allocate(&ivs);
        assert_eq!(a.offsets["a"], a.offsets["b"]);
        assert_eq!(a.peak_bytes, 1024);
        verify(&ivs, &a).unwrap();
    }

    #[test]
    fn overlapping_lifetimes_get_distinct_memory() {
        let ivs = vec![iv("a", 0, 5, 1024), iv("b", 2, 3, 1024)];
        let a = allocate(&ivs);
        assert_ne!(a.offsets["a"], a.offsets["b"]);
        assert_eq!(a.peak_bytes, 2048);
        verify(&ivs, &a).unwrap();
    }

    #[test]
    fn gap_reuse() {
        // c fits into the hole left between a (freed) and b (live)
        let ivs = vec![
            iv("a", 0, 1, 1024),
            iv("b", 0, 9, 1024),
            iv("c", 2, 9, 512),
        ];
        let a = allocate(&ivs);
        verify(&ivs, &a).unwrap();
        assert!(a.peak_bytes <= 2048, "peak {}", a.peak_bytes);
    }

    #[test]
    fn property_never_overlaps() {
        check(
            Config { cases: 60, seed: 0xA110C },
            |rng: &mut XorShift64| {
                let n = 3 + rng.next_below(40) as usize;
                (0..n)
                    .map(|i| {
                        let s = rng.next_below(50) as usize;
                        let e = s + rng.next_below(20) as usize;
                        let b = 8 + rng.next_below(4096) as usize;
                        iv(&format!("t{i}"), s, e, b)
                    })
                    .collect::<Vec<_>>()
            },
            |ivs| {
                let mut shrunk = Vec::new();
                if ivs.len() > 3 {
                    shrunk.push(ivs[..ivs.len() / 2].to_vec());
                    shrunk.push(ivs[1..].to_vec());
                }
                shrunk
            },
            |ivs| {
                let mut sorted = ivs.clone();
                sorted.sort_by_key(|i| (i.start, i.tensor.clone()));
                let a = allocate(&sorted);
                verify(&sorted, &a)
                    .map_err(|(x, y)| format!("{x} overlaps {y}"))
            },
        );
    }

    #[test]
    fn real_model_allocation_fits_reasonable_l2() {
        use crate::deeploy::{lifetime, schedule};
        let g = crate::models::build_graph_layers(&crate::models::MOBILEBERT, 2);
        let order = schedule::topo_schedule(&g);
        let ivs = lifetime::analyze(&g, &order);
        let a = allocate(&ivs);
        verify(&ivs, &a).unwrap();
        // MobileBERT activations (S=128, E=128): peak well under 1 MiB
        assert!(a.peak_bytes < 1 << 20, "peak {}", a.peak_bytes);
    }

    #[test]
    fn alignment_respected() {
        let ivs = vec![iv("a", 0, 5, 3), iv("b", 0, 5, 5)];
        let a = allocate(&ivs);
        assert_eq!(a.offsets["a"] % ALIGN, 0);
        assert_eq!(a.offsets["b"] % ALIGN, 0);
    }
}
