//! The deployment flow — our re-implementation of the paper's extended
//! Deeploy compiler (Sections III-B and IV-D).
//!
//! Pipeline: import (ONNX-like JSON or the built-in model builders)
//!   -> [`passes`]    MHA pattern fusion + head split, operator mapping
//!   -> [`tiler`]     geometric tiling constraints (ITA accelerator model)
//!   -> [`lifetime`]  tensor lifetime analysis
//!   -> [`allocator`] fully static memory layout (L1 + L2 arenas)
//!   -> [`schedule`]  topological schedule with double-buffer prefetching
//!   -> [`codegen`]   command-stream generation (the "C code" equivalent
//!                    that the simulator executes)

pub mod allocator;
pub mod codegen;
pub mod error;
pub mod ir;
pub mod lifetime;
pub mod onnx;
pub mod passes;
pub mod schedule;
pub mod tiler;

pub use error::DeployError;

use crate::models::ModelConfig;
use crate::sim::{ClusterConfig, Step};

/// Deployment target for code generation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Target {
    /// Multi-core cluster only (the paper's baseline column).
    MultiCore,
    /// Multi-core cluster + ITA (the paper's accelerated column).
    MultiCoreIta,
}

/// End-to-end deployment artifact: everything the coordinator needs.
#[derive(Debug)]
pub struct Deployment {
    pub graph: ir::Graph,
    pub target: Target,
    pub steps: Vec<Step>,
    pub total_ops: u64,
    pub l1_peak_bytes: usize,
    pub l2_activation_bytes: usize,
}

/// L1 bytes available to tile buffers for a given cluster geometry:
/// the TCDM capacity minus the cluster-kernel scratch reserve.
pub fn l1_tile_budget(cluster: &ClusterConfig) -> usize {
    cluster.l1_bytes().saturating_sub(tiler::L1_RESERVE)
}

/// Run the full deployment flow on a model config.
pub fn deploy(cfg: &ModelConfig, target: Target) -> Result<Deployment, DeployError> {
    deploy_layers(cfg, target, cfg.layers)
}

/// Deployment with overridden layer count (fast paths for tests/sweeps).
pub fn deploy_layers(
    cfg: &ModelConfig,
    target: Target,
    layers: usize,
) -> Result<Deployment, DeployError> {
    let graph = crate::models::build_graph_layers(cfg, layers);
    deploy_graph(graph, target)
}

/// Run the full flow on an arbitrary imported graph against the paper's
/// default cluster geometry.
pub fn deploy_graph(graph: ir::Graph, target: Target) -> Result<Deployment, DeployError> {
    deploy_graph_on(graph, target, &ClusterConfig::default())
}

/// Run the full flow against an explicit cluster geometry (the L1 tile
/// budget follows the configured TCDM capacity). This is the fallible
/// core every public entry point (including `Pipeline::compile`) funnels
/// through: user-supplied graphs return typed [`DeployError`]s instead
/// of panicking.
pub fn deploy_graph_on(
    graph: ir::Graph,
    target: Target,
    cluster: &ClusterConfig,
) -> Result<Deployment, DeployError> {
    deploy_graph_opts(graph, target, cluster, true)
}

/// Like [`deploy_graph_on`] with the MHA-fusion pass switchable — the
/// collaborative-execution ablation measures the flow with ITAMax left
/// on the cluster cores.
pub fn deploy_graph_opts(
    mut graph: ir::Graph,
    target: Target,
    cluster: &ClusterConfig,
    fuse_mha: bool,
) -> Result<Deployment, DeployError> {
    // normalize node order first: imported graphs may arrive unordered,
    // and cycles must surface as CyclicGraph, not a validity error
    // (already-ordered graphs — the builders, onnx::import output —
    // schedule to the identity and skip the rebuild)
    let order = schedule::try_topo_schedule(&graph)?;
    if order.iter().enumerate().any(|(pos, &node)| pos != node) {
        graph.apply_order(&order);
    }
    graph.validate()?;
    let total_ops = graph.total_ops();

    if target == Target::MultiCoreIta {
        if fuse_mha {
            passes::fuse_mha(&mut graph);
        }
        passes::lower_conv(&mut graph)?;
        passes::check_ita_constraints(&graph)?;
    }
    passes::map_operators(&mut graph, target == Target::MultiCoreIta);

    let order = schedule::try_topo_schedule(&graph)?;
    let lifetimes = lifetime::analyze(&graph, &order);
    let l2_alloc = allocator::allocate(&lifetimes);
    let plans = tiler::plan_graph(&graph, l1_tile_budget(cluster))?;
    let l1_peak = plans.values().map(|p| p.l1_bytes).max().unwrap_or(0);

    let steps = codegen::generate(&graph, &order, &plans)?;
    Ok(Deployment {
        graph,
        target,
        steps,
        total_ops,
        l1_peak_bytes: l1_peak,
        l2_activation_bytes: l2_alloc.peak_bytes,
    })
}
