//! The deployment flow — our re-implementation of the paper's extended
//! Deeploy compiler (Sections III-B and IV-D).
//!
//! Pipeline: import (ONNX-like JSON or the built-in model builders)
//!   -> [`passes`]    MHA pattern fusion + head split, operator mapping
//!   -> [`tiler`]     geometric tiling constraints (ITA accelerator model)
//!   -> [`lifetime`]  tensor lifetime analysis
//!   -> [`allocator`] fully static memory layout (L1 + L2 arenas)
//!   -> [`schedule`]  topological schedule with double-buffer prefetching
//!   -> [`codegen`]   command-stream generation (the "C code" equivalent
//!                    that the simulator executes)

pub mod allocator;
pub mod codegen;
pub mod ir;
pub mod lifetime;
pub mod onnx;
pub mod passes;
pub mod schedule;
pub mod tiler;

use crate::models::ModelConfig;
use crate::sim::Step;

/// Deployment target for code generation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Target {
    /// Multi-core cluster only (the paper's baseline column).
    MultiCore,
    /// Multi-core cluster + ITA (the paper's accelerated column).
    MultiCoreIta,
}

/// End-to-end deployment artifact: everything the coordinator needs.
#[derive(Debug)]
pub struct Deployment {
    pub graph: ir::Graph,
    pub target: Target,
    pub steps: Vec<Step>,
    pub total_ops: u64,
    pub l1_peak_bytes: usize,
    pub l2_activation_bytes: usize,
}

/// Run the full deployment flow on a model config.
pub fn deploy(cfg: &ModelConfig, target: Target) -> Deployment {
    deploy_layers(cfg, target, cfg.layers)
}

/// Deployment with overridden layer count (fast paths for tests/sweeps).
pub fn deploy_layers(cfg: &ModelConfig, target: Target, layers: usize) -> Deployment {
    let graph = crate::models::build_graph_layers(cfg, layers);
    deploy_graph(graph, target)
}

/// Run the full flow on an arbitrary imported graph.
pub fn deploy_graph(mut graph: ir::Graph, target: Target) -> Deployment {
    graph.validate().expect("graph must validate");
    let total_ops = graph.total_ops();

    if target == Target::MultiCoreIta {
        passes::fuse_mha(&mut graph);
        passes::lower_conv(&mut graph);
        passes::check_ita_constraints(&graph).expect("tiling constraints");
    }
    passes::map_operators(&mut graph, target == Target::MultiCoreIta);

    let order = schedule::topo_schedule(&graph);
    let lifetimes = lifetime::analyze(&graph, &order);
    let l2_alloc = allocator::allocate(&lifetimes);
    let plans = tiler::plan_graph(&graph);
    let l1_peak = plans.values().map(|p| p.l1_bytes).max().unwrap_or(0);

    let steps = codegen::generate(&graph, &order, &plans);
    Deployment {
        graph,
        target,
        steps,
        total_ops,
        l1_peak_bytes: l1_peak,
        l2_activation_bytes: l2_alloc.peak_bytes,
    }
}
