//! Topological scheduling.
//!
//! Produces the execution order the code generator emits. The builders
//! keep nodes topologically sorted already, but imported graphs may not
//! be — this is a Kahn's-algorithm list scheduler with a deterministic
//! tie-break (original index), plus a validity checker used in tests.

use std::collections::BTreeMap;

use super::ir::Graph;
use super::DeployError;

/// Compute a topological execution order (indices into g.nodes).
/// Deterministic: among ready nodes, lowest original index first —
/// so an already-topologically-ordered node list schedules to the
/// identity permutation. Returns [`DeployError::CyclicGraph`] when the
/// dependencies contain a cycle.
pub fn try_topo_schedule(g: &Graph) -> Result<Vec<usize>, DeployError> {
    let n = g.nodes.len();
    // tensor -> producer node
    let mut producer: BTreeMap<&str, usize> = BTreeMap::new();
    for (i, node) in g.nodes.iter().enumerate() {
        for o in &node.outputs {
            producer.insert(o, i);
        }
    }
    // dependency edges + indegrees
    let mut indeg = vec![0usize; n];
    let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, node) in g.nodes.iter().enumerate() {
        for inp in &node.inputs {
            if let Some(&p) = producer.get(inp.as_str()) {
                if p != i {
                    succs[p].push(i);
                    indeg[i] += 1;
                }
            }
        }
    }
    // Kahn with a sorted ready set (BTreeMap keys as a min-heap)
    let mut ready: std::collections::BTreeSet<usize> =
        (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut order = Vec::with_capacity(n);
    while let Some(&i) = ready.iter().next() {
        ready.remove(&i);
        order.push(i);
        for &s in &succs[i] {
            indeg[s] -= 1;
            if indeg[s] == 0 {
                ready.insert(s);
            }
        }
    }
    if order.len() != n {
        return Err(DeployError::CyclicGraph {
            graph: g.name.clone(),
            scheduled: order.len(),
            total: n,
        });
    }
    Ok(order)
}

/// Schedule a graph known to be acyclic (the built-in model builders).
/// Panics on a cycle — user-supplied graphs go through
/// [`try_topo_schedule`] / `deeploy::deploy_graph` instead.
pub fn topo_schedule(g: &Graph) -> Vec<usize> {
    try_topo_schedule(g).unwrap_or_else(|e| panic!("{e}"))
}

/// Check that `order` is a valid topological order of `g`.
pub fn is_valid_order(g: &Graph, order: &[usize]) -> bool {
    let mut pos = vec![usize::MAX; g.nodes.len()];
    for (p, &i) in order.iter().enumerate() {
        pos[i] = p;
    }
    let mut producer: BTreeMap<&str, usize> = BTreeMap::new();
    for (i, node) in g.nodes.iter().enumerate() {
        for o in &node.outputs {
            producer.insert(o, i);
        }
    }
    for (i, node) in g.nodes.iter().enumerate() {
        for inp in &node.inputs {
            if let Some(&p) = producer.get(inp.as_str()) {
                if p != i && pos[p] >= pos[i] {
                    return false;
                }
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_graph_layers, ALL_MODELS, MOBILEBERT};

    #[test]
    fn schedules_are_valid_for_all_models() {
        for cfg in ALL_MODELS {
            let g = build_graph_layers(cfg, 2);
            let order = topo_schedule(&g);
            assert_eq!(order.len(), g.nodes.len());
            assert!(is_valid_order(&g, &order), "{}", cfg.name);
        }
    }

    #[test]
    fn schedule_survives_shuffled_input() {
        // reverse the node list (breaking builder order), reschedule
        let mut g = build_graph_layers(&MOBILEBERT, 1);
        g.nodes.reverse();
        let order = topo_schedule(&g);
        assert!(is_valid_order(&g, &order));
    }

    #[test]
    fn fused_graph_schedules() {
        let mut g = build_graph_layers(&MOBILEBERT, 1);
        crate::deeploy::passes::fuse_mha(&mut g);
        let order = topo_schedule(&g);
        assert!(is_valid_order(&g, &order));
    }

    #[test]
    fn deterministic() {
        let g = build_graph_layers(&MOBILEBERT, 1);
        assert_eq!(topo_schedule(&g), topo_schedule(&g));
    }

    #[test]
    fn ordered_graph_schedules_to_identity() {
        // builders emit topological order; min-index Kahn must keep it
        let g = build_graph_layers(&MOBILEBERT, 1);
        let order = try_topo_schedule(&g).unwrap();
        assert!(order.iter().enumerate().all(|(p, &i)| p == i));
    }

    #[test]
    fn cycle_is_a_typed_error() {
        use crate::deeploy::ir::{DType, Graph, Node, Op, TensorKind};
        use crate::deeploy::DeployError;
        let mut g = Graph::new("loop");
        g.add_tensor("x", &[4, 4], DType::I8, TensorKind::Input);
        g.add_tensor("a", &[4, 4], DType::I8, TensorKind::Activation);
        g.add_tensor("b", &[4, 4], DType::I8, TensorKind::Activation);
        g.add_node(Node::new("n0", Op::Add, &["x", "b"], &["a"]));
        g.add_node(Node::new("n1", Op::Add, &["a", "x"], &["b"]));
        match try_topo_schedule(&g) {
            Err(DeployError::CyclicGraph { scheduled, total, .. }) => {
                assert_eq!((scheduled, total), (0, 2));
            }
            other => panic!("expected CyclicGraph, got {other:?}"),
        }
    }

    #[test]
    fn property_random_dags_schedule_validly() {
        // generate random layered DAGs (each node consumes 1-2 tensors
        // from strictly earlier layers), shuffle the node order, and
        // check the scheduler always recovers a valid topological order
        use crate::deeploy::ir::{DType, Graph, Node, Op, TensorKind};
        use crate::util::propcheck::{check, Config};
        use crate::util::prng::XorShift64;

        check(
            Config { cases: 40, seed: 0x5C4ED },
            |rng: &mut XorShift64| {
                let n = 3 + rng.next_below(30) as usize;
                let seed = rng.next_u64();
                (n, seed)
            },
            |&(n, seed)| {
                if n > 3 {
                    vec![(n / 2, seed), (n - 1, seed)]
                } else {
                    vec![]
                }
            },
            |&(n, seed)| {
                let mut rng = XorShift64::new(seed);
                let mut g = Graph::new("rand");
                g.add_tensor("t0", &[4, 4], DType::I8, TensorKind::Input);
                for i in 0..n {
                    let out = format!("t{}", i + 1);
                    g.add_tensor(&out, &[4, 4], DType::I8, TensorKind::Activation);
                    let a = format!("t{}", rng.next_below(i as u64 + 1));
                    let b = format!("t{}", rng.next_below(i as u64 + 1));
                    let mut node =
                        Node::new(&format!("n{i}"), Op::Add, &[], &[]);
                    node.inputs = vec![a, b];
                    node.outputs = vec![out];
                    g.add_node(node);
                }
                // adversarial input order
                g.nodes.reverse();
                let order = topo_schedule(&g);
                if order.len() != g.nodes.len() {
                    return Err("missing nodes".into());
                }
                if !is_valid_order(&g, &order) {
                    return Err("invalid topological order".into());
                }
                Ok(())
            },
        );
    }
}
