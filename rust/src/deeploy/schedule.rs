//! Topological scheduling.
//!
//! Produces the execution order the code generator emits. The builders
//! keep nodes topologically sorted already, but imported graphs may not
//! be — this is a Kahn's-algorithm list scheduler with a deterministic
//! tie-break (original index), plus a validity checker used in tests.

use std::collections::BTreeMap;

use super::ir::Graph;

/// Compute a topological execution order (indices into g.nodes).
/// Deterministic: among ready nodes, lowest original index first.
pub fn topo_schedule(g: &Graph) -> Vec<usize> {
    let n = g.nodes.len();
    // tensor -> producer node
    let mut producer: BTreeMap<&str, usize> = BTreeMap::new();
    for (i, node) in g.nodes.iter().enumerate() {
        for o in &node.outputs {
            producer.insert(o, i);
        }
    }
    // dependency edges + indegrees
    let mut indeg = vec![0usize; n];
    let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, node) in g.nodes.iter().enumerate() {
        for inp in &node.inputs {
            if let Some(&p) = producer.get(inp.as_str()) {
                if p != i {
                    succs[p].push(i);
                    indeg[i] += 1;
                }
            }
        }
    }
    // Kahn with a sorted ready set (BTreeMap keys as a min-heap)
    let mut ready: std::collections::BTreeSet<usize> =
        (0..n).filter(|&i| indeg[i] == 0).collect();
    let mut order = Vec::with_capacity(n);
    while let Some(&i) = ready.iter().next() {
        ready.remove(&i);
        order.push(i);
        for &s in &succs[i] {
            indeg[s] -= 1;
            if indeg[s] == 0 {
                ready.insert(s);
            }
        }
    }
    assert_eq!(order.len(), n, "cycle in graph {}", g.name);
    order
}

/// Check that `order` is a valid topological order of `g`.
pub fn is_valid_order(g: &Graph, order: &[usize]) -> bool {
    let mut pos = vec![usize::MAX; g.nodes.len()];
    for (p, &i) in order.iter().enumerate() {
        pos[i] = p;
    }
    let mut producer: BTreeMap<&str, usize> = BTreeMap::new();
    for (i, node) in g.nodes.iter().enumerate() {
        for o in &node.outputs {
            producer.insert(o, i);
        }
    }
    for (i, node) in g.nodes.iter().enumerate() {
        for inp in &node.inputs {
            if let Some(&p) = producer.get(inp.as_str()) {
                if p != i && pos[p] >= pos[i] {
                    return false;
                }
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_graph_layers, ALL_MODELS, MOBILEBERT};

    #[test]
    fn schedules_are_valid_for_all_models() {
        for cfg in ALL_MODELS {
            let g = build_graph_layers(cfg, 2);
            let order = topo_schedule(&g);
            assert_eq!(order.len(), g.nodes.len());
            assert!(is_valid_order(&g, &order), "{}", cfg.name);
        }
    }

    #[test]
    fn schedule_survives_shuffled_input() {
        // reverse the node list (breaking builder order), reschedule
        let mut g = build_graph_layers(&MOBILEBERT, 1);
        g.nodes.reverse();
        let order = topo_schedule(&g);
        assert!(is_valid_order(&g, &order));
    }

    #[test]
    fn fused_graph_schedules() {
        let mut g = build_graph_layers(&MOBILEBERT, 1);
        crate::deeploy::passes::fuse_mha(&mut g);
        let order = topo_schedule(&g);
        assert!(is_valid_order(&g, &order));
    }

    #[test]
    fn deterministic() {
        let g = build_graph_layers(&MOBILEBERT, 1);
        assert_eq!(topo_schedule(&g), topo_schedule(&g));
    }

    #[test]
    fn property_random_dags_schedule_validly() {
        // generate random layered DAGs (each node consumes 1-2 tensors
        // from strictly earlier layers), shuffle the node order, and
        // check the scheduler always recovers a valid topological order
        use crate::deeploy::ir::{DType, Graph, Node, Op, TensorKind};
        use crate::util::propcheck::{check, Config};
        use crate::util::prng::XorShift64;

        check(
            Config { cases: 40, seed: 0x5C4ED },
            |rng: &mut XorShift64| {
                let n = 3 + rng.next_below(30) as usize;
                let seed = rng.next_u64();
                (n, seed)
            },
            |&(n, seed)| {
                if n > 3 {
                    vec![(n / 2, seed), (n - 1, seed)]
                } else {
                    vec![]
                }
            },
            |&(n, seed)| {
                let mut rng = XorShift64::new(seed);
                let mut g = Graph::new("rand");
                g.add_tensor("t0", &[4, 4], DType::I8, TensorKind::Input);
                for i in 0..n {
                    let out = format!("t{}", i + 1);
                    g.add_tensor(&out, &[4, 4], DType::I8, TensorKind::Activation);
                    let a = format!("t{}", rng.next_below(i as u64 + 1));
                    let b = format!("t{}", rng.next_below(i as u64 + 1));
                    let mut node =
                        Node::new(&format!("n{i}"), Op::Add, &[], &[]);
                    node.inputs = vec![a, b];
                    node.outputs = vec![out];
                    g.add_node(node);
                }
                // adversarial input order
                g.nodes.reverse();
                let order = topo_schedule(&g);
                if order.len() != g.nodes.len() {
                    return Err("missing nodes".into());
                }
                if !is_valid_order(&g, &order) {
                    return Err("invalid topological order".into());
                }
                Ok(())
            },
        );
    }
}
