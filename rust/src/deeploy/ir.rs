//! Graph IR of the deployment compiler.
//!
//! Deeploy is a bottom-up compiler: the graph arrives as generic ONNX-like
//! operators; passes progressively fuse patterns (MHA), split them to match
//! the accelerator geometry (head-by-head), assign executors, tile, and
//! allocate. This IR is deliberately small: named tensors + a node list
//! kept in topological order.

use std::collections::BTreeMap;
use std::fmt;

/// Element type of a tensor (int8 carried in int32 containers at runtime).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    I8,
    I32,
}

impl DType {
    pub fn bytes(&self) -> usize {
        match self {
            DType::I8 => 1,
            DType::I32 => 4,
        }
    }
}

/// Where a tensor lives before the run starts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TensorKind {
    /// Network input (streamed in from L2).
    Input,
    /// Constant parameter (resident in L2, DMA'd per tile).
    Weight,
    /// Intermediate activation.
    Activation,
    /// Network output (streamed out to L2).
    Output,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub kind: TensorKind,
}

impl Tensor {
    pub fn elems(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn bytes(&self) -> usize {
        self.elems() * self.dtype.bytes()
    }
}

/// Operator set. Generic ops arrive from the ONNX-like import; fused /
/// accelerator ops are introduced by passes.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// C = A x B (int8 inputs, int32 accumulate).
    MatMul,
    /// GEMM with bias + requant + activation: the ITA-offloadable form.
    Gemm { act: Activation },
    /// Row-wise integer softmax (ITAMax when fused into attention).
    Softmax,
    /// Integer LayerNorm (cluster-only).
    LayerNorm,
    /// Saturating elementwise add (residual).
    Add,
    /// Standalone requantization.
    Requant,
    /// Standalone activation.
    Act { act: Activation },
    /// Transpose last two dims.
    Transpose,
    /// 1D convolution (Whisper stem; lowered to GEMM via im2col).
    Conv1d { kernel: usize, stride: usize },
    /// im2col data rearrangement (product of the conv-lowering pass;
    /// a strided copy executed by the cluster cores).
    Im2col { kernel: usize, stride: usize },
    /// Fused multi-head attention (product of the MHA fusion pass).
    Mha { heads: usize, proj: usize },
    /// One attention head on ITA (product of the head-split pass).
    AttentionHead { proj: usize },
    /// Cluster-side accumulation of per-head partial projections.
    HeadAcc { heads: usize },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    Identity,
    Relu,
    Gelu,
}

impl fmt::Display for Op {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Op::MatMul => write!(f, "MatMul"),
            Op::Gemm { act } => write!(f, "Gemm[{act:?}]"),
            Op::Softmax => write!(f, "Softmax"),
            Op::LayerNorm => write!(f, "LayerNorm"),
            Op::Add => write!(f, "Add"),
            Op::Requant => write!(f, "Requant"),
            Op::Act { act } => write!(f, "Act[{act:?}]"),
            Op::Transpose => write!(f, "Transpose"),
            Op::Conv1d { kernel, stride } => write!(f, "Conv1d[k{kernel},s{stride}]"),
            Op::Im2col { kernel, stride } => write!(f, "Im2col[k{kernel},s{stride}]"),
            Op::Mha { heads, .. } => write!(f, "MHA[h{heads}]"),
            Op::AttentionHead { .. } => write!(f, "AttentionHead"),
            Op::HeadAcc { heads } => write!(f, "HeadAcc[h{heads}]"),
        }
    }
}

/// Execution target assigned by the operator-mapping pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Executor {
    /// Not yet assigned.
    Unassigned,
    /// Offloaded to the ITA HWPE.
    Ita,
    /// Fallback kernel on the worker cores.
    Cluster,
}

#[derive(Debug, Clone)]
pub struct Node {
    pub name: String,
    pub op: Op,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
    pub executor: Executor,
    /// Requantization parameters attached by the importer/builders.
    pub rq_mult: i32,
    pub rq_shift: u32,
    /// Second requant pair (fused AttentionHead: rq = QK stage, rq2 = AV).
    pub rq2_mult: i32,
    pub rq2_shift: u32,
}

impl Node {
    pub fn new(name: &str, op: Op, inputs: &[&str], outputs: &[&str]) -> Node {
        Node {
            name: name.to_string(),
            op,
            inputs: inputs.iter().map(|s| s.to_string()).collect(),
            outputs: outputs.iter().map(|s| s.to_string()).collect(),
            executor: Executor::Unassigned,
            rq_mult: 1,
            rq_shift: 0,
            rq2_mult: 1,
            rq2_shift: 0,
        }
    }

    pub fn with_rq(mut self, mult: i32, shift: u32) -> Node {
        self.rq_mult = mult;
        self.rq_shift = shift;
        self
    }
}

/// The graph: tensors by name + nodes in topological order.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub name: String,
    pub tensors: BTreeMap<String, Tensor>,
    pub nodes: Vec<Node>,
}

impl Graph {
    pub fn new(name: &str) -> Graph {
        Graph { name: name.to_string(), ..Default::default() }
    }

    pub fn add_tensor(&mut self, name: &str, shape: &[usize], dtype: DType, kind: TensorKind) {
        self.tensors.insert(
            name.to_string(),
            Tensor {
                name: name.to_string(),
                shape: shape.to_vec(),
                dtype,
                kind,
            },
        );
    }

    pub fn add_node(&mut self, node: Node) {
        self.nodes.push(node);
    }

    pub fn tensor(&self, name: &str) -> &Tensor {
        self.tensors
            .get(name)
            .unwrap_or_else(|| panic!("unknown tensor {name}"))
    }

    /// Producer node index of a tensor, if any.
    pub fn producer(&self, tensor: &str) -> Option<usize> {
        self.nodes
            .iter()
            .position(|n| n.outputs.iter().any(|o| o == tensor))
    }

    /// Consumer node indices of a tensor.
    pub fn consumers(&self, tensor: &str) -> Vec<usize> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.inputs.iter().any(|i| i == tensor))
            .map(|(i, _)| i)
            .collect()
    }

    /// Validate: topological order, every input defined before use,
    /// every referenced tensor declared.
    pub fn validate(&self) -> Result<(), String> {
        let mut defined: std::collections::BTreeSet<&str> = self
            .tensors
            .values()
            .filter(|t| t.kind == TensorKind::Input || t.kind == TensorKind::Weight)
            .map(|t| t.name.as_str())
            .collect();
        for node in &self.nodes {
            for i in &node.inputs {
                if !self.tensors.contains_key(i) {
                    return Err(format!("{}: undeclared tensor {i}", node.name));
                }
                if !defined.contains(i.as_str()) {
                    return Err(format!("{}: use of {i} before definition", node.name));
                }
            }
            for o in &node.outputs {
                if !self.tensors.contains_key(o) {
                    return Err(format!("{}: undeclared output {o}", node.name));
                }
                defined.insert(o);
            }
        }
        for t in self.tensors.values() {
            if t.kind == TensorKind::Output && !defined.contains(t.name.as_str()) {
                return Err(format!("output {} never produced", t.name));
            }
        }
        Ok(())
    }

    /// Total ops (the paper's accounting: 2 ops per MAC, 1 per
    /// elementwise op, 5 per softmax element).
    pub fn total_ops(&self) -> u64 {
        self.nodes.iter().map(|n| self.node_ops(n)).sum()
    }

    pub fn node_ops(&self, node: &Node) -> u64 {
        let out = self.tensor(&node.outputs[0]);
        let out_elems = out.elems() as u64;
        match &node.op {
            Op::MatMul | Op::Gemm { .. } => {
                let a = self.tensor(&node.inputs[0]);
                let k = *a.shape.last().unwrap() as u64;
                2 * out_elems * k
            }
            Op::Softmax => 5 * out_elems,
            Op::LayerNorm => 8 * out_elems,
            Op::Add | Op::Requant | Op::Act { .. } | Op::Transpose => out_elems,
            Op::Conv1d { kernel, .. } => {
                // weight layout (k*cin, cout): reduction dim is shape[0]
                let kcin = self.tensor(&node.inputs[1]).shape[0] as u64;
                debug_assert_eq!(kcin % *kernel as u64, 0);
                2 * out_elems * kcin
            }
            Op::Im2col { .. } => out_elems,
            Op::Mha { heads, proj } => {
                // per head: QK + AV + softmax; projections are separate nodes
                let s = self.tensor(&node.inputs[0]).shape[0] as u64;
                let h = *heads as u64;
                let p = *proj as u64;
                h * (2 * 2 * s * s * p + 5 * s * s)
            }
            Op::AttentionHead { proj } => {
                let s = self.tensor(&node.inputs[0]).shape[0] as u64;
                let p = *proj as u64;
                2 * 2 * s * s * p + 5 * s * s
            }
            Op::HeadAcc { heads } => out_elems * (*heads as u64),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_graph() -> Graph {
        let mut g = Graph::new("tiny");
        g.add_tensor("x", &[64, 64], DType::I8, TensorKind::Input);
        g.add_tensor("w", &[64, 64], DType::I8, TensorKind::Weight);
        g.add_tensor("b", &[64], DType::I32, TensorKind::Weight);
        g.add_tensor("y", &[64, 64], DType::I8, TensorKind::Output);
        g.add_node(Node::new(
            "gemm0",
            Op::Gemm { act: Activation::Identity },
            &["x", "w", "b"],
            &["y"],
        ));
        g
    }

    #[test]
    fn validates_well_formed() {
        assert!(tiny_graph().validate().is_ok());
    }

    #[test]
    fn rejects_use_before_def() {
        let mut g = tiny_graph();
        g.add_tensor("z", &[64, 64], DType::I8, TensorKind::Activation);
        // node consuming an activation that nothing produces
        g.nodes.insert(
            0,
            Node::new("bad", Op::Add, &["z", "x"], &["z"]),
        );
        assert!(g.validate().is_err());
    }

    #[test]
    fn rejects_unproduced_output() {
        let mut g = tiny_graph();
        g.add_tensor("orphan", &[4], DType::I8, TensorKind::Output);
        assert!(g.validate().is_err());
    }

    #[test]
    fn producer_consumer_queries() {
        let g = tiny_graph();
        assert_eq!(g.producer("y"), Some(0));
        assert_eq!(g.producer("x"), None);
        assert_eq!(g.consumers("x"), vec![0]);
    }

    #[test]
    fn gemm_op_count() {
        let g = tiny_graph();
        // 2 * 64*64 outputs * 64 K
        assert_eq!(g.total_ops(), 2 * 64 * 64 * 64);
    }
}
