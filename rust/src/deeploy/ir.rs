//! Graph IR of the deployment compiler.
//!
//! Deeploy is a bottom-up compiler: the graph arrives as generic ONNX-like
//! operators; passes progressively fuse patterns (MHA), split them to match
//! the accelerator geometry (head-by-head), assign executors, tile, and
//! allocate. This IR is deliberately small: named tensors + a node list
//! kept in topological order.

use std::collections::BTreeMap;
use std::fmt;

use super::DeployError;

/// Element type of a tensor (int8 carried in int32 containers at runtime).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    I8,
    I32,
}

impl DType {
    pub fn bytes(&self) -> usize {
        match self {
            DType::I8 => 1,
            DType::I32 => 4,
        }
    }
}

/// Where a tensor lives before the run starts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TensorKind {
    /// Network input (streamed in from L2).
    Input,
    /// Constant parameter (resident in L2, DMA'd per tile).
    Weight,
    /// Intermediate activation.
    Activation,
    /// Network output (streamed out to L2).
    Output,
}

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub kind: TensorKind,
}

/// Upper bound on any tensor dim and operator attribute (heads, proj,
/// kernel, stride) accepted by [`Graph::validate`]. Generous for tinyML
/// (the largest real dim is 1536) while keeping every downstream size
/// and op-count computation comfortably inside u64 — hostile imported
/// graphs cannot provoke arithmetic overflow panics.
pub const DIM_MAX: usize = 1 << 20;
/// Upper bound on tensor rank accepted by [`Graph::validate`].
pub const RANK_MAX: usize = 8;
/// Upper bound on total elements per tensor accepted by
/// [`Graph::validate`] (4 Gi elements ≫ any tinyML activation): keeps
/// byte counts and per-node op counts inside u64 without saturation.
/// `u64` so the constant also compiles on 32-bit targets.
pub const ELEMS_MAX: u64 = 1 << 32;

impl Tensor {
    pub fn elems(&self) -> usize {
        // saturating: validate bounds dims, but elems() must not panic
        // even on graphs that have not been validated yet
        self.shape.iter().fold(1usize, |acc, &d| acc.saturating_mul(d))
    }

    pub fn bytes(&self) -> usize {
        self.elems().saturating_mul(self.dtype.bytes())
    }
}

/// Operator set. Generic ops arrive from the ONNX-like import; fused /
/// accelerator ops are introduced by passes.
#[derive(Debug, Clone, PartialEq)]
pub enum Op {
    /// C = A x B (int8 inputs, int32 accumulate).
    MatMul,
    /// GEMM with bias + requant + activation: the ITA-offloadable form.
    Gemm { act: Activation },
    /// Row-wise integer softmax (ITAMax when fused into attention).
    Softmax,
    /// Integer LayerNorm (cluster-only).
    LayerNorm,
    /// Saturating elementwise add (residual).
    Add,
    /// Standalone requantization.
    Requant,
    /// Standalone activation.
    Act { act: Activation },
    /// Transpose last two dims.
    Transpose,
    /// 1D convolution (Whisper stem; lowered to GEMM via im2col).
    Conv1d { kernel: usize, stride: usize },
    /// im2col data rearrangement (product of the conv-lowering pass;
    /// a strided copy executed by the cluster cores).
    Im2col { kernel: usize, stride: usize },
    /// Fused multi-head attention (product of the MHA fusion pass).
    Mha { heads: usize, proj: usize },
    /// One attention head on ITA (product of the head-split pass).
    AttentionHead { proj: usize },
    /// Cluster-side accumulation of per-head partial projections.
    HeadAcc { heads: usize },
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    Identity,
    Relu,
    Gelu,
}

impl fmt::Display for Op {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Op::MatMul => write!(f, "MatMul"),
            Op::Gemm { act } => write!(f, "Gemm[{act:?}]"),
            Op::Softmax => write!(f, "Softmax"),
            Op::LayerNorm => write!(f, "LayerNorm"),
            Op::Add => write!(f, "Add"),
            Op::Requant => write!(f, "Requant"),
            Op::Act { act } => write!(f, "Act[{act:?}]"),
            Op::Transpose => write!(f, "Transpose"),
            Op::Conv1d { kernel, stride } => write!(f, "Conv1d[k{kernel},s{stride}]"),
            Op::Im2col { kernel, stride } => write!(f, "Im2col[k{kernel},s{stride}]"),
            Op::Mha { heads, .. } => write!(f, "MHA[h{heads}]"),
            Op::AttentionHead { .. } => write!(f, "AttentionHead"),
            Op::HeadAcc { heads } => write!(f, "HeadAcc[h{heads}]"),
        }
    }
}

/// Execution target assigned by the operator-mapping pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Executor {
    /// Not yet assigned.
    Unassigned,
    /// Offloaded to the ITA HWPE.
    Ita,
    /// Fallback kernel on the worker cores.
    Cluster,
}

#[derive(Debug, Clone)]
pub struct Node {
    pub name: String,
    pub op: Op,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
    pub executor: Executor,
    /// Requantization parameters attached by the importer/builders.
    pub rq_mult: i32,
    pub rq_shift: u32,
    /// Second requant pair (fused AttentionHead: rq = QK stage, rq2 = AV).
    pub rq2_mult: i32,
    pub rq2_shift: u32,
}

impl Node {
    pub fn new(name: &str, op: Op, inputs: &[&str], outputs: &[&str]) -> Node {
        Node {
            name: name.to_string(),
            op,
            inputs: inputs.iter().map(|s| s.to_string()).collect(),
            outputs: outputs.iter().map(|s| s.to_string()).collect(),
            executor: Executor::Unassigned,
            rq_mult: 1,
            rq_shift: 0,
            rq2_mult: 1,
            rq2_shift: 0,
        }
    }

    pub fn with_rq(mut self, mult: i32, shift: u32) -> Node {
        self.rq_mult = mult;
        self.rq_shift = shift;
        self
    }
}

/// The graph: tensors by name + nodes in topological order.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub name: String,
    pub tensors: BTreeMap<String, Tensor>,
    pub nodes: Vec<Node>,
}

impl Graph {
    pub fn new(name: &str) -> Graph {
        Graph { name: name.to_string(), ..Default::default() }
    }

    pub fn add_tensor(&mut self, name: &str, shape: &[usize], dtype: DType, kind: TensorKind) {
        self.tensors.insert(
            name.to_string(),
            Tensor {
                name: name.to_string(),
                shape: shape.to_vec(),
                dtype,
                kind,
            },
        );
    }

    pub fn add_node(&mut self, node: Node) {
        self.nodes.push(node);
    }

    pub fn tensor(&self, name: &str) -> &Tensor {
        self.tensors
            .get(name)
            .unwrap_or_else(|| panic!("unknown tensor {name}"))
    }

    /// Producer node index of a tensor, if any.
    pub fn producer(&self, tensor: &str) -> Option<usize> {
        self.nodes
            .iter()
            .position(|n| n.outputs.iter().any(|o| o == tensor))
    }

    /// Consumer node indices of a tensor.
    pub fn consumers(&self, tensor: &str) -> Vec<usize> {
        self.nodes
            .iter()
            .enumerate()
            .filter(|(_, n)| n.inputs.iter().any(|i| i == tensor))
            .map(|(i, _)| i)
            .collect()
    }

    /// Reorder `nodes` into the given schedule order (a permutation of
    /// `0..nodes.len()`, e.g. from [`super::schedule::try_topo_schedule`]).
    /// Imported graphs may arrive in any node order; reordering first
    /// lets [`Graph::validate`] check def-before-use meaningfully.
    pub fn apply_order(&mut self, order: &[usize]) {
        debug_assert_eq!(order.len(), self.nodes.len());
        let mut nodes = Vec::with_capacity(self.nodes.len());
        for &i in order {
            nodes.push(self.nodes[i].clone());
        }
        self.nodes = nodes;
    }

    /// Minimum input arity + rank requirements the rest of the flow
    /// (op accounting, tiling, code generation) relies on.
    fn check_node_shape(&self, node: &Node) -> Result<(), String> {
        let need = match &node.op {
            Op::MatMul | Op::Add => 2,
            Op::Gemm { .. } | Op::Conv1d { .. } | Op::AttentionHead { .. } | Op::Mha { .. } => 3,
            Op::Softmax
            | Op::LayerNorm
            | Op::Requant
            | Op::Act { .. }
            | Op::Transpose
            | Op::Im2col { .. }
            | Op::HeadAcc { .. } => 1,
        };
        if node.inputs.len() < need {
            return Err(format!(
                "{}: {} needs >= {need} inputs, has {}",
                node.name,
                node.op,
                node.inputs.len()
            ));
        }
        // matrix operands must be 2-D: the tiler and code generator read
        // shape[0]/shape[1] of these positions
        let need_rank2: &[usize] = match &node.op {
            Op::MatMul | Op::Gemm { .. } | Op::Conv1d { .. } => &[0, 1],
            Op::AttentionHead { .. } => &[0, 1, 2],
            Op::Mha { .. } => &[0],
            _ => &[],
        };
        for &pos in need_rank2 {
            let name = &node.inputs[pos];
            if let Some(t) = self.tensors.get(name) {
                if t.shape.len() != 2 {
                    return Err(format!(
                        "{}: input {name} must be 2-D, has shape {:?}",
                        node.name, t.shape
                    ));
                }
            }
        }
        // operator attributes are sizes too: bound them like dims so no
        // downstream size/op-count computation can overflow
        let attr_ok = |what: &str, v: usize| -> Result<(), String> {
            if v == 0 || v > DIM_MAX {
                return Err(format!(
                    "{}: {what} must be in 1..={DIM_MAX}, got {v}",
                    node.name
                ));
            }
            Ok(())
        };
        match node.op {
            Op::Conv1d { kernel, stride } | Op::Im2col { kernel, stride } => {
                return self.check_conv_attrs(node, kernel, stride);
            }
            Op::Mha { heads, proj } => {
                attr_ok("heads", heads)?;
                attr_ok("proj", proj)?;
            }
            Op::AttentionHead { proj } => attr_ok("proj", proj)?,
            Op::HeadAcc { heads } => attr_ok("heads", heads)?,
            _ => {}
        }
        Ok(())
    }

    /// Conv contract: bounded positive kernel/stride, and the weight
    /// uses the im2col layout (kernel * c_in, c_out) — op accounting
    /// and the lowering pass both derive the reduction dim from it.
    fn check_conv_attrs(&self, node: &Node, kernel: usize, stride: usize) -> Result<(), String> {
        for (what, v) in [("kernel", kernel), ("stride", stride)] {
            if v == 0 || v > DIM_MAX {
                return Err(format!(
                    "{}: {what} must be in 1..={DIM_MAX}, got {v}",
                    node.name
                ));
            }
        }
        if let Op::Conv1d { .. } = node.op {
            let c_in = self.tensors.get(&node.inputs[0]).map(|t| t.shape[1]);
            let w_rows = self.tensors.get(&node.inputs[1]).map(|t| t.shape[0]);
            if let (Some(c_in), Some(w_rows)) = (c_in, w_rows) {
                if kernel.checked_mul(c_in) != Some(w_rows) {
                    return Err(format!(
                        "{}: weight rows {w_rows} != kernel {kernel} x c_in {c_in} \
                         (im2col weight layout)",
                        node.name
                    ));
                }
            }
        }
        Ok(())
    }

    /// Validate: topological order, every input defined before use,
    /// every referenced tensor declared, operator arity/rank sound.
    pub fn validate(&self) -> Result<(), DeployError> {
        let fail = |reason: String| -> Result<(), DeployError> {
            Err(DeployError::InvalidGraph { graph: self.name.clone(), reason })
        };
        let mut defined: std::collections::BTreeSet<&str> = self
            .tensors
            .values()
            .filter(|t| t.kind == TensorKind::Input || t.kind == TensorKind::Weight)
            .map(|t| t.name.as_str())
            .collect();
        for node in &self.nodes {
            if node.outputs.is_empty() {
                return fail(format!("{}: node produces no outputs", node.name));
            }
            for i in &node.inputs {
                if !self.tensors.contains_key(i) {
                    return fail(format!("{}: undeclared tensor {i}", node.name));
                }
                if !defined.contains(i.as_str()) {
                    return fail(format!("{}: use of {i} before definition", node.name));
                }
            }
            if let Err(reason) = self.check_node_shape(node) {
                return fail(reason);
            }
            for o in &node.outputs {
                if !self.tensors.contains_key(o) {
                    return fail(format!("{}: undeclared output {o}", node.name));
                }
                defined.insert(o);
            }
        }
        for t in self.tensors.values() {
            if t.shape.len() > RANK_MAX {
                return fail(format!("tensor {} rank {} > {RANK_MAX}", t.name, t.shape.len()));
            }
            if let Some(&d) = t.shape.iter().find(|&&d| d == 0 || d > DIM_MAX) {
                return fail(format!(
                    "tensor {} dim {d} outside 1..={DIM_MAX}: {:?}",
                    t.name, t.shape
                ));
            }
            if t.elems() as u64 > ELEMS_MAX {
                return fail(format!(
                    "tensor {} has {} elements (> {ELEMS_MAX}): {:?}",
                    t.name,
                    t.elems(),
                    t.shape
                ));
            }
            if t.kind == TensorKind::Output && !defined.contains(t.name.as_str()) {
                return fail(format!("output {} never produced", t.name));
            }
        }
        Ok(())
    }

    /// Total ops (the paper's accounting: 2 ops per MAC, 1 per
    /// elementwise op, 5 per softmax element). Saturating, like
    /// [`Graph::node_ops`].
    pub fn total_ops(&self) -> u64 {
        self.nodes
            .iter()
            .fold(0u64, |acc, n| acc.saturating_add(self.node_ops(n)))
    }

    /// Op count of one node. Saturating arithmetic throughout: with
    /// [`DIM_MAX`]-bounded dims the products fit u64 for every real
    /// graph, and pathological (unvalidated) graphs saturate instead of
    /// panicking.
    pub fn node_ops(&self, node: &Node) -> u64 {
        let mul = |a: u64, b: u64| a.saturating_mul(b);
        let out = self.tensor(&node.outputs[0]);
        let out_elems = out.elems() as u64;
        match &node.op {
            Op::MatMul | Op::Gemm { .. } => {
                let a = self.tensor(&node.inputs[0]);
                let k = *a.shape.last().unwrap() as u64;
                mul(mul(2, out_elems), k)
            }
            Op::Softmax => mul(5, out_elems),
            Op::LayerNorm => mul(8, out_elems),
            Op::Add | Op::Requant | Op::Act { .. } | Op::Transpose => out_elems,
            Op::Conv1d { .. } => {
                // weight layout (k*cin, cout): reduction dim is shape[0]
                let kcin = self.tensor(&node.inputs[1]).shape[0] as u64;
                mul(mul(2, out_elems), kcin)
            }
            Op::Im2col { .. } => out_elems,
            Op::Mha { heads, proj } => {
                // per head: QK + AV + softmax; projections are separate nodes
                let s = self.tensor(&node.inputs[0]).shape[0] as u64;
                let h = *heads as u64;
                let p = *proj as u64;
                mul(h, mul(mul(4, mul(s, s)), p).saturating_add(mul(5, mul(s, s))))
            }
            Op::AttentionHead { proj } => {
                let s = self.tensor(&node.inputs[0]).shape[0] as u64;
                let p = *proj as u64;
                mul(mul(4, mul(s, s)), p).saturating_add(mul(5, mul(s, s)))
            }
            Op::HeadAcc { heads } => mul(out_elems, *heads as u64),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_graph() -> Graph {
        let mut g = Graph::new("tiny");
        g.add_tensor("x", &[64, 64], DType::I8, TensorKind::Input);
        g.add_tensor("w", &[64, 64], DType::I8, TensorKind::Weight);
        g.add_tensor("b", &[64], DType::I32, TensorKind::Weight);
        g.add_tensor("y", &[64, 64], DType::I8, TensorKind::Output);
        g.add_node(Node::new(
            "gemm0",
            Op::Gemm { act: Activation::Identity },
            &["x", "w", "b"],
            &["y"],
        ));
        g
    }

    #[test]
    fn validates_well_formed() {
        assert!(tiny_graph().validate().is_ok());
    }

    #[test]
    fn rejects_use_before_def() {
        let mut g = tiny_graph();
        g.add_tensor("z", &[64, 64], DType::I8, TensorKind::Activation);
        // node consuming an activation that nothing produces
        g.nodes.insert(
            0,
            Node::new("bad", Op::Add, &["z", "x"], &["z"]),
        );
        assert!(g.validate().is_err());
    }

    #[test]
    fn rejects_unproduced_output() {
        let mut g = tiny_graph();
        g.add_tensor("orphan", &[4], DType::I8, TensorKind::Output);
        assert!(g.validate().is_err());
    }

    #[test]
    fn producer_consumer_queries() {
        let g = tiny_graph();
        assert_eq!(g.producer("y"), Some(0));
        assert_eq!(g.producer("x"), None);
        assert_eq!(g.consumers("x"), vec![0]);
    }

    #[test]
    fn gemm_op_count() {
        let g = tiny_graph();
        // 2 * 64*64 outputs * 64 K
        assert_eq!(g.total_ops(), 2 * 64 * 64 * 64);
    }

    #[test]
    fn rejects_bad_arity_and_rank() {
        // MatMul with a single input
        let mut g = tiny_graph();
        g.add_tensor("m", &[64, 64], DType::I8, TensorKind::Activation);
        g.add_node(Node::new("mm", Op::MatMul, &["y"], &["m"]));
        match g.validate() {
            Err(DeployError::InvalidGraph { reason, .. }) => {
                assert!(reason.contains("inputs"), "{reason}")
            }
            other => panic!("expected InvalidGraph, got {other:?}"),
        }
        // Gemm whose weight operand is 1-D
        let mut g = tiny_graph();
        g.tensors.get_mut("w").unwrap().shape = vec![64];
        assert!(matches!(g.validate(), Err(DeployError::InvalidGraph { .. })));
    }

    #[test]
    fn rejects_conv_weight_layout_mismatch() {
        // weight rows must equal kernel * c_in (im2col layout)
        let mut g = Graph::new("conv");
        g.add_tensor("x", &[64, 80], DType::I8, TensorKind::Input);
        g.add_tensor("w", &[128, 64], DType::I8, TensorKind::Weight); // != 3*80
        g.add_tensor("b", &[64], DType::I32, TensorKind::Weight);
        g.add_tensor("y", &[64, 64], DType::I8, TensorKind::Output);
        g.add_node(Node::new(
            "c0",
            Op::Conv1d { kernel: 3, stride: 1 },
            &["x", "w", "b"],
            &["y"],
        ));
        match g.validate() {
            Err(DeployError::InvalidGraph { reason, .. }) => {
                assert!(reason.contains("weight rows"), "{reason}")
            }
            other => panic!("expected InvalidGraph, got {other:?}"),
        }
        // zero kernel is rejected too
        g.nodes[0].op = Op::Conv1d { kernel: 0, stride: 1 };
        assert!(g.validate().is_err());
    }

    #[test]
    fn rejects_zero_dim_tensor() {
        let mut g = tiny_graph();
        g.tensors.get_mut("b").unwrap().shape = vec![0];
        assert!(g.validate().is_err());
    }

    #[test]
    fn apply_order_reorders_nodes() {
        let mut g = tiny_graph();
        g.add_tensor("y2", &[64, 64], DType::I8, TensorKind::Activation);
        g.add_node(Node::new("add1", Op::Add, &["y", "x"], &["y2"]));
        g.nodes.reverse();
        assert!(g.validate().is_err()); // y consumed before produced
        g.apply_order(&[1, 0]);
        g.validate().unwrap();
        assert_eq!(g.nodes[0].name, "gemm0");
    }
}
