//! Graph transformation passes (paper Section IV-D).
//!
//! `fuse_mha` — the MHA pattern matcher: per attention head it finds
//!   Transpose(K) -> MatMul(Q, K^T) -> Softmax -> MatMul(A, V)
//! and fuses the chain into one `AttentionHead` node. This is the
//! monolithic-MHA-fuse + head-split of the paper collapsed into one
//! rewrite: our frontend (like QuantLib's export) already exposes the
//! per-head chains, so fusion directly yields the head-granular ITA
//! tasks. The standalone Softmax node disappears — ITAMax rides on the
//! accelerator dataflow at zero latency instead of costing a cluster
//! kernel, which is where most of the 208x E2E speedup comes from.
//!
//! `map_operators` — the bottom-up executor assignment: operators the
//! accelerator model supports go to ITA, everything else falls back to
//! optimized cluster kernels.
//!
//! `check_ita_constraints` — the geometric tiling constraints of the
//! accelerator model (all matrix dims multiples of the 64-wide datapath).

use super::ir::{Executor, Graph, Node, Op};
use super::DeployError;

/// Fuse per-head attention chains into `AttentionHead` nodes.
/// Returns the number of heads fused.
pub fn fuse_mha(g: &mut Graph) -> usize {
    let mut fused = 0;
    loop {
        let Some((t_idx, qk_idx, sm_idx, av_idx)) = find_head_chain(g) else {
            break;
        };
        // gather pieces
        let q = g.nodes[qk_idx].inputs[0].clone();
        let k = g.nodes[t_idx].inputs[0].clone();
        let v = g.nodes[av_idx].inputs[1].clone();
        let out = g.nodes[av_idx].outputs[0].clone();
        let qk_rq = (g.nodes[qk_idx].rq_mult, g.nodes[qk_idx].rq_shift);
        let av_rq = (g.nodes[av_idx].rq_mult, g.nodes[av_idx].rq_shift);
        // find_head_chain only matches chains whose V is a declared 2-D tensor
        let proj = g.tensors[&v].shape[1];
        let name = g.nodes[sm_idx].name.replace("sm", "attn").replace(".op", ".fused");

        // the fused node replaces the softmax position; drop the others
        let mut node = Node::new(&name, Op::AttentionHead { proj }, &[], &[]);
        node.inputs = vec![q, k, v];
        node.outputs = vec![out];
        node.rq_mult = qk_rq.0;
        node.rq_shift = qk_rq.1;
        node.rq2_mult = av_rq.0;
        node.rq2_shift = av_rq.1;

        // remove in descending index order to keep indices valid
        let mut to_remove = [t_idx, qk_idx, sm_idx, av_idx];
        to_remove.sort_unstable();
        let insert_at = to_remove[0];
        for idx in to_remove.iter().rev() {
            g.nodes.remove(*idx);
        }
        g.nodes.insert(insert_at, node);
        fused += 1;
    }
    fused
}

/// Find one unfused head chain: returns (transpose, qk-matmul, softmax,
/// av-matmul) node indices.
fn find_head_chain(g: &Graph) -> Option<(usize, usize, usize, usize)> {
    for (sm_idx, sm) in g.nodes.iter().enumerate() {
        if sm.op != Op::Softmax || sm.inputs.is_empty() || sm.outputs.is_empty() {
            continue;
        }
        // producer of the softmax input must be a MatMul
        let Some(qk_idx) = g.producer(&sm.inputs[0]) else {
            continue;
        };
        if g.nodes[qk_idx].op != Op::MatMul || g.nodes[qk_idx].inputs.len() < 2 {
            continue;
        }
        // whose second input comes from a Transpose of a 2-D K (the
        // fused node's K operand: the tiler/codegen read its shape[0])
        let t_idx = match g.producer(&g.nodes[qk_idx].inputs[1]) {
            Some(i) if g.nodes[i].op == Op::Transpose && !g.nodes[i].inputs.is_empty() => i,
            _ => continue,
        };
        match g.tensors.get(&g.nodes[t_idx].inputs[0]) {
            Some(k) if k.shape.len() == 2 => {}
            _ => continue,
        }
        // the softmax output must feed exactly one MatMul (A x V)
        let consumers = g.consumers(&sm.outputs[0]);
        if consumers.len() != 1 {
            continue;
        }
        let av_idx = consumers[0];
        if g.nodes[av_idx].op != Op::MatMul
            || g.nodes[av_idx].inputs.len() < 2
            || g.nodes[av_idx].outputs.is_empty()
        {
            continue;
        }
        // A must be the left operand, V a declared 2-D tensor
        if g.nodes[av_idx].inputs[0] != sm.outputs[0] {
            continue;
        }
        match g.tensors.get(&g.nodes[av_idx].inputs[1]) {
            Some(v) if v.shape.len() == 2 => {}
            _ => continue,
        }
        return Some((t_idx, qk_idx, sm_idx, av_idx));
    }
    None
}

/// Lower Conv1d to im2col + GEMM so the accelerator can run it (the
/// deployment flow maps Linear layers to ITA; the im2col rearrangement
/// is a strided copy on the cluster). Returns the number lowered.
/// The graph must have passed [`Graph::validate`] (arity/rank); this
/// re-checks cheaply and returns [`DeployError::InvalidGraph`] instead
/// of panicking on a malformed conv.
pub fn lower_conv(g: &mut Graph) -> Result<usize, DeployError> {
    let mut lowered = 0;
    loop {
        let Some(idx) = g
            .nodes
            .iter()
            .position(|n| matches!(n.op, Op::Conv1d { .. }))
        else {
            break;
        };
        let (kernel, stride) = match g.nodes[idx].op {
            Op::Conv1d { kernel, stride } => (kernel, stride),
            _ => unreachable!(),
        };
        let node = g.nodes[idx].clone();
        if node.inputs.len() < 3 || node.outputs.is_empty() {
            return Err(DeployError::InvalidGraph {
                graph: g.name.clone(),
                reason: format!("{}: Conv1d needs x, w, b inputs", node.name),
            });
        }
        let x = node.inputs[0].clone();
        let w = node.inputs[1].clone();
        let b = node.inputs[2].clone();
        let out = node.outputs[0].clone();
        let t_out = dim_of(g, &node.name, &out, 0)?;
        let c_in = dim_of(g, &node.name, &x, 1)?;
        let cout = dim_of(g, &node.name, &w, 1)?;
        // pad the im2col reduction dim to ITA's 64 quantum; the padded
        // columns are zero and contribute nothing
        let kcin = (kernel * c_in).div_ceil(64) * 64;
        let col = format!("{}.im2col", node.name);
        g.add_tensor(&col, &[t_out, kcin], crate::deeploy::ir::DType::I8,
                     crate::deeploy::ir::TensorKind::Activation);
        // padded weight view
        let wpad = format!("{}.wpad", node.name);
        g.add_tensor(&wpad, &[kcin, cout], crate::deeploy::ir::DType::I8,
                     crate::deeploy::ir::TensorKind::Weight);

        let im2col = Node::new(
            &format!("{}.im2col.op", node.name),
            Op::Im2col { kernel, stride },
            &[&x],
            &[&col],
        );
        let mut gemm = Node::new(
            &format!("{}.gemm", node.name),
            Op::Gemm { act: super::ir::Activation::Identity },
            &[&col, &wpad, &b],
            &[&out],
        );
        gemm.rq_mult = node.rq_mult;
        gemm.rq_shift = node.rq_shift;
        g.nodes.remove(idx);
        g.nodes.insert(idx, gemm);
        g.nodes.insert(idx, im2col);
        lowered += 1;
    }
    Ok(lowered)
}

/// Dimension `axis` of tensor `name`, or a typed error naming the node.
fn dim_of(g: &Graph, node: &str, name: &str, axis: usize) -> Result<usize, DeployError> {
    g.tensors
        .get(name)
        .and_then(|t| t.shape.get(axis))
        .copied()
        .ok_or_else(|| DeployError::InvalidGraph {
            graph: g.name.clone(),
            reason: format!("{node}: tensor {name} needs dim {axis}"),
        })
}

/// Assign executors bottom-up: ITA takes what its accelerator model
/// supports; the cluster cores take everything else.
pub fn map_operators(g: &mut Graph, use_ita: bool) {
    for node in &mut g.nodes {
        node.executor = if use_ita && ita_supports(&node.op) {
            Executor::Ita
        } else {
            Executor::Cluster
        };
    }
}

/// The ITA accelerator model: operators it can execute.
pub fn ita_supports(op: &Op) -> bool {
    matches!(
        op,
        Op::Gemm { .. } | Op::MatMul | Op::AttentionHead { .. } | Op::Mha { .. }
    )
}

/// Geometric tiling constraints: every ITA-eligible operator must have
/// matrix dims compatible with the 64-wide datapath after padding.
pub fn check_ita_constraints(g: &Graph) -> Result<(), DeployError> {
    for node in &g.nodes {
        if !ita_supports(&node.op) {
            continue;
        }
        for tname in node.inputs.iter().chain(node.outputs.iter()) {
            let Some(t) = g.tensors.get(tname) else {
                return Err(DeployError::InvalidGraph {
                    graph: g.name.clone(),
                    reason: format!("{}: undeclared tensor {tname}", node.name),
                });
            };
            if t.shape.len() == 2 {
                for &d in &t.shape {
                    if d % 64 != 0 {
                        return Err(DeployError::ItaConstraint {
                            node: node.name.clone(),
                            tensor: tname.clone(),
                            dim: d,
                        });
                    }
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_graph_layers, MOBILEBERT};

    #[test]
    fn fuses_all_heads() {
        let mut g = build_graph_layers(&MOBILEBERT, 2);
        let before = g.nodes.len();
        let fused = fuse_mha(&mut g);
        assert_eq!(fused, 2 * MOBILEBERT.heads);
        // each fusion removes 4 nodes, adds 1
        assert_eq!(g.nodes.len(), before - fused * 3);
        g.validate().expect("fused graph validates");
        // no standalone softmax remains
        assert!(!g.nodes.iter().any(|n| n.op == Op::Softmax));
        assert!(g
            .nodes
            .iter()
            .any(|n| matches!(n.op, Op::AttentionHead { proj: 64 })));
    }

    #[test]
    fn fusion_preserves_rq_params() {
        let mut g = build_graph_layers(&MOBILEBERT, 1);
        let qk_rq = g
            .nodes
            .iter()
            .find(|n| n.name.contains("qk0"))
            .map(|n| (n.rq_mult, n.rq_shift))
            .unwrap();
        let av_rq = g
            .nodes
            .iter()
            .find(|n| n.name.contains("av0"))
            .map(|n| (n.rq_mult, n.rq_shift))
            .unwrap();
        fuse_mha(&mut g);
        let head = g
            .nodes
            .iter()
            .find(|n| matches!(n.op, Op::AttentionHead { .. }))
            .unwrap();
        assert_eq!((head.rq_mult, head.rq_shift), qk_rq);
        assert_eq!((head.rq2_mult, head.rq2_shift), av_rq);
    }

    #[test]
    fn mapping_assigns_executors() {
        let mut g = build_graph_layers(&MOBILEBERT, 1);
        fuse_mha(&mut g);
        map_operators(&mut g, true);
        let ita = g.nodes.iter().filter(|n| n.executor == Executor::Ita).count();
        let cluster = g
            .nodes
            .iter()
            .filter(|n| n.executor == Executor::Cluster)
            .count();
        assert!(ita > 0 && cluster > 0);
        for n in &g.nodes {
            match n.op {
                Op::LayerNorm | Op::Add | Op::HeadAcc { .. } => {
                    assert_eq!(n.executor, Executor::Cluster, "{}", n.name)
                }
                Op::AttentionHead { .. } | Op::Gemm { .. } => {
                    assert_eq!(n.executor, Executor::Ita, "{}", n.name)
                }
                _ => {}
            }
        }
    }

    #[test]
    fn multicore_maps_everything_to_cluster() {
        let mut g = build_graph_layers(&MOBILEBERT, 1);
        map_operators(&mut g, false);
        assert!(g.nodes.iter().all(|n| n.executor == Executor::Cluster));
    }

    #[test]
    fn constraints_accept_padded_models() {
        for cfg in crate::models::ALL_MODELS {
            let mut g = build_graph_layers(cfg, 1);
            fuse_mha(&mut g);
            check_ita_constraints(&g).unwrap();
        }
    }

    #[test]
    fn constraints_reject_unpadded() {
        use crate::deeploy::ir::{DType, Graph, Node, TensorKind};
        let mut g = Graph::new("bad");
        g.add_tensor("x", &[100, 64], DType::I8, TensorKind::Input);
        g.add_tensor("w", &[64, 64], DType::I8, TensorKind::Weight);
        g.add_tensor("b", &[64], DType::I32, TensorKind::Weight);
        g.add_tensor("y", &[100, 64], DType::I8, TensorKind::Output);
        g.add_node(Node::new(
            "g",
            Op::Gemm { act: crate::deeploy::ir::Activation::Identity },
            &["x", "w", "b"],
            &["y"],
        ));
        match check_ita_constraints(&g) {
            Err(DeployError::ItaConstraint { tensor, dim, .. }) => {
                assert_eq!((tensor.as_str(), dim), ("x", 100));
            }
            other => panic!("expected ItaConstraint, got {other:?}"),
        }
    }

    #[test]
    fn lower_conv_produces_padded_gemm() {
        let mut g = crate::models::build_stem_graph(&crate::models::WHISPER_TINY_ENC)
            .unwrap();
        let n = lower_conv(&mut g).unwrap();
        assert_eq!(n, 2);
        g.validate().unwrap();
        assert!(!g.nodes.iter().any(|x| matches!(x.op, Op::Conv1d { .. })));
        // conv1: k*cin = 240 -> padded to 256; conv2: 1152 (already x64)
        let col1 = g.tensor("stem/conv1.op.im2col");
        assert_eq!(col1.shape, vec![1024, 256]);
        let col2 = g.tensor("stem/conv2.op.im2col");
        assert_eq!(col2.shape, vec![512, 1152]);
        map_operators(&mut g, true);
        check_ita_constraints(&g).unwrap();
        // the GEMMs go to ITA, the im2col copies stay on the cluster
        for node in &g.nodes {
            match node.op {
                Op::Gemm { .. } => assert_eq!(node.executor, Executor::Ita),
                Op::Im2col { .. } => assert_eq!(node.executor, Executor::Cluster),
                _ => {}
            }
        }
    }

    #[test]
    fn fusion_count_scales_with_heads_and_layers() {
        use crate::models::DINOV2S;
        let mut g = build_graph_layers(&DINOV2S, 3);
        assert_eq!(fuse_mha(&mut g), 3 * DINOV2S.heads);
    }
}
