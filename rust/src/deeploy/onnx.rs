//! ONNX-like JSON graph interchange.
//!
//! Deeploy consumes ONNX; our offline environment has no protobuf, so the
//! same information travels as JSON with the obvious schema:
//!
//! ```json
//! { "name": "net",
//!   "tensors": [{"name":"x","shape":[64,64],"dtype":"i8","kind":"input"}],
//!   "nodes": [{"name":"g0","op":"Gemm","act":"relu",
//!              "inputs":["x","w","b"],"outputs":["y"],
//!              "rq_mult":7,"rq_shift":13}] }
//! ```
//!
//! Export -> import round-trips exactly (tested on the full MobileBERT
//! graph); `examples/import_graph.rs` demonstrates deploying a graph
//! from a JSON file.

use super::ir::{Activation, DType, Executor, Graph, Node, Op, Tensor, TensorKind};
use super::DeployError;
use crate::util::json::Json;

pub fn export(g: &Graph) -> Json {
    let tensors: Vec<Json> = g
        .tensors
        .values()
        .map(|t| {
            Json::obj(vec![
                ("name", Json::str(&t.name)),
                ("shape", Json::Arr(t.shape.iter().map(|&d| Json::num(d as f64)).collect())),
                ("dtype", Json::str(match t.dtype {
                    DType::I8 => "i8",
                    DType::I32 => "i32",
                })),
                ("kind", Json::str(match t.kind {
                    TensorKind::Input => "input",
                    TensorKind::Weight => "weight",
                    TensorKind::Activation => "activation",
                    TensorKind::Output => "output",
                })),
            ])
        })
        .collect();
    let nodes: Vec<Json> = g.nodes.iter().map(export_node).collect();
    Json::obj(vec![
        ("name", Json::str(&g.name)),
        ("tensors", Json::Arr(tensors)),
        ("nodes", Json::Arr(nodes)),
    ])
}

fn export_node(n: &Node) -> Json {
    let mut fields = vec![("name", Json::str(&n.name))];
    let (op, extra): (&str, Vec<(&str, Json)>) = match &n.op {
        Op::MatMul => ("MatMul", vec![]),
        Op::Gemm { act } => ("Gemm", vec![("act", Json::str(act_str(*act)))]),
        Op::Softmax => ("Softmax", vec![]),
        Op::LayerNorm => ("LayerNorm", vec![]),
        Op::Add => ("Add", vec![]),
        Op::Requant => ("Requant", vec![]),
        Op::Act { act } => ("Act", vec![("act", Json::str(act_str(*act)))]),
        Op::Transpose => ("Transpose", vec![]),
        Op::Conv1d { kernel, stride } => (
            "Conv1d",
            vec![
                ("kernel", Json::num(*kernel as f64)),
                ("stride", Json::num(*stride as f64)),
            ],
        ),
        Op::Im2col { kernel, stride } => (
            "Im2col",
            vec![
                ("kernel", Json::num(*kernel as f64)),
                ("stride", Json::num(*stride as f64)),
            ],
        ),
        Op::Mha { heads, proj } => (
            "Mha",
            vec![("heads", Json::num(*heads as f64)), ("proj", Json::num(*proj as f64))],
        ),
        Op::AttentionHead { proj } => {
            ("AttentionHead", vec![("proj", Json::num(*proj as f64))])
        }
        Op::HeadAcc { heads } => ("HeadAcc", vec![("heads", Json::num(*heads as f64))]),
    };
    fields.push(("op", Json::str(op)));
    fields.extend(extra);
    fields.push(("inputs", Json::Arr(n.inputs.iter().map(Json::str).collect())));
    fields.push(("outputs", Json::Arr(n.outputs.iter().map(Json::str).collect())));
    fields.push(("rq_mult", Json::num(n.rq_mult as f64)));
    fields.push(("rq_shift", Json::num(n.rq_shift as f64)));
    fields.push(("rq2_mult", Json::num(n.rq2_mult as f64)));
    fields.push(("rq2_shift", Json::num(n.rq2_shift as f64)));
    Json::obj(fields)
}

fn act_str(a: Activation) -> &'static str {
    match a {
        Activation::Identity => "identity",
        Activation::Relu => "relu",
        Activation::Gelu => "gelu",
    }
}

fn parse_act(s: &str) -> Result<Activation, String> {
    match s {
        "identity" => Ok(Activation::Identity),
        "relu" => Ok(Activation::Relu),
        "gelu" => Ok(Activation::Gelu),
        _ => Err(format!("unknown activation {s}")),
    }
}

/// Import a graph from the ONNX-like JSON schema. Schema violations
/// surface as [`DeployError::Import`]; the node list is normalized into
/// topological order (imported graphs may arrive in any order), so
/// cycles and structural errors surface as their own typed variants.
pub fn import(j: &Json) -> Result<Graph, DeployError> {
    let mut g = import_raw(j).map_err(DeployError::Import)?;
    let order = super::schedule::try_topo_schedule(&g)?;
    g.apply_order(&order);
    g.validate()?;
    Ok(g)
}

fn import_raw(j: &Json) -> Result<Graph, String> {
    let name = j.get("name").and_then(Json::as_str).ok_or("missing name")?;
    let mut g = Graph::new(name);
    for t in j.get("tensors").and_then(Json::as_arr).ok_or("missing tensors")? {
        let tname = t.get("name").and_then(Json::as_str).ok_or("tensor name")?;
        let shape: Vec<usize> = t
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or("tensor shape")?
            .iter()
            .map(|d| d.as_usize().ok_or("bad dim"))
            .collect::<Result<_, _>>()?;
        let dtype = match t.get("dtype").and_then(Json::as_str) {
            Some("i8") => DType::I8,
            Some("i32") => DType::I32,
            other => return Err(format!("bad dtype {other:?}")),
        };
        let kind = match t.get("kind").and_then(Json::as_str) {
            Some("input") => TensorKind::Input,
            Some("weight") => TensorKind::Weight,
            Some("activation") => TensorKind::Activation,
            Some("output") => TensorKind::Output,
            other => return Err(format!("bad kind {other:?}")),
        };
        g.tensors.insert(
            tname.to_string(),
            Tensor { name: tname.to_string(), shape, dtype, kind },
        );
    }
    for n in j.get("nodes").and_then(Json::as_arr).ok_or("missing nodes")? {
        let nname = n.get("name").and_then(Json::as_str).ok_or("node name")?;
        let get_usize = |k: &str| n.get(k).and_then(Json::as_usize).ok_or(format!("{nname}: {k}"));
        let op = match n.get("op").and_then(Json::as_str).ok_or("node op")? {
            "MatMul" => Op::MatMul,
            "Gemm" => Op::Gemm {
                act: parse_act(n.get("act").and_then(Json::as_str).unwrap_or("identity"))?,
            },
            "Softmax" => Op::Softmax,
            "LayerNorm" => Op::LayerNorm,
            "Add" => Op::Add,
            "Requant" => Op::Requant,
            "Act" => Op::Act {
                act: parse_act(n.get("act").and_then(Json::as_str).unwrap_or("identity"))?,
            },
            "Transpose" => Op::Transpose,
            "Conv1d" => Op::Conv1d { kernel: get_usize("kernel")?, stride: get_usize("stride")? },
            "Im2col" => Op::Im2col { kernel: get_usize("kernel")?, stride: get_usize("stride")? },
            "Mha" => Op::Mha { heads: get_usize("heads")?, proj: get_usize("proj")? },
            "AttentionHead" => Op::AttentionHead { proj: get_usize("proj")? },
            "HeadAcc" => Op::HeadAcc { heads: get_usize("heads")? },
            other => return Err(format!("unknown op {other}")),
        };
        let strs = |k: &str| -> Result<Vec<String>, String> {
            Ok(n.get(k)
                .and_then(Json::as_arr)
                .ok_or(format!("{nname}: {k}"))?
                .iter()
                .filter_map(|v| v.as_str().map(str::to_string))
                .collect())
        };
        let mut node = Node::new(nname, op, &[], &[]);
        node.inputs = strs("inputs")?;
        node.outputs = strs("outputs")?;
        node.executor = Executor::Unassigned;
        node.rq_mult = n.get("rq_mult").and_then(Json::as_i64).unwrap_or(1) as i32;
        node.rq_shift = n.get("rq_shift").and_then(Json::as_i64).unwrap_or(0) as u32;
        node.rq2_mult = n.get("rq2_mult").and_then(Json::as_i64).unwrap_or(1) as i32;
        node.rq2_shift = n.get("rq2_shift").and_then(Json::as_i64).unwrap_or(0) as u32;
        g.add_node(node);
    }
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{build_graph_layers, MOBILEBERT, WHISPER_TINY_ENC};

    #[test]
    fn roundtrip_mobilebert() {
        let g = build_graph_layers(&MOBILEBERT, 2);
        let j = export(&g);
        let g2 = import(&j).unwrap();
        assert_eq!(g.name, g2.name);
        assert_eq!(g.tensors.len(), g2.tensors.len());
        assert_eq!(g.nodes.len(), g2.nodes.len());
        for (a, b) in g.nodes.iter().zip(&g2.nodes) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.op, b.op);
            assert_eq!(a.inputs, b.inputs);
            assert_eq!((a.rq_mult, a.rq_shift), (b.rq_mult, b.rq_shift));
        }
    }

    #[test]
    fn roundtrip_through_text() {
        let g = build_graph_layers(&WHISPER_TINY_ENC, 1);
        let text = export(&g).to_string_pretty();
        let j = crate::util::json::Json::parse(&text).unwrap();
        let g2 = import(&j).unwrap();
        assert_eq!(g.nodes.len(), g2.nodes.len());
    }

    #[test]
    fn import_normalizes_shuffled_node_order() {
        let mut g = build_graph_layers(&MOBILEBERT, 1);
        g.nodes.reverse();
        let g2 = import(&export(&g)).unwrap();
        g2.validate().unwrap();
        assert_eq!(g.nodes.len(), g2.nodes.len());
        // the first node must again be the layer's leading LayerNorm
        assert_eq!(g2.nodes[0].name, "L0/ln1.op");
    }

    #[test]
    fn import_cyclic_graph_is_typed() {
        let j = crate::util::json::Json::parse(
            r#"{"name":"loop","tensors":[
                {"name":"x","shape":[4,4],"dtype":"i8","kind":"input"},
                {"name":"a","shape":[4,4],"dtype":"i8","kind":"activation"},
                {"name":"b","shape":[4,4],"dtype":"i8","kind":"activation"}],
              "nodes":[
                {"name":"n0","op":"Add","inputs":["x","b"],"outputs":["a"]},
                {"name":"n1","op":"Add","inputs":["a","x"],"outputs":["b"]}]}"#,
        )
        .unwrap();
        assert!(matches!(
            import(&j),
            Err(crate::deeploy::DeployError::CyclicGraph { .. })
        ));
    }

    #[test]
    fn import_rejects_invalid() {
        let j = crate::util::json::Json::parse(r#"{"name":"x","tensors":[],"nodes":[]}"#).unwrap();
        assert!(import(&j).is_ok()); // empty is fine
        let j = crate::util::json::Json::parse(
            r#"{"name":"x","tensors":[],"nodes":[{"name":"n","op":"Nope","inputs":[],"outputs":[]}]}"#,
        )
        .unwrap();
        assert!(import(&j).is_err());
    }
}
