//! Tensor lifetime analysis.
//!
//! Attention graphs branch heavily (per-head chains all fan out of one
//! LayerNorm and fan back into the head accumulation), so naive
//! stack-like allocation fails — this is the "novel lifetime analysis"
//! requirement of Section II-B. Given a schedule order, each activation
//! tensor is live from its producing step to its last consuming step;
//! the static allocator then packs intervals that never overlap in time
//! into overlapping memory.

use std::collections::BTreeMap;

use super::ir::{Graph, TensorKind};

/// Live interval of one tensor in schedule-step indices, inclusive.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Interval {
    pub tensor: String,
    pub start: usize,
    pub end: usize,
    pub bytes: usize,
}

/// Compute live intervals of all activation tensors under `order`
/// (indices into g.nodes in execution order).
pub fn analyze(g: &Graph, order: &[usize]) -> Vec<Interval> {
    // map node index -> schedule position
    let mut pos = BTreeMap::new();
    for (p, &n) in order.iter().enumerate() {
        pos.insert(n, p);
    }
    let mut birth: BTreeMap<&str, usize> = BTreeMap::new();
    let mut death: BTreeMap<&str, usize> = BTreeMap::new();
    for (&node_idx, &p) in &pos {
        let node = &g.nodes[node_idx];
        for o in &node.outputs {
            let e = birth.entry(o).or_insert(p);
            *e = (*e).min(p);
        }
        for i in &node.inputs {
            let e = death.entry(i).or_insert(p);
            *e = (*e).max(p);
        }
    }
    let mut out = Vec::new();
    for t in g.tensors.values() {
        let relevant = matches!(t.kind, TensorKind::Activation | TensorKind::Input | TensorKind::Output);
        if !relevant {
            continue; // weights stream from L2, not allocated here
        }
        let start = match t.kind {
            TensorKind::Input => 0,
            _ => match birth.get(t.name.as_str()) {
                Some(&s) => s,
                None => continue, // dead tensor
            },
        };
        let end = match t.kind {
            TensorKind::Output => order.len().saturating_sub(1),
            _ => match death.get(t.name.as_str()) {
                Some(&e) => e,
                None => start, // produced but never consumed
            },
        };
        out.push(Interval { tensor: t.name.clone(), start, end: end.max(start), bytes: t.bytes() });
    }
    out.sort_by(|a, b| (a.start, a.tensor.clone()).cmp(&(b.start, b.tensor.clone())));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::deeploy::schedule::topo_schedule;
    use crate::models::{build_graph_layers, MOBILEBERT};

    #[test]
    fn intervals_are_well_formed() {
        let g = build_graph_layers(&MOBILEBERT, 2);
        let order = topo_schedule(&g);
        let ivs = analyze(&g, &order);
        assert!(!ivs.is_empty());
        for iv in &ivs {
            assert!(iv.start <= iv.end, "{:?}", iv);
            assert!(iv.bytes > 0);
        }
    }

    #[test]
    fn producer_before_consumers() {
        let g = build_graph_layers(&MOBILEBERT, 1);
        let order = topo_schedule(&g);
        let ivs = analyze(&g, &order);
        // the attention output of layer 0 must outlive all its consumers
        let attn = ivs.iter().find(|i| i.tensor == "L0/attn").unwrap();
        assert!(attn.end > attn.start);
    }

    #[test]
    fn branching_heads_are_simultaneously_live() {
        // all H per-head QK score matrices overlap in time with each
        // other's chains — the branching structure the paper calls out
        let g = build_graph_layers(&MOBILEBERT, 1);
        let order = topo_schedule(&g);
        let ivs = analyze(&g, &order);
        let ln1 = ivs.iter().find(|i| i.tensor == "L0/ln1").unwrap();
        // ln1 feeds every head's projections: it must stay live until the
        // last head's V projection
        let v3 = ivs.iter().find(|i| i.tensor == "L0/v3").unwrap();
        assert!(ln1.end >= v3.start - 1, "ln1 {:?} vs v3 {:?}", ln1, v3);
    }

    #[test]
    fn residual_input_lives_across_attention() {
        // x0 feeds both ln1 (step 0) and the residual add after the
        // whole attention block — a long-lived interval
        let g = build_graph_layers(&MOBILEBERT, 1);
        let order = topo_schedule(&g);
        let ivs = analyze(&g, &order);
        let x0 = ivs.iter().find(|i| i.tensor == "x0").unwrap();
        let span = x0.end - x0.start;
        assert!(span > MOBILEBERT.heads * 5, "x0 span {span}");
    }
}
